//! Multi-stage prune→fine-tune of the trainable proxy model under every
//! sparsity pattern — the accuracy-mechanism validation behind Fig. 6c/8
//! (the surrogate curves carry the paper-scale magnitudes; this run shows
//! the *ordering* emerges from real training + real pruning).
//!
//!   cargo run --release --example prune_model

use tilewise::accuracy::{prune_finetune_sweep, Task};
use tilewise::sparse::Pattern;

fn main() {
    let task = Task::synth(64, 8, 3000, 1000, 2024);
    let sparsities = [0.5, 0.75, 0.875, 0.9375, 0.96875];
    let hidden = 48;

    let patterns: Vec<(&str, Pattern)> = vec![
        ("EW", Pattern::Ew),
        ("VW-4", Pattern::Vw { m: 4 }),
        ("BW-16", Pattern::Bw { g: 16 }),
        ("TW-8", Pattern::Tw { g: 8 }),
        ("TEW-5%", Pattern::Tew { g: 8, delta_pct: 5 }),
        ("TVW-4", Pattern::Tvw { g: 8, m: 4 }),
    ];

    println!("proxy MLP (64->48->8) on synthetic clusters; multi-stage prune + fine-tune");
    print!("{:<8}", "pattern");
    for s in sparsities {
        print!("{:>9}", format!("{:.1}%", s * 100.0));
    }
    println!();

    let mut results = Vec::new();
    for (label, p) in &patterns {
        let pts = prune_finetune_sweep(&task, *p, &sparsities, hidden, 7);
        print!("{label:<8}");
        for pt in &pts {
            print!("{:>9.3}", pt.accuracy);
        }
        println!();
        results.push((label.to_string(), pts));
    }

    // the paper's qualitative claims, checked on real training runs:
    let acc = |label: &str, idx: usize| {
        results.iter().find(|(l, _)| l == label).map(|(_, p)| p[idx].accuracy).unwrap()
    };
    println!("\nchecks (at 93.75% sparsity, tolerance 0.05):");
    let checks = [
        ("EW >= TW (unstructured dominates)", acc("EW", 3) + 0.05 >= acc("TW-8", 3)),
        ("TW >= BW (finer structure wins)", acc("TW-8", 3) + 0.05 >= acc("BW-16", 3)),
        ("TEW >= TW (remedy helps)", acc("TEW-5%", 3) + 0.05 >= acc("TW-8", 3)),
        ("TVW >= TW (register-level freedom)", acc("TVW-4", 3) + 0.05 >= acc("TW-8", 3)),
    ];
    let mut all_ok = true;
    for (desc, ok) in checks {
        println!("  [{}] {desc}", if ok { "ok" } else { "MISS" });
        all_ok &= ok;
    }
    if !all_ok {
        println!("  (single-seed noise can flip a check; the ignored lib test");
        println!("   accuracy_ordering_matches_paper covers the averaged case)");
    }
}
