//! Algorithm 1 end-to-end through the three-layer stack: the Rust driver
//! runs the paper's multi-stage prune → fine-tune loop on the transformer
//! using the AOT-compiled train-step artifact — pruning decisions in Rust
//! (`sparse::prune_tw`), gradient steps through PJRT, zero Python.
//!
//! Stages: fine-tune dense -> prune TW to 25% -> fine-tune (masked) ->
//! 50% -> fine-tune -> 75% -> fine-tune; the mask is re-applied after
//! every step (the pruning-aware training contract).
//!
//!   make artifacts && cargo run --release --example finetune_prune

use tilewise::runtime::{Engine, InputData};
use tilewise::sparse::prune_tw;
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

fn main() -> tilewise::error::Result<()> {
    let dir = std::path::Path::new("artifacts");
    tilewise::ensure!(dir.join("meta.json").exists(), "run `make artifacts` first");
    let engine = Engine::load_only(dir, &["train_dense"])?;
    let model = engine.model("train_dense")?;

    let x_shape = &model.inputs[0].0; // (B, S, D)
    let (b, s, d) = (x_shape[0], x_shape[1], x_shape[2]);
    let n_params = model.output_shapes.len() - 1;
    println!("train_dense: batch={b} seq={s} d_model={d}, {n_params} parameter tensors");

    // synthetic classification task: labels depend on the mean activation
    // of a class-specific slice of the input — learnable, non-trivial
    let n_classes = 8usize;
    let mut rng = Rng::new(77);
    let make_batch = |rng: &mut Rng| {
        let mut x = vec![0.0f32; b * s * d];
        let mut y = vec![0i32; b];
        for i in 0..b {
            let class = rng.below(n_classes);
            y[i] = class as i32;
            for t in 0..s {
                for f in 0..d {
                    let bias = if f / (d / n_classes) == class { 1.2 } else { 0.0 };
                    x[(i * s + t) * d + f] = rng.normal_f32() + bias;
                }
            }
        }
        (x, y)
    };

    // seed params from the artifact's initial values via one step-0 call
    let (x0, y0) = make_batch(&mut rng);
    let outs = engine.run_multi(model, &[InputData::F32(&x0), InputData::I32(&y0)])?;
    let mut params: Vec<Vec<f32>> = outs[1..].to_vec();
    println!("initial loss {:.4}", outs[0][0]);

    // the prunable weights are the first 8 tensors (2 layers x qkv/wo/w1/w2);
    // output_shapes[1..9] carry their (K, N) shapes
    let prunable: Vec<(usize, usize, usize)> = model.output_shapes[1..]
        .iter()
        .enumerate()
        .filter(|(_, sh)| sh.len() == 2 && sh[0] >= 64)
        .map(|(i, sh)| (i, sh[0], sh[1]))
        .collect();
    println!("prunable tensors: {}", prunable.len());

    let mut masks: Vec<Option<Vec<bool>>> = vec![None; params.len()];
    let stage_sparsities = [0.0, 0.25, 0.5, 0.75];
    let steps_per_stage = 60;
    let g = 64;

    for (stage, &target) in stage_sparsities.iter().enumerate() {
        if target > 0.0 {
            // prune each weight to TW at the stage target (Algorithm 1 line 5)
            let mut total_kept = 0usize;
            let mut total = 0usize;
            for &(pi, k, n) in &prunable {
                let w = Matrix::from_vec(k, n, params[pi].clone());
                let tw = prune_tw(&w, target, g, None);
                let mask = tw.mask();
                for (v, keep) in params[pi].iter_mut().zip(&mask.keep) {
                    if !keep {
                        *v = 0.0;
                    }
                }
                total_kept += mask.count_kept();
                total += mask.keep.len();
                masks[pi] = Some(mask.keep);
            }
            println!(
                "stage {stage}: pruned to TW-{g} target {target} (achieved {:.3})",
                1.0 - total_kept as f64 / total as f64
            );
        }
        // fine-tune with the mask re-applied after every step (line 6)
        let mut last_loss = f32::NAN;
        for step in 0..steps_per_stage {
            let (x, y) = make_batch(&mut rng);
            let refs: Vec<&[f32]> = params.iter().map(Vec::as_slice).collect();
            let outs = engine.run_train_iteration(model, &x, &y, &refs)?;
            last_loss = outs[0][0];
            for (pi, new) in outs[1..].iter().enumerate() {
                params[pi].copy_from_slice(new);
                if let Some(mask) = &masks[pi] {
                    for (v, keep) in params[pi].iter_mut().zip(mask) {
                        if !keep {
                            *v = 0.0;
                        }
                    }
                }
            }
            if step % 20 == 19 {
                println!("  stage {stage} step {:>3}: loss {:.4}", step + 1, last_loss);
            }
        }
        let _ = last_loss;
    }

    // verify the final weights still satisfy the masks
    for (pi, mask) in masks.iter().enumerate() {
        if let Some(mask) = mask {
            let violations =
                params[pi].iter().zip(mask).filter(|(v, k)| !**k && **v != 0.0).count();
            assert_eq!(violations, 0, "param {pi} has resurrected weights");
        }
    }
    println!("final weights satisfy the 75% TW masks — Algorithm 1 pipeline complete");
    Ok(())
}
