//! Serve the model zoo through the layer-graph IR (DESIGN.md §6).
//!
//! Compiles each zoo model — BERT encoder, VGG conv chain, NMT stacked
//! LSTM — into per-variant graph programs (weights pruned and packed once
//! into dense / TW fused-CTO / TVW forms), then drives the full serving
//! stack (router + dynamic batcher + worker pool) against every variant
//! and reports per-variant latency percentiles.
//!
//!   cargo run --release --example serve_zoo [bert|vgg|nmt]

use std::sync::Arc;
use std::time::Duration;

use tilewise::coordinator::{start_with_backend, BatcherConfig, Policy, ServerConfig};
use tilewise::exec::{Backend, ZooBackend, ZooSpec};
use tilewise::util::Rng;

fn main() -> tilewise::error::Result<()> {
    let only = std::env::args().nth(1);
    let models: Vec<&str> = match only.as_deref() {
        Some(m) => vec![match m {
            "bert" => "bert",
            "vgg" => "vgg",
            "nmt" => "nmt",
            other => {
                eprintln!("unknown zoo model {other:?} (expected bert|vgg|nmt)");
                std::process::exit(2);
            }
        }],
        None => vec!["bert", "vgg", "nmt"],
    };
    let variants = ["model_dense", "model_tw", "model_tvw"];
    let requests = 32;

    for model in models {
        let spec = ZooSpec::for_model(model)?;
        println!(
            "== {model}: compiling {} variant graphs (sparsity {:.0}%, G={}) ==",
            variants.len(),
            spec.sparsity * 100.0,
            spec.g
        );
        let t0 = std::time::Instant::now();
        let backend: Arc<dyn Backend> = Arc::new(ZooBackend::new(spec, None)?);
        println!("packed in {:.2}s", t0.elapsed().as_secs_f64());

        for variant in variants {
            let cfg = ServerConfig {
                batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
                policy: Policy::Fixed(variant.into()),
                workers: 2,
                ..ServerConfig::default()
            };
            let handle = start_with_backend(backend.clone(), cfg)?;
            let len = handle.seq * handle.d_model;
            let mut rng = Rng::new(7);
            let pending: Vec<_> = (0..requests)
                .map(|_| {
                    let x: Vec<f32> = (0..len).map(|_| rng.normal_f32() * 0.3).collect();
                    handle.submit(x, None)
                })
                .collect();
            let mut ok = 0;
            for rx in pending {
                if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
                    ok += 1;
                }
            }
            for s in handle.metrics.snapshot() {
                println!(
                    "  {:<12} n={:<3} ok={ok:<3} mean={:>7.2}ms p50={:>7.2}ms p99={:>7.2}ms batch={:.1}",
                    s.variant, s.count, s.mean_ms, s.p50_ms, s.p99_ms, s.mean_batch
                );
            }
        }
        println!();
    }
    println!(
        "note: every model above ran end-to-end through the compiled layer\n\
         graph — img2col, attention, LSTM steps, and all GEMMs through the\n\
         packed TW/TVW kernels — with zero per-request allocations in graph\n\
         execution (the workspace arena is reused across requests)."
    );
    Ok(())
}
