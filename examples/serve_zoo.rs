//! Serve the model zoo through the layer-graph IR (DESIGN.md §6/§7).
//!
//! Compiles each zoo model — BERT encoder, VGG conv chain, NMT stacked
//! LSTM — into per-variant graph programs (weights pruned and packed once
//! into dense / TW fused-CTO / TVW forms), then drives the full serving
//! stack (router + dynamic batcher + worker pool) against every variant
//! and reports per-variant latency percentiles plus the dynamic-batch
//! occupancy summary (mean occupancy, padded rows avoided).
//!
//! By default requests are injected in a closed-loop burst; with
//! `--arrival-rate R` they arrive open-loop at `R` req/s instead, which
//! is where dynamic effective-batch serving shines: partial batches cost
//! partial compute (compare with `--padded`).
//!
//!   cargo run --release --example serve_zoo [bert|vgg|nmt|decoder]
//!       [--arrival-rate R] [--padded] [--requests N]
//!
//! The decode-capable models (nmt, decoder) also demonstrate the
//! streaming session API: `ServerHandle::submit_decode` returns a
//! `ResponseStream` of per-step `StreamEvent::Token`s driven by the
//! continuous-batching decode lane.

use std::sync::Arc;
use std::time::{Duration, Instant};

use tilewise::coordinator::{start_with_backend, BatcherConfig, Policy, ServerConfig};
use tilewise::exec::{Backend, ZooBackend, ZooSpec};
use tilewise::util::Rng;
use tilewise::variant::Variant;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> tilewise::error::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let arrival_rate: Option<f64> = flag(&args, "--arrival-rate").and_then(|v| v.parse().ok());
    let dynamic_batch = !args.iter().any(|a| a == "--padded");
    let requests: usize = flag(&args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(32);
    // the positional model name: skip flags AND the value token following
    // a value-taking flag (`--arrival-rate 20` must not parse "20" as a
    // model)
    let value_flags = ["--arrival-rate", "--requests"];
    let mut only: Option<String> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if value_flags.contains(&a.as_str()) {
            it.next();
        } else if !a.starts_with("--") {
            only = Some(a.clone());
            break;
        }
    }
    let models: Vec<&str> = match only.as_deref() {
        Some("bert") => vec!["bert"],
        Some("vgg") => vec!["vgg"],
        Some("nmt") => vec!["nmt"],
        Some("decoder") => vec!["decoder"],
        Some(other) => {
            eprintln!("unknown zoo model {other:?} (expected bert|vgg|nmt|decoder)");
            std::process::exit(2);
        }
        None => vec!["bert", "vgg", "nmt"],
    };
    let variants = [Variant::Dense, Variant::Tw, Variant::Tvw];

    for model in models {
        let spec = ZooSpec::for_model(model)?;
        println!(
            "== {model}: compiling {} variant graphs (sparsity {:.0}%, G={}) — {} execution ==",
            variants.len(),
            spec.sparsity * 100.0,
            spec.g,
            if dynamic_batch { "dynamic-M" } else { "padded" }
        );
        let t0 = Instant::now();
        let mut zoo = ZooBackend::new(spec, None)?;
        // per-node graph profiling: shared by every worker's model instance
        let tele = zoo.enable_telemetry();
        let backend: Arc<dyn Backend> = Arc::new(zoo);
        println!("packed in {:.2}s", t0.elapsed().as_secs_f64());

        for variant in variants {
            let cfg = ServerConfig {
                // open-loop partial load pairs naturally with the
                // low-latency batcher: dispatch what has arrived
                batcher: if arrival_rate.is_some() {
                    BatcherConfig::low_latency(8)
                } else {
                    BatcherConfig {
                        max_batch: 8,
                        max_wait: Duration::from_millis(2),
                        ..BatcherConfig::default()
                    }
                },
                policy: Policy::Fixed(variant),
                workers: 2,
                dynamic_batch,
                ..ServerConfig::default()
            };
            let handle = start_with_backend(backend.clone(), cfg)?;
            let len = handle.seq * handle.d_model;
            let mut rng = Rng::new(7);
            let t_inject = Instant::now();
            let pending: Vec<_> = (0..requests)
                .map(|i| {
                    if let Some(rate) = arrival_rate {
                        // open-loop: submit on the wall-clock schedule,
                        // independent of response progress
                        let target = Duration::from_secs_f64(i as f64 / rate.max(1e-9));
                        if let Some(sleep) = target.checked_sub(t_inject.elapsed()) {
                            std::thread::sleep(sleep);
                        }
                    }
                    let x: Vec<f32> = (0..len).map(|_| rng.normal_f32() * 0.3).collect();
                    handle.submit(x, None)
                })
                .collect();
            let mut ok = 0;
            for stream in pending {
                if stream.wait().is_ok() {
                    ok += 1;
                }
            }
            let wall = t_inject.elapsed().as_secs_f64();
            let snap = handle.metrics.full_snapshot();
            for s in &snap.variants {
                println!(
                    "  {:<12} n={:<3} ok={ok:<3} mean={:>7.2}ms p50={:>7.2}ms p99={:>7.2}ms \
                     batch={:.1} occ={:>3.0}% | {:.1} req/s, {} padded rows avoided",
                    s.variant,
                    s.count,
                    s.mean_ms,
                    s.p50_ms,
                    s.p99_ms,
                    s.mean_batch,
                    s.mean_occupancy * 100.0,
                    ok as f64 / wall,
                    snap.padded_rows_avoided
                );
            }
            // where the end-to-end latency went: queue-wait -> batch
            // assembly -> pack -> execute -> respond
            for vs in snap.stages.iter().filter(|vs| vs.variant == variant.name()) {
                let cols: Vec<String> = vs
                    .stages
                    .iter()
                    .map(|st| format!("{} {:.2}ms", st.stage, st.mean_ms))
                    .collect();
                println!("    stages: {}", cols.join(" | "));
            }
        }
        // streaming decode showcase: the decode-capable models (nmt,
        // decoder) additionally run a handful of autoregressive sessions
        // through the continuous-batching step scheduler, each streaming
        // one token event per step
        {
            let cfg = ServerConfig::builder().policy(Policy::Fixed(Variant::Tw)).build()?;
            let handle = start_with_backend(backend.clone(), cfg)?;
            if let Some(caps) = handle.decode_caps {
                let mut rng = Rng::new(11);
                let streams: Vec<_> = (0..4)
                    .map(|i| {
                        let rows = 1 + i % (caps.max_steps / 2).max(1);
                        let new_tokens = (caps.max_steps - rows).min(3).max(1);
                        let prompt: Vec<f32> =
                            (0..rows * caps.d_in).map(|_| rng.normal_f32() * 0.3).collect();
                        handle.submit_decode(prompt, None, new_tokens)
                    })
                    .collect();
                let mut tokens = 0usize;
                for stream in streams {
                    if let Ok(resp) = stream.wait() {
                        tokens += resp.tokens;
                    }
                }
                let d = handle.metrics.decode_stats();
                println!(
                    "  decode: 4 sessions -> {tokens} tokens, {:.1} tok/s, \
                     mean active slots {:.2}, step p95 {:.3}ms",
                    d.tokens_per_sec, d.mean_active_slots, d.step_p95_ms
                );
            }
        }
        // Fig. 10-style attribution: the slowest GEMM nodes per variant,
        // accumulated over everything this model just served
        for vp in tele.variants() {
            let mut nodes: Vec<_> = vp.nodes.iter().filter(|n| n.calls() > 0).collect();
            nodes.sort_by(|a, b| b.secs().total_cmp(&a.secs()));
            if nodes.is_empty() {
                continue;
            }
            let top: Vec<String> = nodes
                .iter()
                .take(3)
                .map(|n| format!("{} {:.2}ms ({:.1} GFLOP/s)", n.name, n.secs() * 1e3, n.gflops()))
                .collect();
            println!("  slowest GEMM nodes [{}]: {}", vp.variant, top.join(", "));
        }
        println!();
    }
    println!(
        "note: every model above ran end-to-end through the compiled layer\n\
         graph — img2col, attention, LSTM steps, and all GEMMs through the\n\
         packed TW/TVW kernels — with zero per-request allocations in graph\n\
         execution (the workspace arena is reused across requests; under\n\
         dynamic-M a partial batch shrinks it to the live prefix, so\n\
         occupancy below 100% is compute actually saved, not padding)."
    );
    Ok(())
}
