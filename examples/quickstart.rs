//! Quickstart: the smallest end-to-end tour of the tilewise API.
//!
//! 1. prune a weight matrix to the TW pattern (Algorithm 3),
//! 2. encode the condensed CTO plan,
//! 3. run the fused-CTO GEMM on the CPU and check it against the oracle,
//! 4. run the same computation through the AOT-compiled PJRT artifact,
//! 5. ask the gpusim what the speedup would be on an A100.
//!
//!   cargo run --release --example quickstart

use tilewise::gemm::{matmul, tw_matmul};
use tilewise::gpusim::{self, Calibration, GemmShape, Pipe, TwStrategy};
use tilewise::runtime::Engine;
use tilewise::sparse::{prune_tw, TwPlan};
use tilewise::tensor::Matrix;
use tilewise::util::{Rng, Stopwatch};

fn main() -> tilewise::error::Result<()> {
    // --- 1. prune ---------------------------------------------------------
    let mut rng = Rng::new(42);
    let (m, k, n, g, sparsity) = (256usize, 512usize, 512usize, 64usize, 0.75);
    let w = Matrix::randn(k, n, &mut rng);
    let a = Matrix::randn(m, k, &mut rng);
    let tw = prune_tw(&w, sparsity, g, None);
    println!(
        "pruned {}x{} to TW-{g}: {} tiles, sparsity {:.3}",
        k, n, tw.num_tiles(), tw.sparsity()
    );

    // --- 2. encode the CTO plan -------------------------------------------
    let plan = TwPlan::encode(&w, &tw);
    println!(
        "CTO plan: kmax={} storage {:.1} KiB (dense would be {:.1} KiB)",
        plan.kmax,
        plan.storage_bytes() as f64 / 1024.0,
        (k * n * 4) as f64 / 1024.0
    );

    // --- 3. fused-CTO GEMM on the CPU vs the mask oracle ------------------
    let sw = Stopwatch::start();
    let c_tw = tw_matmul(&a, &plan);
    let t_tw = sw.micros();
    let sw = Stopwatch::start();
    let c_ref = matmul(&a, &tw.mask().apply(&w));
    let t_dense = sw.micros();
    println!(
        "CPU fused-CTO GEMM: {:.0}us vs dense-masked {:.0}us, max|diff|={:.2e}",
        t_tw, t_dense, c_tw.max_abs_diff(&c_ref)
    );
    assert!(c_tw.max_abs_diff(&c_ref) < 1e-3);

    // --- 4. same computation via the AOT PJRT artifact --------------------
    let dir = std::path::Path::new("artifacts");
    if dir.join("meta.json").exists() {
        let engine = Engine::load_only(dir, &["gemm_tw", "gemm_dense"])?;
        let model = engine.model("gemm_tw")?;
        let act: Vec<f32> = {
            let rows = model.activation_shape[0];
            let cols = model.activation_shape[1];
            let mut r2 = Rng::new(7);
            (0..rows * cols).map(|_| r2.normal_f32()).collect()
        };
        let sw = Stopwatch::start();
        let out = engine.run(model, &act)?;
        println!(
            "PJRT gemm_tw artifact: output {:?} in {:.0}us (Pallas TW kernel lowered via XLA)",
            model.output_shape,
            sw.micros()
        );
        assert!(out.iter().all(|v| v.is_finite()));
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the PJRT leg)");
    }

    // --- 5. what would an A100 do? ----------------------------------------
    let specs = gpusim::a100();
    let cal = Calibration::default();
    let shape = GemmShape::new(m, k, n);
    let dense = gpusim::dense_plan(shape, Pipe::TensorFp16, &specs, &cal).latency(&specs);
    let tiles = gpusim::tw_tiles_from_plan(&plan);
    let twl = gpusim::tw_latency(shape, &tiles, g, Pipe::TensorFp16, TwStrategy::FusedCto, &specs, &cal);
    println!(
        "gpusim A100 estimate: dense-TC {:.1}us, TW-{g} {:.1}us -> {:.2}x speedup",
        dense * 1e6,
        twl * 1e6,
        dense / twl
    );
    Ok(())
}
