//! End-to-end serving driver (the DESIGN.md §4 validation run).
//!
//! Starts the full serving stack (router + dynamic batcher + worker pool)
//! over an execution backend, drives it with a Poisson open-loop client,
//! and reports per-variant latency percentiles + throughput.
//!
//! With an artifact directory (`make artifacts` + `--features pjrt`) the
//! PJRT engine executes the AOT executables; without one the example
//! degrades to the native backend, which compiles the residual-MLP spec
//! into a layer graph (DESIGN.md §6), packs TW/TVW/2:4 plans at load, and
//! runs the paper's CPU kernels in-process — so this example works on a
//! bare checkout.  `examples/serve_zoo.rs` does the same for the real
//! zoo models (BERT / VGG / NMT).
//!
//!   cargo run --release --example serve_transformer [artifact_dir]

use std::sync::Arc;
use std::time::Duration;

use tilewise::coordinator::{
    start, start_with_backend, BatcherConfig, Policy, ServerConfig, ServerHandle,
};
use tilewise::exec::{Backend, NativeBackend, NativeModelSpec};
use tilewise::util::Rng;
use tilewise::variant::Variant;

fn drive(handle: &ServerHandle, requests: usize, rate_rps: f64) {
    let len = handle.seq * handle.d_model;
    let mut rng = Rng::new(99);

    // open-loop Poisson arrivals; every submission is a ResponseStream
    // (a one-shot forward is a single-Done stream, waited on below)
    let mut pending = Vec::with_capacity(requests);
    let t0 = std::time::Instant::now();
    for _ in 0..requests {
        let x: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        pending.push(handle.submit(x, None));
        std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate_rps)));
    }
    let mut completed = 0usize;
    for stream in pending {
        if stream.wait().is_ok() {
            completed += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    for s in handle.metrics.snapshot() {
        println!(
            "{:<12} n={:<4} mean={:>7.2}ms p50={:>7.2}ms p95={:>7.2}ms p99={:>7.2}ms batch={:.1} throughput={:.1} req/s",
            s.variant, s.count, s.mean_ms, s.p50_ms, s.p95_ms, s.p99_ms, s.mean_batch,
            completed as f64 / wall
        );
    }
}

fn variant_cfg(variant: Variant, workers: usize) -> ServerConfig {
    ServerConfig::builder()
        .batcher(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(3),
            ..BatcherConfig::default()
        })
        .policy(Policy::Fixed(variant))
        .variants(vec![variant])
        .workers(workers)
        .build()
        .expect("static example config")
}

fn main() -> tilewise::error::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let requests = 96;
    let rate = 60.0;
    let variants = [Variant::Dense, Variant::Tw, Variant::Tvw];

    if dir.join("meta.json").exists() {
        println!(
            "serving {requests} Poisson requests at {rate} req/s against each PJRT variant\n\
             (batch=8, max_wait=3ms; BERT-mini encoder, seq x d_model activations)\n"
        );
        for variant in variants {
            let handle = start(&dir, variant_cfg(variant, 1))?;
            drive(&handle, requests, rate);
        }
        println!(
            "\nnote: on this CPU substrate the TW/TVW executables trade FLOPs for\n\
             gather/scatter ops; the A100-level speedups are what gpusim + the\n\
             fig10 bench estimate. The serving stack (routing, batching, PJRT\n\
             execution, zero Python) is exactly the deployment path."
        );
        return Ok(());
    }

    let workers = std::thread::available_parallelism().map(|x| x.get().min(4)).unwrap_or(1);
    println!(
        "artifacts not found at {} — serving through the native backend\n\
         ({requests} Poisson requests at {rate} req/s per variant, {workers} workers,\n\
         weights packed once into CTO/2:4 plans, real gemm kernels)\n",
        dir.display()
    );
    // pack once, share the plans across every variant's server + workers
    let backend: Arc<dyn Backend> =
        Arc::new(NativeBackend::new(NativeModelSpec::default(), None)?);
    for variant in variants {
        let handle = start_with_backend(backend.clone(), variant_cfg(variant, workers))?;
        drive(&handle, requests, rate);
    }
    println!(
        "\nnote: the native backend runs the paper's condensed TW/TVW kernels\n\
         in-process — the same serving stack, no artifacts and no Python."
    );
    Ok(())
}
