//! End-to-end serving driver (the DESIGN.md §4 validation run).
//!
//! Loads the dense / TW / TVW transformer artifacts, starts the full
//! serving stack (router + dynamic batcher + PJRT executor), drives it
//! with a Poisson open-loop client, and reports per-variant latency
//! percentiles + throughput.  The numbers land in EXPERIMENTS.md.
//!
//!   make artifacts && cargo run --release --example serve_transformer

use std::time::Duration;

use tilewise::coordinator::{start, BatcherConfig, Policy, ServerConfig};
use tilewise::util::Rng;

fn run_load(
    dir: &std::path::Path,
    variant: &str,
    requests: usize,
    rate_rps: f64,
) -> tilewise::error::Result<()> {
    let cfg = ServerConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(3) },
        policy: Policy::Fixed(variant.to_string()),
        variants: vec![variant.to_string()],
        ..ServerConfig::default()
    };
    let handle = start(dir, cfg)?;
    let len = handle.seq * handle.d_model;
    let mut rng = Rng::new(99);

    // open-loop Poisson arrivals
    let mut pending = Vec::with_capacity(requests);
    let t0 = std::time::Instant::now();
    for _ in 0..requests {
        let x: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        pending.push(handle.submit(x, None));
        std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate_rps)));
    }
    let mut completed = 0usize;
    for rx in pending {
        if rx.recv().is_ok() {
            completed += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    for s in handle.metrics.snapshot() {
        println!(
            "{:<12} n={:<4} mean={:>7.2}ms p50={:>7.2}ms p95={:>7.2}ms p99={:>7.2}ms batch={:.1} throughput={:.1} req/s",
            s.variant, s.count, s.mean_ms, s.p50_ms, s.p95_ms, s.p99_ms, s.mean_batch,
            completed as f64 / wall
        );
    }
    Ok(())
}

fn main() -> tilewise::error::Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    if !dir.join("meta.json").exists() {
        tilewise::bail!("artifacts not found at {} — run `make artifacts` first", dir.display());
    }
    let requests = 96;
    let rate = 60.0;
    println!(
        "serving {requests} Poisson requests at {rate} req/s against each variant\n\
         (batch=8, max_wait=3ms; BERT-mini encoder, seq x d_model activations)\n"
    );
    for variant in ["model_dense", "model_tw", "model_tvw"] {
        run_load(&dir, variant, requests, rate)?;
    }
    println!(
        "\nnote: on this CPU substrate the TW/TVW executables trade FLOPs for\n\
         gather/scatter ops; the A100-level speedups are what gpusim + the\n\
         fig10 bench estimate. The serving stack (routing, batching, PJRT\n\
         execution, zero Python) is exactly the deployment path."
    );
    Ok(())
}
