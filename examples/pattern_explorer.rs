//! Fig. 9 explorer: prune a synthetic BERT attention weight matrix with
//! all six patterns at 75% sparsity and render the surviving-weight
//! density heatmaps + distribution statistics.
//!
//!   cargo run --release --example pattern_explorer [sparsity]

use tilewise::figures::fig9::{patterns_at_75, synth_bert_wq};
use tilewise::sparse::{mask_stats, render_heatmap, Pattern};

fn main() {
    let sparsity: f64 = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(0.75);
    let w = synth_bert_wq(768, 42);

    if (sparsity - 0.75).abs() < 1e-9 {
        for (label, mask) in patterns_at_75(&w) {
            let s = mask_stats(&mask, 32);
            println!(
                "--- {label}: sparsity={:.3} block_var={:.5} irregularity={:.3} ---",
                s.sparsity, s.block_variance, s.irregularity
            );
            println!("{}", render_heatmap(&mask, 32));
        }
        return;
    }

    // arbitrary sparsity: the patterns that support it
    for (label, p) in [
        ("EW", Pattern::Ew),
        ("BW-64", Pattern::Bw { g: 64 }),
        ("TW-128", Pattern::Tw { g: 128 }),
    ] {
        let mask = p.prune(&w, sparsity);
        let s = mask_stats(&mask, 32);
        println!(
            "--- {label} @ {sparsity}: sparsity={:.3} block_var={:.5} irregularity={:.3} ---",
            s.sparsity, s.block_variance, s.irregularity
        );
        println!("{}", render_heatmap(&mask, 32));
    }
}
