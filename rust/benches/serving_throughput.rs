//! Bench: serving throughput vs worker count on the native backend —
//! requests/sec for BERT-base FFN shapes (d_model 768, d_ff 3072), dense
//! vs TW vs TVW, over 1/2/4/8 workers — plus the partial-load sweep:
//! open-loop arrival at 25/50/100% of measured capacity, padded-batch
//! execution vs dynamic effective-batch (`ServerConfig::dynamic_batch`
//! + the low-latency batcher), req/s, p99 and mean occupancy per cell.
//! Emits `BENCH_serving.json` (`cells` + `load_sweep`).
//!
//!   cargo bench --bench serving_throughput [-- --requests N]

#[path = "bench_util.rs"]
mod bench_util;

use std::sync::Arc;
use std::time::{Duration, Instant};

use bench_util::{scaled, section};
use tilewise::coordinator::{start_with_backend, BatcherConfig, Policy, ServerConfig};
use tilewise::exec::{Backend, NativeBackend, NativeModelSpec};
use tilewise::json::{arr, num, obj, s};
use tilewise::util::percentile;
use tilewise::variant::Variant;

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];
const VARIANTS: [&str; 3] = ["model_dense", "model_tw", "model_tvw"];

struct Cell {
    variant: &'static str,
    workers: usize,
    intra: usize,
    rps: f64,
    p50_ms: f64,
    p99_ms: f64,
}

fn run_cell(
    backend: &Arc<dyn Backend>,
    variant: &'static str,
    workers: usize,
    intra: usize,
    requests: usize,
) -> Cell {
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            ..BatcherConfig::default()
        },
        policy: Policy::Fixed(variant.parse::<Variant>().expect("bench variant")),
        workers,
        intra_threads: intra,
        ..ServerConfig::default()
    };
    let handle = start_with_backend(backend.clone(), cfg).expect("native server start");
    let len = handle.seq * handle.d_model;
    let x = vec![0.1f32; len];

    // warmup: one full batch through every worker's scratch path
    for rx in (0..workers * 8).map(|_| handle.submit(x.clone(), None)).collect::<Vec<_>>() {
        let _ = rx.wait();
    }
    // closed-loop burst: saturate the queue, measure drain rate
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests).map(|_| handle.submit(x.clone(), None)).collect();
    let mut ok = 0usize;
    for rx in rxs {
        if rx.wait().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(ok, requests, "all requests must be served");
    let snap = handle.metrics.full_snapshot();
    let stats = snap.variants.iter().find(|v| v.variant == variant).expect("variant stats");
    Cell {
        variant,
        workers,
        intra,
        rps: ok as f64 / wall,
        p50_ms: stats.p50_ms,
        p99_ms: stats.p99_ms,
    }
}

struct SweepCell {
    load_pct: usize,
    mode: &'static str,
    offered_rps: f64,
    rps: f64,
    p99_ms: f64,
    mean_occupancy: f64,
}

/// Open-loop injection at a fixed offered rate: requests are submitted on
/// a wall-clock schedule (never gated on responses), then the cell's
/// req/s is completions over the full makespan — a server that falls
/// behind the offered rate pays for its backlog in the measurement.
fn run_sweep_cell(
    backend: &Arc<dyn Backend>,
    load_pct: usize,
    dynamic: bool,
    offered_rps: f64,
    requests: usize,
) -> SweepCell {
    let cfg = ServerConfig {
        // dynamic mode pairs variable-M execution with the low-latency
        // batcher; padded keeps the historical size+deadline batcher
        batcher: if dynamic {
            BatcherConfig::low_latency(8)
        } else {
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                ..BatcherConfig::default()
            }
        },
        policy: Policy::Fixed(Variant::Tw),
        workers: 1,
        dynamic_batch: dynamic,
        ..ServerConfig::default()
    };
    let handle = start_with_backend(backend.clone(), cfg).expect("sweep server start");
    let len = handle.seq * handle.d_model;
    let x = vec![0.1f32; len];
    // warmup one full batch through the worker's scratch path
    for rx in (0..8).map(|_| handle.submit(x.clone(), None)).collect::<Vec<_>>() {
        let _ = rx.wait();
    }
    let interval = Duration::from_secs_f64(1.0 / offered_rps.max(1e-9));
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(requests);
    for i in 0..requests {
        let target = interval.mul_f64(i as f64);
        if let Some(sleep) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        rxs.push(handle.submit(x.clone(), None));
    }
    // p99/occupancy come from the measured responses themselves (not the
    // server metrics, which also hold the warmup burst's samples)
    let mut ok = 0usize;
    let mut lat_ms: Vec<f64> = Vec::with_capacity(requests);
    let mut occ_sum = 0.0f64;
    for rx in rxs {
        if let Ok(r) = rx.wait() {
            ok += 1;
            lat_ms.push(r.total_secs() * 1e3);
            occ_sum += r.batch_size as f64 / 8.0;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(ok, requests, "all sweep requests must be served");
    SweepCell {
        load_pct,
        mode: if dynamic { "dynamic" } else { "padded" },
        offered_rps,
        rps: ok as f64 / wall,
        p99_ms: percentile(&mut lat_ms, 0.99),
        mean_occupancy: occ_sum / ok.max(1) as f64,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    // PALLAS_BENCH_QUICK trims the closed-loop burst to a CI-sized run
    let requests: usize = args
        .iter()
        .position(|a| a == "--requests")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| scaled(48, 16));

    // BERT-base FFN widths; seq trimmed so one forward stays sub-second
    let spec = NativeModelSpec::bert_base(8, 8).with_variants(&VARIANTS);
    section(&format!(
        "native serving throughput, BERT-base FFN shapes ({}x{}, batch {}, seq {}, {} requests/cell)",
        spec.d_model, spec.d_ff, spec.batch, spec.seq, requests
    ));
    let t_pack = Instant::now();
    let backend: Arc<dyn Backend> =
        Arc::new(NativeBackend::new(spec.clone(), None).expect("pack native model"));
    println!("packed dense/TW/TVW plans once in {:.2}s\n", t_pack.elapsed().as_secs_f64());

    println!(
        "{:<14}{:>9}{:>7}{:>12}{:>12}{:>12}{:>10}",
        "variant", "workers", "intra", "req/s", "p50(ms)", "p99(ms)", "scaling"
    );
    let worker_counts: Vec<usize> = if bench_util::quick_mode() {
        vec![1, 4]
    } else {
        WORKER_COUNTS.to_vec()
    };
    let mut cells: Vec<Cell> = Vec::new();
    let mut scaling = Vec::new();
    for variant in VARIANTS {
        let mut base_rps = 0.0f64;
        for &workers in &worker_counts {
            let cell = run_cell(&backend, variant, workers, 1, requests);
            if workers == 1 {
                base_rps = cell.rps;
            }
            let scale = if base_rps > 0.0 { cell.rps / base_rps } else { 1.0 };
            println!(
                "{:<14}{:>9}{:>7}{:>12.1}{:>12.2}{:>12.2}{:>9.2}x",
                cell.variant, cell.workers, cell.intra, cell.rps, cell.p50_ms, cell.p99_ms, scale
            );
            cells.push(cell);
        }
        let max_rps = cells
            .iter()
            .filter(|c| c.variant == variant)
            .map(|c| c.rps)
            .fold(0.0f64, f64::max);
        let final_scale = if base_rps > 0.0 { max_rps / base_rps } else { 1.0 };
        scaling.push((variant, final_scale));
        println!();
    }

    // two-level split: same total thread budget divided between
    // inter-request workers and the shared intra-op kernel pool
    section("two-level parallelism: workers x intra-threads (TW variant)");
    let splits: [(usize, usize); 3] = if bench_util::quick_mode() {
        [(1, 2), (2, 1), (2, 2)]
    } else {
        [(1, 4), (2, 2), (4, 1)]
    };
    for &(workers, intra) in &splits {
        let cell = run_cell(&backend, "model_tw", workers, intra, requests);
        println!(
            "{:<14}{:>9}{:>7}{:>12.1}{:>12.2}{:>12.2}",
            cell.variant, cell.workers, cell.intra, cell.rps, cell.p50_ms, cell.p99_ms
        );
        cells.push(cell);
    }
    println!();

    for (variant, scale) in &scaling {
        println!("{variant}: best throughput {scale:.2}x over 1 worker");
    }
    if scaling.iter().all(|(_, s)| *s < 1.2) {
        println!("warning: no variant scaled >=1.2x with workers on this host");
    }

    // ---- partial-load sweep: padded vs dynamic effective-batch --------
    // capacity = the closed-loop full-batch rate of one padded worker;
    // offered arrival rates are fractions of it.  At partial load the
    // padded server pays full-B compute for mostly-empty batches, the
    // dynamic server pays for real rows only.
    section("load sweep: offered rate vs padded/dynamic (TW, 1 worker)");
    let capacity = run_cell(&backend, "model_tw", 1, 1, requests).rps;
    println!("measured closed-loop capacity: {capacity:.1} req/s\n");
    println!(
        "{:<8}{:<9}{:>13}{:>12}{:>12}{:>8}",
        "load", "mode", "offered", "req/s", "p99(ms)", "occ"
    );
    let loads: &[usize] = if bench_util::quick_mode() {
        &[25, 50]
    } else {
        &[25, 50, 100]
    };
    let mut sweep: Vec<SweepCell> = Vec::new();
    for &load_pct in loads {
        let offered = capacity * load_pct as f64 / 100.0;
        for dynamic in [false, true] {
            let cell = run_sweep_cell(&backend, load_pct, dynamic, offered, requests);
            println!(
                "{:<8}{:<9}{:>13.1}{:>12.1}{:>12.2}{:>7.0}%",
                format!("{load_pct}%"),
                cell.mode,
                cell.offered_rps,
                cell.rps,
                cell.p99_ms,
                cell.mean_occupancy * 100.0
            );
            sweep.push(cell);
        }
    }
    for &load_pct in loads {
        let padded = sweep.iter().find(|c| c.load_pct == load_pct && c.mode == "padded");
        let dynamic = sweep.iter().find(|c| c.load_pct == load_pct && c.mode == "dynamic");
        if let (Some(p), Some(d)) = (padded, dynamic) {
            println!(
                "load {load_pct}%: dynamic {:.2}x padded req/s, p99 {:.2}x lower",
                d.rps / p.rps.max(1e-9),
                p.p99_ms / d.p99_ms.max(1e-9)
            );
        }
    }
    println!();

    // ---- telemetry overhead: per-node profiling on vs off -------------
    // same TW serving cell against a backend with the graph profiler
    // enabled; best-of-2 on both sides damps scheduler noise.  The stage
    // tracer is on in both cells (it always is); the delta isolates the
    // per-op/per-node attribution cost, budgeted at <= 10% in CI.
    section("telemetry overhead: per-node profiling on vs off (TW, 1 worker)");
    let mut on_native = NativeBackend::new(spec.clone().with_variants(&["model_tw"]), None)
        .expect("pack profiled model");
    let _tele = on_native.enable_telemetry();
    let on_backend: Arc<dyn Backend> = Arc::new(on_native);
    let off_rps = (0..2)
        .map(|_| run_cell(&backend, "model_tw", 1, 1, requests).rps)
        .fold(0.0f64, f64::max);
    let on_rps = (0..2)
        .map(|_| run_cell(&on_backend, "model_tw", 1, 1, requests).rps)
        .fold(0.0f64, f64::max);
    let overhead_pct = (off_rps / on_rps.max(1e-9) - 1.0) * 100.0;
    println!("off {off_rps:.1} req/s, on {on_rps:.1} req/s -> overhead {overhead_pct:.1}%\n");

    let doc = obj(vec![
        ("bench", s("serving_throughput")),
        ("backend", s("native")),
        ("d_model", num(spec.d_model as f64)),
        ("d_ff", num(spec.d_ff as f64)),
        ("batch", num(spec.batch as f64)),
        ("seq", num(spec.seq as f64)),
        ("sparsity", num(spec.sparsity)),
        ("requests_per_cell", num(requests as f64)),
        (
            "cells",
            arr(cells
                .iter()
                .map(|c| {
                    obj(vec![
                        ("variant", s(c.variant)),
                        ("workers", num(c.workers as f64)),
                        ("intra_threads", num(c.intra as f64)),
                        ("rps", num(c.rps)),
                        ("p50_ms", num(c.p50_ms)),
                        ("p99_ms", num(c.p99_ms)),
                    ])
                })
                .collect()),
        ),
        (
            "scaling_vs_one_worker",
            obj(scaling.iter().map(|(v, sc)| (*v, num(*sc))).collect()),
        ),
        ("capacity_rps", num(capacity)),
        (
            "load_sweep",
            arr(sweep
                .iter()
                .map(|c| {
                    obj(vec![
                        ("load_pct", num(c.load_pct as f64)),
                        ("mode", s(c.mode)),
                        ("offered_rps", num(c.offered_rps)),
                        ("rps", num(c.rps)),
                        ("p99_ms", num(c.p99_ms)),
                        ("mean_occupancy", num(c.mean_occupancy)),
                    ])
                })
                .collect()),
        ),
        (
            "telemetry",
            obj(vec![
                ("off_rps", num(off_rps)),
                ("on_rps", num(on_rps)),
                ("overhead_pct", num(overhead_pct)),
            ]),
        ),
    ]);
    let out = "BENCH_serving.json";
    match std::fs::write(out, doc.to_string()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("writing {out}: {e}"),
    }
}
