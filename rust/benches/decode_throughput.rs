//! Bench: streaming autoregressive decode throughput — continuous
//! batching (sessions join/leave the in-flight slot set at step
//! boundaries) vs static wave batching (a wave of M sessions must fully
//! drain before the next wave is admitted) over the graph-compiled NMT
//! decoder, at M in {1, 8, 32} slots with mixed prompt/generation
//! lengths.  The continuous scheduler's win is pure occupancy: a retired
//! slot is refilled at the very next step instead of idling until the
//! wave's longest session finishes.  Emits `BENCH_decode.json`.
//!
//!   cargo bench --bench decode_throughput

#[path = "bench_util.rs"]
mod bench_util;

use std::time::Instant;

use bench_util::{scaled, section};
use tilewise::exec::{Backend, PreparedModel, ZooBackend, ZooSpec};
use tilewise::json::{arr, num, obj, s};

const SLOT_COUNTS: [usize; 3] = [1, 8, 32];
const VARIANT: &str = "model_tw";

/// One synthetic session: a prompt of `rows` embedding rows, then
/// `new_tokens` greedy-feedback steps.
struct Session {
    prompt: Vec<f32>,
    new_tokens: usize,
}

/// Mixed lengths, deterministic: prompt rows cycle 1..=max_steps/2 and
/// generation lengths cycle against them — the ragged retirement times
/// that make continuous refill matter.
fn mixed_sessions(n: usize, d_in: usize, max_steps: usize) -> Vec<Session> {
    (0..n)
        .map(|i| {
            let rows = 1 + i % (max_steps / 2).max(1);
            let budget = max_steps - rows;
            let new_tokens = (1 + (i * 7) % budget.max(1)).min(budget).max(1);
            let prompt =
                (0..rows * d_in).map(|j| (((i + j) % 13) as f32 - 6.0) * 0.05).collect();
            Session { prompt, new_tokens }
        })
        .collect()
}

struct Cell {
    m: usize,
    mode: &'static str,
    sessions: usize,
    tokens: usize,
    steps: usize,
    wall_secs: f64,
    tokens_per_sec: f64,
}

/// Drive the decode engine over `sessions`.  `continuous` refills freed
/// slots at every step boundary; static mode admits a wave only into a
/// fully drained engine.
fn run_schedule(
    model: &mut dyn PreparedModel,
    sessions: &[Session],
    m: usize,
    continuous: bool,
) -> Cell {
    let mut next = 0usize;
    let mut want = vec![0usize; m];
    let mut got = vec![0usize; m];
    let mut tokens = 0usize;
    let mut steps = 0usize;
    let t0 = Instant::now();
    loop {
        let active = model.decode_active();
        if continuous || active == 0 {
            while next < sessions.len() {
                let Some(slot) = model.decode_free_slot() else { break };
                model.decode_begin(slot, &sessions[next].prompt).expect("admit session");
                want[slot] = sessions[next].new_tokens;
                got[slot] = 0;
                next += 1;
            }
        }
        if model.decode_active() == 0 {
            break;
        }
        let outs = model.decode_step(VARIANT).expect("decode step");
        steps += 1;
        for out in outs {
            if out.prompt_done {
                got[out.slot] += 1;
                tokens += 1;
                if got[out.slot] >= want[out.slot] {
                    model.decode_end(out.slot).expect("retire session");
                }
            }
        }
    }
    let wall_secs = t0.elapsed().as_secs_f64();
    Cell {
        m,
        mode: if continuous { "continuous" } else { "static" },
        sessions: sessions.len(),
        tokens,
        steps,
        wall_secs,
        tokens_per_sec: tokens as f64 / wall_secs.max(1e-12),
    }
}

fn main() {
    // sessions per slot: enough waves that wave-boundary idling shows
    let waves: usize = scaled(6, 2);
    section("streaming decode throughput: continuous vs static batching (NMT, TW)");
    println!(
        "{:<6}{:<12}{:>10}{:>9}{:>8}{:>12}{:>14}",
        "M", "mode", "sessions", "tokens", "steps", "wall(s)", "tokens/s"
    );
    let mut cells: Vec<Cell> = Vec::new();
    let mut spec_shape = (0usize, 0usize);
    for m in SLOT_COUNTS {
        let mut spec = ZooSpec::for_model("nmt").expect("nmt spec");
        spec.batch = m;
        spec.max_steps = 16;
        let spec = spec.with_variants(&[VARIANT]);
        spec_shape = (spec.width, spec.max_steps);
        let backend = ZooBackend::new(spec, None).expect("compile nmt");
        let mut model = backend.load().expect("load nmt");
        let caps = model.decode_caps().expect("nmt decodes");
        assert_eq!(caps.slots, m);
        let sessions = mixed_sessions(m * waves, caps.d_in, caps.max_steps);
        // warmup: one short session through every slot's state path
        {
            let warm = mixed_sessions(m, caps.d_in, caps.max_steps);
            run_schedule(model.as_mut(), &warm, m, true);
        }
        for continuous in [false, true] {
            let cell = run_schedule(model.as_mut(), &sessions, m, continuous);
            println!(
                "{:<6}{:<12}{:>10}{:>9}{:>8}{:>12.3}{:>14.1}",
                cell.m,
                cell.mode,
                cell.sessions,
                cell.tokens,
                cell.steps,
                cell.wall_secs,
                cell.tokens_per_sec
            );
            cells.push(cell);
        }
    }
    for m in SLOT_COUNTS {
        let stat = cells.iter().find(|c| c.m == m && c.mode == "static");
        let cont = cells.iter().find(|c| c.m == m && c.mode == "continuous");
        if let (Some(st), Some(co)) = (stat, cont) {
            println!(
                "M={m}: continuous {:.2}x static tokens/s ({:.1} vs {:.1})",
                co.tokens_per_sec / st.tokens_per_sec.max(1e-9),
                co.tokens_per_sec,
                st.tokens_per_sec
            );
        }
    }

    let doc = obj(vec![
        ("bench", s("decode_throughput")),
        ("model", s("nmt")),
        ("variant", s(VARIANT)),
        ("width", num(spec_shape.0 as f64)),
        ("max_steps", num(spec_shape.1 as f64)),
        ("waves", num(waves as f64)),
        (
            "cells",
            arr(cells
                .iter()
                .map(|c| {
                    obj(vec![
                        ("m", num(c.m as f64)),
                        ("mode", s(c.mode)),
                        ("sessions", num(c.sessions as f64)),
                        ("tokens", num(c.tokens as f64)),
                        ("steps", num(c.steps as f64)),
                        ("wall_secs", num(c.wall_secs)),
                        ("tokens_per_sec", num(c.tokens_per_sec)),
                    ])
                })
                .collect()),
        ),
    ]);
    let out = "BENCH_decode.json";
    match std::fs::write(out, doc.to_string()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("writing {out}: {e}"),
    }
}
