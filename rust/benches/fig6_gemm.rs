//! Bench: regenerate Fig. 6a/6b/6c (4096^3 GEMM latency sweeps + the
//! granularity-accuracy trade-off), then validate the *shape* of the
//! simulated curves against real CPU-kernel timings at 512^3.
//!
//!   cargo bench --bench fig6_gemm

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench, section};
use tilewise::figures::fig6;
use tilewise::gemm::{csr_spmm, matmul, tw_matmul, vw24_matmul};
use tilewise::sparse::{prune_ew, prune_tw, prune_vw, Csr, TwPlan, Vw24Plan};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

fn main() {
    // --- the paper figures (simulated A100) --------------------------------
    println!("{}", fig6::fig6a().render());
    println!("{}", fig6::fig6b().render());
    println!("{}", fig6::fig6c().render());

    // --- real CPU kernel cross-check at 512^3 -------------------------------
    section("CPU kernel validation at 512^3 (same orderings must hold)");
    let mut rng = Rng::new(2026);
    let (m, k, n) = (512usize, 512usize, 512usize);
    let a = Matrix::randn(m, k, &mut rng);
    let w = Matrix::randn(k, n, &mut rng);

    let t_dense = bench("dense blocked", || {
        std::hint::black_box(matmul(&a, &w));
    });

    let mut crossover_seen = false;
    for s in [0.25f64, 0.5, 0.75, 0.9] {
        let tw = prune_tw(&w, s, 64, None);
        let plan = TwPlan::encode(&w, &tw);
        let t = bench(&format!("TW-64 fused-CTO @ {:.0}%", s * 100.0), || {
            std::hint::black_box(tw_matmul(&a, &plan));
        });
        if t < t_dense {
            crossover_seen = true;
        }
    }
    assert!(crossover_seen, "TW must beat dense somewhere in the sweep");

    let mask24 = prune_vw(&w, 0.5, 4);
    let vplan = Vw24Plan::encode(&w, &mask24).unwrap();
    bench("VW-4 (2:4 emulated) @ 50%", || {
        std::hint::black_box(vw24_matmul(&a, &vplan));
    });

    for s in [0.75f64, 0.95, 0.99] {
        let maske = prune_ew(&w, s, None);
        let csr = Csr::from_masked(&w, &maske);
        bench(&format!("EW CSR SpMM @ {:.0}%", s * 100.0), || {
            std::hint::black_box(csr_spmm(&a, &csr));
        });
    }
    println!("\nfig6 bench complete");
}
