//! Bench: f32 vs int8 (i8×i8→i32, dequant-on-store) for every GEMM
//! pattern at serving-sized M, plus end-to-end zoo-model forwards at both
//! precisions.  Emits `BENCH_quant.json`; CI validates the grid is
//! complete (all four patterns per shape) and fails if int8 dense loses
//! to f32 whenever an x86 SIMD ISA was detected.
//!
//! The int8 timings include the full serving cost: dynamic activation
//! quantization, the i32 accumulation, and per-channel dequantization on
//! store — so `speedup` is the number a `--precision int8` deployment
//! actually sees per dispatch.
//!
//!   cargo bench --bench quant_speedup

#[path = "bench_util.rs"]
mod bench_util;

use std::sync::Arc;

use bench_util::{bench, quick_mode, section};
use tilewise::exec::PreparedModel;
use tilewise::gemm::micro::{self, Isa};
use tilewise::gemm::{
    int8_dense_panel, int8_matmul_tiled_into, int8_tvw_matmul_into, int8_tw_matmul_into,
    int8_tw_pack_panels, int8_vw24_matmul_into, matmul_tiled_into, matmul_tiled_into_panel,
    tvw_matmul_into_with, tw_matmul_into_with, vw24_matmul_into_with, GemmScratch, Int8TvwPlan,
    Int8TwPlan, Int8Vw24Plan, PackedPanel, TileConfig,
};
use tilewise::graph::{compile, CompileOptions, GraphModel, GraphPattern, PackOptions};
use tilewise::json::{arr, num, obj, s, Json};
use tilewise::models;
use tilewise::quant::{Precision, QuantMatrix};
use tilewise::sparse::{prune_tvw, prune_tw, prune_vw, TvwPlan, TwPlan, Vw24Plan};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

fn gflops(m: usize, k: usize, n: usize, density: f64, us: f64) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64 * density / (us * 1e-6) / 1e9
}

fn main() {
    let sparsity = 0.75;
    let g = 32usize;
    // serving-sized M (batch x seq at the zoo serving defaults) over the
    // BERT-base projection/FFN widths; quick mode shrinks K/N, not M —
    // the serving-M claim is the point of this bench
    let shapes: Vec<(usize, usize, usize)> = if quick_mode() {
        vec![(64, 256, 256), (64, 256, 1024)]
    } else {
        vec![(64, 768, 768), (64, 768, 3072), (64, 3072, 768)]
    };

    let auto = micro::resolve(&TileConfig::dense_default());
    let x86_simd = matches!(auto.isa, Isa::Avx2 | Isa::Avx512);
    section(&format!(
        "f32 vs int8 GFLOP/s at serving M, kernel {} (sparsity {sparsity}, G {g})",
        micro::active_label()
    ));

    let mut rng = Rng::new(0x18A7);
    let mut cells = Vec::new();
    for &(m, k, n) in &shapes {
        let a = Matrix::randn(m, k, &mut rng);
        let w = Matrix::randn(k, n, &mut rng);
        // f32 plans and their quantized twins (same pruning decision)
        let twplan = TwPlan::encode(&w, &prune_tw(&w, sparsity, g, None));
        let (tws, mask) = prune_tvw(&w, sparsity, g);
        let tvplan = TvwPlan::encode(&w, &tws, &mask);
        let vplan = Vw24Plan::encode(&w, &prune_vw(&w, 0.5, 4)).expect("2:4 encodable");
        let qw = QuantMatrix::quantize(&w);
        let q_tw = Int8TwPlan::from_plan(&twplan);
        let q_tvw = Int8TvwPlan::from_plan(&tvplan);
        let q_vw = Int8Vw24Plan::from_plan(&vplan);
        let q_panel = int8_dense_panel(&qw, auto.nr);
        let q_tw_panels = int8_tw_pack_panels(&q_tw, auto.nr);
        let f_panel = auto.is_simd().then(|| PackedPanel::pack(&w.data, k, n, n, auto.nr));
        let mut c = Matrix::zeros(m, n);
        let mut scratch = GemmScratch::new();

        for (pattern, density) in
            [("dense", 1.0), ("tw", 1.0 - sparsity), ("tvw", 1.0 - sparsity), ("vw24", 0.5)]
        {
            let fp32_us = bench(&format!("{pattern} {m}x{k}x{n} f32"), || {
                c.data.fill(0.0);
                match pattern {
                    "dense" => match &f_panel {
                        Some(p) => matmul_tiled_into_panel(
                            &a,
                            &w,
                            Some(p),
                            &mut c,
                            &TileConfig::dense_default(),
                        ),
                        None => matmul_tiled_into(&a, &w, &mut c, &TileConfig::dense_default()),
                    },
                    "tw" => tw_matmul_into_with(&a, &twplan, &mut c, &TileConfig::tw_default()),
                    "tvw" => tvw_matmul_into_with(&a, &tvplan, &mut c, &TileConfig::tvw_default()),
                    _ => vw24_matmul_into_with(&a, &vplan, &mut c, &TileConfig::vw_default()),
                }
            });
            let int8_us = bench(&format!("{pattern} {m}x{k}x{n} int8"), || {
                c.data.fill(0.0);
                match pattern {
                    "dense" => int8_matmul_tiled_into(
                        &a,
                        &qw,
                        Some(&q_panel),
                        &mut c,
                        &TileConfig::dense_default(),
                        &mut scratch,
                    ),
                    "tw" => int8_tw_matmul_into(
                        &a,
                        &q_tw,
                        Some(&q_tw_panels),
                        &mut c,
                        &TileConfig::tw_default(),
                        &mut scratch,
                    ),
                    "tvw" => int8_tvw_matmul_into(
                        &a,
                        &q_tvw,
                        &mut c,
                        &TileConfig::tvw_default(),
                        &mut scratch,
                    ),
                    _ => int8_vw24_matmul_into(
                        &a,
                        &q_vw,
                        &mut c,
                        &TileConfig::vw_default(),
                        &mut scratch,
                    ),
                }
            });
            let (fp_gf, i8_gf) =
                (gflops(m, k, n, density, fp32_us), gflops(m, k, n, density, int8_us));
            println!(
                "    {pattern:<6} {m}x{k}x{n}: f32 {fp_gf:.2} GFLOP/s, int8 {i8_gf:.2} GFLOP/s \
                 ({:.2}x)",
                fp32_us / int8_us.max(1e-12)
            );
            cells.push(obj(vec![
                ("pattern", s(pattern)),
                ("m", num(m as f64)),
                ("k", num(k as f64)),
                ("n", num(n as f64)),
                ("density", num(density)),
                ("fp32_gflops", num(fp_gf)),
                ("int8_gflops", num(i8_gf)),
                ("fp32_us", num(fp32_us)),
                ("int8_us", num(int8_us)),
                ("speedup", num(fp32_us / int8_us.max(1e-12))),
            ]));
        }
    }

    // end-to-end: the compiled zoo transformer at both precisions,
    // through the same graph executor `serve --backend native` dispatches
    section("end-to-end model forward, f32 vs int8 (quantize-at-pack)");
    let (batch, seq, width, layers) = if quick_mode() { (2, 4, 32, 1) } else { (4, 16, 256, 2) };
    let workload = models::bert_at(batch, seq, width, layers);
    let opts = CompileOptions {
        seq,
        heads: 4,
        n_classes: 8,
        pack: PackOptions { sparsity, g, ..Default::default() },
        seed: 42,
        ..CompileOptions::default()
    };
    let mut model_cells = Vec::new();
    for pattern in [GraphPattern::Dense, GraphPattern::Tw, GraphPattern::Tvw, GraphPattern::Vw24] {
        let f32_prog = compile(&workload, &opts.with_pattern(pattern)).expect("f32 compile");
        let int8_prog =
            compile(&workload, &opts.with_pattern(pattern).with_precision(Precision::Int8))
                .expect("int8 compile");
        let dims = f32_prog.dims;
        let variant = f32_prog.variant.clone();
        let x: Vec<f32> =
            (0..dims.batch * dims.per_request_len()).map(|i| ((i % 13) as f32 - 6.0) * 0.05).collect();
        let mut fm = GraphModel::new(Arc::new(vec![f32_prog]), None).unwrap();
        let mut qm = GraphModel::new(Arc::new(vec![int8_prog]), None).unwrap();
        let fp32_us = bench(&format!("bert/{variant} f32"), || {
            fm.run(&variant, &x).unwrap();
        });
        let int8_us = bench(&format!("bert/{variant} int8"), || {
            qm.run(&variant, &x).unwrap();
        });
        println!(
            "    bert/{variant}: f32 {fp32_us:.1}us, int8 {int8_us:.1}us ({:.2}x)",
            fp32_us / int8_us.max(1e-12)
        );
        model_cells.push(obj(vec![
            ("model", s("bert")),
            ("variant", s(&variant)),
            ("fp32_us", num(fp32_us)),
            ("int8_us", num(int8_us)),
            ("speedup", num(fp32_us / int8_us.max(1e-12))),
        ]));
    }

    let doc = obj(vec![
        ("bench", s("quant")),
        ("isa", s(auto.isa.label())),
        ("micro", s(&micro::active_label())),
        ("avx2", Json::Bool(x86_simd)),
        ("sparsity", num(sparsity)),
        ("g", num(g as f64)),
        ("cells", arr(cells)),
        ("models", arr(model_cells)),
    ]);
    let out = "BENCH_quant.json";
    match std::fs::write(out, doc.to_string()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("writing {out}: {e}"),
    }
}
