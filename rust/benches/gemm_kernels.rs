//! Bench: the CPU GEMM hot paths — the §Perf profiling harness.
//! Reports every kernel variant so before/after optimization deltas are
//! directly visible (EXPERIMENTS.md §Perf quotes these numbers).
//!
//!   cargo bench --bench gemm_kernels

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench, section};
use tilewise::gemm::{
    block_spmm, csr_spmm, matmul, matmul_naive, matmul_parallel, tw_matmul, tw_matmul_into,
    tw_matmul_masked, tw_matmul_parallel, tw_matmul_per_tile, tvw_matmul, vw24_matmul,
    BlockSparse,
};
use tilewise::sparse::{
    prune_bw, prune_ew, prune_tvw, prune_tw, prune_vw, Csr, TvwPlan, TwPlan, Vw24Plan,
};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

fn main() {
    let mut rng = Rng::new(4242);
    let (m, k, n) = (256usize, 512usize, 512usize);
    let a = Matrix::randn(m, k, &mut rng);
    let w = Matrix::randn(k, n, &mut rng);
    let threads = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4);
    println!("shape {m}x{k}x{n}, {threads} threads available");

    section("dense baselines");
    let t_naive = bench("dense naive (i,j,k)", || {
        std::hint::black_box(matmul_naive(&a, &w));
    });
    let t_blocked = bench("dense blocked (i,k,j)", || {
        std::hint::black_box(matmul(&a, &w));
    });
    bench("dense parallel", || {
        std::hint::black_box(matmul_parallel(&a, &w, threads));
    });
    assert!(t_blocked < t_naive, "blocked must beat naive");

    section("TW strategies at 75% sparsity, G=64 (the Fig. 4 ladder on CPU)");
    let tw = prune_tw(&w, 0.75, 64, None);
    let plan = TwPlan::encode(&w, &tw);
    let mask = tw.mask();
    bench("TW masked dense-loop (strawman)", || {
        std::hint::black_box(tw_matmul_masked(&a, &w, &mask));
    });
    bench("TW per-tile kernels", || {
        std::hint::black_box(tw_matmul_per_tile(&a, &plan));
    });
    let t_fused = bench("TW fused-CTO", || {
        std::hint::black_box(tw_matmul(&a, &plan));
    });
    bench("TW fused-CTO parallel", || {
        std::hint::black_box(tw_matmul_parallel(&a, &plan, threads));
    });
    let mut c = Matrix::zeros(m, n);
    bench("TW fused-CTO into (no alloc)", || {
        tw_matmul_into(&a, &plan, &mut c);
        std::hint::black_box(&c);
    });
    assert!(t_fused < t_blocked, "TW at 75% must beat the dense kernel");

    section("2:4 and TVW");
    let mask24 = prune_vw(&w, 0.5, 4);
    let vplan = Vw24Plan::encode(&w, &mask24).unwrap();
    bench("VW-4 2:4 GEMM @50%", || {
        std::hint::black_box(vw24_matmul(&a, &vplan));
    });
    let (tws, tvmask) = prune_tvw(&w, 0.75, 64);
    let tvplan = TvwPlan::encode(&w, &tws, &tvmask);
    bench("TVW fused GEMM @75%", || {
        std::hint::black_box(tvw_matmul(&a, &tvplan));
    });

    section("sparse baselines");
    let maske = prune_ew(&w, 0.75, None);
    let csr = Csr::from_masked(&w, &maske);
    bench("EW CSR SpMM @75%", || {
        std::hint::black_box(csr_spmm(&a, &csr));
    });
    let maskb = prune_bw(&w, 0.75, 16);
    let bs = BlockSparse::from_masked(&w, &maskb, 16);
    bench("BW block-sparse @75% (16x16)", || {
        std::hint::black_box(block_spmm(&a, &bs));
    });

    println!("\ngemm_kernels bench complete");
}
