//! Bench: regenerate Fig. 8 (accuracy curves), Fig. 9 (pattern stats),
//! Fig. 10/11 (speedup-vs-accuracy Pareto per model) and the headline
//! table (§VI-D averages vs the paper's reported numbers).
//!
//!   cargo bench --bench fig10_pareto

use tilewise::figures::{fig10, fig8, fig9, headline};

fn main() {
    for t in fig8::fig8_all() {
        println!("{}", t.render());
    }
    println!("{}", fig9::fig9_stats().render());
    for t in fig10::fig10_all() {
        println!("{}", t.render());
    }
    for t in fig10::fig11_all() {
        println!("{}", t.render());
    }
    println!("{}", headline::headline().render());
    println!("fig8/9/10/11 + headline bench complete");
}
