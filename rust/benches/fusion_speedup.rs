//! Bench: fused GEMM epilogues (bias + relu + residual applied at store
//! time) vs the unfused kernel + separate elementwise sweeps, for every
//! GEMM pattern at serving-sized M, plus end-to-end zoo-model forwards
//! compiled with and without the graph fusion pass.  Emits
//! `BENCH_fusion.json`; CI validates the grid is complete (all four
//! patterns per shape) and fails if fusion ever loses on the
//! bandwidth-bound FFN shapes whenever an x86 SIMD ISA was detected.
//!
//! The unfused side performs the exact work the graph executor used to
//! do per layer: the bare GEMM, then a bias+activation sweep over C,
//! then a residual-add sweep — two extra full passes of C through
//! memory that the fused epilogue eliminates.
//!
//!   cargo bench --bench fusion_speedup

#[path = "bench_util.rs"]
mod bench_util;

use std::sync::Arc;

use bench_util::{bench, quick_mode, section};
use tilewise::exec::PreparedModel;
use tilewise::gemm::micro::{self, Isa};
use tilewise::gemm::{
    matmul_tiled_into, matmul_tiled_into_panel, matmul_tiled_into_panel_epi,
    tvw_matmul_into_scratch, tvw_matmul_into_scratch_epi, tw_matmul_into_scratch_panels,
    tw_matmul_into_scratch_panels_epi, vw24_matmul_into_epi, vw24_matmul_into_with, Act, Epilogue,
    GemmScratch, PackedPanel, TileConfig,
};
use tilewise::graph::{compile, CompileOptions, GraphModel, GraphPattern, Op, PackOptions};
use tilewise::json::{arr, num, obj, s, Json};
use tilewise::models;
use tilewise::sparse::{prune_tvw, prune_tw, prune_vw, TvwPlan, TwPlan, Vw24Plan};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

fn gflops(m: usize, k: usize, n: usize, density: f64, us: f64) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64 * density / (us * 1e-6) / 1e9
}

/// The unfused elementwise tail: one bias+relu sweep, one residual sweep.
fn unfused_tail(c: &mut Matrix, bias: &[f32], r: &Matrix) {
    let cols = c.cols;
    for row in c.data.chunks_mut(cols) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
    for (cv, rv) in c.data.iter_mut().zip(&r.data) {
        *cv += rv;
    }
}

fn arena_floats(p: &tilewise::graph::GraphProgram) -> u64 {
    p.buf_shapes.iter().map(|&(r, c)| (r * c) as u64).sum()
}

fn main() {
    let sparsity = 0.75;
    let g = 32usize;
    // serving-sized M over the BERT-base projection/FFN widths; quick
    // mode shrinks K/N, not M — the serving-M claim is the point
    let shapes: Vec<(usize, usize, usize)> = if quick_mode() {
        vec![(64, 256, 256), (64, 256, 1024)]
    } else {
        vec![(64, 768, 768), (64, 768, 3072), (64, 3072, 768)]
    };

    let auto = micro::resolve(&TileConfig::dense_default());
    let x86_simd = matches!(auto.isa, Isa::Avx2 | Isa::Avx512);
    section(&format!(
        "fused vs unfused epilogue (bias+relu+residual) at serving M, kernel {} (sparsity {sparsity}, G {g})",
        micro::active_label()
    ));

    let mut rng = Rng::new(0xF5ED);
    let mut cells = Vec::new();
    for &(m, k, n) in &shapes {
        let a = Matrix::randn(m, k, &mut rng);
        let w = Matrix::randn(k, n, &mut rng);
        let bias: Vec<f32> = (0..n).map(|j| ((j % 17) as f32 - 8.0) * 0.02).collect();
        let r = Matrix::randn(m, n, &mut rng);
        let twplan = TwPlan::encode(&w, &prune_tw(&w, sparsity, g, None));
        let (tws, mask) = prune_tvw(&w, sparsity, g);
        let tvplan = TvwPlan::encode(&w, &tws, &mask);
        let vplan = Vw24Plan::encode(&w, &prune_vw(&w, 0.5, 4)).expect("2:4 encodable");
        let f_panel = auto.is_simd().then(|| PackedPanel::pack(&w.data, k, n, n, auto.nr));
        let mut c = Matrix::zeros(m, n);
        let mut scratch = GemmScratch::new();
        let epi = Epilogue { bias: Some(&bias), act: Some(Act::Relu), residual: Some(&r) };

        for (pattern, density) in
            [("dense", 1.0), ("tw", 1.0 - sparsity), ("tvw", 1.0 - sparsity), ("vw24", 0.5)]
        {
            let unfused_us = bench(&format!("{pattern} {m}x{k}x{n} unfused"), || {
                match pattern {
                    "dense" => match &f_panel {
                        Some(p) => matmul_tiled_into_panel(
                            &a,
                            &w,
                            Some(p),
                            &mut c,
                            &TileConfig::dense_default(),
                        ),
                        None => matmul_tiled_into(&a, &w, &mut c, &TileConfig::dense_default()),
                    },
                    "tw" => {
                        c.data.fill(0.0);
                        tw_matmul_into_scratch_panels(
                            &a,
                            &twplan,
                            None,
                            &mut c,
                            &TileConfig::tw_default(),
                            &mut scratch,
                        );
                    }
                    "tvw" => tvw_matmul_into_scratch(
                        &a,
                        &tvplan,
                        &mut c,
                        &TileConfig::tvw_default(),
                        &mut scratch,
                    ),
                    _ => vw24_matmul_into_with(&a, &vplan, &mut c, &TileConfig::vw_default()),
                }
                unfused_tail(&mut c, &bias, &r);
            });
            let fused_us = bench(&format!("{pattern} {m}x{k}x{n} fused"), || match pattern {
                "dense" => match &f_panel {
                    Some(p) => matmul_tiled_into_panel_epi(
                        &a,
                        &w,
                        Some(p),
                        &mut c,
                        &TileConfig::dense_default(),
                        Some(&epi),
                    ),
                    None => matmul_tiled_into_panel_epi(
                        &a,
                        &w,
                        None,
                        &mut c,
                        &TileConfig::dense_default(),
                        Some(&epi),
                    ),
                },
                "tw" => {
                    // caller-prefill contract: pruned columns read epi(0)
                    epi.prefill(&mut c);
                    tw_matmul_into_scratch_panels_epi(
                        &a,
                        &twplan,
                        None,
                        &mut c,
                        &TileConfig::tw_default(),
                        &mut scratch,
                        Some(&epi),
                    );
                }
                "tvw" => tvw_matmul_into_scratch_epi(
                    &a,
                    &tvplan,
                    &mut c,
                    &TileConfig::tvw_default(),
                    &mut scratch,
                    Some(&epi),
                ),
                _ => vw24_matmul_into_epi(
                    &a,
                    &vplan,
                    &mut c,
                    &TileConfig::vw_default(),
                    Some(&epi),
                ),
            });
            let (f_gf, u_gf) =
                (gflops(m, k, n, density, fused_us), gflops(m, k, n, density, unfused_us));
            println!(
                "    {pattern:<6} {m}x{k}x{n}: unfused {u_gf:.2} GFLOP/s, fused {f_gf:.2} GFLOP/s \
                 ({:.2}x)",
                unfused_us / fused_us.max(1e-12)
            );
            cells.push(obj(vec![
                ("pattern", s(pattern)),
                ("m", num(m as f64)),
                ("k", num(k as f64)),
                ("n", num(n as f64)),
                ("density", num(density)),
                ("unfused_gflops", num(u_gf)),
                ("fused_gflops", num(f_gf)),
                ("unfused_us", num(unfused_us)),
                ("fused_us", num(fused_us)),
                ("speedup", num(unfused_us / fused_us.max(1e-12))),
            ]));
        }
    }

    // end-to-end: zoo models compiled with and without the fusion pass,
    // through the same graph executor `serve --backend native` dispatches
    section("end-to-end model forward, fused vs unfused compile");
    let (batch, seq, width, layers) = if quick_mode() { (2, 4, 32, 1) } else { (4, 16, 256, 2) };
    let mut model_cells = Vec::new();
    for (model, workload) in [
        ("bert", models::bert_at(batch, seq, width, layers)),
        ("nmt", models::nmt_at(batch, width.min(64), seq)),
    ] {
        let opts = CompileOptions {
            seq,
            heads: 4,
            n_classes: 8,
            pack: PackOptions { sparsity, g, ..Default::default() },
            seed: 42,
            ..CompileOptions::default()
        };
        for pattern in [GraphPattern::Dense, GraphPattern::Tw, GraphPattern::Tvw] {
            let fused_prog =
                compile(&workload, &CompileOptions { fuse: true, ..opts.with_pattern(pattern) })
                    .expect("fused compile");
            let unfused_prog =
                compile(&workload, &CompileOptions { fuse: false, ..opts.with_pattern(pattern) })
                    .expect("unfused compile");
            let tail_ops = |p: &tilewise::graph::GraphProgram| {
                p.ops
                    .iter()
                    .filter(|o| matches!(o, Op::BiasAct { .. } | Op::Residual { .. }))
                    .count()
            };
            let ops_removed = tail_ops(&unfused_prog) - tail_ops(&fused_prog);
            let (fused_arena, unfused_arena) =
                (arena_floats(&fused_prog), arena_floats(&unfused_prog));
            let dims = fused_prog.dims;
            let variant = fused_prog.variant.clone();
            let x: Vec<f32> = (0..dims.batch * dims.per_request_len())
                .map(|i| ((i % 13) as f32 - 6.0) * 0.05)
                .collect();
            let mut fm = GraphModel::new(Arc::new(vec![fused_prog]), None).unwrap();
            let mut um = GraphModel::new(Arc::new(vec![unfused_prog]), None).unwrap();
            let unfused_us = bench(&format!("{model}/{variant} unfused"), || {
                um.run(&variant, &x).unwrap();
            });
            let fused_us = bench(&format!("{model}/{variant} fused"), || {
                fm.run(&variant, &x).unwrap();
            });
            println!(
                "    {model}/{variant}: unfused {unfused_us:.1}us, fused {fused_us:.1}us \
                 ({:.2}x, {ops_removed} tail ops removed, arena {unfused_arena} -> {fused_arena} floats)",
                unfused_us / fused_us.max(1e-12)
            );
            model_cells.push(obj(vec![
                ("model", s(model)),
                ("variant", s(&variant)),
                ("unfused_us", num(unfused_us)),
                ("fused_us", num(fused_us)),
                ("speedup", num(unfused_us / fused_us.max(1e-12))),
                ("tail_ops_removed", num(ops_removed as f64)),
                ("unfused_arena_floats", num(unfused_arena as f64)),
                ("fused_arena_floats", num(fused_arena as f64)),
            ]));
        }
    }

    let doc = obj(vec![
        ("bench", s("fusion")),
        ("isa", s(auto.isa.label())),
        ("micro", s(&micro::active_label())),
        ("avx2", Json::Bool(x86_simd)),
        ("sparsity", num(sparsity)),
        ("g", num(g as f64)),
        ("cells", arr(cells)),
        ("models", arr(model_cells)),
    ]);
    let out = "BENCH_fusion.json";
    match std::fs::write(out, doc.to_string()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("writing {out}: {e}"),
    }
}
