//! Bench: regenerate Fig. 7a/7b (the TEW delta trade-off) and time the
//! real CPU composition TW-kernel + CSC remainder that implements TEW's
//! linear split (§III-A).
//!
//!   cargo bench --bench fig7_tew

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench, section};
use tilewise::figures::fig7;
use tilewise::gemm::{csr_spmm, tw_matmul};
use tilewise::sparse::{prune_tew, Csr, TwPlan};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

fn main() {
    println!("{}", fig7::fig7a().render());
    println!("{}", fig7::fig7b().render());

    section("CPU TEW composition at 512^3, 75% sparsity");
    let mut rng = Rng::new(7);
    let (m, k, n) = (512usize, 512usize, 512usize);
    let a = Matrix::randn(m, k, &mut rng);
    let w = Matrix::randn(k, n, &mut rng);

    for delta_pct in [1u8, 5, 10] {
        let delta = delta_pct as f64 / 100.0;
        let (tw, remedy) = prune_tew(&w, 0.75, delta, 64);
        let plan = TwPlan::encode(&w, &tw);
        let remainder = Csr::from_masked(&w, &remedy);
        let t_tw = bench(&format!("TEW-{delta_pct}%: TW part"), || {
            std::hint::black_box(tw_matmul(&a, &plan));
        });
        let t_rem = bench(&format!("TEW-{delta_pct}%: EW remainder ({} nnz)", remainder.nnz()), || {
            std::hint::black_box(csr_spmm(&a, &remainder));
        });
        println!(
            "  -> TEW-{delta_pct}% serial total {:.1} us (concurrent would be max = {:.1} us)",
            t_tw + t_rem,
            t_tw.max(t_rem)
        );
    }

    // correctness of the linear split
    let (tw, remedy) = prune_tew(&w, 0.75, 0.05, 64);
    let plan = TwPlan::encode(&w, &tw);
    let remainder = Csr::from_masked(&w, &remedy);
    let c_tw = tw_matmul(&a, &plan);
    let c_rem = csr_spmm(&a, &remainder);
    let mut c = c_tw.clone();
    for (x, y) in c.data.iter_mut().zip(&c_rem.data) {
        *x += y;
    }
    let full = tilewise::gemm::matmul(&a, &tw.mask().or(&remedy).apply(&w));
    assert!(c.max_abs_diff(&full) < 1e-2, "TEW split mismatch");
    println!("\nfig7 bench complete (TEW linear split verified)");
}
