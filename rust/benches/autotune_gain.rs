//! Bench: default-config vs autotuned TW GEMM on the BERT-base layer
//! shapes — the headline evidence that the `autotune` subsystem pays for
//! itself.  Emits `BENCH_autotune.json` with per-shape speedups.
//!
//!   cargo bench --bench autotune_gain

#[path = "bench_util.rs"]
mod bench_util;

use std::collections::BTreeSet;

use bench_util::{quick_mode, section};
use tilewise::autotune::{MeasureOpts, PatternFamily, SearchSpace, Tuner, TunerOpts};
use tilewise::gpusim::GemmShape;
use tilewise::json::{arr, num, obj, s};
use tilewise::models;
use tilewise::util::geomean;

fn main() {
    let threads = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1);
    // tuning-time M cap: GEMM cost is linear in M, so tile decisions made
    // at M=256 transfer to the serving batch (M=1024) at a fraction of
    // the tuning cost
    let m_cap = if quick_mode() { 64usize } else { 256 };
    let opts = TunerOpts {
        sparsity: 0.75,
        nthreads: threads,
        m_cap: Some(m_cap),
        measure: if quick_mode() {
            MeasureOpts::quick()
        } else {
            MeasureOpts { warmup: 1, min_iters: 3, max_iters: 30, budget_secs: 0.15, trim_frac: 0.2 }
        },
        space: SearchSpace::default(),
        ..TunerOpts::default()
    };
    let tuner = Tuner::new(opts);

    let bert = models::bert_base(8, 128);
    let mut shapes: BTreeSet<(usize, usize, usize)> = BTreeSet::new();
    for layer in bert.prunable_layers() {
        shapes.insert((layer.shape.m, layer.shape.k, layer.shape.n));
    }
    // quick profile: the two FFN shapes (the FLOP-dominant GEMMs) only
    let shapes: Vec<(usize, usize, usize)> = if quick_mode() {
        shapes.into_iter().rev().take(2).collect()
    } else {
        shapes.into_iter().collect()
    };

    section(&format!(
        "TW autotune gain on BERT-base layer shapes (75% sparsity, m-cap {m_cap}, {threads} threads)"
    ));
    println!(
        "{:<20}{:>14}{:>12}{:>9}   {}",
        "shape(MxKxN)", "default(us)", "tuned(us)", "speedup", "winner"
    );

    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    for &(m, k, n) in &shapes {
        let shape = GemmShape::new(m, k, n);
        let Some(res) = tuner.tune_gemm(shape, PatternFamily::Tw) else {
            println!("{m}x{k}x{n}: not tunable, skipped");
            continue;
        };
        let e = &res.entry;
        let speedup = e.speedup();
        println!(
            "{:<20}{:>14.1}{:>12.1}{:>8.2}x   {}",
            format!("{}x{}x{}", e.key.m, e.key.k, e.key.n),
            e.default_us,
            e.measured_us,
            speedup,
            e.candidate().map(|c| c.label()).unwrap_or_default(),
        );
        speedups.push(speedup);
        rows.push(obj(vec![
            ("m", num(e.key.m as f64)),
            ("k", num(e.key.k as f64)),
            ("n", num(e.key.n as f64)),
            ("default_us", num(e.default_us)),
            ("tuned_us", num(e.measured_us)),
            ("speedup", num(speedup)),
            ("winner", s(&e.candidate().map(|c| c.label()).unwrap_or_default())),
            ("candidates_measured", num(res.candidates_measured as f64)),
        ]));
    }

    let gm = geomean(&speedups);
    let max = speedups.iter().copied().fold(0.0f64, f64::max);
    println!("\ngeomean speedup {gm:.2}x, best {max:.2}x over the hard-coded TW config");
    if max < 1.1 {
        println!("warning: no shape reached the 1.1x acceptance bar on this host");
    }

    let doc = obj(vec![
        ("bench", s("autotune_gain")),
        ("model", s("bert")),
        ("pattern", s("TW")),
        ("sparsity", num(0.75)),
        ("m_cap", num(m_cap as f64)),
        ("threads", num(threads as f64)),
        ("shapes", arr(rows)),
        ("geomean_speedup", num(gm)),
        ("max_speedup", num(max)),
    ]);
    let out = "BENCH_autotune.json";
    match std::fs::write(out, doc.to_string()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("writing {out}: {e}"),
    }
}
