//! Bench: the persistent pool runtime vs per-call thread spawning, and
//! thread scaling of the previously-serial TVW / 2:4 kernels — the
//! evidence that moving every parallel kernel onto `tilewise::pool`
//! pays at serving-sized M (batch <= 32), where per-call spawn+join used
//! to rival the kernel itself.  Emits `BENCH_pool.json`.
//!
//!   cargo bench --bench pool_scaling            # full profile
//!   PALLAS_BENCH_QUICK=1 cargo bench --bench pool_scaling   # CI profile

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench, quick_mode, section};
use tilewise::gemm::{
    tvw_matmul_parallel_into, tw_matmul_parallel_into, vw24_matmul_parallel_into, TileConfig,
};
use tilewise::json::{arr, num, obj, s, Json};
use tilewise::pool::{split_range, SendPtr, ThreadPool};
use tilewise::sparse::{prune_tvw, prune_tw, prune_vw, TvwPlan, TwPlan, Vw24Plan};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

/// The pre-pool execution model, kept as the bench baseline: identical
/// tile partition to `tw_matmul_parallel_into`, but fresh `thread::scope`
/// threads spawned on every call — the cost the pool runtime eliminated.
fn tw_matmul_spawn(a: &Matrix, plan: &TwPlan, c: &mut Matrix, threads: usize) {
    let eff = threads.min(plan.tiles).max(1);
    let (m, n) = (a.rows, plan.n);
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    std::thread::scope(|scope| {
        for chunk in 0..eff {
            let c_ptr = &c_ptr;
            scope.spawn(move || {
                let (t0, t1) = split_range(plan.tiles, eff, chunk);
                let mut a_gather = vec![0.0f32; plan.kmax];
                for t in t0..t1 {
                    let kt = plan.row_len[t] as usize;
                    let width = (0..plan.g)
                        .take_while(|&j| (plan.col_idx[t * plan.g + j] as usize) < n)
                        .count();
                    if kt == 0 || width == 0 {
                        continue;
                    }
                    let rows = &plan.row_idx[t * plan.kmax..t * plan.kmax + kt];
                    for i in 0..m {
                        let arow = a.row(i);
                        for (d, &r) in a_gather[..kt].iter_mut().zip(rows) {
                            *d = arow[r as usize];
                        }
                        for j in 0..width {
                            let mut acc = 0.0f32;
                            for ii in 0..kt {
                                let b = plan.b_cond[(t * plan.kmax + ii) * plan.g + j];
                                acc += a_gather[ii] * b;
                            }
                            let cj = plan.col_idx[t * plan.g + j] as usize;
                            // SAFETY: tiles own disjoint output columns
                            unsafe { *c_ptr.0.add(i * n + cj) = acc };
                        }
                    }
                }
            });
        }
    });
}

/// Spawn-per-call TVW baseline (the parallel path TVW never had): same
/// tile partition as `tvw_matmul_parallel_into`, scope threads per call.
fn tvw_matmul_spawn(a: &Matrix, plan: &TvwPlan, c: &mut Matrix, threads: usize) {
    let eff = threads.min(plan.tiles).max(1);
    let (m, n) = (a.rows, plan.n);
    let khalf = plan.kmax / 2;
    c.data.fill(0.0);
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    std::thread::scope(|scope| {
        for chunk in 0..eff {
            let c_ptr = &c_ptr;
            scope.spawn(move || {
                let (t0, t1) = split_range(plan.tiles, eff, chunk);
                let mut a_gather = vec![0.0f32; plan.kmax];
                let mut c_tile = vec![0.0f32; plan.g];
                for t in t0..t1 {
                    let kt = plan.row_len[t] as usize;
                    let width = (0..plan.g)
                        .take_while(|&j| (plan.col_idx[t * plan.g + j] as usize) < n)
                        .count();
                    if kt == 0 || width == 0 {
                        continue;
                    }
                    let rows = &plan.row_idx[t * plan.kmax..t * plan.kmax + kt];
                    let groups_max = kt.div_ceil(4).min(plan.kmax / 4);
                    for i in 0..m {
                        let arow = a.row(i);
                        for (d, &r) in a_gather[..kt].iter_mut().zip(rows) {
                            *d = arow[r as usize];
                        }
                        for x in a_gather[kt..plan.kmax].iter_mut() {
                            *x = 0.0;
                        }
                        c_tile[..width].fill(0.0);
                        for g in 0..groups_max {
                            let a4 = [
                                a_gather[g * 4],
                                a_gather[g * 4 + 1],
                                a_gather[g * 4 + 2],
                                a_gather[g * 4 + 3],
                            ];
                            if a4 == [0.0; 4] {
                                continue;
                            }
                            let base0 = (t * khalf + g * 2) * plan.g;
                            let base1 = (t * khalf + g * 2 + 1) * plan.g;
                            let v0 = &plan.b_vals[base0..base0 + width];
                            let s0 = &plan.b_sel[base0..base0 + width];
                            let v1 = &plan.b_vals[base1..base1 + width];
                            let s1 = &plan.b_sel[base1..base1 + width];
                            for j in 0..width {
                                let (x0, x1) = (a4[s0[j] as usize], a4[s1[j] as usize]);
                                c_tile[j] += x0 * v0[j] + x1 * v1[j];
                            }
                        }
                        for j in 0..width {
                            let cj = plan.col_idx[t * plan.g + j] as usize;
                            // SAFETY: tiles own disjoint output columns
                            unsafe { *c_ptr.0.add(i * n + cj) = c_tile[j] };
                        }
                    }
                }
            });
        }
    });
}

struct VsRow {
    kernel: &'static str,
    m: usize,
    threads: usize,
    spawn_us: f64,
    pool_us: f64,
}

struct ScaleRow {
    kernel: &'static str,
    threads: usize,
    us: f64,
    scale: f64,
}

fn main() {
    let quick = quick_mode();
    // BERT-base FFN widths in the full profile; shrunk pack in quick mode
    let (k, n) = if quick {
        (512usize, 1024usize)
    } else {
        (768, 3072)
    };
    let (g, sparsity) = (64usize, 0.75f64);
    let vs_threads = 4usize;
    let ms: Vec<usize> = if quick { vec![8] } else { vec![8, 32] };
    let grid: Vec<usize> = if quick {
        vec![1, 2, 4]
    } else {
        vec![1, 2, 4, 8]
    };
    let m_scale = 32usize; // serving-sized batch

    let tw_cfg = TileConfig::tw_default();
    let tvw_cfg = TileConfig::tvw_default();
    let vw_cfg = TileConfig::vw_default();

    let mut rng = Rng::new(0xBEEF);
    let w = Matrix::randn(k, n, &mut rng);
    let tw = prune_tw(&w, sparsity, g, None);
    let tw_plan = TwPlan::encode(&w, &tw);
    let (tvw_tw, tvw_mask) = prune_tvw(&w, sparsity, g);
    let tvw_plan = TvwPlan::encode(&w, &tvw_tw, &tvw_mask);
    let vw_mask = prune_vw(&w, 0.5, 4);
    let vw_plan = Vw24Plan::encode(&w, &vw_mask).expect("K is 4-aligned");

    section(&format!(
        "per-call spawn vs persistent pool, {k}x{n} @ {:.0}% (G={g}, {vs_threads} threads)",
        sparsity * 100.0
    ));
    let pool = ThreadPool::new(vs_threads);
    let mut vs_rows: Vec<VsRow> = Vec::new();
    for &m in &ms {
        let a = Matrix::randn(m, k, &mut rng);
        let mut c = Matrix::zeros(m, n);
        let spawn_us = bench(&format!("tw  spawn-per-call   m={m}"), || {
            tw_matmul_spawn(&a, &tw_plan, &mut c, vs_threads);
        });
        let pool_us = bench(&format!("tw  pooled           m={m}"), || {
            tw_matmul_parallel_into(&a, &tw_plan, &mut c, &tw_cfg, vs_threads, &pool);
        });
        vs_rows.push(VsRow { kernel: "tw", m, threads: vs_threads, spawn_us, pool_us });
        let spawn_us = bench(&format!("tvw spawn-per-call   m={m}"), || {
            tvw_matmul_spawn(&a, &tvw_plan, &mut c, vs_threads);
        });
        let pool_us = bench(&format!("tvw pooled           m={m}"), || {
            tvw_matmul_parallel_into(&a, &tvw_plan, &mut c, &tvw_cfg, vs_threads, &pool);
        });
        vs_rows.push(VsRow { kernel: "tvw", m, threads: vs_threads, spawn_us, pool_us });
    }
    for r in &vs_rows {
        println!(
            "{:<4} m={:<4} spawn {:>9.1}us  pool {:>9.1}us  -> {:.2}x",
            r.kernel,
            r.m,
            r.spawn_us,
            r.pool_us,
            r.spawn_us / r.pool_us.max(1e-9)
        );
    }

    section(&format!("thread scaling on the pool, m={m_scale} (previously-serial TVW / 2:4)"));
    let mut scale_rows: Vec<ScaleRow> = Vec::new();
    let a = Matrix::randn(m_scale, k, &mut rng);
    let mut c = Matrix::zeros(m_scale, n);
    let mut base: std::collections::HashMap<&'static str, f64> = std::collections::HashMap::new();
    for &t in &grid {
        let pool_t = ThreadPool::new(t);
        let tw_us = bench(&format!("tw   t={t}"), || {
            tw_matmul_parallel_into(&a, &tw_plan, &mut c, &tw_cfg, t, &pool_t);
        });
        let tvw_us = bench(&format!("tvw  t={t}"), || {
            tvw_matmul_parallel_into(&a, &tvw_plan, &mut c, &tvw_cfg, t, &pool_t);
        });
        let vw_us = bench(&format!("vw24 t={t}"), || {
            vw24_matmul_parallel_into(&a, &vw_plan, &mut c, &vw_cfg, t, &pool_t);
        });
        for (kernel, us) in [("tw", tw_us), ("tvw", tvw_us), ("vw24", vw_us)] {
            let b = *base.entry(kernel).or_insert(us);
            scale_rows.push(ScaleRow { kernel, threads: t, us, scale: b / us.max(1e-9) });
        }
    }
    for kernel in ["tw", "tvw", "vw24"] {
        let best = scale_rows
            .iter()
            .filter(|r| r.kernel == kernel)
            .map(|r| r.scale)
            .fold(0.0f64, f64::max);
        println!("{kernel}: best scaling {best:.2}x over 1 thread");
    }

    // acceptance signals (also recorded in the JSON)
    let pool_beats_spawn = vs_rows.iter().all(|r| r.pool_us < r.spawn_us);
    if !pool_beats_spawn {
        println!("warning: pooled kernels did not beat the spawn baseline on this host");
    }
    let tvw_best = scale_rows
        .iter()
        .filter(|r| r.kernel == "tvw")
        .map(|r| r.scale)
        .fold(0.0f64, f64::max);
    if tvw_best < 1.1 {
        println!("warning: TVW scaled < 1.1x with threads on this host");
    }

    let doc = obj(vec![
        ("bench", s("pool_scaling")),
        ("quick", Json::Bool(quick)),
        ("k", num(k as f64)),
        ("n", num(n as f64)),
        ("g", num(g as f64)),
        ("sparsity", num(sparsity)),
        ("m_scaling", num(m_scale as f64)),
        ("pool_beats_spawn", Json::Bool(pool_beats_spawn)),
        ("tvw_best_scaling", num(tvw_best)),
        (
            "spawn_vs_pool",
            arr(vs_rows
                .iter()
                .map(|r| {
                    obj(vec![
                        ("kernel", s(r.kernel)),
                        ("m", num(r.m as f64)),
                        ("threads", num(r.threads as f64)),
                        ("spawn_us", num(r.spawn_us)),
                        ("pool_us", num(r.pool_us)),
                        ("speedup", num(r.spawn_us / r.pool_us.max(1e-9))),
                    ])
                })
                .collect()),
        ),
        (
            "scaling",
            arr(scale_rows
                .iter()
                .map(|r| {
                    obj(vec![
                        ("kernel", s(r.kernel)),
                        ("threads", num(r.threads as f64)),
                        ("us", num(r.us)),
                        ("scale_vs_serial", num(r.scale)),
                    ])
                })
                .collect()),
        ),
    ]);
    let out = "BENCH_pool.json";
    match std::fs::write(out, doc.to_string()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("writing {out}: {e}"),
    }
}
