//! Shared measurement harness for the benches (the offline registry has no
//! criterion; this provides warmup + median-of-N timing with MAD spread).

use std::time::Instant;

/// Run `f` until `min_runs` samples and `min_secs` have elapsed; report
/// median and median-absolute-deviation in microseconds.
#[allow(dead_code)] // not every bench binary uses both helpers
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> f64 {
    // warmup
    for _ in 0..2 {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    let min_runs = 5;
    let min_secs = 0.25;
    while samples.len() < min_runs || start.elapsed().as_secs_f64() < min_secs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
        if samples.len() >= 200 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut dev: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = dev[dev.len() / 2];
    println!("{name:<44} {median:>12.1} us  (±{mad:.1}, n={})", samples.len());
    median
}

/// Section header for bench output.
#[allow(dead_code)] // not every bench binary uses both helpers
pub fn section(title: &str) {
    println!("\n### {title}");
}
