//! Shared measurement harness for the benches (the offline registry has no
//! criterion; this provides warmup + median-of-N timing with MAD spread),
//! plus the CI-wide quick-mode switch.

use std::time::Instant;

/// Shared quick-mode switch honored by every bench: set
/// `PALLAS_BENCH_QUICK=1` (any value but `0`/empty) to trim sampling and
/// per-bench workloads to a CI-sized profile that finishes in minutes.
#[allow(dead_code)] // not every bench binary uses every helper
pub fn quick_mode() -> bool {
    std::env::var("PALLAS_BENCH_QUICK").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// `full` normally, `quick` under `PALLAS_BENCH_QUICK` — the one-liner
/// benches use to scale request counts / shape lists / thread grids.
#[allow(dead_code)]
pub fn scaled(full: usize, quick: usize) -> usize {
    if quick_mode() {
        quick
    } else {
        full
    }
}

/// Run `f` until `min_runs` samples and `min_secs` have elapsed; report
/// median and median-absolute-deviation in microseconds.
#[allow(dead_code)]
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> f64 {
    // warmup
    for _ in 0..2 {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    // quick mode cuts the floor, not the method: still median-of-N
    let (min_runs, min_secs, cap) = if quick_mode() {
        (3, 0.03, 25)
    } else {
        (5, 0.25, 200)
    };
    while samples.len() < min_runs || start.elapsed().as_secs_f64() < min_secs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
        if samples.len() >= cap {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut dev: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = dev[dev.len() / 2];
    println!("{name:<44} {median:>12.1} us  (±{mad:.1}, n={})", samples.len());
    median
}

/// Section header for bench output.
#[allow(dead_code)] // not every bench binary uses both helpers
pub fn section(title: &str) {
    println!("\n### {title}");
}
