//! Ablation bench: the paper's Fig. 4 optimization ladder — naive tiling
//! → transposed/coalesced → batched streams → fused CTO — on the gpusim
//! A100 model, plus the analogous CPU ladder, at several sparsities.
//!
//!   cargo bench --bench ablation_tw_impl

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench, section};
use tilewise::gemm::{tw_matmul, tw_matmul_masked, tw_matmul_parallel, tw_matmul_per_tile};
use tilewise::gpusim::{
    a100, dense_plan, tw_latency, tw_uniform_tiles, Calibration, GemmShape, Pipe, TwStrategy,
};
use tilewise::sparse::{prune_tw, TwPlan};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

fn main() {
    let specs = a100();
    let cal = Calibration::default();
    let shape = GemmShape::new(4096, 4096, 4096);
    let dense = dense_plan(shape, Pipe::TensorFp16, &specs, &cal).latency(&specs);

    println!("== Fig.4 ablation (gpusim A100, 4096^3, G=128; x = speedup vs dense TC) ==");
    println!(
        "{:<12}{:>10}{:>12}{:>10}{:>10}",
        "sparsity", "naive", "transposed", "streams", "fusedCTO"
    );
    for s in [0.25f64, 0.5, 0.75, 0.9] {
        let tiles = tw_uniform_tiles(shape, s, 128);
        let lat = |st| tw_latency(shape, &tiles, 128, Pipe::TensorFp16, st, &specs, &cal);
        let naive = lat(TwStrategy::Naive);
        let transposed = lat(TwStrategy::Transposed);
        let streams = lat(TwStrategy::BatchedStreams);
        let fused = lat(TwStrategy::FusedCto);
        println!(
            "{:<12}{:>9.2}x{:>11.2}x{:>9.2}x{:>9.2}x",
            format!("{:.0}%", s * 100.0),
            dense / naive,
            dense / transposed,
            dense / streams,
            dense / fused
        );
        assert!(fused <= streams && streams <= transposed && transposed <= naive);
    }

    section("CPU ladder at 512^3 / 75% (masked -> per-tile -> fused -> parallel)");
    let mut rng = Rng::new(11);
    let a = Matrix::randn(512, 512, &mut rng);
    let w = Matrix::randn(512, 512, &mut rng);
    let tw = prune_tw(&w, 0.75, 64, None);
    let plan = TwPlan::encode(&w, &tw);
    let mask = tw.mask();
    let threads = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4);
    let t_masked = bench("masked dense-loop", || {
        std::hint::black_box(tw_matmul_masked(&a, &w, &mask));
    });
    let t_tile = bench("per-tile kernels", || {
        std::hint::black_box(tw_matmul_per_tile(&a, &plan));
    });
    let t_fused = bench("fused CTO", || {
        std::hint::black_box(tw_matmul(&a, &plan));
    });
    bench("fused CTO parallel", || {
        std::hint::black_box(tw_matmul_parallel(&a, &plan, threads));
    });
    assert!(t_fused < t_masked, "fused must beat the masked strawman");
    assert!(t_fused <= t_tile * 1.5, "fused should not lose to per-tile");

    section("global vs per-layer budget ablation (pruner)");
    // two layers with different redundancy; global allocation should give
    // the redundant one a higher sparsity at equal total budget
    let important = Matrix::randn(256, 256, &mut rng);
    let mut redundant = Matrix::randn(256, 256, &mut rng);
    for r in 0..256 {
        for c in 0..128 {
            *redundant.at_mut(r, c) *= 0.05;
        }
    }
    let targets = tilewise::pruner::allocate_global_budget(&[&important, &redundant], 0.25);
    println!(
        "global budget @25%: important={:.3} redundant={:.3} (uniform would be 0.250/0.250)",
        targets[0], targets[1]
    );
    assert!(targets[1] > targets[0]);
    println!("\nablation bench complete");
}
