//! Bench: end-to-end forward latency of every servable zoo model through
//! the layer-graph IR, per sparsity pattern, dense-normalized like the
//! paper's Fig. 10 — plus the buffered-attention micro-benchmark (the
//! `attention_into` workspace path vs the historical per-head-allocating
//! implementation).  Emits `BENCH_models.json`.
//!
//!   cargo bench --bench model_forward
//!   PALLAS_BENCH_QUICK=1 cargo bench --bench model_forward   # CI profile

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench, quick_mode, section};
use tilewise::exec::{Backend, PreparedModel, ZooBackend, ZooSpec};
use tilewise::gemm::matmul;
use tilewise::json::{arr, num, obj, s};
use tilewise::nn::{attention_forward, attention_forward_unbuffered};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

const VARIANTS: [&str; 4] = ["model_dense", "model_tw", "model_tvw", "model_vw24"];

fn bench_spec(model: &str) -> ZooSpec {
    let mut spec = ZooSpec::for_model(model).expect("zoo model");
    if quick_mode() {
        match model {
            "bert" => {
                spec.batch = 1;
                spec.seq = 16;
                spec.width = 256;
                spec.n_layers = 1;
            }
            "vgg" => {
                spec.width_div = 4;
                spec.fc_dim = 256;
            }
            _ => {
                spec.batch = 8;
                spec.width = 128;
                spec.seq = 4;
            }
        }
    } else {
        match model {
            "bert" => {
                spec.batch = 2;
                spec.seq = 32;
                spec.width = 512;
                spec.heads = 8;
                spec.n_layers = 1;
            }
            "vgg" => {
                spec.width_div = 2;
                spec.fc_dim = 512;
            }
            _ => {
                spec.batch = 32;
                spec.width = 256;
                spec.seq = 8;
            }
        }
    }
    spec.with_variants(&VARIANTS)
}

struct PatternCell {
    variant: &'static str,
    us: f64,
    speedup: f64,
}

fn main() {
    let mut model_docs = Vec::new();
    let mut bert_tw_speedup = 0.0f64;

    for model in ["bert", "vgg", "nmt"] {
        let spec = bench_spec(model);
        section(&format!(
            "{model} end-to-end forward (batch {}, seq {}, width {}, sparsity {:.0}%, G={})",
            spec.batch,
            spec.seq,
            spec.width,
            spec.sparsity * 100.0,
            spec.g
        ));
        let t0 = std::time::Instant::now();
        let backend = ZooBackend::new(spec.clone(), None).expect("compile zoo graphs");
        let mut prepared = backend.load().expect("load graph model");
        let pack_secs = t0.elapsed().as_secs_f64();
        println!("compiled + packed {} variants in {pack_secs:.2}s", VARIANTS.len());
        let dims = backend.dims();
        let mut rng = Rng::new(11);
        let x: Vec<f32> =
            (0..dims.batch * dims.per_request_len()).map(|_| rng.normal_f32() * 0.3).collect();

        let mut cells: Vec<PatternCell> = Vec::new();
        let mut dense_us = 0.0f64;
        for variant in VARIANTS {
            let us = bench(&format!("{model} {variant}"), || {
                let out = prepared.run(variant, &x).expect("forward");
                assert!(out[0].is_finite());
            });
            if variant == "model_dense" {
                dense_us = us;
            }
            let speedup = if us > 0.0 { dense_us / us } else { 1.0 };
            cells.push(PatternCell { variant, us, speedup });
        }
        println!("dense-normalized speedups (Fig. 10 shape):");
        for c in &cells {
            println!("  {:<14} {:>10.1} us   {:>6.2}x", c.variant, c.us, c.speedup);
            if model == "bert" && c.variant == "model_tw" {
                bert_tw_speedup = c.speedup;
            }
        }
        model_docs.push(obj(vec![
            ("model", s(model)),
            ("batch", num(dims.batch as f64)),
            ("seq", num(dims.seq as f64)),
            ("d_model", num(dims.d_model as f64)),
            ("n_classes", num(dims.n_classes as f64)),
            ("sparsity", num(spec.sparsity)),
            ("g", num(spec.g as f64)),
            (
                "patterns",
                arr(cells
                    .iter()
                    .map(|c| {
                        obj(vec![
                            ("variant", s(c.variant)),
                            ("us", num(c.us)),
                            ("speedup_vs_dense", num(c.speedup)),
                        ])
                    })
                    .collect()),
            ),
        ]));
    }
    if bert_tw_speedup < 1.0 {
        println!(
            "warning: BERT TW end-to-end speedup {bert_tw_speedup:.2}x < 1 on this host \
             (gather/scatter overhead exceeded the FLOP saving at these dims)"
        );
    }

    // satellite: the buffered attention core vs the historical per-head
    // allocating implementation (scores realloc + strided V walks)
    let (seq, d, heads) = if quick_mode() { (32, 128, 4) } else { (64, 256, 8) };
    section(&format!("attention core: buffered workspace vs unbuffered baseline ({seq}x{d}, {heads} heads)"));
    let mut rng = Rng::new(12);
    let x = Matrix::randn(seq, d, &mut rng);
    let wqkv = Matrix::randn(d, 3 * d, &mut rng);
    let wout = Matrix::randn(d, d, &mut rng);
    let unbuffered_us = bench("attention unbuffered (legacy)", || {
        let y = attention_forward_unbuffered(&x, &wqkv, &wout, heads, |a, b| matmul(a, b));
        assert!(y.at(0, 0).is_finite());
    });
    let buffered_us = bench("attention buffered (_into path)", || {
        let y = attention_forward(&x, &wqkv, &wout, heads, |a, b| matmul(a, b));
        assert!(y.at(0, 0).is_finite());
    });
    let attn_speedup = if buffered_us > 0.0 { unbuffered_us / buffered_us } else { 1.0 };
    println!("buffered attention speedup: {attn_speedup:.2}x");

    let doc = obj(vec![
        ("bench", s("model_forward")),
        ("backend", s("graph-zoo")),
        ("quick", num(if quick_mode() { 1.0 } else { 0.0 })),
        ("models", arr(model_docs)),
        (
            "attention",
            obj(vec![
                ("seq", num(seq as f64)),
                ("d_model", num(d as f64)),
                ("heads", num(heads as f64)),
                ("unbuffered_us", num(unbuffered_us)),
                ("buffered_us", num(buffered_us)),
                ("speedup", num(attn_speedup)),
            ]),
        ),
    ]);
    let out = "BENCH_models.json";
    match std::fs::write(out, doc.to_string()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("writing {out}: {e}"),
    }
}
