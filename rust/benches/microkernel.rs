//! Bench: scalar loops vs the register-level SIMD microkernels for every
//! GEMM pattern (dense / TW / TVW / 2:4) at the BERT-base paper shapes,
//! plus packed-B panels vs strided B on the dense kernel.  Emits
//! `BENCH_micro.json`; CI asserts SIMD >= scalar on the dense cells
//! whenever an x86 SIMD ISA was detected.
//!
//!   cargo bench --bench microkernel

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench, quick_mode, section};
use tilewise::gemm::micro::{self, Isa};
use tilewise::gemm::{
    matmul_tiled_into, matmul_tiled_into_panel, tvw_matmul_into_with, tw_matmul_into_with,
    vw24_matmul_into_with, MicroCfg, PackedPanel, TileConfig,
};
use tilewise::json::{arr, num, obj, s, Json};
use tilewise::sparse::{prune_tvw, prune_tw, prune_vw, TvwPlan, TwPlan, Vw24Plan};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

/// GFLOP/s from a median time, counting only the useful (kept) FLOPs the
/// pattern actually executes — `density` is 1.0 for dense, (1 - sparsity)
/// for TW/TVW, 0.5 for 2:4.
fn gflops(m: usize, k: usize, n: usize, density: f64, us: f64) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64 * density / (us * 1e-6) / 1e9
}

fn main() {
    let sparsity = 0.75;
    let g = 32usize;
    // BERT-base layer shapes at seq 128 (attention projection + the two
    // FFN GEMMs — the FLOP-dominant layers the paper benchmarks)
    let shapes: Vec<(usize, usize, usize)> = if quick_mode() {
        vec![(32, 256, 256), (32, 256, 1024)]
    } else {
        vec![(128, 768, 768), (128, 768, 3072), (128, 3072, 768)]
    };

    let auto = micro::resolve(&TileConfig::dense_default());
    let x86_simd = matches!(auto.isa, Isa::Avx2 | Isa::Avx512);
    section(&format!(
        "microkernel GFLOP/s, scalar vs {} (sparsity {sparsity}, G {g})",
        micro::active_label()
    ));

    let mut rng = Rng::new(0xB16C);
    let mut cells = Vec::new();
    for &(m, k, n) in &shapes {
        let a = Matrix::randn(m, k, &mut rng);
        let w = Matrix::randn(k, n, &mut rng);
        let twplan = TwPlan::encode(&w, &prune_tw(&w, sparsity, g, None));
        let (tws, mask) = prune_tvw(&w, sparsity, g);
        let tvplan = TvwPlan::encode(&w, &tws, &mask);
        let vplan = Vw24Plan::encode(&w, &prune_vw(&w, 0.5, 4)).expect("2:4 encodable");
        let mut c = Matrix::zeros(m, n);

        // (pattern, density, bench closure factory over a pinned cfg)
        type Cell = (&'static str, f64, TileConfig);
        let pats: [Cell; 4] = [
            ("dense", 1.0, TileConfig::dense_default()),
            ("tw", 1.0 - sparsity, TileConfig::tw_default()),
            ("tvw", 1.0 - sparsity, TileConfig::tvw_default()),
            ("vw24", 0.5, TileConfig::vw_default()),
        ];
        for (pattern, density, base) in pats {
            let mut run = |mc: MicroCfg| -> f64 {
                let cfg = base.with_micro(mc);
                let name = format!("{pattern} {m}x{k}x{n} {}", mc.label());
                let us = bench(&name, || {
                    c.data.fill(0.0);
                    match pattern {
                        "dense" => matmul_tiled_into(&a, &w, &mut c, &cfg),
                        "tw" => tw_matmul_into_with(&a, &twplan, &mut c, &cfg),
                        "tvw" => tvw_matmul_into_with(&a, &tvplan, &mut c, &cfg),
                        _ => vw24_matmul_into_with(&a, &vplan, &mut c, &cfg),
                    }
                });
                gflops(m, k, n, density, us)
            };
            let scalar_gf = run(MicroCfg::Scalar);
            let simd_gf = run(MicroCfg::Auto);
            let mut cell = vec![
                ("pattern", s(pattern)),
                ("m", num(m as f64)),
                ("k", num(k as f64)),
                ("n", num(n as f64)),
                ("density", num(density)),
                ("scalar_gflops", num(scalar_gf)),
                ("simd_gflops", num(simd_gf)),
            ];
            // packed-B panel variant: dense only, and only when a SIMD
            // microkernel is live (the panel path is unreachable otherwise)
            if pattern == "dense" && auto.is_simd() {
                let panel = PackedPanel::pack(&w.data, k, n, n, auto.nr);
                let cfg = base.with_micro(MicroCfg::Auto);
                let us = bench(&format!("dense {m}x{k}x{n} panel"), || {
                    matmul_tiled_into_panel(&a, &w, Some(&panel), &mut c, &cfg);
                });
                cell.push(("panel_gflops", num(gflops(m, k, n, 1.0, us))));
            }
            println!(
                "    {pattern:<6} {m}x{k}x{n}: scalar {scalar_gf:.2} GFLOP/s, \
                 simd {simd_gf:.2} GFLOP/s ({:.2}x)",
                simd_gf / scalar_gf.max(1e-12)
            );
            cells.push(obj(cell));
        }
    }

    let doc = obj(vec![
        ("bench", s("micro")),
        ("isa", s(auto.isa.label())),
        ("micro", s(&micro::active_label())),
        ("avx2", Json::Bool(x86_simd)),
        ("sparsity", num(sparsity)),
        ("g", num(g as f64)),
        ("cells", arr(cells)),
    ]);
    let out = "BENCH_micro.json";
    match std::fs::write(out, doc.to_string()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("writing {out}: {e}"),
    }
}
