//! Bench: the L3 serving stack — batcher throughput, metrics overhead,
//! and (when artifacts are present) end-to-end request latency through
//! the PJRT executor per model variant.
//!
//!   make artifacts && cargo bench --bench coordinator

#[path = "bench_util.rs"]
mod bench_util;

use std::time::Duration;

use bench_util::{bench, section};
use tilewise::coordinator::{
    pack_batch, start, BatcherConfig, Metrics, Policy, Request, ResponseStream, ServerConfig,
};
use tilewise::util::Rng;
use tilewise::variant::Variant;

fn mk_request(id: u64, len: usize) -> Request {
    let (tx, stream) = ResponseStream::channel();
    std::mem::forget(stream); // bench: nobody reads the events
    Request {
        id,
        activation: vec![0.5; len],
        variant: None,
        decode_steps: 0,
        submitted: std::time::Instant::now(),
        events: tx,
    }
}

fn main() {
    section("micro: batching + metrics hot-path costs");
    let reqs: Vec<Request> = (0..8).map(|i| mk_request(i, 64 * 256)).collect();
    bench("pack_batch 8x(64x256)", || {
        std::hint::black_box(pack_batch(&reqs, 8, 64 * 256));
    });
    let metrics = Metrics::default();
    bench("metrics.record x100", || {
        for i in 0..100 {
            metrics.record("model_tw", 0.001 * i as f64, 4);
        }
    });
    bench("metrics.snapshot", || {
        std::hint::black_box(metrics.snapshot());
    });

    let dir = std::path::Path::new("artifacts");
    if !dir.join("meta.json").exists() {
        println!("artifacts/ missing - skipping end-to-end serving bench (run `make artifacts`)");
        return;
    }

    section("end-to-end: closed-loop single-request latency per variant");
    for variant in [Variant::Dense, Variant::Tw, Variant::Tvw] {
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
                ..BatcherConfig::default()
            },
            policy: Policy::Fixed(variant),
            variants: vec![variant],
            ..ServerConfig::default()
        };
        let handle = start(dir, cfg).expect("server start");
        let len = handle.seq * handle.d_model;
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        bench(&format!("{variant} single request (batch=1)"), || {
            let resp = handle.infer(x.clone(), None).expect("infer");
            std::hint::black_box(resp);
        });
    }
    println!("\ncoordinator bench complete");
}
