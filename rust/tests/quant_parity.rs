//! INT8 quantized serving parity: the i8×i8→i32 kernels against their f32
//! oracles at a quantization-aware tolerance, plus the invariances the
//! precision contract (DESIGN.md §11) promises exactly:
//!
//! - dense: panel (SIMD when available) vs no-panel scalar path is
//!   BIT-identical — i32 accumulation is exact, so microkernel choice can
//!   never change a served logit (the same contract `PALLAS_FORCE_SCALAR=1`
//!   and the CI forced-scalar lane rely on);
//! - pooled vs serial int8 execution is bit-identical for the same reason;
//! - model level: bert / nmt / decoder compiled at `Precision::Int8` track
//!   their f32-compiled twins across dense / TW / TVW / 2:4, serial and on
//!   an intra-op pool.
//!
//! The dense kernel is checked against a *derived* error bound (half-ulp
//! rounding of both operands), not a hand-tuned epsilon.

use std::sync::Arc;

use tilewise::gemm::{
    int8_dense_panel, int8_matmul_parallel_into, int8_matmul_tiled_into, int8_tvw_matmul_into,
    int8_tw_matmul_into, int8_tw_pack_panels, int8_vw24_matmul_into, matmul, tvw_matmul_with,
    tw_matmul_with, vw24_matmul_with, GemmScratch, Int8TvwPlan, Int8TwPlan, Int8Vw24Plan,
    TileConfig,
};
use tilewise::graph::{compile, CompileOptions, GraphModel, GraphPattern, PackOptions};
use tilewise::models::{self, ModelWorkload};
use tilewise::pool::ThreadPool;
use tilewise::quant::{Precision, QuantMatrix};
use tilewise::sparse::{prune_tvw, prune_tw, prune_vw, TvwPlan, TwPlan, Vw24Plan};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

/// Derived per-element error bound for C = A·W under symmetric int8
/// quantization of both operands: with a = qa·sa + ea (|ea| <= sa/2) and
/// w = qw·sw_j + ew (|ew| <= sw_j/2),
///   |c_f32 - c_i8| <= sa/2·sum_k|w_kj| + sw_j/2·sum_k|a_ik| + K·sa·sw_j/4.
fn dense_quant_bound(a: &Matrix, w: &Matrix, i: usize, j: usize) -> f32 {
    let amax_a = a.data.iter().fold(0.0f32, |x, &v| x.max(v.abs()));
    let sa = if amax_a > 0.0 { amax_a / 127.0 } else { 1.0 };
    let col_amax = (0..w.rows).fold(0.0f32, |x, k| x.max(w.at(k, j).abs()));
    let sw = if col_amax > 0.0 { col_amax / 127.0 } else { 1.0 };
    let row_abs: f32 = a.row(i).iter().map(|v| v.abs()).sum();
    let col_abs: f32 = (0..w.rows).map(|k| w.at(k, j).abs()).sum();
    0.5 * sa * col_abs + 0.5 * sw * row_abs + 0.25 * w.rows as f32 * sa * sw
}

fn loose_tolerance(c: &Matrix) -> f32 {
    // condensed patterns gather/scatter, so the per-element derivation
    // above does not apply verbatim; bound by the output scale instead
    let scale = c.data.iter().fold(1.0f32, |x, &v| x.max(v.abs()));
    0.06 * scale + 1e-4
}

#[test]
fn int8_dense_matches_f32_within_derived_bound() {
    let mut rng = Rng::new(31);
    // odd sizes exercise the quad-group and register-strip tails
    let a = Matrix::randn(9, 41, &mut rng);
    let w = Matrix::randn(41, 33, &mut rng);
    let q = QuantMatrix::quantize(&w);
    let cfg = TileConfig::dense_default();
    let mut scratch = GemmScratch::new();
    let mut c = Matrix::zeros(9, 33);
    int8_matmul_tiled_into(&a, &q, None, &mut c, &cfg, &mut scratch);
    let want = matmul(&a, &w);
    for i in 0..9 {
        for j in 0..33 {
            let bound = dense_quant_bound(&a, &w, i, j);
            let (got, ref_v) = (c.at(i, j), want.at(i, j));
            assert!(
                (got - ref_v).abs() <= bound,
                "c[{i}][{j}]: int8 {got} vs f32 {ref_v}, bound {bound}"
            );
        }
    }
}

#[test]
fn int8_dense_panel_path_is_bit_identical_to_scalar() {
    // i32 accumulation is exact: the packed-panel (SIMD) path and the
    // strided scalar fallback must agree to the last bit, SIMD or not
    let mut rng = Rng::new(37);
    let a = Matrix::randn(7, 50, &mut rng);
    let w = Matrix::randn(50, 19, &mut rng);
    let q = QuantMatrix::quantize(&w);
    let mut scratch = GemmScratch::new();
    for cfg in [TileConfig::dense_default(), TileConfig::dense_default().with_micro(
        tilewise::gemm::MicroCfg::Simd { mr: 4, nr: 16 },
    )] {
        let panel = int8_dense_panel(&q, tilewise::gemm::micro::resolve(&cfg).nr);
        let mut with_panel = Matrix::zeros(7, 19);
        let mut without = Matrix::zeros(7, 19);
        int8_matmul_tiled_into(&a, &q, Some(&panel), &mut with_panel, &cfg, &mut scratch);
        int8_matmul_tiled_into(&a, &q, None, &mut without, &cfg, &mut scratch);
        assert_eq!(with_panel.data, without.data);
    }
}

#[test]
fn int8_dense_pooled_is_bit_identical_to_serial() {
    let mut rng = Rng::new(41);
    let a = Matrix::randn(24, 32, &mut rng);
    let w = Matrix::randn(32, 20, &mut rng);
    let q = QuantMatrix::quantize(&w);
    let cfg = TileConfig::dense_default();
    let pool = ThreadPool::new(3);
    let mut scratch = GemmScratch::new();
    let mut serial = Matrix::zeros(24, 20);
    int8_matmul_tiled_into(&a, &q, None, &mut serial, &cfg, &mut scratch);
    let mut pooled = Matrix::zeros(24, 20);
    let eff = int8_matmul_parallel_into(&a, &q, None, &mut pooled, &cfg, 3, &pool, &mut scratch);
    assert!(eff >= 1);
    assert_eq!(serial.data, pooled.data);
}

#[test]
fn int8_condensed_kernels_track_their_f32_twins() {
    let mut rng = Rng::new(43);
    let a = Matrix::randn(6, 64, &mut rng);
    let w = Matrix::randn(64, 48, &mut rng);
    let g = 16;
    let mut scratch = GemmScratch::new();

    // TW: CTO condensation, scatter assigns kept columns into a zeroed c
    let tw = prune_tw(&w, 0.75, g, None);
    let plan = TwPlan::encode(&w, &tw);
    let qplan = Int8TwPlan::from_plan(&plan);
    let cfg = TileConfig::tw_default();
    let want = tw_matmul_with(&a, &plan, &cfg);
    let mut got = Matrix::zeros(6, 48);
    int8_tw_matmul_into(&a, &qplan, None, &mut got, &cfg, &mut scratch);
    let tol = loose_tolerance(&want);
    for (i, (x, y)) in got.data.iter().zip(&want.data).enumerate() {
        assert!((x - y).abs() <= tol, "tw[{i}]: int8 {x} vs f32 {y} (tol {tol})");
    }
    // the packed-panel path agrees bit-exactly with the no-panel path
    let panels = int8_tw_pack_panels(&qplan, tilewise::gemm::micro::resolve(&cfg).nr);
    let mut got_p = Matrix::zeros(6, 48);
    int8_tw_matmul_into(&a, &qplan, Some(&panels), &mut got_p, &cfg, &mut scratch);
    assert_eq!(got.data, got_p.data);

    // TVW: TW condensation + register 2:4
    let (tw2, mask) = prune_tvw(&w, 0.5, g);
    let tvw_plan = TvwPlan::encode(&w, &tw2, &mask);
    let q_tvw = Int8TvwPlan::from_plan(&tvw_plan);
    let cfg = TileConfig::tvw_default();
    let want = tvw_matmul_with(&a, &tvw_plan, &cfg);
    let mut got = Matrix::zeros(6, 48);
    int8_tvw_matmul_into(&a, &q_tvw, &mut got, &cfg, &mut scratch);
    let tol = loose_tolerance(&want);
    for (i, (x, y)) in got.data.iter().zip(&want.data).enumerate() {
        assert!((x - y).abs() <= tol, "tvw[{i}]: int8 {x} vs f32 {y} (tol {tol})");
    }

    // VW 2:4
    let mask = prune_vw(&w, 0.5, 4);
    let vw_plan = Vw24Plan::encode(&w, &mask).unwrap();
    let q_vw = Int8Vw24Plan::from_plan(&vw_plan);
    let cfg = TileConfig::vw_default();
    let want = vw24_matmul_with(&a, &vw_plan, &cfg);
    let mut got = Matrix::zeros(6, 48);
    int8_vw24_matmul_into(&a, &q_vw, &mut got, &cfg, &mut scratch);
    let tol = loose_tolerance(&want);
    for (i, (x, y)) in got.data.iter().zip(&want.data).enumerate() {
        assert!((x - y).abs() <= tol, "vw24[{i}]: int8 {x} vs f32 {y} (tol {tol})");
    }
}

// ---- model level: quantize-at-pack through the graph IR ----

const PATTERNS: [GraphPattern; 4] =
    [GraphPattern::Dense, GraphPattern::Tw, GraphPattern::Tvw, GraphPattern::Vw24];

fn small_opts() -> CompileOptions {
    CompileOptions {
        seq: 4,
        heads: 4,
        n_classes: 4,
        pack: PackOptions { sparsity: 0.75, g: 8, ..Default::default() },
        seed: 7,
        ..CompileOptions::default()
    }
}

fn deterministic_input(len: usize) -> Vec<f32> {
    (0..len).map(|i| ((i * 17 % 23) as f32 - 11.0) * 0.05).collect()
}

/// Compile `workload` at f32 and at int8 under `pattern`, run both, and
/// require the int8 logits to track the f32 logits within a quantization
/// budget of the logit scale — serial, pooled (bit-identical to serial),
/// and reproducible across invocations.
fn check_model_parity(workload: &ModelWorkload, pattern: GraphPattern, pool: &Arc<ThreadPool>) {
    let label = format!("{}/{:?}", workload.name, pattern);
    let opts = small_opts().with_pattern(pattern);
    let f32_prog = compile(workload, &opts).unwrap_or_else(|e| panic!("{label}: f32 compile: {e}"));
    let int8_opts = opts.with_precision(Precision::Int8);
    let int8_prog =
        compile(workload, &int8_opts).unwrap_or_else(|e| panic!("{label}: int8 compile: {e}"));
    assert!(
        int8_prog.scratch_qa > 0,
        "{label}: int8 program must reserve activation-quantization scratch"
    );
    let dims = f32_prog.dims;
    let variant = f32_prog.variant.clone();
    let x = deterministic_input(dims.batch * dims.per_request_len());

    let mut f32_model = GraphModel::new(Arc::new(vec![f32_prog]), None).unwrap();
    let want = f32_model.run(&variant, &x).unwrap();
    assert!(want.iter().all(|v| v.is_finite()), "{label}: f32 logits non-finite");

    let progs = Arc::new(vec![int8_prog]);
    let mut serial = GraphModel::new(progs.clone(), None).unwrap();
    let got = serial.run(&variant, &x).unwrap();
    assert_eq!(got.len(), want.len(), "{label}");
    let scale = want.iter().fold(1.0f32, |a, &v| a.max(v.abs()));
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!(a.is_finite(), "{label}: int8 logit {i} non-finite");
        assert!(
            (a - b).abs() <= 0.12 * scale,
            "{label}: logit {i}: int8 {a} vs f32 {b} (scale {scale})"
        );
    }

    // pooled int8 execution is exact-int: bit-identical to serial
    let mut pooled = GraphModel::new(progs, Some(pool.clone())).unwrap();
    let got_pooled = pooled.run(&variant, &x).unwrap();
    assert_eq!(got, got_pooled, "{label}: pooled int8 differs from serial");

    // workspace reuse: a second run returns bit-identical logits
    let again = serial.run(&variant, &x).unwrap();
    assert_eq!(got, again, "{label}: second int8 run differs");
}

#[test]
fn bert_int8_tracks_f32_all_patterns() {
    let workload = models::bert_at(2, 4, 16, 2);
    let pool = Arc::new(ThreadPool::new(3));
    for pattern in PATTERNS {
        check_model_parity(&workload, pattern, &pool);
    }
}

#[test]
fn nmt_int8_tracks_f32_all_patterns() {
    let workload = models::nmt_at(2, 8, 3);
    let pool = Arc::new(ThreadPool::new(3));
    for pattern in PATTERNS {
        check_model_parity(&workload, pattern, &pool);
    }
}

#[test]
fn decoder_int8_tracks_f32_all_patterns() {
    let workload = models::decoder_at(2, 4, 16, 1);
    let pool = Arc::new(ThreadPool::new(3));
    for pattern in PATTERNS {
        check_model_parity(&workload, pattern, &pool);
    }
}

#[test]
fn auto_precision_resolves_from_plan_cache_at_pack_time() {
    // Precision::Auto asks the plan cache per layer shape; with no cache
    // (or no entry) it must fall back to f32 and still serve
    let workload = models::bert_at(1, 4, 16, 1);
    let opts = small_opts().with_precision(Precision::Auto);
    let prog = compile(&workload, &opts.with_pattern(GraphPattern::Tw)).unwrap();
    // no plan cache: every layer packed f32, so no int8 staging reserved
    assert_eq!(prog.scratch_qa, 0, "Auto with no cache must degrade to f32");
    let dims = prog.dims;
    let variant = prog.variant.clone();
    let mut model = GraphModel::new(Arc::new(vec![prog]), None).unwrap();
    let x = deterministic_input(dims.batch * dims.per_request_len());
    let logits = model.run(&variant, &x).unwrap();
    assert!(logits.iter().all(|v| v.is_finite()));
}
