//! Property sweep: every tuned kernel — dense, TW, TVW, 2:4 — must match
//! the naive dense reference within 1e-4 across randomized shapes, tile
//! configs, and sparsity ratios.  This is the safety contract behind the
//! autotuner: any candidate it measures computes the same function.

use tilewise::gemm::{
    matmul_naive, matmul_tiled, tvw_matmul_with, tw_matmul_with, vw24_matmul_with, MicroCfg,
    TileConfig,
};
use tilewise::sparse::{prune_tvw, prune_tw, prune_vw, TvwPlan, TwPlan, Vw24Plan};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

const TOL: f32 = 1e-4;

#[test]
fn tuned_kernels_match_naive_reference() {
    let mut rng = Rng::new(0x7153);
    let sparsities = [0.3, 0.5, 0.75, 0.9];
    let gs = [4usize, 8, 16, 32, 64];
    for iter in 0..24 {
        let m = 1 + rng.below(48);
        let k = 4 * (1 + rng.below(24)); // 4-aligned so 2:4 always applies
        let n = 1 + rng.below(80);
        let s = sparsities[rng.below(sparsities.len())];
        let g = gs[rng.below(gs.len())];
        let cfg = TileConfig::new(1 + rng.below(70), 1 + rng.below(70));
        let ctx = format!(
            "iter={iter} m={m} k={k} n={n} s={s} g={g} bm={} bk={}",
            cfg.bm, cfg.bk
        );

        let a = Matrix::randn(m, k, &mut rng);
        let w = Matrix::randn(k, n, &mut rng);

        // dense: tuned blocking vs textbook loop
        let want_dense = matmul_naive(&a, &w);
        let got_dense = matmul_tiled(&a, &w, &cfg);
        assert!(
            got_dense.max_abs_diff(&want_dense) < TOL,
            "dense {ctx}: {}",
            got_dense.max_abs_diff(&want_dense)
        );

        // TW: tuned fused-CTO kernel vs mask oracle
        let tw = prune_tw(&w, s, g, None);
        let plan = TwPlan::encode(&w, &tw);
        let want_tw = matmul_naive(&a, &tw.mask().apply(&w));
        let got_tw = tw_matmul_with(&a, &plan, &cfg);
        assert!(
            got_tw.max_abs_diff(&want_tw) < TOL,
            "tw {ctx}: {}",
            got_tw.max_abs_diff(&want_tw)
        );

        // TVW: tuned fused kernel vs mask oracle (2:4 leg needs s >= 0.5)
        let s_tvw = s.max(0.5);
        let (tws, mask) = prune_tvw(&w, s_tvw, g);
        let tvplan = TvwPlan::encode(&w, &tws, &mask);
        let want_tvw = matmul_naive(&a, &mask.apply(&w));
        let got_tvw = tvw_matmul_with(&a, &tvplan, &cfg);
        assert!(
            got_tvw.max_abs_diff(&want_tvw) < TOL,
            "tvw {ctx}: {}",
            got_tvw.max_abs_diff(&want_tvw)
        );

        // 2:4: tuned row blocking vs mask oracle
        let mask24 = prune_vw(&w, 0.5, 4);
        let vplan = Vw24Plan::encode(&w, &mask24).expect("2:4 encodable");
        let want_vw = matmul_naive(&a, &mask24.apply(&w));
        let got_vw = vw24_matmul_with(&a, &vplan, &cfg);
        assert!(
            got_vw.max_abs_diff(&want_vw) < TOL,
            "vw24 {ctx}: {}",
            got_vw.max_abs_diff(&want_vw)
        );
    }
}

/// SIMD-vs-scalar oracle parity at deliberately awkward shapes: K not a
/// lane multiple, N not an NR multiple, m = 1, and single-tile problems.
/// Every requested register block (snapped or not) must agree with the
/// forced-scalar run of the same kernel within 1e-4.  On hosts without
/// SIMD the requests degrade to scalar and the comparison is exact.
#[test]
fn simd_tail_shapes_match_scalar_oracle() {
    let mut rng = Rng::new(0x51D0);
    // (m, k, n): lane-misaligned K (not /8 or /16), ragged N, m = 1,
    // and a single-tile case (n <= g)
    let shapes = [(1usize, 12usize, 9usize), (5, 20, 31), (17, 36, 50), (33, 28, 16), (2, 4, 3)];
    let micros = [
        MicroCfg::Simd { mr: 4, nr: 16 },
        MicroCfg::Simd { mr: 8, nr: 8 },
        MicroCfg::Simd { mr: 3, nr: 9 }, // snapped onto a compiled block
        MicroCfg::Auto,
    ];
    for &(m, k, n) in &shapes {
        let a = Matrix::randn(m, k, &mut rng);
        let w = Matrix::randn(k, n, &mut rng);
        let scalar_cfg = TileConfig::new(16, 16).with_micro(MicroCfg::Scalar);

        let want_dense = matmul_tiled(&a, &w, &scalar_cfg);
        let g = 8.min(n);
        let tw = prune_tw(&w, 0.5, g, None);
        let twplan = TwPlan::encode(&w, &tw);
        let want_tw = tw_matmul_with(&a, &twplan, &scalar_cfg);
        let (tws, mask) = prune_tvw(&w, 0.5, g);
        let tvplan = TvwPlan::encode(&w, &tws, &mask);
        let want_tvw = tvw_matmul_with(&a, &tvplan, &scalar_cfg);
        let vplan = (k % 4 == 0).then(|| {
            let mask24 = prune_vw(&w, 0.5, 4);
            Vw24Plan::encode(&w, &mask24).expect("2:4 encodable")
        });
        let want_vw = vplan.as_ref().map(|p| vw24_matmul_with(&a, p, &scalar_cfg));

        for mc in micros {
            let cfg = TileConfig::new(16, 16).with_micro(mc);
            let ctx = format!("m={m} k={k} n={n} micro={}", mc.label());
            let d = matmul_tiled(&a, &w, &cfg).max_abs_diff(&want_dense);
            assert!(d < TOL, "dense {ctx}: {d}");
            let d = tw_matmul_with(&a, &twplan, &cfg).max_abs_diff(&want_tw);
            assert!(d < TOL, "tw {ctx}: {d}");
            let d = tvw_matmul_with(&a, &tvplan, &cfg).max_abs_diff(&want_tvw);
            assert!(d < TOL, "tvw {ctx}: {d}");
            if let (Some(p), Some(want)) = (&vplan, &want_vw) {
                let d = vw24_matmul_with(&a, p, &cfg).max_abs_diff(want);
                assert!(d < TOL, "vw24 {ctx}: {d}");
            }
        }
    }
}

/// The pooled kernels must agree with the forced-scalar serial oracle at
/// the same tail shapes (chunk boundaries add their own edge cases).
#[test]
fn simd_pooled_kernels_match_scalar_oracle() {
    use tilewise::gemm::{
        matmul_parallel_into, tvw_matmul_parallel_into, tw_matmul_parallel_into,
        vw24_matmul_parallel_into,
    };
    use tilewise::pool::ThreadPool;

    let mut rng = Rng::new(0x51D1);
    let pool = ThreadPool::new(4);
    let simd_cfg = TileConfig::new(16, 16).with_micro(MicroCfg::Simd { mr: 4, nr: 16 });
    let scalar_cfg = TileConfig::new(16, 16).with_micro(MicroCfg::Scalar);
    for &(m, k, n) in &[(33usize, 36usize, 70usize), (64, 20, 96)] {
        let a = Matrix::randn(m, k, &mut rng);
        let w = Matrix::randn(k, n, &mut rng);
        let ctx = format!("m={m} k={k} n={n}");

        let want = matmul_tiled(&a, &w, &scalar_cfg);
        let mut c = Matrix::zeros(m, n);
        matmul_parallel_into(&a, &w, &mut c, &simd_cfg, 4, &pool);
        assert!(c.max_abs_diff(&want) < TOL, "dense-par {ctx}");

        let g = 16.min(n);
        let tw = prune_tw(&w, 0.5, g, None);
        let twplan = TwPlan::encode(&w, &tw);
        let want = tw_matmul_with(&a, &twplan, &scalar_cfg);
        let mut c = Matrix::zeros(m, n); // pruned columns stay zero, as in the oracle
        tw_matmul_parallel_into(&a, &twplan, &mut c, &simd_cfg, 4, &pool);
        assert!(c.max_abs_diff(&want) < TOL, "tw-par {ctx}");

        let (tws, mask) = prune_tvw(&w, 0.5, g);
        let tvplan = TvwPlan::encode(&w, &tws, &mask);
        let want = tvw_matmul_with(&a, &tvplan, &scalar_cfg);
        let mut c = Matrix::zeros(m, n);
        tvw_matmul_parallel_into(&a, &tvplan, &mut c, &simd_cfg, 4, &pool);
        assert!(c.max_abs_diff(&want) < TOL, "tvw-par {ctx}");

        let mask24 = prune_vw(&w, 0.5, 4);
        let vplan = Vw24Plan::encode(&w, &mask24).expect("2:4 encodable");
        let want = vw24_matmul_with(&a, &vplan, &scalar_cfg);
        let mut c = Matrix::zeros(m, n);
        vw24_matmul_parallel_into(&a, &vplan, &mut c, &simd_cfg, 4, &pool);
        assert!(c.max_abs_diff(&want) < TOL, "vw24-par {ctx}");
    }
}

/// The tuner's end product must survive a disk round-trip and still
/// describe runnable candidates (the serving stack depends on this).
#[test]
fn tuned_cache_roundtrip_reexecutes() {
    use tilewise::autotune::{
        bench_candidate, BenchData, MeasureOpts, PatternFamily, PlanCache, SearchSpace, Tuner,
        TunerOpts,
    };
    use tilewise::gpusim::GemmShape;

    let opts = TunerOpts {
        measure: MeasureOpts { warmup: 0, min_iters: 1, max_iters: 1, budget_secs: 0.0, trim_frac: 0.0 },
        space: SearchSpace {
            bms: vec![16, 32],
            bks: vec![64],
            gs: vec![16],
            threads: vec![1],
            ..SearchSpace::default()
        },
        max_measured: 2,
        m_cap: Some(16),
        ..TunerOpts::default()
    };
    let tuner = Tuner::new(opts);
    let shape = GemmShape::new(32, 64, 64);
    let res = tuner.tune_gemm(shape, PatternFamily::Tw).expect("tw tunable");

    let mut cache = PlanCache::new();
    cache.insert(res.entry.clone());
    let dir = std::env::temp_dir().join(format!("tilewise_props_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plans.json");
    cache.save(&path).unwrap();

    let loaded = PlanCache::load(&path).unwrap();
    assert_eq!(loaded.len(), 1);
    let entry = loaded.get(&res.entry.key).expect("key survives");
    let cand = entry.candidate().expect("candidate reconstructs");
    // the reloaded candidate still executes on fresh operands
    let mut data = BenchData::new(
        GemmShape::new(entry.key.m, entry.key.k, entry.key.n),
        0.75,
        1,
    );
    let meas = bench_candidate(
        &mut data,
        &cand,
        &MeasureOpts { warmup: 0, min_iters: 1, max_iters: 1, budget_secs: 0.0, trim_frac: 0.0 },
    );
    assert!(meas.is_some());
    let _ = std::fs::remove_dir_all(&dir);
}
