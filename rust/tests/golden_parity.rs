//! Cross-language golden parity: the Rust pruners must reproduce the
//! Python implementation's pattern decisions exactly (same weights in →
//! same masks out).  The fixture is written by `python/compile/golden.py`
//! during `make artifacts`.

use tilewise::json::Json;
use tilewise::sparse::{prune_bw, prune_ew, prune_tew, prune_tvw, prune_tw, prune_vw, Mask};
use tilewise::tensor::Matrix;

fn fixture() -> Option<(Json, Matrix, usize)> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden.json");
    let text = std::fs::read_to_string(path).ok()?;
    let v = Json::parse(&text).ok()?;
    let k = v.get("k")?.as_usize()?;
    let n = v.get("n")?.as_usize()?;
    let g = v.get("g")?.as_usize()?;
    let w: Vec<f32> = v.get("w")?.as_arr()?.iter().map(|x| x.as_f64().unwrap() as f32).collect();
    Some((v.clone(), Matrix::from_vec(k, n, w), g))
}

fn golden_mask(v: &Json, case: &str, rows: usize, cols: usize) -> Mask {
    let bits = v.at(&["cases", case]).unwrap().as_arr().unwrap();
    Mask { rows, cols, keep: bits.iter().map(|b| b.as_f64().unwrap() != 0.0).collect() }
}

fn check(case: &str, got: &Mask, v: &Json) {
    let want = golden_mask(v, case, got.rows, got.cols);
    let diff = got.keep.iter().zip(&want.keep).filter(|(a, b)| a != b).count();
    assert_eq!(
        diff, 0,
        "{case}: {diff}/{} cells differ from the Python fixture",
        got.keep.len()
    );
}

#[test]
fn ew_parity() {
    let Some((v, w, _)) = fixture() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    check("ew_50", &prune_ew(&w, 0.5, None), &v);
}

#[test]
fn vw_parity() {
    let Some((v, w, _)) = fixture() else { return };
    check("vw4_50", &prune_vw(&w, 0.5, 4), &v);
}

#[test]
fn bw_parity() {
    let Some((v, w, _)) = fixture() else { return };
    check("bw8_50", &prune_bw(&w, 0.5, 8), &v);
}

#[test]
fn tw_parity() {
    let Some((v, w, g)) = fixture() else { return };
    check("tw_60", &prune_tw(&w, 0.6, g, None).mask(), &v);
}

#[test]
fn tw_plan_structure_parity() {
    let Some((v, w, g)) = fixture() else { return };
    let plan = tilewise::sparse::TwPlan::encode(&w, &prune_tw(&w, 0.6, g, None));
    let p = v.get("tw_plan").unwrap();
    assert_eq!(plan.tiles, p.get("tiles").unwrap().as_usize().unwrap());
    assert_eq!(plan.kmax, p.get("kmax").unwrap().as_usize().unwrap());
    let row_len: Vec<i32> = p
        .get("row_len")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as i32)
        .collect();
    assert_eq!(plan.row_len, row_len);
    let col_idx: Vec<i32> = p
        .get("col_idx")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as i32)
        .collect();
    assert_eq!(plan.col_idx, col_idx);
    let row_idx: Vec<i32> = p
        .get("row_idx")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as i32)
        .collect();
    assert_eq!(plan.row_idx, row_idx);
}

#[test]
fn tew_parity() {
    let Some((v, w, g)) = fixture() else { return };
    let (tw, remedy) = prune_tew(&w, 0.6, 0.05, g);
    check("tew_60_5", &tw.mask().or(&remedy), &v);
}

#[test]
fn tvw_parity() {
    let Some((v, w, g)) = fixture() else { return };
    let (_, mask) = prune_tvw(&w, 0.75, g);
    check("tvw_75", &mask, &v);
}
