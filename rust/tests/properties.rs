//! Property-based tests over randomized shapes/sparsities (hand-rolled
//! generator loop; the offline registry has no proptest).  Each property
//! runs against `CASES` random configurations.

use tilewise::gemm::{
    block_spmm, csr_spmm, matmul_naive, tw_matmul, tw_matmul_parallel, tvw_matmul, vw24_matmul,
};
use tilewise::gemm::BlockSparse;
use tilewise::sparse::{
    prune_bw, prune_ew, prune_tew, prune_tvw, prune_tw, prune_vw, Csr, TvwPlan, TwPlan, Vw24Plan,
};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

const CASES: usize = 40;

struct Gen {
    rng: Rng,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed) }
    }
    fn dim(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }
    fn dim_mult(&mut self, mult: usize, max_mults: usize) -> usize {
        (1 + self.rng.below(max_mults)) * mult
    }
    fn sparsity(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }
    fn matrix(&mut self, r: usize, c: usize) -> Matrix {
        Matrix::randn(r, c, &mut self.rng)
    }
}

#[test]
fn prop_tw_plan_roundtrip() {
    let mut g = Gen::new(100);
    for case in 0..CASES {
        let (k, n) = (g.dim(8, 96), g.dim(4, 96));
        let gran = [4usize, 8, 16, 32][g.rng.below(4)];
        let s = g.sparsity(0.0, 0.95);
        let w = g.matrix(k, n);
        let tw = prune_tw(&w, s, gran, None);
        let plan = TwPlan::encode(&w, &tw);
        let masked = tw.mask().apply(&w);
        assert_eq!(
            plan.decode().max_abs_diff(&masked),
            0.0,
            "case {case}: k={k} n={n} g={gran} s={s}"
        );
    }
}

#[test]
fn prop_tw_kernel_matches_oracle() {
    let mut g = Gen::new(200);
    for case in 0..CASES {
        let (m, k, n) = (g.dim(1, 48), g.dim(8, 64), g.dim(4, 64));
        let gran = [4usize, 8, 16][g.rng.below(3)];
        let s = g.sparsity(0.0, 0.9);
        let a = g.matrix(m, k);
        let w = g.matrix(k, n);
        let tw = prune_tw(&w, s, gran, None);
        let plan = TwPlan::encode(&w, &tw);
        let want = matmul_naive(&a, &tw.mask().apply(&w));
        let got = tw_matmul(&a, &plan);
        assert!(
            got.max_abs_diff(&want) < 1e-3,
            "case {case}: m={m} k={k} n={n} g={gran} s={s}: {}",
            got.max_abs_diff(&want)
        );
        let got_par = tw_matmul_parallel(&a, &plan, 3);
        assert!(got_par.max_abs_diff(&want) < 1e-3, "parallel case {case}");
    }
}

#[test]
fn prop_vw24_kernel_matches_oracle() {
    let mut g = Gen::new(300);
    for case in 0..CASES {
        let (m, k, n) = (g.dim(1, 40), g.dim_mult(4, 16), g.dim(1, 48));
        let a = g.matrix(m, k);
        let w = g.matrix(k, n);
        let mask = prune_vw(&w, 0.5, 4);
        let plan = Vw24Plan::encode(&w, &mask).unwrap();
        let want = matmul_naive(&a, &mask.apply(&w));
        assert!(
            vw24_matmul(&a, &plan).max_abs_diff(&want) < 1e-3,
            "case {case}: m={m} k={k} n={n}"
        );
    }
}

#[test]
fn prop_tvw_kernel_matches_oracle() {
    let mut g = Gen::new(400);
    for case in 0..CASES {
        let (m, k, n) = (g.dim(1, 40), g.dim_mult(8, 10), g.dim(4, 64));
        let gran = [4usize, 8, 16][g.rng.below(3)];
        let s = g.sparsity(0.5, 0.95);
        let a = g.matrix(m, k);
        let w = g.matrix(k, n);
        let (tw, mask) = prune_tvw(&w, s, gran);
        let plan = TvwPlan::encode(&w, &tw, &mask);
        let want = matmul_naive(&a, &mask.apply(&w));
        let got = tvw_matmul(&a, &plan);
        assert!(
            got.max_abs_diff(&want) < 1e-3,
            "case {case}: m={m} k={k} n={n} g={gran} s={s}: {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn prop_spmm_matches_oracle() {
    let mut g = Gen::new(500);
    for case in 0..CASES {
        let (m, k, n) = (g.dim(1, 40), g.dim(4, 64), g.dim(4, 64));
        let s = g.sparsity(0.1, 0.99);
        let a = g.matrix(m, k);
        let w = g.matrix(k, n);
        let mask = prune_ew(&w, s, None);
        let csr = Csr::from_masked(&w, &mask);
        let want = matmul_naive(&a, &mask.apply(&w));
        assert!(csr_spmm(&a, &csr).max_abs_diff(&want) < 1e-3, "case {case}");
    }
}

#[test]
fn prop_block_spmm_matches_oracle() {
    let mut g = Gen::new(600);
    for case in 0..CASES {
        let gran = [4usize, 8, 16][g.rng.below(3)];
        let (m, kb, nb) = (g.dim(1, 32), g.dim(1, 6), g.dim(1, 6));
        let (k, n) = (kb * gran, nb * gran);
        let s = g.sparsity(0.0, 0.95);
        let a = g.matrix(m, k);
        let w = g.matrix(k, n);
        let mask = prune_bw(&w, s, gran);
        let bs = BlockSparse::from_masked(&w, &mask, gran);
        let want = matmul_naive(&a, &mask.apply(&w));
        assert!(block_spmm(&a, &bs).max_abs_diff(&want) < 1e-3, "case {case}");
    }
}

#[test]
fn prop_sparsity_targets_hit() {
    let mut g = Gen::new(700);
    for _ in 0..CASES {
        let (k, n) = (g.dim(32, 128), g.dim(32, 128));
        let w = g.matrix(k, n);
        let s = g.sparsity(0.1, 0.9);
        let ew = prune_ew(&w, s, None);
        assert!((ew.sparsity() - s).abs() < 0.02, "EW {} vs {s}", ew.sparsity());
        let tw = prune_tw(&w, s, 16, None);
        assert!((tw.sparsity() - s).abs() < 0.08, "TW {} vs {s}", tw.sparsity());
    }
}

#[test]
fn prop_tew_masks_disjoint_and_sized() {
    let mut g = Gen::new(800);
    for _ in 0..CASES {
        let (k, n) = (g.dim(24, 96), g.dim(24, 96));
        let w = g.matrix(k, n);
        let s = g.sparsity(0.3, 0.85);
        let delta = g.sparsity(0.01, 0.10);
        let (tw, remedy) = prune_tew(&w, s, delta, 8);
        let twm = tw.mask();
        assert!(!remedy.keep.iter().zip(&twm.keep).any(|(r, t)| *r && *t));
        let fin = twm.or(&remedy);
        assert!((fin.sparsity() - s).abs() < 0.1, "{} vs {s}", fin.sparsity());
    }
}

#[test]
fn prop_tvw_is_24_and_subset() {
    let mut g = Gen::new(900);
    for _ in 0..CASES {
        let (k, n) = (g.dim(24, 96), g.dim(24, 96));
        let w = g.matrix(k, n);
        let s = g.sparsity(0.5, 0.95);
        let (tw, mask) = prune_tvw(&w, s, 8);
        assert!(mask.subset_of(&tw.mask()));
        // every 4-condensed-row group keeps at most 2 per column
        for t in 0..tw.num_tiles() {
            let rows = &tw.tile_rows[t];
            for &c in tw.tile_cols(t) {
                for grp in 0..rows.len().div_ceil(4) {
                    let len = 4.min(rows.len() - grp * 4);
                    let kept = (0..len).filter(|&i| mask.at(rows[grp * 4 + i], c)).count();
                    assert!(kept <= 2);
                }
            }
        }
    }
}
