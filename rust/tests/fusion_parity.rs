//! Fused-epilogue parity: every zoo model (BERT / NMT / decoder at small
//! dims) compiled with the graph fusion pass must serve the same logits
//! as its unfused twin (`CompileOptions { fuse: false }`) at 1e-4 —
//! across dense / TW / TVW / 2:4, f32 and int8, serial and pooled, and
//! at every effective batch prefix (m_eff = 1, B/2, B).  For dense f32
//! the fused epilogue performs the identical float ops in the identical
//! order, so serial parity is required to be bit-exact.
//!
//! The fused side compiles under the *default* options, so the no-fusion
//! CI lane (`PALLAS_NO_FUSION=1`) degrades it to the unfused program and
//! the comparison stays trivially green — the same degradation contract
//! the forced-scalar lane (`PALLAS_FORCE_SCALAR=1`) relies on.  The
//! op-stream structure tests pin `fuse: true` explicitly so they hold in
//! every lane.

use std::sync::Arc;

use tilewise::exec::PreparedModel;
use tilewise::graph::{compile, CompileOptions, GraphModel, GraphPattern, Op, PackOptions};
use tilewise::models::{self, ModelWorkload};
use tilewise::pool::ThreadPool;
use tilewise::quant::Precision;

const PATTERNS: [GraphPattern; 4] =
    [GraphPattern::Dense, GraphPattern::Tw, GraphPattern::Tvw, GraphPattern::Vw24];

fn opts_at(precision: Precision, causal: bool) -> CompileOptions {
    CompileOptions {
        seq: 4,
        heads: 4,
        n_classes: 4,
        pack: PackOptions { sparsity: 0.75, g: 8, precision },
        seed: 7,
        causal,
        // fuse: the env-aware default — on everywhere except the
        // no-fusion CI lane
        ..CompileOptions::default()
    }
}

fn deterministic_input(len: usize) -> Vec<f32> {
    (0..len).map(|i| ((i * 17 % 23) as f32 - 11.0) * 0.05).collect()
}

/// Compile `workload` fused and unfused under one pattern/precision, and
/// require logit agreement serial, pooled, and at batch prefixes.
fn check_fusion_parity(
    workload: &ModelWorkload,
    pattern: GraphPattern,
    precision: Precision,
    causal: bool,
    pool: &Arc<ThreadPool>,
) {
    let label = format!("{}/{:?}/{}", workload.name, pattern, precision.label());
    let opts = opts_at(precision, causal).with_pattern(pattern);
    let fused = compile(workload, &opts).unwrap_or_else(|e| panic!("{label}: compile: {e}"));
    let unfused = compile(workload, &CompileOptions { fuse: false, ..opts.clone() }).unwrap();
    let dims = fused.dims;
    assert_eq!(dims, unfused.dims, "{label}: fused/unfused dims diverge");
    let variant = fused.variant.clone();
    let full = deterministic_input(dims.batch * dims.per_request_len());

    let mut fused_serial = GraphModel::new(Arc::new(vec![fused]), None).unwrap();
    let mut unfused_serial = GraphModel::new(Arc::new(vec![unfused]), None).unwrap();
    let want = unfused_serial.run(&variant, &full).unwrap();
    let got = fused_serial.run(&variant, &full).unwrap();
    assert_eq!(got.len(), want.len(), "{label}");
    assert!(want.iter().all(|v| v.is_finite()), "{label}: unfused non-finite");
    if pattern == GraphPattern::Dense && precision == Precision::Fp32 {
        // dense f32 runs the same float ops in the same order fused or
        // not: serial parity must be bit-exact, not just within tolerance
        assert_eq!(got, want, "{label}: dense f32 fusion must be bit-identical");
    }
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-4, "{label}: serial logit {i}: fused {a} vs unfused {b}");
    }

    // pooled dispatch of the fused program against the serial unfused
    // oracle: fusion must compose with every parallel kernel path
    let fused2 = compile(workload, &opts).unwrap();
    let mut fused_pooled = GraphModel::new(Arc::new(vec![fused2]), Some(pool.clone())).unwrap();
    let got_pooled = fused_pooled.run(&variant, &full).unwrap();
    for (i, (a, b)) in got_pooled.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-4, "{label}: pooled logit {i}: fused {a} vs unfused {b}");
    }

    // batch prefixes: the per-bucket variable-M dispatch must thread the
    // epilogue exactly like the full-batch path
    let mut m_effs = vec![1, (dims.batch / 2).max(1)];
    m_effs.dedup();
    for m_eff in m_effs {
        let prefix = &full[..m_eff * dims.per_request_len()];
        let want_m = unfused_serial.run_batch(&variant, prefix, m_eff).unwrap();
        let got_m = fused_serial.run_batch(&variant, prefix, m_eff).unwrap();
        assert_eq!(got_m.len(), m_eff * dims.n_classes, "{label} m_eff={m_eff}");
        for (i, (a, b)) in got_m.iter().zip(&want_m).enumerate() {
            assert!(
                (a - b).abs() < 1e-4,
                "{label} m_eff={m_eff}: logit {i}: fused {a} vs unfused {b}"
            );
        }
        let got_mp = fused_pooled.run_batch(&variant, prefix, m_eff).unwrap();
        for (i, (a, b)) in got_mp.iter().zip(&want_m).enumerate() {
            assert!(
                (a - b).abs() < 1e-4,
                "{label} m_eff={m_eff}: pooled logit {i}: fused {a} vs unfused {b}"
            );
        }
    }
    // the full batch still executes correctly after prefix runs shrank
    // and regrew the fused workspace
    let again = fused_serial.run(&variant, &full).unwrap();
    assert_eq!(got, again, "{label}: full batch after prefix runs differs");
}

fn check_model(workload: &ModelWorkload, causal: bool) {
    let pool = Arc::new(ThreadPool::new(3));
    for precision in [Precision::Fp32, Precision::Int8] {
        for pattern in PATTERNS {
            check_fusion_parity(workload, pattern, precision, causal, &pool);
        }
    }
}

#[test]
fn bert_fused_matches_unfused_all_patterns_and_precisions() {
    check_model(&models::bert_at(4, 4, 16, 2), false);
}

#[test]
fn nmt_fused_matches_unfused_all_patterns_and_precisions() {
    check_model(&models::nmt_at(4, 8, 3), false);
}

#[test]
fn decoder_fused_matches_unfused_all_patterns_and_precisions() {
    check_model(&models::decoder_at(4, 4, 16, 2), true);
}

#[test]
fn fused_transformer_op_stream_has_no_elementwise_tail_ops() {
    // pinned fuse: true so this structural claim holds in the no-fusion
    // CI lane too — the pass itself must strip every BiasAct/Residual a
    // transformer layer emits, for every pattern and precision
    for precision in [Precision::Fp32, Precision::Int8] {
        for pattern in PATTERNS {
            let opts = CompileOptions { fuse: true, ..opts_at(precision, false) }
                .with_pattern(pattern);
            let p = compile(&models::bert_at(2, 4, 16, 2), &opts).unwrap();
            let bias = p.ops.iter().filter(|o| matches!(o, Op::BiasAct { .. })).count();
            let res = p.ops.iter().filter(|o| matches!(o, Op::Residual { .. })).count();
            assert_eq!(
                (bias, res),
                (0, 0),
                "{pattern:?}/{}: unfused elementwise ops remain",
                precision.label()
            );
            assert!(
                p.weights.iter().any(|w| w.epilogue.is_some()),
                "{pattern:?}/{}: no node carries an epilogue",
                precision.label()
            );
        }
    }
}

#[test]
fn decode_step_programs_fuse_and_match_the_unfused_decode() {
    // the skinny-M decode-step GEMMs thread the epilogue too: a fused
    // decode engine must stream the same logits as an unfused one
    use tilewise::graph::{compile_decode_set, DecodeEngine};
    let wl = models::decoder_at(2, 4, 16, 2);
    let opts = CompileOptions { fuse: true, ..opts_at(Precision::Fp32, true) };
    let patterns = [GraphPattern::Dense, GraphPattern::Tw];
    let fused = compile_decode_set(&wl, &opts, &patterns, 8).unwrap();
    let unfused =
        compile_decode_set(&wl, &CompileOptions { fuse: false, ..opts }, &patterns, 8).unwrap();
    let mut fe = DecodeEngine::new(Arc::new(fused)).unwrap();
    let mut ue = DecodeEngine::new(Arc::new(unfused)).unwrap();
    let d_in = fe.caps().d_in;
    let prompt: Vec<f32> = (0..2 * d_in).map(|i| ((i % 5) as f32 - 2.0) * 0.2).collect();
    let slot = fe.free_slot().unwrap();
    fe.begin(slot, &prompt).unwrap();
    ue.begin(slot, &prompt).unwrap();
    for variant in ["model_dense", "model_tw"] {
        for step in 0..3 {
            let f = fe.step(variant, None).unwrap();
            let u = ue.step(variant, None).unwrap();
            assert_eq!(f.len(), u.len(), "{variant} step {step}");
            for (a, b) in f.iter().flat_map(|o| &o.logits).zip(u.iter().flat_map(|o| &o.logits)) {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{variant} step {step}: fused {a} vs unfused {b}"
                );
            }
        }
    }
    fe.end(slot).unwrap();
    ue.end(slot).unwrap();
}
