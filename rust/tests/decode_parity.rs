//! Decode-parity suite (ISSUE 8 acceptance): streamed autoregressive
//! decode through the serving stack must be numerically equivalent to the
//! one-shot forward of the same model, for both decode-capable zoo models
//! (nmt stacked-LSTM, decoder-style transformer) across all four packed
//! patterns (dense / TW / TVW / 2:4) — the decode step programs replay
//! the exact one-shot weight draw, so the step that consumes the last
//! prompt row must reproduce the one-shot logits at 1e-4.
//!
//! Plus the scheduling properties the tolerance alone doesn't cover:
//! sessions joining and leaving the in-flight batch mid-decode (slot
//! reuse included) must stream exactly what they stream when run solo,
//! the M=1 fast path must match the batched path, and backpressure must
//! shed at submit time without wedging the decode lane.

use std::sync::Arc;

use tilewise::coordinator::{
    start_with_backend, ServerConfig, ServerHandle, StreamEvent,
};
use tilewise::exec::{ZooBackend, ZooSpec};
use tilewise::variant::Variant;

const PATTERNS: [Variant; 4] = [Variant::Dense, Variant::Tw, Variant::Tvw, Variant::Vw24];
const ALL_VARIANTS: [&str; 4] = ["model_dense", "model_tw", "model_tvw", "model_vw24"];

fn tiny_spec(model: &str) -> ZooSpec {
    let mut spec = ZooSpec::for_model(model).expect("zoo model");
    spec.batch = 2;
    spec.seq = 4;
    spec.width = 16;
    spec.n_layers = 1;
    spec.n_classes = 4;
    spec.g = 8;
    spec.max_steps = 8;
    spec.with_variants(&ALL_VARIANTS)
}

fn start_zoo(model: &str, cfg: ServerConfig) -> ServerHandle {
    let backend = Arc::new(ZooBackend::new(tiny_spec(model), None).expect("compile zoo model"));
    start_with_backend(backend, cfg).expect("zoo server start")
}

fn deterministic_prompt(len: usize, salt: usize) -> Vec<f32> {
    (0..len).map(|i| (((i * 17 + salt * 5) % 23) as f32 - 11.0) * 0.05).collect()
}

/// The headline acceptance check: for every decode-capable model and
/// every pattern, a streamed session over the full one-shot prompt
/// (seq rows, 1 generated token) must reproduce the one-shot logits at
/// 1e-4 — the retiring step is exactly the step that consumed the last
/// prompt row.
#[test]
fn streamed_decode_matches_one_shot_across_patterns() {
    for model in ["nmt", "decoder"] {
        let handle = start_zoo(model, ServerConfig::default());
        let caps = handle.decode_caps.expect("decode-capable zoo model");
        assert_eq!(caps.d_in, handle.d_model, "{model}: prompt rows are embedding rows");
        let x = deterministic_prompt(handle.seq * handle.d_model, 1);
        for variant in PATTERNS {
            let label = format!("{model}/{variant}");
            let one_shot = handle.infer(x.clone(), Some(variant)).unwrap();
            let streamed = handle.submit_decode(x.clone(), Some(variant), 1).wait().unwrap();
            assert_eq!(streamed.tokens, 1, "{label}");
            assert_eq!(streamed.variant, variant.name(), "{label}");
            assert_eq!(one_shot.logits.len(), streamed.logits.len(), "{label}");
            assert!(one_shot.logits.iter().all(|v| v.is_finite()), "{label}");
            for (i, (a, b)) in one_shot.logits.iter().zip(&streamed.logits).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{label}: logit {i}: one-shot {a} vs streamed {b}"
                );
            }
        }
        assert_eq!(handle.metrics.errors(), 0, "{model}");
    }
}

/// Collect every Token event's logits (one row per step) plus the
/// terminal token count.
fn stream_rows(stream: tilewise::coordinator::ResponseStream) -> (Vec<Vec<f32>>, usize) {
    let mut rows = Vec::new();
    let mut tokens = 0;
    for ev in stream {
        match ev {
            StreamEvent::Token(t) => rows.push(t.logits),
            StreamEvent::Done(resp) => tokens = resp.tokens,
            StreamEvent::Error(e) => panic!("decode session failed: {e}"),
        }
    }
    (rows, tokens)
}

/// Continuous-batching isolation: three sessions with ragged lengths on a
/// 2-slot engine — the third pends until a retirement frees a slot (join
/// mid-decode + slot reuse), the shortest retires while others run (leave
/// mid-decode).  Every session must stream exactly what it streams when
/// run solo on a fresh server.
#[test]
fn sessions_joining_and_leaving_mid_decode_match_solo_runs() {
    for model in ["nmt", "decoder"] {
        let handle = start_zoo(model, ServerConfig::default());
        let caps = handle.decode_caps.unwrap();
        assert_eq!(caps.slots, 2, "{model}: ragged schedule below assumes 2 slots");
        // (prompt rows, new tokens): steps = rows + tokens - 1 gives 5,
        // 7 and 4 steps — session 0 retires while session 1 runs (leave
        // mid-decode), and session 2 joins into the freed slot (join
        // mid-decode + slot reuse)
        let shapes = [(1usize, 5usize), (4, 4), (2, 3)];
        let prompts: Vec<Vec<f32>> = shapes
            .iter()
            .enumerate()
            .map(|(i, (rows, _))| deterministic_prompt(rows * caps.d_in, i))
            .collect();

        // solo controls: each session alone on its own server
        let solo: Vec<(Vec<Vec<f32>>, usize)> = shapes
            .iter()
            .zip(&prompts)
            .map(|((_, tokens), prompt)| {
                let solo_handle = start_zoo(model, ServerConfig::default());
                stream_rows(solo_handle.submit_decode(
                    prompt.clone(),
                    Some(Variant::Tw),
                    *tokens,
                ))
            })
            .collect();

        // shared run: all three submitted at once — admission order is
        // FIFO, so session 2 joins only after a slot frees
        let streams: Vec<_> = shapes
            .iter()
            .zip(&prompts)
            .map(|((_, tokens), prompt)| {
                handle.submit_decode(prompt.clone(), Some(Variant::Tw), *tokens)
            })
            .collect();
        for (i, stream) in streams.into_iter().enumerate() {
            let label = format!("{model}: session {i}");
            let (rows, tokens) = stream_rows(stream);
            let (want_rows, want_tokens) = &solo[i];
            assert_eq!(tokens, *want_tokens, "{label}");
            assert_eq!(rows.len(), want_rows.len(), "{label}: step count");
            for (step, (got, want)) in rows.iter().zip(want_rows).enumerate() {
                assert_eq!(got.len(), want.len(), "{label}: step {step}");
                for (j, (a, b)) in got.iter().zip(want).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "{label}: step {step} logit {j}: shared {a} vs solo {b}"
                    );
                }
            }
        }
        assert_eq!(handle.metrics.errors(), 0, "{model}");
        let stats = handle.metrics.decode_stats();
        assert_eq!(stats.tokens, 5 + 4 + 3, "{model}: all sessions retired");
        // >= 1.0 (not > 1.0): admission timing is the client's race to
        // the intake channel, so perfect overlap is not guaranteed —
        // the per-session logits equality above is the real check
        assert!(stats.mean_active_slots >= 1.0, "{model}");
    }
}

/// The M=1 fast path is a latency optimisation only: same kernels, same
/// logits as the batched path, batch_size 1 — checked through the
/// graph-compiled zoo model (the native-backend twin lives in the server
/// unit tests).
#[test]
fn fast_path_m1_matches_batched_logits_on_zoo_model() {
    let handle = start_zoo("bert", ServerConfig::low_latency().build().unwrap());
    let x = deterministic_prompt(handle.seq * handle.d_model, 3);
    for variant in PATTERNS {
        let fast = handle.submit_fast(x.clone(), Some(variant)).wait().unwrap();
        let batched = handle.submit(x.clone(), Some(variant)).wait().unwrap();
        assert_eq!(fast.batch_size, 1, "{variant}");
        assert_eq!(fast.logits.len(), batched.logits.len(), "{variant}");
        for (i, (a, b)) in fast.logits.iter().zip(&batched.logits).enumerate() {
            assert!((a - b).abs() < 1e-5, "{variant}: logit {i}: fast {a} vs batched {b}");
        }
    }
    assert_eq!(handle.metrics.errors(), 0);
}

/// Backpressure sheds one-shot submissions at submit time (None, counted)
/// while the decode lane — which has its own pending queue — keeps
/// serving sessions; nothing wedges.
#[test]
fn backpressure_sheds_one_shot_but_decode_keeps_streaming() {
    let cfg = ServerConfig::builder().max_queue(1).build().unwrap();
    let handle = start_zoo("nmt", cfg);
    let caps = handle.decode_caps.unwrap();
    let len = handle.seq * handle.d_model;
    let mut kept = Vec::new();
    let mut shed = 0u64;
    for _ in 0..32 {
        match handle.try_submit(vec![0.1; len], Some(Variant::Tw)) {
            Some(stream) => kept.push(stream),
            None => shed += 1,
        }
    }
    assert!(shed > 0, "expected sheds with max_queue=1");
    assert_eq!(handle.shed_count(), shed);
    // decode sessions are not subject to the one-shot queue bound
    let resp = handle
        .submit_decode(deterministic_prompt(2 * caps.d_in, 9), Some(Variant::Tw), 2)
        .wait()
        .expect("decode unaffected by one-shot backpressure");
    assert_eq!(resp.tokens, 2);
    for stream in kept {
        assert!(stream.wait().is_ok(), "kept submissions all complete");
    }
}
