//! Integration tests for the persistent pool runtime and the pooled
//! parallel kernel paths: pool reuse / containment / oversubscription,
//! and parity of every `*_parallel_into` kernel against its serial
//! oracle at 1e-4 across odd shapes and thread counts.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};

use tilewise::gemm::{
    matmul_naive, matmul_parallel_into, tvw_matmul_parallel_into, tvw_matmul_with,
    tw_matmul_parallel_into, tw_matmul_with, vw24_matmul_parallel_into, vw24_matmul_with,
    TileConfig,
};
use tilewise::pool::ThreadPool;
use tilewise::sparse::{prune_tvw, prune_tw, prune_vw, TvwPlan, TwPlan, Vw24Plan};
use tilewise::tensor::Matrix;
use tilewise::util::Rng;

// ---- pool runtime behaviour ----

#[test]
fn pool_is_reused_across_many_calls() {
    let pool = ThreadPool::new(4);
    let counter = AtomicUsize::new(0);
    for round in 0..100 {
        pool.parallel_for(8, |i| {
            counter.fetch_add(i + 1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), (round + 1) * 36);
    }
}

#[test]
fn panic_in_task_is_contained() {
    let pool = ThreadPool::new(3);
    for _ in 0..5 {
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(12, |i| {
                if i % 5 == 2 {
                    panic!("task failure");
                }
            });
        }));
        assert!(r.is_err());
    }
    // all workers survived every panicking job
    let ok = AtomicUsize::new(0);
    pool.parallel_for(12, |_| {
        ok.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(ok.load(Ordering::Relaxed), 12);
}

#[test]
fn oversubscribed_pools_and_jobs_complete() {
    // more lanes than the host has cores, and more chunks than lanes
    let pool = ThreadPool::new(32);
    let sum = AtomicUsize::new(0);
    pool.parallel_for(500, |i| {
        sum.fetch_add(i, Ordering::Relaxed);
    });
    assert_eq!(sum.load(Ordering::Relaxed), (0..500).sum::<usize>());
    // tiny pool, many concurrent submissions from scope threads
    let small = ThreadPool::new(2);
    let total = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let small = &small;
            let total = &total;
            scope.spawn(move || {
                for _ in 0..10 {
                    small.parallel_for(16, |_| {
                        total.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
        }
    });
    assert_eq!(total.load(Ordering::Relaxed), 4 * 10 * 16);
}

// ---- parallel-kernel parity vs serial oracles ----

const ODD_SHAPES: [(usize, usize, usize); 4] =
    [(1, 64, 48), (7, 96, 80), (13, 64, 112), (37, 128, 96)];
const THREADS: [usize; 4] = [1, 2, 3, 8];

#[test]
fn dense_parallel_into_matches_naive() {
    let pool = ThreadPool::new(4);
    let mut rng = Rng::new(0xD0);
    for &(m, k, n) in &[(16usize, 33usize, 29usize), (64, 96, 80), (37, 53, 41)] {
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let want = matmul_naive(&a, &b);
        for &t in &THREADS {
            let mut c = Matrix::zeros(m, n);
            for v in &mut c.data {
                *v = 1e9; // stale output must be overwritten
            }
            let eff = matmul_parallel_into(&a, &b, &mut c, &TileConfig::new(16, 32), t, &pool);
            assert!(eff >= 1 && eff <= t.max(1));
            assert!(c.max_abs_diff(&want) < 1e-3, "{m}x{k}x{n} t={t}");
        }
    }
}

#[test]
fn tw_parallel_into_matches_serial_oracle() {
    let pool = ThreadPool::new(4);
    let mut rng = Rng::new(0xD1);
    for &(m, k, n) in &ODD_SHAPES {
        let a = Matrix::randn(m, k, &mut rng);
        let w = Matrix::randn(k, n, &mut rng);
        let tw = prune_tw(&w, 0.6, 16, None);
        let plan = TwPlan::encode(&w, &tw);
        let want = tw_matmul_with(&a, &plan, &TileConfig::tw_default());
        for &t in &THREADS {
            let mut c = Matrix::zeros(m, n);
            tw_matmul_parallel_into(&a, &plan, &mut c, &TileConfig::tw_default(), t, &pool);
            assert!(c.max_abs_diff(&want) < 1e-4, "{m}x{k}x{n} t={t}");
        }
    }
}

#[test]
fn tvw_parallel_into_matches_serial_oracle() {
    let pool = ThreadPool::new(4);
    let mut rng = Rng::new(0xD2);
    for &(m, k, n) in &ODD_SHAPES {
        let a = Matrix::randn(m, k, &mut rng);
        let w = Matrix::randn(k, n, &mut rng);
        for &s in &[0.5, 0.75, 0.875] {
            let (tw, mask) = prune_tvw(&w, s, 16);
            let plan = TvwPlan::encode(&w, &tw, &mask);
            let want = tvw_matmul_with(&a, &plan, &TileConfig::tvw_default());
            for &t in &THREADS {
                let mut c = Matrix::zeros(m, n);
                for v in &mut c.data {
                    *v = -1e9; // pruned columns must come back zeroed
                }
                let cfg = TileConfig::tvw_default();
                let eff = tvw_matmul_parallel_into(&a, &plan, &mut c, &cfg, t, &pool);
                assert!(eff >= 1);
                assert!(c.max_abs_diff(&want) < 1e-4, "{m}x{k}x{n} s={s} t={t}");
            }
        }
    }
}

#[test]
fn vw24_parallel_into_matches_serial_oracle() {
    let pool = ThreadPool::new(4);
    let mut rng = Rng::new(0xD3);
    for &(m, k, n) in &ODD_SHAPES {
        let a = Matrix::randn(m, k, &mut rng);
        let w = Matrix::randn(k, n, &mut rng);
        let mask = prune_vw(&w, 0.5, 4);
        let plan = Vw24Plan::encode(&w, &mask).expect("4-aligned K");
        let want = vw24_matmul_with(&a, &plan, &TileConfig::vw_default());
        for &t in &THREADS {
            let mut c = Matrix::zeros(m, n);
            for v in &mut c.data {
                *v = 1e9;
            }
            let cfg = TileConfig::vw_default();
            let eff = vw24_matmul_parallel_into(&a, &plan, &mut c, &cfg, t, &pool);
            assert!(eff >= 1);
            assert!(c.max_abs_diff(&want) < 1e-4, "{m}x{k}x{n} t={t}");
        }
    }
}

#[test]
fn parallel_kernels_share_one_pool_concurrently() {
    // several "serving workers" hammer one shared intra-op pool with
    // different kernel families at once — the two-level serving shape
    let pool = ThreadPool::new(3);
    let mut rng = Rng::new(0xD4);
    let (m, k, n) = (24usize, 64usize, 96usize);
    let a = Matrix::randn(m, k, &mut rng);
    let w = Matrix::randn(k, n, &mut rng);
    let tw = prune_tw(&w, 0.6, 16, None);
    let tw_plan = TwPlan::encode(&w, &tw);
    let (tvw_tw, tvw_mask) = prune_tvw(&w, 0.75, 16);
    let tvw_plan = TvwPlan::encode(&w, &tvw_tw, &tvw_mask);
    let want_tw = tw_matmul_with(&a, &tw_plan, &TileConfig::tw_default());
    let want_tvw = tvw_matmul_with(&a, &tvw_plan, &TileConfig::tvw_default());
    std::thread::scope(|scope| {
        for worker in 0..4 {
            let (pool, a) = (&pool, &a);
            let (tw_plan, tvw_plan) = (&tw_plan, &tvw_plan);
            let (want_tw, want_tvw) = (&want_tw, &want_tvw);
            scope.spawn(move || {
                let mut c = Matrix::zeros(m, n);
                for round in 0..8 {
                    if (worker + round) % 2 == 0 {
                        let cfg = TileConfig::tw_default();
                        tw_matmul_parallel_into(a, tw_plan, &mut c, &cfg, 3, pool);
                        assert!(c.max_abs_diff(want_tw) < 1e-4);
                        c.data.fill(0.0);
                    } else {
                        let cfg = TileConfig::tvw_default();
                        tvw_matmul_parallel_into(a, tvw_plan, &mut c, &cfg, 3, pool);
                        assert!(c.max_abs_diff(want_tvw) < 1e-4);
                    }
                }
            });
        }
    });
}
