//! End-to-end parity of the layer-graph IR: every compiled zoo model
//! (BERT / VGG / NMT at small dims) under dense / TW / TVW / 2:4 must
//! match its masked-dense oracle — the identical topology with every
//! packed weight decoded back to its masked-dense matrix — at 1e-4,
//! both serial and with an intra-op pool (`intra_threads > 1`).
//!
//! Plus the zoo/nn consistency check: every `models::` conv layer's
//! listed GEMM shape must agree with the `nn::Conv2dSpec` lowering its
//! metadata describes.

use std::sync::Arc;

use tilewise::exec::PreparedModel;
use tilewise::graph::{compile, CompileOptions, GraphModel, GraphPattern, PackOptions};
use tilewise::models::{self, LayerKind, ModelWorkload};
use tilewise::pool::ThreadPool;

const PATTERNS: [GraphPattern; 4] =
    [GraphPattern::Dense, GraphPattern::Tw, GraphPattern::Tvw, GraphPattern::Vw24];

fn small_opts() -> CompileOptions {
    CompileOptions {
        seq: 4,
        heads: 4,
        n_classes: 4,
        pack: PackOptions { sparsity: 0.75, g: 8, ..Default::default() },
        seed: 7,
        ..CompileOptions::default()
    }
}

fn deterministic_input(len: usize) -> Vec<f32> {
    (0..len).map(|i| ((i * 17 % 23) as f32 - 11.0) * 0.05).collect()
}

/// Compile `workload` under `pattern`, run it and its masked-dense oracle
/// (serial and pooled), and require 1e-4 agreement everywhere.
fn check_parity(workload: &ModelWorkload, pattern: GraphPattern, pool: &Arc<ThreadPool>) {
    let label = format!("{}/{:?}", workload.name, pattern);
    let opts = small_opts().with_pattern(pattern);
    let program = compile(workload, &opts).unwrap_or_else(|e| panic!("{label}: compile: {e}"));
    let oracle = program.to_dense_oracle();
    let dims = program.dims;
    let x = deterministic_input(dims.batch * dims.per_request_len());

    let variant = program.variant.clone();
    let oracle_variant = oracle.variant.clone();
    let mut serial = GraphModel::new(Arc::new(vec![program]), None).unwrap();
    let mut oracle_model = GraphModel::new(Arc::new(vec![oracle]), None).unwrap();
    let want = oracle_model.run(&oracle_variant, &x).unwrap();
    let got = serial.run(&variant, &x).unwrap();
    assert_eq!(got.len(), want.len(), "{label}");
    assert!(want.iter().all(|v| v.is_finite()), "{label}: oracle non-finite");
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-4, "{label}: serial logit {i}: {a} vs oracle {b}");
    }

    // the pooled kernel paths are a scheduling change, not a numeric one
    let opts2 = small_opts().with_pattern(pattern);
    let program2 = compile(workload, &opts2).unwrap();
    let mut pooled = GraphModel::new(Arc::new(vec![program2]), Some(pool.clone())).unwrap();
    let got_pooled = pooled.run(&variant, &x).unwrap();
    for (i, (a, b)) in got_pooled.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-4, "{label}: pooled logit {i}: {a} vs oracle {b}");
    }
    // results are reproducible across invocations (state reset, workspace
    // reuse): a second run returns bit-identical logits
    let again = serial.run(&variant, &x).unwrap();
    assert_eq!(got, again, "{label}: second run differs");
}

#[test]
fn bert_matches_masked_dense_oracle_all_patterns() {
    let workload = models::bert_at(2, 4, 16, 2);
    let pool = Arc::new(ThreadPool::new(3));
    for pattern in PATTERNS {
        check_parity(&workload, pattern, &pool);
    }
}

#[test]
fn vgg_matches_masked_dense_oracle_all_patterns() {
    let workload = models::vgg16_scaled(32, 16, 32);
    let pool = Arc::new(ThreadPool::new(3));
    for pattern in PATTERNS {
        check_parity(&workload, pattern, &pool);
    }
}

#[test]
fn nmt_matches_masked_dense_oracle_all_patterns() {
    let workload = models::nmt_at(2, 8, 3);
    let pool = Arc::new(ThreadPool::new(3));
    for pattern in PATTERNS {
        check_parity(&workload, pattern, &pool);
    }
}

/// Forced-microkernel parity: the same compiled model must serve logits
/// within 1e-4 whether every GEMM node is pinned to the scalar loops or
/// to an explicit SIMD register block (serial and pooled).  On hosts
/// without a SIMD ISA the forced-SIMD request degrades to scalar and the
/// comparison is trivially exact — the same degradation contract
/// `PALLAS_FORCE_SCALAR=1` relies on at serve time.
#[test]
fn forced_microkernel_graph_execution_matches_scalar() {
    use tilewise::gemm::MicroCfg;

    fn pin(program: &mut tilewise::graph::GraphProgram, mc: MicroCfg) {
        for node in &mut program.weights {
            node.cfg = node.cfg.with_micro(mc);
            for (_, c) in &mut node.bucket_cfgs {
                *c = c.with_micro(mc);
            }
        }
    }

    let workload = models::bert_at(2, 4, 16, 2);
    let pool = Arc::new(ThreadPool::new(3));
    for pattern in PATTERNS {
        let label = format!("{}/{:?}", workload.name, pattern);
        let opts = small_opts().with_pattern(pattern);
        let mut scalar_prog = compile(&workload, &opts).unwrap();
        let mut simd_prog = compile(&workload, &small_opts().with_pattern(pattern)).unwrap();
        pin(&mut scalar_prog, MicroCfg::Scalar);
        pin(&mut simd_prog, MicroCfg::Simd { mr: 4, nr: 16 });

        let variant = scalar_prog.variant.clone();
        let dims = scalar_prog.dims;
        let x = deterministic_input(dims.batch * dims.per_request_len());

        let mut scalar_model = GraphModel::new(Arc::new(vec![scalar_prog]), None).unwrap();
        let want = scalar_model.run(&variant, &x).unwrap();
        assert!(want.iter().all(|v| v.is_finite()), "{label}: scalar non-finite");

        let progs = Arc::new(vec![simd_prog]);
        let mut simd_serial = GraphModel::new(progs.clone(), None).unwrap();
        let got = simd_serial.run(&variant, &x).unwrap();
        assert_eq!(got.len(), want.len(), "{label}");
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-4, "{label}: serial logit {i}: {a} vs scalar {b}");
        }
        let mut simd_pooled = GraphModel::new(progs, Some(pool.clone())).unwrap();
        let got_pooled = simd_pooled.run(&variant, &x).unwrap();
        for (i, (a, b)) in got_pooled.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-4, "{label}: pooled logit {i}: {a} vs scalar {b}");
        }
    }
}

/// Variable-batch parity: executing `m_eff` real rows inside a batch-`B`
/// workspace must match a freshly compiled batch-`m_eff` model at 1e-4 —
/// weights are deterministic in the seed and independent of the batch
/// dimension, so a dedicated small-batch compilation is the exact oracle.
/// Checked serial and on the intra-op pool for every pattern.
fn check_variable_batch<F>(make: F, big_batch: usize, pool: &Arc<ThreadPool>)
where
    F: Fn(usize) -> ModelWorkload,
{
    let big_wl = make(big_batch);
    let m_effs: Vec<usize> = {
        let mut v = vec![1, (big_batch / 2).max(1), big_batch.saturating_sub(1).max(1)];
        v.sort_unstable();
        v.dedup();
        v
    };
    for pattern in PATTERNS {
        let label = format!("{}/{:?}", big_wl.name, pattern);
        let opts = small_opts().with_pattern(pattern);
        let program = compile(&big_wl, &opts).unwrap_or_else(|e| panic!("{label}: compile: {e}"));
        let dims = program.dims;
        assert_eq!(dims.batch, big_batch, "{label}: workload batch");
        let variant = program.variant.clone();
        let full = deterministic_input(dims.batch * dims.per_request_len());
        let mut serial = GraphModel::new(Arc::new(vec![program]), None).unwrap();
        let program2 = compile(&big_wl, &small_opts().with_pattern(pattern)).unwrap();
        let mut pooled = GraphModel::new(Arc::new(vec![program2]), Some(pool.clone())).unwrap();

        for &m_eff in &m_effs {
            // the oracle: a dedicated batch-m_eff compilation (same seed)
            let small_wl = make(m_eff);
            let small = compile(&small_wl, &small_opts().with_pattern(pattern)).unwrap();
            let mut small_model = GraphModel::new(Arc::new(vec![small]), None).unwrap();
            let prefix = &full[..m_eff * dims.per_request_len()];
            let want = small_model.run(&variant, prefix).unwrap();
            assert_eq!(want.len(), m_eff * dims.n_classes, "{label} m_eff={m_eff}");

            let got = serial.run_batch(&variant, prefix, m_eff).unwrap();
            assert_eq!(got.len(), want.len(), "{label} m_eff={m_eff}");
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{label} m_eff={m_eff}: serial logit {i}: {a} vs dedicated {b}"
                );
            }
            let got_pooled = pooled.run_batch(&variant, prefix, m_eff).unwrap();
            for (i, (a, b)) in got_pooled.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{label} m_eff={m_eff}: pooled logit {i}: {a} vs dedicated {b}"
                );
            }
        }
        // after shrinking, the full batch still executes correctly over
        // the regrown workspace
        let full_again = serial.run(&variant, &full).unwrap();
        assert_eq!(full_again.len(), dims.batch * dims.n_classes, "{label}");
        assert!(full_again.iter().all(|v| v.is_finite()), "{label}");
    }
}

#[test]
fn bert_variable_batch_matches_dedicated_compilation() {
    let pool = Arc::new(ThreadPool::new(3));
    check_variable_batch(|b| models::bert_at(b, 4, 16, 2), 4, &pool);
}

#[test]
fn nmt_variable_batch_matches_dedicated_compilation() {
    let pool = Arc::new(ThreadPool::new(3));
    check_variable_batch(|b| models::nmt_at(b, 8, 3), 4, &pool);
}

#[test]
fn vgg_variable_batch_degenerates_to_batch_one() {
    // conv workloads serve batch 1: the only legal m_eff is 1 and it must
    // equal the plain run; larger m_eff is a clean error
    let workload = models::vgg16_scaled(32, 16, 32);
    for pattern in PATTERNS {
        let program = compile(&workload, &small_opts().with_pattern(pattern)).unwrap();
        let dims = program.dims;
        assert_eq!(dims.batch, 1);
        let variant = program.variant.clone();
        let x = deterministic_input(dims.per_request_len());
        let mut model = GraphModel::new(Arc::new(vec![program]), None).unwrap();
        let full = model.run(&variant, &x).unwrap();
        let via_batch = model.run_batch(&variant, &x, 1).unwrap();
        assert_eq!(full, via_batch, "{pattern:?}");
        assert!(model.run_batch(&variant, &x, 2).is_err(), "{pattern:?}");
    }
}

#[test]
fn residual_mlp_native_backend_matches_oracle() {
    // the native backend's surrogate is "just another compiled spec":
    // its TW variant must track a masked-dense recomputation through the
    // same graph machinery (covered structurally in exec::native tests;
    // here we check the packed program decodes to finite dense weights)
    use tilewise::exec::{Backend, NativeBackend, NativeModelSpec};
    let spec = NativeModelSpec {
        seq: 4,
        d_model: 16,
        d_ff: 32,
        n_classes: 4,
        batch: 2,
        g: 8,
        ..NativeModelSpec::default()
    };
    let backend = NativeBackend::new(spec, None).unwrap();
    let mut model = backend.load().unwrap();
    let dims = model.dims();
    let x = deterministic_input(dims.batch * dims.per_request_len());
    for variant in ["model_dense", "model_tw", "model_tvw", "model_vw24"] {
        let logits = model.run(variant, &x).unwrap();
        assert_eq!(logits.len(), dims.batch * dims.n_classes, "{variant}");
        assert!(logits.iter().all(|v| v.is_finite()), "{variant}");
    }
}

#[test]
fn zoo_conv_shapes_agree_with_nn_lowering() {
    // models:: conv entries vs nn::Conv2dSpec: K = gemm_k(), M = out_hw^2,
    // N = c_out — for every conv layer of every zoo workload
    let mut checked = 0usize;
    for workload in models::zoo() {
        for layer in &workload.layers {
            if let LayerKind::Conv(meta) = layer.kind {
                let spec = meta.spec();
                assert_eq!(
                    spec.gemm_k(),
                    layer.shape.k,
                    "{}/{}: K disagrees with Conv2dSpec::gemm_k()",
                    workload.name,
                    layer.name
                );
                let (ho, wo) = spec.out_hw(meta.in_hw, meta.in_hw);
                assert_eq!(
                    ho * wo,
                    layer.shape.m,
                    "{}/{}: M disagrees with Conv2dSpec output dims",
                    workload.name,
                    layer.name
                );
                assert_eq!(spec.c_out, layer.shape.n, "{}/{}", workload.name, layer.name);
                checked += 1;
            }
        }
    }
    assert!(checked >= 20, "expected to check all zoo conv layers, got {checked}");
}

#[test]
fn scaled_zoo_constructors_compile_for_every_servable_model() {
    // the three servable workloads compile under every fixed pattern at
    // serving-sized dims (what `serve --model ...` actually builds)
    use tilewise::exec::{ZooBackend, ZooSpec};
    for model in ["bert", "vgg", "nmt"] {
        let mut spec = ZooSpec::for_model(model).unwrap();
        // shrink to test-sized dims
        spec.batch = spec.batch.min(2);
        spec.seq = 4;
        spec.width = 16;
        spec.n_layers = 1;
        spec.n_classes = 4;
        spec.width_div = 16;
        spec.fc_dim = 32;
        spec.g = 8;
        let spec = spec.with_variants(&["model_dense", "model_tw", "model_tvw", "model_vw24"]);
        let backend = ZooBackend::new(spec, None).unwrap_or_else(|e| panic!("{model}: {e}"));
        let dims = backend.dims();
        assert!(dims.batch >= 1 && dims.n_classes >= 1, "{model}");
    }
}
