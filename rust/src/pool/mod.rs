//! Persistent work-chunking thread pool — the kernel-level parallel
//! runtime every multi-threaded GEMM path runs on.
//!
//! Before this module existed each parallel kernel call spawned fresh
//! `std::thread::scope` threads, a fixed per-call cost (tens of
//! microseconds for an 8-way spawn+join) the serving hot loop paid on
//! every request even though the kernels themselves finish in comparable
//! time at serving-sized M.  The pool amortises that cost: workers are
//! spawned once, park on a condvar, and claim *chunks* of submitted jobs
//! through an atomic cursor — the CPU analogue of the paper's insight
//! that condensed tiles are independently schedulable units.
//!
//! Design points:
//!
//! - **Scoped, blocking submission.** [`ThreadPool::parallel_for`] does
//!   not return until every chunk has run, so tasks may borrow the
//!   caller's stack (operands, output slices) without `'static` bounds —
//!   the same contract as `std::thread::scope`, minus the spawn cost.
//! - **The caller is a lane.** A pool configured for `t` threads spawns
//!   `t - 1` workers; the submitting thread claims chunks alongside them.
//!   `ThreadPool::new(1)` therefore spawns nothing and `parallel_for`
//!   degrades to a plain serial loop — no pool, no overhead.
//! - **Work-claiming, not work-splitting.** Chunks are claimed via
//!   `fetch_add`, so an oversubscribed pool (more chunks than lanes, or
//!   several jobs queued by concurrent serving workers) drains in claim
//!   order without any rebalancing logic.
//! - **Panic containment.** A panicking task poisons nothing: the worker
//!   catches the unwind, the job completes, and the *submitting* thread
//!   re-panics after the last chunk finishes.  Pool workers survive and
//!   keep serving subsequent jobs.
//!
//! Kernels parallelise over **disjoint output ranges** (row bands for
//! dense, condensed-tile ranges for TW/TVW, column blocks for 2:4), so
//! chunk tasks never overlap a write; [`SendPtr`] is the shared escape
//! hatch for the column-strided cases where `chunks_mut` cannot express
//! the partition.
//!
//! See `docs/DESIGN.md` §5 for how this pool composes with the serving
//! coordinator's inter-request worker pool.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

/// One submitted `parallel_for`: a type-erased task plus claim/completion
/// state.  The submitting thread keeps the closure alive until `pending`
/// reaches zero, which is what makes the `'static` erasure sound.
struct Job {
    /// Pointer to the submitting caller's closure with its lifetime
    /// erased.  A raw pointer (not a reference) on purpose: the Job can
    /// outlive the closure inside worker-held `Arc`s, and it is only
    /// *dereferenced* for successfully claimed chunks — which cannot
    /// happen after the submitting call returned.
    task: *const (dyn Fn(usize) + Sync),
    n_chunks: usize,
    /// Claim cursor: `fetch_add` hands out chunk indices.
    next: AtomicUsize,
    /// Chunks not yet finished; the job is complete at zero.
    pending: AtomicUsize,
    panicked: AtomicBool,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: `task` targets a `Sync` closure kept alive by the submitting
// thread until `pending` reaches zero (see [`ThreadPool::parallel_for`]);
// every other field is a thread-safe primitive.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    fn exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.n_chunks
    }

    /// Claim and run chunks until none remain to claim.  Returns once this
    /// thread can contribute nothing further (other lanes may still be
    /// finishing their claimed chunks).
    fn work(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_chunks {
                return;
            }
            // SAFETY: chunk `i` was claimed, so the submitting thread is
            // still blocked in `parallel_for` and the closure is alive.
            let task = unsafe { &*self.task };
            if catch_unwind(AssertUnwindSafe(|| task(i))).is_err() {
                self.panicked.store(true, Ordering::Relaxed);
            }
            if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                *self.done.lock().unwrap() = true;
                self.done_cv.notify_all();
            }
        }
    }
}

struct Queue {
    jobs: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    work_cv: Condvar,
    /// Busy nanoseconds per lane: index 0 aggregates every submitting
    /// caller (each `parallel_for` caller is a lane of its own job),
    /// indices `1..threads` are the pinned workers.  Telemetry only —
    /// written once per job per lane, never on the chunk hot path.
    lane_busy: Vec<AtomicU64>,
}

/// Per-lane utilisation snapshot ([`ThreadPool::lane_stats`]): how much
/// of the pool's lifetime each lane spent claiming chunks vs parked.
#[derive(Clone, Copy, Debug)]
pub struct LaneStats {
    pub busy_secs: f64,
    pub idle_secs: f64,
}

/// The persistent pool.  Sized once; shared freely (`Arc<ThreadPool>`)
/// across serving workers, the autotuner, and benches.
pub struct ThreadPool {
    shared: Arc<Shared>,
    threads: usize,
    started: Instant,
    joins: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// A pool presenting `threads` lanes of parallelism: `threads - 1`
    /// pinned workers plus the submitting caller.  `new(1)` (and `new(0)`)
    /// spawn nothing and run everything inline.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
            lane_busy: (0..threads).map(|_| AtomicU64::new(0)).collect(),
        });
        let joins = (1..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("tilewise-pool-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawning pool worker")
            })
            .collect();
        ThreadPool { shared, threads, started: Instant::now(), joins }
    }

    /// The lane count this pool was configured for (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Per-lane busy/idle split since the pool was built.  Lane 0 folds
    /// every submitting caller together; lanes `1..threads` are the
    /// pinned workers.  Idle is wall time minus busy time, clamped to
    /// zero (a lane mid-chunk at snapshot time can read slightly ahead).
    pub fn lane_stats(&self) -> Vec<LaneStats> {
        let wall = self.started.elapsed().as_secs_f64();
        self.shared
            .lane_busy
            .iter()
            .map(|b| {
                let busy = b.load(Ordering::Relaxed) as f64 / 1e9;
                LaneStats { busy_secs: busy, idle_secs: (wall - busy).max(0.0) }
            })
            .collect()
    }

    /// Run `task(0..n_chunks)` across the pool and the calling thread;
    /// returns only after every chunk has finished.  Chunks must write
    /// disjoint data.  If any chunk panics, the panic is re-raised *here*
    /// after the job completes; the pool itself survives.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, n_chunks: usize, task: F) {
        if n_chunks == 0 {
            return;
        }
        if self.joins.is_empty() || n_chunks == 1 {
            let t = Instant::now();
            for i in 0..n_chunks {
                task(i);
            }
            let nanos = t.elapsed().as_nanos() as u64;
            self.shared.lane_busy[0].fetch_add(nanos, Ordering::Relaxed);
            return;
        }
        let task: &(dyn Fn(usize) + Sync) = &task;
        // SAFETY (lifetime erasure): this function blocks until `pending`
        // hits zero, so the closure outlives every dereference; workers
        // never dereference the pointer once all chunks are claimed.
        let task: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(task) };
        let job = Arc::new(Job {
            task,
            n_chunks,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n_chunks),
            panicked: AtomicBool::new(false),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        self.shared.queue.lock().unwrap().jobs.push_back(job.clone());
        self.shared.work_cv.notify_all();
        // the submitting thread is a full lane
        let t = Instant::now();
        job.work();
        let nanos = t.elapsed().as_nanos() as u64;
        self.shared.lane_busy[0].fetch_add(nanos, Ordering::Relaxed);
        let mut done = job.done.lock().unwrap();
        while !*done {
            done = job.done_cv.wait(done).unwrap();
        }
        drop(done);
        if job.panicked.load(Ordering::Relaxed) {
            panic!("a task submitted to the thread pool panicked");
        }
    }

    /// Split `data` into disjoint `chunk_len`-element chunks and run
    /// `task(chunk_index, chunk)` across the pool — the safe row-band
    /// idiom (a row-major matrix with `chunk_len = band_rows * cols`).
    pub fn for_each_chunk_mut<T, F>(&self, data: &mut [T], chunk_len: usize, task: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let chunk_len = chunk_len.max(1);
        let total = data.len();
        let n_chunks = total.div_ceil(chunk_len);
        let base = SendPtr(data.as_mut_ptr());
        self.parallel_for(n_chunks, |i| {
            let lo = i * chunk_len;
            let len = chunk_len.min(total - lo);
            // SAFETY: chunks are disjoint by construction and `data`'s
            // borrow is held across the blocking parallel_for call.
            let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), len) };
            task(i, chunk);
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

fn worker_loop(shared: &Shared, lane: usize) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                // drop fully-claimed jobs; their completion is tracked by
                // `pending`, not by queue residency
                q.jobs.retain(|j| !j.exhausted());
                if q.shutdown {
                    return;
                }
                if let Some(j) = q.jobs.front() {
                    break j.clone();
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        let t = Instant::now();
        job.work();
        shared.lane_busy[lane].fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

/// The process-wide pool, lazily sized to the host's available
/// parallelism.  The serial-signature kernel wrappers
/// (`gemm::matmul_parallel`, `gemm::tw_matmul_parallel`) and the
/// autotuner's measurement harness run here, so tuned `threads` axes
/// reflect the same runtime the serving stack uses.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        ThreadPool::new(std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1))
    })
}

/// Contiguous range of `n` items owned by chunk `i` of `chunks`:
/// `[i * ceil(n / chunks), min((i + 1) * ceil(n / chunks), n))`.
/// Tail chunks may be empty when `chunks` does not divide `n`.
pub fn split_range(n: usize, chunks: usize, i: usize) -> (usize, usize) {
    let per = n.div_ceil(chunks.max(1));
    let lo = (i * per).min(n);
    let hi = ((i + 1) * per).min(n);
    (lo, hi)
}

/// `Send + Sync` raw-pointer wrapper for kernels whose disjoint output
/// partition is column-strided (TW/TVW tile scatter, 2:4 column blocks)
/// and therefore inexpressible as `chunks_mut`.  Safety is the caller's:
/// tasks must write disjoint elements only.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn reuse_across_calls_accumulates() {
        let pool = ThreadPool::new(3);
        let sum = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.parallel_for(16, |i| {
                sum.fetch_add(i, Ordering::Relaxed);
            });
        }
        assert_eq!(sum.load(Ordering::Relaxed), 50 * (0..16).sum::<usize>());
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicUsize::new(0);
        pool.parallel_for(8, |i| {
            sum.fetch_add(i + 1, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 36);
    }

    #[test]
    fn panicking_task_is_contained_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(16, |i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must surface at the submitting thread");
        // workers survive: the pool still completes fresh jobs
        let sum = AtomicUsize::new(0);
        pool.parallel_for(16, |i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (0..16).sum::<usize>());
    }

    #[test]
    fn for_each_chunk_mut_partitions_disjointly() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u32; 103]; // deliberately not chunk-aligned
        pool.for_each_chunk_mut(&mut data, 10, |ci, chunk| {
            for v in chunk.iter_mut() {
                *v += 1 + ci as u32;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, 1 + (i / 10) as u32, "element {i}");
        }
    }

    #[test]
    fn split_range_covers_and_never_overlaps() {
        for &(n, chunks) in &[(10usize, 3usize), (7, 7), (5, 8), (0, 4), (64, 4)] {
            let mut covered = 0usize;
            let mut prev_hi = 0usize;
            for i in 0..chunks {
                let (lo, hi) = split_range(n, chunks, i);
                assert!(lo >= prev_hi, "n={n} chunks={chunks} i={i}");
                assert!(hi <= n);
                covered += hi - lo;
                prev_hi = prev_hi.max(hi);
            }
            assert_eq!(covered, n, "n={n} chunks={chunks}");
        }
    }

    #[test]
    fn oversubscription_more_chunks_than_lanes() {
        let pool = ThreadPool::new(2);
        let sum = AtomicUsize::new(0);
        pool.parallel_for(256, |i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (0..256).sum::<usize>());
    }

    #[test]
    fn lane_stats_track_busy_time() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(8, |_| {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        let stats = pool.lane_stats();
        assert_eq!(stats.len(), 2, "one entry per lane, callers folded into lane 0");
        // the submitting caller is itself a lane and always claims chunks
        assert!(stats[0].busy_secs > 0.0, "{stats:?}");
        let total_busy: f64 = stats.iter().map(|s| s.busy_secs).sum();
        assert!(total_busy >= 0.008, "8 x 2ms chunks across 2 lanes: {total_busy}");
        assert!(stats.iter().all(|s| s.idle_secs >= 0.0), "{stats:?}");
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let p1 = global();
        let p2 = global();
        assert!(std::ptr::eq(p1, p2));
        assert!(p1.threads() >= 1);
        let sum = AtomicUsize::new(0);
        p1.parallel_for(32, |i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (0..32).sum::<usize>());
    }
}
