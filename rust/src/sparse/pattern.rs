//! Algorithm 2: element-wise, vector-wise (n:m), and block-wise pruning.
//!
//! Exact twins of `python/compile/pruning.py` — rank-based selection,
//! density-normalised ragged blocks — so cross-language golden tests hold.

use crate::sparse::Mask;
use crate::tensor::Matrix;
use crate::util::argsort_desc_by;

/// Per-element importance score: |w| (magnitude) or |w * grad| (first-order
/// Taylor, Molchanov et al.) when a gradient is supplied.
pub fn importance_element(w: &Matrix, grad: Option<&Matrix>) -> Vec<f64> {
    match grad {
        None => w.data.iter().map(|x| x.abs() as f64).collect(),
        Some(g) => {
            assert_eq!((w.rows, w.cols), (g.rows, g.cols));
            w.data.iter().zip(&g.data).map(|(x, gx)| (x * gx).abs() as f64).collect()
        }
    }
}

fn keep_topk(scores: &[f64], keep: usize) -> Vec<bool> {
    let keep = keep.min(scores.len());
    let order = argsort_desc_by(scores.len(), |i| scores[i]);
    let mut mask = vec![false; scores.len()];
    for &i in order.iter().take(keep) {
        mask[i] = true;
    }
    mask
}

/// Element-wise pruning: keep the global top `(1 - sparsity)` fraction.
pub fn prune_ew(w: &Matrix, sparsity: f64, grad: Option<&Matrix>) -> Mask {
    let scores = importance_element(w, grad);
    let keep = ((1.0 - sparsity) * w.data.len() as f64).round() as usize;
    Mask { rows: w.rows, cols: w.cols, keep: keep_topk(&scores, keep) }
}

/// Vector-wise n:m pruning along K (rows): each group of `m` consecutive
/// elements in a column keeps its top `round((1-s)*m)` by magnitude.
/// `w.rows` must be divisible by `m`.  `(m=4, s=0.5)` is Ampere 2:4.
pub fn prune_vw(w: &Matrix, sparsity: f64, m: usize) -> Mask {
    assert_eq!(w.rows % m, 0, "K={} not divisible by m={}", w.rows, m);
    let keep_per_vec = ((1.0 - sparsity) * m as f64).round() as usize;
    let mut mask = Mask::none(w.rows, w.cols);
    for c in 0..w.cols {
        for g in 0..w.rows / m {
            let base = g * m;
            let order = argsort_desc_by(m, |i| w.at(base + i, c).abs() as f64);
            for &i in order.iter().take(keep_per_vec) {
                mask.set(base + i, c, true);
            }
        }
    }
    mask
}

/// Block-wise pruning with GxG blocks and a global threshold over block
/// importance densities (sum |w| / valid area — ragged edges compete fairly).
pub fn prune_bw(w: &Matrix, sparsity: f64, g: usize) -> Mask {
    let bk = w.rows.div_ceil(g);
    let bn = w.cols.div_ceil(g);
    let nblocks = bk * bn;
    let mut density = vec![0.0f64; nblocks];
    for bi in 0..bk {
        for bj in 0..bn {
            let r0 = bi * g;
            let c0 = bj * g;
            let r1 = (r0 + g).min(w.rows);
            let c1 = (c0 + g).min(w.cols);
            let mut sum = 0.0f64;
            for r in r0..r1 {
                for c in c0..c1 {
                    sum += w.at(r, c).abs() as f64;
                }
            }
            let area = ((r1 - r0) * (c1 - c0)).max(1) as f64;
            density[bi * bn + bj] = sum / area;
        }
    }
    let keep = ((1.0 - sparsity) * nblocks as f64).round() as usize;
    let bmask = keep_topk(&density, keep);
    let mut mask = Mask::none(w.rows, w.cols);
    for bi in 0..bk {
        for bj in 0..bn {
            if bmask[bi * bn + bj] {
                for r in bi * g..((bi + 1) * g).min(w.rows) {
                    for c in bj * g..((bj + 1) * g).min(w.cols) {
                        mask.set(r, c, true);
                    }
                }
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mat(r: usize, c: usize, seed: u64) -> Matrix {
        Matrix::randn(r, c, &mut Rng::new(seed))
    }

    #[test]
    fn ew_hits_target_and_keeps_largest() {
        let w = mat(32, 32, 1);
        let m = prune_ew(&w, 0.5, None);
        assert!((m.sparsity() - 0.5).abs() < 0.01);
        let kept_min = w
            .data
            .iter()
            .zip(&m.keep)
            .filter(|(_, k)| **k)
            .map(|(x, _)| x.abs())
            .fold(f32::MAX, f32::min);
        let pruned_max = w
            .data
            .iter()
            .zip(&m.keep)
            .filter(|(_, k)| !**k)
            .map(|(x, _)| x.abs())
            .fold(0.0, f32::max);
        assert!(kept_min >= pruned_max);
    }

    #[test]
    fn vw_24_is_balanced() {
        let w = mat(64, 48, 2);
        let m = prune_vw(&w, 0.5, 4);
        for c in 0..48 {
            for g in 0..16 {
                let cnt = (0..4).filter(|i| m.at(g * 4 + i, c)).count();
                assert_eq!(cnt, 2);
            }
        }
    }

    #[test]
    fn vw_416() {
        let w = mat(64, 8, 3);
        let m = prune_vw(&w, 0.75, 16);
        for c in 0..8 {
            for g in 0..4 {
                let cnt = (0..16).filter(|i| m.at(g * 16 + i, c)).count();
                assert_eq!(cnt, 4);
            }
        }
    }

    #[test]
    fn bw_is_block_structured() {
        let w = mat(64, 64, 4);
        let m = prune_bw(&w, 0.5, 16);
        for bi in 0..4 {
            for bj in 0..4 {
                let cnt = (0..16)
                    .flat_map(|r| (0..16).map(move |c| (r, c)))
                    .filter(|&(r, c)| m.at(bi * 16 + r, bj * 16 + c))
                    .count();
                assert!(cnt == 0 || cnt == 256);
            }
        }
        assert!((m.sparsity() - 0.5).abs() < 0.01);
    }

    #[test]
    fn bw_ragged_edges_reasonable() {
        let w = mat(70, 50, 5);
        let m = prune_bw(&w, 0.5, 16);
        assert!(m.sparsity() > 0.3 && m.sparsity() < 0.7, "{}", m.sparsity());
    }

    #[test]
    fn taylor_score_changes_selection() {
        let w = mat(16, 16, 6);
        let g = mat(16, 16, 7);
        let m1 = prune_ew(&w, 0.5, None);
        let m2 = prune_ew(&w, 0.5, Some(&g));
        assert_ne!(m1, m2);
    }
}
