//! Sparsity-pattern substrate: masks, the six pruning patterns of the
//! paper's Fig. 2 (EW / VW / BW / TW / TEW / TVW), CTO execution plans,
//! CSR/CSC formats, and distribution statistics.

mod cto;
mod csr;
mod mask;
mod pattern;
mod stats;
mod tw;

pub use cto::{TvwPlan, TwPlan, Vw24Plan};
pub use csr::{Csc, Csr};
pub use mask::Mask;
pub use pattern::{importance_element, prune_bw, prune_ew, prune_vw};
pub use stats::{mask_stats, render_heatmap, MaskStats};
pub use tw::{prune_tew, prune_tvw, prune_tw, TwStructure};

/// The six sparsity patterns evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pattern {
    /// Element-wise (unstructured).
    Ew,
    /// Vector-wise n:m along K; `m` is the vector length (4 => 2:4, 16 => n:16).
    Vw { m: usize },
    /// Block-wise GxG.
    Bw { g: usize },
    /// Tile-wise with granularity G.
    Tw { g: usize },
    /// TW overlaid with an EW remedy fraction (delta in the paper).
    Tew { g: usize, delta_pct: u8 },
    /// TW fused with 2:4 VW (TVW-4) or n:16 (TVW-16).
    Tvw { g: usize, m: usize },
}

impl Pattern {
    /// Label in the paper's "XX-YY" convention (e.g. `TW-64`, `VW-4`).
    pub fn label(&self) -> String {
        match self {
            Pattern::Ew => "EW".to_string(),
            Pattern::Vw { m } => format!("VW-{m}"),
            Pattern::Bw { g } => format!("BW-{g}"),
            Pattern::Tw { g } => format!("TW-{g}"),
            Pattern::Tew { g, delta_pct } => format!("TEW-{g}@{delta_pct}%"),
            Pattern::Tvw { g, m } => format!("TVW-{m}(G={g})"),
        }
    }

    /// Prune a weight matrix to this pattern at the given sparsity; returns
    /// the keep-mask (losing TW structure — use the specific functions when
    /// the CTO plan is needed).
    pub fn prune(&self, w: &crate::tensor::Matrix, sparsity: f64) -> Mask {
        match self {
            Pattern::Ew => prune_ew(w, sparsity, None),
            Pattern::Vw { m } => prune_vw(w, sparsity, *m),
            Pattern::Bw { g } => prune_bw(w, sparsity, *g),
            Pattern::Tw { g } => prune_tw(w, sparsity, *g, None).mask(),
            Pattern::Tew { g, delta_pct } => {
                let (tw, remedy) = prune_tew(w, sparsity, *delta_pct as f64 / 100.0, *g);
                tw.mask().or(&remedy)
            }
            Pattern::Tvw { g, .. } => prune_tvw(w, sparsity.max(0.5), *g).1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::util::Rng;

    #[test]
    fn labels_match_paper_convention() {
        assert_eq!(Pattern::Tw { g: 64 }.label(), "TW-64");
        assert_eq!(Pattern::Vw { m: 4 }.label(), "VW-4");
        assert_eq!(Pattern::Bw { g: 16 }.label(), "BW-16");
    }

    #[test]
    fn all_patterns_prune_to_roughly_target() {
        let w = Matrix::randn(128, 128, &mut Rng::new(50));
        for p in [
            Pattern::Ew,
            Pattern::Vw { m: 4 },
            Pattern::Bw { g: 16 },
            Pattern::Tw { g: 32 },
            Pattern::Tew { g: 32, delta_pct: 5 },
            Pattern::Tvw { g: 32, m: 4 },
        ] {
            let s = 0.5;
            let m = p.prune(&w, s);
            assert!(
                (m.sparsity() - s).abs() < 0.05,
                "{}: {}",
                p.label(),
                m.sparsity()
            );
        }
    }
}
