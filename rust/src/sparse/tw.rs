//! Algorithm 3: tile-wise (TW), tile-element-wise (TEW), and
//! tile-vector-wise (TVW) pruning.
//!
//! Mirrors `python/compile/pruning.py` exactly (rank-based selection,
//! importance-density ranking for ragged segments, per-tile min-one-row
//! invariant) so the two implementations can be golden-tested against each
//! other through JSON fixtures.

use crate::sparse::Mask;
use crate::tensor::Matrix;
use crate::util::argsort_desc_by;

/// Structural description of a TW-pruned matrix: the surviving columns
/// (TW-C) and, per width-G condensed tile, the surviving rows (TW-R).
#[derive(Clone, Debug)]
pub struct TwStructure {
    /// Sorted original column indices that survived TW-C.
    pub kept_cols: Vec<usize>,
    /// Per condensed tile: sorted original row indices that survived TW-R.
    pub tile_rows: Vec<Vec<usize>>,
    /// Tile granularity G.
    pub g: usize,
    /// Original (K, N).
    pub shape: (usize, usize),
}

impl TwStructure {
    pub fn num_tiles(&self) -> usize {
        self.tile_rows.len()
    }

    /// Original column indices covered by condensed tile `t`.
    pub fn tile_cols(&self, t: usize) -> &[usize] {
        let lo = t * self.g;
        let hi = ((t + 1) * self.g).min(self.kept_cols.len());
        &self.kept_cols[lo..hi]
    }

    /// Expand to a keep-mask in original (K, N) coordinates.
    pub fn mask(&self) -> Mask {
        let (k, n) = self.shape;
        let mut m = Mask::none(k, n);
        for t in 0..self.num_tiles() {
            for &r in &self.tile_rows[t] {
                for &c in self.tile_cols(t) {
                    m.set(r, c, true);
                }
            }
        }
        m
    }

    /// Fraction of weights removed.
    pub fn sparsity(&self) -> f64 {
        let (k, n) = self.shape;
        let kept: usize = (0..self.num_tiles())
            .map(|t| self.tile_rows[t].len() * self.tile_cols(t).len())
            .sum();
        1.0 - kept as f64 / (k * n) as f64
    }
}

/// Tile-wise pruning (Alg. 3 `TW`).
///
/// Stage 1 (TW-C) scores whole columns and keeps the global top
/// `1 - s_c`; stage 2 (TW-R) re-tiles the condensed matrix into width-`g`
/// tiles and keeps (1,G) row segments globally by importance *density*
/// until the element budget `(1 - s_r) * K * Nk` is met.  Per-stage split
/// is the paper's `s = 1 - sqrt(1 - s_t)` unless `col_sparsity` overrides.
pub fn prune_tw(w: &Matrix, sparsity: f64, g: usize, col_sparsity: Option<f64>) -> TwStructure {
    let (k, n) = (w.rows, w.cols);
    let (s_c, s_r) = match col_sparsity {
        None => {
            let s = 1.0 - (1.0 - sparsity).max(0.0).sqrt();
            (s, s)
        }
        Some(s_c) => {
            let s_r = (1.0 - (1.0 - sparsity) / (1.0 - s_c).max(1e-12)).clamp(0.0, 1.0);
            (s_c, s_r)
        }
    };

    // --- TW-C: global column pruning ---
    let col_scores: Vec<f64> = (0..n)
        .map(|c| (0..k).map(|r| w.at(r, c).abs() as f64).sum())
        .collect();
    let keep_c = (((1.0 - s_c) * n as f64).round() as usize).max(1);
    let order = argsort_desc_by(n, |i| col_scores[i]);
    let mut kept_cols: Vec<usize> = order[..keep_c].to_vec();
    kept_cols.sort_unstable();
    let nk = kept_cols.len();

    // --- TW-R: per-tile row pruning, global density ranking ---
    let num_tiles = nk.div_ceil(g);
    let widths: Vec<usize> = (0..num_tiles).map(|t| g.min(nk - t * g)).collect();
    // seg[(r, t)] = sum |w[r, cols_of_tile_t]|
    let mut seg = vec![0.0f64; k * num_tiles];
    for t in 0..num_tiles {
        for (j, &c) in kept_cols[t * g..(t * g + widths[t])].iter().enumerate() {
            let _ = j;
            for r in 0..k {
                seg[r * num_tiles + t] += w.at(r, c).abs() as f64;
            }
        }
    }
    let target_kept = ((1.0 - s_r) * (k * nk) as f64).round() as usize;
    let order = argsort_desc_by(k * num_tiles, |i| seg[i] / widths[i % num_tiles] as f64);
    // keep the longest prefix whose cumulative element count stays within
    // the budget (== numpy's searchsorted(csum, target, side="right") in
    // the Python twin — keep exact parity for the golden tests)
    let mut kept_elems = 0usize;
    let mut n_keep = 0usize;
    for &i in &order {
        let w_i = widths[i % num_tiles];
        if kept_elems + w_i > target_kept {
            break;
        }
        kept_elems += w_i;
        n_keep += 1;
    }
    n_keep = n_keep.max(num_tiles);
    let mut seg_mask = vec![false; k * num_tiles];
    for &i in order.iter().take(n_keep) {
        seg_mask[i] = true;
    }
    // per-tile min-one-row invariant
    for t in 0..num_tiles {
        if !(0..k).any(|r| seg_mask[r * num_tiles + t]) {
            let best = argsort_desc_by(k, |r| seg[r * num_tiles + t])[0];
            seg_mask[best * num_tiles + t] = true;
        }
    }
    let tile_rows: Vec<Vec<usize>> = (0..num_tiles)
        .map(|t| (0..k).filter(|&r| seg_mask[r * num_tiles + t]).collect())
        .collect();

    TwStructure { kept_cols, tile_rows, g, shape: (k, n) }
}

/// Tile-element-wise pruning (Alg. 3 `TEW`): TW at `sparsity + delta`,
/// then remedy the top-`delta` fraction of importance among TW-pruned
/// elements.  Returns the TW structure and the remedy keep-mask.
pub fn prune_tew(w: &Matrix, sparsity: f64, delta: f64, g: usize) -> (TwStructure, Mask) {
    let s = (sparsity + delta).min(0.995);
    let tw = prune_tw(w, s, g, None);
    let tw_mask = tw.mask();
    let mut scores = crate::sparse::importance_element(w, None);
    for (i, k) in tw_mask.keep.iter().enumerate() {
        if *k {
            scores[i] = 0.0;
        }
    }
    let remedy_count = (delta * w.data.len() as f64).round() as usize;
    let order = argsort_desc_by(scores.len(), |i| scores[i]);
    let mut remedy = Mask::none(w.rows, w.cols);
    for &i in order.iter().take(remedy_count) {
        if !tw_mask.keep[i] {
            remedy.keep[i] = true;
        }
    }
    (tw, remedy)
}

/// Tile-vector-wise pruning (Alg. 3 `TVW`): TW at `1 - 2*(1 - s_t)`, then
/// fixed 2:4 along the condensed K dimension inside every tile.  Returns
/// the TW structure and the final keep-mask.  Requires `sparsity >= 0.5`.
pub fn prune_tvw(w: &Matrix, sparsity: f64, g: usize) -> (TwStructure, Mask) {
    assert!(sparsity >= 0.5 - 1e-9, "TVW sparsity must be >= 0.5 (2:4 floor)");
    let s_tw = 1.0 - 2.0 * (1.0 - sparsity);
    let tw = prune_tw(w, s_tw, g, None);
    let mut mask = Mask::none(w.rows, w.cols);
    for t in 0..tw.num_tiles() {
        let rows = &tw.tile_rows[t];
        let cols = tw.tile_cols(t);
        // condensed sub-matrix (Kt x width), zero-padded to a multiple of 4
        for (j, &c) in cols.iter().enumerate() {
            let _ = j;
            let kt = rows.len();
            let groups = kt.div_ceil(4);
            for grp in 0..groups {
                // keep the top-2 magnitudes of this 4-row group
                let lo = grp * 4;
                let len = 4.min(kt - lo);
                let order = argsort_desc_by(len, |i| w.at(rows[lo + i], c).abs() as f64);
                for &i in order.iter().take(2.min(len)) {
                    mask.set(rows[lo + i], c, true);
                }
            }
        }
    }
    (tw, mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mat(r: usize, c: usize, seed: u64) -> Matrix {
        Matrix::randn(r, c, &mut Rng::new(seed))
    }

    #[test]
    fn tw_hits_target_sparsity() {
        for &(k, n, g, s) in
            &[(96usize, 80usize, 16usize, 0.6), (256, 256, 64, 0.75), (128, 100, 32, 0.5)]
        {
            let w = mat(k, n, 11);
            let tw = prune_tw(&w, s, g, None);
            assert!((tw.sparsity() - s).abs() < 0.03, "{k}x{n} g={g} s={s}: {}", tw.sparsity());
        }
    }

    #[test]
    fn tw_mask_is_tile_structured() {
        let w = mat(64, 64, 12);
        let tw = prune_tw(&w, 0.5, 16, None);
        let m = tw.mask();
        for t in 0..tw.num_tiles() {
            let cols = tw.tile_cols(t);
            // each tile: mask = rows_on × cols (outer product structure)
            for &c in cols {
                for r in 0..64 {
                    let expected = tw.tile_rows[t].contains(&r);
                    assert_eq!(m.at(r, c), expected);
                }
            }
        }
    }

    #[test]
    fn tw_every_tile_nonempty() {
        let w = mat(64, 64, 13);
        let tw = prune_tw(&w, 0.95, 16, None);
        assert!(tw.tile_rows.iter().all(|r| !r.is_empty()));
    }

    #[test]
    fn tew_remedy_disjoint_and_sized() {
        let w = mat(96, 96, 14);
        let (tw, remedy) = prune_tew(&w, 0.7, 0.05, 16);
        let twm = tw.mask();
        assert!(!remedy.keep.iter().zip(&twm.keep).any(|(r, t)| *r && *t));
        assert!((remedy.keep.iter().filter(|&&x| x).count() as f64 / (96.0 * 96.0) - 0.05).abs() < 0.01);
        let fin = twm.or(&remedy);
        assert!((fin.sparsity() - 0.7).abs() < 0.03, "{}", fin.sparsity());
    }

    #[test]
    fn tvw_is_24_inside_tiles() {
        let w = mat(128, 128, 15);
        let (tw, mask) = prune_tvw(&w, 0.75, 32);
        for t in 0..tw.num_tiles() {
            let rows = &tw.tile_rows[t];
            for &c in tw.tile_cols(t) {
                for grp in 0..rows.len().div_ceil(4) {
                    let len = 4.min(rows.len() - grp * 4);
                    let cnt = (0..len).filter(|&i| mask.at(rows[grp * 4 + i], c)).count();
                    assert!(cnt <= 2);
                }
            }
        }
    }

    #[test]
    fn tvw_target_sparsity() {
        let w = mat(256, 256, 16);
        for &s in &[0.5, 0.625, 0.75, 0.875] {
            let (_, mask) = prune_tvw(&w, s, 64);
            assert!((mask.sparsity() - s).abs() < 0.02, "s={s}: {}", mask.sparsity());
        }
    }

    #[test]
    fn tvw_mask_subset_of_tw() {
        let w = mat(64, 64, 17);
        let (tw, mask) = prune_tvw(&w, 0.75, 16);
        assert!(mask.subset_of(&tw.mask()));
    }

    #[test]
    #[should_panic]
    fn tvw_below_half_panics() {
        let w = mat(16, 16, 18);
        prune_tvw(&w, 0.3, 4);
    }

    #[test]
    fn g_equals_n_is_global_structural() {
        let w = mat(32, 32, 19);
        let tw = prune_tw(&w, 0.5, 32, None);
        assert_eq!(tw.num_tiles(), 1);
    }

    #[test]
    fn prune_vw_composes_with_tw_for_reference() {
        // sanity: standalone 2:4 has exactly 50% sparsity
        let w = mat(64, 64, 20);
        let m = crate::sparse::prune_vw(&w, 0.5, 4);
        assert!((m.sparsity() - 0.5).abs() < 1e-9);
    }
}
