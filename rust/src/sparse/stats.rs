//! Sparsity-distribution statistics (paper Fig. 9: how "irregular" and how
//! "unevenly distributed" each pattern's surviving weights are).

use crate::sparse::Mask;

/// Summary statistics of a keep-mask's spatial distribution.
#[derive(Clone, Debug)]
pub struct MaskStats {
    pub sparsity: f64,
    /// Per-block kept-fraction variance over a `block x block` partition —
    /// the paper's "uneven distribution" axis: EW/TW high, VW ~0.
    pub block_variance: f64,
    /// Fraction of adjacent (horizontal) kept/pruned transitions — a proxy
    /// for irregularity: EW high, BW low.
    pub irregularity: f64,
    /// Kept fraction of each block row/column band (for heatmap rendering).
    pub block_density: Vec<f64>,
    pub blocks_per_row: usize,
}

/// Compute distribution statistics over a `block`-sized partition.
pub fn mask_stats(mask: &Mask, block: usize) -> MaskStats {
    let bk = mask.rows.div_ceil(block);
    let bn = mask.cols.div_ceil(block);
    let mut density = vec![0.0f64; bk * bn];
    for bi in 0..bk {
        for bj in 0..bn {
            let r1 = ((bi + 1) * block).min(mask.rows);
            let c1 = ((bj + 1) * block).min(mask.cols);
            let mut kept = 0usize;
            let mut area = 0usize;
            for r in bi * block..r1 {
                for c in bj * block..c1 {
                    kept += mask.at(r, c) as usize;
                    area += 1;
                }
            }
            density[bi * bn + bj] = kept as f64 / area.max(1) as f64;
        }
    }
    let mean = density.iter().sum::<f64>() / density.len() as f64;
    let var = density.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>() / density.len() as f64;

    let mut transitions = 0usize;
    let mut pairs = 0usize;
    for r in 0..mask.rows {
        for c in 1..mask.cols {
            transitions += (mask.at(r, c) != mask.at(r, c - 1)) as usize;
            pairs += 1;
        }
    }
    MaskStats {
        sparsity: mask.sparsity(),
        block_variance: var,
        irregularity: transitions as f64 / pairs.max(1) as f64,
        block_density: density,
        blocks_per_row: bn,
    }
}

/// Render a mask as a text heatmap (one char per block): ' ' empty .. '#'
/// fully kept — the Fig. 9 visualisation.
pub fn render_heatmap(mask: &Mask, block: usize) -> String {
    let stats = mask_stats(mask, block);
    let ramp = [' ', '.', ':', '-', '=', '+', '*', '#'];
    let bn = stats.blocks_per_row;
    let mut out = String::new();
    for (i, d) in stats.block_density.iter().enumerate() {
        let lvl = ((d * (ramp.len() - 1) as f64).round() as usize).min(ramp.len() - 1);
        out.push(ramp[lvl]);
        if (i + 1) % bn == 0 {
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{prune_bw, prune_ew, prune_tw, prune_vw};
    use crate::tensor::Matrix;
    use crate::util::Rng;

    fn w128() -> Matrix {
        Matrix::randn(128, 128, &mut Rng::new(40))
    }

    #[test]
    fn vw_has_zero_block_variance() {
        let w = w128();
        let m = prune_vw(&w, 0.5, 4);
        let s = mask_stats(&m, 16);
        // every 4-vector keeps exactly 2 -> every block is exactly 50% dense
        assert!(s.block_variance < 1e-6, "{}", s.block_variance);
    }

    #[test]
    fn ew_more_irregular_than_bw() {
        let w = w128();
        let ew = mask_stats(&prune_ew(&w, 0.75, None), 16);
        let bw = mask_stats(&prune_bw(&w, 0.75, 16), 16);
        assert!(ew.irregularity > bw.irregularity);
    }

    #[test]
    fn tw_adapts_to_uneven_distribution() {
        // bias the magnitudes: left half of the matrix is "important"
        let mut w = w128();
        for r in 0..128 {
            for c in 0..64 {
                *w.at_mut(r, c) *= 4.0;
            }
        }
        let tw = prune_tw(&w, 0.75, 32, None);
        let s = mask_stats(&tw.mask(), 16);
        let vw = mask_stats(&prune_vw(&w, 0.75, 4), 16);
        // TW concentrates survivors on the important half; VW cannot
        assert!(s.block_variance > vw.block_variance);
    }

    #[test]
    fn heatmap_shape() {
        let w = w128();
        let m = prune_ew(&w, 0.5, None);
        let hm = render_heatmap(&m, 16);
        assert_eq!(hm.lines().count(), 8);
        assert!(hm.lines().all(|l| l.chars().count() == 8));
    }
}
