//! Boolean keep-masks over weight matrices.

/// Dense boolean keep-mask (true = weight survives) with matrix geometry.
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    pub rows: usize,
    pub cols: usize,
    pub keep: Vec<bool>,
}

impl Mask {
    pub fn all(rows: usize, cols: usize) -> Self {
        Self { rows, cols, keep: vec![true; rows * cols] }
    }

    pub fn none(rows: usize, cols: usize) -> Self {
        Self { rows, cols, keep: vec![false; rows * cols] }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> bool {
        self.keep[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        self.keep[r * self.cols + c] = v;
    }

    pub fn count_kept(&self) -> usize {
        self.keep.iter().filter(|&&k| k).count()
    }

    /// Fraction of weights removed.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.count_kept() as f64 / self.keep.len() as f64
    }

    /// Element-wise OR (used by TEW = TW mask | remedy mask).
    pub fn or(&self, other: &Mask) -> Mask {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mask {
            rows: self.rows,
            cols: self.cols,
            keep: self.keep.iter().zip(&other.keep).map(|(a, b)| *a || *b).collect(),
        }
    }

    /// Element-wise AND (used by TVW = TW mask & 2:4 mask).
    pub fn and(&self, other: &Mask) -> Mask {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mask {
            rows: self.rows,
            cols: self.cols,
            keep: self.keep.iter().zip(&other.keep).map(|(a, b)| *a && *b).collect(),
        }
    }

    /// True where both masks disagree on no kept element of `self`
    /// (i.e. self ⊆ other).
    pub fn subset_of(&self, other: &Mask) -> bool {
        self.keep.iter().zip(&other.keep).all(|(a, b)| !*a || *b)
    }

    /// Apply to a weight matrix: zero every pruned element.
    pub fn apply(&self, w: &crate::tensor::Matrix) -> crate::tensor::Matrix {
        assert_eq!((self.rows, self.cols), (w.rows, w.cols));
        let data = w
            .data
            .iter()
            .zip(&self.keep)
            .map(|(x, k)| if *k { *x } else { 0.0 })
            .collect();
        crate::tensor::Matrix::from_vec(w.rows, w.cols, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::util::Rng;

    #[test]
    fn sparsity_accounting() {
        let mut m = Mask::all(4, 4);
        m.set(0, 0, false);
        m.set(1, 1, false);
        assert_eq!(m.count_kept(), 14);
        assert!((m.sparsity() - 2.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn or_and_subset() {
        let mut a = Mask::none(2, 2);
        a.set(0, 0, true);
        let mut b = Mask::none(2, 2);
        b.set(1, 1, true);
        let u = a.or(&b);
        assert_eq!(u.count_kept(), 2);
        assert!(a.subset_of(&u));
        assert_eq!(a.and(&b).count_kept(), 0);
    }

    #[test]
    fn apply_zeroes_pruned() {
        let mut rng = Rng::new(0);
        let w = Matrix::randn(3, 3, &mut rng);
        let mut m = Mask::all(3, 3);
        m.set(2, 2, false);
        let wm = m.apply(&w);
        assert_eq!(wm.at(2, 2), 0.0);
        assert_eq!(wm.at(0, 0), w.at(0, 0));
    }
}
