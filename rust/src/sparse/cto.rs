//! Compressed-Tile-Offset (CTO) execution plans — Rust twin of
//! `python/compile/plans.py` (paper §V "Tile Fusion and Compressed Tile
//! Offset").
//!
//! A `TwPlan` stores each condensed tile's values plus two offset tables:
//! `row_idx` (which original rows of B / columns of A each condensed row
//! corresponds to — `CTO_k` in the paper's Listing 1) and `col_idx`
//! (which original output columns each condensed column scatters to —
//! `CTO_n`).  Padding rows index 0 against zeroed values; padding columns
//! carry the sentinel `n` and are dropped by the scatter.

use crate::sparse::TwStructure;
use crate::tensor::Matrix;
use crate::util::round_up;

/// Padded CTO arrays for one TW-pruned weight matrix.
#[derive(Clone, Debug)]
pub struct TwPlan {
    /// Condensed tile values, `(tiles, kmax, g)` flattened row-major.
    pub b_cond: Vec<f32>,
    /// Original row index per condensed row, `(tiles, kmax)`.
    pub row_idx: Vec<i32>,
    /// Valid rows per tile, `(tiles,)`.
    pub row_len: Vec<i32>,
    /// Original column index per condensed column, `(tiles, g)`;
    /// sentinel == `n` marks padding.
    pub col_idx: Vec<i32>,
    pub tiles: usize,
    pub kmax: usize,
    pub g: usize,
    /// Original K (reduction length).
    pub k: usize,
    /// Original N (output width).
    pub n: usize,
}

impl TwPlan {
    /// Encode a TW structure over weight matrix `w`.
    pub fn encode(w: &Matrix, tw: &TwStructure) -> TwPlan {
        Self::encode_with_kmax_multiple(w, tw, 8)
    }

    pub fn encode_with_kmax_multiple(w: &Matrix, tw: &TwStructure, mult: usize) -> TwPlan {
        let (k, n) = tw.shape;
        let g = tw.g;
        let tiles = tw.num_tiles();
        let kmax = round_up(
            tw.tile_rows.iter().map(Vec::len).max().unwrap_or(1).max(1),
            mult,
        );
        let mut b_cond = vec![0.0f32; tiles * kmax * g];
        let mut row_idx = vec![0i32; tiles * kmax];
        let mut row_len = vec![0i32; tiles];
        let mut col_idx = vec![n as i32; tiles * g];
        for t in 0..tiles {
            let rows = &tw.tile_rows[t];
            let cols = tw.tile_cols(t);
            row_len[t] = rows.len() as i32;
            for (i, &r) in rows.iter().enumerate() {
                row_idx[t * kmax + i] = r as i32;
                for (j, &c) in cols.iter().enumerate() {
                    b_cond[(t * kmax + i) * g + j] = w.at(r, c);
                }
            }
            for (j, &c) in cols.iter().enumerate() {
                col_idx[t * g + j] = c as i32;
            }
        }
        TwPlan { b_cond, row_idx, row_len, col_idx, tiles, kmax, g, k, n }
    }

    /// Expand back to the dense masked weight matrix (tests, debugging).
    pub fn decode(&self) -> Matrix {
        let mut w = Matrix::zeros(self.k, self.n);
        for t in 0..self.tiles {
            let kt = self.row_len[t] as usize;
            for i in 0..kt {
                let r = self.row_idx[t * self.kmax + i] as usize;
                for j in 0..self.g {
                    let c = self.col_idx[t * self.g + j];
                    if (c as usize) < self.n {
                        *w.at_mut(r, c as usize) = self.b_cond[(t * self.kmax + i) * self.g + j];
                    }
                }
            }
        }
        w
    }

    /// MACs*2 executed by the condensed GEMM for `m` activation rows.
    pub fn flops(&self, m: usize) -> usize {
        2 * m * self.g * self.row_len.iter().map(|&x| x as usize).sum::<usize>()
    }

    pub fn dense_flops(&self, m: usize) -> usize {
        2 * m * self.k * self.n
    }

    /// Bytes of the condensed representation (values + offset tables).
    pub fn storage_bytes(&self) -> usize {
        self.b_cond.len() * 4 + self.row_idx.len() * 4 + self.col_idx.len() * 4 + self.row_len.len() * 4
    }
}

/// TW plan whose condensed tiles are additionally 2:4-compressed along K —
/// the TVW storage format (values + in-group positions, the sparse tensor
/// core metadata word).
#[derive(Clone, Debug)]
pub struct TvwPlan {
    /// Kept values, `(tiles, kmax/2, g)`.
    pub b_vals: Vec<f32>,
    /// In-group position (0..3) of each kept value, `(tiles, kmax/2, g)`.
    pub b_sel: Vec<i32>,
    pub row_idx: Vec<i32>,
    pub row_len: Vec<i32>,
    pub col_idx: Vec<i32>,
    pub tiles: usize,
    pub kmax: usize,
    pub g: usize,
    pub k: usize,
    pub n: usize,
}

impl TvwPlan {
    /// Encode from the TW structure + final TVW keep-mask (which keeps at
    /// most 2 of every 4 condensed rows per column).
    pub fn encode(w: &Matrix, tw: &TwStructure, mask: &crate::sparse::Mask) -> TvwPlan {
        let wm = mask.apply(w);
        let base = TwPlan::encode_with_kmax_multiple(&wm, tw, 8);
        let (tiles, kmax, g) = (base.tiles, base.kmax, base.g);
        assert_eq!(kmax % 4, 0);
        let khalf = kmax / 2;
        let mut b_vals = vec![0.0f32; tiles * khalf * g];
        let mut b_sel = vec![0i32; tiles * khalf * g];
        for t in 0..tiles {
            for grp in 0..kmax / 4 {
                for j in 0..g {
                    // top-2 magnitudes of the 4-row group, positions ascending
                    let mut v: Vec<(usize, f32)> = (0..4)
                        .map(|i| (i, base.b_cond[(t * kmax + grp * 4 + i) * g + j]))
                        .collect();
                    v.sort_by(|a, b| b.1.abs().partial_cmp(&a.1.abs()).unwrap());
                    let mut sel = [v[0].0, v[1].0];
                    sel.sort_unstable();
                    for (slot, &pos) in sel.iter().enumerate() {
                        let out = (t * khalf + grp * 2 + slot) * g + j;
                        b_sel[out] = pos as i32;
                        b_vals[out] = base.b_cond[(t * kmax + grp * 4 + pos) * g + j];
                    }
                }
            }
        }
        TvwPlan {
            b_vals,
            b_sel,
            row_idx: base.row_idx,
            row_len: base.row_len,
            col_idx: base.col_idx,
            tiles,
            kmax,
            g,
            k: base.k,
            n: base.n,
        }
    }

    /// Expand back to the dense masked weight matrix.
    pub fn decode(&self) -> Matrix {
        let khalf = self.kmax / 2;
        let mut b_cond = vec![0.0f32; self.tiles * self.kmax * self.g];
        for t in 0..self.tiles {
            for i in 0..khalf {
                let grp_base = (i / 2) * 4;
                for j in 0..self.g {
                    let pos = self.b_sel[(t * khalf + i) * self.g + j] as usize;
                    b_cond[(t * self.kmax + grp_base + pos) * self.g + j] =
                        self.b_vals[(t * khalf + i) * self.g + j];
                }
            }
        }
        let base = TwPlan {
            b_cond,
            row_idx: self.row_idx.clone(),
            row_len: self.row_len.clone(),
            col_idx: self.col_idx.clone(),
            tiles: self.tiles,
            kmax: self.kmax,
            g: self.g,
            k: self.k,
            n: self.n,
        };
        base.decode()
    }

    /// The sparse tensor core executes only the kept half of each vector.
    pub fn flops(&self, m: usize) -> usize {
        m * self.g * self.row_len.iter().map(|&x| x as usize).sum::<usize>()
    }

    pub fn storage_bytes(&self) -> usize {
        // values f32 + 2-bit metadata per value (packed, as on hardware)
        self.b_vals.len() * 4
            + self.b_vals.len() / 4
            + self.row_idx.len() * 4
            + self.col_idx.len() * 4
    }
}

/// Plain 2:4 compression of a full matrix along K (Ampere sparse tensor
/// core storage: values + 2-bit metadata).
#[derive(Clone, Debug)]
pub struct Vw24Plan {
    /// `(k/2, n)` kept values.
    pub b_vals: Vec<f32>,
    /// `(k/2, n)` in-group positions (0..3).
    pub b_sel: Vec<i32>,
    pub k: usize,
    pub n: usize,
}

impl Vw24Plan {
    /// Compress a 2:4-masked matrix; `mask` must keep exactly 2 of every 4
    /// consecutive elements along K.
    pub fn encode(w: &Matrix, mask: &crate::sparse::Mask) -> Result<Vw24Plan, String> {
        let (k, n) = (w.rows, w.cols);
        if k % 4 != 0 {
            return Err(format!("K={k} not a multiple of 4"));
        }
        let khalf = k / 2;
        let mut b_vals = vec![0.0f32; khalf * n];
        let mut b_sel = vec![0i32; khalf * n];
        for c in 0..n {
            for grp in 0..k / 4 {
                let kept: Vec<usize> = (0..4).filter(|&i| mask.at(grp * 4 + i, c)).collect();
                if kept.len() != 2 {
                    return Err(format!("group ({grp},{c}) keeps {} != 2", kept.len()));
                }
                for (slot, &pos) in kept.iter().enumerate() {
                    b_sel[(grp * 2 + slot) * n + c] = pos as i32;
                    b_vals[(grp * 2 + slot) * n + c] = w.at(grp * 4 + pos, c);
                }
            }
        }
        Ok(Vw24Plan { b_vals, b_sel, k, n })
    }

    pub fn decode(&self) -> Matrix {
        let khalf = self.k / 2;
        let mut w = Matrix::zeros(self.k, self.n);
        for c in 0..self.n {
            for i in 0..khalf {
                let r = (i / 2) * 4 + self.b_sel[i * self.n + c] as usize;
                *w.at_mut(r, c) = self.b_vals[i * self.n + c];
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{prune_tvw, prune_tw, prune_vw};
    use crate::util::Rng;

    fn mat(r: usize, c: usize, seed: u64) -> Matrix {
        Matrix::randn(r, c, &mut Rng::new(seed))
    }

    #[test]
    fn tw_plan_roundtrip() {
        let w = mat(96, 80, 21);
        let tw = prune_tw(&w, 0.6, 16, None);
        let plan = TwPlan::encode(&w, &tw);
        let decoded = plan.decode();
        let masked = tw.mask().apply(&w);
        assert_eq!(decoded.max_abs_diff(&masked), 0.0);
    }

    #[test]
    fn tw_plan_padding_invariants() {
        let w = mat(64, 48, 22);
        let tw = prune_tw(&w, 0.5, 16, None);
        let p = TwPlan::encode(&w, &tw);
        assert_eq!(p.kmax % 8, 0);
        for t in 0..p.tiles {
            let kt = p.row_len[t] as usize;
            for i in kt..p.kmax {
                for j in 0..p.g {
                    assert_eq!(p.b_cond[(t * p.kmax + i) * p.g + j], 0.0);
                }
                assert!((p.row_idx[t * p.kmax + i] as usize) < p.k);
            }
        }
    }

    #[test]
    fn tvw_plan_roundtrip() {
        let w = mat(96, 80, 23);
        let (tw, mask) = prune_tvw(&w, 0.7, 16);
        let plan = TvwPlan::encode(&w, &tw, &mask);
        let decoded = plan.decode();
        let masked = mask.apply(&w);
        assert_eq!(decoded.max_abs_diff(&masked), 0.0);
    }

    #[test]
    fn vw24_plan_roundtrip() {
        let w = mat(64, 48, 24);
        let mask = prune_vw(&w, 0.5, 4);
        let plan = Vw24Plan::encode(&w, &mask).unwrap();
        assert_eq!(plan.decode().max_abs_diff(&mask.apply(&w)), 0.0);
    }

    #[test]
    fn vw24_rejects_bad_mask() {
        let w = mat(8, 4, 25);
        let mask = crate::sparse::Mask::all(8, 4);
        assert!(Vw24Plan::encode(&w, &mask).is_err());
    }

    #[test]
    fn flops_accounting() {
        let w = mat(64, 64, 26);
        let tw = prune_tw(&w, 0.75, 16, None);
        let p = TwPlan::encode(&w, &tw);
        assert!(p.flops(32) < p.dense_flops(32));
        let (tw2, mask) = prune_tvw(&w, 0.75, 16);
        let q = TvwPlan::encode(&w, &tw2, &mask);
        let base = TwPlan::encode(&w, &tw2);
        assert_eq!(q.flops(32) * 2, base.flops(32));
    }

    #[test]
    fn storage_shrinks_with_sparsity() {
        let w = mat(256, 256, 27);
        let lo = TwPlan::encode(&w, &prune_tw(&w, 0.25, 32, None));
        let hi = TwPlan::encode(&w, &prune_tw(&w, 0.9, 32, None));
        assert!(hi.storage_bytes() < lo.storage_bytes());
    }
}
