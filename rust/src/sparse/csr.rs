//! Compressed sparse row / column formats — the EW (cuSparse-style) and
//! TEW-remainder storage substrate.

use crate::sparse::Mask;
use crate::tensor::Matrix;

/// Compressed sparse row.
#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Length `rows + 1`.
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr {
    /// Build from a dense matrix, treating exact zeros as absent.
    pub fn from_dense(w: &Matrix) -> Csr {
        let mut row_ptr = Vec::with_capacity(w.rows + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0);
        for r in 0..w.rows {
            for c in 0..w.cols {
                let v = w.at(r, c);
                if v != 0.0 {
                    col_idx.push(c as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        Csr { rows: w.rows, cols: w.cols, row_ptr, col_idx, vals }
    }

    /// Build from a weight matrix + keep-mask (pruned entries absent even
    /// if their value is coincidentally zero).
    pub fn from_masked(w: &Matrix, mask: &Mask) -> Csr {
        Csr::from_dense(&mask.apply(w))
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.row_ptr[r]..self.row_ptr[r + 1] {
                *m.at_mut(r, self.col_idx[i] as usize) = self.vals[i];
            }
        }
        m
    }

    /// Storage footprint in bytes (vals f32 + col idx u32 + row ptr u32).
    pub fn storage_bytes(&self) -> usize {
        self.vals.len() * 4 + self.col_idx.len() * 4 + self.row_ptr.len() * 4
    }
}

/// Compressed sparse column (the paper stores the TEW remainder as CSC).
#[derive(Clone, Debug)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    /// Length `cols + 1`.
    pub col_ptr: Vec<usize>,
    pub row_idx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csc {
    pub fn from_dense(w: &Matrix) -> Csc {
        let mut col_ptr = Vec::with_capacity(w.cols + 1);
        let mut row_idx = Vec::new();
        let mut vals = Vec::new();
        col_ptr.push(0);
        for c in 0..w.cols {
            for r in 0..w.rows {
                let v = w.at(r, c);
                if v != 0.0 {
                    row_idx.push(r as u32);
                    vals.push(v);
                }
            }
            col_ptr.push(row_idx.len());
        }
        Csc { rows: w.rows, cols: w.cols, col_ptr, row_idx, vals }
    }

    pub fn from_masked(w: &Matrix, mask: &Mask) -> Csc {
        Csc::from_dense(&mask.apply(w))
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for c in 0..self.cols {
            for i in self.col_ptr[c]..self.col_ptr[c + 1] {
                *m.at_mut(self.row_idx[i] as usize, c) = self.vals[i];
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::prune_ew;
    use crate::util::Rng;

    #[test]
    fn csr_roundtrip() {
        let mut rng = Rng::new(31);
        let w = Matrix::randn(20, 30, &mut rng);
        let mask = prune_ew(&w, 0.7, None);
        let csr = Csr::from_masked(&w, &mask);
        assert_eq!(csr.nnz(), mask.count_kept());
        assert_eq!(csr.to_dense().max_abs_diff(&mask.apply(&w)), 0.0);
    }

    #[test]
    fn csc_roundtrip() {
        let mut rng = Rng::new(32);
        let w = Matrix::randn(20, 30, &mut rng);
        let mask = prune_ew(&w, 0.9, None);
        let csc = Csc::from_masked(&w, &mask);
        assert_eq!(csc.nnz(), mask.count_kept());
        assert_eq!(csc.to_dense().max_abs_diff(&mask.apply(&w)), 0.0);
    }

    #[test]
    fn empty_matrix() {
        let w = Matrix::zeros(5, 5);
        let csr = Csr::from_dense(&w);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.to_dense(), w);
    }
}
