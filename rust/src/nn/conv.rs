//! img2col convolution lowering (paper §II-A: "the convolutional layer
//! can be converted to GEMM through the img2col transformation").
//!
//! `im2col` flattens each receptive field into a row of the activation
//! matrix A `(H_out*W_out, C_in*kh*kw)`; the filter bank flattens into
//! B `(C_in*kh*kw, C_out)` — exactly the (K, N) weight orientation the
//! pruning patterns operate on, so a TW-pruned conv is just a TW-pruned
//! B matrix fed to the condensed GEMM.

use crate::gemm::matmul;
use crate::tensor::Matrix;

/// Convolution hyper-parameters (square kernel, same stride both dims).
#[derive(Clone, Copy, Debug)]
pub struct Conv2dSpec {
    pub c_in: usize,
    pub c_out: usize,
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2dSpec {
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.kernel) / self.stride + 1,
            (w + 2 * self.pad - self.kernel) / self.stride + 1,
        )
    }

    /// GEMM K dimension of the lowered convolution.
    pub fn gemm_k(&self) -> usize {
        self.c_in * self.kernel * self.kernel
    }
}

/// NCHW single-image tensor (channels x height x width), row-major.
#[derive(Clone, Debug)]
pub struct Image {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl Image {
    pub fn zeros(c: usize, h: usize, w: usize) -> Image {
        Image { c, h, w, data: vec![0.0; c * h * w] }
    }

    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> f32 {
        self.data[(c * self.h + y) * self.w + x]
    }

    #[inline]
    pub fn at_mut(&mut self, c: usize, y: usize, x: usize) -> &mut f32 {
        &mut self.data[(c * self.h + y) * self.w + x]
    }
}

/// Layout of the activation an im2col lowering reads from.  The graph
/// executor lowers conv chains without materialising `Image`s: the network
/// input arrives as a flat CHW slice and every intermediate conv output is
/// already the previous GEMM's `(H*W, C)` matrix.
pub enum ImgSrc<'a> {
    /// NCHW flat slice of length `c * h * w` (the network-input layout).
    Chw { data: &'a [f32], c: usize, h: usize, w: usize },
    /// A previous conv GEMM's output: rows = pixels (`h*w`), cols = channels.
    HwC { m: &'a Matrix, h: usize, w: usize },
}

impl ImgSrc<'_> {
    fn dims(&self) -> (usize, usize, usize) {
        match self {
            ImgSrc::Chw { c, h, w, data } => {
                assert_eq!(data.len(), c * h * w, "CHW slice length");
                (*c, *h, *w)
            }
            ImgSrc::HwC { m, h, w } => {
                assert_eq!(m.rows, h * w, "HwC rows must be h*w");
                (m.cols, *h, *w)
            }
        }
    }

    #[inline]
    fn at(&self, c: usize, y: usize, x: usize) -> f32 {
        match self {
            ImgSrc::Chw { data, h, w, .. } => data[(c * h + y) * w + x],
            ImgSrc::HwC { m, w, .. } => m.at(y * w + x, c),
        }
    }
}

/// Allocation-free im2col lowering into a caller-owned
/// `(H_out*W_out, C_in*kh*kw)` matrix; out-of-bounds (padding) taps
/// write 0.  [`im2col`] is the allocating shim over this.
pub fn im2col_into(src: &ImgSrc, spec: &Conv2dSpec, a: &mut Matrix) {
    let (c_in, h, w) = src.dims();
    assert_eq!(c_in, spec.c_in);
    let (ho, wo) = spec.out_hw(h, w);
    assert_eq!((a.rows, a.cols), (ho * wo, spec.gemm_k()), "im2col output shape");
    let kk = spec.kernel;
    for oy in 0..ho {
        for ox in 0..wo {
            let row = oy * wo + ox;
            let out = a.row_mut(row);
            let mut col = 0usize;
            for c in 0..c_in {
                for ky in 0..kk {
                    for kx in 0..kk {
                        let iy = oy * spec.stride + ky;
                        let ix = ox * spec.stride + kx;
                        // padded coordinates: shift by pad, check bounds
                        let v = if iy >= spec.pad
                            && ix >= spec.pad
                            && iy - spec.pad < h
                            && ix - spec.pad < w
                        {
                            src.at(c, iy - spec.pad, ix - spec.pad)
                        } else {
                            0.0
                        };
                        out[col] = v;
                        col += 1;
                    }
                }
            }
        }
    }
}

/// Lower an image to the im2col activation matrix A
/// `(H_out*W_out, C_in*kh*kw)`; out-of-bounds (padding) taps read 0.
pub fn im2col(img: &Image, spec: &Conv2dSpec) -> Matrix {
    assert_eq!(img.c, spec.c_in);
    let (ho, wo) = spec.out_hw(img.h, img.w);
    let mut a = Matrix::zeros(ho * wo, spec.gemm_k());
    im2col_into(
        &ImgSrc::Chw { data: &img.data, c: img.c, h: img.h, w: img.w },
        spec,
        &mut a,
    );
    a
}

/// Flatten a filter bank `[c_out][c_in][kh][kw]` (as a flat slice) into
/// the GEMM B matrix `(C_in*kh*kw, C_out)`.
pub fn filters_to_matrix(filters: &[f32], spec: &Conv2dSpec) -> Matrix {
    let k = spec.gemm_k();
    assert_eq!(filters.len(), spec.c_out * k);
    let mut b = Matrix::zeros(k, spec.c_out);
    for co in 0..spec.c_out {
        for i in 0..k {
            *b.at_mut(i, co) = filters[co * k + i];
        }
    }
    b
}

/// Direct (sliding-window) convolution — the correctness oracle.
pub fn conv2d_direct(img: &Image, filters: &[f32], spec: &Conv2dSpec) -> Image {
    let (ho, wo) = spec.out_hw(img.h, img.w);
    let kk = spec.kernel;
    let k = spec.gemm_k();
    let mut out = Image::zeros(spec.c_out, ho, wo);
    for co in 0..spec.c_out {
        for oy in 0..ho {
            for ox in 0..wo {
                let mut acc = 0.0f32;
                let mut idx = 0usize;
                for c in 0..img.c {
                    for ky in 0..kk {
                        for kx in 0..kk {
                            let iy = oy * spec.stride + ky;
                            let ix = ox * spec.stride + kx;
                            if iy >= spec.pad
                                && ix >= spec.pad
                                && iy - spec.pad < img.h
                                && ix - spec.pad < img.w
                            {
                                acc += img.at(c, iy - spec.pad, ix - spec.pad)
                                    * filters[co * k + idx];
                            }
                            idx += 1;
                        }
                    }
                }
                *out.at_mut(co, oy, ox) = acc;
            }
        }
    }
    out
}

/// Convolution via im2col + GEMM (the accelerator path).  Any pruned GEMM
/// kernel can replace `matmul` here — `conv2d_with` takes the GEMM as a
/// closure for exactly that.
pub fn conv2d(img: &Image, filters: &[f32], spec: &Conv2dSpec) -> Image {
    conv2d_with(img, filters, spec, |a, b| matmul(a, b))
}

pub fn conv2d_with<F>(img: &Image, filters: &[f32], spec: &Conv2dSpec, gemm: F) -> Image
where
    F: Fn(&Matrix, &Matrix) -> Matrix,
{
    let (ho, wo) = spec.out_hw(img.h, img.w);
    let a = im2col(img, spec);
    let b = filters_to_matrix(filters, spec);
    let c = gemm(&a, &b);
    // (ho*wo, c_out) -> NCHW
    let mut out = Image::zeros(spec.c_out, ho, wo);
    for row in 0..ho * wo {
        for co in 0..spec.c_out {
            out.data[(co * ho + row / wo) * wo + row % wo] = c.at(row, co);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::tw_matmul;
    use crate::sparse::{prune_tw, TwPlan};
    use crate::util::Rng;

    fn rand_image(c: usize, h: usize, w: usize, seed: u64) -> Image {
        let mut rng = Rng::new(seed);
        let mut img = Image::zeros(c, h, w);
        for v in &mut img.data {
            *v = rng.normal_f32();
        }
        img
    }

    fn rand_filters(spec: &Conv2dSpec, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..spec.c_out * spec.gemm_k()).map(|_| rng.normal_f32() * 0.2).collect()
    }

    #[test]
    fn im2col_gemm_matches_direct() {
        for (spec, h, w) in [
            (Conv2dSpec { c_in: 3, c_out: 8, kernel: 3, stride: 1, pad: 1 }, 8, 8),
            (Conv2dSpec { c_in: 4, c_out: 6, kernel: 3, stride: 2, pad: 0 }, 9, 11),
            (Conv2dSpec { c_in: 2, c_out: 4, kernel: 1, stride: 1, pad: 0 }, 5, 5),
            (Conv2dSpec { c_in: 3, c_out: 5, kernel: 5, stride: 1, pad: 2 }, 7, 7),
        ] {
            let img = rand_image(spec.c_in, h, w, 10);
            let f = rand_filters(&spec, 11);
            let direct = conv2d_direct(&img, &f, &spec);
            let gemm = conv2d(&img, &f, &spec);
            let diff = direct
                .data
                .iter()
                .zip(&gemm.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 1e-3, "k={} s={} p={}: {diff}", spec.kernel, spec.stride, spec.pad);
        }
    }

    #[test]
    fn im2col_hwc_layout_matches_chw() {
        // the graph path feeds a previous GEMM's (hw, c) output straight
        // into the next im2col; both layouts must lower identically
        let spec = Conv2dSpec { c_in: 4, c_out: 6, kernel: 3, stride: 1, pad: 1 };
        let img = rand_image(4, 6, 6, 14);
        let via_chw = im2col(&img, &spec);
        // repack CHW -> (hw, c)
        let mut hwc = Matrix::zeros(36, 4);
        for c in 0..4 {
            for p in 0..36 {
                *hwc.at_mut(p, c) = img.data[c * 36 + p];
            }
        }
        let mut via_hwc = Matrix::zeros(36, spec.gemm_k());
        im2col_into(&ImgSrc::HwC { m: &hwc, h: 6, w: 6 }, &spec, &mut via_hwc);
        assert_eq!(via_chw, via_hwc);
    }

    #[test]
    fn output_shape() {
        let spec = Conv2dSpec { c_in: 3, c_out: 8, kernel: 3, stride: 2, pad: 1 };
        assert_eq!(spec.out_hw(224, 224), (112, 112));
        assert_eq!(spec.gemm_k(), 27);
    }

    #[test]
    fn tw_pruned_convolution() {
        // the paper's actual use: prune the flattened filter matrix with TW
        // and run the conv through the condensed GEMM
        let spec = Conv2dSpec { c_in: 8, c_out: 16, kernel: 3, stride: 1, pad: 1 };
        let img = rand_image(8, 10, 10, 12);
        let f = rand_filters(&spec, 13);
        let b = filters_to_matrix(&f, &spec);
        let tw = prune_tw(&b, 0.5, 8, None);
        let plan = TwPlan::encode(&b, &tw);
        let masked_b = tw.mask().apply(&b);

        let via_tw = conv2d_with(&img, &f, &spec, |a, _| tw_matmul(a, &plan));
        let via_masked = conv2d_with(&img, &f, &spec, |a, _| matmul(a, &masked_b));
        let diff = via_tw
            .data
            .iter()
            .zip(&via_masked.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-3, "{diff}");
    }

    #[test]
    fn vgg_first_block_shapes_match_zoo() {
        // the zoo's conv entries must agree with the real lowering
        let spec = Conv2dSpec { c_in: 64, c_out: 64, kernel: 3, stride: 1, pad: 1 };
        let (ho, wo) = spec.out_hw(224, 224);
        assert_eq!(ho * wo, 224 * 224); // matches models::vgg16 conv1_2 M
        assert_eq!(spec.gemm_k(), 64 * 9); // matches its K
    }
}
