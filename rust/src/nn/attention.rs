//! Scaled-dot-product multi-head attention (the BERT workload's core):
//! QKV projection + per-head softmax(QK^T/sqrt(d))V + output projection,
//! with the two weight GEMMs pluggable so pruned kernels drop in — the
//! Rust twin of `python/compile/model.py`'s attention block.
//!
//! The hot path is [`attention_into`], the workspace-buffered core the
//! graph executor calls: one `(s, s)` scores buffer and one contiguous
//! `(s, dh)` staging buffer per Q/K/V head are reused across *all* heads
//! of *all* calls (the historical implementation reallocated the scores
//! buffer per head and walked V through strided `qkv.row(j)` reads —
//! `benches/model_forward.rs` quantifies the win).  The closure-based
//! [`attention_forward`] remains as a thin back-compat shim.

use crate::tensor::Matrix;

/// Reusable scratch for the buffered attention core: the `(s, s)` scores
/// matrix plus contiguous per-head Q/K/V staging `(s, dh)`.  Allocated
/// once (per graph workspace / per call site) and lent to every head.
pub struct AttnScratch {
    pub scores: Matrix,
    pub qh: Matrix,
    pub kh: Matrix,
    pub vh: Matrix,
}

impl AttnScratch {
    pub fn new(seq: usize, head_dim: usize) -> AttnScratch {
        AttnScratch {
            scores: Matrix::zeros(seq, seq),
            qh: Matrix::zeros(seq, head_dim),
            kh: Matrix::zeros(seq, head_dim),
            vh: Matrix::zeros(seq, head_dim),
        }
    }
}

/// Buffered multi-head attention core over one sequence window.
///
/// Reads the fused QKV projection rows `row0 .. row0+seq` of `qkv`
/// (`(tokens, 3d)`, head layout `[Q | K | V]` along columns) and writes
/// the same rows of `ctx` (`(tokens, d)`).  Allocation-free: all
/// intermediates live in `scratch`, which must have been built with this
/// `seq` and `d / n_heads`.
pub fn attention_into(
    qkv: &Matrix,
    ctx: &mut Matrix,
    row0: usize,
    seq: usize,
    n_heads: usize,
    scratch: &mut AttnScratch,
) {
    attention_window_into(qkv, ctx, row0, seq, n_heads, scratch, false)
}

/// Causal variant of [`attention_into`]: position `i` attends only to
/// positions `0..=i` of its window.  Because each output row then
/// depends solely on earlier rows, a causal one-shot forward equals
/// step-by-step KV-cache decode exactly — the decode-parity contract.
pub fn attention_causal_into(
    qkv: &Matrix,
    ctx: &mut Matrix,
    row0: usize,
    seq: usize,
    n_heads: usize,
    scratch: &mut AttnScratch,
) {
    attention_window_into(qkv, ctx, row0, seq, n_heads, scratch, true)
}

/// The shared window core behind [`attention_into`] /
/// [`attention_causal_into`].
pub fn attention_window_into(
    qkv: &Matrix,
    ctx: &mut Matrix,
    row0: usize,
    seq: usize,
    n_heads: usize,
    scratch: &mut AttnScratch,
    causal: bool,
) {
    let d = ctx.cols;
    assert_eq!(qkv.cols, 3 * d, "qkv projection must be 3*d_model wide");
    assert_eq!(qkv.rows, ctx.rows);
    assert!(row0 + seq <= qkv.rows);
    assert_eq!(d % n_heads, 0);
    let dh = d / n_heads;
    assert_eq!((scratch.scores.rows, scratch.scores.cols), (seq, seq), "scratch sized for seq");
    assert_eq!((scratch.qh.rows, scratch.qh.cols), (seq, dh), "scratch sized for head_dim");
    let scale = 1.0 / (dh as f32).sqrt();

    for h in 0..n_heads {
        // per-head column windows: q at [h*dh, ..), k at d + ..., v at 2d + ...
        let (q0, k0, v0) = (h * dh, d + h * dh, 2 * d + h * dh);
        // stage Q/K/V heads contiguously: the score and context loops then
        // stream dense rows instead of striding through qkv
        for i in 0..seq {
            let src = qkv.row(row0 + i);
            scratch.qh.row_mut(i).copy_from_slice(&src[q0..q0 + dh]);
            scratch.kh.row_mut(i).copy_from_slice(&src[k0..k0 + dh]);
            scratch.vh.row_mut(i).copy_from_slice(&src[v0..v0 + dh]);
        }
        // scores = softmax(q k^T * scale), (seq, seq); causal masking
        // restricts row i to its 0..=i prefix
        for i in 0..seq {
            let lim = if causal { i + 1 } else { seq };
            let qi = scratch.qh.row(i);
            let row = &mut scratch.scores.row_mut(i)[..lim];
            for (j, sv) in row.iter_mut().enumerate() {
                let kj = scratch.kh.row(j);
                *sv = qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            let mx = row.iter().fold(f32::MIN, |a, &b| a.max(b));
            let mut z = 0.0;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                z += *v;
            }
            for v in row.iter_mut() {
                *v /= z;
            }
        }
        // ctx_head = scores @ v_head (contiguous accumulate)
        for i in 0..seq {
            let lim = if causal { i + 1 } else { seq };
            let out = &mut ctx.row_mut(row0 + i)[h * dh..(h + 1) * dh];
            out.fill(0.0);
            for j in 0..lim {
                let w = scratch.scores.at(i, j);
                for (o, vv) in out.iter_mut().zip(scratch.vh.row(j)) {
                    *o += w * vv;
                }
            }
        }
    }
}

/// Forward pass for one attention block over `(seq, d_model)` activations.
///
/// `w_qkv` is `(d_model, 3*d_model)`; `w_out` is `(d_model, d_model)`;
/// `gemm` is invoked for both weight multiplications.  Back-compat shim
/// over [`attention_into`] (scratch allocated per call here; the graph
/// path keeps it in the model workspace).
pub fn attention_forward<F>(
    x: &Matrix,
    w_qkv: &Matrix,
    w_out: &Matrix,
    n_heads: usize,
    gemm: F,
) -> Matrix
where
    F: Fn(&Matrix, &Matrix) -> Matrix,
{
    let (s, d) = (x.rows, x.cols);
    assert_eq!(w_qkv.rows, d);
    assert_eq!(w_qkv.cols, 3 * d);
    assert_eq!(d % n_heads, 0);
    let qkv = gemm(x, w_qkv); // (s, 3d)
    let mut ctx = Matrix::zeros(s, d);
    let mut scratch = AttnScratch::new(s, d / n_heads);
    attention_into(&qkv, &mut ctx, 0, s, n_heads, &mut scratch);
    gemm(&ctx, w_out)
}

/// The historical per-head-allocating implementation, kept as the
/// correctness oracle for [`attention_into`] and as the baseline
/// `benches/model_forward.rs` measures the buffered path against:
/// it reallocates the `(s, s)` scores buffer on every head and reads
/// K/V through strided `qkv.row(j)` slices.
pub fn attention_forward_unbuffered<F>(
    x: &Matrix,
    w_qkv: &Matrix,
    w_out: &Matrix,
    n_heads: usize,
    gemm: F,
) -> Matrix
where
    F: Fn(&Matrix, &Matrix) -> Matrix,
{
    let (s, d) = (x.rows, x.cols);
    assert_eq!(w_qkv.rows, d);
    assert_eq!(w_qkv.cols, 3 * d);
    assert_eq!(d % n_heads, 0);
    let dh = d / n_heads;

    let qkv = gemm(x, w_qkv); // (s, 3d)
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = Matrix::zeros(s, d);
    for h in 0..n_heads {
        let q0 = h * dh;
        let k0 = d + h * dh;
        let v0 = 2 * d + h * dh;
        let mut scores = vec![0.0f32; s * s];
        for i in 0..s {
            let qi = &qkv.row(i)[q0..q0 + dh];
            for j in 0..s {
                let kj = &qkv.row(j)[k0..k0 + dh];
                scores[i * s + j] =
                    qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
        }
        for i in 0..s {
            let row = &mut scores[i * s..(i + 1) * s];
            let mx = row.iter().fold(f32::MIN, |a, &b| a.max(b));
            let mut z = 0.0;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                z += *v;
            }
            for v in row.iter_mut() {
                *v /= z;
            }
        }
        for i in 0..s {
            let out = &mut ctx.row_mut(i)[h * dh..(h + 1) * dh];
            for j in 0..s {
                let w = scores[i * s + j];
                let vj = &qkv.row(j)[v0..v0 + dh];
                for (o, vv) in out.iter_mut().zip(vj) {
                    *o += w * vv;
                }
            }
        }
    }
    gemm(&ctx, w_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::matmul;
    use crate::gemm::tw_matmul;
    use crate::sparse::{prune_tw, TwPlan};
    use crate::util::Rng;

    #[test]
    fn output_shape_and_finite() {
        let mut rng = Rng::new(30);
        let (s, d) = (12, 32);
        let x = Matrix::randn(s, d, &mut rng);
        let wqkv = Matrix::randn(d, 3 * d, &mut rng);
        let wout = Matrix::randn(d, d, &mut rng);
        let y = attention_forward(&x, &wqkv, &wout, 4, |a, b| matmul(a, b));
        assert_eq!((y.rows, y.cols), (s, d));
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn buffered_matches_unbuffered_oracle() {
        // the workspace path is a memory-layout change, not a numeric one
        let mut rng = Rng::new(33);
        for (s, d, heads) in [(8, 32, 4), (12, 48, 4), (5, 16, 2), (1, 8, 2)] {
            let x = Matrix::randn(s, d, &mut rng);
            let wqkv = Matrix::randn(d, 3 * d, &mut rng);
            let wout = Matrix::randn(d, d, &mut rng);
            let a = attention_forward(&x, &wqkv, &wout, heads, |a, b| matmul(a, b));
            let b = attention_forward_unbuffered(&x, &wqkv, &wout, heads, |a, b| matmul(a, b));
            assert!(a.max_abs_diff(&b) < 1e-5, "s={s} d={d}: {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn scratch_is_reusable_across_windows() {
        // the graph path: one scratch serves every (batch, head) window
        let mut rng = Rng::new(34);
        let (batch, s, d) = (3, 6, 16);
        let qkv = Matrix::randn(batch * s, 3 * d, &mut rng);
        let mut ctx = Matrix::zeros(batch * s, d);
        let mut scratch = AttnScratch::new(s, d / 4);
        for b in 0..batch {
            attention_into(&qkv, &mut ctx, b * s, s, 4, &mut scratch);
        }
        // each window must equal an isolated single-window run
        for b in 0..batch {
            let mut one = Matrix::zeros(s, 3 * d);
            for i in 0..s {
                one.row_mut(i).copy_from_slice(qkv.row(b * s + i));
            }
            let mut ctx1 = Matrix::zeros(s, d);
            let mut sc = AttnScratch::new(s, d / 4);
            attention_into(&one, &mut ctx1, 0, s, 4, &mut sc);
            for i in 0..s {
                for (x, y) in ctx.row(b * s + i).iter().zip(ctx1.row(i)) {
                    assert!((x - y).abs() < 1e-6, "window {b}");
                }
            }
        }
    }

    #[test]
    fn causal_rows_equal_prefix_windows() {
        // the decode contract: causal row i == non-causal attention over
        // the 0..=i prefix window, read at its last row
        let mut rng = Rng::new(35);
        let (s, d, heads) = (6, 16, 4);
        let qkv = Matrix::randn(s, 3 * d, &mut rng);
        let mut ctx = Matrix::zeros(s, d);
        let mut sc = AttnScratch::new(s, d / heads);
        attention_causal_into(&qkv, &mut ctx, 0, s, heads, &mut sc);
        for i in 0..s {
            let mut pre = Matrix::zeros(i + 1, 3 * d);
            for r in 0..=i {
                pre.row_mut(r).copy_from_slice(qkv.row(r));
            }
            let mut pctx = Matrix::zeros(i + 1, d);
            let mut psc = AttnScratch::new(i + 1, d / heads);
            attention_into(&pre, &mut pctx, 0, i + 1, heads, &mut psc);
            for (a, b) in ctx.row(i).iter().zip(pctx.row(i)) {
                assert!((a - b).abs() < 1e-5, "row {i}");
            }
        }
    }

    #[test]
    fn softmax_rows_are_convex_combinations() {
        // uniform V => context equals V regardless of scores
        let mut rng = Rng::new(31);
        let (s, d) = (6, 16);
        let x = Matrix::randn(s, d, &mut rng);
        let mut wqkv = Matrix::zeros(d, 3 * d);
        // V projection = identity block, Q/K zero => uniform attention
        for i in 0..d {
            *wqkv.at_mut(i, 2 * d + i) = 1.0;
        }
        let mut wout = Matrix::zeros(d, d);
        for i in 0..d {
            *wout.at_mut(i, i) = 1.0;
        }
        let y = attention_forward(&x, &wqkv, &wout, 4, |a, b| matmul(a, b));
        // uniform attention over V=x: each output row = mean of x rows
        let mut mean = vec![0.0f32; d];
        for i in 0..s {
            for (m, v) in mean.iter_mut().zip(x.row(i)) {
                *m += v / s as f32;
            }
        }
        for i in 0..s {
            for j in 0..d {
                assert!((y.at(i, j) - mean[j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn tw_pruned_attention_matches_masked() {
        let mut rng = Rng::new(32);
        let (s, d) = (8, 32);
        let x = Matrix::randn(s, d, &mut rng);
        let wqkv = Matrix::randn(d, 3 * d, &mut rng);
        let wout = Matrix::randn(d, d, &mut rng);
        let tw_qkv = prune_tw(&wqkv, 0.5, 8, None);
        let tw_out = prune_tw(&wout, 0.5, 8, None);
        let plan_qkv = TwPlan::encode(&wqkv, &tw_qkv);
        let plan_out = TwPlan::encode(&wout, &tw_out);
        let mq = tw_qkv.mask().apply(&wqkv);
        let mo = tw_out.mask().apply(&wout);

        let via_tw = attention_forward(&x, &wqkv, &wout, 4, |a, b| {
            if b.cols == 3 * d {
                tw_matmul(a, &plan_qkv)
            } else {
                tw_matmul(a, &plan_out)
            }
        });
        let via_masked = attention_forward(&x, &mq, &mo, 4, |a, b| matmul(a, b));
        assert!(via_tw.max_abs_diff(&via_masked) < 1e-3);
    }
}
