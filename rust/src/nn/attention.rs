//! Scaled-dot-product multi-head attention (the BERT workload's core):
//! QKV projection + per-head softmax(QK^T/sqrt(d))V + output projection,
//! with the two weight GEMMs pluggable so pruned kernels drop in — the
//! Rust twin of `python/compile/model.py`'s attention block.

use crate::tensor::Matrix;

/// Forward pass for one attention block over `(seq, d_model)` activations.
///
/// `w_qkv` is `(d_model, 3*d_model)`; `w_out` is `(d_model, d_model)`;
/// `gemm` is invoked for both weight multiplications.
pub fn attention_forward<F>(
    x: &Matrix,
    w_qkv: &Matrix,
    w_out: &Matrix,
    n_heads: usize,
    gemm: F,
) -> Matrix
where
    F: Fn(&Matrix, &Matrix) -> Matrix,
{
    let (s, d) = (x.rows, x.cols);
    assert_eq!(w_qkv.rows, d);
    assert_eq!(w_qkv.cols, 3 * d);
    assert_eq!(d % n_heads, 0);
    let dh = d / n_heads;

    let qkv = gemm(x, w_qkv); // (s, 3d)
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = Matrix::zeros(s, d);
    for h in 0..n_heads {
        // per-head slices: q at [h*dh, (h+1)*dh), k at d + ..., v at 2d + ...
        let q0 = h * dh;
        let k0 = d + h * dh;
        let v0 = 2 * d + h * dh;
        // scores = softmax(q k^T * scale), (s, s)
        let mut scores = vec![0.0f32; s * s];
        for i in 0..s {
            let qi = &qkv.row(i)[q0..q0 + dh];
            for j in 0..s {
                let kj = &qkv.row(j)[k0..k0 + dh];
                scores[i * s + j] =
                    qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
        }
        for i in 0..s {
            let row = &mut scores[i * s..(i + 1) * s];
            let mx = row.iter().fold(f32::MIN, |a, &b| a.max(b));
            let mut z = 0.0;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                z += *v;
            }
            for v in row.iter_mut() {
                *v /= z;
            }
        }
        // ctx_head = scores @ v_head
        for i in 0..s {
            let out = &mut ctx.row_mut(i)[h * dh..(h + 1) * dh];
            for j in 0..s {
                let w = scores[i * s + j];
                let vj = &qkv.row(j)[v0..v0 + dh];
                for (o, vv) in out.iter_mut().zip(vj) {
                    *o += w * vv;
                }
            }
        }
    }
    gemm(&ctx, w_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::matmul;
    use crate::gemm::tw_matmul;
    use crate::sparse::{prune_tw, TwPlan};
    use crate::util::Rng;

    #[test]
    fn output_shape_and_finite() {
        let mut rng = Rng::new(30);
        let (s, d) = (12, 32);
        let x = Matrix::randn(s, d, &mut rng);
        let wqkv = Matrix::randn(d, 3 * d, &mut rng);
        let wout = Matrix::randn(d, d, &mut rng);
        let y = attention_forward(&x, &wqkv, &wout, 4, |a, b| matmul(a, b));
        assert_eq!((y.rows, y.cols), (s, d));
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn softmax_rows_are_convex_combinations() {
        // uniform V => context equals V regardless of scores
        let mut rng = Rng::new(31);
        let (s, d) = (6, 16);
        let x = Matrix::randn(s, d, &mut rng);
        let mut wqkv = Matrix::zeros(d, 3 * d);
        // V projection = identity block, Q/K zero => uniform attention
        for i in 0..d {
            *wqkv.at_mut(i, 2 * d + i) = 1.0;
        }
        let mut wout = Matrix::zeros(d, d);
        for i in 0..d {
            *wout.at_mut(i, i) = 1.0;
        }
        let y = attention_forward(&x, &wqkv, &wout, 4, |a, b| matmul(a, b));
        // uniform attention over V=x: each output row = mean of x rows
        let mut mean = vec![0.0f32; d];
        for i in 0..s {
            for (m, v) in mean.iter_mut().zip(x.row(i)) {
                *m += v / s as f32;
            }
        }
        for i in 0..s {
            for j in 0..d {
                assert!((y.at(i, j) - mean[j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn tw_pruned_attention_matches_masked() {
        let mut rng = Rng::new(32);
        let (s, d) = (8, 32);
        let x = Matrix::randn(s, d, &mut rng);
        let wqkv = Matrix::randn(d, 3 * d, &mut rng);
        let wout = Matrix::randn(d, d, &mut rng);
        let tw_qkv = prune_tw(&wqkv, 0.5, 8, None);
        let tw_out = prune_tw(&wout, 0.5, 8, None);
        let plan_qkv = TwPlan::encode(&wqkv, &tw_qkv);
        let plan_out = TwPlan::encode(&wout, &tw_out);
        let mq = tw_qkv.mask().apply(&wqkv);
        let mo = tw_out.mask().apply(&wout);

        let via_tw = attention_forward(&x, &wqkv, &wout, 4, |a, b| {
            if b.cols == 3 * d {
                tw_matmul(a, &plan_qkv)
            } else {
                tw_matmul(a, &plan_out)
            }
        });
        let via_masked = attention_forward(&x, &mq, &mo, 4, |a, b| matmul(a, b));
        assert!(via_tw.max_abs_diff(&via_masked) < 1e-3);
    }
}
