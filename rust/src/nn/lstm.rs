//! LSTM cell (the NMT workload's compute): the four gates form one
//! `(batch, 2*hidden) x (2*hidden, 4*hidden)` GEMM per step — the matrix
//! the paper prunes for the NMT rows of Fig. 8/10/11.

use crate::gemm::matmul;
use crate::tensor::Matrix;
use crate::util::Rng;

/// Gate weight matrix in the GEMM orientation: rows = input ++ hidden
/// (K = 2H), cols = [i | f | g | o] gates (N = 4H).
pub struct LstmCell {
    pub hidden: usize,
    pub w: Matrix,
    pub bias: Vec<f32>,
}

/// Recurrent state (h, c), each `(batch, hidden)`.
#[derive(Clone)]
pub struct LstmState {
    pub h: Matrix,
    pub c: Matrix,
}

impl LstmState {
    pub fn zeros(batch: usize, hidden: usize) -> LstmState {
        LstmState { h: Matrix::zeros(batch, hidden), c: Matrix::zeros(batch, hidden) }
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl LstmCell {
    pub fn init(hidden: usize, rng: &mut Rng) -> LstmCell {
        LstmCell {
            hidden,
            w: Matrix::randn(2 * hidden, 4 * hidden, rng),
            bias: vec![0.0; 4 * hidden],
        }
    }

    /// One step with a custom GEMM (so pruned kernels can be dropped in).
    pub fn step_with<F>(&self, x: &Matrix, state: &LstmState, gemm: F) -> LstmState
    where
        F: Fn(&Matrix, &Matrix) -> Matrix,
    {
        let batch = x.rows;
        let hid = self.hidden;
        assert_eq!(x.cols, hid, "input width must equal hidden for this cell");
        // concat [x | h] -> (batch, 2H)
        let mut xh = Matrix::zeros(batch, 2 * hid);
        for i in 0..batch {
            xh.row_mut(i)[..hid].copy_from_slice(x.row(i));
            xh.row_mut(i)[hid..].copy_from_slice(state.h.row(i));
        }
        let gates = gemm(&xh, &self.w); // (batch, 4H)
        let mut next = LstmState::zeros(batch, hid);
        for i in 0..batch {
            let g = gates.row(i);
            for j in 0..hid {
                let ig = sigmoid(g[j] + self.bias[j]);
                let fg = sigmoid(g[hid + j] + self.bias[hid + j] + 1.0); // forget bias 1
                let cand = (g[2 * hid + j] + self.bias[2 * hid + j]).tanh();
                let og = sigmoid(g[3 * hid + j] + self.bias[3 * hid + j]);
                let c = fg * state.c.at(i, j) + ig * cand;
                *next.c.at_mut(i, j) = c;
                *next.h.at_mut(i, j) = og * c.tanh();
            }
        }
        next
    }

    pub fn step(&self, x: &Matrix, state: &LstmState) -> LstmState {
        self.step_with(x, state, |a, b| matmul(a, b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::tw_matmul;
    use crate::sparse::{prune_tw, TwPlan};

    #[test]
    fn state_stays_bounded() {
        let mut rng = Rng::new(20);
        let cell = LstmCell::init(16, &mut rng);
        let mut state = LstmState::zeros(4, 16);
        for _ in 0..50 {
            let x = Matrix::randn(4, 16, &mut rng);
            state = cell.step(&x, &state);
        }
        // h = o * tanh(c) is in (-1, 1)
        assert!(state.h.data.iter().all(|v| v.abs() < 1.0 && v.is_finite()));
        assert!(state.c.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn zero_input_zero_state_is_deterministic() {
        let mut rng = Rng::new(21);
        let cell = LstmCell::init(8, &mut rng);
        let x = Matrix::zeros(2, 8);
        let s1 = cell.step(&x, &LstmState::zeros(2, 8));
        let s2 = cell.step(&x, &LstmState::zeros(2, 8));
        assert_eq!(s1.h, s2.h);
        assert_eq!(s1.c, s2.c);
    }

    #[test]
    fn tw_pruned_cell_matches_masked_dense() {
        let mut rng = Rng::new(22);
        let cell = LstmCell::init(16, &mut rng);
        let tw = prune_tw(&cell.w, 0.5, 8, None);
        let plan = TwPlan::encode(&cell.w, &tw);
        let masked = tw.mask().apply(&cell.w);
        let x = Matrix::randn(4, 16, &mut rng);
        let state = LstmState::zeros(4, 16);
        let via_tw = cell.step_with(&x, &state, |a, _| tw_matmul(a, &plan));
        let via_masked = cell.step_with(&x, &state, |a, _| matmul(a, &masked));
        assert!(via_tw.h.max_abs_diff(&via_masked.h) < 1e-4);
        assert!(via_tw.c.max_abs_diff(&via_masked.c) < 1e-4);
    }

    #[test]
    fn gate_gemm_shape_matches_zoo() {
        // models::nmt lists (batch, 1024, 2048) for hidden=512
        let mut rng = Rng::new(23);
        let cell = LstmCell::init(512, &mut rng);
        assert_eq!(cell.w.rows, 1024);
        assert_eq!(cell.w.cols, 2048);
    }
}
