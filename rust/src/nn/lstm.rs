//! LSTM cell (the NMT workload's compute): the four gates form one
//! `(batch, 2*hidden) x (2*hidden, 4*hidden)` GEMM per step — the matrix
//! the paper prunes for the NMT rows of Fig. 8/10/11.
//!
//! The hot path is [`LstmCell::step_into`]: the `[x | h]` concat and the
//! gate pre-activations live in a caller-owned [`LstmScratch`] reused
//! across *every* step of the unroll (the historical [`LstmCell::step_with`]
//! rebuilt the concat matrix and the output state from scratch each step;
//! it remains as a thin shim).  The gate nonlinearity itself is exposed as
//! [`lstm_gate_update`] so the graph executor can run packed-weight gate
//! GEMMs and share the exact same update rule.

use crate::gemm::matmul;
use crate::tensor::Matrix;
use crate::util::Rng;

/// Gate weight matrix in the GEMM orientation: rows = input ++ hidden
/// (K = 2H), cols = [i | f | g | o] gates (N = 4H).
pub struct LstmCell {
    pub hidden: usize,
    pub w: Matrix,
    pub bias: Vec<f32>,
}

/// Recurrent state (h, c), each `(batch, hidden)`.
#[derive(Clone)]
pub struct LstmState {
    pub h: Matrix,
    pub c: Matrix,
}

impl LstmState {
    pub fn zeros(batch: usize, hidden: usize) -> LstmState {
        LstmState { h: Matrix::zeros(batch, hidden), c: Matrix::zeros(batch, hidden) }
    }
}

/// Reusable per-unroll scratch: the `[x | h]` concat `(batch, 2H)` and the
/// gate pre-activations `(batch, 4H)`.
pub struct LstmScratch {
    pub xh: Matrix,
    pub gates: Matrix,
}

impl LstmScratch {
    pub fn new(batch: usize, hidden: usize) -> LstmScratch {
        LstmScratch {
            xh: Matrix::zeros(batch, 2 * hidden),
            gates: Matrix::zeros(batch, 4 * hidden),
        }
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// The LSTM gate nonlinearity + state update, in place over `(h, c)`.
///
/// `gates` is the `(batch, 4H)` pre-activation GEMM output in
/// `[i | f | g | o]` order; `bias` is its `4H` bias vector (the forget
/// gate gets the customary +1 on top).  Shared by [`LstmCell::step_into`]
/// and the graph executor's `LstmStep` op.
pub fn lstm_gate_update(
    gates: &Matrix,
    bias: &[f32],
    hidden: usize,
    h: &mut Matrix,
    c: &mut Matrix,
) {
    let batch = gates.rows;
    let hid = hidden;
    assert_eq!(gates.cols, 4 * hid);
    assert_eq!(bias.len(), 4 * hid);
    assert_eq!((h.rows, h.cols), (batch, hid));
    assert_eq!((c.rows, c.cols), (batch, hid));
    for i in 0..batch {
        let g = gates.row(i);
        for j in 0..hid {
            let ig = sigmoid(g[j] + bias[j]);
            let fg = sigmoid(g[hid + j] + bias[hid + j] + 1.0); // forget bias 1
            let cand = (g[2 * hid + j] + bias[2 * hid + j]).tanh();
            let og = sigmoid(g[3 * hid + j] + bias[3 * hid + j]);
            let cv = fg * c.at(i, j) + ig * cand;
            *c.at_mut(i, j) = cv;
            *h.at_mut(i, j) = og * cv.tanh();
        }
    }
}

impl LstmCell {
    pub fn init(hidden: usize, rng: &mut Rng) -> LstmCell {
        LstmCell {
            hidden,
            w: Matrix::randn(2 * hidden, 4 * hidden, rng),
            bias: vec![0.0; 4 * hidden],
        }
    }

    /// One step, allocation-free: concat `[x | h]` into `ws.xh`, run
    /// `gemm(xh, gates)` (an in-place GEMM writing `ws.gates`), then update
    /// `state` in place.  `ws` is reused across the whole unroll.
    pub fn step_into<F>(&self, x: &Matrix, state: &mut LstmState, ws: &mut LstmScratch, gemm: F)
    where
        F: FnOnce(&Matrix, &mut Matrix),
    {
        let batch = x.rows;
        let hid = self.hidden;
        assert_eq!(x.cols, hid, "input width must equal hidden for this cell");
        assert_eq!((ws.xh.rows, ws.xh.cols), (batch, 2 * hid), "scratch sized for batch/hidden");
        for i in 0..batch {
            let row = ws.xh.row_mut(i);
            row[..hid].copy_from_slice(x.row(i));
            row[hid..].copy_from_slice(state.h.row(i));
        }
        gemm(&ws.xh, &mut ws.gates);
        lstm_gate_update(&ws.gates, &self.bias, hid, &mut state.h, &mut state.c);
    }

    /// One step with a custom GEMM (so pruned kernels can be dropped in).
    /// Back-compat shim over [`LstmCell::step_into`]: allocates a fresh
    /// scratch and next-state per call.
    pub fn step_with<F>(&self, x: &Matrix, state: &LstmState, gemm: F) -> LstmState
    where
        F: Fn(&Matrix, &Matrix) -> Matrix,
    {
        let mut next = state.clone();
        let mut ws = LstmScratch::new(x.rows, self.hidden);
        self.step_into(x, &mut next, &mut ws, |xh, gates| {
            let out = gemm(xh, &self.w);
            assert_eq!((out.rows, out.cols), (gates.rows, gates.cols), "gate GEMM shape");
            *gates = out;
        });
        next
    }

    pub fn step(&self, x: &Matrix, state: &LstmState) -> LstmState {
        self.step_with(x, state, |a, b| matmul(a, b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::tw_matmul;
    use crate::sparse::{prune_tw, TwPlan};

    #[test]
    fn state_stays_bounded() {
        let mut rng = Rng::new(20);
        let cell = LstmCell::init(16, &mut rng);
        let mut state = LstmState::zeros(4, 16);
        for _ in 0..50 {
            let x = Matrix::randn(4, 16, &mut rng);
            state = cell.step(&x, &state);
        }
        // h = o * tanh(c) is in (-1, 1)
        assert!(state.h.data.iter().all(|v| v.abs() < 1.0 && v.is_finite()));
        assert!(state.c.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn zero_input_zero_state_is_deterministic() {
        let mut rng = Rng::new(21);
        let cell = LstmCell::init(8, &mut rng);
        let x = Matrix::zeros(2, 8);
        let s1 = cell.step(&x, &LstmState::zeros(2, 8));
        let s2 = cell.step(&x, &LstmState::zeros(2, 8));
        assert_eq!(s1.h, s2.h);
        assert_eq!(s1.c, s2.c);
    }

    #[test]
    fn step_into_reuses_scratch_and_matches_step() {
        // the workspace path across a whole unroll equals the per-step
        // allocating shim exactly
        let mut rng = Rng::new(24);
        let cell = LstmCell::init(12, &mut rng);
        let xs: Vec<Matrix> = (0..6).map(|_| Matrix::randn(3, 12, &mut rng)).collect();
        let mut via_shim = LstmState::zeros(3, 12);
        for x in &xs {
            via_shim = cell.step(x, &via_shim);
        }
        let mut via_ws = LstmState::zeros(3, 12);
        let mut ws = LstmScratch::new(3, 12);
        for x in &xs {
            cell.step_into(x, &mut via_ws, &mut ws, |xh, gates| {
                crate::gemm::matmul_tiled_into(xh, &cell.w, gates, &Default::default());
            });
        }
        assert!(via_shim.h.max_abs_diff(&via_ws.h) < 1e-5);
        assert!(via_shim.c.max_abs_diff(&via_ws.c) < 1e-5);
    }

    #[test]
    fn tw_pruned_cell_matches_masked_dense() {
        let mut rng = Rng::new(22);
        let cell = LstmCell::init(16, &mut rng);
        let tw = prune_tw(&cell.w, 0.5, 8, None);
        let plan = TwPlan::encode(&cell.w, &tw);
        let masked = tw.mask().apply(&cell.w);
        let x = Matrix::randn(4, 16, &mut rng);
        let state = LstmState::zeros(4, 16);
        let via_tw = cell.step_with(&x, &state, |a, _| tw_matmul(a, &plan));
        let via_masked = cell.step_with(&x, &state, |a, _| matmul(a, &masked));
        assert!(via_tw.h.max_abs_diff(&via_masked.h) < 1e-4);
        assert!(via_tw.c.max_abs_diff(&via_masked.c) < 1e-4);
    }

    #[test]
    fn gate_gemm_shape_matches_zoo() {
        // models::nmt lists (batch, 1024, 2048) for hidden=512
        let mut rng = Rng::new(23);
        let cell = LstmCell::init(512, &mut rng);
        assert_eq!(cell.w.rows, 1024);
        assert_eq!(cell.w.cols, 2048);
    }
}
