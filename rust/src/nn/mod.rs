//! Neural-network substrate: the layer types the paper's model zoo is
//! built from, implemented so the GEMM workloads are *executable*, not
//! just shape lists — img2col convolution lowering (§II-A), an LSTM cell
//! (NMT), and scaled-dot-product attention (BERT), each routed through
//! the library's GEMM kernels so any sparsity pattern can be dropped in.

pub mod attention;
pub mod conv;
pub mod lstm;

pub use attention::attention_forward;
pub use conv::{conv2d, im2col, Conv2dSpec};
pub use lstm::{LstmCell, LstmState};
