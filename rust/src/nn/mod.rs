//! Neural-network substrate: the layer types the paper's model zoo is
//! built from, implemented so the GEMM workloads are *executable*, not
//! just shape lists — img2col convolution lowering (§II-A), an LSTM cell
//! (NMT), and scaled-dot-product attention (BERT), each routed through
//! the library's GEMM kernels so any sparsity pattern can be dropped in.
//!
//! Every operator has two entry points: a workspace-buffered `_into` core
//! (`attention_into`, `LstmCell::step_into`, `im2col_into`) that the
//! `graph` executor calls allocation-free, and the original closure-based
//! wrapper kept as a thin back-compat shim.

pub mod attention;
pub mod conv;
pub mod lstm;

pub use attention::{
    attention_causal_into, attention_forward, attention_forward_unbuffered, attention_into,
    attention_window_into, AttnScratch,
};
pub use conv::{conv2d, im2col, im2col_into, Conv2dSpec, ImgSrc};
pub use lstm::{lstm_gate_update, LstmCell, LstmScratch, LstmState};
