//! Fig. 8: accuracy vs sparsity for all six model/task pairs under every
//! pattern (surrogate magnitudes; mechanism validated by accuracy::proxy).

use super::Table;
use crate::accuracy::{accuracy, ModelFamily};
use crate::sparse::Pattern;

/// The paper's per-model granularity choice: G=64 for CNNs, 128 for
/// NMT/BERT; BW fixed at 16 (the §VI-B design-space conclusion).
pub fn model_granularity(family: ModelFamily) -> usize {
    match family {
        ModelFamily::Vgg16 | ModelFamily::Resnet18 | ModelFamily::Resnet50 => 64,
        _ => 128,
    }
}

pub fn families() -> Vec<ModelFamily> {
    vec![
        ModelFamily::Vgg16,
        ModelFamily::Resnet18,
        ModelFamily::Resnet50,
        ModelFamily::Nmt,
        ModelFamily::BertMnli,
        ModelFamily::BertSquad,
    ]
}

fn patterns(g: usize) -> Vec<(String, Pattern)> {
    vec![
        ("EW".into(), Pattern::Ew),
        ("VW-4".into(), Pattern::Vw { m: 4 }),
        ("VW-16".into(), Pattern::Vw { m: 16 }),
        ("BW-16".into(), Pattern::Bw { g: 16 }),
        (format!("TW-{g}"), Pattern::Tw { g }),
        (format!("TVW-4(G={g})"), Pattern::Tvw { g, m: 4 }),
        (format!("TVW-16(G={g})"), Pattern::Tvw { g, m: 16 }),
    ]
}

/// One sub-figure: accuracy curves for a model family.
pub fn fig8_model(family: ModelFamily) -> Table {
    let sp: Vec<f64> = vec![0.25, 0.5, 0.625, 0.75, 0.8125, 0.875, 0.9375];
    let g = model_granularity(family);
    let mut t = Table::new(
        "fig8",
        &format!("{} accuracy ({}) vs sparsity (surrogate)", family.label(), family.metric_name()),
        sp.iter().map(|s| format!("{:.1}%", s * 100.0)).collect(),
    );
    for (label, p) in patterns(g) {
        t.push(
            &label,
            sp.iter()
                .map(|&s| {
                    // TVW starts at 50% (hardware floor); VW points are fixed
                    match p {
                        Pattern::Tvw { .. } if s < 0.5 => f64::NAN,
                        Pattern::Vw { m: 4 } if (s - 0.5).abs() > 1e-9 => f64::NAN,
                        Pattern::Vw { m: 16 } if (s - 0.75).abs() > 1e-9 => f64::NAN,
                        _ => accuracy(family, &p, s),
                    }
                })
                .collect(),
        );
    }
    t
}

impl ModelFamily {
    pub fn metric_name(&self) -> &'static str {
        match self {
            ModelFamily::Nmt => "BLEU",
            ModelFamily::BertSquad => "F1",
            ModelFamily::BertMnli => "acc",
            _ => "top-5",
        }
    }
}

/// All six sub-figures.
pub fn fig8_all() -> Vec<Table> {
    families().into_iter().map(fig8_model).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_subfigures() {
        assert_eq!(fig8_all().len(), 6);
    }

    #[test]
    fn ew_best_everywhere() {
        for t in fig8_all() {
            let ew: Vec<f64> =
                t.rows.iter().find(|(l, _)| l == "EW").map(|(_, c)| c.clone()).unwrap();
            for (label, cells) in &t.rows {
                if label == "EW" {
                    continue;
                }
                for (i, (&e, &o)) in ew.iter().zip(cells).enumerate() {
                    if !o.is_nan() {
                        assert!(e >= o - 0.3, "{}: EW {e} < {label} {o} at col {i}", t.title);
                    }
                }
            }
        }
    }

    #[test]
    fn tvw16_beats_tw() {
        let t = fig8_model(ModelFamily::BertMnli);
        let get = |label: &str| {
            t.rows.iter().find(|(l, _)| l.starts_with(label)).map(|(_, c)| c.clone()).unwrap()
        };
        let tvw16 = get("TVW-16");
        let tw = get("TW-");
        // beyond 50%, TVW-16 dominates TW (paper §VI-C)
        for i in 1..tw.len() {
            if !tvw16[i].is_nan() {
                assert!(tvw16[i] >= tw[i], "col {i}: {} vs {}", tvw16[i], tw[i]);
            }
        }
    }

    #[test]
    fn collapse_past_75_for_structured() {
        let t = fig8_model(ModelFamily::BertMnli);
        let tw: Vec<f64> =
            t.rows.iter().find(|(l, _)| l.starts_with("TW-")).map(|(_, c)| c.clone()).unwrap();
        // columns: ..., 75% at idx 3, 93.75% at idx 6
        let drop_mid = ModelFamily::BertMnli.baseline() - tw[3];
        let drop_high = ModelFamily::BertMnli.baseline() - tw[6];
        assert!(drop_high > 3.0 * drop_mid);
    }
}
