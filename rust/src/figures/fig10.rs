//! Fig. 10 (tensor core) and Fig. 11 (CUDA core): the speedup-vs-accuracy
//! trade-off per model.  Speedups come from `gpusim` over the model zoo's
//! GEMM workloads; accuracies from the calibrated surrogate.

use super::{model_latency, LatencyPattern, Table};
use crate::accuracy::{accuracy, ModelFamily};
use crate::gpusim::{a100, Calibration, Pipe};
use crate::models::{bert_base, nmt, resnet18, resnet50, vgg16, ModelWorkload};
use crate::sparse::Pattern;

/// (family, workload) pairs of the evaluation; BERT serves two tasks.
pub fn eval_models() -> Vec<(ModelFamily, ModelWorkload)> {
    vec![
        (ModelFamily::Vgg16, vgg16()),
        (ModelFamily::Resnet18, resnet18()),
        (ModelFamily::Resnet50, resnet50()),
        (ModelFamily::Nmt, nmt(128)),
        (ModelFamily::BertMnli, bert_base(8, 128)),
        (ModelFamily::BertSquad, bert_base(8, 384)),
    ]
}

fn g_for(family: ModelFamily) -> usize {
    super::fig8::model_granularity(family)
}

/// Fig. 10, one model: rows = pattern, cols = (sparsity, speedup,
/// accuracy) triplets flattened over the sweep grid.  Speedup is vs the
/// dense model on the dense tensor core.
pub fn fig10_model(family: ModelFamily, workload: &ModelWorkload) -> Table {
    let specs = a100();
    let cal = Calibration::default();
    let g = g_for(family);
    let sweep = [0.5, 0.625, 0.75, 0.8125, 0.875];
    let mut cols = Vec::new();
    for s in sweep {
        cols.push(format!("spd@{:.0}%", s * 100.0));
        cols.push(format!("acc@{:.0}%", s * 100.0));
    }
    let mut t = Table::new(
        "fig10",
        &format!("{}: speedup (dense-TC baseline) vs accuracy on (S)TC", family.label()),
        cols,
    );
    let dense = model_latency(
        workload,
        |_| LatencyPattern::Dense(Pipe::TensorFp16),
        Pipe::TensorFp16,
        &specs,
        &cal,
    );

    let mut push_sweep = |label: &str, f: &dyn Fn(f64) -> (f64, f64)| {
        let mut cells = Vec::new();
        for &s in &sweep {
            let (lat, acc) = f(s);
            cells.push(if lat.is_nan() { f64::NAN } else { dense / lat });
            cells.push(acc);
        }
        t.push(label, cells);
    };

    push_sweep(&format!("TW-{g}"), &|s| {
        let lat = model_latency(
            workload,
            |_| LatencyPattern::Tw { g, pipe: Pipe::TensorFp16, sparsity: s },
            Pipe::TensorFp16,
            &specs,
            &cal,
        );
        (lat, accuracy(family, &Pattern::Tw { g }, s))
    });
    push_sweep(&format!("TVW-4(G={g})"), &|s| {
        let lat = model_latency(
            workload,
            |_| LatencyPattern::Tvw { g, sparsity: s },
            Pipe::TensorFp16,
            &specs,
            &cal,
        );
        (lat, accuracy(family, &Pattern::Tvw { g, m: 4 }, s))
    });
    push_sweep("BW-16", &|s| {
        let lat = model_latency(
            workload,
            |_| LatencyPattern::Bw { g: 16, sparsity: s },
            Pipe::TensorFp16,
            &specs,
            &cal,
        );
        (lat, accuracy(family, &Pattern::Bw { g: 16 }, s))
    });
    // fixed points
    let vw = model_latency(workload, |_| LatencyPattern::Vw4 { int8: false }, Pipe::TensorFp16, &specs, &cal);
    let vw_acc = accuracy(family, &Pattern::Vw { m: 4 }, 0.5);
    t.push("VW-4(STC)", vec![dense / vw, vw_acc, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN]);
    let i8d = model_latency(workload, |_| LatencyPattern::Int8Dense, Pipe::TensorInt8, &specs, &cal);
    t.push("Int8-Dense", vec![dense / i8d, family.baseline(), f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN]);
    let i8s = model_latency(workload, |_| LatencyPattern::Vw4 { int8: true }, Pipe::TensorInt8, &specs, &cal);
    t.push("Int8-Sparse", vec![dense / i8s, vw_acc, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN]);
    t
}

/// Fig. 11, one model: TW and EW on the CUDA core, vs dense CUDA.
pub fn fig11_model(family: ModelFamily, workload: &ModelWorkload) -> Table {
    let specs = a100();
    let cal = Calibration::default();
    let g = g_for(family);
    let sweep = [0.25, 0.5, 0.625, 0.75, 0.8125, 0.875];
    let mut cols = Vec::new();
    for s in sweep {
        cols.push(format!("spd@{:.0}%", s * 100.0));
        cols.push(format!("acc@{:.0}%", s * 100.0));
    }
    let mut t = Table::new(
        "fig11",
        &format!("{}: speedup (dense-CUDA baseline) vs accuracy on CUDA core", family.label()),
        cols,
    );
    let dense = model_latency(
        workload,
        |_| LatencyPattern::Dense(Pipe::CudaFp32),
        Pipe::CudaFp32,
        &specs,
        &cal,
    );
    let mut tw_cells = Vec::new();
    let mut ew_cells = Vec::new();
    for &s in &sweep {
        let tw = model_latency(
            workload,
            |_| LatencyPattern::Tw { g, pipe: Pipe::CudaFp32, sparsity: s },
            Pipe::CudaFp32,
            &specs,
            &cal,
        );
        tw_cells.push(dense / tw);
        tw_cells.push(accuracy(family, &Pattern::Tw { g }, s));
        let ew = {
            // EW latency scales with nnz; use ew_plan per layer at sparsity s
            let specs2 = &specs;
            let cal2 = &cal;
            let mut total = 0.0;
            for layer in &workload.layers {
                let lat = if layer.prunable {
                    crate::gpusim::ew_plan(layer.shape, s, specs2, cal2).latency(specs2)
                } else {
                    crate::gpusim::dense_plan(layer.shape, Pipe::CudaFp32, specs2, cal2)
                        .latency(specs2)
                };
                total += lat * layer.count as f64;
            }
            total
        };
        ew_cells.push(dense / ew);
        ew_cells.push(accuracy(family, &Pattern::Ew, s));
    }
    t.push(&format!("TW-{g}"), tw_cells);
    t.push("EW(cuSparse)", ew_cells);
    t
}

pub fn fig10_all() -> Vec<Table> {
    eval_models().into_iter().map(|(f, w)| fig10_model(f, &w)).collect()
}

pub fn fig11_all() -> Vec<Table> {
    eval_models().into_iter().map(|(f, w)| fig11_model(f, &w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_bert_pareto_extends() {
        let t = fig10_model(ModelFamily::BertMnli, &bert_base(8, 128));
        let row = |label_prefix: &str| {
            t.rows
                .iter()
                .find(|(l, _)| l.starts_with(label_prefix))
                .map(|(_, c)| c.clone())
                .unwrap()
        };
        let tw = row("TW-");
        // at 75% (index 4 = spd, 5 = acc): meaningful speedup, small drop
        assert!(tw[4] > 1.3, "TW speedup at 75%: {}", tw[4]);
        assert!(ModelFamily::BertMnli.baseline() - tw[5] < 4.0);
        // TVW keeps more accuracy than TW at every sparsity (less
        // constrained pattern) — the iso-accuracy Pareto advantage
        let tvw = row("TVW-4");
        for i in [1usize, 3, 5, 7, 9] {
            assert!(tvw[i] >= tw[i], "acc col {i}: TVW {} vs TW {}", tvw[i], tw[i]);
        }
        // BW slower than TW at iso-sparsity
        let bw = row("BW-16");
        assert!(bw[4] < tw[4]);
    }

    #[test]
    fn fig10_vw_point_shape_dependence() {
        // VW-4 speedup should be healthy on BERT but weak on CNNs (§VI-D)
        let bert = fig10_model(ModelFamily::BertMnli, &bert_base(8, 128));
        let r50 = fig10_model(ModelFamily::Resnet50, &resnet50());
        let vw_of = |t: &Table| {
            t.rows.iter().find(|(l, _)| l.starts_with("VW-4")).map(|(_, c)| c[0]).unwrap()
        };
        assert!(vw_of(&bert) > vw_of(&r50), "bert {} r50 {}", vw_of(&bert), vw_of(&r50));
    }

    #[test]
    fn fig11_tw_beats_ew() {
        let t = fig11_model(ModelFamily::BertMnli, &bert_base(8, 128));
        let tw = t.rows.iter().find(|(l, _)| l.starts_with("TW-")).map(|(_, c)| c.clone()).unwrap();
        let ew = t.rows.iter().find(|(l, _)| l.starts_with("EW")).map(|(_, c)| c.clone()).unwrap();
        // at 75%: TW >1x speedup, EW <1x (paper: EW cannot deliver speedups)
        assert!(tw[6] > 1.0, "TW at 75% on CUDA: {}", tw[6]);
        assert!(ew[6] < 1.0, "EW at 75% on CUDA: {}", ew[6]);
    }
}
