//! The paper's headline numbers (§I / §VI-D): geomean speedups at
//! iso-accuracy over the model zoo.
//!
//!   TVW vs dense-TC: 1.85x    TW vs dense-TC: 1.70x
//!   TVW vs BW:       2.75x    TW vs dense-CUDA: 2.43x
//!   TW vs EW (CUDA): 2.78x    TVW(TC) vs EW(CUDA): 22.18x

use super::fig10::eval_models;
use super::{model_latency, LatencyPattern, Table};
use crate::accuracy::{max_sparsity_within_tolerance, ModelFamily};
use crate::gpusim::{a100, ew_plan, Calibration, Pipe};
use crate::models::ModelWorkload;
use crate::sparse::Pattern;
use crate::util::geomean;

fn g_for(family: ModelFamily) -> usize {
    super::fig8::model_granularity(family)
}

/// Per-model iso-accuracy latencies for every execution mode.
struct ModelPoint {
    dense_tc: f64,
    dense_cuda: f64,
    tw_tc: f64,
    tvw_tc: f64,
    bw_tc: f64,
    tw_cuda: f64,
    ew_cuda: f64,
}

fn eval_one(family: ModelFamily, w: &ModelWorkload) -> ModelPoint {
    let specs = a100();
    let cal = Calibration::default();
    let g = g_for(family);
    // iso-accuracy operating sparsity per pattern (the paper's "<2% drop")
    let s_tw = max_sparsity_within_tolerance(family, &Pattern::Tw { g });
    let s_tvw = max_sparsity_within_tolerance(family, &Pattern::Tvw { g, m: 4 }).max(0.5);
    let s_bw = max_sparsity_within_tolerance(family, &Pattern::Bw { g: 16 });
    let s_ew = max_sparsity_within_tolerance(family, &Pattern::Ew);

    let dense_tc = model_latency(w, |_| LatencyPattern::Dense(Pipe::TensorFp16), Pipe::TensorFp16, &specs, &cal);
    let dense_cuda = model_latency(w, |_| LatencyPattern::Dense(Pipe::CudaFp32), Pipe::CudaFp32, &specs, &cal);
    let tw_tc = model_latency(
        w,
        |_| LatencyPattern::Tw { g, pipe: Pipe::TensorFp16, sparsity: s_tw },
        Pipe::TensorFp16,
        &specs,
        &cal,
    );
    let tvw_tc = model_latency(
        w,
        |_| LatencyPattern::Tvw { g, sparsity: s_tvw },
        Pipe::TensorFp16,
        &specs,
        &cal,
    );
    let bw_tc = model_latency(
        w,
        |_| LatencyPattern::Bw { g: 16, sparsity: s_bw },
        Pipe::TensorFp16,
        &specs,
        &cal,
    );
    let tw_cuda = model_latency(
        w,
        |_| LatencyPattern::Tw { g, pipe: Pipe::CudaFp32, sparsity: s_tw },
        Pipe::CudaFp32,
        &specs,
        &cal,
    );
    let ew_cuda = {
        let mut total = 0.0;
        for layer in &w.layers {
            let lat = if layer.prunable {
                ew_plan(layer.shape, s_ew, &specs, &cal).latency(&specs)
            } else {
                crate::gpusim::dense_plan(layer.shape, Pipe::CudaFp32, &specs, &cal).latency(&specs)
            };
            total += lat * layer.count as f64;
        }
        total
    };
    ModelPoint { dense_tc, dense_cuda, tw_tc, tvw_tc, bw_tc, tw_cuda, ew_cuda }
}

/// The headline summary table: per-model + geomean speedups, with the
/// paper's reported values alongside.
pub fn headline() -> Table {
    let mut t = Table::new(
        "headline",
        "iso-accuracy speedups (geomean row vs paper's reported averages)",
        vec![
            "TVW/denseTC".into(),
            "TW/denseTC".into(),
            "TVW/BW".into(),
            "TW/denseCUDA".into(),
            "TW/EW(CUDA)".into(),
            "TVW(TC)/EW(CUDA)".into(),
        ],
    );
    let mut cols: Vec<Vec<f64>> = vec![Vec::new(); 6];
    for (family, w) in eval_models() {
        let p = eval_one(family, &w);
        let row = vec![
            p.dense_tc / p.tvw_tc,
            p.dense_tc / p.tw_tc,
            p.bw_tc / p.tvw_tc,
            p.dense_cuda / p.tw_cuda,
            p.ew_cuda / p.tw_cuda,
            p.ew_cuda / p.tvw_tc,
        ];
        for (c, v) in cols.iter_mut().zip(&row) {
            c.push(*v);
        }
        t.push(family.label(), row);
    }
    t.push("GEOMEAN", cols.iter().map(|c| geomean(c)).collect());
    t.push("paper", vec![1.85, 1.70, 2.75, 2.43, 2.78, 22.18]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_directionally_matches_paper() {
        let t = headline();
        let geo = t.rows.iter().find(|(l, _)| l == "GEOMEAN").map(|(_, c)| c.clone()).unwrap();
        // TVW vs dense TC: paper 1.85 — require >1.2 and <3
        assert!(geo[0] > 1.2 && geo[0] < 3.5, "TVW/denseTC {}", geo[0]);
        // TW vs dense TC: paper 1.70
        assert!(geo[1] > 1.2 && geo[1] < 3.0, "TW/denseTC {}", geo[1]);
        // TVW vs BW: paper 2.75 — TVW must clearly win
        assert!(geo[2] > 1.5, "TVW/BW {}", geo[2]);
        // TW vs dense CUDA: paper 2.43
        assert!(geo[3] > 1.5, "TW/denseCUDA {}", geo[3]);
        // TW vs EW on CUDA: paper 2.78
        assert!(geo[4] > 1.5, "TW/EW {}", geo[4]);
        // cross-pipe TVW vs EW: paper 22.18 — order of magnitude
        assert!(geo[5] > 8.0, "TVW/EW {}", geo[5]);
    }

    #[test]
    fn ordering_tvw_geq_tw() {
        let t = headline();
        let geo = t.rows.iter().find(|(l, _)| l == "GEOMEAN").map(|(_, c)| c.clone()).unwrap();
        assert!(geo[0] >= geo[1] * 0.9, "TVW {} vs TW {}", geo[0], geo[1]);
    }
}
