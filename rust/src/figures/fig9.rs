//! Fig. 9: weight-sparsity distribution of the six patterns at 75%
//! sparsity on a BERT-like first-layer attention weight matrix — rendered
//! as text heatmaps plus the distribution statistics the paper reads off
//! the plots (irregularity, block variance).

use super::Table;
use crate::sparse::{mask_stats, render_heatmap, Mask, Pattern};
use crate::tensor::Matrix;
use crate::util::Rng;

/// Synthesize a BERT-omega_Q-like weight matrix: Gaussian weights with an
/// uneven column/row importance profile (attention heads differ in
/// magnitude), which is what makes EW/TW's adaptive allocation visible.
pub fn synth_bert_wq(dim: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut w = Matrix::randn(dim, dim, &mut rng);
    let heads = 12;
    let head_dim = dim / heads;
    for h in 0..heads {
        // head-level magnitude profile in [0.4, 1.8]
        let scale = 0.4 + 1.4 * ((h * 7919) % heads) as f32 / heads as f32;
        for r in 0..dim {
            for c in h * head_dim..(h + 1) * head_dim {
                *w.at_mut(r, c) *= scale;
            }
        }
    }
    w
}

pub fn patterns_at_75(w: &Matrix) -> Vec<(String, Mask)> {
    vec![
        ("EW".into(), Pattern::Ew.prune(w, 0.75)),
        ("VW-16".into(), Pattern::Vw { m: 16 }.prune(w, 0.75)),
        ("BW-64".into(), Pattern::Bw { g: 64 }.prune(w, 0.75)),
        ("TW-128".into(), Pattern::Tw { g: 128 }.prune(w, 0.75)),
        ("TVW-4".into(), Pattern::Tvw { g: 128, m: 4 }.prune(w, 0.75)),
        ("TVW-16".into(), {
            // TVW-16: TW + 4:16 inside tiles — approximate with TW(s') & VW-16
            let tw = crate::sparse::prune_tw(w, 0.0, 128, None);
            let _ = tw;
            let twm = Pattern::Tw { g: 128 }.prune(w, 0.5);
            let vw = Pattern::Vw { m: 16 }.prune(w, 0.5);
            twm.and(&vw)
        }),
    ]
}

/// The Fig. 9 statistics table: sparsity, block variance (uneven
/// distribution), irregularity per pattern.
pub fn fig9_stats() -> Table {
    let w = synth_bert_wq(768, 42);
    let mut t = Table::new(
        "fig9",
        "pattern distribution statistics @75% on synthetic BERT wQ (768x768)",
        vec!["sparsity".into(), "block_var".into(), "irregularity".into()],
    );
    for (label, mask) in patterns_at_75(&w) {
        let s = mask_stats(&mask, 32);
        t.push(&label, vec![s.sparsity, s.block_variance, s.irregularity]);
    }
    t
}

/// Render all six heatmaps (the visual part of Fig. 9).
pub fn fig9_heatmaps() -> String {
    let w = synth_bert_wq(768, 42);
    let mut out = String::new();
    for (label, mask) in patterns_at_75(&w) {
        out.push_str(&format!("--- {label} (kept-weight density, 24x24 blocks) ---\n"));
        out.push_str(&render_heatmap(&mask, 32));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_match_paper_reading() {
        let t = fig9_stats();
        let row = |label: &str| {
            t.rows.iter().find(|(l, _)| l == label).map(|(_, c)| c.clone()).unwrap()
        };
        let ew = row("EW");
        let vw16 = row("VW-16");
        let bw = row("BW-64");
        let tw = row("TW-128");
        // all near 75% sparsity
        for (label, cells) in &t.rows {
            if label.starts_with("TVW-16") {
                continue; // composed approximation sits near 75% but looser
            }
            assert!((cells[0] - 0.75).abs() < 0.05, "{label}: {}", cells[0]);
        }
        // EW shows uneven distribution; VW forces evenness (paper's reading)
        assert!(ew[1] > vw16[1], "EW var {} vs VW {}", ew[1], vw16[1]);
        // TW adapts to the uneven distribution better than VW
        assert!(tw[1] > vw16[1]);
        // BW is the least irregular, EW the most
        assert!(ew[2] > bw[2]);
    }

    #[test]
    fn heatmaps_render() {
        let text = fig9_heatmaps();
        let headers = text.lines().filter(|l| l.starts_with("--- ")).count();
        assert_eq!(headers, 6);
        assert!(text.lines().count() > 6 * 24);
    }
}
