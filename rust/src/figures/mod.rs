//! Figure-regeneration harnesses: one function per table/figure in the
//! paper's evaluation (§VI), emitting structured tables the CLI prints
//! and the benches record.  DESIGN.md §3 maps each figure to its modules.

pub mod fig10;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod headline;

use crate::gpusim::{
    bw_plan, dense_plan, ew_plan, tvw_latency, tw_latency, tw_uniform_tiles, vw24_plan,
    Calibration, GemmShape, GpuSpecs, Pipe, TwStrategy,
};
use crate::models::ModelWorkload;

/// A rendered figure: column headers + rows of (label, numeric cells).
#[derive(Clone, Debug)]
pub struct Table {
    pub id: &'static str,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
}

impl Table {
    pub fn new(id: &'static str, title: &str, columns: Vec<String>) -> Table {
        Table { id, title: title.to_string(), columns, rows: Vec::new() }
    }

    pub fn push(&mut self, label: &str, cells: Vec<f64>) {
        self.rows.push((label.to_string(), cells));
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut out = format!("== {} — {} ==\n", self.id, self.title);
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(8))
            .max()
            .unwrap_or(8)
            + 2;
        out.push_str(&format!("{:label_w$}", ""));
        for c in &self.columns {
            out.push_str(&format!("{c:>12}"));
        }
        out.push('\n');
        for (label, cells) in &self.rows {
            out.push_str(&format!("{label:label_w$}"));
            for v in cells {
                if v.is_nan() {
                    out.push_str(&format!("{:>12}", "-"));
                } else if v.abs() >= 1000.0 {
                    out.push_str(&format!("{v:>12.0}"));
                } else {
                    out.push_str(&format!("{v:>12.3}"));
                }
            }
            out.push('\n');
        }
        out
    }

    /// Render as CSV (for plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("label");
        for c in &self.columns {
            out.push(',');
            out.push_str(c);
        }
        out.push('\n');
        for (label, cells) in &self.rows {
            out.push_str(label);
            for v in cells {
                out.push(',');
                if v.is_nan() {
                    out.push_str("");
                } else {
                    out.push_str(&format!("{v}"));
                }
            }
            out.push('\n');
        }
        out
    }

    /// Serialise to the json module's value type.
    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::{arr, num, obj, s, Json};
        obj(vec![
            ("id", s(self.id)),
            ("title", s(&self.title)),
            ("columns", Json::Arr(self.columns.iter().map(|c| s(c)).collect())),
            (
                "rows",
                arr(self
                    .rows
                    .iter()
                    .map(|(l, cells)| {
                        obj(vec![
                            ("label", s(l)),
                            ("cells", Json::Arr(cells.iter().map(|&v| num(v)).collect())),
                        ])
                    })
                    .collect()),
            ),
        ])
    }
}

/// Pattern selector for model-level latency aggregation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LatencyPattern {
    Dense(Pipe),
    Vw4 { int8: bool },
    Bw { g: usize, sparsity: f64 },
    Ew,
    Tw { g: usize, pipe: Pipe, sparsity: f64 },
    Tvw { g: usize, sparsity: f64 },
    Int8Dense,
}

/// Simulated latency of one GEMM under a pattern.
pub fn gemm_latency(
    shape: GemmShape,
    pattern: LatencyPattern,
    specs: &GpuSpecs,
    cal: &Calibration,
) -> f64 {
    match pattern {
        LatencyPattern::Dense(pipe) => dense_plan(shape, pipe, specs, cal).latency(specs),
        LatencyPattern::Int8Dense => dense_plan(shape, Pipe::TensorInt8, specs, cal).latency(specs),
        LatencyPattern::Vw4 { int8 } => vw24_plan(shape, int8, specs, cal).latency(specs),
        LatencyPattern::Bw { g, sparsity } => bw_plan(shape, sparsity, g, specs, cal).latency(specs),
        LatencyPattern::Ew => ew_plan(shape, 0.0, specs, cal).latency(specs),
        LatencyPattern::Tw { g, pipe, sparsity } => {
            let tiles = tw_uniform_tiles(shape, sparsity, g);
            tw_latency(shape, &tiles, g, pipe, TwStrategy::FusedCto, specs, cal)
        }
        LatencyPattern::Tvw { g, sparsity } => {
            let s_tw = (1.0 - 2.0 * (1.0 - sparsity)).max(0.0);
            let tiles = tw_uniform_tiles(shape, s_tw, g);
            tvw_latency(shape, &tiles, g, specs, cal)
        }
    }
}

/// Simulated latency of a whole model: prunable layers use `pattern` (at
/// `sparsity` where applicable), non-prunable layers stay dense on
/// `dense_pipe` (the paper keeps first convs dense).
pub fn model_latency(
    model: &ModelWorkload,
    pattern: impl Fn(GemmShape) -> LatencyPattern,
    dense_pipe: Pipe,
    specs: &GpuSpecs,
    cal: &Calibration,
) -> f64 {
    let mut total = 0.0;
    for layer in &model.layers {
        let lat = if layer.prunable {
            gemm_latency(layer.shape, pattern(layer.shape), specs, cal)
        } else {
            dense_plan(layer.shape, dense_pipe, specs, cal).latency(specs)
        };
        total += lat * layer.count as f64;
    }
    total
}

/// Sparsity at which a model-level pattern is evaluated by Fig. 10/11:
/// highest sparsity within the iso-accuracy tolerance (the paper's "<2%
/// accuracy drop" comparison).
pub fn sparsity_grid() -> Vec<f64> {
    vec![0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.8125, 0.875, 0.9375]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::a100;
    use crate::models::bert_base;

    #[test]
    fn table_renders_and_roundtrips_csv() {
        let mut t = Table::new("test", "demo", vec!["a".into(), "b".into()]);
        t.push("row1", vec![1.0, f64::NAN]);
        let txt = t.render();
        assert!(txt.contains("row1"));
        let csv = t.to_csv();
        assert!(csv.starts_with("label,a,b"));
        assert!(crate::json::Json::parse(&t.to_json().to_string()).is_ok());
    }

    #[test]
    fn model_latency_tw_beats_dense_at_75() {
        let specs = a100();
        let cal = Calibration::default();
        let bert = bert_base(8, 128);
        let dense = model_latency(&bert, |_| LatencyPattern::Dense(Pipe::TensorFp16),
                                  Pipe::TensorFp16, &specs, &cal);
        let tw = model_latency(
            &bert,
            |_| LatencyPattern::Tw { g: 128, pipe: Pipe::TensorFp16, sparsity: 0.75 },
            Pipe::TensorFp16,
            &specs,
            &cal,
        );
        assert!(tw < dense, "tw {tw} dense {dense}");
        assert!(dense / tw > 1.5, "speedup {}", dense / tw);
    }
}
