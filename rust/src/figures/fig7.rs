//! Fig. 7: the TEW hybrid — (a) accuracy for delta in {1, 5, 10}% vs EW
//! and TW; (b) latency (tensor core + CUDA core) and accuracy of the
//! 75%-sparse BERT model as delta varies, normalized to dense-on-CUDA.

use super::Table;
use crate::accuracy::{accuracy, ModelFamily};
use crate::gpusim::{
    dense_plan, tew_latency, tw_latency, tw_uniform_tiles, Calibration, GemmShape, Pipe,
    TwStrategy,
};
use crate::sparse::Pattern;

const SHAPE: GemmShape = GemmShape { m: 4096, k: 4096, n: 4096 };

/// Fig. 7a: accuracy vs sparsity for EW, TW, TEW-{1,5,10}% (surrogate).
pub fn fig7a() -> Table {
    let sp: Vec<f64> = (0..=9).map(|i| i as f64 * 0.1).collect();
    let mut t = Table::new(
        "fig7a",
        "BERT accuracy: TEW delta sweep (surrogate)",
        sp.iter().map(|s| format!("{:.0}%", s * 100.0)).collect(),
    );
    let fam = ModelFamily::BertMnli;
    t.push("EW", sp.iter().map(|&s| accuracy(fam, &Pattern::Ew, s)).collect());
    t.push("TW-128", sp.iter().map(|&s| accuracy(fam, &Pattern::Tw { g: 128 }, s)).collect());
    for d in [1u8, 5, 10] {
        t.push(
            &format!("TEW-{d}%"),
            sp.iter()
                .map(|&s| accuracy(fam, &Pattern::Tew { g: 128, delta_pct: d }, s))
                .collect(),
        );
    }
    t
}

/// Fig. 7b: latency of dense / TW / TEW(delta) at fixed 75% sparsity on
/// both pipes, all normalized to the dense model on the CUDA core, plus
/// the accuracy row.
pub fn fig7b() -> Table {
    let specs = crate::gpusim::a100();
    let cal = Calibration::default();
    let mut t = Table::new(
        "fig7b",
        "75%-sparse BERT: latency (normalized to dense CUDA) & accuracy vs delta",
        vec!["lat-TC".into(), "lat-CUDA".into(), "accuracy".into()],
    );
    let dense_cuda = dense_plan(SHAPE, Pipe::CudaFp32, &specs, &cal).latency(&specs);
    let dense_tc = dense_plan(SHAPE, Pipe::TensorFp16, &specs, &cal).latency(&specs);
    let fam = ModelFamily::BertMnli;
    let s = 0.75;

    t.push("Dense", vec![dense_tc / dense_cuda, 1.0, fam.baseline()]);
    let tiles = tw_uniform_tiles(SHAPE, s, 128);
    let tw_tc =
        tw_latency(SHAPE, &tiles, 128, Pipe::TensorFp16, TwStrategy::FusedCto, &specs, &cal);
    let tw_cuda =
        tw_latency(SHAPE, &tiles, 128, Pipe::CudaFp32, TwStrategy::FusedCto, &specs, &cal);
    t.push(
        "TW-128",
        vec![tw_tc / dense_cuda, tw_cuda / dense_cuda, accuracy(fam, &Pattern::Tw { g: 128 }, s)],
    );
    for d in [1u8, 2, 5, 10] {
        let delta = d as f64 / 100.0;
        // at fixed total sparsity, the TW part carries s + delta
        let tew_tiles = tw_uniform_tiles(SHAPE, (s + delta).min(0.99), 128);
        let tc = tew_latency(SHAPE, &tew_tiles, 128, delta, Pipe::TensorFp16, &specs, &cal);
        let cuda = tew_latency(SHAPE, &tew_tiles, 128, delta, Pipe::CudaFp32, &specs, &cal);
        t.push(
            &format!("TEW-{d}%"),
            vec![
                tc / dense_cuda,
                cuda / dense_cuda,
                accuracy(fam, &Pattern::Tew { g: 128, delta_pct: d }, s),
            ],
        );
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7a_delta_recovers_accuracy() {
        let t = fig7a();
        let at = |label: &str, i: usize| {
            t.rows.iter().find(|(l, _)| l == label).map(|(_, c)| c[i]).unwrap()
        };
        // at 80% sparsity: TEW-1 < TEW-5 <= ~EW <= TEW-10 ordering
        assert!(at("TEW-1%", 8) < at("TEW-5%", 8));
        assert!(at("TEW-5%", 8) <= at("EW", 8) + 0.5);
        assert!(at("TEW-10%", 8) >= at("EW", 8) - 0.1);
        assert!(at("TW-128", 8) < at("TEW-1%", 8));
    }

    #[test]
    fn fig7b_paper_shape() {
        let t = fig7b();
        let row = |label: &str| {
            t.rows.iter().find(|(l, _)| l == label).map(|(_, c)| c.clone()).unwrap()
        };
        let dense = row("Dense");
        let tw = row("TW-128");
        let tew1 = row("TEW-1%");
        let tew10 = row("TEW-10%");
        // TW on TC is ~3x faster than dense TC (paper: 2.98x)
        let tw_speedup = dense[0] / tw[0];
        assert!(tw_speedup > 2.0 && tw_speedup < 4.5, "TW speedup {tw_speedup}");
        // TEW latency grows with delta; TEW-1% loses (most of) TW's gain
        assert!(tew1[0] > tw[0]);
        assert!(tew10[0] > tew1[0]);
        // on CUDA cores only, TEW-1% still beats the dense model (paper: ~2x)
        assert!(tew1[1] < 1.0, "TEW-1% on CUDA: {}", tew1[1]);
        // accuracy column increases with delta
        assert!(tew10[2] > tew1[2]);
    }
}
