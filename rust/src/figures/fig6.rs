//! Fig. 6: normalized latency (a: tensor core, b: CUDA core) of the
//! 4096x4096x4096 GEMM across patterns and sparsities, and (c) accuracy
//! vs sparsity under different pruning granularities (BERT-MNLI).

use super::Table;
use crate::accuracy::{accuracy, ModelFamily};
use crate::gpusim::{
    bw_plan, dense_plan, ew_plan, tvw_latency, tw_latency, tw_uniform_tiles, vw24_plan,
    Calibration, GemmShape, Pipe, TwStrategy,
};
use crate::sparse::Pattern;

const SHAPE: GemmShape = GemmShape { m: 4096, k: 4096, n: 4096 };

fn sparsities() -> Vec<f64> {
    (0..=18).map(|i| i as f64 * 0.05).collect()
}

/// Fig. 6a: tensor-core latency, normalized to the dense tensor core.
pub fn fig6a() -> Table {
    let specs = crate::gpusim::a100();
    let cal = Calibration::default();
    let sp = sparsities();
    let mut t = Table::new(
        "fig6a",
        "4096^3 GEMM normalized latency on (sparse) tensor core",
        sp.iter().map(|s| format!("{:.0}%", s * 100.0)).collect(),
    );
    let dense = dense_plan(SHAPE, Pipe::TensorFp16, &specs, &cal).latency(&specs);
    t.push("Dense-DTC", sp.iter().map(|_| 1.0).collect());
    t.push(
        "VW-4(STC)",
        sp.iter()
            .map(|&s| {
                // fixed 50% sparsity: defined only at s = 0.5
                if (s - 0.5).abs() < 1e-9 {
                    vw24_plan(SHAPE, false, &specs, &cal).latency(&specs) / dense
                } else {
                    f64::NAN
                }
            })
            .collect(),
    );
    for g in [16usize, 32] {
        t.push(
            &format!("BW-{g}"),
            sp.iter()
                .map(|&s| bw_plan(SHAPE, s, g, &specs, &cal).latency(&specs) / dense)
                .collect(),
        );
    }
    for g in [64usize, 128] {
        t.push(
            &format!("TW-{g}"),
            sp.iter()
                .map(|&s| {
                    let tiles = tw_uniform_tiles(SHAPE, s, g);
                    tw_latency(SHAPE, &tiles, g, Pipe::TensorFp16, TwStrategy::FusedCto, &specs, &cal)
                        / dense
                })
                .collect(),
        );
    }
    t.push(
        "TVW-4(G=128)",
        sp.iter()
            .map(|&s| {
                if s < 0.5 {
                    f64::NAN
                } else {
                    let tiles = tw_uniform_tiles(SHAPE, 1.0 - 2.0 * (1.0 - s), 128);
                    tvw_latency(SHAPE, &tiles, 128, &specs, &cal) / dense
                }
            })
            .collect(),
    );
    t.push(
        "Int8-Dense",
        sp.iter()
            .map(|_| dense_plan(SHAPE, Pipe::TensorInt8, &specs, &cal).latency(&specs) / dense)
            .collect(),
    );
    t.push(
        "Int8-VW4",
        sp.iter()
            .map(|&s| {
                if (s - 0.5).abs() < 1e-9 {
                    vw24_plan(SHAPE, true, &specs, &cal).latency(&specs) / dense
                } else {
                    f64::NAN
                }
            })
            .collect(),
    );
    t
}

/// Fig. 6b: CUDA-core latency, normalized to the dense CUDA core; the DTC
/// row shows the dense tensor core on the same scale (the ~9.7x gap).
pub fn fig6b() -> Table {
    let specs = crate::gpusim::a100();
    let cal = Calibration::default();
    let sp = sparsities();
    let mut t = Table::new(
        "fig6b",
        "4096^3 GEMM normalized latency on CUDA core",
        sp.iter().map(|s| format!("{:.0}%", s * 100.0)).collect(),
    );
    let dense = dense_plan(SHAPE, Pipe::CudaFp32, &specs, &cal).latency(&specs);
    t.push("Dense-CUDA", sp.iter().map(|_| 1.0).collect());
    t.push(
        "EW(cuSparse)",
        sp.iter().map(|&s| ew_plan(SHAPE, s, &specs, &cal).latency(&specs) / dense).collect(),
    );
    for g in [64usize, 128] {
        t.push(
            &format!("TW-{g}"),
            sp.iter()
                .map(|&s| {
                    let tiles = tw_uniform_tiles(SHAPE, s, g);
                    tw_latency(SHAPE, &tiles, g, Pipe::CudaFp32, TwStrategy::FusedCto, &specs, &cal)
                        / dense
                })
                .collect(),
        );
    }
    let dtc = dense_plan(SHAPE, Pipe::TensorFp16, &specs, &cal).latency(&specs);
    t.push("Dense-DTC(ref)", sp.iter().map(|_| dtc / dense).collect());
    t
}

/// Fig. 6c: accuracy vs sparsity under different granularities on
/// BERT-MNLI (surrogate model; the proxy validation lives in
/// `accuracy::proxy` and examples/prune_model.rs).
pub fn fig6c() -> Table {
    let sp: Vec<f64> = (0..=9).map(|i| i as f64 * 0.1).collect();
    let mut t = Table::new(
        "fig6c",
        "BERT-MNLI accuracy vs sparsity by granularity (surrogate)",
        sp.iter().map(|s| format!("{:.0}%", s * 100.0)).collect(),
    );
    let fam = ModelFamily::BertMnli;
    let patterns: Vec<(String, Pattern)> = vec![
        ("EW".into(), Pattern::Ew),
        ("BW-32".into(), Pattern::Bw { g: 32 }),
        ("BW-64".into(), Pattern::Bw { g: 64 }),
        ("TW-32".into(), Pattern::Tw { g: 32 }),
        ("TW-64".into(), Pattern::Tw { g: 64 }),
        ("TW-128".into(), Pattern::Tw { g: 128 }),
    ];
    for (label, p) in patterns {
        t.push(&label, sp.iter().map(|&s| accuracy(fam, &p, s)).collect());
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6a_paper_shape() {
        let t = fig6a();
        let get = |label: &str| {
            t.rows.iter().find(|(l, _)| l == label).map(|(_, c)| c.clone()).unwrap()
        };
        let tw128 = get("TW-128");
        // crossover near 10%: slower than dense at 5%, faster at 20%
        assert!(tw128[1] > 1.0, "TW-128 at 5%: {}", tw128[1]);
        assert!(tw128[4] < 1.0, "TW-128 at 20%: {}", tw128[4]);
        // VW-4 fixed point ~ 1/1.67
        let vw = get("VW-4(STC)");
        assert!((vw[10] - 1.0 / 1.67).abs() < 0.1, "VW point {}", vw[10]);
        // BW-16 crosses later than BW-32
        let bw16 = get("BW-16");
        let bw32 = get("BW-32");
        let cross = |c: &Vec<f64>| c.iter().position(|&v| v < 1.0).unwrap_or(usize::MAX);
        assert!(cross(&bw16) > cross(&bw32));
    }

    #[test]
    fn fig6b_paper_shape() {
        let t = fig6b();
        let get = |label: &str| {
            t.rows.iter().find(|(l, _)| l == label).map(|(_, c)| c.clone()).unwrap()
        };
        // DTC reference ~ 1/9.7 of dense CUDA
        let dtc = get("Dense-DTC(ref)");
        assert!((dtc[0] - 1.0 / 9.7).abs() < 0.03, "DTC ref {}", dtc[0]);
        // EW needs >95% to beat dense: still slower at 90%
        let ew = get("EW(cuSparse)");
        assert!(ew[18] > 1.0, "EW at 90% should still be above dense: {}", ew[18]);
        assert!(ew[14] > 1.0, "EW at 70% should be above dense: {}", ew[14]);
        // TW crossover earlier on CUDA (~5%)
        let tw128 = get("TW-128");
        assert!(tw128[2] < 1.0, "TW-128 at 10% on CUDA: {}", tw128[2]);
    }

    #[test]
    fn fig6c_granularity_ordering() {
        let t = fig6c();
        let at75 = |label: &str| {
            t.rows.iter().find(|(l, _)| l == label).map(|(_, c)| c[7]).unwrap()
        };
        assert!(at75("EW") > at75("TW-128"));
        assert!(at75("TW-32") > at75("TW-128")); // smaller G = better accuracy
        assert!(at75("TW-128") > at75("BW-32"));
        assert!(at75("BW-32") > at75("BW-64"));
    }
}
