//! Per-node graph profiling: the Fig. 10 attribution machinery.
//!
//! A [`Telemetry`] handle owns one [`VariantProfile`] per registered
//! graph program.  The executor (`graph::execute_with`) records wall
//! time per op *kind* plus, for every GEMM-backed op, per *node*: call
//! count, nanoseconds, rows processed, FLOPs, and the dispatch that
//! actually ran (effective batch M, the bucket-selected `TileConfig`,
//! effective intra-op threads).  All counters are atomics sized at
//! registration, so recording is lock-free and the profile adds no
//! allocation to the serving path.
//!
//! Attribution contract: summing the per-op-kind times reproduces the
//! end-to-end forward within the ISSUE's 20% bound.  `LstmStep` op time
//! *includes* its internal gate GEMM (the node counters record that
//! GEMM separately), so coverage sums op kinds only — never op kinds
//! plus nodes, which would double-count recurrent models.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

use crate::graph::{GraphProgram, Op, PackedWeight};
use crate::json::{arr, num, obj, s, Json};

/// Executable op categories, mirroring `graph::Op`'s variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Gemm,
    BiasAct,
    Attention,
    Im2col,
    AvgPool2,
    GlobalAvgPool,
    Flatten,
    LstmStep,
    Residual,
    LayerNorm,
    MeanPool,
    LastPool,
    DecodeAttend,
    Zero,
}

/// Number of [`OpKind`] categories (counter-array size).
pub const OP_KINDS: usize = 14;

impl OpKind {
    pub const ALL: [OpKind; OP_KINDS] = [
        OpKind::Gemm,
        OpKind::BiasAct,
        OpKind::Attention,
        OpKind::Im2col,
        OpKind::AvgPool2,
        OpKind::GlobalAvgPool,
        OpKind::Flatten,
        OpKind::LstmStep,
        OpKind::Residual,
        OpKind::LayerNorm,
        OpKind::MeanPool,
        OpKind::LastPool,
        OpKind::DecodeAttend,
        OpKind::Zero,
    ];

    pub fn index(self) -> usize {
        match self {
            OpKind::Gemm => 0,
            OpKind::BiasAct => 1,
            OpKind::Attention => 2,
            OpKind::Im2col => 3,
            OpKind::AvgPool2 => 4,
            OpKind::GlobalAvgPool => 5,
            OpKind::Flatten => 6,
            OpKind::LstmStep => 7,
            OpKind::Residual => 8,
            OpKind::LayerNorm => 9,
            OpKind::MeanPool => 10,
            OpKind::LastPool => 11,
            OpKind::DecodeAttend => 12,
            OpKind::Zero => 13,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            OpKind::Gemm => "gemm",
            OpKind::BiasAct => "bias_act",
            OpKind::Attention => "attention",
            OpKind::Im2col => "im2col",
            OpKind::AvgPool2 => "avg_pool2",
            OpKind::GlobalAvgPool => "global_avg_pool",
            OpKind::Flatten => "flatten",
            OpKind::LstmStep => "lstm_step",
            OpKind::Residual => "residual",
            OpKind::LayerNorm => "layer_norm",
            OpKind::MeanPool => "mean_pool",
            OpKind::LastPool => "last_pool",
            OpKind::DecodeAttend => "decode_attend",
            OpKind::Zero => "zero",
        }
    }

    pub fn of(op: &Op) -> OpKind {
        match op {
            Op::Gemm { .. } => OpKind::Gemm,
            Op::BiasAct { .. } => OpKind::BiasAct,
            Op::Attention { .. } => OpKind::Attention,
            Op::Im2col { .. } => OpKind::Im2col,
            Op::AvgPool2 { .. } => OpKind::AvgPool2,
            Op::GlobalAvgPool { .. } => OpKind::GlobalAvgPool,
            Op::Flatten { .. } => OpKind::Flatten,
            Op::LstmStep { .. } => OpKind::LstmStep,
            Op::Residual { .. } => OpKind::Residual,
            Op::LayerNorm { .. } => OpKind::LayerNorm,
            Op::MeanPool { .. } => OpKind::MeanPool,
            Op::LastPool { .. } => OpKind::LastPool,
            Op::DecodeAttend { .. } => OpKind::DecodeAttend,
            Op::Zero { .. } => OpKind::Zero,
        }
    }
}

fn family_label(w: &PackedWeight) -> &'static str {
    match w {
        PackedWeight::Dense(_) => "dense",
        PackedWeight::Tw(_) => "tw",
        PackedWeight::Tvw(_) => "tvw",
        PackedWeight::Vw24(_) => "vw24",
        PackedWeight::Int8Dense(_) => "dense-i8",
        PackedWeight::Int8Tw(_) => "tw-i8",
        PackedWeight::Int8Tvw(_) => "tvw-i8",
        PackedWeight::Int8Vw24(_) => "vw24-i8",
    }
}

/// Lock-free counters for one GEMM node (one `GraphProgram::weights`
/// slot), pre-sized at registration so the hot path only does
/// `fetch_add`s.
pub struct NodeProfile {
    pub name: String,
    pub family: &'static str,
    pub k: usize,
    pub n: usize,
    calls: AtomicU64,
    nanos: AtomicU64,
    rows: AtomicU64,
    flops: AtomicU64,
    /// Bytes moved through memory per dispatch at the node's storage
    /// precision: A reads (i8 or f32) + packed weight bytes + C writes.
    bytes: AtomicU64,
    last_m: AtomicUsize,
    last_bm: AtomicUsize,
    last_bk: AtomicUsize,
    last_threads: AtomicUsize,
    /// Packed `gemm::micro::Resolved` code of the last dispatch's
    /// microkernel (`gemm::micro::describe` renders it).
    last_micro: AtomicUsize,
    /// [`crate::graph::EpilogueSpec::kind_code`] of the last dispatch's
    /// fused epilogue (0 = bare GEMM; `gemm::epilogue_label` renders it).
    last_epilogue: AtomicUsize,
    /// Memory traffic the fused epilogue avoided versus running the
    /// elementwise tail as separate passes (cumulative, like `bytes`).
    bytes_avoided: AtomicU64,
}

impl NodeProfile {
    fn new(name: &str, family: &'static str, k: usize, n: usize) -> NodeProfile {
        NodeProfile {
            name: name.to_string(),
            family,
            k,
            n,
            calls: AtomicU64::new(0),
            nanos: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            flops: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            last_m: AtomicUsize::new(0),
            last_bm: AtomicUsize::new(0),
            last_bk: AtomicUsize::new(0),
            last_threads: AtomicUsize::new(0),
            last_micro: AtomicUsize::new(0),
            last_epilogue: AtomicUsize::new(0),
            bytes_avoided: AtomicU64::new(0),
        }
    }

    /// Record one kernel dispatch on this node.  `micro` is the packed
    /// [`crate::gemm::micro::Resolved::code`] of the inner loops that ran;
    /// `epilogue` is the fused epilogue's kind code (0 when unfused) and
    /// `avoided` the memory traffic that fusion saved for this dispatch.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &self,
        m: usize,
        nanos: u64,
        flops: u64,
        bytes: u64,
        bm: usize,
        bk: usize,
        threads: usize,
        micro: usize,
        epilogue: usize,
        avoided: u64,
    ) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.nanos.fetch_add(nanos, Ordering::Relaxed);
        self.rows.fetch_add(m as u64, Ordering::Relaxed);
        self.flops.fetch_add(flops, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.last_m.store(m, Ordering::Relaxed);
        self.last_bm.store(bm, Ordering::Relaxed);
        self.last_bk.store(bk, Ordering::Relaxed);
        self.last_threads.store(threads, Ordering::Relaxed);
        self.last_micro.store(micro, Ordering::Relaxed);
        self.last_epilogue.store(epilogue, Ordering::Relaxed);
        self.bytes_avoided.fetch_add(avoided, Ordering::Relaxed);
    }

    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    pub fn secs(&self) -> f64 {
        self.nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn rows(&self) -> u64 {
        self.rows.load(Ordering::Relaxed)
    }

    pub fn flops(&self) -> u64 {
        self.flops.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Achieved effective memory bandwidth (GB/s) over recorded time.
    pub fn gbps(&self) -> f64 {
        let secs = self.secs();
        if secs > 0.0 {
            self.bytes() as f64 / secs / 1e9
        } else {
            0.0
        }
    }

    /// Achieved GFLOP/s over this node's recorded time.
    pub fn gflops(&self) -> f64 {
        let secs = self.secs();
        if secs > 0.0 {
            self.flops() as f64 / secs / 1e9
        } else {
            0.0
        }
    }

    /// `(m, bm, bk, threads)` of the most recent dispatch.
    pub fn last_dispatch(&self) -> (usize, usize, usize, usize) {
        (
            self.last_m.load(Ordering::Relaxed),
            self.last_bm.load(Ordering::Relaxed),
            self.last_bk.load(Ordering::Relaxed),
            self.last_threads.load(Ordering::Relaxed),
        )
    }

    /// Microkernel label of the most recent dispatch (e.g. "avx2 4x16"
    /// or "scalar"); "scalar" before any dispatch.
    pub fn last_micro(&self) -> String {
        crate::gemm::micro::describe(self.last_micro.load(Ordering::Relaxed))
    }

    /// Fused-epilogue label of the most recent dispatch (e.g.
    /// "bias+relu+res"); "-" for a bare GEMM or before any dispatch.
    pub fn last_epilogue(&self) -> String {
        crate::gemm::epilogue_label(self.last_epilogue.load(Ordering::Relaxed))
    }

    /// Cumulative memory traffic avoided by epilogue fusion.
    pub fn bytes_avoided(&self) -> u64 {
        self.bytes_avoided.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
        self.nanos.store(0, Ordering::Relaxed);
        self.rows.store(0, Ordering::Relaxed);
        self.flops.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
        self.last_m.store(0, Ordering::Relaxed);
        self.last_bm.store(0, Ordering::Relaxed);
        self.last_bk.store(0, Ordering::Relaxed);
        self.last_threads.store(0, Ordering::Relaxed);
        self.last_micro.store(0, Ordering::Relaxed);
        self.last_epilogue.store(0, Ordering::Relaxed);
        self.bytes_avoided.store(0, Ordering::Relaxed);
    }

    fn to_json(&self) -> Json {
        let (m, bm, bk, threads) = self.last_dispatch();
        obj(vec![
            ("name", s(&self.name)),
            ("family", s(self.family)),
            ("k", num(self.k as f64)),
            ("n", num(self.n as f64)),
            ("calls", num(self.calls() as f64)),
            ("secs", num(self.secs())),
            ("rows", num(self.rows() as f64)),
            ("flops", num(self.flops() as f64)),
            ("gflops", num(self.gflops())),
            ("bytes", num(self.bytes() as f64)),
            ("gbps", num(self.gbps())),
            ("last_m", num(m as f64)),
            ("last_bm", num(bm as f64)),
            ("last_bk", num(bk as f64)),
            ("last_threads", num(threads as f64)),
            ("micro", s(&self.last_micro())),
            ("epilogue", s(&self.last_epilogue())),
            ("bytes_avoided", num(self.bytes_avoided() as f64)),
        ])
    }
}

/// Profiling counters for one graph program (one serving variant):
/// per-op-kind wall time plus one [`NodeProfile`] per weight slot,
/// index-aligned with `GraphProgram::weights`.
pub struct VariantProfile {
    pub model: String,
    pub variant: String,
    op_calls: Vec<AtomicU64>,
    op_nanos: Vec<AtomicU64>,
    pub nodes: Vec<NodeProfile>,
    /// Whole-forward invocations and nanoseconds (`execute` entry/exit).
    forwards: AtomicU64,
    forward_nanos: AtomicU64,
}

impl VariantProfile {
    pub fn for_program(p: &GraphProgram) -> VariantProfile {
        VariantProfile {
            model: p.model.clone(),
            variant: p.variant.clone(),
            op_calls: (0..OP_KINDS).map(|_| AtomicU64::new(0)).collect(),
            op_nanos: (0..OP_KINDS).map(|_| AtomicU64::new(0)).collect(),
            nodes: p
                .weights
                .iter()
                .map(|w| NodeProfile::new(&w.name, family_label(&w.weight), w.k, w.n))
                .collect(),
            forwards: AtomicU64::new(0),
            forward_nanos: AtomicU64::new(0),
        }
    }

    pub fn record_op(&self, kind: OpKind, nanos: u64) {
        let i = kind.index();
        self.op_calls[i].fetch_add(1, Ordering::Relaxed);
        self.op_nanos[i].fetch_add(nanos, Ordering::Relaxed);
    }

    pub fn record_forward(&self, nanos: u64) {
        self.forwards.fetch_add(1, Ordering::Relaxed);
        self.forward_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    pub fn op_calls(&self, kind: OpKind) -> u64 {
        self.op_calls[kind.index()].load(Ordering::Relaxed)
    }

    pub fn op_secs(&self, kind: OpKind) -> f64 {
        self.op_nanos[kind.index()].load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Total attributed seconds: the sum over op kinds.  `LstmStep`
    /// already includes its gate GEMM, so this never double-counts.
    pub fn attributed_secs(&self) -> f64 {
        OpKind::ALL.iter().map(|&k| self.op_secs(k)).sum()
    }

    pub fn forwards(&self) -> u64 {
        self.forwards.load(Ordering::Relaxed)
    }

    pub fn forward_secs(&self) -> f64 {
        self.forward_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn reset(&self) {
        for c in self.op_calls.iter().chain(&self.op_nanos) {
            c.store(0, Ordering::Relaxed);
        }
        for n in &self.nodes {
            n.reset();
        }
        self.forwards.store(0, Ordering::Relaxed);
        self.forward_nanos.store(0, Ordering::Relaxed);
    }

    pub fn to_json(&self) -> Json {
        let ops: Vec<Json> = OpKind::ALL
            .iter()
            .filter(|&&k| self.op_calls(k) > 0)
            .map(|&k| {
                obj(vec![
                    ("kind", s(k.label())),
                    ("calls", num(self.op_calls(k) as f64)),
                    ("secs", num(self.op_secs(k))),
                ])
            })
            .collect();
        let nodes: Vec<Json> =
            self.nodes.iter().filter(|n| n.calls() > 0).map(NodeProfile::to_json).collect();
        obj(vec![
            ("model", s(&self.model)),
            ("variant", s(&self.variant)),
            ("forwards", num(self.forwards() as f64)),
            ("forward_secs", num(self.forward_secs())),
            ("attributed_secs", num(self.attributed_secs())),
            ("ops", arr(ops)),
            ("nodes", arr(nodes)),
        ])
    }
}

/// The enable/disable seam: backends hold `Option<Arc<Telemetry>>`, the
/// executor resolves `Option<&VariantProfile>` once per forward, and
/// every timing site is a branch on that `Option` — `None` costs one
/// predictable branch per op.
#[derive(Default)]
pub struct Telemetry {
    variants: RwLock<Vec<Arc<VariantProfile>>>,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry::default()
    }

    /// Register profiles for every program (idempotent per variant name).
    pub fn register_programs(&self, programs: &[GraphProgram]) {
        let mut vars = self.variants.write().expect("telemetry lock poisoned");
        for p in programs {
            if !vars.iter().any(|v| v.variant == p.variant) {
                vars.push(Arc::new(VariantProfile::for_program(p)));
            }
        }
    }

    /// Profile handle for one variant (cheap Arc clone; resolve once per
    /// forward, not per op).
    pub fn variant(&self, name: &str) -> Option<Arc<VariantProfile>> {
        let vars = self.variants.read().ok()?;
        vars.iter().find(|v| v.variant == name).cloned()
    }

    pub fn variants(&self) -> Vec<Arc<VariantProfile>> {
        self.variants.read().map(|v| v.clone()).unwrap_or_default()
    }

    /// Zero every counter (post-warmup reset in `profile` runs).
    pub fn reset(&self) {
        for v in self.variants() {
            v.reset();
        }
    }

    /// Full profile report as in-tree JSON.
    pub fn report(&self) -> Json {
        let variants: Vec<Json> = self.variants().iter().map(|v| v.to_json()).collect();
        obj(vec![("variants", arr(variants))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::PatternFamily;
    use crate::exec::ModelDims;
    use crate::graph::{pack_weight, GraphBuilder, PackOptions};
    use crate::tensor::Matrix;
    use crate::util::Rng;

    fn tiny_program() -> GraphProgram {
        let mut rng = Rng::new(7);
        let w = Matrix::from_vec(4, 4, (0..16).map(|_| rng.normal_f32()).collect());
        let mut b = GraphBuilder::new();
        let input = b.buffer(2, 4);
        b.scale_by_batch(input, 1);
        let node = pack_weight(
            "l0.up",
            &w,
            2,
            &[1, 2],
            PatternFamily::Dense,
            &PackOptions::default(),
            None,
        )
        .unwrap();
        let out = b.gemm(input, node);
        let dims = ModelDims { batch: 2, seq: 1, d_model: 4, n_classes: 4 };
        b.finish("tiny", "model_dense", input, out, dims)
    }

    #[test]
    fn op_kind_covers_every_op_exactly_once() {
        // index() must be a bijection onto 0..OP_KINDS
        let mut seen = [false; OP_KINDS];
        for k in OpKind::ALL {
            assert!(!seen[k.index()], "duplicate index for {:?}", k);
            seen[k.index()] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn register_resolve_record_roundtrip() {
        let tele = Telemetry::new();
        let p = tiny_program();
        tele.register_programs(&[p]);
        assert!(tele.variant("nope").is_none());
        let prof = tele.variant("model_dense").expect("registered variant resolves");
        assert_eq!(prof.nodes.len(), 1);
        assert_eq!(prof.nodes[0].name, "l0.up");
        assert_eq!(prof.nodes[0].family, "dense");

        prof.record_op(OpKind::Gemm, 1_000_000);
        // packed micro code for "avx2 4x16" (Isa index 1, MR 4, NR 16)
        let micro = (1usize << 16) | (4 << 8) | 16;
        // epilogue kind 3 = bias + relu; 64 bytes of tail traffic avoided
        prof.nodes[0].record(2, 1_000_000, 64, 128, 64, 64, 1, micro, 3, 64);
        prof.record_forward(1_500_000);

        assert_eq!(prof.op_calls(OpKind::Gemm), 1);
        assert!((prof.op_secs(OpKind::Gemm) - 1e-3).abs() < 1e-12);
        assert!((prof.attributed_secs() - 1e-3).abs() < 1e-12);
        assert_eq!(prof.nodes[0].calls(), 1);
        assert_eq!(prof.nodes[0].rows(), 2);
        assert!(prof.nodes[0].gflops() > 0.0);
        assert_eq!(prof.nodes[0].bytes(), 128);
        assert!(prof.nodes[0].gbps() > 0.0);
        assert_eq!(prof.nodes[0].last_dispatch(), (2, 64, 64, 1));
        assert_eq!(prof.nodes[0].last_micro(), "avx2 4x16");
        assert_eq!(prof.nodes[0].last_epilogue(), "bias+relu");
        assert_eq!(prof.nodes[0].bytes_avoided(), 64);

        // report JSON carries the node and op rows, microkernel included
        let rep = tele.report().to_string();
        assert!(rep.contains("\"l0.up\""), "report: {rep}");
        assert!(rep.contains("\"gemm\""), "report: {rep}");
        assert!(rep.contains("\"avx2 4x16\""), "report: {rep}");
        assert!(rep.contains("\"bias+relu\""), "report: {rep}");
        assert!(rep.contains("\"bytes_avoided\""), "report: {rep}");

        tele.reset();
        assert_eq!(prof.op_calls(OpKind::Gemm), 0);
        assert_eq!(prof.nodes[0].calls(), 0);
        assert_eq!(prof.forwards(), 0);
        assert_eq!(prof.nodes[0].last_micro(), "scalar");
        assert_eq!(prof.nodes[0].last_epilogue(), "-");
        assert_eq!(prof.nodes[0].bytes_avoided(), 0);
    }

    #[test]
    fn registration_is_idempotent_per_variant() {
        let tele = Telemetry::new();
        let p = tiny_program();
        tele.register_programs(&[p]);
        let p2 = tiny_program();
        tele.register_programs(&[p2]);
        assert_eq!(tele.variants().len(), 1);
    }
}
