//! Request-stage tracing: each served request is decomposed into the
//! five coordinator stages (queue-wait → batch-assembly → pack →
//! execute → respond), aggregated per stage and per variant, with a
//! bounded ring of slow-request exemplars for postmortems.
//!
//! The span model (DESIGN.md §8): stage boundaries come from four
//! timestamps the worker loop already touches — `Request.submitted`,
//! the batcher's first-receive and assembly-done instants, and the
//! execute start/end pair — so tracing adds no extra clock reads on the
//! kernel path.  `queue + assembly + pack = execute_start - submitted`
//! exactly (for requests submitted before the batch opened), which the
//! trace-consistency test pins down.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One coordinator pipeline stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// `submitted` → the batcher's first `recv` for the batch.
    Queue,
    /// First `recv` → batch handed to the worker (drain + wait window).
    Assembly,
    /// Batch handed over → kernels start (routing + activation packing).
    Pack,
    /// Kernel execution (`run_batch` / `run`).
    Execute,
    /// Execution end → response handed to the requester's channel.
    Respond,
}

impl Stage {
    pub const ALL: [Stage; 5] =
        [Stage::Queue, Stage::Assembly, Stage::Pack, Stage::Execute, Stage::Respond];

    pub fn label(self) -> &'static str {
        match self {
            Stage::Queue => "queue",
            Stage::Assembly => "assembly",
            Stage::Pack => "pack",
            Stage::Execute => "execute",
            Stage::Respond => "respond",
        }
    }

    pub fn index(self) -> usize {
        match self {
            Stage::Queue => 0,
            Stage::Assembly => 1,
            Stage::Pack => 2,
            Stage::Execute => 3,
            Stage::Respond => 4,
        }
    }
}

/// Per-request stage durations in seconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestTrace {
    pub queue: f64,
    pub assembly: f64,
    pub pack: f64,
    pub execute: f64,
    pub respond: f64,
}

impl RequestTrace {
    pub fn stage(&self, s: Stage) -> f64 {
        match s {
            Stage::Queue => self.queue,
            Stage::Assembly => self.assembly,
            Stage::Pack => self.pack,
            Stage::Execute => self.execute,
            Stage::Respond => self.respond,
        }
    }

    /// End-to-end seconds: the stages partition the request lifetime, so
    /// their sum is the submitted→responded latency.
    pub fn total(&self) -> f64 {
        self.queue + self.assembly + self.pack + self.execute + self.respond
    }
}

/// One retained slow-request trace.
#[derive(Clone, Debug)]
pub struct TraceExemplar {
    pub variant: String,
    pub trace: RequestTrace,
}

/// Bounded ring of the last N traces whose end-to-end latency crossed
/// the slow threshold.  Recording is a threshold check (two atomics) on
/// the fast path; only actually-slow requests take the mutex.
pub struct TraceRing {
    ring: Mutex<VecDeque<TraceExemplar>>,
    cap: usize,
    /// f64 bit pattern of the threshold in seconds (atomic so it can be
    /// retuned while workers run).
    threshold_bits: AtomicU64,
}

/// Default exemplar capacity.
pub const DEFAULT_EXEMPLARS: usize = 32;
/// Default slow threshold: 100 ms.
pub const DEFAULT_SLOW_SECS: f64 = 0.1;

impl Default for TraceRing {
    fn default() -> Self {
        Self::new(DEFAULT_EXEMPLARS, DEFAULT_SLOW_SECS)
    }
}

impl TraceRing {
    pub fn new(cap: usize, threshold_secs: f64) -> TraceRing {
        TraceRing {
            ring: Mutex::new(VecDeque::with_capacity(cap)),
            cap: cap.max(1),
            threshold_bits: AtomicU64::new(threshold_secs.to_bits()),
        }
    }

    pub fn threshold_secs(&self) -> f64 {
        f64::from_bits(self.threshold_bits.load(Ordering::Relaxed))
    }

    pub fn set_threshold_secs(&self, secs: f64) {
        self.threshold_bits.store(secs.to_bits(), Ordering::Relaxed);
    }

    /// Retain the trace if it is slow enough; drops the oldest exemplar
    /// when full.
    pub fn offer(&self, variant: &str, trace: RequestTrace) {
        if trace.total() < self.threshold_secs() {
            return;
        }
        if let Ok(mut ring) = self.ring.lock() {
            if ring.len() == self.cap {
                ring.pop_front();
            }
            ring.push_back(TraceExemplar { variant: variant.to_string(), trace });
        }
    }

    /// Snapshot of retained exemplars, oldest first.
    pub fn exemplars(&self) -> Vec<TraceExemplar> {
        self.ring.lock().map(|r| r.iter().cloned().collect()).unwrap_or_default()
    }

    pub fn clear(&self) {
        if let Ok(mut ring) = self.ring.lock() {
            ring.clear();
        }
    }
}

/// Aggregated per-stage statistics for one variant, produced by
/// `Metrics::full_snapshot` from the stage histograms.
#[derive(Clone, Debug)]
pub struct StageStats {
    pub stage: &'static str,
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stages_partition_the_total() {
        let t = RequestTrace { queue: 1.0, assembly: 0.5, pack: 0.25, execute: 2.0, respond: 0.1 };
        let sum: f64 = Stage::ALL.iter().map(|&s| t.stage(s)).sum();
        assert!((sum - t.total()).abs() < 1e-12);
    }

    #[test]
    fn ring_keeps_only_slow_traces_and_bounds_memory() {
        let ring = TraceRing::new(3, 0.5);
        let fast = RequestTrace { execute: 0.1, ..Default::default() };
        ring.offer("model_tw", fast);
        assert!(ring.exemplars().is_empty(), "fast trace must not be retained");
        for i in 0..5 {
            let slow = RequestTrace { execute: 1.0 + i as f64, ..Default::default() };
            ring.offer("model_tw", slow);
        }
        let kept = ring.exemplars();
        assert_eq!(kept.len(), 3, "ring is bounded at capacity");
        // oldest were evicted: the survivors are the last three offered
        assert!((kept[0].trace.execute - 3.0).abs() < 1e-12);
        assert!((kept[2].trace.execute - 5.0).abs() < 1e-12);
    }

    #[test]
    fn threshold_is_retunable() {
        let ring = TraceRing::default();
        assert!((ring.threshold_secs() - DEFAULT_SLOW_SECS).abs() < 1e-12);
        ring.set_threshold_secs(0.001);
        ring.offer("v", RequestTrace { execute: 0.002, ..Default::default() });
        assert_eq!(ring.exemplars().len(), 1);
    }
}
