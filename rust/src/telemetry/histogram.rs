//! Fixed-bucket log-scale latency histogram with lock-free recording.
//!
//! Buckets cover `[MIN_SECS * GROWTH^i, MIN_SECS * GROWTH^(i+1))` for
//! `i` in `0..BUCKETS`: 2048 buckets growing 1% per step span 1 µs to
//! ~700 s.  A recorded sample touches exactly two atomic counters (its
//! bucket and the running nanosecond sum), so many serving workers can
//! hammer one histogram with no lock and no allocation, and memory stays
//! bounded no matter how many samples arrive — the properties the old
//! `Vec<f64>`-per-variant metrics store lacked.
//!
//! Percentile queries walk the cumulative counts and report the
//! *geometric midpoint* of the bucket holding the requested rank, so the
//! worst-case relative error is half a bucket width: `sqrt(1.01) - 1`
//! ≈ 0.5%, far inside the ≤10% budget DESIGN.md §8 documents.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets; with [`GROWTH`] this spans 1 µs .. ~700 s.
pub const BUCKETS: usize = 2048;
/// Lower edge of bucket 0 in seconds; smaller samples clamp into it.
pub const MIN_SECS: f64 = 1e-6;
/// Per-bucket geometric growth factor.
pub const GROWTH: f64 = 1.01;

/// Lock-free log-scale histogram of durations in seconds.
pub struct Histogram {
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum_nanos: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
        }
    }

    /// Bucket index for a duration; NaN/negative/sub-µs clamp to 0 and
    /// anything past the top edge clamps to the last bucket.
    pub fn bucket_index(secs: f64) -> usize {
        if secs.is_nan() || secs <= MIN_SECS {
            return 0;
        }
        let idx = ((secs / MIN_SECS).ln() / GROWTH.ln()) as usize;
        idx.min(BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` (the value a percentile query
    /// reports for ranks landing in that bucket).
    pub fn bucket_midpoint(i: usize) -> f64 {
        MIN_SECS * GROWTH.powf(i as f64 + 0.5)
    }

    /// Record one duration. Lock-free; safe from any number of threads.
    pub fn record(&self, secs: f64) {
        let idx = Self::bucket_index(secs);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let nanos = if secs.is_finite() && secs > 0.0 { (secs * 1e9).round() as u64 } else { 0 };
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_secs(&self) -> f64 {
        self.sum_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Exact arithmetic mean (from the nanosecond sum, not the buckets).
    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_secs() / n as f64
        }
    }

    /// Percentile `q` in `[0, 1]`; 0.0 when empty.  Reports the geometric
    /// midpoint of the bucket holding rank `q * (n - 1)` — matching the
    /// rank convention of `util::percentile` to within bucket resolution.
    pub fn percentile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = q.clamp(0.0, 1.0) * (n - 1) as f64;
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum as f64 > rank {
                return Self::bucket_midpoint(i);
            }
        }
        Self::bucket_midpoint(BUCKETS - 1)
    }

    /// Fold another histogram into this one (bucket-wise atomic adds).
    pub fn merge(&self, other: &Histogram) {
        for (dst, src) in self.counts.iter().zip(&other.counts) {
            let v = src.load(Ordering::Relaxed);
            if v > 0 {
                dst.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_nanos.fetch_add(other.sum_nanos.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Zero every counter (profiling warmup reset).
    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_nanos.store(0, Ordering::Relaxed);
    }

    /// Sum of all per-bucket counters (test invariant: equals `count()`).
    pub fn bucket_total(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{percentile, Rng};
    use std::sync::Arc;

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.mean_secs(), 0.0);
    }

    #[test]
    fn bucket_edges_clamp() {
        assert_eq!(Histogram::bucket_index(f64::NAN), 0);
        assert_eq!(Histogram::bucket_index(-1.0), 0);
        assert_eq!(Histogram::bucket_index(0.0), 0);
        assert_eq!(Histogram::bucket_index(1e-9), 0);
        assert_eq!(Histogram::bucket_index(1e12), BUCKETS - 1);
    }

    #[test]
    fn percentiles_track_exact_samples_within_bucket_resolution() {
        // Log-uniform samples across 0.1 ms .. 1 s: the regime where a
        // linear-bucket scheme would fall apart but log buckets hold the
        // ISSUE's <=10% relative-error bound everywhere.
        let mut rng = Rng::new(42);
        let h = Histogram::new();
        let mut samples = Vec::new();
        for _ in 0..2000 {
            let v = 1e-4 * 10f64.powf(rng.next_f64() * 4.0);
            h.record(v);
            samples.push(v);
        }
        for q in [0.5, 0.95, 0.99] {
            let exact = percentile(&mut samples, q);
            let est = h.percentile(q);
            let rel = (est - exact).abs() / exact;
            assert!(rel <= 0.10, "p{q}: exact {exact} vs hist {est} (rel err {rel})");
        }
    }

    #[test]
    fn mean_is_exact_not_bucketed() {
        let h = Histogram::new();
        for ms in [1.0, 2.0, 3.0, 4.0] {
            h.record(ms * 1e-3);
        }
        assert!((h.mean_secs() - 2.5e-3).abs() < 1e-12);
    }

    #[test]
    fn concurrent_hammering_loses_nothing() {
        // ISSUE satellite: many threads hammering one histogram — the
        // total count and the bucket-wise sum must match exactly.
        let h = Arc::new(Histogram::new());
        let threads = 8;
        let per_thread = 5000;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    let mut rng = Rng::new(100 + t as u64);
                    for _ in 0..per_thread {
                        h.record(1e-5 + rng.next_f64() * 0.2);
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        let expect = (threads * per_thread) as u64;
        assert_eq!(h.count(), expect);
        assert_eq!(h.bucket_total(), expect);
    }

    #[test]
    fn merge_adds_bucketwise() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(1e-3);
        b.record(1e-3);
        b.record(5e-2);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.bucket_total(), 3);
        assert!((a.sum_secs() - 5.2e-2).abs() < 1e-9);
    }

    #[test]
    fn reset_zeroes_everything() {
        let h = Histogram::new();
        h.record(0.5);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.bucket_total(), 0);
        assert_eq!(h.percentile(0.99), 0.0);
    }
}
