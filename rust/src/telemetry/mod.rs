//! End-to-end telemetry: lock-free latency histograms, request-stage
//! tracing, and per-GEMM-node graph profiling (DESIGN.md §8).
//!
//! Three pieces, one design rule — *bounded memory, lock-free on the
//! hot path, a single `Option` branch when disabled*:
//!
//! - [`Histogram`] — 2048 log-scale buckets (1% growth from 1 µs) of
//!   atomic counters; replaces the unbounded `Vec<f64>` sample stores
//!   that `coordinator::Metrics` used to sort under its mutex.
//! - [`Stage`] / [`RequestTrace`] / [`TraceRing`] — the request
//!   pipeline decomposed into queue → assembly → pack → execute →
//!   respond spans, aggregated per variant into stage histograms, plus
//!   a bounded ring of slow-request exemplars.
//! - [`Telemetry`] / [`VariantProfile`] / [`NodeProfile`] — the Fig. 10
//!   attribution layer: per-op-kind and per-GEMM-node wall time, the
//!   `TileConfig` actually dispatched, effective intra-op threads, and
//!   FLOPs → achieved GFLOP/s, recorded by `graph::execute_with` when a
//!   profile handle is present.

pub mod histogram;
pub mod profile;
pub mod trace;

pub use histogram::Histogram;
pub use profile::{NodeProfile, OpKind, Telemetry, VariantProfile, OP_KINDS};
pub use trace::{RequestTrace, Stage, StageStats, TraceExemplar, TraceRing};
