//! Model zoo: the per-layer GEMM workloads of the paper's five benchmark
//! networks (§VI-A), with convolutions lowered to GEMM via img2col
//! (`M = H_out*W_out`, `K = C_in*k_h*k_w`, `N = C_out`).
//!
//! These shape lists drive two consumers:
//!
//! - the `gpusim` latency figures (Fig. 10/11): a model's latency under a
//!   pattern is the sum over its prunable GEMMs of the pattern's simulated
//!   kernel latency, plus the dense layers kept as-is (e.g. first conv
//!   layers, embedding-adjacent GEMMs);
//! - the `graph` execution IR: `graph::compile` turns a workload into an
//!   *executable* layer graph, which is why each layer now records its
//!   [`LayerKind`] — an FC layer is just its GEMM, while a conv layer
//!   carries the [`ConvMeta`] needed to reconstruct the img2col lowering
//!   (`nn::Conv2dSpec`) the shape was derived from.
//!
//! The classic constructors (`bert_base`, `vgg16`, `nmt`, ...) keep the
//! paper's evaluation dims; the `_at`/`_scaled` variants produce the same
//! topology at reduced dims so tests and CPU-serving runs stay fast.

use crate::gpusim::GemmShape;
use crate::nn::Conv2dSpec;

/// How a GEMM-shaped layer maps back onto a network operator.
#[derive(Clone, Copy, Debug)]
pub enum LayerKind {
    /// A plain fully-connected GEMM (also LSTM gate stacks and attention
    /// projections — anything whose activations are already a matrix).
    Fc,
    /// A convolution lowered via img2col; the metadata reconstructs the
    /// lowering (`M = out_hw^2`, `K = c_in*k^2`, `N = c_out`).
    Conv(ConvMeta),
}

/// img2col lowering parameters of one conv layer.
#[derive(Clone, Copy, Debug)]
pub struct ConvMeta {
    /// Input spatial extent (square images).
    pub in_hw: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvMeta {
    pub fn spec(&self) -> Conv2dSpec {
        Conv2dSpec {
            c_in: self.c_in,
            c_out: self.c_out,
            kernel: self.kernel,
            stride: self.stride,
            pad: self.pad,
        }
    }

    /// Output spatial extent of the lowering.
    pub fn out_hw(&self) -> usize {
        self.spec().out_hw(self.in_hw, self.in_hw).0
    }
}

/// One GEMM-shaped layer (possibly repeated `count` times).
#[derive(Clone, Debug)]
pub struct GemmLayer {
    pub name: String,
    pub shape: GemmShape,
    pub count: usize,
    /// Whether the pruner touches this layer (first convs are kept dense,
    /// the paper's ResNet-50 observation in §VI-C).
    pub prunable: bool,
    /// Operator provenance of the GEMM shape (FC vs lowered conv).
    pub kind: LayerKind,
}

/// A benchmark network as a GEMM workload.
#[derive(Clone, Debug)]
pub struct ModelWorkload {
    pub name: &'static str,
    /// Accuracy metric label for reports ("top-5", "BLEU", "acc", "F1").
    pub metric: &'static str,
    pub layers: Vec<GemmLayer>,
}

impl ModelWorkload {
    pub fn total_flops(&self) -> f64 {
        self.layers.iter().map(|l| l.shape.flops() * l.count as f64).sum()
    }

    pub fn prunable_layers(&self) -> impl Iterator<Item = &GemmLayer> {
        self.layers.iter().filter(|l| l.prunable)
    }
}

/// Stride-1 "same" convolution entry (`pad = k/2`, spatial size preserved).
fn conv(name: &str, hw: usize, cin: usize, k: usize, cout: usize, count: usize, prunable: bool) -> GemmLayer {
    conv_s(name, hw, cin, k, cout, count, prunable, 1)
}

/// Convolution entry at an arbitrary stride; `hw` is the *output* spatial
/// extent and the input extent is `hw * stride` (the zoo's downsampling
/// convs halve resolution with `pad = k/2`).
#[allow(clippy::too_many_arguments)]
fn conv_s(
    name: &str,
    hw: usize,
    cin: usize,
    k: usize,
    cout: usize,
    count: usize,
    prunable: bool,
    stride: usize,
) -> GemmLayer {
    let meta =
        ConvMeta { in_hw: hw * stride, c_in: cin, c_out: cout, kernel: k, stride, pad: k / 2 };
    debug_assert_eq!(meta.out_hw(), hw, "{name}: conv meta disagrees with listed hw");
    GemmLayer {
        name: name.to_string(),
        shape: GemmShape::new(hw * hw, cin * k * k, cout),
        count,
        prunable,
        kind: LayerKind::Conv(meta),
    }
}

fn fc(name: &str, m: usize, k: usize, n: usize, count: usize) -> GemmLayer {
    GemmLayer {
        name: name.to_string(),
        shape: GemmShape::new(m, k, n),
        count,
        prunable: true,
        kind: LayerKind::Fc,
    }
}

/// BERT-style encoder at arbitrary width/depth: `d_ff = 4*d`, `qkv` fused
/// to `3*d`.  `bert_base(8, 128)` is `bert_at(8, 128, 768, 12)`.
pub fn bert_at(batch: usize, seq: usize, d_model: usize, n_layers: usize) -> ModelWorkload {
    let m = batch * seq;
    let d = d_model;
    let layers = vec![
        fc("qkv", m, d, 3 * d, n_layers),
        fc("attn_out", m, d, d, n_layers),
        fc("ffn1", m, d, 4 * d, n_layers),
        fc("ffn2", m, 4 * d, d, n_layers),
    ];
    ModelWorkload { name: "BERT-base", metric: "acc", layers }
}

/// BERT-base (12 layers, d=768, ffn=3072) at batch 8 x seq 128.
pub fn bert_base(batch: usize, seq: usize) -> ModelWorkload {
    bert_at(batch, seq, 768, 12)
}

/// Decoder-style transformer at arbitrary width/depth: the same fused
/// QKV / FFN block shapes as [`bert_at`], but compiled with causal
/// attention and a last-position head (`CompileOptions::causal`) and
/// served through the streaming-decode path with per-layer KV caches.
pub fn decoder_at(batch: usize, seq: usize, d_model: usize, n_layers: usize) -> ModelWorkload {
    let m = batch * seq;
    let d = d_model;
    let layers = vec![
        fc("qkv", m, d, 3 * d, n_layers),
        fc("attn_out", m, d, d, n_layers),
        fc("ffn1", m, d, 4 * d, n_layers),
        fc("ffn2", m, 4 * d, d, n_layers),
    ];
    ModelWorkload { name: "decoder", metric: "acc", layers }
}

/// GNMT-style NMT at arbitrary hidden width / unroll depth: 2-layer LSTM
/// encoder + decoder (each step's four gates are one
/// `(batch, 2H, 4H)` GEMM), an attention FC, and an `8H`-wide projection.
/// `nmt(128)` is `nmt_at(128, 512, 32)`.
pub fn nmt_at(batch: usize, hidden: usize, steps: usize) -> ModelWorkload {
    let h = hidden;
    let layers = vec![
        fc("enc_l1_gates", batch, 2 * h, 4 * h, steps),
        fc("enc_l2_gates", batch, 2 * h, 4 * h, steps),
        fc("dec_l1_gates", batch, 2 * h, 4 * h, steps),
        fc("dec_l2_gates", batch, 2 * h, 4 * h, steps),
        fc("attention", batch, h, h, steps),
        fc("softmax_proj", batch, h, 8 * h, 1),
    ];
    ModelWorkload { name: "NMT", metric: "BLEU", layers }
}

/// GNMT-style NMT: 2-layer LSTM encoder + decoder, hidden 512, batch 128,
/// one unrolled step per token over a 32-token sentence.
pub fn nmt(batch: usize) -> ModelWorkload {
    let mut w = nmt_at(batch, 512, 32);
    // the paper's workload counts the projection once per step
    for l in &mut w.layers {
        if l.name == "softmax_proj" {
            l.count = 32;
        }
    }
    w
}

/// VGG16 topology at a reduced scale: `img` is the input resolution
/// (must be a positive multiple of 32), `width_div` divides every channel
/// width after the 3-channel input, and `fc_dim` replaces the 4096-wide
/// FC pair.  `vgg16()` is `vgg16_scaled(224, 1, 4096)`.
pub fn vgg16_scaled(img: usize, width_div: usize, fc_dim: usize) -> ModelWorkload {
    assert!(img >= 32 && img % 32 == 0, "vgg16 needs img as a positive multiple of 32");
    let w = |c: usize| (c / width_div).max(1);
    let s = img;
    let layers = vec![
        conv("conv1_1", s, 3, 3, w(64), 1, false), // first conv kept dense
        conv("conv1_2", s, w(64), 3, w(64), 1, true),
        conv("conv2_1", s / 2, w(64), 3, w(128), 1, true),
        conv("conv2_2", s / 2, w(128), 3, w(128), 1, true),
        conv("conv3_1", s / 4, w(128), 3, w(256), 1, true),
        conv("conv3_2", s / 4, w(256), 3, w(256), 2, true),
        conv("conv4_1", s / 8, w(256), 3, w(512), 1, true),
        conv("conv4_2", s / 8, w(512), 3, w(512), 2, true),
        conv("conv5", s / 16, w(512), 3, w(512), 3, true),
        fc("fc6", 1, w(512) * (s / 32) * (s / 32), fc_dim, 1),
        fc("fc7", 1, fc_dim, fc_dim, 1),
        fc("fc8", 1, fc_dim, 1000, 1),
    ];
    ModelWorkload { name: "VGG16", metric: "top-5", layers }
}

/// VGG16 at 224x224 (13 convs + 3 FC).
pub fn vgg16() -> ModelWorkload {
    vgg16_scaled(224, 1, 4096)
}

/// ResNet-18 at 224x224 (basic blocks).
pub fn resnet18() -> ModelWorkload {
    let layers = vec![
        conv_s("conv1", 112, 3, 7, 64, 1, false, 2),
        conv("layer1", 56, 64, 3, 64, 4, true),
        conv_s("layer2_ds", 28, 64, 3, 128, 1, true, 2),
        conv("layer2", 28, 128, 3, 128, 3, true),
        conv_s("layer3_ds", 14, 128, 3, 256, 1, true, 2),
        conv("layer3", 14, 256, 3, 256, 3, true),
        conv_s("layer4_ds", 7, 256, 3, 512, 1, true, 2),
        conv("layer4", 7, 512, 3, 512, 3, true),
        fc("fc", 1, 512, 1000, 1),
    ];
    ModelWorkload { name: "ResNet-18", metric: "top-5", layers }
}

/// ResNet-50 at 224x224 (bottleneck blocks, 1x1/3x3/1x1).
pub fn resnet50() -> ModelWorkload {
    let mut layers = vec![conv_s("conv1", 112, 3, 7, 64, 1, false, 2)];
    // (stage, hw, cin_mid, blocks)
    let stages = [(1usize, 56usize, 64usize, 3usize), (2, 28, 128, 4), (3, 14, 256, 6), (4, 7, 512, 3)];
    for (s, hw, mid, blocks) in stages {
        let cout = mid * 4;
        layers.push(conv(&format!("s{s}_1x1a"), hw, cout.min(mid * 2), 1, mid, blocks, true));
        layers.push(conv(&format!("s{s}_3x3"), hw, mid, 3, mid, blocks, true));
        layers.push(conv(&format!("s{s}_1x1b"), hw, mid, 1, cout, blocks, true));
    }
    layers.push(fc("fc", 1, 2048, 1000, 1));
    ModelWorkload { name: "ResNet-50", metric: "top-5", layers }
}

/// The full evaluation zoo in the paper's Fig. 8/10/11 order.
pub fn zoo() -> Vec<ModelWorkload> {
    vec![vgg16(), resnet18(), resnet50(), nmt(128), bert_base(8, 128)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_five_models() {
        let z = zoo();
        assert_eq!(z.len(), 5);
        let names: Vec<_> = z.iter().map(|m| m.name).collect();
        assert_eq!(names, ["VGG16", "ResNet-18", "ResNet-50", "NMT", "BERT-base"]);
    }

    #[test]
    fn bert_flops_dominated_by_ffn() {
        let b = bert_base(8, 128);
        let ffn: f64 = b
            .layers
            .iter()
            .filter(|l| l.name.starts_with("ffn"))
            .map(|l| l.shape.flops() * l.count as f64)
            .sum();
        assert!(ffn / b.total_flops() > 0.5);
    }

    #[test]
    fn first_convs_not_prunable() {
        for m in [vgg16(), resnet18(), resnet50()] {
            assert!(!m.layers[0].prunable, "{}", m.name);
            assert!(m.prunable_layers().count() >= 5, "{}", m.name);
        }
    }

    #[test]
    fn cnn_gemms_smaller_than_bert() {
        // the paper's §VI-D observation: CNN GEMM shapes are smaller
        let bert_max = bert_base(8, 128)
            .layers
            .iter()
            .map(|l| l.shape.flops())
            .fold(0.0, f64::max);
        let r50_max = resnet50().layers.iter().map(|l| l.shape.flops()).fold(0.0, f64::max);
        assert!(r50_max < bert_max);
    }

    #[test]
    fn img2col_shapes() {
        let v = vgg16();
        let c12 = &v.layers[1];
        assert_eq!(c12.shape.m, 224 * 224);
        assert_eq!(c12.shape.k, 64 * 9);
        assert_eq!(c12.shape.n, 64);
    }

    #[test]
    fn conv_meta_reconstructs_listed_shapes() {
        // every conv layer's metadata must regenerate its GEMM shape —
        // the contract graph::compile relies on
        for m in zoo() {
            for l in &m.layers {
                if let LayerKind::Conv(meta) = l.kind {
                    let hw = meta.out_hw();
                    assert_eq!(hw * hw, l.shape.m, "{}/{}", m.name, l.name);
                    assert_eq!(meta.spec().gemm_k(), l.shape.k, "{}/{}", m.name, l.name);
                    assert_eq!(meta.c_out, l.shape.n, "{}/{}", m.name, l.name);
                }
            }
        }
    }

    #[test]
    fn scaled_constructors_match_paper_dims() {
        // the parameterised constructors at paper dims equal the classics
        let a = bert_at(8, 128, 768, 12);
        let b = bert_base(8, 128);
        assert_eq!(a.layers.len(), b.layers.len());
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!((x.shape.m, x.shape.k, x.shape.n), (y.shape.m, y.shape.k, y.shape.n));
        }
        let n = nmt(128);
        let gates = n.layers.iter().find(|l| l.name == "enc_l1_gates").unwrap();
        assert_eq!((gates.shape.k, gates.shape.n), (1024, 2048));
        let v = vgg16_scaled(32, 4, 256);
        let fc6 = v.layers.iter().find(|l| l.name == "fc6").unwrap();
        assert_eq!(fc6.shape.k, 128); // (512/4) * (32/32)^2
        assert_eq!(fc6.shape.n, 256);
    }
}
