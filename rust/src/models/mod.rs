//! Model zoo: the per-layer GEMM workloads of the paper's five benchmark
//! networks (§VI-A), with convolutions lowered to GEMM via img2col
//! (`M = H_out*W_out`, `K = C_in*k_h*k_w`, `N = C_out`).
//!
//! These shape lists drive the `gpusim` latency figures (Fig. 10/11): a
//! model's latency under a pattern is the sum over its prunable GEMMs of
//! the pattern's simulated kernel latency, plus the dense layers kept
//! as-is (e.g. first conv layers, embedding-adjacent GEMMs).

use crate::gpusim::GemmShape;

/// One GEMM-shaped layer (possibly repeated `count` times).
#[derive(Clone, Debug)]
pub struct GemmLayer {
    pub name: String,
    pub shape: GemmShape,
    pub count: usize,
    /// Whether the pruner touches this layer (first convs are kept dense,
    /// the paper's ResNet-50 observation in §VI-C).
    pub prunable: bool,
}

/// A benchmark network as a GEMM workload.
#[derive(Clone, Debug)]
pub struct ModelWorkload {
    pub name: &'static str,
    /// Accuracy metric label for reports ("top-5", "BLEU", "acc", "F1").
    pub metric: &'static str,
    pub layers: Vec<GemmLayer>,
}

impl ModelWorkload {
    pub fn total_flops(&self) -> f64 {
        self.layers.iter().map(|l| l.shape.flops() * l.count as f64).sum()
    }

    pub fn prunable_layers(&self) -> impl Iterator<Item = &GemmLayer> {
        self.layers.iter().filter(|l| l.prunable)
    }
}

fn conv(name: &str, hw: usize, cin: usize, k: usize, cout: usize, count: usize, prunable: bool) -> GemmLayer {
    GemmLayer {
        name: name.to_string(),
        shape: GemmShape::new(hw * hw, cin * k * k, cout),
        count,
        prunable,
    }
}

fn fc(name: &str, m: usize, k: usize, n: usize, count: usize) -> GemmLayer {
    GemmLayer { name: name.to_string(), shape: GemmShape::new(m, k, n), count, prunable: true }
}

/// BERT-base (12 layers, d=768, ffn=3072) at batch 8 x seq 128.
pub fn bert_base(batch: usize, seq: usize) -> ModelWorkload {
    let m = batch * seq;
    let layers = vec![
        fc("qkv", m, 768, 2304, 12),
        fc("attn_out", m, 768, 768, 12),
        fc("ffn1", m, 768, 3072, 12),
        fc("ffn2", m, 3072, 768, 12),
    ];
    ModelWorkload { name: "BERT-base", metric: "acc", layers }
}

/// GNMT-style NMT: 2-layer LSTM encoder + decoder, hidden 512, batch 128.
/// Each LSTM step's four gates form one (batch, 2*hidden, 4*hidden) GEMM;
/// we count one unrolled step per token over a 32-token sentence.
pub fn nmt(batch: usize) -> ModelWorkload {
    let steps = 32;
    let layers = vec![
        fc("enc_l1_gates", batch, 1024, 2048, steps),
        fc("enc_l2_gates", batch, 1024, 2048, steps),
        fc("dec_l1_gates", batch, 1024, 2048, steps),
        fc("dec_l2_gates", batch, 1024, 2048, steps),
        fc("attention", batch, 512, 512, steps),
        fc("softmax_proj", batch, 512, 4096, steps),
    ];
    ModelWorkload { name: "NMT", metric: "BLEU", layers }
}

/// VGG16 at 224x224 (13 convs + 3 FC).
pub fn vgg16() -> ModelWorkload {
    let layers = vec![
        conv("conv1_1", 224, 3, 3, 64, 1, false), // first conv kept dense
        conv("conv1_2", 224, 64, 3, 64, 1, true),
        conv("conv2_1", 112, 64, 3, 128, 1, true),
        conv("conv2_2", 112, 128, 3, 128, 1, true),
        conv("conv3_1", 56, 128, 3, 256, 1, true),
        conv("conv3_2", 56, 256, 3, 256, 2, true),
        conv("conv4_1", 28, 256, 3, 512, 1, true),
        conv("conv4_2", 28, 512, 3, 512, 2, true),
        conv("conv5", 14, 512, 3, 512, 3, true),
        fc("fc6", 1, 25088, 4096, 1),
        fc("fc7", 1, 4096, 4096, 1),
        fc("fc8", 1, 4096, 1000, 1),
    ];
    ModelWorkload { name: "VGG16", metric: "top-5", layers }
}

/// ResNet-18 at 224x224 (basic blocks).
pub fn resnet18() -> ModelWorkload {
    let layers = vec![
        conv("conv1", 112, 3, 7, 64, 1, false),
        conv("layer1", 56, 64, 3, 64, 4, true),
        conv("layer2_ds", 28, 64, 3, 128, 1, true),
        conv("layer2", 28, 128, 3, 128, 3, true),
        conv("layer3_ds", 14, 128, 3, 256, 1, true),
        conv("layer3", 14, 256, 3, 256, 3, true),
        conv("layer4_ds", 7, 256, 3, 512, 1, true),
        conv("layer4", 7, 512, 3, 512, 3, true),
        fc("fc", 1, 512, 1000, 1),
    ];
    ModelWorkload { name: "ResNet-18", metric: "top-5", layers }
}

/// ResNet-50 at 224x224 (bottleneck blocks, 1x1/3x3/1x1).
pub fn resnet50() -> ModelWorkload {
    let mut layers = vec![conv("conv1", 112, 3, 7, 64, 1, false)];
    // (stage, hw, cin_mid, blocks)
    let stages = [(1usize, 56usize, 64usize, 3usize), (2, 28, 128, 4), (3, 14, 256, 6), (4, 7, 512, 3)];
    for (s, hw, mid, blocks) in stages {
        let cout = mid * 4;
        layers.push(conv(&format!("s{s}_1x1a"), hw, cout.min(mid * 2), 1, mid, blocks, true));
        layers.push(conv(&format!("s{s}_3x3"), hw, mid, 3, mid, blocks, true));
        layers.push(conv(&format!("s{s}_1x1b"), hw, mid, 1, cout, blocks, true));
    }
    layers.push(fc("fc", 1, 2048, 1000, 1));
    ModelWorkload { name: "ResNet-50", metric: "top-5", layers }
}

/// The full evaluation zoo in the paper's Fig. 8/10/11 order.
pub fn zoo() -> Vec<ModelWorkload> {
    vec![vgg16(), resnet18(), resnet50(), nmt(128), bert_base(8, 128)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_five_models() {
        let z = zoo();
        assert_eq!(z.len(), 5);
        let names: Vec<_> = z.iter().map(|m| m.name).collect();
        assert_eq!(names, ["VGG16", "ResNet-18", "ResNet-50", "NMT", "BERT-base"]);
    }

    #[test]
    fn bert_flops_dominated_by_ffn() {
        let b = bert_base(8, 128);
        let ffn: f64 = b
            .layers
            .iter()
            .filter(|l| l.name.starts_with("ffn"))
            .map(|l| l.shape.flops() * l.count as f64)
            .sum();
        assert!(ffn / b.total_flops() > 0.5);
    }

    #[test]
    fn first_convs_not_prunable() {
        for m in [vgg16(), resnet18(), resnet50()] {
            assert!(!m.layers[0].prunable, "{}", m.name);
            assert!(m.prunable_layers().count() >= 5, "{}", m.name);
        }
    }

    #[test]
    fn cnn_gemms_smaller_than_bert() {
        // the paper's §VI-D observation: CNN GEMM shapes are smaller
        let bert_max = bert_base(8, 128)
            .layers
            .iter()
            .map(|l| l.shape.flops())
            .fold(0.0, f64::max);
        let r50_max = resnet50().layers.iter().map(|l| l.shape.flops()).fold(0.0, f64::max);
        assert!(r50_max < bert_max);
    }

    #[test]
    fn img2col_shapes() {
        let v = vgg16();
        let c12 = &v.layers[1];
        assert_eq!(c12.shape.m, 224 * 224);
        assert_eq!(c12.shape.k, 64 * 9);
        assert_eq!(c12.shape.n, 64);
    }
}
