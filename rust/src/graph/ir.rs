//! The execution IR: a compiled model is a flat op list over a fixed set
//! of workspace buffers (the arena) plus a table of packed GEMM weights.
//!
//! Buffers are identified by [`BufId`] and their shapes are fixed at
//! compile time — the executor never allocates.  Ops reference weights
//! and biases by index into the program's tables, so a program is a pure
//! description: the mutable state (the arena + kernel scratch) lives in
//! `graph::Workspace`, one per serving worker, while the program itself
//! sits behind an `Arc` shared by the whole worker pool.

use crate::exec::ModelDims;
use crate::nn::Conv2dSpec;

use super::pack::GemmNode;

/// Index of one workspace buffer (a row-major matrix in the arena).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BufId(pub usize);

/// Elementwise activation of a [`Op::BiasAct`] node (and of a fused GEMM
/// epilogue — the kernel-layer type is the canonical definition so both
/// layers agree on semantics by construction).
pub use crate::gemm::Act;

/// One executable node.  Every referenced buffer is distinct per op (the
/// executor temporarily takes mutated buffers out of the arena).
#[derive(Clone, Debug)]
pub enum Op {
    /// `out = bufs[input] @ weights[w]` — the packed-kernel dispatch
    /// (dense / TW fused-CTO / TVW / 2:4, serial or pool-parallel).
    Gemm { input: BufId, w: usize, out: BufId },
    /// In-place `buf = act(buf + bias)`; either part optional.
    BiasAct { buf: BufId, bias: Option<usize>, act: Option<Act> },
    /// Multi-head self-attention over each `seq`-row window of the fused
    /// QKV projection (`(batch*seq, 3d)`), writing context `(batch*seq, d)`.
    /// `scores`/`qh`/`kh`/`vh` are the arena-resident scratch buffers the
    /// `nn::attention_into` core reuses across every head and window.
    Attention {
        qkv: BufId,
        out: BufId,
        heads: usize,
        seq: usize,
        scores: BufId,
        qh: BufId,
        kh: BufId,
        vh: BufId,
        /// Causal masking: position `i` attends only to positions
        /// `0..=i` of its window (decoder-style models).  The causal
        /// one-shot forward is then the exact twin of step-by-step
        /// KV-cache decode, which the decode-parity suite exploits.
        causal: bool,
    },
    /// One KV-cache attention step (decoder decode path).  Row `b` of
    /// `qkv` (`(batch, 3d)`) appends its K/V projections into this
    /// layer's cache at `slot_pos[b]` (`kcache`/`vcache` are
    /// `(batch*max_steps, d)`, `max_steps` rows per slot), then attends
    /// its Q against cache rows `0..=slot_pos[b]` per head, writing
    /// context row `b` of `out` (`(batch, d)`).  `slot_pos` lives in the
    /// workspace and is advanced by the decode driver once per step —
    /// not by this op, since every layer of a step shares the position.
    DecodeAttend {
        qkv: BufId,
        kcache: BufId,
        vcache: BufId,
        out: BufId,
        heads: usize,
        max_steps: usize,
        /// `(1, max_steps)` scratch row for one head's scores.
        scores: BufId,
    },
    /// img2col lowering of one image into the GEMM activation matrix.
    /// `from_chw`: the input buffer is a flat CHW image (the network
    /// input); otherwise it is a previous conv GEMM's `(h*w, c)` output.
    Im2col { input: BufId, out: BufId, spec: Conv2dSpec, in_hw: usize, from_chw: bool },
    /// 2x2 average pool (stride 2) on an `(hw*hw, c)` activation.
    AvgPool2 { input: BufId, out: BufId, hw: usize },
    /// Global average pool: `(hw*hw, c)` -> `(1, c)`.
    GlobalAvgPool { input: BufId, out: BufId },
    /// `(hw*hw, c)` -> `(1, c*hw*hw)` in CHW order (conv -> FC seam).
    Flatten { input: BufId, out: BufId },
    /// One LSTM step: concat `[x_t | h]` into `xh`, gate GEMM through
    /// `weights[w]` into `gates`, then the shared `nn::lstm_gate_update`
    /// over `(h, c)`.  `x_t` comes from `input`: read directly when the
    /// buffer is `(batch, hidden)` (a stacked cell's hidden state), or
    /// sliced at `step` when it is the packed `(batch, seq*hidden)` input.
    LstmStep {
        input: BufId,
        step: usize,
        w: usize,
        bias: usize,
        h: BufId,
        c: BufId,
        xh: BufId,
        gates: BufId,
        hidden: usize,
    },
    /// `dst += src` (the transformer residual).
    Residual { src: BufId, dst: BufId },
    /// In-place per-row layer normalisation (no learned affine).
    LayerNorm { buf: BufId },
    /// Mean over each `seq`-row window: `(batch*seq, d)` -> `(batch, d)`.
    MeanPool { input: BufId, out: BufId, seq: usize },
    /// Last row of each `seq`-row window: `(batch*seq, d)` -> `(batch, d)`
    /// (the decoder head reads the final position, so one-shot logits
    /// match the last decode step's).
    LastPool { input: BufId, out: BufId, seq: usize },
    /// `buf = 0` (recurrent-state reset at the start of a request).
    Zero { buf: BufId },
}

impl Op {
    /// Visit every [`BufId`] this op references (reads, writes, scratch).
    pub fn visit_bufs(&self, mut f: impl FnMut(BufId)) {
        // reuse the mutable visitor on a clone so the two never drift
        let mut op = self.clone();
        op.visit_bufs_mut(|b| f(*b));
    }

    /// Visit every [`BufId`] this op references, mutably — the fusion
    /// pass's buffer-remap hook.  Must enumerate every `BufId` field of
    /// every variant.
    pub fn visit_bufs_mut(&mut self, mut f: impl FnMut(&mut BufId)) {
        match self {
            Op::Gemm { input, out, .. } => {
                f(input);
                f(out);
            }
            Op::BiasAct { buf, .. } => f(buf),
            Op::Attention { qkv, out, scores, qh, kh, vh, .. } => {
                f(qkv);
                f(out);
                f(scores);
                f(qh);
                f(kh);
                f(vh);
            }
            Op::DecodeAttend { qkv, kcache, vcache, out, scores, .. } => {
                f(qkv);
                f(kcache);
                f(vcache);
                f(out);
                f(scores);
            }
            Op::Im2col { input, out, .. } => {
                f(input);
                f(out);
            }
            Op::AvgPool2 { input, out, .. } => {
                f(input);
                f(out);
            }
            Op::GlobalAvgPool { input, out } => {
                f(input);
                f(out);
            }
            Op::Flatten { input, out } => {
                f(input);
                f(out);
            }
            Op::LstmStep { input, h, c, xh, gates, .. } => {
                f(input);
                f(h);
                f(c);
                f(xh);
                f(gates);
            }
            Op::Residual { src, dst } => {
                f(src);
                f(dst);
            }
            Op::LayerNorm { buf } => f(buf),
            Op::MeanPool { input, out, .. } => {
                f(input);
                f(out);
            }
            Op::LastPool { input, out, .. } => {
                f(input);
                f(out);
            }
            Op::Zero { buf } => f(buf),
        }
    }

    /// Buffers this op *reads* (including read-modify-write operands like
    /// the residual destination or recurrent state).  Used by the fusion
    /// pass's overwrite-before-read check.
    pub fn reads(&self, mut f: impl FnMut(BufId)) {
        match *self {
            Op::Gemm { input, .. } => f(input),
            // in-place read-modify ops read their buffer
            Op::BiasAct { buf, .. } => f(buf),
            Op::LayerNorm { buf } => f(buf),
            Op::Attention { qkv, .. } => f(qkv),
            // caches are read-modify (append + attend over the prefix)
            Op::DecodeAttend { qkv, kcache, vcache, .. } => {
                f(qkv);
                f(kcache);
                f(vcache);
            }
            Op::Im2col { input, .. } => f(input),
            Op::AvgPool2 { input, .. } => f(input),
            Op::GlobalAvgPool { input, .. } => f(input),
            Op::Flatten { input, .. } => f(input),
            // h/c are carried state (read-modify), xh/gates pure scratch
            // that the step fully rewrites before reading
            Op::LstmStep { input, h, c, .. } => {
                f(input);
                f(h);
                f(c);
            }
            Op::Residual { src, dst } => {
                f(src);
                f(dst); // dst += src reads dst
            }
            Op::MeanPool { input, .. } => f(input),
            Op::LastPool { input, .. } => f(input),
            Op::Zero { .. } => {}
        }
    }

    /// The buffer this op *fully overwrites* without reading its previous
    /// contents, if any.  Attention scratch (`scores`/`qh`/...) is
    /// excluded: those are internal and never fusion endpoints.
    pub fn full_overwrite(&self) -> Option<BufId> {
        match *self {
            Op::Gemm { out, .. } => Some(out),
            Op::Attention { out, .. } => Some(out),
            Op::DecodeAttend { out, .. } => Some(out),
            Op::Im2col { out, .. } => Some(out),
            Op::AvgPool2 { out, .. } => Some(out),
            Op::GlobalAvgPool { out, .. } => Some(out),
            Op::Flatten { out, .. } => Some(out),
            Op::MeanPool { out, .. } => Some(out),
            Op::LastPool { out, .. } => Some(out),
            Op::Zero { buf } => Some(buf),
            Op::BiasAct { .. }
            | Op::LstmStep { .. }
            | Op::Residual { .. }
            | Op::LayerNorm { .. } => None,
        }
    }
}

/// A compiled, immutable, executable model: ops + packed weights + buffer
/// shapes.  Shared via `Arc` across serving workers; all mutable state
/// lives in `graph::Workspace`.
pub struct GraphProgram {
    /// Workload name ("BERT-base", "VGG16", ... or "residual-mlp").
    pub model: String,
    /// Serving-variant name ("model_dense" / "model_tw" / ...).
    pub variant: String,
    pub ops: Vec<Op>,
    pub weights: Vec<GemmNode>,
    pub biases: Vec<Vec<f32>>,
    /// `(rows, cols)` of every arena buffer at the full compile-time batch.
    pub buf_shapes: Vec<(usize, usize)>,
    /// Per-buffer batch scaling: `Some(rpr)` marks a buffer whose row count
    /// is `rpr` rows per request (so at effective batch `m_eff` it holds
    /// `rpr * m_eff` live rows as a contiguous row-major prefix);
    /// `None` is a batch-independent buffer (attention scratch, conv
    /// activations — conv models serve batch 1).  The executor resizes the
    /// `Some` buffers before a variable-M run (`Workspace::set_effective_batch`);
    /// capacity stays at the full batch, so no allocation happens.
    pub buf_rows_per_request: Vec<Option<usize>>,
    /// Where the packed request batch is written before execution.
    pub input: BufId,
    /// Where the logits are read after execution.
    pub output: BufId,
    pub dims: ModelDims,
    /// Kernel scratch maxima over all weights (`GemmScratch` sizing).
    pub scratch_a: usize,
    pub scratch_c: usize,
    /// Int8 staging maxima (quantized activations / CTO gather / i32
    /// accumulator tile) over all int8-packed weights at the full
    /// compile-time batch.  All zero for a pure-f32 program.
    pub scratch_qa: usize,
    pub scratch_qg: usize,
    pub scratch_qi: usize,
}

impl GraphProgram {
    /// The masked-dense twin: identical topology and buffer layout, every
    /// packed weight decoded back to its masked-dense matrix — the parity
    /// oracle `rust/tests/graph_parity.rs` checks kernels against.
    pub fn to_dense_oracle(&self) -> GraphProgram {
        GraphProgram {
            model: self.model.clone(),
            variant: format!("{}_oracle", self.variant),
            ops: self.ops.clone(),
            weights: self.weights.iter().map(GemmNode::to_dense_oracle).collect(),
            biases: self.biases.clone(),
            buf_shapes: self.buf_shapes.clone(),
            buf_rows_per_request: self.buf_rows_per_request.clone(),
            input: self.input,
            output: self.output,
            dims: self.dims,
            scratch_a: 0,
            scratch_c: 0,
            scratch_qa: 0,
            scratch_qg: 0,
            scratch_qi: 0,
        }
    }

    /// Arena footprint in floats (reporting / workspace sizing sanity).
    pub fn arena_floats(&self) -> usize {
        self.buf_shapes.iter().map(|(r, c)| r * c).sum()
    }
}

/// Incremental program constructor used by `graph::compile` and by
/// backends that define bespoke topologies (the native residual-MLP).
#[derive(Default)]
pub struct GraphBuilder {
    pub(crate) ops: Vec<Op>,
    pub(crate) weights: Vec<GemmNode>,
    pub(crate) biases: Vec<Vec<f32>>,
    pub(crate) buf_shapes: Vec<(usize, usize)>,
    pub(crate) buf_rows_per_request: Vec<Option<usize>>,
}

impl GraphBuilder {
    pub fn new() -> GraphBuilder {
        GraphBuilder::default()
    }

    /// Reserve one arena buffer (batch-independent unless
    /// [`GraphBuilder::scale_by_batch`] marks it afterwards).
    pub fn buffer(&mut self, rows: usize, cols: usize) -> BufId {
        assert!(rows > 0 && cols > 0, "zero-sized graph buffer");
        self.buf_shapes.push((rows, cols));
        self.buf_rows_per_request.push(None);
        BufId(self.buf_shapes.len() - 1)
    }

    /// Mark `id` as batch-scaled: it holds `rows_per_request` rows per
    /// real request, so at effective batch `m_eff` only the first
    /// `rows_per_request * m_eff` rows are live (a contiguous row-major
    /// prefix — the dynamic-M contract of `docs/DESIGN.md` §7).
    pub fn scale_by_batch(&mut self, id: BufId, rows_per_request: usize) {
        assert!(rows_per_request > 0, "batch-scaled buffer needs rows_per_request >= 1");
        let (rows, _) = self.buf_shapes[id.0];
        assert!(
            rows % rows_per_request == 0,
            "buffer rows {rows} not a multiple of rows_per_request {rows_per_request}"
        );
        self.buf_rows_per_request[id.0] = Some(rows_per_request);
    }

    pub fn shape(&self, id: BufId) -> (usize, usize) {
        self.buf_shapes[id.0]
    }

    pub fn push(&mut self, op: Op) {
        self.ops.push(op);
    }

    /// Register a packed weight; returns its table index.
    pub fn add_weight(&mut self, node: GemmNode) -> usize {
        self.weights.push(node);
        self.weights.len() - 1
    }

    /// Register a bias vector; returns its table index.
    pub fn add_bias(&mut self, bias: Vec<f32>) -> usize {
        self.biases.push(bias);
        self.biases.len() - 1
    }

    /// Append a GEMM op: allocates the `(input.rows, node.n)` output
    /// buffer, validates the reduction width, returns the output id.
    /// A batch-scaled input propagates its scaling to the output (a GEMM
    /// is row-wise, so the live-prefix contract carries through).
    pub fn gemm(&mut self, input: BufId, node: GemmNode) -> BufId {
        let (rows, cols) = self.shape(input);
        assert_eq!(cols, node.k, "GEMM {}: input width {} != K {}", node.name, cols, node.k);
        let out = self.buffer(rows, node.n);
        if let Some(rpr) = self.buf_rows_per_request[input.0] {
            self.scale_by_batch(out, rpr);
        }
        let w = self.add_weight(node);
        self.push(Op::Gemm { input, w, out });
        out
    }

    /// Like [`GraphBuilder::gemm`] but writing into an existing buffer
    /// (shape-checked) — lets topologies reuse ping-pong buffers.
    pub fn gemm_into(&mut self, input: BufId, node: GemmNode, out: BufId) {
        let (rows, cols) = self.shape(input);
        assert_eq!(cols, node.k, "GEMM {}: input width {} != K {}", node.name, cols, node.k);
        assert_eq!(self.shape(out), (rows, node.n), "GEMM {}: output buffer shape", node.name);
        let w = self.add_weight(node);
        self.push(Op::Gemm { input, w, out });
    }

    /// Seal the program; computes the kernel-scratch maxima.
    pub fn finish(
        self,
        model: &str,
        variant: &str,
        input: BufId,
        output: BufId,
        dims: ModelDims,
    ) -> GraphProgram {
        let (mut sa, mut sc) = (0usize, 0usize);
        for w in &self.weights {
            let (a, c) = w.scratch_needs();
            sa = sa.max(a);
            sc = sc.max(c);
        }
        // Int8 staging depends on the activation row count, so walk the
        // ops to find each weight's driving buffer at the full
        // compile-time batch (Gemm reads `input`, LstmStep reads `xh`).
        let mut max_rows = vec![0usize; self.weights.len()];
        for op in &self.ops {
            match *op {
                Op::Gemm { input, w, .. } => {
                    max_rows[w] = max_rows[w].max(self.buf_shapes[input.0].0);
                }
                Op::LstmStep { w, xh, .. } => {
                    max_rows[w] = max_rows[w].max(self.buf_shapes[xh.0].0);
                }
                _ => {}
            }
        }
        let (mut qa, mut qg, mut qi) = (0usize, 0usize, 0usize);
        for (w, &rows) in self.weights.iter().zip(&max_rows) {
            let (a, g, i) = w.scratch_needs_int8(rows);
            qa = qa.max(a);
            qg = qg.max(g);
            qi = qi.max(i);
        }
        GraphProgram {
            model: model.to_string(),
            variant: variant.to_string(),
            ops: self.ops,
            weights: self.weights,
            biases: self.biases,
            buf_shapes: self.buf_shapes,
            buf_rows_per_request: self.buf_rows_per_request,
            input,
            output,
            dims,
            scratch_a: sa,
            scratch_c: sc,
            scratch_qa: qa,
            scratch_qg: qg,
            scratch_qi: qi,
        }
    }
}
