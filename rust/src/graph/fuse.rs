//! Graph-level epilogue fusion: rewrite `Gemm -> BiasAct (-> Residual)`
//! chains into a single GEMM node carrying an [`EpilogueSpec`], so the
//! elementwise tail is applied on tile-resident accumulators at store
//! time instead of re-streaming the output through memory (the inter-op
//! round-trip the paper's tiled kernels otherwise pay between layers).
//!
//! The pass runs once at compile time, after the topology is built and
//! before the program is sealed into serving.  It is purely an op-stream
//! rewrite — buffer shapes, weight packing and tile configs are
//! untouched — so every pattern variant of one model fuses identically
//! and the variants keep sharing one arena layout.
//!
//! ## Residual fusion and the buffer swap
//!
//! `Gemm { input, w, out: t }` followed by `Residual { src: t, dst: x }`
//! computes `x += gemm(...)`.  Fused, the kernel writes
//! `t = act(acc + bias) + x_old` directly — buffer `t` now holds the
//! value downstream expects in `x`, and `x` holds its stale pre-residual
//! contents.  The pass therefore renames `t <-> x` in every *subsequent*
//! op (and in the program output).  That swap is sound iff:
//!
//! - `t` and `x` have identical shapes and batch scaling (the rename is
//!   a pure relabeling of interchangeable arena slots), and
//! - no later op reads `t`'s old value: the first later op referencing
//!   `t` must fully overwrite it (ping-pong reuse), or `t` must be dead.
//!
//! The program input is never renamed: request copy-in happens before
//! op 0, which the rewrite does not reach.

use super::ir::{BufId, GraphProgram, Op};
use super::pack::EpilogueSpec;

/// What one [`fuse_program`] call did (surfaced in logs and tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FusionReport {
    /// `BiasAct` ops folded into the preceding GEMM's epilogue.
    pub bias_act_fused: usize,
    /// `Residual` ops folded into the preceding GEMM's epilogue.
    pub residual_fused: usize,
    /// Pure-copy `BiasAct { bias: None, act: None }` ops deleted outright.
    pub noop_dropped: usize,
    /// Arena buffers left unreferenced by fusion and shrunk to zero.
    pub bufs_freed: usize,
}

/// Fuse eligible `Gemm -> BiasAct (-> Residual)` chains in place.
/// Idempotent; safe on any program (ineligible chains are left alone).
pub fn fuse_program(p: &mut GraphProgram) -> FusionReport {
    let mut report = FusionReport::default();

    // 1. no-op BiasAct chains are pure copies: delete them everywhere,
    //    fused or not, before pattern matching sees them
    let before = p.ops.len();
    p.ops.retain(|op| !matches!(op, Op::BiasAct { bias: None, act: None, .. }));
    report.noop_dropped = before - p.ops.len();

    // weight indices used by more than one op can't carry an epilogue
    // (it would fire on every use — LSTM gate weights shared across
    // steps are the live case)
    let mut w_uses = vec![0usize; p.weights.len()];
    for op in &p.ops {
        match *op {
            Op::Gemm { w, .. } | Op::LstmStep { w, .. } => w_uses[w] += 1,
            _ => {}
        }
    }

    // 2. left-to-right chain absorption.  Epilogues attached at earlier
    //    positions are final: a later residual swap renames only ops
    //    *after* its own position, so earlier specs never need patching.
    let mut i = 0;
    while i < p.ops.len() {
        let Op::Gemm { w, out, .. } = p.ops[i] else {
            i += 1;
            continue;
        };
        if w_uses[w] != 1 {
            i += 1;
            continue;
        }
        let mut spec = EpilogueSpec { bias: None, act: None, residual: None };
        let absorb = match p.ops.get(i + 1) {
            Some(&Op::BiasAct { buf, bias, act }) if buf == out => Some((bias, act)),
            _ => None,
        };
        if let Some((bias, act)) = absorb {
            spec.bias = bias;
            spec.act = act;
            p.ops.remove(i + 1);
            report.bias_act_fused += 1;
        }
        let resid = match p.ops.get(i + 1) {
            Some(&Op::Residual { src, dst }) if src == out => Some(dst),
            _ => None,
        };
        if let Some(dst) = resid {
            if residual_swap_is_safe(p, i + 2, out, dst) {
                spec.residual = Some(dst);
                p.ops.remove(i + 1);
                report.residual_fused += 1;
                // rename t <-> x in everything downstream
                for op in &mut p.ops[i + 1..] {
                    op.visit_bufs_mut(|b| {
                        if *b == out {
                            *b = dst;
                        } else if *b == dst {
                            *b = out;
                        }
                    });
                }
                if p.output == out {
                    p.output = dst;
                } else if p.output == dst {
                    p.output = out;
                }
            }
        }
        if spec.bias.is_some() || spec.act.is_some() || spec.residual.is_some() {
            p.weights[w].epilogue = Some(spec);
        }
        i += 1;
    }

    // 3. shrink buffers fusion left unreferenced so the arena stops
    //    allocating them (ping-pong topologies usually free nothing —
    //    both swap endpoints stay live — but dead intermediates from
    //    dropped no-op chains can unhook a buffer entirely)
    let mut live = vec![false; p.buf_shapes.len()];
    live[p.input.0] = true;
    live[p.output.0] = true;
    for op in &p.ops {
        op.visit_bufs(|b| live[b.0] = true);
    }
    for node in &p.weights {
        if let Some(EpilogueSpec { residual: Some(r), .. }) = &node.epilogue {
            live[r.0] = true;
        }
    }
    for (id, alive) in live.iter().enumerate() {
        if !alive && p.buf_shapes[id] != (0, 0) {
            p.buf_shapes[id] = (0, 0);
            p.buf_rows_per_request[id] = None;
            report.bufs_freed += 1;
        }
    }
    report
}

/// Is swapping `t <-> x` in `p.ops[from..]` sound?  (`t` = the fused
/// GEMM's output, `x` = the residual destination.)  See the module docs
/// for the derivation.
fn residual_swap_is_safe(p: &GraphProgram, from: usize, t: BufId, x: BufId) -> bool {
    if t == x
        || p.buf_shapes[t.0] != p.buf_shapes[x.0]
        || p.buf_rows_per_request[t.0] != p.buf_rows_per_request[x.0]
    {
        return false;
    }
    for op in &p.ops[from..] {
        let mut referenced = false;
        op.visit_bufs(|b| referenced |= b == t);
        if !referenced {
            continue;
        }
        let mut read = false;
        op.reads(|b| read |= b == t);
        // the first op touching t must be a clean full overwrite: that
        // re-establishes the naming isomorphism for t itself.  Any read
        // (or a scratch-style partial use) would see the stale value.
        return !read && op.full_overwrite() == Some(t);
    }
    // t is never referenced again: safe unless the program output reads it
    p.output != t
}

#[cfg(test)]
mod tests {
    use super::super::ir::Act;
    use super::super::{compile, CompileOptions, GraphPattern, Op};
    use super::*;
    use crate::models;

    fn ffn_ops(p: &GraphProgram) -> (usize, usize, usize) {
        let gemms = p.ops.iter().filter(|o| matches!(o, Op::Gemm { .. })).count();
        let bias = p.ops.iter().filter(|o| matches!(o, Op::BiasAct { .. })).count();
        let res = p.ops.iter().filter(|o| matches!(o, Op::Residual { .. })).count();
        (gemms, bias, res)
    }

    #[test]
    fn transformer_fusion_removes_every_bias_act_and_residual() {
        let wl = models::bert_at(2, 4, 16, 2);
        let opts = CompileOptions { seq: 4, heads: 4, n_classes: 4, ..CompileOptions::default() };
        for pattern in [GraphPattern::Dense, GraphPattern::Tw] {
            let fused = compile(&wl, &opts.with_pattern(pattern)).unwrap();
            let (gemms, bias, res) = ffn_ops(&fused);
            assert!(gemms >= 4, "{pattern:?}: {gemms} gemms");
            assert_eq!((bias, res), (0, 0), "{pattern:?}: unfused elementwise ops remain");
            let with_epi = fused.weights.iter().filter(|w| w.epilogue.is_some()).count();
            assert!(with_epi >= 4, "{pattern:?}: only {with_epi} fused nodes");
            // every residual endpoint passed the shape/scaling gates
            for w in &fused.weights {
                if let Some(spec) = &w.epilogue {
                    if let Some(r) = spec.residual {
                        assert_ne!(fused.buf_shapes[r.0], (0, 0));
                    }
                }
            }
        }
    }

    #[test]
    fn no_fusion_option_leaves_the_op_stream_alone() {
        let wl = models::bert_at(2, 4, 16, 1);
        let opts = CompileOptions { seq: 4, heads: 4, n_classes: 4, ..CompileOptions::default() };
        let unfused = compile(&wl, &CompileOptions { fuse: false, ..opts.clone() }).unwrap();
        let (_, bias, res) = ffn_ops(&unfused);
        assert!(bias > 0 && res > 0, "unfused program must keep elementwise ops");
        assert!(unfused.weights.iter().all(|w| w.epilogue.is_none()));
    }

    #[test]
    fn noop_bias_act_chains_are_dropped_even_where_fusion_cannot_reach() {
        // hand-build: Gemm -> noop BiasAct where the gemm weight is used
        // twice (fusion-ineligible) — the noop must still disappear
        let wl = models::bert_at(1, 4, 16, 1);
        let opts = CompileOptions { seq: 4, heads: 4, n_classes: 4, ..CompileOptions::default() };
        let mut p = compile(&wl, &CompileOptions { fuse: false, ..opts }).unwrap();
        p.ops.push(Op::BiasAct { buf: p.output, bias: None, act: None });
        let before = p.ops.len();
        let report = fuse_program(&mut p);
        assert!(report.noop_dropped >= 1);
        assert!(p.ops.len() < before);
        assert!(!p.ops.iter().any(|o| matches!(o, Op::BiasAct { bias: None, act: None, .. })));
    }

    #[test]
    fn fusion_is_identical_across_pattern_variants() {
        // the pass decides from ops + shapes only, so every variant of
        // one model must fuse the same chains and keep one arena layout
        let wl = models::bert_at(1, 4, 16, 1);
        let opts = CompileOptions { seq: 4, heads: 4, n_classes: 4, ..CompileOptions::default() };
        let programs: Vec<GraphProgram> =
            [GraphPattern::Dense, GraphPattern::Tw, GraphPattern::Tvw, GraphPattern::Vw24]
                .iter()
                .map(|p| compile(&wl, &opts.with_pattern(*p)).unwrap())
                .collect();
        assert!(programs.windows(2).all(|w| w[0].buf_shapes == w[1].buf_shapes));
        let codes: Vec<Vec<usize>> = programs
            .iter()
            .map(|p| {
                p.weights
                    .iter()
                    .map(|w| w.epilogue.as_ref().map(|e| e.kind_code()).unwrap_or(0))
                    .collect()
            })
            .collect();
        assert!(codes.windows(2).all(|w| w[0] == w[1]), "variants fused differently: {codes:?}");
    }

    #[test]
    fn conv_bias_relu_fuses_into_the_gemm() {
        let wl = models::vgg16_scaled(32, 16, 32);
        let p = compile(&wl, &CompileOptions::default()).unwrap();
        let fused_relu = p
            .weights
            .iter()
            .filter(|w| {
                matches!(
                    &w.epilogue,
                    Some(EpilogueSpec { bias: Some(_), act: Some(Act::Relu), .. })
                )
            })
            .count();
        assert!(fused_relu >= 2, "conv chains should fuse bias+relu, got {fused_relu}");
    }
}
