//! Weight packing: one GEMM operand pruned and encoded into its
//! kernel-ready form, with its cache-blocking resolved from the autotune
//! plan cache — done **once** at graph-compile time, never on the request
//! path.

use std::sync::Arc;

use crate::autotune::{PatternFamily, PlanCache};
use crate::error::Result;
use crate::gemm::{
    int8_dense_panel, int8_tw_pack_panels, micro, tw_pack_panels, Int8Panel, Int8TvwPlan,
    Int8TwPlan, Int8Vw24Plan, PackedPanel, TileConfig,
};
use crate::gpusim::GemmShape;
use crate::quant::{Precision, QuantMatrix};
use crate::sparse::{prune_tvw, prune_tw, prune_vw, TvwPlan, TwPlan, Vw24Plan};
use crate::tensor::Matrix;
use crate::{anyhow, bail};

/// A GEMM weight operand packed into one serving variant's kernel-ready
/// form (the per-layer analogue of the paper's offline compilation step).
/// The `Int8*` forms are the quantize-at-pack variants: the same pruned
/// encoding with values narrowed to i8 and per-output-channel scales
/// carried alongside (`docs/DESIGN.md` §11).
#[derive(Clone)]
pub enum PackedWeight {
    /// Raw row-major weights, run by `gemm::matmul_tiled_into`.
    Dense(Matrix),
    /// TW-pruned condensed tiles + CTO offset tables, run by the fused-CTO
    /// `gemm::tw_matmul_into_scratch`.
    Tw(TwPlan),
    /// TVW-pruned (CTO + 2:4 metadata), run by `gemm::tvw_matmul_into_scratch`.
    Tvw(TvwPlan),
    /// Plain 2:4 along K, run by `gemm::vw24_matmul_into_with`.
    Vw24(Vw24Plan),
    /// Quantized dense weights + per-channel scales, run by
    /// `gemm::int8_matmul_tiled_into`.
    Int8Dense(QuantMatrix),
    /// Quantized TW condensed tiles, run by `gemm::int8_tw_matmul_into`.
    Int8Tw(Int8TwPlan),
    /// Quantized TVW plan, run by `gemm::int8_tvw_matmul_into`.
    Int8Tvw(Int8TvwPlan),
    /// Quantized 2:4 plan, run by `gemm::int8_vw24_matmul_into`.
    Int8Vw24(Int8Vw24Plan),
}

impl PackedWeight {
    pub fn family(&self) -> PatternFamily {
        match self {
            PackedWeight::Dense(_) | PackedWeight::Int8Dense(_) => PatternFamily::Dense,
            PackedWeight::Tw(_) | PackedWeight::Int8Tw(_) => PatternFamily::Tw,
            PackedWeight::Tvw(_) | PackedWeight::Int8Tvw(_) => PatternFamily::Tvw,
            PackedWeight::Vw24(_) | PackedWeight::Int8Vw24(_) => PatternFamily::Vw24,
        }
    }

    /// The numeric precision this operand executes at.
    pub fn precision(&self) -> Precision {
        match self {
            PackedWeight::Dense(_)
            | PackedWeight::Tw(_)
            | PackedWeight::Tvw(_)
            | PackedWeight::Vw24(_) => Precision::Fp32,
            _ => Precision::Int8,
        }
    }

    /// `(K, N)` of the GEMM this operand serves.
    pub fn kn(&self) -> (usize, usize) {
        match self {
            PackedWeight::Dense(w) => (w.rows, w.cols),
            PackedWeight::Tw(p) => (p.k, p.n),
            PackedWeight::Tvw(p) => (p.k, p.n),
            PackedWeight::Vw24(p) => (p.k, p.n),
            PackedWeight::Int8Dense(w) => (w.rows, w.cols),
            PackedWeight::Int8Tw(p) => (p.k, p.n),
            PackedWeight::Int8Tvw(p) => (p.k, p.n),
            PackedWeight::Int8Vw24(p) => (p.k, p.n),
        }
    }

    /// Expand back to the masked-dense weight matrix (the parity oracle;
    /// Int8 forms dequantize, so the oracle carries the quantization
    /// error and parity tests compare at the quantization-aware bound).
    pub fn decode(&self) -> Matrix {
        match self {
            PackedWeight::Dense(w) => w.clone(),
            PackedWeight::Tw(p) => p.decode(),
            PackedWeight::Tvw(p) => p.decode(),
            PackedWeight::Vw24(p) => p.decode(),
            PackedWeight::Int8Dense(w) => w.dequantize(),
            PackedWeight::Int8Tw(p) => p.decode(),
            PackedWeight::Int8Tvw(p) => p.decode(),
            PackedWeight::Int8Vw24(p) => p.decode(),
        }
    }

    /// Bytes the kernel streams from this operand per dispatch (the "B
    /// traffic" term of the profiler's bytes-moved counter) — values at
    /// the node's precision plus offset/metadata tables.
    pub fn weight_bytes(&self) -> usize {
        match self {
            PackedWeight::Dense(w) => w.data.len() * 4,
            PackedWeight::Tw(p) => p.storage_bytes(),
            PackedWeight::Tvw(p) => p.storage_bytes(),
            PackedWeight::Vw24(p) => p.b_vals.len() * 4 + p.b_vals.len() / 4,
            PackedWeight::Int8Dense(w) => w.storage_bytes(),
            PackedWeight::Int8Tw(p) => p.storage_bytes(),
            PackedWeight::Int8Tvw(p) => p.storage_bytes(),
            PackedWeight::Int8Vw24(p) => p.storage_bytes(),
        }
    }
}

/// Packed-B panels built at graph-compile time for the patterns whose
/// weight operand is still strided row-major (dense and TW).  The TVW and
/// 2:4 condensed plans are already panel-contiguous — their value arrays
/// *are* the panel layout — so they carry none (see `docs/DESIGN.md` §9).
#[derive(Clone)]
pub enum NodePanels {
    None,
    Dense(PackedPanel),
    Tw(Vec<PackedPanel>),
    /// Quad-grouped i8 panel over the quantized dense weight.
    Int8Dense(Int8Panel),
    /// Per-tile quad-grouped i8 panels over the quantized condensed tiles.
    Int8Tw(Vec<Int8Panel>),
}

/// A fused GEMM epilogue attached to a node by the graph fusion pass
/// (`graph::fuse`): what the kernel applies on register/tile-resident
/// accumulators at store time instead of separate elementwise passes.
/// `c[i][j] = act(acc[i][j] + biases[bias][j]) + bufs[residual][i][j]`,
/// each part optional.  Indices resolve against the owning program's
/// bias table / arena at execution time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EpilogueSpec {
    /// Index into `GraphProgram::biases`.
    pub bias: Option<usize>,
    pub act: Option<crate::gemm::Act>,
    /// Arena buffer added after the activation (the transformer residual).
    pub residual: Option<super::ir::BufId>,
}

impl EpilogueSpec {
    /// The kernel-layer bit code of this spec (see
    /// [`crate::gemm::epilogue_label`]) — what node telemetry records.
    pub fn kind_code(&self) -> usize {
        let mut code = 0usize;
        if self.bias.is_some() {
            code |= 1;
        }
        match self.act {
            Some(crate::gemm::Act::Relu) => code |= 2,
            Some(crate::gemm::Act::Tanh) => code |= 4,
            None => {}
        }
        if self.residual.is_some() {
            code |= 8;
        }
        code
    }

    /// Arena bytes the fusion avoided per dispatch at `m` rows of an
    /// `m x n` output: an unfused bias/act pass re-reads and re-writes C
    /// (2 sweeps), an unfused residual reads dst + src and writes dst
    /// (3 sweeps).
    pub fn bytes_avoided(&self, m: usize, n: usize) -> u64 {
        let sweep = (m * n * 4) as u64;
        let mut avoided = 0u64;
        if self.bias.is_some() || self.act.is_some() {
            avoided += 2 * sweep;
        }
        if self.residual.is_some() {
            avoided += 3 * sweep;
        }
        avoided
    }
}

/// One GEMM node of the graph: the packed operand plus its resolved
/// cache-blocking.  Ops reference nodes by index into the program's
/// weight table.
#[derive(Clone)]
pub struct GemmNode {
    pub name: String,
    pub weight: PackedWeight,
    /// Tile config resolved at the full compile-time M (the fallback when
    /// no bucket applies).
    pub cfg: TileConfig,
    /// Per-bucket tile plans for dynamic effective-batch dispatch: `(M,
    /// config)` pairs resolved **once** at pack time from the plan cache,
    /// one per power-of-two batch bucket (M ascending).  Empty when the
    /// graph compiled without a cache — dispatch then always uses `cfg`.
    pub bucket_cfgs: Vec<(usize, TileConfig)>,
    pub k: usize,
    pub n: usize,
    /// Microkernel panels packed once at compile time (strip width keyed
    /// to the compile config's resolved NR; the executor re-checks the
    /// width and falls back to the strided kernel on a mismatch).
    pub panels: NodePanels,
    /// Fused store-time epilogue, attached by `graph::fuse` when the op
    /// stream proves the following elementwise ops fold into this GEMM.
    /// `None` straight out of packing.
    pub epilogue: Option<EpilogueSpec>,
}

impl GemmNode {
    /// The masked-dense twin of this node (same tile config), used to
    /// build the naive parity oracle of a compiled graph.
    pub fn to_dense_oracle(&self) -> GemmNode {
        GemmNode {
            name: self.name.clone(),
            weight: PackedWeight::Dense(self.weight.decode()),
            cfg: TileConfig::dense_default(),
            bucket_cfgs: Vec::new(),
            k: self.k,
            n: self.n,
            panels: NodePanels::None,
            // the oracle keeps the fused epilogue: a fused program's twin
            // must compute the same function
            epilogue: self.epilogue.clone(),
        }
    }

    /// The tile config to dispatch with at `m` activation rows: the
    /// smallest pre-resolved bucket covering `m` (exact bucket when `m`
    /// is itself a bucket M), else the largest bucket, else the node's
    /// compile default — the resolution order of `docs/DESIGN.md` §7.
    pub fn cfg_for_m(&self, m: usize) -> TileConfig {
        self.bucket_cfgs
            .iter()
            .find(|(bm, _)| *bm >= m)
            .or_else(|| self.bucket_cfgs.last())
            .map(|(_, cfg)| *cfg)
            .unwrap_or(self.cfg)
    }

    /// Useful floating-point work one dispatch at `m` activation rows
    /// performs — the numerator of the profiler's achieved-GFLOP/s.
    /// Dense counts the full `2·m·k·n`; TW/TVW count only the surviving
    /// condensed columns (the plans' own accounting); 2:4 is exactly half
    /// dense by construction.
    pub fn flops(&self, m: usize) -> u64 {
        match &self.weight {
            PackedWeight::Dense(_) | PackedWeight::Int8Dense(_) => {
                2 * (m * self.k * self.n) as u64
            }
            PackedWeight::Tw(p) => p.flops(m) as u64,
            PackedWeight::Tvw(p) => p.flops(m) as u64,
            PackedWeight::Vw24(_) | PackedWeight::Int8Vw24(_) => (m * self.k * self.n) as u64,
            // the int8 plans condense identically to their f32 twins
            PackedWeight::Int8Tw(p) => {
                2 * (m * p.g * p.row_len.iter().map(|&x| x as usize).sum::<usize>()) as u64
            }
            PackedWeight::Int8Tvw(p) => {
                (m * p.g * p.row_len.iter().map(|&x| x as usize).sum::<usize>()) as u64
            }
        }
    }

    /// Bytes one dispatch at `m` activation rows moves: the activation
    /// operand at the node's precision (int8 nodes stream the quantized
    /// copy), the packed weight, and the f32 output.  The profiler's
    /// memory-traffic counter — comparing a node's fp32 and int8 figures
    /// shows the B-traffic halving the quantized path buys.
    pub fn bytes_moved(&self, m: usize) -> u64 {
        let a_elem = match self.weight.precision() {
            Precision::Int8 => 1,
            _ => 4,
        };
        (m * self.k * a_elem + self.weight.weight_bytes() + m * self.n * 4) as u64
    }

    /// Serial-kernel scratch this node needs: `(a_gather, c_tile)` staging
    /// lengths (see [`crate::gemm::GemmScratch`]); dense and 2:4 kernels
    /// stage nothing.  Sized over the compile config *and* every bucket
    /// config, so variable-M dispatch never grows the scratch on the
    /// request path.
    pub fn scratch_needs(&self) -> (usize, usize) {
        let bm_max = self.bm_max();
        match &self.weight {
            PackedWeight::Tw(p) => (bm_max * p.kmax, bm_max * p.g),
            PackedWeight::Tvw(p) => (p.kmax, p.g),
            _ => (0, 0), // dense, 2:4 and every int8 form stage elsewhere
        }
    }

    /// Largest row block any dispatch of this node can use.
    fn bm_max(&self) -> usize {
        self.bucket_cfgs.iter().map(|(_, cfg)| cfg.bm()).fold(self.cfg.bm(), usize::max)
    }

    /// Int8 staging this node needs at up to `max_rows` activation rows:
    /// `(qa, qg, qi)` lengths (quantized activations, CTO gather block,
    /// i32 accumulator — see [`crate::gemm::GemmScratch`]).  Zero for f32
    /// nodes.
    pub fn scratch_needs_int8(&self, max_rows: usize) -> (usize, usize, usize) {
        let bm = self.bm_max().min(max_rows.max(1));
        let qa = max_rows * crate::gemm::int8::quad_stride(self.k);
        match &self.weight {
            PackedWeight::Int8Dense(_) => (qa, 0, max_rows * self.n),
            PackedWeight::Int8Tw(p) => (qa, bm * p.kmax, bm * p.g),
            PackedWeight::Int8Tvw(p) => (qa, p.kmax, p.g),
            PackedWeight::Int8Vw24(_) => (qa, 0, self.n),
            _ => (0, 0, 0),
        }
    }
}

/// Pruning parameters shared by every packed layer of one graph.
#[derive(Clone, Copy, Debug)]
pub struct PackOptions {
    /// Target sparsity for TW / TVW (TVW floors at 0.5).
    pub sparsity: f64,
    /// TW tile granularity G (clamped to the layer's N).
    pub g: usize,
    /// Numeric precision to pack at.  `Auto` asks the plan cache per
    /// layer shape and falls back to f32 for untuned shapes.
    pub precision: Precision,
}

impl Default for PackOptions {
    fn default() -> Self {
        PackOptions { sparsity: 0.75, g: 32, precision: Precision::Fp32 }
    }
}

/// Resolve a layer's tile config: serving-time nearest-match lookup in the
/// plan cache (exact on `(K, N, pattern)`, nearest on M/sparsity/threads —
/// the tuner keys DENSE at sparsity 0 and caps M, so exact probes would
/// miss), falling back to the family's historical default.
pub fn resolve_tile(
    cache: Option<&PlanCache>,
    shape: GemmShape,
    family: PatternFamily,
    sparsity: f64,
) -> TileConfig {
    let fallback = match family {
        PatternFamily::Dense => TileConfig::dense_default(),
        PatternFamily::Tw => TileConfig::tw_default(),
        PatternFamily::Tvw => TileConfig::tvw_default(),
        PatternFamily::Vw24 => TileConfig::vw_default(),
    };
    cache
        .and_then(|c| c.lookup_tile_config(shape, family.label(), sparsity))
        .unwrap_or(fallback)
}

/// Prune + encode one weight matrix into `family`'s kernel-ready form and
/// resolve its tile config.  `m_hint` is the activation row count the
/// layer serves at the full compile-time batch (the M the cache lookup
/// transfers across); `m_buckets` lists the additional M values to
/// pre-resolve for dynamic effective-batch dispatch (one per power-of-two
/// batch bucket — empty for batch-independent layers).  A 2:4 request
/// on a K not divisible by 4 degrades to Dense — the same "keep
/// hardware-incompatible layers dense" rule the paper applies to
/// accuracy-critical layers.
pub fn pack_weight(
    name: &str,
    w: &Matrix,
    m_hint: usize,
    m_buckets: &[usize],
    family: PatternFamily,
    opts: &PackOptions,
    cache: Option<&PlanCache>,
) -> Result<GemmNode> {
    let (k, n) = (w.rows, w.cols);
    if k == 0 || n == 0 {
        bail!("layer {name:?} has a zero-dimension weight ({k}x{n})");
    }
    let shape = GemmShape::new(m_hint, k, n);
    let g = opts.g.clamp(1, n);
    let (weight, family, sparsity) = match family {
        PatternFamily::Dense => {
            (PackedWeight::Dense(w.clone()), PatternFamily::Dense, opts.sparsity)
        }
        PatternFamily::Tw => {
            let tw = prune_tw(w, opts.sparsity, g, None);
            (PackedWeight::Tw(TwPlan::encode(w, &tw)), PatternFamily::Tw, opts.sparsity)
        }
        PatternFamily::Tvw => {
            let s = opts.sparsity.max(0.5);
            let (tw, mask) = prune_tvw(w, s, g);
            (PackedWeight::Tvw(TvwPlan::encode(w, &tw, &mask)), PatternFamily::Tvw, s)
        }
        PatternFamily::Vw24 => {
            if k % 4 != 0 {
                // hardware-incompatible layer: serve it dense
                (PackedWeight::Dense(w.clone()), PatternFamily::Dense, opts.sparsity)
            } else {
                let mask = prune_vw(w, 0.5, 4);
                let plan = Vw24Plan::encode(w, &mask)
                    .map_err(|e| anyhow!("packing 2:4 plan for {name:?}: {e}"))?;
                (PackedWeight::Vw24(plan), PatternFamily::Vw24, 0.5)
            }
        }
    };
    // quantize-at-pack: the f32 pruned encoding converts to its i8 twin
    // here, once, so the request path never touches f32 weights.  `Auto`
    // defers to the plan cache's per-shape precision pick (f32 when the
    // shape is untuned).
    let precision = match opts.precision {
        Precision::Auto => cache
            .and_then(|c| c.lookup_precision(shape, family.label(), sparsity))
            .unwrap_or(Precision::Fp32),
        p => p,
    };
    let weight = if precision == Precision::Int8 {
        match weight {
            PackedWeight::Dense(m) => PackedWeight::Int8Dense(QuantMatrix::quantize(&m)),
            PackedWeight::Tw(p) => PackedWeight::Int8Tw(Int8TwPlan::from_plan(&p)),
            PackedWeight::Tvw(p) => PackedWeight::Int8Tvw(Int8TvwPlan::from_plan(&p)),
            PackedWeight::Vw24(p) => PackedWeight::Int8Vw24(Int8Vw24Plan::from_plan(&p)),
            w => w,
        }
    } else {
        weight
    };
    let cfg = resolve_tile(cache, shape, family, sparsity);
    // per-bucket tile plans: probe the cache once per bucket M at pack
    // time so dispatch is a table walk, never a cache lookup.  Without a
    // cache every bucket would resolve to the family default == `cfg`, so
    // the table is skipped entirely.
    let bucket_cfgs = match cache {
        Some(c) => {
            let mut bs: Vec<usize> = m_buckets.to_vec();
            bs.sort_unstable();
            bs.dedup();
            bs.into_iter()
                .map(|mb| (mb, resolve_tile(Some(c), GemmShape::new(mb, k, n), family, sparsity)))
                .collect()
        }
        None => Vec::new(),
    };
    // packed-B panels for the microkernel, built once here so the serving
    // path never re-packs.  Strip width comes from the compile config's
    // resolved ISA; run-time dispatch re-checks it (a bucket config that
    // resolves to a different NR just takes the strided SIMD path).
    let r = micro::resolve(&cfg);
    let panels = if !r.is_simd() {
        NodePanels::None
    } else {
        match &weight {
            PackedWeight::Dense(m) => {
                NodePanels::Dense(PackedPanel::pack(&m.data, m.rows, m.cols, m.cols, r.nr))
            }
            PackedWeight::Tw(p) => NodePanels::Tw(tw_pack_panels(p, r.nr)),
            PackedWeight::Int8Dense(q) => NodePanels::Int8Dense(int8_dense_panel(q, r.nr)),
            PackedWeight::Int8Tw(p) => NodePanels::Int8Tw(int8_tw_pack_panels(p, r.nr)),
            _ => NodePanels::None,
        }
    };
    Ok(GemmNode { name: name.to_string(), weight, cfg, bucket_cfgs, k, n, panels, epilogue: None })
}

/// Which pattern a compiled graph variant packs its prunable layers with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphPattern {
    Dense,
    Tw,
    Tvw,
    Vw24,
    /// Per-layer selection from the autotune plan cache (see
    /// `docs/DESIGN.md` §6 for the resolution order).
    Auto,
}

impl GraphPattern {
    /// The serving-variant name this pattern maps to (the router's
    /// vocabulary).
    pub fn variant_name(&self) -> &'static str {
        match self {
            GraphPattern::Dense => "model_dense",
            GraphPattern::Tw => "model_tw",
            GraphPattern::Tvw => "model_tvw",
            GraphPattern::Vw24 => "model_vw24",
            GraphPattern::Auto => "model_auto",
        }
    }

    pub fn from_variant(name: &str) -> Option<GraphPattern> {
        Some(match name {
            "model_dense" => GraphPattern::Dense,
            "model_tw" => GraphPattern::Tw,
            "model_tvw" => GraphPattern::Tvw,
            "model_vw24" => GraphPattern::Vw24,
            "model_auto" => GraphPattern::Auto,
            _ => return None,
        })
    }

    /// The concrete family for one prunable layer.  Fixed patterns map
    /// 1:1; `Auto` resolves through the plan cache: (1) the tuner's
    /// per-workload serving recommendation, (2) the best measured tuned
    /// entry at the layer's exact `(K, N)`, (3) TW at the compile
    /// sparsity (the paper's default serving pattern).
    pub fn family_for_layer(
        &self,
        model: &str,
        shape: GemmShape,
        cache: Option<&Arc<PlanCache>>,
    ) -> PatternFamily {
        match self {
            GraphPattern::Dense => PatternFamily::Dense,
            GraphPattern::Tw => PatternFamily::Tw,
            GraphPattern::Tvw => PatternFamily::Tvw,
            GraphPattern::Vw24 => PatternFamily::Vw24,
            GraphPattern::Auto => {
                let Some(cache) = cache else { return PatternFamily::Tw };
                if let Some(fam) = cache
                    .model_variant(model)
                    .and_then(GraphPattern::from_variant)
                    .and_then(|p| match p {
                        GraphPattern::Dense => Some(PatternFamily::Dense),
                        GraphPattern::Tw => Some(PatternFamily::Tw),
                        GraphPattern::Tvw => Some(PatternFamily::Tvw),
                        GraphPattern::Vw24 => Some(PatternFamily::Vw24),
                        GraphPattern::Auto => None,
                    })
                {
                    return fam;
                }
                cache
                    .entries()
                    .filter(|e| e.key.k == shape.k && e.key.n == shape.n)
                    .min_by(|a, b| a.measured_us.total_cmp(&b.measured_us))
                    .and_then(|e| PatternFamily::from_label(&e.key.pattern))
                    .unwrap_or(PatternFamily::Tw)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::{PlanKey, TunedEntry};
    use crate::util::Rng;

    #[test]
    fn pack_families_roundtrip_through_decode() {
        let mut rng = Rng::new(40);
        let w = Matrix::randn(32, 48, &mut rng);
        let opts = PackOptions { sparsity: 0.75, g: 16, ..Default::default() };
        let families =
            [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw, PatternFamily::Vw24];
        for fam in families {
            let node = pack_weight("l", &w, 8, &[], fam, &opts, None).unwrap();
            assert_eq!(node.weight.family(), fam, "{fam:?}");
            assert_eq!(node.weight.kn(), (32, 48));
            let dec = node.weight.decode();
            assert_eq!((dec.rows, dec.cols), (32, 48));
            if fam == PatternFamily::Dense {
                assert_eq!(dec, w);
            } else {
                // pruning must actually remove weight
                let zeros = dec.data.iter().filter(|v| **v == 0.0).count();
                assert!(zeros > w.data.len() / 4, "{fam:?}");
            }
        }
    }

    #[test]
    fn int8_pack_quantizes_every_family_and_decodes_close() {
        let mut rng = Rng::new(43);
        let w = Matrix::randn(32, 48, &mut rng);
        let opts =
            PackOptions { sparsity: 0.75, g: 16, precision: crate::quant::Precision::Int8 };
        let families =
            [PatternFamily::Dense, PatternFamily::Tw, PatternFamily::Tvw, PatternFamily::Vw24];
        for fam in families {
            let node = pack_weight("l", &w, 8, &[], fam, &opts, None).unwrap();
            assert_eq!(node.weight.family(), fam, "{fam:?}");
            assert_eq!(node.weight.precision(), crate::quant::Precision::Int8);
            assert_eq!(node.weight.kn(), (32, 48));
            // the dequantized oracle stays close to the f32 pack of the
            // same family
            let f32_opts = PackOptions { sparsity: 0.75, g: 16, ..Default::default() };
            let f32_node = pack_weight("l", &w, 8, &[], fam, &f32_opts, None).unwrap();
            let d = node.weight.decode().max_abs_diff(&f32_node.weight.decode());
            let amax = w.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            assert!(d <= amax / 254.0 + 1e-6, "{fam:?}: {d}");
            // quantized storage beats f32 storage
            assert!(node.weight.weight_bytes() < f32_node.weight.weight_bytes(), "{fam:?}");
            // int8 scratch is requested, f32 scratch is not (and vice versa)
            assert_eq!(f32_node.scratch_needs_int8(8), (0, 0, 0));
            let (qa, _, qi) = node.scratch_needs_int8(8);
            assert!(qa >= 8 * 32 && qi > 0, "{fam:?}");
        }
    }

    #[test]
    fn vw24_on_bad_k_degrades_to_dense() {
        let mut rng = Rng::new(41);
        let w = Matrix::randn(27, 16, &mut rng); // K = 27, not 2:4-compatible
        let node =
            pack_weight("c1", &w, 4, &[], PatternFamily::Vw24, &PackOptions::default(), None)
                .unwrap();
        assert_eq!(node.weight.family(), PatternFamily::Dense);
    }

    #[test]
    fn bucket_configs_resolve_per_m_and_dispatch_covers() {
        // two tuned entries at different M for one (K, N, TW): the packed
        // node must carry one config per bucket and dispatch the covering
        // bucket's config for any effective M
        let (k, n) = (96, 128);
        let mut cache = PlanCache::new();
        for (m, bm) in [(4usize, 2usize), (64, 48)] {
            cache.insert(TunedEntry {
                key: PlanKey::new(GemmShape::new(m, k, n), "TW", 0.75, 1),
                variant: "tw-fused".into(),
                bm,
                bk: 64,
                g: 16,
                threads: 1,
                micro: "auto".into(),
                precision: "fp32".into(),
                measured_us: 10.0,
                model_us: 9.0,
                default_us: 20.0,
            });
        }
        let mut rng = Rng::new(42);
        let w = Matrix::randn(k, n, &mut rng);
        let opts = PackOptions { sparsity: 0.75, g: 16, ..Default::default() };
        let node =
            pack_weight("l", &w, 64, &[4, 16, 64], PatternFamily::Tw, &opts, Some(&cache)).unwrap();
        assert_eq!(node.bucket_cfgs.len(), 3);
        // exact bucket M hits its tuned entry; in-between M takes the
        // smallest covering bucket; beyond-largest falls to the last
        assert_eq!(node.cfg_for_m(4), TileConfig::new(2, 64));
        assert_eq!(node.cfg_for_m(3), TileConfig::new(2, 64));
        assert_eq!(node.cfg_for_m(64), TileConfig::new(48, 64));
        assert_eq!(node.cfg_for_m(17), TileConfig::new(48, 64));
        assert_eq!(node.cfg_for_m(1000), TileConfig::new(48, 64));
        // scratch is sized over every bucket config, not just the default
        let (sa, _) = node.scratch_needs();
        assert!(sa >= 48, "scratch must cover the largest bucket bm, got {sa}");
        // no cache -> no bucket table, dispatch uses the compile default
        let bare = pack_weight("l", &w, 64, &[4, 64], PatternFamily::Tw, &opts, None).unwrap();
        assert!(bare.bucket_cfgs.is_empty());
        assert_eq!(bare.cfg_for_m(4), bare.cfg);
    }

    #[test]
    fn auto_resolves_recommendation_then_best_entry() {
        let shape = GemmShape::new(64, 96, 128);
        let mut cache = PlanCache::new();
        cache.insert(TunedEntry {
            key: PlanKey::new(shape, "TVW", 0.75, 1),
            variant: "tvw".into(),
            bm: 8,
            bk: 64,
            g: 16,
            threads: 1,
            micro: "auto".into(),
            precision: "fp32".into(),
            measured_us: 10.0,
            model_us: 9.0,
            default_us: 20.0,
        });
        cache.insert(TunedEntry {
            key: PlanKey::new(shape, "DENSE", 0.0, 1),
            variant: "dense".into(),
            bm: 64,
            bk: 64,
            g: 0,
            threads: 1,
            micro: "auto".into(),
            precision: "fp32".into(),
            measured_us: 30.0,
            model_us: 28.0,
            default_us: 30.0,
        });
        let cache = Arc::new(cache);
        // best measured entry at (K, N) wins when no recommendation is set
        assert_eq!(
            GraphPattern::Auto.family_for_layer("bert", shape, Some(&cache)),
            PatternFamily::Tvw
        );
        // an explicit per-workload recommendation takes precedence
        let mut with_rec = (*cache).clone();
        with_rec.set_model_variant("bert", "model_tw");
        assert_eq!(
            GraphPattern::Auto.family_for_layer("bert", shape, Some(&Arc::new(with_rec))),
            PatternFamily::Tw
        );
        // no cache: the paper's default serving pattern
        assert_eq!(GraphPattern::Auto.family_for_layer("bert", shape, None), PatternFamily::Tw);
    }
}
