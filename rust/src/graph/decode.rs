//! Streaming autoregressive decode over the graph IR.
//!
//! A decode-capable model carries, next to its one-shot programs, a
//! [`DecodeSet`]: one *step program* per variant (the per-step twin of
//! the one-shot op list — same packed weights, no state reset) plus a
//! token-embedding table for generated-token feedback.  The
//! [`DecodeEngine`] owns the mutable side: a dedicated [`Workspace`]
//! whose batch-scaled buffers hold **per-slot state** (LSTM `h`/`c`
//! rows, appendable KV-cache row ranges) that persists across steps,
//! and per-slot session bookkeeping (prompt, position, last token).
//!
//! The step model is *unified prefill/decode*: every slot consumes one
//! input row per global step — its next prompt row while the prompt
//! lasts, then the embedding of its previous argmax token.  A joining
//! request therefore interleaves its prompt consumption with other
//! slots' generation; no separate prefill pass exists, which is what
//! makes step-boundary admission safe (a prefill pass over the shared
//! state buffers would clobber resident slots).
//!
//! Execution uses the *high-water prefix*: slots are allocated
//! lowest-free-first and a step runs at effective batch
//! `highest_active_slot + 1` through the same variable-M machinery as
//! one-shot serving (`Workspace::set_effective_batch`).  Rows of
//! retired slots inside the prefix are zeroed ([`Workspace::reset_slot`])
//! so they compute bounded garbage until reused.
//!
//! Parity contract (pinned by `tests/decode_parity.rs`): after a slot
//! consumes its full prompt, its streamed logits at the last prompt
//! step equal a one-shot forward of the same prompt at 1e-4 — the step
//! program replays the one-shot weight-draw order from the same seed,
//! and every op is row-wise, so resident slots are unaffected by
//! admission/retirement of their neighbours.

use std::sync::Arc;

use crate::error::Result;
use crate::exec::{DecodeCaps, StepOut};
use crate::pool::ThreadPool;
use crate::tensor::Matrix;
use crate::{bail, ensure};

use super::exec::{execute, Workspace};
use super::ir::GraphProgram;

/// The immutable decode half of a compiled model: per-variant step
/// programs (sharing one arena layout) plus the token-embedding table.
/// `Arc`-shared across workers like the one-shot programs.
pub struct DecodeSet {
    /// One step program per variant; op lists advance every resident
    /// slot by one step (no `Op::Zero` state resets).
    pub programs: Vec<GraphProgram>,
    /// `(n_classes, d_in)` embedding used to feed generated tokens back
    /// as the next step's input row.  Decode-only: prompt-parity never
    /// reads it, so it is drawn from its own seed stream.
    pub embed: Matrix,
    /// Per-slot state capacity in steps (KV-cache rows per slot); a
    /// slot's `prompt_steps + generated` may not exceed it.
    pub max_steps: usize,
}

/// One workspace slot's session bookkeeping.
#[derive(Clone, Default)]
struct Slot {
    active: bool,
    /// Flattened `(prompt_steps, d_in)` prompt rows, consumed one per step.
    prompt: Vec<f32>,
    prompt_steps: usize,
    /// Steps already executed for this slot (== its cache length).
    pos: usize,
    /// argmax of the previous step's logits (feedback input after the
    /// prompt is consumed).
    last_token: usize,
}

/// Mutable decode state for one worker's model: slot table + the decode
/// workspace whose batch-scaled rows are the per-slot recurrent/KV state.
pub struct DecodeEngine {
    set: Arc<DecodeSet>,
    ws: Workspace,
    slots: Vec<Slot>,
}

impl DecodeEngine {
    pub fn new(set: Arc<DecodeSet>) -> Result<DecodeEngine> {
        ensure!(!set.programs.is_empty(), "decode set needs at least one step program");
        let first = &set.programs[0];
        for p in set.programs.iter().skip(1) {
            ensure!(
                p.buf_shapes == first.buf_shapes
                    && p.dims == first.dims
                    && p.buf_rows_per_request == first.buf_rows_per_request,
                "decode variants must share one arena layout ({} vs {})",
                p.variant,
                first.variant
            );
        }
        ensure!(
            set.embed.cols == first.dims.d_model,
            "embedding width {} != decode input width {}",
            set.embed.cols,
            first.dims.d_model
        );
        ensure!(set.max_steps >= 1, "decode set needs max_steps >= 1");
        let slots = vec![Slot::default(); first.dims.batch];
        let ws = Workspace::for_program(first);
        Ok(DecodeEngine { set: Arc::clone(&set), ws, slots })
    }

    pub fn caps(&self) -> DecodeCaps {
        let dims = self.set.programs[0].dims;
        DecodeCaps { slots: dims.batch, max_steps: self.set.max_steps, d_in: dims.d_model }
    }

    pub fn active_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.active).count()
    }

    /// Lowest free slot, if any — the allocation order that keeps the
    /// high-water execution prefix tight.
    pub fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| !s.active)
    }

    /// Admit a session into `slot`: validates the prompt, zeroes the
    /// slot's state rows, and arms its prompt cursor.  Steps begin on
    /// the next [`DecodeEngine::step`] call — admission happens only at
    /// step boundaries by construction.
    pub fn begin(&mut self, slot: usize, prompt: &[f32]) -> Result<()> {
        let caps = self.caps();
        ensure!(slot < caps.slots, "slot {slot} out of range 0..{}", caps.slots);
        ensure!(!self.slots[slot].active, "slot {slot} already occupied");
        ensure!(
            !prompt.is_empty() && prompt.len() % caps.d_in == 0,
            "prompt length {} not a positive multiple of d_in {}",
            prompt.len(),
            caps.d_in
        );
        let prompt_steps = prompt.len() / caps.d_in;
        ensure!(
            prompt_steps <= caps.max_steps,
            "prompt of {prompt_steps} steps exceeds slot capacity {}",
            caps.max_steps
        );
        self.ws.reset_slot(&self.set.programs[0], slot);
        self.slots[slot] = Slot {
            active: true,
            prompt: prompt.to_vec(),
            prompt_steps,
            pos: 0,
            last_token: 0,
        };
        Ok(())
    }

    /// Retire `slot` (idempotent): its state rows are zeroed so the dead
    /// row computes bounded values while it stays inside the high-water
    /// prefix, and the slot becomes claimable by the next admission.
    pub fn end(&mut self, slot: usize) -> Result<()> {
        let caps = self.caps();
        ensure!(slot < caps.slots, "slot {slot} out of range 0..{}", caps.slots);
        self.slots[slot] = Slot::default();
        self.ws.reset_slot(&self.set.programs[0], slot);
        Ok(())
    }

    /// Advance every resident slot by one step under `variant`.
    ///
    /// All concurrently-resident slots must decode under the *same*
    /// variant: a step is one row-wise pass through that variant's
    /// packed weights, so mixing variants within a step is unexecutable
    /// — the coordinator's scheduler enforces a single-variant in-flight
    /// set at admission.
    pub fn step(&mut self, variant: &str, intra: Option<&ThreadPool>) -> Result<Vec<StepOut>> {
        let set = Arc::clone(&self.set);
        let Some(p) = set.programs.iter().find(|p| p.variant == variant) else {
            bail!("variant {variant:?} has no compiled decode program");
        };
        let Some(high_water) = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.active)
            .map(|(i, _)| i + 1)
            .next_back()
        else {
            return Ok(Vec::new());
        };
        let d_in = p.dims.d_model;
        for (i, s) in self.slots.iter().enumerate().take(high_water) {
            ensure!(
                !s.active || s.pos < set.max_steps,
                "slot {i} exceeded its {}-step capacity without retirement",
                set.max_steps
            );
        }
        self.ws.set_effective_batch(p, high_water);
        // per-slot cache positions for DecodeAttend; dead prefix rows sit
        // at 0 and overwrite their own scratch cache row harmlessly
        for b in 0..self.slots.len() {
            self.ws.slot_pos[b] = if self.slots[b].active { self.slots[b].pos } else { 0 };
        }
        {
            let input = self.ws.buf_mut(p.input);
            debug_assert_eq!(input.cols, d_in);
            for b in 0..high_water {
                let row = input.row_mut(b);
                let s = &self.slots[b];
                if !s.active {
                    row.fill(0.0);
                } else if s.pos < s.prompt_steps {
                    row.copy_from_slice(&s.prompt[s.pos * d_in..(s.pos + 1) * d_in]);
                } else {
                    let tok = s.last_token.min(set.embed.rows - 1);
                    row.copy_from_slice(set.embed.row(tok));
                }
            }
        }
        execute(p, &mut self.ws, intra);
        let out = self.ws.buf(p.output);
        let mut results = Vec::with_capacity(self.active_slots());
        for b in 0..high_water {
            if !self.slots[b].active {
                continue;
            }
            let logits = out.row(b).to_vec();
            let token = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            let s = &mut self.slots[b];
            let step = s.pos;
            s.pos += 1;
            s.last_token = token;
            results.push(StepOut {
                slot: b,
                step,
                token,
                prompt_done: s.pos >= s.prompt_steps,
                logits,
            });
        }
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{compile, compile_decode, CompileOptions, GraphPattern, PackOptions};
    use crate::models;

    fn nmt_opts() -> CompileOptions {
        CompileOptions {
            pack: PackOptions { sparsity: 0.75, g: 8, ..Default::default() },
            ..CompileOptions::default()
        }
    }

    fn nmt_engine(pattern: GraphPattern) -> DecodeEngine {
        let wl = models::nmt_at(2, 16, 4);
        let set = compile_decode(&wl, &nmt_opts().with_pattern(pattern), 8).unwrap();
        DecodeEngine::new(Arc::new(set)).unwrap()
    }

    #[test]
    fn lifecycle_admits_steps_and_retires() {
        let mut eng = nmt_engine(GraphPattern::Dense);
        let caps = eng.caps();
        assert_eq!(caps.slots, 2);
        assert_eq!(caps.d_in, 16);
        assert_eq!(eng.free_slot(), Some(0));

        let prompt: Vec<f32> = (0..4 * 16).map(|i| ((i % 5) as f32 - 2.0) * 0.3).collect();
        eng.begin(0, &prompt).unwrap();
        assert_eq!(eng.free_slot(), Some(1));
        assert!(eng.begin(0, &prompt).is_err(), "double admission must fail");

        // 4 prompt steps then 2 generated
        for step in 0..6 {
            let outs = eng.step("model_dense", None).unwrap();
            assert_eq!(outs.len(), 1);
            let o = &outs[0];
            assert_eq!((o.slot, o.step), (0, step));
            assert_eq!(o.prompt_done, step >= 3);
            assert!(o.logits.iter().all(|v| v.is_finite()));
        }
        eng.end(0).unwrap();
        eng.end(0).unwrap(); // idempotent
        assert_eq!(eng.active_slots(), 0);
        assert!(eng.step("model_dense", None).unwrap().is_empty(), "no slots -> no work");
    }

    #[test]
    fn prompt_validation_rejects_bad_shapes() {
        let mut eng = nmt_engine(GraphPattern::Tw);
        assert!(eng.begin(0, &[]).is_err());
        assert!(eng.begin(0, &[0.0; 17]).is_err(), "not a multiple of d_in");
        assert!(eng.begin(0, &[0.0; 16 * 9]).is_err(), "prompt longer than max_steps");
        assert!(eng.begin(5, &[0.0; 16]).is_err(), "slot out of range");
    }

    #[test]
    fn generation_is_deterministic_and_slot_isolated() {
        // slot 1 decoding alone must generate the same tokens as slot 1
        // decoding next to a neighbour that joins and leaves
        let prompt_a: Vec<f32> = (0..2 * 16).map(|i| ((i % 7) as f32 - 3.0) * 0.25).collect();
        let prompt_b: Vec<f32> = (0..3 * 16).map(|i| ((i % 4) as f32 - 1.5) * 0.4).collect();

        let mut solo = nmt_engine(GraphPattern::Tvw);
        solo.begin(0, &prompt_b).unwrap();
        let mut solo_tokens = Vec::new();
        for _ in 0..6 {
            let outs = solo.step("model_tvw", None).unwrap();
            solo_tokens.push(outs[0].token);
        }

        let mut busy = nmt_engine(GraphPattern::Tvw);
        busy.begin(0, &prompt_a).unwrap();
        busy.step("model_tvw", None).unwrap();
        let slot = busy.free_slot().unwrap();
        assert_eq!(slot, 1);
        busy.begin(slot, &prompt_b).unwrap();
        let mut busy_tokens = Vec::new();
        for step in 0..6 {
            if step == 3 {
                busy.end(0).unwrap(); // neighbour leaves mid-decode
            }
            let outs = busy.step("model_tvw", None).unwrap();
            let o = outs.iter().find(|o| o.slot == 1).unwrap();
            busy_tokens.push(o.token);
        }
        assert_eq!(solo_tokens, busy_tokens, "neighbour churn must not perturb a slot");
    }

    #[test]
    fn streamed_prompt_matches_one_shot_logits() {
        // the core parity claim at engine level (the full four-pattern
        // sweep lives in tests/decode_parity.rs)
        let wl = models::nmt_at(2, 16, 4);
        let opts = nmt_opts();
        let p = compile(&wl, &opts).unwrap();
        let set = compile_decode(&wl, &opts, 8).unwrap();
        let x: Vec<f32> = (0..2 * 4 * 16).map(|i| ((i % 9) as f32 - 4.0) * 0.2).collect();

        let mut one_shot =
            crate::graph::GraphModel::new(Arc::new(vec![p]), None).unwrap();
        use crate::exec::PreparedModel;
        let want = one_shot.run("model_dense", &x).unwrap();
        let n_classes = want.len() / 2;

        let mut eng = DecodeEngine::new(Arc::new(set)).unwrap();
        let per = 4 * 16;
        eng.begin(0, &x[..per]).unwrap();
        eng.begin(1, &x[per..]).unwrap();
        let mut last = vec![Vec::new(), Vec::new()];
        for _ in 0..4 {
            for o in eng.step("model_dense", None).unwrap() {
                last[o.slot] = o.logits.clone();
            }
        }
        for slot in 0..2 {
            let got = &last[slot];
            assert_eq!(got.len(), n_classes);
            let want_row = &want[slot * n_classes..(slot + 1) * n_classes];
            for (a, b) in got.iter().zip(want_row) {
                assert!((a - b).abs() < 1e-4, "slot {slot}: {a} vs {b}");
            }
        }
    }
}
