//! `models::ModelWorkload` -> executable [`GraphProgram`].
//!
//! The zoo's workloads are shape lists with operator provenance
//! (`models::LayerKind`); compilation reconstructs the network around
//! them:
//!
//! - **transformer** (layers `qkv`/`attn_out`/`ffn1`/`ffn2`): encoder
//!   blocks of QKV GEMM -> multi-head attention -> output projection ->
//!   residual + layer-norm -> FFN (bias+ReLU) -> residual + layer-norm,
//!   then mean-pool + dense classifier head;
//! - **conv chain** (any `LayerKind::Conv` layer): img2col -> GEMM ->
//!   bias+ReLU per conv, 2x2 average pools inserted wherever the listed
//!   spatial extents halve, then the conv->FC seam (global-pool or
//!   flatten, inferred from the first FC's K) and the FC stack.
//!   Residual skip connections are *not* modelled (ResNet-50's bottleneck
//!   widths don't chain sequentially and are rejected with an error);
//! - **LSTM** (layers named `*_gates`): the gate layers form a stacked
//!   recurrence unrolled over the workload's step count, sharing one
//!   `[x|h]` concat + gate buffer across all steps and cells, followed by
//!   the non-gate FC tail (attention fc, softmax projection).
//!
//! Weights are generated deterministically from `CompileOptions::seed`,
//! then each **prunable** layer is pruned and packed into the variant's
//! pattern (`prunable: false` layers — first convs, classifier heads —
//! always stay dense) with its `TileConfig` resolved from the autotune
//! plan cache.  See `docs/DESIGN.md` §6.

use std::sync::Arc;

use crate::autotune::{PatternFamily, PlanCache};
use crate::error::{Context, Result};
use crate::exec::ModelDims;
use crate::gpusim::GemmShape;
use crate::models::{GemmLayer, LayerKind, ModelWorkload};
use crate::nn::Conv2dSpec;
use crate::quant::Precision;
use crate::tensor::Matrix;
use crate::util::Rng;
use crate::{bail, ensure};

use super::decode::DecodeSet;
use super::fuse::fuse_program;
use super::ir::{Act, BufId, GraphBuilder, GraphProgram, Op};
use super::pack::{pack_weight, GemmNode, GraphPattern, PackOptions};

/// How to compile a workload into one serving variant.
#[derive(Clone)]
pub struct CompileOptions {
    /// Pattern every prunable layer is packed with (`Auto` = per-layer
    /// selection from the plan cache).
    pub pattern: GraphPattern,
    pub pack: PackOptions,
    /// Transformer sequence length per request (`M = batch * seq` must
    /// match the workload's listed M).  Ignored by conv/LSTM workloads.
    pub seq: usize,
    /// Transformer attention heads (must divide d_model).
    pub heads: usize,
    /// Transformer classifier width (conv/LSTM take theirs from the
    /// workload's final layer).
    pub n_classes: usize,
    /// Decoder-style transformer: causal attention masking and a
    /// last-position (instead of mean-pooled) classifier head.  Makes the
    /// one-shot forward the exact twin of step-by-step KV-cache decode —
    /// `tests/decode_parity.rs` pins the two against each other.  Ignored
    /// by conv/LSTM workloads (the LSTM recurrence is causal already).
    pub causal: bool,
    /// Deterministic weight seed: every backend compiled from the same
    /// workload + seed serves identical logits.
    pub seed: u64,
    pub plan_cache: Option<Arc<PlanCache>>,
    /// Plan-cache model key for `Auto` pattern resolution — the name the
    /// autotune CLI tuned under (`autotune --model bert` stores its
    /// recommendation as "bert", not the workload's display name).
    /// Defaults to the workload's display name when unset.
    pub model_key: Option<String>,
    /// Run the epilogue fusion pass (`graph::fuse`) on the compiled op
    /// stream.  On by default; the `PALLAS_NO_FUSION=1` environment
    /// variable (or `serve --no-fusion`) flips the default off — the
    /// escape hatch the no-fusion CI lane exercises.
    pub fuse: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            pattern: GraphPattern::Dense,
            pack: PackOptions::default(),
            seq: 16,
            heads: 4,
            n_classes: 8,
            causal: false,
            seed: 42,
            plan_cache: None,
            model_key: None,
            fuse: !no_fusion_env(),
        }
    }
}

/// `PALLAS_NO_FUSION` set to anything but "" / "0" disables fusion by
/// default (read per call — tests toggle it).
fn no_fusion_env() -> bool {
    std::env::var("PALLAS_NO_FUSION").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

impl CompileOptions {
    /// Same options, different pattern — the per-variant loop backends use.
    pub fn with_pattern(&self, pattern: GraphPattern) -> CompileOptions {
        CompileOptions { pattern, ..self.clone() }
    }

    /// Same options, different numeric precision (the `--precision` knob;
    /// flows into [`PackOptions::precision`], so every packed layer is
    /// quantized — or plan-cache-resolved under `Auto` — at pack time).
    pub fn with_precision(&self, precision: Precision) -> CompileOptions {
        let mut o = self.clone();
        o.pack.precision = precision;
        o
    }

    fn family_for(&self, model: &str, prunable: bool, shape: GemmShape) -> PatternFamily {
        if prunable {
            self.pattern.family_for_layer(model, shape, self.plan_cache.as_ref())
        } else {
            PatternFamily::Dense
        }
    }

    /// Resolve a layer's pattern family (`prunable: false` forces dense)
    /// and pack it — the single packing path shared by every compiled
    /// topology, including the native backend's residual-MLP spec.
    /// `m_buckets` lists the per-bucket M values to pre-resolve for
    /// dynamic effective-batch dispatch (empty for batch-independent
    /// layers — conv GEMMs run the same M regardless of load).
    pub(crate) fn pack_layer(
        &self,
        model: &str,
        name: &str,
        w: &Matrix,
        m_hint: usize,
        m_buckets: &[usize],
        prunable: bool,
    ) -> Result<GemmNode> {
        let shape = GemmShape::new(m_hint, w.rows, w.cols);
        let family = self.family_for(model, prunable, shape);
        pack_weight(name, w, m_hint, m_buckets, family, &self.pack, self.plan_cache.as_deref())
    }
}

/// The power-of-two effective-batch buckets of a batch-`b` model:
/// `1, 2, 4, …` up to and including `b` itself (the full batch is always
/// a bucket even when it is not a power of two).  These are the M grid
/// the plan cache is probed on at pack time and the grid `GemmNode::
/// cfg_for_m` covers at dispatch.
pub fn batch_buckets(b: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut m = 1usize;
    while m < b {
        out.push(m);
        m *= 2;
    }
    out.push(b.max(1));
    out
}

/// Compile one workload into one variant's executable graph.
pub fn compile(workload: &ModelWorkload, opts: &CompileOptions) -> Result<GraphProgram> {
    let has_conv = workload.layers.iter().any(|l| matches!(l.kind, LayerKind::Conv(_)));
    let has_gates = workload.layers.iter().any(|l| l.name.ends_with("_gates"));
    let has_qkv = workload.layers.iter().any(|l| l.name == "qkv");
    ensure!(!workload.layers.is_empty(), "workload {} has no layers", workload.name);
    let mut p = if has_conv {
        compile_conv(workload, opts)?
    } else if has_gates {
        compile_lstm(workload, opts)?
    } else if has_qkv {
        compile_transformer(workload, opts)?
    } else {
        bail!(
            "workload {} has no compilable structure (expected conv layers, *_gates layers, \
             or a qkv/ffn transformer block)",
            workload.name
        );
    };
    if opts.fuse {
        fuse_program(&mut p);
    }
    Ok(p)
}

fn small_bias(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32() * 0.05).collect()
}

// ---------------------------------------------------------------- BERT --

fn compile_transformer(workload: &ModelWorkload, opts: &CompileOptions) -> Result<GraphProgram> {
    let get = |name: &str| -> Result<&GemmLayer> {
        workload
            .layers
            .iter()
            .find(|l| l.name == name)
            .with_context(|| {
                format!("transformer workload {} missing layer {name:?}", workload.name)
            })
    };
    let model_key = opts.model_key.as_deref().unwrap_or(workload.name);
    let (qkv, attn_out, ffn1, ffn2) = (get("qkv")?, get("attn_out")?, get("ffn1")?, get("ffn2")?);
    let d = qkv.shape.k;
    let m = qkv.shape.m;
    let d_ff = ffn1.shape.n;
    let n_layers = qkv.count.max(1);
    ensure!(qkv.shape.n == 3 * d, "qkv must project to 3*d_model");
    ensure!(attn_out.shape.k == d && attn_out.shape.n == d, "attn_out must be (d, d)");
    ensure!(ffn1.shape.k == d && ffn2.shape.k == d_ff && ffn2.shape.n == d, "ffn pair shapes");
    for l in [attn_out, ffn1, ffn2] {
        ensure!(l.shape.m == m && l.count == qkv.count, "transformer layers must agree on M/count");
    }
    let seq = opts.seq.max(1);
    ensure!(m % seq == 0, "M={m} not divisible by seq={seq}");
    let batch = m / seq;
    let heads = opts.heads.max(1);
    ensure!(d % heads == 0, "d_model {d} not divisible by heads {heads}");
    ensure!(opts.n_classes > 0, "transformer head needs n_classes >= 1");

    let mut rng = Rng::new(opts.seed);
    let mut b = GraphBuilder::new();
    let x = b.buffer(m, d);
    let qkvb = b.buffer(m, 3 * d);
    let ctx = b.buffer(m, d);
    let t = b.buffer(m, d);
    let h = b.buffer(m, d_ff);
    // token-resident activations carry `seq` rows per request; the
    // attention scratch below is per-window and batch-independent
    for id in [x, qkvb, ctx, t, h] {
        b.scale_by_batch(id, seq);
    }
    let scores = b.buffer(seq, seq);
    let qh = b.buffer(seq, d / heads);
    let kh = b.buffer(seq, d / heads);
    let vh = b.buffer(seq, d / heads);

    // per-bucket GEMM M values: encoder GEMMs run seq rows per request,
    // the classifier head one row per request
    let token_buckets: Vec<usize> = batch_buckets(batch).iter().map(|&bb| bb * seq).collect();
    let head_buckets = batch_buckets(batch);

    for layer in 0..n_layers {
        let w_qkv = Matrix::randn(d, 3 * d, &mut rng);
        let w_out = Matrix::randn(d, d, &mut rng);
        let w_up = Matrix::randn(d, d_ff, &mut rng);
        let w_down = Matrix::randn(d_ff, d, &mut rng);
        let ffn_bias = small_bias(d_ff, &mut rng);

        let node = opts.pack_layer(
            model_key,
            &format!("l{layer}.qkv"),
            &w_qkv,
            m,
            &token_buckets,
            qkv.prunable,
        )?;
        b.gemm_into(x, node, qkvb);
        b.push(Op::Attention {
            qkv: qkvb,
            out: ctx,
            heads,
            seq,
            scores,
            qh,
            kh,
            vh,
            causal: opts.causal,
        });
        let node = opts.pack_layer(
            model_key,
            &format!("l{layer}.attn_out"),
            &w_out,
            m,
            &token_buckets,
            attn_out.prunable,
        )?;
        b.gemm_into(ctx, node, t);
        b.push(Op::Residual { src: t, dst: x });
        b.push(Op::LayerNorm { buf: x });
        let node = opts.pack_layer(
            model_key,
            &format!("l{layer}.ffn1"),
            &w_up,
            m,
            &token_buckets,
            ffn1.prunable,
        )?;
        b.gemm_into(x, node, h);
        let bias = b.add_bias(ffn_bias);
        b.push(Op::BiasAct { buf: h, bias: Some(bias), act: Some(Act::Relu) });
        let node = opts.pack_layer(
            model_key,
            &format!("l{layer}.ffn2"),
            &w_down,
            m,
            &token_buckets,
            ffn2.prunable,
        )?;
        b.gemm_into(h, node, t);
        b.push(Op::Residual { src: t, dst: x });
        b.push(Op::LayerNorm { buf: x });
    }

    let pooled = b.buffer(batch, d);
    b.scale_by_batch(pooled, 1);
    if opts.causal {
        // decoder head: the last position already attends over the whole
        // prompt, and it is the only row whose step-by-step twin exists
        b.push(Op::LastPool { input: x, out: pooled, seq });
    } else {
        b.push(Op::MeanPool { input: x, out: pooled, seq });
    }
    // the classifier head stays dense in every variant — the paper's
    // "keep the small accuracy-critical layers dense" rule
    let w_head = Matrix::randn(d, opts.n_classes, &mut rng);
    let head = opts.pack_layer(model_key, "head", &w_head, batch, &head_buckets, false)?;
    let logits = b.gemm(pooled, head);

    let dims = ModelDims { batch, seq, d_model: d, n_classes: opts.n_classes };
    Ok(b.finish(workload.name, opts.pattern.variant_name(), x, logits, dims))
}

// ----------------------------------------------------------- VGG / CNN --

fn compile_conv(workload: &ModelWorkload, opts: &CompileOptions) -> Result<GraphProgram> {
    let convs: Vec<&GemmLayer> =
        workload.layers.iter().filter(|l| matches!(l.kind, LayerKind::Conv(_))).collect();
    let fcs: Vec<&GemmLayer> =
        workload.layers.iter().filter(|l| matches!(l.kind, LayerKind::Fc)).collect();
    ensure!(!convs.is_empty(), "conv workload {} lists no conv layers", workload.name);
    ensure!(!fcs.is_empty(), "conv workload {} needs an FC classifier tail", workload.name);

    let model_key = opts.model_key.as_deref().unwrap_or(workload.name);
    let first = match convs[0].kind {
        LayerKind::Conv(meta) => meta,
        LayerKind::Fc => unreachable!(),
    };
    let (hw0, c0) = (first.in_hw, first.c_in);

    // Arena recycler: conv chains are deep (13+ GEMMs in VGG) and each
    // layer's im2col matrix is large, so dead buffers are reused for later
    // same-shaped allocations instead of growing the workspace with depth.
    // Execution is sequential and every op fully overwrites its output, so
    // a buffer is recyclable the moment its last reader has been pushed.
    struct BufPool {
        free: std::collections::HashMap<(usize, usize), Vec<BufId>>,
    }
    impl BufPool {
        fn grab(&mut self, b: &mut GraphBuilder, rows: usize, cols: usize) -> BufId {
            if let Some(id) = self.free.get_mut(&(rows, cols)).and_then(Vec::pop) {
                return id;
            }
            b.buffer(rows, cols)
        }
        fn release(&mut self, b: &GraphBuilder, id: BufId) {
            self.free.entry(b.shape(id)).or_default().push(id);
        }
    }
    let mut arena = BufPool { free: std::collections::HashMap::new() };

    let mut rng = Rng::new(opts.seed);
    let mut b = GraphBuilder::new();
    let input = b.buffer(1, c0 * hw0 * hw0);
    let mut cur = input;
    let mut cur_hw = hw0;
    let mut cur_c = c0;
    let mut from_chw = true;

    for l in convs {
        let LayerKind::Conv(meta) = l.kind else { unreachable!() };
        if l.count > 1 {
            ensure!(
                meta.stride == 1 && meta.c_in == meta.c_out,
                "conv layer {} repeats {}x but does not chain (stride/width)",
                l.name,
                l.count
            );
        }
        for rep in 0..l.count {
            let c_in = if rep == 0 { meta.c_in } else { meta.c_out };
            ensure!(
                c_in == cur_c,
                "conv chain breaks at {}: needs {} input channels, previous layer produced {} \
                 (non-sequential topologies are not compilable)",
                l.name,
                c_in,
                cur_c
            );
            // spatial transition: the zoo halves resolution between blocks
            if rep == 0 && meta.in_hw * 2 == cur_hw {
                ensure!(cur_hw % 2 == 0 && !from_chw, "pool transition at {}", l.name);
                let pooled = arena.grab(&mut b, (cur_hw / 2) * (cur_hw / 2), cur_c);
                b.push(Op::AvgPool2 { input: cur, out: pooled, hw: cur_hw });
                arena.release(&b, cur);
                cur = pooled;
                cur_hw /= 2;
            } else if rep == 0 {
                ensure!(
                    meta.in_hw == cur_hw,
                    "conv chain breaks at {}: needs {}x{} input, previous produced {}x{}",
                    l.name,
                    meta.in_hw,
                    meta.in_hw,
                    cur_hw,
                    cur_hw
                );
            }
            let spec = Conv2dSpec {
                c_in,
                c_out: meta.c_out,
                kernel: meta.kernel,
                stride: meta.stride,
                pad: meta.pad,
            };
            let (out_hw, _) = spec.out_hw(cur_hw, cur_hw);
            let a = arena.grab(&mut b, out_hw * out_hw, spec.gemm_k());
            b.push(Op::Im2col { input: cur, out: a, spec, in_hw: cur_hw, from_chw });
            // `cur` is dead once lowered (the program input is kept out of
            // the recycler: run() writes it fresh before every execute)
            if cur != input {
                arena.release(&b, cur);
            }
            let w = Matrix::randn(spec.gemm_k(), spec.c_out, &mut rng);
            let name = if l.count > 1 { format!("{}.{rep}", l.name) } else { l.name.clone() };
            // conv GEMMs run a fixed M (out_hw^2 pixels of one image, batch
            // 1) regardless of load — no effective-batch buckets
            let node = opts.pack_layer(model_key, &name, &w, out_hw * out_hw, &[], l.prunable)?;
            let y = arena.grab(&mut b, out_hw * out_hw, node.n);
            b.gemm_into(a, node, y);
            arena.release(&b, a);
            let bias = b.add_bias(small_bias(spec.c_out, &mut rng));
            b.push(Op::BiasAct { buf: y, bias: Some(bias), act: Some(Act::Relu) });
            cur = y;
            cur_hw = out_hw;
            cur_c = spec.c_out;
            from_chw = false;
        }
    }

    // conv -> FC seam, inferred from the first FC's reduction width
    let k0 = fcs[0].shape.k;
    let hw2 = cur_hw * cur_hw;
    let mut cur_fc = if k0 == cur_c {
        // global average pool (the ResNet head)
        let gp = b.buffer(1, cur_c);
        b.push(Op::GlobalAvgPool { input: cur, out: gp });
        gp
    } else if k0 == cur_c * hw2 {
        let fl = b.buffer(1, cur_c * hw2);
        b.push(Op::Flatten { input: cur, out: fl });
        fl
    } else if cur_hw % 2 == 0 && k0 == cur_c * (cur_hw / 2) * (cur_hw / 2) {
        // one final 2x2 pool before flattening (the VGG conv5 -> fc6 seam)
        let pooled = b.buffer((cur_hw / 2) * (cur_hw / 2), cur_c);
        b.push(Op::AvgPool2 { input: cur, out: pooled, hw: cur_hw });
        let fl = b.buffer(1, cur_c * (cur_hw / 2) * (cur_hw / 2));
        b.push(Op::Flatten { input: pooled, out: fl });
        fl
    } else {
        bail!(
            "conv->FC seam of {}: fc K={k0} matches neither {} (global pool), {} (flatten), \
             nor a pooled flatten",
            workload.name,
            cur_c,
            cur_c * hw2
        );
    };

    for (i, l) in fcs.iter().enumerate() {
        ensure!(l.count == 1, "FC layer {} repeats in a conv net", l.name);
        let w = Matrix::randn(l.shape.k, l.shape.n, &mut rng);
        let node = opts.pack_layer(model_key, &l.name, &w, 1, &[], l.prunable)?;
        let out = b.gemm(cur_fc, node);
        if i + 1 < fcs.len() {
            let bias = b.add_bias(small_bias(l.shape.n, &mut rng));
            b.push(Op::BiasAct { buf: out, bias: Some(bias), act: Some(Act::Relu) });
        }
        cur_fc = out;
    }

    let dims = ModelDims {
        batch: 1,
        seq: 1,
        d_model: c0 * hw0 * hw0,
        n_classes: fcs.last().map(|l| l.shape.n).unwrap_or(1),
    };
    Ok(b.finish(workload.name, opts.pattern.variant_name(), input, cur_fc, dims))
}

// ------------------------------------------------------------ NMT/LSTM --

fn compile_lstm(workload: &ModelWorkload, opts: &CompileOptions) -> Result<GraphProgram> {
    let gates: Vec<&GemmLayer> =
        workload.layers.iter().filter(|l| l.name.ends_with("_gates")).collect();
    let tail: Vec<&GemmLayer> =
        workload.layers.iter().filter(|l| !l.name.ends_with("_gates")).collect();
    ensure!(!gates.is_empty(), "LSTM workload {} lists no *_gates layers", workload.name);
    ensure!(!tail.is_empty(), "LSTM workload {} needs an FC tail", workload.name);

    let model_key = opts.model_key.as_deref().unwrap_or(workload.name);
    let hidden = gates[0].shape.k / 2;
    let batch = gates[0].shape.m;
    let steps = gates[0].count.max(1);
    ensure!(hidden > 0, "LSTM hidden width must be positive");
    for g in &gates {
        ensure!(
            g.shape.k == 2 * hidden && g.shape.n == 4 * hidden,
            "gate layer {} must be (2H, 4H)",
            g.name
        );
        ensure!(
            g.shape.m == batch && g.count == gates[0].count,
            "gate layers must agree on M/steps"
        );
    }

    let mut rng = Rng::new(opts.seed);
    let mut b = GraphBuilder::new();
    let input = b.buffer(batch, steps * hidden);
    let xh = b.buffer(batch, 2 * hidden);
    let gbuf = b.buffer(batch, 4 * hidden);
    // every recurrent buffer carries one row per request
    for id in [input, xh, gbuf] {
        b.scale_by_batch(id, 1);
    }
    let buckets = batch_buckets(batch);

    struct Cell {
        h: BufId,
        c: BufId,
        w: usize,
        bias: usize,
    }
    let mut cells = Vec::with_capacity(gates.len());
    for g in &gates {
        let h = b.buffer(batch, hidden);
        let c = b.buffer(batch, hidden);
        b.scale_by_batch(h, 1);
        b.scale_by_batch(c, 1);
        let w = Matrix::randn(2 * hidden, 4 * hidden, &mut rng);
        let node = opts.pack_layer(model_key, &g.name, &w, batch, &buckets, g.prunable)?;
        let w = b.add_weight(node);
        let bias = b.add_bias(small_bias(4 * hidden, &mut rng));
        b.push(Op::Zero { buf: h });
        b.push(Op::Zero { buf: c });
        cells.push(Cell { h, c, w, bias });
    }

    for step in 0..steps {
        for (idx, cell) in cells.iter().enumerate() {
            let src = if idx == 0 { input } else { cells[idx - 1].h };
            b.push(Op::LstmStep {
                input: src,
                step,
                w: cell.w,
                bias: cell.bias,
                h: cell.h,
                c: cell.c,
                xh,
                gates: gbuf,
                hidden,
            });
        }
    }

    // FC tail over the final hidden state.  A tail layer's `count` is the
    // workload's *per-step cost accounting* (the simulator bills GNMT's
    // attention/projection once per decoded token); the serving graph
    // deliberately applies each tail GEMM once, to the final state — so a
    // compiled `models::nmt()` executes `softmax_proj` once even though
    // the shape list counts it 32 times for Fig. 10 latency totals.
    let mut cur = cells.last().map(|c| c.h).unwrap();
    for (i, l) in tail.iter().enumerate() {
        ensure!(l.shape.m == batch, "tail layer {} must run at batch M", l.name);
        let w = Matrix::randn(l.shape.k, l.shape.n, &mut rng);
        let node = opts.pack_layer(model_key, &l.name, &w, batch, &buckets, l.prunable)?;
        let out = b.gemm(cur, node);
        if i + 1 < tail.len() {
            b.push(Op::BiasAct { buf: out, bias: None, act: Some(Act::Tanh) });
        }
        cur = out;
    }

    let n_classes = tail.last().map(|l| l.shape.n).unwrap_or(hidden);
    let dims = ModelDims { batch, seq: steps, d_model: hidden, n_classes };
    Ok(b.finish(workload.name, opts.pattern.variant_name(), input, cur, dims))
}

// ------------------------------------------------------ decode steps --

/// Seed-stream offset for the decode-only token embedding.  The embedding
/// feeds *generated* tokens back as input rows; prompt parity never reads
/// it, so it draws from its own stream instead of perturbing the one-shot
/// weight-draw order the step programs must replay exactly.
const EMBED_SEED_SALT: u64 = 0x00DE_C0DE;

/// Compile one variant's streaming-decode half: a single-pattern
/// [`DecodeSet`] (step program + token embedding).  Backends serving
/// several variants use [`compile_decode_set`].
pub fn compile_decode(
    workload: &ModelWorkload,
    opts: &CompileOptions,
    max_steps: usize,
) -> Result<DecodeSet> {
    compile_decode_set(workload, opts, &[opts.pattern], max_steps)
}

/// Compile step programs for every listed pattern into one [`DecodeSet`].
/// Each program replays the one-shot weight-draw order from
/// `CompileOptions::seed`, so streamed logits at the last prompt step
/// match a one-shot forward of the same prompt; all programs share one
/// arena layout (patterns change packed weights, never buffer shapes), as
/// [`super::decode::DecodeEngine`] requires.
pub fn compile_decode_set(
    workload: &ModelWorkload,
    opts: &CompileOptions,
    patterns: &[GraphPattern],
    max_steps: usize,
) -> Result<DecodeSet> {
    ensure!(max_steps >= 1, "decode needs max_steps >= 1");
    ensure!(!patterns.is_empty(), "decode set needs at least one pattern");
    let has_conv = workload.layers.iter().any(|l| matches!(l.kind, LayerKind::Conv(_)));
    let has_gates = workload.layers.iter().any(|l| l.name.ends_with("_gates"));
    let has_qkv = workload.layers.iter().any(|l| l.name == "qkv");
    ensure!(
        !has_conv && (has_gates || has_qkv),
        "workload {} has no streaming-decode topology (conv models are one-shot only)",
        workload.name
    );
    let mut programs = Vec::with_capacity(patterns.len());
    for &pattern in patterns {
        let o = opts.with_pattern(pattern);
        let mut p = if has_gates {
            compile_lstm_decode(workload, &o, max_steps)?
        } else {
            compile_transformer_decode(workload, &o, max_steps)?
        };
        if o.fuse {
            fuse_program(&mut p);
        }
        programs.push(p);
    }
    let dims = programs[0].dims;
    let mut erng = Rng::new(opts.seed ^ EMBED_SEED_SALT);
    let embed = Matrix::randn(dims.n_classes, dims.d_model, &mut erng);
    Ok(DecodeSet { programs, embed, max_steps })
}

/// The per-step twin of [`compile_lstm`]: one `LstmStep` per stacked cell
/// over a `(batch, hidden)` input row (step index 0 — the op reads the
/// whole row when the input buffer is exactly `hidden` wide), then the FC
/// tail over the top hidden state, producing logits *every* step.  No
/// `Op::Zero` resets: `h`/`c` rows persist across steps and are zeroed
/// per slot by the engine's admission/retirement lifecycle.
fn compile_lstm_decode(
    workload: &ModelWorkload,
    opts: &CompileOptions,
    max_steps: usize,
) -> Result<GraphProgram> {
    let _ = max_steps; // LSTM state is O(1) per slot; capacity is policy only
    let gates: Vec<&GemmLayer> =
        workload.layers.iter().filter(|l| l.name.ends_with("_gates")).collect();
    let tail: Vec<&GemmLayer> =
        workload.layers.iter().filter(|l| !l.name.ends_with("_gates")).collect();
    ensure!(!gates.is_empty(), "LSTM workload {} lists no *_gates layers", workload.name);
    ensure!(!tail.is_empty(), "LSTM workload {} needs an FC tail", workload.name);

    let model_key = opts.model_key.as_deref().unwrap_or(workload.name);
    let hidden = gates[0].shape.k / 2;
    let batch = gates[0].shape.m;
    ensure!(hidden > 0, "LSTM hidden width must be positive");
    for g in &gates {
        ensure!(
            g.shape.k == 2 * hidden && g.shape.n == 4 * hidden,
            "gate layer {} must be (2H, 4H)",
            g.name
        );
        ensure!(g.shape.m == batch, "gate layers must agree on M");
    }

    let mut rng = Rng::new(opts.seed);
    let mut b = GraphBuilder::new();
    let input = b.buffer(batch, hidden);
    let xh = b.buffer(batch, 2 * hidden);
    let gbuf = b.buffer(batch, 4 * hidden);
    for id in [input, xh, gbuf] {
        b.scale_by_batch(id, 1);
    }
    let buckets = batch_buckets(batch);

    struct Cell {
        h: BufId,
        w: usize,
        bias: usize,
        c: BufId,
    }
    let mut cells: Vec<Cell> = Vec::with_capacity(gates.len());
    for g in &gates {
        let h = b.buffer(batch, hidden);
        let c = b.buffer(batch, hidden);
        b.scale_by_batch(h, 1);
        b.scale_by_batch(c, 1);
        // identical draw order to compile_lstm: per cell, gate weight then
        // gate bias — same seed, same weights, same pruning masks
        let w = Matrix::randn(2 * hidden, 4 * hidden, &mut rng);
        let node = opts.pack_layer(model_key, &g.name, &w, batch, &buckets, g.prunable)?;
        let w = b.add_weight(node);
        let bias = b.add_bias(small_bias(4 * hidden, &mut rng));
        cells.push(Cell { h, w, bias, c });
    }

    for (idx, cell) in cells.iter().enumerate() {
        let src = if idx == 0 { input } else { cells[idx - 1].h };
        b.push(Op::LstmStep {
            input: src,
            step: 0,
            w: cell.w,
            bias: cell.bias,
            h: cell.h,
            c: cell.c,
            xh,
            gates: gbuf,
            hidden,
        });
    }

    let mut cur = cells.last().map(|c| c.h).unwrap();
    for (i, l) in tail.iter().enumerate() {
        ensure!(l.shape.m == batch, "tail layer {} must run at batch M", l.name);
        let w = Matrix::randn(l.shape.k, l.shape.n, &mut rng);
        let node = opts.pack_layer(model_key, &l.name, &w, batch, &buckets, l.prunable)?;
        let out = b.gemm(cur, node);
        if i + 1 < tail.len() {
            b.push(Op::BiasAct { buf: out, bias: None, act: Some(Act::Tanh) });
        }
        cur = out;
    }

    let n_classes = tail.last().map(|l| l.shape.n).unwrap_or(hidden);
    let dims = ModelDims { batch, seq: 1, d_model: hidden, n_classes };
    Ok(b.finish(workload.name, opts.pattern.variant_name(), input, cur, dims))
}

/// The per-step twin of a *causal* [`compile_transformer`]: every encoder
/// GEMM runs one row per slot, attention becomes [`Op::DecodeAttend`]
/// against per-layer `(batch * max_steps, d)` KV caches, and the dense
/// head projects the current position directly (the one-shot twin reads
/// the same row through `Op::LastPool`).
fn compile_transformer_decode(
    workload: &ModelWorkload,
    opts: &CompileOptions,
    max_steps: usize,
) -> Result<GraphProgram> {
    let get = |name: &str| -> Result<&GemmLayer> {
        workload
            .layers
            .iter()
            .find(|l| l.name == name)
            .with_context(|| {
                format!("transformer workload {} missing layer {name:?}", workload.name)
            })
    };
    let model_key = opts.model_key.as_deref().unwrap_or(workload.name);
    let (qkv, attn_out, ffn1, ffn2) = (get("qkv")?, get("attn_out")?, get("ffn1")?, get("ffn2")?);
    let d = qkv.shape.k;
    let m = qkv.shape.m;
    let d_ff = ffn1.shape.n;
    let n_layers = qkv.count.max(1);
    ensure!(qkv.shape.n == 3 * d, "qkv must project to 3*d_model");
    ensure!(attn_out.shape.k == d && attn_out.shape.n == d, "attn_out must be (d, d)");
    ensure!(ffn1.shape.k == d && ffn2.shape.k == d_ff && ffn2.shape.n == d, "ffn pair shapes");
    let seq = opts.seq.max(1);
    ensure!(m % seq == 0, "M={m} not divisible by seq={seq}");
    let batch = m / seq;
    let heads = opts.heads.max(1);
    ensure!(d % heads == 0, "d_model {d} not divisible by heads {heads}");
    ensure!(opts.n_classes > 0, "transformer head needs n_classes >= 1");

    let mut rng = Rng::new(opts.seed);
    let mut b = GraphBuilder::new();
    let x = b.buffer(batch, d);
    let qkvb = b.buffer(batch, 3 * d);
    let ctx = b.buffer(batch, d);
    let t = b.buffer(batch, d);
    let h = b.buffer(batch, d_ff);
    for id in [x, qkvb, ctx, t, h] {
        b.scale_by_batch(id, 1);
    }
    // one head's score row over the longest possible cache prefix
    let scores = b.buffer(1, max_steps);
    let buckets = batch_buckets(batch);

    for layer in 0..n_layers {
        // identical draw order to compile_transformer: qkv, attn_out,
        // ffn up/down, ffn bias — per layer, from the same seed
        let w_qkv = Matrix::randn(d, 3 * d, &mut rng);
        let w_out = Matrix::randn(d, d, &mut rng);
        let w_up = Matrix::randn(d, d_ff, &mut rng);
        let w_down = Matrix::randn(d_ff, d, &mut rng);
        let ffn_bias = small_bias(d_ff, &mut rng);

        // this layer's appendable KV cache: max_steps rows per slot
        let kcache = b.buffer(batch * max_steps, d);
        let vcache = b.buffer(batch * max_steps, d);
        b.scale_by_batch(kcache, max_steps);
        b.scale_by_batch(vcache, max_steps);

        let node = opts.pack_layer(
            model_key,
            &format!("l{layer}.qkv"),
            &w_qkv,
            batch,
            &buckets,
            qkv.prunable,
        )?;
        b.gemm_into(x, node, qkvb);
        b.push(Op::DecodeAttend { qkv: qkvb, kcache, vcache, out: ctx, heads, max_steps, scores });
        let node = opts.pack_layer(
            model_key,
            &format!("l{layer}.attn_out"),
            &w_out,
            batch,
            &buckets,
            attn_out.prunable,
        )?;
        b.gemm_into(ctx, node, t);
        b.push(Op::Residual { src: t, dst: x });
        b.push(Op::LayerNorm { buf: x });
        let node = opts.pack_layer(
            model_key,
            &format!("l{layer}.ffn1"),
            &w_up,
            batch,
            &buckets,
            ffn1.prunable,
        )?;
        b.gemm_into(x, node, h);
        let bias = b.add_bias(ffn_bias);
        b.push(Op::BiasAct { buf: h, bias: Some(bias), act: Some(Act::Relu) });
        let node = opts.pack_layer(
            model_key,
            &format!("l{layer}.ffn2"),
            &w_down,
            batch,
            &buckets,
            ffn2.prunable,
        )?;
        b.gemm_into(h, node, t);
        b.push(Op::Residual { src: t, dst: x });
        b.push(Op::LayerNorm { buf: x });
    }

    let w_head = Matrix::randn(d, opts.n_classes, &mut rng);
    let head = opts.pack_layer(model_key, "head", &w_head, batch, &buckets, false)?;
    let logits = b.gemm(x, head);

    let dims = ModelDims { batch, seq: 1, d_model: d, n_classes: opts.n_classes };
    Ok(b.finish(workload.name, opts.pattern.variant_name(), x, logits, dims))
}
