//! Layer-graph execution IR: the seam that makes the whole model zoo
//! servable through the sparse GEMM kernels (`docs/DESIGN.md` §6).
//!
//! The paper measures its speedups on *whole networks* — BERT
//! attention+FFN stacks, VGG convs lowered via img2col, NMT LSTM gates —
//! with tile-wise/TVW sparsity applied per layer.  This module is the
//! executable counterpart: a small IR
//! ([`Op`]: `Gemm`/`BiasAct`/`Attention`/`Im2col`/`LstmStep`/`Residual`/
//! `LayerNorm` plus pooling/plumbing ops) where each GEMM node carries a
//! [`PackedWeight`] (Dense / TW fused-CTO / TVW / 2:4 — packed **once**
//! at load) and a [`crate::gemm::TileConfig`] resolved from the autotune
//! plan cache, executed allocation-free over a per-worker [`Workspace`]
//! arena sized at compile time.
//!
//! Pipeline:
//!
//! ```text
//! models::ModelWorkload ──compile──▶ GraphProgram (ops + packed weights)
//!                                         │  Arc-shared across workers
//!                      Workspace (arena) ──┤  one per worker
//!                                     GraphModel::run(variant, batch)
//! ```
//!
//! [`compile`] reconstructs the network topology from the workload's
//! layer kinds (transformer / conv chain / stacked LSTM), prunes and
//! packs every `prunable` layer into the variant's pattern ([`GraphPattern`],
//! including per-layer `Auto` selection from the plan cache), and keeps
//! `prunable: false` layers dense.  The serving backends (`exec::native`,
//! `exec::zoo`) are thin adapters over [`GraphModel`].

pub mod compile;
pub mod decode;
pub mod exec;
pub mod fuse;
pub mod ir;
pub mod pack;

pub use compile::{batch_buckets, compile, compile_decode, compile_decode_set, CompileOptions};
pub use decode::{DecodeEngine, DecodeSet};
pub use exec::{execute, execute_batch, execute_with, run_gemm, GemmDispatch, GraphModel, Workspace};
pub use fuse::{fuse_program, FusionReport};
pub use ir::{Act, BufId, GraphBuilder, GraphProgram, Op};
pub use pack::{
    pack_weight, resolve_tile, EpilogueSpec, GemmNode, GraphPattern, PackOptions, PackedWeight,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::PreparedModel;
    use crate::models;
    use std::sync::Arc;

    fn run_once(p: GraphProgram, x: &[f32]) -> Vec<f32> {
        let mut model = GraphModel::new(Arc::new(vec![p]), None).unwrap();
        let variant = model.variants()[0].clone();
        model.run(&variant, x).unwrap()
    }

    #[test]
    fn transformer_compiles_and_runs() {
        let wl = models::bert_at(2, 4, 16, 1);
        let opts = CompileOptions {
            seq: 4,
            heads: 4,
            n_classes: 4,
            pack: PackOptions { sparsity: 0.75, g: 8, ..Default::default() },
            ..CompileOptions::default()
        };
        let patterns =
            [GraphPattern::Dense, GraphPattern::Tw, GraphPattern::Tvw, GraphPattern::Vw24];
        for pattern in patterns {
            let p = compile(&wl, &opts.with_pattern(pattern)).unwrap();
            assert_eq!(p.dims.batch, 2);
            assert_eq!(p.dims.per_request_len(), 4 * 16);
            let x: Vec<f32> = (0..2 * 4 * 16).map(|i| ((i % 11) as f32 - 5.0) * 0.1).collect();
            let logits = run_once(p, &x);
            assert_eq!(logits.len(), 2 * 4, "{pattern:?}");
            assert!(logits.iter().all(|v| v.is_finite()), "{pattern:?}");
        }
    }

    #[test]
    fn conv_net_compiles_and_runs() {
        let wl = models::vgg16_scaled(32, 16, 32);
        let p = compile(&wl, &CompileOptions::default()).unwrap();
        assert_eq!(p.dims.batch, 1);
        assert_eq!(p.dims.per_request_len(), 3 * 32 * 32);
        assert_eq!(p.dims.n_classes, 1000);
        let x: Vec<f32> = (0..3 * 32 * 32).map(|i| ((i % 7) as f32 - 3.0) * 0.2).collect();
        let logits = run_once(p, &x);
        assert_eq!(logits.len(), 1000);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn resnet18_compiles_as_plain_chain_and_resnet50_rejects() {
        // ResNet-18's listed shapes chain sequentially (skip connections
        // are not modelled); ResNet-50's bottleneck widths do not, and
        // compile must say so instead of silently mis-wiring
        let opts = CompileOptions::default();
        assert!(compile(&models::resnet18(), &opts).is_ok());
        let err = compile(&models::resnet50(), &opts).unwrap_err().to_string();
        assert!(err.contains("chain"), "{err}");
    }

    #[test]
    fn lstm_compiles_and_runs_with_state_reset() {
        let wl = models::nmt_at(2, 8, 3);
        let p = compile(&wl, &CompileOptions::default()).unwrap();
        assert_eq!(p.dims.batch, 2);
        assert_eq!((p.dims.seq, p.dims.d_model), (3, 8));
        assert_eq!(p.dims.n_classes, 64);
        let x: Vec<f32> = (0..2 * 3 * 8).map(|i| ((i % 5) as f32 - 2.0) * 0.3).collect();
        let mut model = GraphModel::new(Arc::new(vec![p]), None).unwrap();
        let a = model.run("model_dense", &x).unwrap();
        // recurrent state must be reset per request: a second identical
        // request returns identical logits
        let b = model.run("model_dense", &x).unwrap();
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn variants_share_one_arena_layout() {
        let wl = models::bert_at(1, 4, 16, 1);
        let opts = CompileOptions { seq: 4, n_classes: 4, ..CompileOptions::default() };
        let programs: Vec<GraphProgram> = [GraphPattern::Dense, GraphPattern::Tw, GraphPattern::Tvw]
            .iter()
            .map(|p| compile(&wl, &opts.with_pattern(*p)).unwrap())
            .collect();
        assert!(programs.windows(2).all(|w| w[0].buf_shapes == w[1].buf_shapes));
        let model = GraphModel::new(Arc::new(programs), None).unwrap();
        assert_eq!(model.variants(), ["model_dense", "model_tw", "model_tvw"]);
    }

    #[test]
    fn auto_pattern_resolves_recommendation_under_the_cli_model_key() {
        // the tuner stores its recommendation under the CLI name ("bert"),
        // not the workload display name ("BERT-base"); Auto must find it
        use crate::autotune::{PatternFamily, PlanCache};
        let wl = models::bert_at(1, 4, 16, 1);
        let mut cache = PlanCache::new();
        cache.set_model_variant("bert", "model_tvw");
        let opts = CompileOptions {
            seq: 4,
            n_classes: 4,
            pack: PackOptions { sparsity: 0.75, g: 8, ..Default::default() },
            plan_cache: Some(Arc::new(cache)),
            model_key: Some("bert".into()),
            ..CompileOptions::default()
        };
        let p = compile(&wl, &opts.with_pattern(GraphPattern::Auto)).unwrap();
        let ffn1 = p.weights.iter().find(|w| w.name == "l0.ffn1").expect("ffn1 packed");
        assert_eq!(ffn1.weight.family(), PatternFamily::Tvw);
        // the dense head ignores the recommendation
        let head = p.weights.iter().find(|w| w.name == "head").unwrap();
        assert_eq!(head.weight.family(), PatternFamily::Dense);
    }

    #[test]
    fn conv_arena_recycles_dead_buffers() {
        // a deep conv chain's arena must be bounded by the live set, not
        // the depth: vgg's 13 conv instances share recycled im2col and
        // activation buffers wherever shapes repeat
        let wl = models::vgg16_scaled(32, 16, 32);
        let p = compile(&wl, &CompileOptions::default()).unwrap();
        let gemms =
            p.ops.iter().filter(|op| matches!(op, Op::Gemm { .. })).count();
        // without recycling every conv GEMM owns a private (a, y) pair on
        // top of input/seam/fc buffers; recycled, the arena is strictly
        // smaller than that worst case
        assert!(p.buf_shapes.len() < 2 * gemms + 2, "arena {} for {gemms} GEMMs", p.buf_shapes.len());
    }

    #[test]
    fn unknown_topology_is_an_error() {
        let mut wl = models::bert_at(1, 2, 8, 1);
        for l in &mut wl.layers {
            l.name = format!("x_{}", l.name);
        }
        assert!(compile(&wl, &CompileOptions::default()).is_err());
    }
}
