//! The graph executor: runs a [`GraphProgram`] over a per-worker
//! [`Workspace`] with **zero steady-state heap allocations** — every
//! activation, attention score, LSTM concat, and kernel staging area
//! lives in the arena sized at compile time.  Multi-buffer ops briefly
//! take their mutated buffers out of the arena (an O(1) pointer swap
//! with an empty matrix, no allocation) to satisfy the borrow checker.

use std::sync::Arc;
use std::time::Instant;

use crate::error::Result;
use crate::exec::{DecodeCaps, ModelDims, PreparedModel, StepOut};
use crate::gemm::{
    effective_parallel_threads, int8_matmul_parallel_into_epi, int8_matmul_tiled_into_epi,
    int8_tvw_matmul_into_epi, int8_tw_matmul_into_epi, int8_vw24_matmul_into_epi,
    matmul_parallel_into_epi, matmul_tiled_into_panel_epi, micro, tvw_effective_parallel_threads,
    tvw_matmul_into_scratch_epi, tvw_matmul_parallel_into_epi, tw_effective_parallel_threads,
    tw_matmul_into_scratch_panels_epi, tw_matmul_parallel_into_epi,
    vw24_effective_parallel_threads, vw24_matmul_into_epi, vw24_matmul_parallel_into_epi,
    Epilogue, GemmScratch, TileConfig,
};
use crate::nn::{attention_window_into, im2col_into, lstm_gate_update, AttnScratch, ImgSrc};
use crate::pool::ThreadPool;
use crate::telemetry::{OpKind, Telemetry, VariantProfile};
use crate::tensor::Matrix;
use crate::{anyhow, bail, ensure};

use super::ir::{Act, BufId, GraphProgram, Op};
use super::pack::{GemmNode, NodePanels, PackedWeight};

/// One worker's mutable execution state: the buffer arena plus the
/// serial-kernel staging scratch.  Built once per worker from the
/// program's compile-time shape table.
pub struct Workspace {
    bufs: Vec<Matrix>,
    scratch: GemmScratch,
    /// Per-slot cache length for decode programs: `slot_pos[b]` is the
    /// number of steps slot `b` has already cached, read by
    /// `Op::DecodeAttend` (which appends at that index) and advanced by
    /// the decode driver once per step.  Unused by one-shot programs.
    pub slot_pos: Vec<usize>,
}

impl Workspace {
    pub fn for_program(p: &GraphProgram) -> Workspace {
        let mut scratch = GemmScratch::with_capacity(p.scratch_a, p.scratch_c);
        scratch.ensure_int8(p.scratch_qa, p.scratch_qg, p.scratch_qi);
        Workspace {
            bufs: p.buf_shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect(),
            scratch,
            slot_pos: vec![0; p.dims.batch],
        }
    }

    pub fn buf(&self, id: BufId) -> &Matrix {
        &self.bufs[id.0]
    }

    pub fn buf_mut(&mut self, id: BufId) -> &mut Matrix {
        &mut self.bufs[id.0]
    }

    /// Resize every batch-scaled buffer to `m_eff` requests — the
    /// variable-M entry of the dynamic-batch contract (`docs/DESIGN.md`
    /// §7).  Row-major leading-batch layout makes the live rows a
    /// contiguous prefix, so shrinking is a `Vec::truncate` and growing
    /// back re-fills within the capacity reserved at the compile-time
    /// batch: **no allocation either way**, and every downstream op reads
    /// its row count straight from the buffer (`Matrix::rows`), so the
    /// whole op list — GEMM row prefixes, the per-window attention loop,
    /// LSTM step rows, LayerNorm/pooling row counts — adapts without an
    /// explicit per-op parameter.
    pub fn set_effective_batch(&mut self, p: &GraphProgram, m_eff: usize) {
        debug_assert!(m_eff >= 1 && m_eff <= p.dims.batch);
        for (buf, rpr) in self.bufs.iter_mut().zip(&p.buf_rows_per_request) {
            let Some(rpr) = rpr else { continue };
            let rows = rpr * m_eff;
            if buf.rows != rows {
                buf.rows = rows;
                buf.data.resize(rows * buf.cols, 0.0);
            }
        }
    }

    /// Zero every batch-scaled buffer's rows belonging to `slot` and
    /// reset its cache position — the slot-lifecycle reset run when a
    /// decode request is admitted into (or retired from) a workspace
    /// slot.  Rows beyond the current effective batch are already dead
    /// (truncated by [`Workspace::set_effective_batch`], which re-grows
    /// them zero-filled), so only the resident prefix needs clearing.
    pub fn reset_slot(&mut self, p: &GraphProgram, slot: usize) {
        debug_assert!(slot < p.dims.batch);
        for (buf, rpr) in self.bufs.iter_mut().zip(&p.buf_rows_per_request) {
            let Some(rpr) = rpr else { continue };
            let (lo, hi) = (slot * rpr, (slot + 1) * rpr);
            let hi = hi.min(buf.rows);
            if lo >= hi {
                continue;
            }
            buf.data[lo * buf.cols..hi * buf.cols].fill(0.0);
        }
        if slot < self.slot_pos.len() {
            self.slot_pos[slot] = 0;
        }
    }
}

/// Take a buffer out of the arena for mutation (restored by [`put`]);
/// the placeholder is an empty matrix, so no allocation happens.
fn take(bufs: &mut [Matrix], id: BufId) -> Matrix {
    std::mem::replace(&mut bufs[id.0], Matrix::zeros(0, 0))
}

fn put(bufs: &mut [Matrix], id: BufId, m: Matrix) {
    bufs[id.0] = m;
}

/// What [`run_gemm`] actually dispatched: the bucket-resolved tile
/// config and the effective intra-op lane count (1 when the problem was
/// too small to split or no pool was attached).  The profiler records
/// this per node; callers that don't profile just drop it.
#[derive(Clone, Copy, Debug)]
pub struct GemmDispatch {
    pub cfg: TileConfig,
    pub threads: usize,
    /// Packed [`micro::Resolved`] code of the microkernel the config
    /// resolved to (`micro::describe` turns it back into a label).
    pub micro: usize,
}

/// Dispatch one packed GEMM into `c` (fully overwritten).  With an
/// intra-op pool each family runs its pool-parallel path — row bands
/// (dense), condensed-tile ranges (TW/TVW), column blocks (2:4).  The
/// small-problem fallback is decided *here* via the published
/// `*_effective_parallel_threads` helpers (not inside the parallel entry
/// points, whose fallback would allocate fresh kernel scratch), so every
/// serial TW/TVW execution stages through the workspace's [`GemmScratch`]
/// and the request loop stays allocation-free even with `intra_threads > 1`
/// on problems too small to split.
///
/// `epi` is the fused store-time epilogue (bias / activation / residual),
/// applied by every kernel family at its store or scatter site — `None`
/// reproduces the bare GEMM bit-for-bit.  For the partial-scatter TW
/// patterns this function seeds `c` with the epilogue prefill so pruned
/// output columns hold `epi(0)` instead of stale data.
pub fn run_gemm(
    a: &Matrix,
    node: &GemmNode,
    c: &mut Matrix,
    intra: Option<&ThreadPool>,
    scratch: &mut GemmScratch,
    epi: Option<&Epilogue>,
) -> GemmDispatch {
    let threads = intra.map_or(1, ThreadPool::threads);
    // dynamic-M dispatch: the bucket table resolved at pack time picks the
    // blocking tuned for this effective row count (falling back to the
    // compile default); `a.rows` already reflects the live batch prefix
    let cfg = node.cfg_for_m(a.rows);
    let r = micro::resolve(&cfg);
    // the TW scatter only writes kept output columns: seed the rest here
    // (epilogue prefill when fusing, zero otherwise)
    let seed_partial = |c: &mut Matrix| match epi {
        Some(e) => e.prefill(c),
        None => c.data.fill(0.0),
    };
    let used = match &node.weight {
        PackedWeight::Dense(w) => {
            let eff = effective_parallel_threads(a.rows, threads);
            if let Some(pool) = intra.filter(|_| eff > 1) {
                matmul_parallel_into_epi(a, w, c, &cfg, threads, pool, epi);
                eff
            } else {
                let panel = match &node.panels {
                    NodePanels::Dense(p) => Some(p),
                    _ => None,
                };
                matmul_tiled_into_panel_epi(a, w, panel, c, &cfg, epi);
                1
            }
        }
        PackedWeight::Tw(p) => {
            seed_partial(c);
            let eff = tw_effective_parallel_threads(p.tiles, threads);
            if let Some(pool) = intra.filter(|_| eff > 1) {
                tw_matmul_parallel_into_epi(a, p, c, &cfg, threads, pool, epi);
                eff
            } else {
                let panels = match &node.panels {
                    NodePanels::Tw(ps) => Some(ps.as_slice()),
                    _ => None,
                };
                tw_matmul_into_scratch_panels_epi(a, p, panels, c, &cfg, scratch, epi);
                1
            }
        }
        PackedWeight::Tvw(p) => {
            let eff = tvw_effective_parallel_threads(p.tiles, threads);
            if let Some(pool) = intra.filter(|_| eff > 1) {
                tvw_matmul_parallel_into_epi(a, p, c, &cfg, threads, pool, epi);
                eff
            } else {
                tvw_matmul_into_scratch_epi(a, p, c, &cfg, scratch, epi);
                1
            }
        }
        PackedWeight::Vw24(p) => {
            let eff = vw24_effective_parallel_threads(p.n, threads);
            if let Some(pool) = intra.filter(|_| eff > 1) {
                vw24_matmul_parallel_into_epi(a, p, c, &cfg, threads, pool, epi);
                eff
            } else {
                vw24_matmul_into_epi(a, p, c, &cfg, epi);
                1
            }
        }
        PackedWeight::Int8Dense(w) => {
            let panel = match &node.panels {
                NodePanels::Int8Dense(p) => Some(p),
                _ => None,
            };
            if let Some(pool) =
                intra.filter(|_| effective_parallel_threads(a.rows, threads) > 1)
            {
                int8_matmul_parallel_into_epi(a, w, panel, c, &cfg, threads, pool, scratch, epi)
            } else {
                int8_matmul_tiled_into_epi(a, w, panel, c, &cfg, scratch, epi);
                1
            }
        }
        // the condensed int8 kernels run serial even under a pool: their
        // compact per-tile problems are below the parallel split threshold
        // at serving M, and the i32 staging lives in the (per-worker)
        // GemmScratch — inter-worker parallelism still applies above
        PackedWeight::Int8Tw(p) => {
            seed_partial(c);
            let panels = match &node.panels {
                NodePanels::Int8Tw(ps) => Some(ps.as_slice()),
                _ => None,
            };
            int8_tw_matmul_into_epi(a, p, panels, c, &cfg, scratch, epi);
            1
        }
        PackedWeight::Int8Tvw(p) => {
            int8_tvw_matmul_into_epi(a, p, c, &cfg, scratch, epi);
            1
        }
        PackedWeight::Int8Vw24(p) => {
            int8_vw24_matmul_into_epi(a, p, c, &cfg, scratch, epi);
            1
        }
    };
    GemmDispatch { cfg, threads: used, micro: r.code() }
}

/// Variable-M execution: resize the batch-scaled buffers to `m_eff`
/// requests, then run the op list.  The caller writes `m_eff` requests'
/// activations into the (now `m_eff`-sized) `ws.buf_mut(p.input)` and
/// reads `m_eff` requests' logits from `ws.buf(p.output)`.
pub fn execute_batch(
    p: &GraphProgram,
    ws: &mut Workspace,
    m_eff: usize,
    intra: Option<&ThreadPool>,
) {
    ws.set_effective_batch(p, m_eff);
    execute(p, ws, intra);
}

/// Execute every op of `p` in order over `ws` at the workspace's current
/// (possibly batch-shrunk) buffer shapes.  The caller writes the packed
/// request batch into `ws.buf_mut(p.input)` beforehand and reads the
/// logits from `ws.buf(p.output)` afterwards.
pub fn execute(p: &GraphProgram, ws: &mut Workspace, intra: Option<&ThreadPool>) {
    execute_with(p, ws, intra, None);
}

/// Record one GEMM dispatch against its node profile.
fn note_gemm(
    pr: &VariantProfile,
    node: &GemmNode,
    w: usize,
    m: usize,
    started: Instant,
    d: &GemmDispatch,
) {
    let (epi_code, avoided) = node
        .epilogue
        .as_ref()
        .map(|s| (s.kind_code(), s.bytes_avoided(m, node.n)))
        .unwrap_or((0, 0));
    pr.nodes[w].record(
        m,
        started.elapsed().as_nanos() as u64,
        node.flops(m),
        node.bytes_moved(m),
        d.cfg.bm(),
        d.cfg.bk(),
        d.threads,
        d.micro,
        epi_code,
        avoided,
    );
}

/// [`execute`] with optional per-node profiling: when `prof` is `Some`,
/// every op's wall time is attributed to its [`OpKind`] and every GEMM
/// dispatch (including the LSTM gate GEMM) to its weight-table node —
/// two `Instant` reads per op.  When `None`, each op pays one branch on
/// the option and nothing else, so the disabled path stays at kernel
/// speed.
pub fn execute_with(
    p: &GraphProgram,
    ws: &mut Workspace,
    intra: Option<&ThreadPool>,
    prof: Option<&VariantProfile>,
) {
    assert_eq!(ws.bufs.len(), p.buf_shapes.len(), "workspace built for a different program");
    let Workspace { bufs, scratch, slot_pos } = ws;
    let t_fwd = prof.map(|_| Instant::now());
    for op in &p.ops {
        // pure-copy chains (`BiasAct { bias: None, act: None }`) would walk
        // the buffer for nothing; the fusion pass drops them from compiled
        // programs, and the unfused executor skips any that remain
        if let Op::BiasAct { bias: None, act: None, .. } = op {
            continue;
        }
        let t_op = prof.map(|_| Instant::now());
        match op {
            Op::Gemm { input, w, out } => {
                let mut c = take(bufs, *out);
                let m = bufs[input.0].rows;
                let node = &p.weights[*w];
                // materialize the fused epilogue: bias slice from the bias
                // table, residual as a shared borrow of its arena buffer
                // (disjoint from `c`, which `take` moved out of the arena)
                let epi = node.epilogue.as_ref().map(|s| Epilogue {
                    bias: s.bias.map(|bi| p.biases[bi].as_slice()),
                    act: s.act,
                    residual: s.residual.map(|r| &bufs[r.0]),
                });
                let t = prof.map(|_| Instant::now());
                let d = run_gemm(&bufs[input.0], node, &mut c, intra, scratch, epi.as_ref());
                if let (Some(pr), Some(t0)) = (prof, t) {
                    note_gemm(pr, node, *w, m, t0, &d);
                }
                put(bufs, *out, c);
            }
            Op::BiasAct { buf, bias, act } => {
                let m = &mut bufs[buf.0];
                if let Some(bi) = bias {
                    let b = p.biases[*bi].as_slice();
                    let cols = m.cols;
                    for row in m.data.chunks_mut(cols) {
                        for (v, bv) in row.iter_mut().zip(b) {
                            *v += bv;
                        }
                    }
                }
                match act {
                    Some(Act::Relu) => {
                        for v in &mut m.data {
                            if *v < 0.0 {
                                *v = 0.0;
                            }
                        }
                    }
                    Some(Act::Tanh) => {
                        for v in &mut m.data {
                            *v = v.tanh();
                        }
                    }
                    None => {}
                }
            }
            Op::Attention { qkv, out, heads, seq, scores, qh, kh, vh, causal } => {
                let mut ctx = take(bufs, *out);
                let mut sc = AttnScratch {
                    scores: take(bufs, *scores),
                    qh: take(bufs, *qh),
                    kh: take(bufs, *kh),
                    vh: take(bufs, *vh),
                };
                {
                    let qkvb = &bufs[qkv.0];
                    let batch = qkvb.rows / seq;
                    for b in 0..batch {
                        attention_window_into(
                            qkvb, &mut ctx, b * seq, *seq, *heads, &mut sc, *causal,
                        );
                    }
                }
                put(bufs, *out, ctx);
                put(bufs, *scores, sc.scores);
                put(bufs, *qh, sc.qh);
                put(bufs, *kh, sc.kh);
                put(bufs, *vh, sc.vh);
            }
            Op::DecodeAttend { qkv, kcache, vcache, out, heads, max_steps, scores } => {
                let mut kc = take(bufs, *kcache);
                let mut vc = take(bufs, *vcache);
                let mut ctx = take(bufs, *out);
                let mut sc = take(bufs, *scores);
                {
                    let qkvb = &bufs[qkv.0];
                    let d = ctx.cols;
                    debug_assert_eq!(qkvb.cols, 3 * d);
                    debug_assert_eq!(d % heads, 0);
                    let dh = d / heads;
                    let scale = 1.0 / (dh as f32).sqrt();
                    for b in 0..qkvb.rows {
                        // append this step's K/V at the slot's position,
                        // clamped so dead prefix rows (retired slots kept
                        // resident by the high-water prefix) stay in-bounds
                        let pos = slot_pos.get(b).copied().unwrap_or(0).min(max_steps - 1);
                        let base = b * max_steps;
                        let row = qkvb.row(b);
                        kc.row_mut(base + pos).copy_from_slice(&row[d..2 * d]);
                        vc.row_mut(base + pos).copy_from_slice(&row[2 * d..3 * d]);
                        let q = &row[..d];
                        for h in 0..*heads {
                            let hcol = h * dh..(h + 1) * dh;
                            let qh = &q[hcol.clone()];
                            let srow = &mut sc.row_mut(0)[..pos + 1];
                            for (j, sv) in srow.iter_mut().enumerate() {
                                let kj = &kc.row(base + j)[hcol.clone()];
                                *sv = qh.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
                            }
                            let mx = srow.iter().fold(f32::MIN, |a, &b| a.max(b));
                            let mut z = 0.0;
                            for v in srow.iter_mut() {
                                *v = (*v - mx).exp();
                                z += *v;
                            }
                            let out_row = &mut ctx.row_mut(b)[hcol.clone()];
                            out_row.fill(0.0);
                            for (j, wj) in srow.iter().enumerate() {
                                let w = wj / z;
                                let vj = &vc.row(base + j)[hcol.clone()];
                                for (o, vv) in out_row.iter_mut().zip(vj) {
                                    *o += w * vv;
                                }
                            }
                        }
                    }
                }
                put(bufs, *kcache, kc);
                put(bufs, *vcache, vc);
                put(bufs, *out, ctx);
                put(bufs, *scores, sc);
            }
            Op::Im2col { input, out, spec, in_hw, from_chw } => {
                let mut a = take(bufs, *out);
                {
                    let src_m = &bufs[input.0];
                    let src = if *from_chw {
                        ImgSrc::Chw { data: &src_m.data, c: spec.c_in, h: *in_hw, w: *in_hw }
                    } else {
                        ImgSrc::HwC { m: src_m, h: *in_hw, w: *in_hw }
                    };
                    im2col_into(&src, spec, &mut a);
                }
                put(bufs, *out, a);
            }
            Op::AvgPool2 { input, out, hw } => {
                let mut o = take(bufs, *out);
                {
                    let src = &bufs[input.0];
                    let (hw, ho) = (*hw, *hw / 2);
                    debug_assert_eq!(src.rows, hw * hw);
                    debug_assert_eq!(o.rows, ho * ho);
                    for oy in 0..ho {
                        for ox in 0..ho {
                            let p00 = src.row((2 * oy) * hw + 2 * ox);
                            let p01 = src.row((2 * oy) * hw + 2 * ox + 1);
                            let p10 = src.row((2 * oy + 1) * hw + 2 * ox);
                            let p11 = src.row((2 * oy + 1) * hw + 2 * ox + 1);
                            let orow = o.row_mut(oy * ho + ox);
                            for (j, ov) in orow.iter_mut().enumerate() {
                                *ov = 0.25 * (p00[j] + p01[j] + p10[j] + p11[j]);
                            }
                        }
                    }
                }
                put(bufs, *out, o);
            }
            Op::GlobalAvgPool { input, out } => {
                let mut o = take(bufs, *out);
                {
                    let src = &bufs[input.0];
                    let dst = o.row_mut(0);
                    dst.fill(0.0);
                    for r in 0..src.rows {
                        for (dv, sv) in dst.iter_mut().zip(src.row(r)) {
                            *dv += sv;
                        }
                    }
                    let inv = 1.0 / src.rows as f32;
                    for dv in dst.iter_mut() {
                        *dv *= inv;
                    }
                }
                put(bufs, *out, o);
            }
            Op::Flatten { input, out } => {
                let mut o = take(bufs, *out);
                {
                    let src = &bufs[input.0];
                    let (pixels, chans) = (src.rows, src.cols);
                    let dst = o.row_mut(0);
                    debug_assert_eq!(dst.len(), pixels * chans);
                    for pix in 0..pixels {
                        for (ch, v) in src.row(pix).iter().enumerate() {
                            dst[ch * pixels + pix] = *v;
                        }
                    }
                }
                put(bufs, *out, o);
            }
            Op::LstmStep { input, step, w, bias, h, c, xh, gates, hidden } => {
                let hid = *hidden;
                let mut xhb = take(bufs, *xh);
                let mut gb = take(bufs, *gates);
                let mut hb = take(bufs, *h);
                let mut cb = take(bufs, *c);
                {
                    let inp = &bufs[input.0];
                    for i in 0..xhb.rows {
                        let src = inp.row(i);
                        // packed (batch, seq*H) input reads the step slice;
                        // a stacked cell's (batch, H) hidden state reads whole
                        let x_t =
                            if inp.cols == hid { src } else { &src[step * hid..(step + 1) * hid] };
                        let row = xhb.row_mut(i);
                        row[..hid].copy_from_slice(x_t);
                        row[hid..].copy_from_slice(hb.row(i));
                    }
                    let m = xhb.rows;
                    let t = prof.map(|_| Instant::now());
                    let d = run_gemm(&xhb, &p.weights[*w], &mut gb, intra, scratch, None);
                    if let (Some(pr), Some(t0)) = (prof, t) {
                        note_gemm(pr, &p.weights[*w], *w, m, t0, &d);
                    }
                    lstm_gate_update(&gb, &p.biases[*bias], hid, &mut hb, &mut cb);
                }
                put(bufs, *xh, xhb);
                put(bufs, *gates, gb);
                put(bufs, *h, hb);
                put(bufs, *c, cb);
            }
            Op::Residual { src, dst } => {
                let mut d = take(bufs, *dst);
                for (dv, sv) in d.data.iter_mut().zip(&bufs[src.0].data) {
                    *dv += sv;
                }
                put(bufs, *dst, d);
            }
            Op::LayerNorm { buf } => {
                let m = &mut bufs[buf.0];
                let cols = m.cols;
                let inv_n = 1.0 / cols as f32;
                for row in m.data.chunks_mut(cols) {
                    let mean = row.iter().sum::<f32>() * inv_n;
                    let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() * inv_n;
                    let inv_std = 1.0 / (var + 1e-5).sqrt();
                    for v in row.iter_mut() {
                        *v = (*v - mean) * inv_std;
                    }
                }
            }
            Op::MeanPool { input, out, seq } => {
                let mut o = take(bufs, *out);
                {
                    let src = &bufs[input.0];
                    let inv = 1.0 / *seq as f32;
                    for b in 0..o.rows {
                        let dst = o.row_mut(b);
                        dst.fill(0.0);
                        for s_i in 0..*seq {
                            for (dv, sv) in dst.iter_mut().zip(src.row(b * seq + s_i)) {
                                *dv += sv;
                            }
                        }
                        for dv in dst.iter_mut() {
                            *dv *= inv;
                        }
                    }
                }
                put(bufs, *out, o);
            }
            Op::LastPool { input, out, seq } => {
                let mut o = take(bufs, *out);
                {
                    let src = &bufs[input.0];
                    for b in 0..o.rows {
                        o.row_mut(b).copy_from_slice(src.row(b * seq + (seq - 1)));
                    }
                }
                put(bufs, *out, o);
            }
            Op::Zero { buf } => {
                bufs[buf.0].data.fill(0.0);
            }
        }
        if let (Some(pr), Some(t0)) = (prof, t_op) {
            pr.record_op(OpKind::of(op), t0.elapsed().as_nanos() as u64);
        }
    }
    if let (Some(pr), Some(t0)) = (prof, t_fwd) {
        pr.record_forward(t0.elapsed().as_nanos() as u64);
    }
}

/// One worker's executable model: a set of compiled variant programs
/// sharing one arena layout (patterns change the packed weights, never
/// the buffer shapes), plus that worker's private [`Workspace`].
pub struct GraphModel {
    programs: Arc<Vec<GraphProgram>>,
    ws: Workspace,
    /// Shared intra-op kernel pool; `None` = serial kernels at their
    /// tuned/default tile configs.
    intra: Option<Arc<ThreadPool>>,
    /// Shared profiling handle; `None` keeps every timing site to one
    /// branch per op.
    telemetry: Option<Arc<Telemetry>>,
    /// Streaming decode engine (step programs + per-slot state in its
    /// own workspace, so one-shot runs between steps never clobber
    /// resident sessions); `None` = one-shot only.
    decode: Option<super::decode::DecodeEngine>,
}

impl GraphModel {
    pub fn new(
        programs: Arc<Vec<GraphProgram>>,
        intra: Option<Arc<ThreadPool>>,
    ) -> Result<GraphModel> {
        GraphModel::with_telemetry(programs, intra, None)
    }

    /// Like [`GraphModel::new`] but attaching a [`Telemetry`] handle:
    /// the handle grows one [`VariantProfile`] per program (idempotent,
    /// so workers sharing a handle share the counters) and every forward
    /// records per-op and per-GEMM-node attribution into it.
    pub fn with_telemetry(
        programs: Arc<Vec<GraphProgram>>,
        intra: Option<Arc<ThreadPool>>,
        telemetry: Option<Arc<Telemetry>>,
    ) -> Result<GraphModel> {
        ensure!(!programs.is_empty(), "graph model needs at least one compiled variant");
        let first = &programs[0];
        let (mut sa, mut sc) = (first.scratch_a, first.scratch_c);
        let (mut qa, mut qg, mut qi) =
            (first.scratch_qa, first.scratch_qg, first.scratch_qi);
        for p in programs.iter().skip(1) {
            ensure!(
                p.buf_shapes == first.buf_shapes
                    && p.dims == first.dims
                    && p.buf_rows_per_request == first.buf_rows_per_request,
                "graph variants must share one arena layout ({} vs {})",
                p.variant,
                first.variant
            );
            sa = sa.max(p.scratch_a);
            sc = sc.max(p.scratch_c);
            qa = qa.max(p.scratch_qa);
            qg = qg.max(p.scratch_qg);
            qi = qi.max(p.scratch_qi);
        }
        let mut ws = Workspace::for_program(first);
        ws.scratch = GemmScratch::with_capacity(sa, sc);
        ws.scratch.ensure_int8(qa, qg, qi);
        if let Some(tele) = &telemetry {
            tele.register_programs(&programs);
        }
        Ok(GraphModel { programs, ws, intra, telemetry, decode: None })
    }

    /// Attach a streaming-decode engine built from `set` (the compiled
    /// step programs + embedding).  The engine gets its own workspace:
    /// per-slot recurrent/KV state must survive one-shot forwards that
    /// run between decode steps on the same worker.
    pub fn attach_decode(&mut self, set: Arc<super::decode::DecodeSet>) -> Result<()> {
        self.decode = Some(super::decode::DecodeEngine::new(set)?);
        Ok(())
    }

    /// Shared variable-M execution: `packed` holds exactly `m_eff`
    /// requests' activations; returns `m_eff` requests' logits.
    fn run_inner(&mut self, variant: &str, packed: &[f32], m_eff: usize) -> Result<Vec<f32>> {
        let programs = self.programs.clone();
        let p = programs
            .iter()
            .find(|p| p.variant == variant)
            .ok_or_else(|| anyhow!("variant {variant:?} not compiled in this graph model"))?;
        ensure!(
            m_eff >= 1 && m_eff <= p.dims.batch,
            "effective batch {m_eff} outside 1..={} for model {}",
            p.dims.batch,
            p.model
        );
        let want = m_eff * p.dims.per_request_len();
        ensure!(
            packed.len() == want,
            "packed batch has {} floats, model {} expects {want} for {m_eff} request(s)",
            packed.len(),
            p.model
        );
        self.ws.set_effective_batch(p, m_eff);
        let input = self.ws.buf_mut(p.input);
        debug_assert_eq!(input.data.len(), packed.len(), "input buffer matches request layout");
        input.data.copy_from_slice(packed);
        // resolve the profile once per forward (an Arc clone behind a read
        // lock), never per op; `None` when telemetry is off or the variant
        // is unregistered
        let prof = self.telemetry.as_ref().and_then(|t| t.variant(variant));
        execute_with(p, &mut self.ws, self.intra.as_deref(), prof.as_deref());
        Ok(self.ws.buf(p.output).data.clone())
    }
}

impl PreparedModel for GraphModel {
    fn dims(&self) -> ModelDims {
        self.programs[0].dims
    }

    fn variants(&self) -> Vec<String> {
        self.programs.iter().map(|p| p.variant.clone()).collect()
    }

    fn run(&mut self, variant: &str, packed: &[f32]) -> Result<Vec<f32>> {
        let batch = self.programs[0].dims.batch;
        self.run_inner(variant, packed, batch)
    }

    /// True variable-M execution: compute runs over the `m_eff`-request
    /// prefix only — no padding rows are packed, copied, or multiplied.
    fn run_batch(&mut self, variant: &str, packed: &[f32], m_eff: usize) -> Result<Vec<f32>> {
        self.run_inner(variant, packed, m_eff)
    }

    fn supports_dynamic_batch(&self) -> bool {
        true
    }

    fn decode_caps(&self) -> Option<DecodeCaps> {
        self.decode.as_ref().map(super::decode::DecodeEngine::caps)
    }

    fn decode_begin(&mut self, slot: usize, prompt: &[f32]) -> Result<()> {
        match self.decode.as_mut() {
            Some(d) => d.begin(slot, prompt),
            None => bail!("model {} has no decode programs attached", self.programs[0].model),
        }
    }

    fn decode_step(&mut self, variant: &str) -> Result<Vec<StepOut>> {
        let intra = self.intra.clone();
        match self.decode.as_mut() {
            Some(d) => d.step(variant, intra.as_deref()),
            None => bail!("model {} has no decode programs attached", self.programs[0].model),
        }
    }

    fn decode_end(&mut self, slot: usize) -> Result<()> {
        match self.decode.as_mut() {
            Some(d) => d.end(slot),
            None => bail!("model {} has no decode programs attached", self.programs[0].model),
        }
    }

    fn decode_active(&self) -> usize {
        self.decode.as_ref().map_or(0, super::decode::DecodeEngine::active_slots)
    }

    fn decode_free_slot(&self) -> Option<usize> {
        self.decode.as_ref().and_then(super::decode::DecodeEngine::free_slot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{compile, CompileOptions, GraphPattern, PackOptions};
    use crate::models;

    fn tiny_bert(pattern: GraphPattern) -> GraphProgram {
        let wl = models::bert_at(2, 4, 16, 1);
        let opts = CompileOptions {
            seq: 4,
            heads: 4,
            n_classes: 4,
            pack: PackOptions { sparsity: 0.75, g: 8, ..Default::default() },
            ..CompileOptions::default()
        };
        compile(&wl, &opts.with_pattern(pattern)).unwrap()
    }

    #[test]
    fn profiled_forward_attributes_ops_and_nodes() {
        let tele = Arc::new(Telemetry::new());
        let p = tiny_bert(GraphPattern::Tw);
        let mut model =
            GraphModel::with_telemetry(Arc::new(vec![p]), None, Some(Arc::clone(&tele))).unwrap();
        let x: Vec<f32> = (0..2 * 4 * 16).map(|i| ((i % 11) as f32 - 5.0) * 0.1).collect();
        model.run("model_tw", &x).unwrap();
        model.run("model_tw", &x).unwrap();

        let prof = tele.variant("model_tw").expect("variant registered at load");
        assert_eq!(prof.forwards(), 2);
        assert!(prof.op_calls(OpKind::Gemm) > 0, "transformer forwards hit GEMM ops");
        assert!(prof.op_calls(OpKind::Attention) > 0);
        let node_calls: u64 = prof.nodes.iter().map(|n| n.calls()).sum();
        assert!(node_calls > 0, "per-node dispatches recorded");
        for n in prof.nodes.iter().filter(|n| n.calls() > 0) {
            let (m, bm, bk, threads) = n.last_dispatch();
            assert!(m > 0, "{}: live rows recorded", n.name);
            assert!(bm > 0 && bk > 0, "{}: dispatched tile config recorded", n.name);
            assert_eq!(threads, 1, "{}: serial model reports one lane", n.name);
            assert!(n.flops() > 0, "{}: FLOP accounting", n.name);
        }
        // op spans nest inside the forward span, so attributed time can
        // never exceed it; on a micro model the inter-op timer gaps can
        // eat a visible share, hence the relaxed floor (the 20% bound is
        // enforced on real models by the `profile` subcommand)
        let cov = prof.attributed_secs() / prof.forward_secs().max(1e-12);
        assert!(cov > 0.3 && cov <= 1.0 + 1e-9, "attribution coverage {cov}");
    }

    #[test]
    fn telemetry_does_not_change_logits() {
        let x: Vec<f32> = (0..2 * 4 * 16).map(|i| ((i % 7) as f32 - 3.0) * 0.2).collect();
        let mut plain =
            GraphModel::new(Arc::new(vec![tiny_bert(GraphPattern::Tvw)]), None).unwrap();
        let tele = Arc::new(Telemetry::new());
        let mut profiled = GraphModel::with_telemetry(
            Arc::new(vec![tiny_bert(GraphPattern::Tvw)]),
            None,
            Some(tele),
        )
        .unwrap();
        let a = plain.run("model_tvw", &x).unwrap();
        let b = profiled.run("model_tvw", &x).unwrap();
        assert_eq!(a, b, "profiling must be observation-only");
    }

    #[test]
    fn run_gemm_reports_the_bucket_dispatch() {
        let p = tiny_bert(GraphPattern::Dense);
        let mut ws = Workspace::for_program(&p);
        let node = &p.weights[0];
        let a = Matrix::zeros(2, node.k);
        let mut c = Matrix::zeros(2, node.n);
        let d = run_gemm(&a, node, &mut c, None, &mut ws.scratch, None);
        assert_eq!((d.cfg.bm(), d.cfg.bk()), (node.cfg_for_m(2).bm(), node.cfg_for_m(2).bk()));
        assert_eq!(d.threads, 1, "no pool attached: one lane");
        assert_eq!(d.micro, micro::resolve(&node.cfg_for_m(2)).code(), "microkernel code reported");
    }
}
