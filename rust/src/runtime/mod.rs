//! Runtime: PJRT engine + artifact bundle loading.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute_b` with pre-staged weight buffers; HLO
//! *text* is the interchange format (see python/compile/aot.py and
//! /opt/xla-example/README.md for why not serialized protos).

pub mod bundle;
pub mod engine;
#[cfg(feature = "pjrt")]
pub(crate) mod xla_stub;

pub use bundle::{Bundle, Dtype, ExecutableMeta, Meta, TensorEntry};
pub use engine::{Engine, InputData, LoadedExecutable};
