//! Artifact bundle loader: `bundle.json` (tensor index) + `bundle.bin`
//! (raw little-endian blob) + `meta.json` (executable index), produced by
//! `python/compile/aot.py`.  See `python/compile/bundle.py` for the format.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Context, Result};
use crate::json::Json;
use crate::{anyhow, bail};

/// Tensor datatype in the bundle (matches the Python writer's set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub(crate) fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported bundle dtype {other:?}"),
        }
    }
}

/// One tensor's index entry.
#[derive(Clone, Debug)]
pub struct TensorEntry {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// The loaded tensor bundle.
pub struct Bundle {
    entries: HashMap<String, TensorEntry>,
    blob: Vec<u8>,
}

impl Bundle {
    pub fn load(dir: &Path) -> Result<Bundle> {
        let index_text = std::fs::read_to_string(dir.join("bundle.json"))
            .with_context(|| format!("reading {}/bundle.json", dir.display()))?;
        let index = Json::parse(&index_text).map_err(|e| anyhow!("bundle.json: {e}"))?;
        let blob_name = index
            .get("blob")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("bundle.json missing 'blob'"))?;
        let blob = std::fs::read(dir.join(blob_name))?;
        let mut entries = HashMap::new();
        for t in index
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("bundle.json missing 'tensors'"))?
        {
            let name = t.get("name").and_then(Json::as_str).ok_or_else(|| anyhow!("tensor name"))?;
            let entry = TensorEntry {
                name: name.to_string(),
                dtype: Dtype::parse(
                    t.get("dtype").and_then(Json::as_str).ok_or_else(|| anyhow!("dtype"))?,
                )?,
                shape: t
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("shape"))?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                offset: t.get("offset").and_then(Json::as_usize).ok_or_else(|| anyhow!("offset"))?,
                nbytes: t.get("nbytes").and_then(Json::as_usize).ok_or_else(|| anyhow!("nbytes"))?,
            };
            if entry.offset + entry.nbytes > blob.len() {
                bail!("tensor {name} extends past blob end");
            }
            entries.insert(name.to_string(), entry);
        }
        Ok(Bundle { entries, blob })
    }

    pub fn entry(&self, name: &str) -> Result<&TensorEntry> {
        self.entries.get(name).ok_or_else(|| anyhow!("bundle tensor {name:?} not found"))
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }

    /// Copy a tensor out as f32 (its native type must be f32).
    pub fn f32_data(&self, name: &str) -> Result<Vec<f32>> {
        let e = self.entry(name)?;
        if e.dtype != Dtype::F32 {
            bail!("tensor {name} is not f32");
        }
        Ok(self.blob[e.offset..e.offset + e.nbytes]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn i32_data(&self, name: &str) -> Result<Vec<i32>> {
        let e = self.entry(name)?;
        if e.dtype != Dtype::I32 {
            bail!("tensor {name} is not i32");
        }
        Ok(self.blob[e.offset..e.offset + e.nbytes]
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

/// Executable description from `meta.json`.
#[derive(Clone, Debug)]
pub struct ExecutableMeta {
    pub name: String,
    pub hlo_file: String,
    pub kind: String,
    pub activation_shape: Vec<usize>,
    pub args: Vec<String>,
    pub output_shape: Vec<usize>,
    /// Multi-input executables (e.g. the train step's (x, y)): shape+dtype
    /// per dynamic input, in argument order.  Empty = single f32 activation.
    pub inputs: Vec<(Vec<usize>, Dtype)>,
    /// Tuple-output executables: one shape per element.  Empty = single
    /// output of `output_shape`.
    pub output_shapes: Vec<Vec<usize>>,
}

/// Parsed `meta.json`.
#[derive(Clone, Debug)]
pub struct Meta {
    pub batch: usize,
    pub seq: usize,
    pub d_model: usize,
    pub executables: Vec<ExecutableMeta>,
}

impl Meta {
    pub fn load(dir: &Path) -> Result<Meta> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json", dir.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("meta.json: {e}"))?;
        let spec = v.get("spec").ok_or_else(|| anyhow!("meta.json missing spec"))?;
        let mut executables = Vec::new();
        for (name, e) in v
            .get("executables")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("meta.json missing executables"))?
        {
            executables.push(ExecutableMeta {
                name: name.clone(),
                hlo_file: e.get("hlo").and_then(Json::as_str).unwrap_or_default().to_string(),
                kind: e.get("kind").and_then(Json::as_str).unwrap_or("model").to_string(),
                activation_shape: e
                    .at(&["activation", "shape"])
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().map(|x| x.as_usize().unwrap_or(0)).collect())
                    .unwrap_or_default(),
                args: e
                    .get("args")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                    .unwrap_or_default(),
                output_shape: e
                    .get("output_shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().map(|x| x.as_usize().unwrap_or(0)).collect())
                    .unwrap_or_default(),
                inputs: e
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .map(|arr| {
                        arr.iter()
                            .filter_map(|i| {
                                let shape: Vec<usize> = i
                                    .get("shape")?
                                    .as_arr()?
                                    .iter()
                                    .map(|x| x.as_usize().unwrap_or(0))
                                    .collect();
                                let dtype =
                                    Dtype::parse(i.get("dtype")?.as_str()?).ok()?;
                                Some((shape, dtype))
                            })
                            .collect()
                    })
                    .unwrap_or_default(),
                output_shapes: e
                    .get("output_shapes")
                    .and_then(Json::as_arr)
                    .map(|arr| {
                        arr.iter()
                            .map(|s| {
                                s.as_arr()
                                    .map(|a| {
                                        a.iter().map(|x| x.as_usize().unwrap_or(0)).collect()
                                    })
                                    .unwrap_or_default()
                            })
                            .collect()
                    })
                    .unwrap_or_default(),
            });
        }
        executables.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(Meta {
            batch: v.get("batch").and_then(Json::as_usize).unwrap_or(1),
            seq: v.get("seq").and_then(Json::as_usize).unwrap_or(1),
            d_model: spec.get("d_model").and_then(Json::as_usize).unwrap_or(0),
            executables,
        })
    }

    pub fn executable(&self, name: &str) -> Result<&ExecutableMeta> {
        self.executables
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("executable {name:?} not in meta.json"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("meta.json").exists().then_some(dir)
    }

    #[test]
    fn load_real_bundle() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let bundle = Bundle::load(&dir).unwrap();
        let meta = Meta::load(&dir).unwrap();
        assert!(meta.executables.len() >= 7);
        for e in &meta.executables {
            for arg in &e.args {
                let t = bundle.entry(arg).unwrap();
                match t.dtype {
                    Dtype::F32 => assert!(!bundle.f32_data(arg).unwrap().is_empty()),
                    Dtype::I32 => assert!(!bundle.i32_data(arg).unwrap().is_empty()),
                }
            }
        }
    }

    #[test]
    fn missing_tensor_errors() {
        let Some(dir) = artifacts_dir() else { return };
        let bundle = Bundle::load(&dir).unwrap();
        assert!(bundle.entry("no/such/tensor").is_err());
    }
}
