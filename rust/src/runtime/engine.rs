//! PJRT execution engine: compile HLO-text artifacts once, stage every
//! static argument (weights, condensed tiles, CTO tables) as device
//! buffers once, then serve activations through `execute_b` — zero Python,
//! zero re-staging on the request path.
//!
//! The real engine needs the external `xla` crate and is gated behind the
//! `pjrt` cargo feature; without it a std-only stub with the identical
//! public surface takes its place, failing at load time so every
//! artifact-dependent caller degrades to its "artifacts missing" path.
//!
//! The serving stack no longer calls this engine directly: it reaches it
//! through `exec::PjrtBackend`, one implementation of the backend-agnostic
//! `exec::Backend` trait (DESIGN.md §5); `exec::NativeBackend` is the
//! artifact-free alternative that runs the CPU kernels in-process.

// The engine compiles against the in-tree `xla_stub` (API-shaped, fails
// at load) so `--features pjrt` type-checks offline and this file cannot
// bit-rot.  With the real `xla` crate in [dependencies], delete this
// import to link against it instead.
#[cfg(feature = "pjrt")]
use crate::runtime::xla_stub as xla;

#[cfg(feature = "pjrt")]
use std::path::Path;

#[cfg(feature = "pjrt")]
use crate::error::Result;
#[cfg(feature = "pjrt")]
use crate::{anyhow, bail};

#[cfg(feature = "pjrt")]
use super::bundle::{Bundle, Dtype, ExecutableMeta, Meta};

/// The PJRT client plus everything loaded from one artifact directory.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    pub meta: Meta,
    models: Vec<LoadedExecutable>,
}

/// One compiled executable with its static arguments pre-staged on device.
#[cfg(feature = "pjrt")]
pub struct LoadedExecutable {
    pub name: String,
    pub activation_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    /// Multi-input spec (train step etc.); empty = single f32 activation.
    pub inputs: Vec<(Vec<usize>, Dtype)>,
    /// Tuple-output shapes; empty = single output.
    pub output_shapes: Vec<Vec<usize>>,
    exe: xla::PjRtLoadedExecutable,
    static_buffers: Vec<xla::PjRtBuffer>,
}

/// A dynamic input value for multi-input executables.
pub enum InputData<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    //! API-identical stand-in used when the `xla` crate is unavailable.
    //! Loading always fails with a diagnostic; nothing else is reachable.

    use std::path::Path;

    use super::super::bundle::{Dtype, Meta};
    use super::InputData;
    use crate::error::Result;

    const UNAVAILABLE: &str = "PJRT runtime unavailable: built without the `pjrt` feature \
         (the `xla` crate is not in the offline registry); CPU kernels in `gemm` and the \
         gpusim latency model remain fully functional";

    /// Stub engine (see module docs).
    pub struct Engine {
        pub meta: Meta,
        models: Vec<LoadedExecutable>,
    }

    /// Stub executable description (never constructed).
    pub struct LoadedExecutable {
        pub name: String,
        pub activation_shape: Vec<usize>,
        pub output_shape: Vec<usize>,
        pub inputs: Vec<(Vec<usize>, Dtype)>,
        pub output_shapes: Vec<Vec<usize>>,
    }

    impl Engine {
        pub fn load(_dir: &Path) -> Result<Engine> {
            Err(crate::anyhow!("{UNAVAILABLE}"))
        }

        pub fn load_only(_dir: &Path, _names: &[&str]) -> Result<Engine> {
            Err(crate::anyhow!("{UNAVAILABLE}"))
        }

        pub fn model(&self, name: &str) -> Result<&LoadedExecutable> {
            self.models
                .iter()
                .find(|m| m.name == name)
                .ok_or_else(|| crate::anyhow!("executable {name:?} not loaded"))
        }

        pub fn model_names(&self) -> Vec<&str> {
            self.models.iter().map(|m| m.name.as_str()).collect()
        }

        pub fn run(&self, _model: &LoadedExecutable, _activation: &[f32]) -> Result<Vec<f32>> {
            Err(crate::anyhow!("{UNAVAILABLE}"))
        }

        pub fn run_named(&self, _name: &str, _activation: &[f32]) -> Result<Vec<f32>> {
            Err(crate::anyhow!("{UNAVAILABLE}"))
        }

        pub fn run_multi(
            &self,
            _model: &LoadedExecutable,
            _dynamic: &[InputData<'_>],
        ) -> Result<Vec<Vec<f32>>> {
            Err(crate::anyhow!("{UNAVAILABLE}"))
        }

        pub fn run_train_iteration(
            &self,
            _model: &LoadedExecutable,
            _x: &[f32],
            _y: &[i32],
            _params: &[&[f32]],
        ) -> Result<Vec<Vec<f32>>> {
            Err(crate::anyhow!("{UNAVAILABLE}"))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{Engine, LoadedExecutable};

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load every executable listed in `meta.json` under `dir`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let meta = Meta::load(dir)?;
        let bundle = Bundle::load(dir)?;
        let mut models = Vec::new();
        for em in &meta.executables {
            models.push(Self::load_one(&client, dir, em, &bundle)?);
        }
        Ok(Engine { client, meta, models })
    }

    /// Load a single named executable (faster startup for examples).
    pub fn load_only(dir: &Path, names: &[&str]) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let meta = Meta::load(dir)?;
        let bundle = Bundle::load(dir)?;
        let mut models = Vec::new();
        for name in names {
            let em = meta.executable(name)?.clone();
            models.push(Self::load_one(&client, dir, &em, &bundle)?);
        }
        Ok(Engine { client, meta, models })
    }

    fn load_one(
        client: &xla::PjRtClient,
        dir: &Path,
        em: &ExecutableMeta,
        bundle: &Bundle,
    ) -> Result<LoadedExecutable> {
        let hlo_path = dir.join(&em.hlo_file);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", em.name))?;
        // stage static args on device once
        let mut static_buffers = Vec::with_capacity(em.args.len());
        for arg in &em.args {
            let entry = bundle.entry(arg)?;
            let buf = match entry.dtype {
                Dtype::F32 => {
                    let data = bundle.f32_data(arg)?;
                    client
                        .buffer_from_host_buffer(&data, &entry.shape, None)
                        .map_err(|e| anyhow!("staging {arg}: {e:?}"))?
                }
                Dtype::I32 => {
                    let data = bundle.i32_data(arg)?;
                    client
                        .buffer_from_host_buffer(&data, &entry.shape, None)
                        .map_err(|e| anyhow!("staging {arg}: {e:?}"))?
                }
            };
            static_buffers.push(buf);
        }
        Ok(LoadedExecutable {
            name: em.name.clone(),
            activation_shape: em.activation_shape.clone(),
            output_shape: em.output_shape.clone(),
            inputs: em.inputs.clone(),
            output_shapes: em.output_shapes.clone(),
            exe,
            static_buffers,
        })
    }

    pub fn model(&self, name: &str) -> Result<&LoadedExecutable> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("executable {name:?} not loaded"))
    }

    pub fn model_names(&self) -> Vec<&str> {
        self.models.iter().map(|m| m.name.as_str()).collect()
    }

    /// Run one executable on an activation tensor (row-major f32 matching
    /// the executable's activation shape).  Returns the flat f32 output.
    pub fn run(&self, model: &LoadedExecutable, activation: &[f32]) -> Result<Vec<f32>> {
        let expect: usize = model.activation_shape.iter().product();
        if activation.len() != expect {
            bail!(
                "activation has {} elements, executable {} expects {:?}",
                activation.len(),
                model.name,
                model.activation_shape
            );
        }
        let act = self
            .client
            .buffer_from_host_buffer(activation, &model.activation_shape, None)
            .map_err(|e| anyhow!("staging activation: {e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + model.static_buffers.len());
        args.push(&act);
        args.extend(model.static_buffers.iter());
        let result = model
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("executing {}: {e:?}", model.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple
        let out = literal.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("output to_vec: {e:?}")).and_then(|v| {
            let want: usize = model.output_shape.iter().product();
            if v.len() != want {
                bail!("output has {} elements, expected {:?}", v.len(), model.output_shape);
            }
            Ok(v)
        })
    }

    /// Convenience: run by name.
    pub fn run_named(&self, name: &str, activation: &[f32]) -> Result<Vec<f32>> {
        let m = self.model(name)?;
        self.run(m, activation)
    }

    /// Run a multi-input, tuple-output executable (e.g. the train step):
    /// `dynamic` inputs precede the pre-staged static arguments; the
    /// output tuple is returned as flat f32 vectors per element.
    pub fn run_multi(
        &self,
        model: &LoadedExecutable,
        dynamic: &[InputData<'_>],
    ) -> Result<Vec<Vec<f32>>> {
        if model.inputs.len() != dynamic.len() {
            bail!(
                "executable {} takes {} dynamic inputs, got {}",
                model.name,
                model.inputs.len(),
                dynamic.len()
            );
        }
        let mut input_bufs = Vec::with_capacity(dynamic.len());
        for (d, (shape, dtype)) in dynamic.iter().zip(&model.inputs) {
            let want: usize = shape.iter().product();
            let buf = match (d, dtype) {
                (InputData::F32(v), Dtype::F32) => {
                    if v.len() != want {
                        bail!("input length {} != shape {:?}", v.len(), shape);
                    }
                    self.client.buffer_from_host_buffer(v, shape, None)
                }
                (InputData::I32(v), Dtype::I32) => {
                    if v.len() != want {
                        bail!("input length {} != shape {:?}", v.len(), shape);
                    }
                    self.client.buffer_from_host_buffer(v, shape, None)
                }
                _ => bail!("input dtype mismatch for {}", model.name),
            }
            .map_err(|e| anyhow!("staging input: {e:?}"))?;
            input_bufs.push(buf);
        }
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(input_bufs.len() + model.static_buffers.len());
        args.extend(input_bufs.iter());
        args.extend(model.static_buffers.iter());
        let result = model
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("executing {}: {e:?}", model.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output: {e:?}"))?;
        let parts = literal.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        if !model.output_shapes.is_empty() && parts.len() != model.output_shapes.len() {
            bail!(
                "executable {} returned {} outputs, expected {}",
                model.name,
                parts.len(),
                model.output_shapes.len()
            );
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("output to_vec: {e:?}")))
            .collect()
    }

    /// One fine-tuning iteration: run the train-step executable with
    /// caller-held parameters (overriding the pre-staged initial ones).
    /// Parameter shapes come from the executable's tuple-output spec
    /// (output 0 is the loss; outputs 1.. are the updated parameters).
    pub fn run_train_iteration(
        &self,
        model: &LoadedExecutable,
        x: &[f32],
        y: &[i32],
        params: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        if model.output_shapes.len() != params.len() + 1 {
            bail!(
                "executable {} has {} params, got {}",
                model.name,
                model.output_shapes.len().saturating_sub(1),
                params.len()
            );
        }
        let x_buf = self
            .client
            .buffer_from_host_buffer(x, &model.inputs[0].0, None)
            .map_err(|e| anyhow!("staging x: {e:?}"))?;
        let y_buf = self
            .client
            .buffer_from_host_buffer(y, &model.inputs[1].0, None)
            .map_err(|e| anyhow!("staging y: {e:?}"))?;
        let mut param_bufs = Vec::with_capacity(params.len());
        for (p, shape) in params.iter().zip(&model.output_shapes[1..]) {
            let shape: &[usize] = if shape.is_empty() { &[1] } else { shape };
            let buf = self
                .client
                .buffer_from_host_buffer(p, shape, None)
                .map_err(|e| anyhow!("staging param: {e:?}"))?;
            param_bufs.push(buf);
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(2 + param_bufs.len());
        args.push(&x_buf);
        args.push(&y_buf);
        args.extend(param_bufs.iter());
        let result = model
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("executing {}: {e:?}", model.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output: {e:?}"))?;
        let parts = literal.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("output to_vec: {e:?}")))
            .collect()
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("meta.json").exists().then_some(dir)
    }

    /// The core AOT round-trip check: the Rust-loaded gemm_dense executable
    /// must reproduce A @ W for the bundled W.
    #[test]
    fn gemm_dense_numerics() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = Engine::load_only(&dir, &["gemm_dense"]).unwrap();
        let bundle = Bundle::load(&dir).unwrap();
        let m = engine.model("gemm_dense").unwrap();
        let (rows, k) = (m.activation_shape[0], m.activation_shape[1]);
        let n = m.output_shape[1];
        let w = bundle.f32_data("gemm_dense/w").unwrap();

        let mut rng = crate::util::Rng::new(5);
        let a: Vec<f32> = (0..rows * k).map(|_| rng.normal_f32()).collect();
        let out = engine.run(m, &a).unwrap();

        // reference on the CPU
        let am = crate::tensor::Matrix::from_vec(rows, k, a);
        let wm = crate::tensor::Matrix::from_vec(k, n, w);
        let want = crate::gemm::matmul(&am, &wm);
        let got = crate::tensor::Matrix::from_vec(rows, n, out);
        assert!(
            got.max_abs_diff(&want) < 1e-2,
            "PJRT vs CPU mismatch: {}",
            got.max_abs_diff(&want)
        );
    }

    /// TW / TVW executables must agree with the CPU CTO kernels fed the
    /// same bundled plan tensors — the cross-layer consistency check.
    #[test]
    fn gemm_tw_numerics() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load_only(&dir, &["gemm_tw"]).unwrap();
        let bundle = Bundle::load(&dir).unwrap();
        let m = engine.model("gemm_tw").unwrap();
        let (rows, k) = (m.activation_shape[0], m.activation_shape[1]);
        let n = m.output_shape[1];

        let b_cond = bundle.f32_data("gemm_tw/b_cond").unwrap();
        let row_idx = bundle.i32_data("gemm_tw/row_idx").unwrap();
        let col_idx = bundle.i32_data("gemm_tw/col_idx").unwrap();
        let e = bundle.entry("gemm_tw/b_cond").unwrap();
        let (tiles, kmax, g) = (e.shape[0], e.shape[1], e.shape[2]);
        let row_len: Vec<i32> = (0..tiles)
            .map(|t| {
                // padding rows have zero values; recover kt as last row with data
                let mut kt = 0;
                for i in 0..kmax {
                    if (0..g).any(|j| b_cond[(t * kmax + i) * g + j] != 0.0) {
                        kt = i + 1;
                    }
                }
                kt as i32
            })
            .collect();
        let plan = crate::sparse::TwPlan {
            b_cond,
            row_idx,
            row_len,
            col_idx,
            tiles,
            kmax,
            g,
            k,
            n,
        };

        let mut rng = crate::util::Rng::new(6);
        let a: Vec<f32> = (0..rows * k).map(|_| rng.normal_f32()).collect();
        let out = engine.run(m, &a).unwrap();
        let am = crate::tensor::Matrix::from_vec(rows, k, a);
        let want = crate::gemm::tw_matmul(&am, &plan);
        let got = crate::tensor::Matrix::from_vec(rows, n, out);
        assert!(got.max_abs_diff(&want) < 1e-2, "{}", got.max_abs_diff(&want));
    }

    /// gemm_tew artifact: TW part + COO remainder must equal the CPU TEW
    /// composition fed the same bundled tensors.
    #[test]
    fn gemm_tew_numerics() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load_only(&dir, &["gemm_tew"]).unwrap();
        let bundle = Bundle::load(&dir).unwrap();
        let m = engine.model("gemm_tew").unwrap();
        let (rows, k) = (m.activation_shape[0], m.activation_shape[1]);
        let n = m.output_shape[1];

        let mut rng = crate::util::Rng::new(13);
        let a: Vec<f32> = (0..rows * k).map(|_| rng.normal_f32()).collect();
        let out = engine.run(m, &a).unwrap();

        // CPU reference: decode TW plan to the masked dense weight, add the
        // COO remainder, run the dense oracle
        let b_cond = bundle.f32_data("gemm_tew/b_cond").unwrap();
        let row_idx = bundle.i32_data("gemm_tew/row_idx").unwrap();
        let col_idx = bundle.i32_data("gemm_tew/col_idx").unwrap();
        let e = bundle.entry("gemm_tew/b_cond").unwrap();
        let (tiles, kmax, g) = (e.shape[0], e.shape[1], e.shape[2]);
        let row_len: Vec<i32> = (0..tiles)
            .map(|t| {
                let mut kt = 0;
                for i in 0..kmax {
                    if (0..g).any(|j| b_cond[(t * kmax + i) * g + j] != 0.0) {
                        kt = i + 1;
                    }
                }
                kt as i32
            })
            .collect();
        let plan = crate::sparse::TwPlan {
            b_cond, row_idx, row_len, col_idx, tiles, kmax, g, k, n,
        };
        let mut w = plan.decode();
        let r_vals = bundle.f32_data("gemm_tew/r_vals").unwrap();
        let r_rows = bundle.i32_data("gemm_tew/r_rows").unwrap();
        let r_cols = bundle.i32_data("gemm_tew/r_cols").unwrap();
        for ((v, r), c) in r_vals.iter().zip(&r_rows).zip(&r_cols) {
            if (*c as usize) < n {
                *w.at_mut(*r as usize, *c as usize) = *v;
            }
        }
        let am = crate::tensor::Matrix::from_vec(rows, k, a);
        let want = crate::gemm::matmul(&am, &w);
        let got = crate::tensor::Matrix::from_vec(rows, n, out);
        assert!(got.max_abs_diff(&want) < 1e-2, "{}", got.max_abs_diff(&want));
    }

    #[test]
    fn model_dense_runs_and_is_finite() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load_only(&dir, &["model_dense"]).unwrap();
        let m = engine.model("model_dense").unwrap();
        let len: usize = m.activation_shape.iter().product();
        let mut rng = crate::util::Rng::new(7);
        let x: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        let out = engine.run(m, &x).unwrap();
        assert_eq!(out.len(), m.output_shape.iter().product::<usize>());
        assert!(out.iter().all(|v| v.is_finite()));
    }

    /// The train-step artifact must reduce loss when iterated from Rust —
    /// the full AOT fine-tune path (DESIGN.md: Algorithm 1's FineTune hook
    /// executed via PJRT with zero Python).
    #[test]
    fn train_step_reduces_loss() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load_only(&dir, &["train_dense"]).unwrap();
        let m = engine.model("train_dense").unwrap();
        assert_eq!(m.inputs.len(), 2);
        let (x_shape, _) = &m.inputs[0];
        let (y_shape, _) = &m.inputs[1];
        let xlen: usize = x_shape.iter().product();
        let batch = y_shape[0];
        let mut rng = crate::util::Rng::new(9);
        let x: Vec<f32> = (0..xlen).map(|_| rng.normal_f32()).collect();
        let y: Vec<i32> = (0..batch).map(|i| (i % 4) as i32).collect();

        // step 0 uses the pre-staged initial params
        let mut outs = engine
            .run_multi(m, &[InputData::F32(&x), InputData::I32(&y)])
            .unwrap();
        let loss0 = outs[0][0];
        // iterate: feed updated params back as dynamic... params are static
        // buffers, so re-run through run_multi_with_params below
        for _ in 0..8 {
            let params: Vec<&[f32]> = outs[1..].iter().map(|v| v.as_slice()).collect();
            outs = engine.run_train_iteration(m, &x, &y, &params).unwrap();
        }
        let loss_n = outs[0][0];
        assert!(
            loss_n < loss0,
            "loss did not decrease: {loss0} -> {loss_n}"
        );
    }

    #[test]
    fn wrong_activation_size_rejected() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load_only(&dir, &["gemm_dense"]).unwrap();
        let m = engine.model("gemm_dense").unwrap();
        assert!(engine.run(m, &[0.0; 3]).is_err());
    }
}
