//! Offline stand-in for the external `xla` crate, just wide enough for
//! `runtime::engine` to **type-check** under `--features pjrt` with no
//! registry access.
//!
//! The real PJRT engine code in `engine.rs` used to bit-rot silently: the
//! `pjrt` feature could never be built offline (it needs the `xla` crate),
//! so nothing compiled that half of the file.  This module restores the
//! compile coverage: every entry point the engine calls exists here with
//! the same shape, and the two fallible constructors
//! ([`PjRtClient::cpu`], [`HloModuleProto::from_text_file`]) fail at
//! runtime — so the engine's degradation path ("fails at load, callers
//! fall back to the native backend") is identical to the featureless
//! stub, while the full engine source stays live under the type checker.
//!
//! Swapping in the real runtime is a two-line change: add the `xla` crate
//! under `[dependencies]` and delete the `use crate::runtime::xla_stub as
//! xla;` import in `engine.rs`.

use std::fmt;

/// Error type matching the engine's `{e:?}` formatting of xla errors.
pub struct XlaError(&'static str);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

const UNAVAILABLE: &str = "xla stub: the external `xla` crate is not in the offline registry; \
     the PJRT engine fails at load and callers degrade to the native backend";

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(UNAVAILABLE))
}

pub struct PjRtClient(());
pub struct PjRtBuffer(());
pub struct PjRtLoadedExecutable(());
pub struct HloModuleProto(());
pub struct XlaComputation(());
pub struct Literal(());

impl PjRtClient {
    /// Always fails: the stub has no runtime behind it.  Everything below
    /// is unreachable in practice (no client ⇒ no buffers/executables)
    /// but keeps the engine's call sites type-checked.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        unavailable()
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

impl Literal {
    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}
