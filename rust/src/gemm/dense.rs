//! Dense CPU GEMM — the baseline hot path.
//!
//! `matmul` is the cache-blocked, auto-vectorizing kernel used everywhere;
//! `matmul_naive` is the textbook triple loop kept for correctness
//! cross-checks and as the "before" point of the §Perf log.

use super::micro::{self, PackedPanel};
use super::{Epilogue, TileConfig};
use crate::pool::{self, ThreadPool};
use crate::tensor::Matrix;

/// Blocked C = A * B with the default (historical) 64x64 blocking.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_tiled(a, b, &TileConfig::dense_default())
}

/// Blocked C = A * B.  Loop order (i, k, j) with row-major operands makes
/// the inner j-loop a contiguous FMA stream the compiler vectorizes.
/// Block extents come from `cfg` (the autotuner's dense search axes).
pub fn matmul_tiled(a: &Matrix, b: &Matrix, cfg: &TileConfig) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_tiled_into(a, b, &mut c, cfg);
    c
}

/// In-place blocked GEMM: `c` is fully overwritten (zeroed, then
/// accumulated into).  The serving hot loop reuses the output allocation.
/// Dispatches to the SIMD microkernels when `cfg.micro` resolves to one.
pub fn matmul_tiled_into(a: &Matrix, b: &Matrix, c: &mut Matrix, cfg: &TileConfig) {
    matmul_tiled_into_panel(a, b, None, c, cfg);
}

/// Panel-aware form of [`matmul_tiled_into`]: when the graph executor
/// packed B into a [`PackedPanel`] at weight-pack time and its strip
/// width matches the resolved microkernel, the kernel streams the panel
/// contiguously instead of striding B rows.
pub fn matmul_tiled_into_panel(
    a: &Matrix,
    b: &Matrix,
    panel: Option<&PackedPanel>,
    c: &mut Matrix,
    cfg: &TileConfig,
) {
    matmul_tiled_into_panel_epi(a, b, panel, c, cfg, None);
}

/// [`matmul_tiled_into_panel`] with a fused [`Epilogue`] applied on each
/// completed row block before the kernel moves to the next — C is
/// written exactly once per cell, so the extra bias/activation/residual
/// sweeps the unfused graph pays disappear.  `epi: None` is the plain
/// GEMM (identical accumulation order, bit-identical output).
pub fn matmul_tiled_into_panel_epi(
    a: &Matrix,
    b: &Matrix,
    panel: Option<&PackedPanel>,
    c: &mut Matrix,
    cfg: &TileConfig,
    epi: Option<&Epilogue>,
) {
    assert_eq!(a.cols, b.rows, "GEMM shape mismatch");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    c.data.fill(0.0);
    let r = micro::resolve(cfg);
    if micro::dense_blocked(&r, a, b, panel, c, cfg, epi) {
        return;
    }
    scalar_tiled_into(a, b, c, cfg, epi);
}

/// The scalar blocked loops (the always-available fallback; `c` must be
/// pre-zeroed).  Loop order and 2-way k-unroll as in the module docs.
/// The epilogue applies per row block once its reduction is complete.
fn scalar_tiled_into(a: &Matrix, b: &Matrix, c: &mut Matrix, cfg: &TileConfig, epi: Option<&Epilogue>) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let bm = cfg.bm();
    let bk = cfg.bk();
    for i0 in (0..m).step_by(bm) {
        let i1 = (i0 + bm).min(m);
        for k0 in (0..k).step_by(bk) {
            let k1 = (k0 + bk).min(k);
            for i in i0..i1 {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut c.data[i * n..(i + 1) * n];
                // 2-way k unroll: one pass over the C row per two B rows
                let mut kk = k0;
                while kk + 1 < k1 {
                    let a0 = arow[kk];
                    let a1 = arow[kk + 1];
                    let b0 = &b.data[kk * n..(kk + 1) * n];
                    let b1 = &b.data[(kk + 1) * n..(kk + 2) * n];
                    for ((cv, bv0), bv1) in crow.iter_mut().zip(b0).zip(b1) {
                        *cv += a0 * bv0 + a1 * bv1;
                    }
                    kk += 2;
                }
                if kk < k1 {
                    let aik = arow[kk];
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
        if let Some(e) = epi {
            e.apply_rows(c, i0, i1);
        }
    }
}

/// Textbook triple loop (correctness oracle).
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a.at(i, kk) * b.at(kk, j);
            }
            *c.at_mut(i, j) = acc;
        }
    }
    c
}

/// The thread count the row-banded parallel kernel will actually use for
/// `m` activation rows: bands thinner than 8 rows cost more in chunk
/// bookkeeping than they recover, so small-M problems run serial.  This
/// used to be a silent fallback buried in `matmul_parallel`; exposing the
/// decision lets the autotuner (and metrics) stop crediting phantom
/// parallelism to configs that degrade to serial at their measured M.
pub fn effective_parallel_threads(m: usize, threads: usize) -> usize {
    if threads <= 1 || m < threads * 8 {
        1
    } else {
        threads
    }
}

/// Multi-threaded blocked GEMM: row bands on the global persistent pool
/// (historical signature; see [`matmul_parallel_into`]).
pub fn matmul_parallel(a: &Matrix, b: &Matrix, threads: usize) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_parallel_into(a, b, &mut c, &TileConfig::dense_default(), threads, pool::global());
    c
}

/// In-place multi-threaded GEMM: row bands across `threads` chunks claimed
/// from `pool` (no per-call thread spawns).  `c` is fully overwritten.
/// Returns the *effective* thread count — 1 when the problem fell back to
/// the serial blocked kernel (which then honours `cfg`).
pub fn matmul_parallel_into(
    a: &Matrix,
    b: &Matrix,
    c: &mut Matrix,
    cfg: &TileConfig,
    threads: usize,
    pool: &ThreadPool,
) -> usize {
    matmul_parallel_into_epi(a, b, c, cfg, threads, pool, None)
}

/// [`matmul_parallel_into`] with a fused [`Epilogue`]: each lane applies
/// it to its own completed row band before releasing the chunk, so the
/// fused sweeps parallelize with the GEMM itself.
pub fn matmul_parallel_into_epi(
    a: &Matrix,
    b: &Matrix,
    c: &mut Matrix,
    cfg: &TileConfig,
    threads: usize,
    pool: &ThreadPool,
    epi: Option<&Epilogue>,
) -> usize {
    assert_eq!(a.cols, b.rows, "GEMM shape mismatch");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let eff = effective_parallel_threads(m, threads);
    if eff == 1 {
        matmul_tiled_into_panel_epi(a, b, None, c, cfg, epi);
        return 1;
    }
    let band = m.div_ceil(eff);
    let a_data = &a.data;
    let b_data = &b.data;
    let r = micro::resolve(cfg);
    pool.for_each_chunk_mut(&mut c.data, band * n, |t, chunk| {
        chunk.fill(0.0);
        let i0 = t * band;
        let rows = chunk.len() / n;
        if rows == 0 {
            return;
        }
        let arows = &a_data[i0 * k..];
        if !micro::gemm_strided(&r, rows, k, n, arows, k, b_data, n, chunk, n) {
            for i in 0..rows {
                let arow = &a_data[(i0 + i) * k..(i0 + i + 1) * k];
                let crow = &mut chunk[i * n..(i + 1) * n];
                for (kk, &aik) in arow.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b_data[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
        if let Some(e) = epi {
            e.apply_chunk(chunk, i0, n);
        }
    });
    eff
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(70);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (7, 13, 5), (64, 64, 64), (100, 37, 59)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let c1 = matmul(&a, &b);
            let c2 = matmul_naive(&a, &b);
            assert!(c1.max_abs_diff(&c2) < 1e-3, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn parallel_matches_blocked() {
        let mut rng = Rng::new(71);
        let a = Matrix::randn(128, 96, &mut rng);
        let b = Matrix::randn(96, 64, &mut rng);
        let c1 = matmul(&a, &b);
        let c2 = matmul_parallel(&a, &b, 4);
        assert!(c1.max_abs_diff(&c2) < 1e-3);
    }

    #[test]
    fn tiled_matches_naive_across_configs() {
        let mut rng = Rng::new(73);
        let a = Matrix::randn(37, 53, &mut rng);
        let b = Matrix::randn(53, 29, &mut rng);
        let want = matmul_naive(&a, &b);
        for &(bm, bk) in &[(1usize, 1usize), (8, 16), (17, 31), (64, 64), (128, 256), (0, 0)] {
            let got = matmul_tiled(&a, &b, &TileConfig::new(bm, bk));
            assert!(got.max_abs_diff(&want) < 1e-3, "bm={bm} bk={bk}");
        }
    }

    #[test]
    fn into_variant_fully_overwrites() {
        let mut rng = Rng::new(74);
        let a = Matrix::randn(9, 12, &mut rng);
        let b = Matrix::randn(12, 7, &mut rng);
        let want = matmul_naive(&a, &b);
        let mut c = Matrix::zeros(9, 7);
        for v in &mut c.data {
            *v = 1e9; // stale output must not leak through
        }
        matmul_tiled_into(&a, &b, &mut c, &TileConfig::new(4, 5));
        assert!(c.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn parallel_into_reports_effective_threads() {
        let mut rng = Rng::new(75);
        let pool = crate::pool::ThreadPool::new(4);
        let cfg = TileConfig::dense_default();
        // small M: silent-serial no more — the fallback is reported
        let a = Matrix::randn(8, 16, &mut rng);
        let b = Matrix::randn(16, 12, &mut rng);
        let mut c = Matrix::zeros(8, 12);
        assert_eq!(matmul_parallel_into(&a, &b, &mut c, &cfg, 4, &pool), 1);
        assert!(c.max_abs_diff(&matmul_naive(&a, &b)) < 1e-3);
        // large M: genuinely parallel, and stale output is overwritten
        let a = Matrix::randn(64, 32, &mut rng);
        let b = Matrix::randn(32, 24, &mut rng);
        let mut c = Matrix::zeros(64, 24);
        for v in &mut c.data {
            *v = 1e9;
        }
        assert_eq!(matmul_parallel_into(&a, &b, &mut c, &cfg, 4, &pool), 4);
        assert!(c.max_abs_diff(&matmul_naive(&a, &b)) < 1e-3);
        assert_eq!(effective_parallel_threads(64, 4), 4);
        assert_eq!(effective_parallel_threads(31, 4), 1);
        assert_eq!(effective_parallel_threads(1000, 1), 1);
    }

    #[test]
    fn simd_and_scalar_paths_agree() {
        use super::super::MicroCfg;
        let mut rng = Rng::new(76);
        // awkward shapes on purpose: K not a lane multiple, N not an NR
        // multiple, m = 1, single-element
        for &(m, k, n) in &[(1usize, 7usize, 5usize), (13, 9, 23), (33, 17, 40), (64, 65, 31)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let base = TileConfig::new(16, 16);
            let scalar = matmul_tiled(&a, &b, &base.with_micro(MicroCfg::Scalar));
            assert!(scalar.max_abs_diff(&matmul_naive(&a, &b)) < 1e-3);
            for &(mr, nr) in &[(1u8, 8u8), (4, 8), (4, 16), (8, 8), (8, 16)] {
                let cfg = base.with_micro(MicroCfg::Simd { mr, nr });
                let got = matmul_tiled(&a, &b, &cfg);
                let d = got.max_abs_diff(&scalar);
                assert!(d < 1e-4, "m={m} k={k} n={n} mr={mr} nr={nr} diff={d}");
            }
        }
    }

    #[test]
    fn panel_path_matches_strided() {
        let mut rng = Rng::new(77);
        let cfg = TileConfig::new(32, 24);
        let r = crate::gemm::micro::resolve(&cfg);
        if !r.is_simd() {
            return; // scalar-only host (or PALLAS_FORCE_SCALAR): nothing to compare
        }
        for &(m, k, n) in &[(9usize, 31usize, 21usize), (1, 8, 16), (17, 64, 50)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let panel = crate::gemm::micro::PackedPanel::pack(&b.data, k, n, n, r.nr);
            let mut want = Matrix::zeros(m, n);
            matmul_tiled_into(&a, &b, &mut want, &cfg);
            let mut got = Matrix::zeros(m, n);
            matmul_tiled_into_panel(&a, &b, Some(&panel), &mut got, &cfg);
            assert!(got.max_abs_diff(&want) < 1e-4, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn fused_epilogue_is_bit_identical_to_separate_passes() {
        use super::super::Act;
        let mut rng = Rng::new(78);
        let pool = crate::pool::ThreadPool::new(3);
        for &(m, k, n) in &[(1usize, 7usize, 5usize), (13, 16, 23), (64, 32, 24)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let bias: Vec<f32> = (0..n).map(|j| (j as f32 - 2.0) * 0.1).collect();
            let res = Matrix::randn(m, n, &mut rng);
            // unfused reference: GEMM, then the separate sweeps the graph
            // executor would run
            let mut want = Matrix::zeros(m, n);
            matmul_tiled_into(&a, &b, &mut want, &TileConfig::new(16, 16));
            for i in 0..m {
                for j in 0..n {
                    let mut v = want.at(i, j) + bias[j];
                    if v < 0.0 {
                        v = 0.0;
                    }
                    *want.at_mut(i, j) = v + res.at(i, j);
                }
            }
            let epi =
                Epilogue { bias: Some(&bias), act: Some(Act::Relu), residual: Some(&res) };
            let mut got = Matrix::zeros(m, n);
            matmul_tiled_into_panel_epi(&a, &b, None, &mut got, &TileConfig::new(16, 16), Some(&epi));
            assert_eq!(got.data, want.data, "serial {m}x{k}x{n}");
            let mut got_p = Matrix::zeros(m, n);
            matmul_parallel_into_epi(
                &a,
                &b,
                &mut got_p,
                &TileConfig::new(16, 16),
                3,
                &pool,
                Some(&epi),
            );
            // pooled bands band the rows differently, so compare at tolerance
            assert!(got_p.max_abs_diff(&want) < 1e-4, "pooled {m}x{k}x{n}");
        }
    }

    #[test]
    fn identity_multiplication() {
        let mut rng = Rng::new(72);
        let a = Matrix::randn(16, 16, &mut rng);
        let mut eye = Matrix::zeros(16, 16);
        for i in 0..16 {
            *eye.at_mut(i, i) = 1.0;
        }
        assert!(matmul(&a, &eye).max_abs_diff(&a) < 1e-6);
    }
}
