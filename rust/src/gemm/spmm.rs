//! Sparse-dense matrix multiplication baselines: CSR SpMM (the EW /
//! cuSparse analogue) and block-sparse GEMM (the BW / Triton-blocksparse
//! analogue).

use crate::sparse::{Csr, Mask};
use crate::tensor::Matrix;

/// C = A * W with W in CSR.  Irregular inner access over W's columns —
/// the structural reason EW is slow on wide-vector hardware; on CPU the
/// penalty shows up as strided writes across C.
pub fn csr_spmm(a: &Matrix, w: &Csr) -> Matrix {
    assert_eq!(a.cols, w.rows);
    let (m, n) = (a.rows, w.cols);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for kk in 0..w.rows {
            let aik = arow[kk];
            if aik == 0.0 {
                continue;
            }
            for idx in w.row_ptr[kk]..w.row_ptr[kk + 1] {
                crow[w.col_idx[idx] as usize] += aik * w.vals[idx];
            }
        }
    }
    c
}

/// Block descriptor for the block-sparse GEMM: which GxG blocks of W are
/// kept, plus the dense payload of those blocks.
#[derive(Clone, Debug)]
pub struct BlockSparse {
    pub k: usize,
    pub n: usize,
    pub g: usize,
    /// (block_row, block_col) of each kept block.
    pub blocks: Vec<(u32, u32)>,
    /// g*g values per kept block, row-major.
    pub vals: Vec<f32>,
}

impl BlockSparse {
    /// Build from a BW-masked matrix; K and N must be multiples of g for
    /// the payload extraction (callers pad otherwise).
    pub fn from_masked(w: &Matrix, mask: &Mask, g: usize) -> BlockSparse {
        assert_eq!(w.rows % g, 0);
        assert_eq!(w.cols % g, 0);
        let wm = mask.apply(w);
        let (bk, bn) = (w.rows / g, w.cols / g);
        let mut blocks = Vec::new();
        let mut vals = Vec::new();
        for bi in 0..bk {
            for bj in 0..bn {
                let any = (0..g).any(|r| (0..g).any(|c| mask.at(bi * g + r, bj * g + c)));
                if any {
                    blocks.push((bi as u32, bj as u32));
                    for r in 0..g {
                        for c in 0..g {
                            vals.push(wm.at(bi * g + r, bj * g + c));
                        }
                    }
                }
            }
        }
        BlockSparse { k: w.rows, n: w.cols, g, blocks, vals }
    }

    pub fn nnz_blocks(&self) -> usize {
        self.blocks.len()
    }
}

/// C = A * W with W block-sparse: dense micro-GEMM per kept block.
pub fn block_spmm(a: &Matrix, w: &BlockSparse) -> Matrix {
    assert_eq!(a.cols, w.k);
    let (m, n, g) = (a.rows, w.n, w.g);
    let mut c = Matrix::zeros(m, n);
    for (bidx, &(bi, bj)) in w.blocks.iter().enumerate() {
        let k0 = bi as usize * g;
        let n0 = bj as usize * g;
        let payload = &w.vals[bidx * g * g..(bidx + 1) * g * g];
        for i in 0..m {
            let arow = &a.row(i)[k0..k0 + g];
            let crow = &mut c.row_mut(i)[n0..n0 + g];
            for (r, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &payload[r * g..(r + 1) * g];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dense::matmul_naive;
    use crate::sparse::{prune_bw, prune_ew};
    use crate::util::Rng;

    #[test]
    fn csr_spmm_matches_oracle() {
        let mut rng = Rng::new(100);
        let a = Matrix::randn(20, 48, &mut rng);
        let w = Matrix::randn(48, 36, &mut rng);
        let mask = prune_ew(&w, 0.8, None);
        let csr = Csr::from_masked(&w, &mask);
        let want = matmul_naive(&a, &mask.apply(&w));
        assert!(csr_spmm(&a, &csr).max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn block_spmm_matches_oracle() {
        let mut rng = Rng::new(101);
        let a = Matrix::randn(24, 64, &mut rng);
        let w = Matrix::randn(64, 64, &mut rng);
        let mask = prune_bw(&w, 0.6, 16);
        let bs = BlockSparse::from_masked(&w, &mask, 16);
        let want = matmul_naive(&a, &mask.apply(&w));
        assert!(block_spmm(&a, &bs).max_abs_diff(&want) < 1e-3);
        assert!(bs.nnz_blocks() < 16);
    }

    #[test]
    fn empty_csr_gives_zero() {
        let a = Matrix::zeros(4, 8);
        let w = Matrix::zeros(8, 8);
        let csr = Csr::from_dense(&w);
        assert_eq!(csr_spmm(&a, &csr), Matrix::zeros(4, 8));
    }
}
