//! Int8 serving kernels for all four GEMM patterns (paper §VI-B/§VI-D:
//! Int8-Dense / Int8-Sparse vs the pruning patterns).
//!
//! Every kernel here follows one contract:
//!
//! 1. The weight operand was quantized **at pack time** with per-output-
//!    channel symmetric scales (`crate::quant`); the activation batch is
//!    quantized **dynamically per call** with one tensor-wide scale,
//!    staged through the workspace [`GemmScratch`] (`qa` / `qg` / `qi`)
//!    so the steady-state serving loop performs zero allocations.
//! 2. The multiply accumulates exactly in i32 (overflow-free while
//!    `K <= ` [`crate::quant::I32_ACC_SAFE_K`]) and dequantizes on store:
//!    `c[i][j] = acc * a_scale * scales[col(j)]`.
//! 3. SIMD rides the `gemm::micro` dispatch contract: the quad-grouped
//!    [`Int8Panel`] feeds `micro::int8_gemm_panel`, the 2:4 kernels use
//!    `micro::int8_sel24_row`, and every path keeps a scalar i32 loop as
//!    the always-available fallback (`PALLAS_FORCE_SCALAR` exercises it).
//!
//! The sparse plans ([`Int8TwPlan`] / [`Int8TvwPlan`] / [`Int8Vw24Plan`])
//! mirror their f32 twins in `sparse::cto` with the value array narrowed
//! to i8 — the offset tables (`row_idx` / `col_idx` / `b_sel`) stay i32,
//! exactly as the hardware formats keep metadata at full width.  Scales
//! are indexed by **original output column**, not condensed position, so
//! the CTO scatter dequantizes with the same per-channel scale the
//! quantizer derived.

use super::micro::{self, Int8Panel};
use super::{Epilogue, GemmScratch, TileConfig};
use crate::pool::ThreadPool;
use crate::quant::QuantMatrix;
use crate::sparse::{TvwPlan, TwPlan, Vw24Plan};
use crate::tensor::Matrix;

/// Quantize activation rows into `dst` with row stride `lda >= a.cols`,
/// zero-filling the padding tail of every row (the panel kernels read
/// whole 4-byte quads).  One dynamic tensor-wide symmetric scale; all-zero
/// batches get scale 1.0.  Returns the scale.
pub fn quantize_rows_into(a: &Matrix, lda: usize, dst: &mut [i8]) -> f32 {
    let (m, k) = (a.rows, a.cols);
    debug_assert!(lda >= k);
    debug_assert!(dst.len() >= m * lda);
    let amax = a.data.iter().fold(0.0f32, |x, &v| x.max(v.abs()));
    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
    let inv = 1.0 / scale;
    for i in 0..m {
        let row = &a.data[i * k..(i + 1) * k];
        let drow = &mut dst[i * lda..(i + 1) * lda];
        for (d, &v) in drow.iter_mut().zip(row) {
            *d = (v * inv).round().clamp(-127.0, 127.0) as i8;
        }
        for d in drow[k..].iter_mut() {
            *d = 0;
        }
    }
    scale
}

/// Row stride (bytes) of a quantized activation block with reduction
/// depth `k`: padded up to whole quads.
#[inline]
pub fn quad_stride(k: usize) -> usize {
    k.div_ceil(4) * 4
}

// ---------------------------------------------------------------------------
// Dense
// ---------------------------------------------------------------------------

/// Pack a quantized dense weight into the quad-grouped panel layout for
/// `micro::int8_gemm_panel` (NR from the resolved microkernel).
pub fn int8_dense_panel(w: &QuantMatrix, nr: usize) -> Int8Panel {
    Int8Panel::pack(&w.data, w.rows, w.cols, w.cols, nr)
}

/// C = A * dequant(W): int8 dense GEMM with dequantization on store.
/// `c` is fully overwritten.  `panel` is consumed when its geometry
/// matches the resolved microkernel; otherwise the scalar i32 loop runs
/// against the row-major quantized weight.
pub fn int8_matmul_tiled_into(
    a: &Matrix,
    w: &QuantMatrix,
    panel: Option<&Int8Panel>,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut GemmScratch,
) {
    int8_matmul_tiled_into_epi(a, w, panel, c, cfg, scratch, None)
}

/// [`int8_matmul_tiled_into`] with a fused [`Epilogue`] composed into the
/// dequantizing store: `c = epi(acc * a_scale * scales[j])` — the epilogue
/// sees dequantized f32 values, so bias/activation/residual semantics are
/// identical to the f32 kernels.
#[allow(clippy::too_many_arguments)]
pub fn int8_matmul_tiled_into_epi(
    a: &Matrix,
    w: &QuantMatrix,
    panel: Option<&Int8Panel>,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut GemmScratch,
    epi: Option<&Epilogue>,
) {
    assert_eq!(a.cols, w.rows, "GEMM shape mismatch");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, w.cols);
    let (m, k, n) = (a.rows, a.cols, w.cols);
    if m == 0 || n == 0 {
        return;
    }
    let lda = quad_stride(k);
    scratch.ensure_int8(m * lda, 0, m * n);
    let (qa, acc) = (&mut scratch.qa, &mut scratch.qi);
    let a_scale = quantize_rows_into(a, lda, qa);
    let acc = &mut acc[..m * n];
    acc.fill(0);
    let r = micro::resolve(cfg);
    let panel = panel.filter(|p| p.kc == k && p.n == n);
    let done = match panel {
        Some(p) => micro::int8_gemm_panel(&r, m, qa, lda, p, acc, n),
        None => false,
    };
    if !done {
        int8_scalar_strided(qa, lda, &w.data, m, k, n, acc);
    }
    dequant_rows(acc, a_scale, &w.scales, &mut c.data, 0, epi);
}

/// In-place multi-threaded int8 dense GEMM: the activation batch is
/// quantized once (serial), then row bands accumulate into per-band i32
/// buffers on `pool` and dequantize into their disjoint slice of `c`.
/// Returns the effective thread count (1 = serial fallback, which honours
/// `cfg` and the panel).
#[allow(clippy::too_many_arguments)]
pub fn int8_matmul_parallel_into(
    a: &Matrix,
    w: &QuantMatrix,
    panel: Option<&Int8Panel>,
    c: &mut Matrix,
    cfg: &TileConfig,
    threads: usize,
    pool: &ThreadPool,
    scratch: &mut GemmScratch,
) -> usize {
    int8_matmul_parallel_into_epi(a, w, panel, c, cfg, threads, pool, scratch, None)
}

/// [`int8_matmul_parallel_into`] with a fused [`Epilogue`]: each band
/// dequantizes + applies the epilogue into its disjoint slice of `c`
/// (global row index = band offset + local row).
#[allow(clippy::too_many_arguments)]
pub fn int8_matmul_parallel_into_epi(
    a: &Matrix,
    w: &QuantMatrix,
    panel: Option<&Int8Panel>,
    c: &mut Matrix,
    cfg: &TileConfig,
    threads: usize,
    pool: &ThreadPool,
    scratch: &mut GemmScratch,
    epi: Option<&Epilogue>,
) -> usize {
    assert_eq!(a.cols, w.rows, "GEMM shape mismatch");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, w.cols);
    let (m, k, n) = (a.rows, a.cols, w.cols);
    let eff = super::dense::effective_parallel_threads(m, threads);
    if eff == 1 {
        int8_matmul_tiled_into_epi(a, w, panel, c, cfg, scratch, epi);
        return 1;
    }
    let lda = quad_stride(k);
    scratch.ensure_int8(m * lda, 0, 0);
    let a_scale = quantize_rows_into(a, lda, &mut scratch.qa);
    let qa = &scratch.qa;
    let band = m.div_ceil(eff);
    let r = micro::resolve(cfg);
    let panel = panel.filter(|p| p.kc == k && p.n == n);
    let scales = &w.scales;
    let w_data = &w.data;
    pool.for_each_chunk_mut(&mut c.data, band * n, |t, chunk| {
        let i0 = t * band;
        let rows = chunk.len() / n;
        if rows == 0 {
            return;
        }
        // per-band accumulator: bands are few (= threads) and short-lived
        let mut acc = vec![0i32; rows * n];
        let arows = &qa[i0 * lda..];
        let done = match panel {
            Some(p) => micro::int8_gemm_panel(&r, rows, arows, lda, p, &mut acc, n),
            None => false,
        };
        if !done {
            int8_scalar_strided(arows, lda, w_data, rows, k, n, &mut acc);
        }
        dequant_rows(&acc, a_scale, scales, chunk, i0, epi);
    });
    eff
}

/// Scalar i32 fallback: C (m x n) += qa (m x k, stride `lda`) * B (k x n),
/// skipping zero activation bytes (the same short-circuit the f32
/// fallback uses — quantized activations are frequently exactly zero).
fn int8_scalar_strided(qa: &[i8], lda: usize, b: &[i8], m: usize, k: usize, n: usize, acc: &mut [i32]) {
    for i in 0..m {
        let arow = &qa[i * lda..i * lda + k];
        let crow = &mut acc[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv as i32;
            }
        }
    }
}

/// Dequantize whole rows on store: `out[i*n + j] = acc * a_scale * scales[j]`,
/// composing an optional fused [`Epilogue`] after the dequant (`row0` is the
/// global row index of `out`'s first row, for bias/residual addressing).
fn dequant_rows(
    acc: &[i32],
    a_scale: f32,
    scales: &[f32],
    out: &mut [f32],
    row0: usize,
    epi: Option<&Epilogue>,
) {
    let n = scales.len();
    for (ri, (crow, arow)) in out.chunks_exact_mut(n).zip(acc.chunks_exact(n)).enumerate() {
        match epi {
            Some(e) => {
                for (j, ((cv, &av), &s)) in crow.iter_mut().zip(arow).zip(scales).enumerate() {
                    *cv = e.apply(row0 + ri, j, av as f32 * a_scale * s);
                }
            }
            None => {
                for ((cv, &av), &s) in crow.iter_mut().zip(arow).zip(scales) {
                    *cv = av as f32 * a_scale * s;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// TW (fused CTO condensation)
// ---------------------------------------------------------------------------

/// [`crate::sparse::TwPlan`] with the condensed values quantized to i8.
/// Offset tables are shared shapes with the f32 plan; `scales` is indexed
/// by **original output column** (length `n`).
#[derive(Clone, Debug)]
pub struct Int8TwPlan {
    /// Quantized condensed values, `(tiles, kmax, g)`.
    pub b_cond: Vec<i8>,
    pub row_idx: Vec<i32>,
    pub row_len: Vec<i32>,
    pub col_idx: Vec<i32>,
    pub tiles: usize,
    pub kmax: usize,
    pub g: usize,
    pub k: usize,
    pub n: usize,
    /// Per-output-channel scales (original column space, length `n`);
    /// pruned columns keep scale 1.0.
    pub scales: Vec<f32>,
}

/// Per-original-column symmetric scales over a condensed value array:
/// `amax` per kept column / 127, with 1.0 for all-zero (or pruned)
/// columns.  `at(t, kk, j)` reads the condensed value.
fn column_scales(
    n: usize,
    tiles: usize,
    g: usize,
    col_idx: &[i32],
    row_len: &[i32],
    kt_extent: impl Fn(usize) -> usize,
    at: impl Fn(usize, usize, usize) -> f32,
) -> Vec<f32> {
    let mut scales = vec![1.0f32; n];
    for t in 0..tiles {
        let kt = kt_extent(row_len[t] as usize);
        for j in 0..g {
            let col = col_idx[t * g + j] as usize;
            if col >= n {
                break; // sentinel: no more kept columns in this tile
            }
            let mut amax = 0.0f32;
            for kk in 0..kt {
                amax = amax.max(at(t, kk, j).abs());
            }
            if amax > 0.0 {
                scales[col] = amax / 127.0;
            }
        }
    }
    scales
}

impl Int8TwPlan {
    /// Quantize a condensed TW plan per original output column.
    pub fn from_plan(plan: &TwPlan) -> Int8TwPlan {
        let (tiles, kmax, g, n) = (plan.tiles, plan.kmax, plan.g, plan.n);
        let scales =
            column_scales(n, tiles, g, &plan.col_idx, &plan.row_len, |kt| kt, |t, kk, j| {
                plan.b_cond[(t * kmax + kk) * g + j]
            });
        let mut b_cond = vec![0i8; plan.b_cond.len()];
        for t in 0..tiles {
            for j in 0..g {
                let col = plan.col_idx[t * g + j] as usize;
                if col >= n {
                    break;
                }
                let inv = 1.0 / scales[col];
                for kk in 0..kmax {
                    let idx = (t * kmax + kk) * g + j;
                    b_cond[idx] = (plan.b_cond[idx] * inv).round().clamp(-127.0, 127.0) as i8;
                }
            }
        }
        Int8TwPlan {
            b_cond,
            row_idx: plan.row_idx.clone(),
            row_len: plan.row_len.clone(),
            col_idx: plan.col_idx.clone(),
            tiles,
            kmax,
            g,
            k: plan.k,
            n,
            scales,
        }
    }

    /// Dequantize back to the dense masked weight (the parity oracle).
    pub fn decode(&self) -> Matrix {
        let mut w = Matrix::zeros(self.k, self.n);
        for t in 0..self.tiles {
            let kt = self.row_len[t] as usize;
            for i in 0..kt {
                let r = self.row_idx[t * self.kmax + i] as usize;
                for j in 0..self.g {
                    let c = self.col_idx[t * self.g + j] as usize;
                    if c < self.n {
                        *w.at_mut(r, c) =
                            self.b_cond[(t * self.kmax + i) * self.g + j] as f32 * self.scales[c];
                    }
                }
            }
        }
        w
    }

    /// Bytes of the quantized condensed representation.
    pub fn storage_bytes(&self) -> usize {
        self.b_cond.len()
            + self.row_idx.len() * 4
            + self.col_idx.len() * 4
            + self.row_len.len() * 4
            + self.scales.len() * 4
    }
}

/// Per-tile quad-grouped panels over the quantized condensed blocks.
pub fn int8_tw_pack_panels(plan: &Int8TwPlan, nr: usize) -> Vec<Int8Panel> {
    (0..plan.tiles)
        .map(|t| {
            let base = t * plan.kmax * plan.g;
            Int8Panel::pack(
                &plan.b_cond[base..base + plan.kmax * plan.g],
                plan.kmax,
                plan.g,
                plan.g,
                nr,
            )
        })
        .collect()
}

/// Int8 TW fused kernel: CTO gather on the *quantized* activation block,
/// condensed i32 GEMM, dequantizing CTO scatter.  Like the f32 kernel,
/// only kept output columns are written — the caller zeroes `c` if pruned
/// columns may hold stale data.
pub fn int8_tw_matmul_into(
    a: &Matrix,
    plan: &Int8TwPlan,
    panels: Option<&[Int8Panel]>,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut GemmScratch,
) {
    int8_tw_matmul_into_epi(a, plan, panels, c, cfg, scratch, None)
}

/// [`int8_tw_matmul_into`] with a fused [`Epilogue`] applied at the
/// dequantizing CTO scatter.  Same caller-prefill contract as the f32 TW
/// kernel: when fusing, seed `c` with [`Epilogue::prefill`] first so pruned
/// columns hold `epi(i, j, 0.0)` instead of stale data.
#[allow(clippy::too_many_arguments)]
pub fn int8_tw_matmul_into_epi(
    a: &Matrix,
    plan: &Int8TwPlan,
    panels: Option<&[Int8Panel]>,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut GemmScratch,
    epi: Option<&Epilogue>,
) {
    assert_eq!(a.cols, plan.k);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, plan.n);
    let m = a.rows;
    let bm = cfg.bm();
    let r = micro::resolve(cfg);
    debug_assert_eq!(plan.kmax % 4, 0, "encode rounds kmax to a multiple of 8");
    scratch.ensure_int8(m * a.cols, bm * plan.kmax, bm * plan.g);
    let (qa, qg, qi) = (&mut scratch.qa, &mut scratch.qg, &mut scratch.qi);
    let a_scale = quantize_rows_into(a, a.cols, qa);
    for t in 0..plan.tiles {
        let kt = plan.row_len[t] as usize;
        let width = (0..plan.g)
            .take_while(|&j| (plan.col_idx[t * plan.g + j] as usize) < plan.n)
            .count();
        if kt == 0 || width == 0 {
            continue;
        }
        let rows = &plan.row_idx[t * plan.kmax..t * plan.kmax + kt];
        for i0 in (0..m).step_by(bm) {
            let bm = bm.min(m - i0);
            // CTO gather of quantized A columns (quad-padded rows)
            for i in 0..bm {
                let arow = &qa[(i0 + i) * a.cols..(i0 + i + 1) * a.cols];
                let dst = &mut qg[i * plan.kmax..(i + 1) * plan.kmax];
                for (d, &rr) in dst.iter_mut().zip(rows) {
                    *d = arow[rr as usize];
                }
                for d in dst[kt..].iter_mut() {
                    *d = 0;
                }
            }
            let acc = &mut qi[..bm * plan.g];
            acc.fill(0);
            let mut stride = 0usize;
            if let Some(ps) = panels {
                let p = &ps[t];
                if p.kc == plan.kmax
                    && p.n == plan.g
                    && micro::int8_gemm_panel(&r, bm, qg, plan.kmax, p, acc, plan.g)
                {
                    stride = plan.g;
                }
            }
            if stride == 0 {
                stride = width;
                let b = &plan.b_cond[t * plan.kmax * plan.g..];
                for i in 0..bm {
                    let ag = &qg[i * plan.kmax..i * plan.kmax + kt];
                    let crow = &mut acc[i * width..(i + 1) * width];
                    for (kk, &av) in ag.iter().enumerate() {
                        if av == 0 {
                            continue;
                        }
                        let av = av as i32;
                        let brow = &b[kk * plan.g..kk * plan.g + width];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += av * bv as i32;
                        }
                    }
                }
            }
            // dequantizing CTO scatter (assign, like the f32 kernel)
            match epi {
                Some(e) => {
                    for i in 0..bm {
                        let row = i0 + i;
                        let crow = c.row_mut(row);
                        for j in 0..width {
                            let col = plan.col_idx[t * plan.g + j] as usize;
                            let v = acc[i * stride + j] as f32 * a_scale * plan.scales[col];
                            crow[col] = e.apply(row, col, v);
                        }
                    }
                }
                None => {
                    for i in 0..bm {
                        let crow = c.row_mut(i0 + i);
                        for j in 0..width {
                            let col = plan.col_idx[t * plan.g + j] as usize;
                            crow[col] = acc[i * stride + j] as f32 * a_scale * plan.scales[col];
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// TVW (CTO + register-level 2:4)
// ---------------------------------------------------------------------------

/// [`crate::sparse::TvwPlan`] with the kept values quantized to i8.
#[derive(Clone, Debug)]
pub struct Int8TvwPlan {
    /// Quantized kept values, `(tiles, kmax/2, g)`.
    pub b_vals: Vec<i8>,
    /// In-group positions (0..3), same shape — metadata stays i32.
    pub b_sel: Vec<i32>,
    pub row_idx: Vec<i32>,
    pub row_len: Vec<i32>,
    pub col_idx: Vec<i32>,
    pub tiles: usize,
    pub kmax: usize,
    pub g: usize,
    pub k: usize,
    pub n: usize,
    /// Per-output-channel scales (original column space, length `n`).
    pub scales: Vec<f32>,
}

impl Int8TvwPlan {
    /// Quantize a TVW plan per original output column.
    pub fn from_plan(plan: &TvwPlan) -> Int8TvwPlan {
        let (tiles, kmax, g, n) = (plan.tiles, plan.kmax, plan.g, plan.n);
        let khalf = kmax / 2;
        let scales = column_scales(
            n,
            tiles,
            g,
            &plan.col_idx,
            &plan.row_len,
            |kt| kt.div_ceil(2).min(khalf),
            |t, h, j| plan.b_vals[(t * khalf + h) * g + j],
        );
        let mut b_vals = vec![0i8; plan.b_vals.len()];
        for t in 0..tiles {
            for j in 0..g {
                let col = plan.col_idx[t * g + j] as usize;
                if col >= n {
                    break;
                }
                let inv = 1.0 / scales[col];
                for h in 0..khalf {
                    let idx = (t * khalf + h) * g + j;
                    b_vals[idx] = (plan.b_vals[idx] * inv).round().clamp(-127.0, 127.0) as i8;
                }
            }
        }
        Int8TvwPlan {
            b_vals,
            b_sel: plan.b_sel.clone(),
            row_idx: plan.row_idx.clone(),
            row_len: plan.row_len.clone(),
            col_idx: plan.col_idx.clone(),
            tiles,
            kmax,
            g,
            k: plan.k,
            n,
            scales,
        }
    }

    /// Dequantize back to the dense masked weight (the parity oracle).
    pub fn decode(&self) -> Matrix {
        let khalf = self.kmax / 2;
        let mut w = Matrix::zeros(self.k, self.n);
        for t in 0..self.tiles {
            let kt = self.row_len[t] as usize;
            for h in 0..khalf {
                let grp_base = (h / 2) * 4;
                for j in 0..self.g {
                    let c = self.col_idx[t * self.g + j] as usize;
                    if c >= self.n {
                        continue;
                    }
                    let pos = self.b_sel[(t * khalf + h) * self.g + j] as usize;
                    let cond_row = grp_base + pos;
                    if cond_row >= kt {
                        continue; // zero-padded region beyond the tile's rows
                    }
                    let r = self.row_idx[t * self.kmax + cond_row] as usize;
                    let v = self.b_vals[(t * khalf + h) * self.g + j];
                    if v != 0 {
                        *w.at_mut(r, c) = v as f32 * self.scales[c];
                    }
                }
            }
        }
        w
    }

    /// Bytes of the quantized representation (i8 values + 2-bit metadata
    /// as on hardware + offset tables + scales).
    pub fn storage_bytes(&self) -> usize {
        self.b_vals.len()
            + self.b_vals.len() / 4
            + self.row_idx.len() * 4
            + self.col_idx.len() * 4
            + self.scales.len() * 4
    }
}

/// Int8 TVW fused kernel: CTO gather of quantized activations + register-
/// level 2:4 selection in i32, dequantizing scatter.  `c` is fully
/// overwritten.
pub fn int8_tvw_matmul_into(
    a: &Matrix,
    plan: &Int8TvwPlan,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut GemmScratch,
) {
    int8_tvw_matmul_into_epi(a, plan, c, cfg, scratch, None)
}

/// [`int8_tvw_matmul_into`] with a fused [`Epilogue`] applied at the
/// dequantizing scatter.  The kernel seeds `c` itself (prefill when fusing,
/// zero otherwise); each (row, col) is finalized exactly once because tiles
/// own disjoint output columns and each row visits each tile once.
pub fn int8_tvw_matmul_into_epi(
    a: &Matrix,
    plan: &Int8TvwPlan,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut GemmScratch,
    epi: Option<&Epilogue>,
) {
    assert_eq!(a.cols, plan.k);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, plan.n);
    let m = a.rows;
    let khalf = plan.kmax / 2;
    let bm = cfg.bm();
    let r = micro::resolve(cfg);
    match epi {
        Some(e) => e.prefill(c),
        None => c.data.fill(0.0),
    }
    scratch.ensure_int8(m * a.cols, plan.kmax, plan.g);
    let (qa, qg, qi) = (&mut scratch.qa, &mut scratch.qg, &mut scratch.qi);
    let a_scale = quantize_rows_into(a, a.cols, qa);
    for i0 in (0..m).step_by(bm) {
        let i1 = (i0 + bm).min(m);
        for t in 0..plan.tiles {
            let kt = plan.row_len[t] as usize;
            let width = (0..plan.g)
                .take_while(|&j| (plan.col_idx[t * plan.g + j] as usize) < plan.n)
                .count();
            if kt == 0 || width == 0 {
                continue;
            }
            let rows = &plan.row_idx[t * plan.kmax..t * plan.kmax + kt];
            let groups_max = kt.div_ceil(4).min(plan.kmax / 4);
            for i in i0..i1 {
                let arow = &qa[i * a.cols..(i + 1) * a.cols];
                for (d, &rr) in qg[..kt].iter_mut().zip(rows) {
                    *d = arow[rr as usize];
                }
                for d in qg[kt..plan.kmax].iter_mut() {
                    *d = 0;
                }
                let acc = &mut qi[..width];
                acc.fill(0);
                for grp in 0..groups_max {
                    let a4 = [
                        qg[grp * 4] as i32,
                        qg[grp * 4 + 1] as i32,
                        qg[grp * 4 + 2] as i32,
                        qg[grp * 4 + 3] as i32,
                    ];
                    if a4 == [0; 4] {
                        continue;
                    }
                    let base0 = (t * khalf + grp * 2) * plan.g;
                    let base1 = (t * khalf + grp * 2 + 1) * plan.g;
                    let v0 = &plan.b_vals[base0..base0 + width];
                    let s0 = &plan.b_sel[base0..base0 + width];
                    let v1 = &plan.b_vals[base1..base1 + width];
                    let s1 = &plan.b_sel[base1..base1 + width];
                    if micro::int8_sel24_row(&r, &a4, v0, s0, v1, s1, acc) {
                        continue;
                    }
                    for j in 0..width {
                        acc[j] +=
                            a4[s0[j] as usize] * v0[j] as i32 + a4[s1[j] as usize] * v1[j] as i32;
                    }
                }
                let crow = c.row_mut(i);
                match epi {
                    Some(e) => {
                        for j in 0..width {
                            let col = plan.col_idx[t * plan.g + j] as usize;
                            let v = acc[j] as f32 * a_scale * plan.scales[col];
                            crow[col] = e.apply(i, col, v);
                        }
                    }
                    None => {
                        for j in 0..width {
                            let col = plan.col_idx[t * plan.g + j] as usize;
                            crow[col] += acc[j] as f32 * a_scale * plan.scales[col];
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 2:4 (VW)
// ---------------------------------------------------------------------------

/// [`crate::sparse::Vw24Plan`] with the kept values quantized to i8.
#[derive(Clone, Debug)]
pub struct Int8Vw24Plan {
    /// `(k/2, n)` quantized kept values.
    pub b_vals: Vec<i8>,
    /// `(k/2, n)` in-group positions (0..3).
    pub b_sel: Vec<i32>,
    pub k: usize,
    pub n: usize,
    /// Per-output-channel scales (length `n`).
    pub scales: Vec<f32>,
}

impl Int8Vw24Plan {
    /// Quantize a 2:4 plan per output column.
    pub fn from_plan(plan: &Vw24Plan) -> Int8Vw24Plan {
        let (k, n) = (plan.k, plan.n);
        let khalf = k / 2;
        let mut scales = vec![1.0f32; n];
        for (c, s) in scales.iter_mut().enumerate() {
            let mut amax = 0.0f32;
            for h in 0..khalf {
                amax = amax.max(plan.b_vals[h * n + c].abs());
            }
            if amax > 0.0 {
                *s = amax / 127.0;
            }
        }
        let mut b_vals = vec![0i8; plan.b_vals.len()];
        for h in 0..khalf {
            for c in 0..n {
                let q = (plan.b_vals[h * n + c] / scales[c]).round().clamp(-127.0, 127.0);
                b_vals[h * n + c] = q as i8;
            }
        }
        Int8Vw24Plan { b_vals, b_sel: plan.b_sel.clone(), k, n, scales }
    }

    /// Dequantize back to the dense masked weight (the parity oracle).
    pub fn decode(&self) -> Matrix {
        let khalf = self.k / 2;
        let mut w = Matrix::zeros(self.k, self.n);
        for c in 0..self.n {
            for h in 0..khalf {
                let r = (h / 2) * 4 + self.b_sel[h * self.n + c] as usize;
                *w.at_mut(r, c) = self.b_vals[h * self.n + c] as f32 * self.scales[c];
            }
        }
        w
    }

    /// Bytes of the quantized representation (i8 values + 2-bit metadata
    /// as on hardware + scales).
    pub fn storage_bytes(&self) -> usize {
        self.b_vals.len() + self.b_vals.len() / 4 + self.scales.len() * 4
    }
}

/// Int8 2:4 kernel: register-level selection in i32, one activation row's
/// accumulator at a time, dequantized on store.  `c` is fully overwritten.
pub fn int8_vw24_matmul_into(
    a: &Matrix,
    plan: &Int8Vw24Plan,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut GemmScratch,
) {
    int8_vw24_matmul_into_epi(a, plan, c, cfg, scratch, None)
}

/// [`int8_vw24_matmul_into`] with a fused [`Epilogue`] composed into the
/// per-row dequantizing store.  `c` is fully overwritten.
pub fn int8_vw24_matmul_into_epi(
    a: &Matrix,
    plan: &Int8Vw24Plan,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut GemmScratch,
    epi: Option<&Epilogue>,
) {
    assert_eq!(a.cols, plan.k);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, plan.n);
    let (m, n) = (a.rows, plan.n);
    let groups = plan.k / 4;
    let r = micro::resolve(cfg);
    scratch.ensure_int8(m * a.cols, 0, n);
    let (qa, qi) = (&mut scratch.qa, &mut scratch.qi);
    let a_scale = quantize_rows_into(a, a.cols, qa);
    for i in 0..m {
        let arow = &qa[i * a.cols..(i + 1) * a.cols];
        let acc = &mut qi[..n];
        acc.fill(0);
        for grp in 0..groups {
            let a4 = [
                arow[grp * 4] as i32,
                arow[grp * 4 + 1] as i32,
                arow[grp * 4 + 2] as i32,
                arow[grp * 4 + 3] as i32,
            ];
            if a4 == [0; 4] {
                continue;
            }
            let v0 = &plan.b_vals[(grp * 2) * n..(grp * 2 + 1) * n];
            let s0 = &plan.b_sel[(grp * 2) * n..(grp * 2 + 1) * n];
            let v1 = &plan.b_vals[(grp * 2 + 1) * n..(grp * 2 + 2) * n];
            let s1 = &plan.b_sel[(grp * 2 + 1) * n..(grp * 2 + 2) * n];
            if micro::int8_sel24_row(&r, &a4, v0, s0, v1, s1, acc) {
                continue;
            }
            for j in 0..n {
                acc[j] += a4[s0[j] as usize] * v0[j] as i32 + a4[s1[j] as usize] * v1[j] as i32;
            }
        }
        let crow = c.row_mut(i);
        match epi {
            Some(e) => {
                for (j, ((cv, &av), &s)) in
                    crow.iter_mut().zip(acc.iter()).zip(&plan.scales).enumerate()
                {
                    *cv = e.apply(i, j, av as f32 * a_scale * s);
                }
            }
            None => {
                for ((cv, &av), &s) in crow.iter_mut().zip(acc.iter()).zip(&plan.scales) {
                    *cv = av as f32 * a_scale * s;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{matmul_naive, MicroCfg};
    use crate::sparse::{prune_tvw, prune_tw, prune_vw};
    use crate::util::Rng;

    fn mat(r: usize, c: usize, seed: u64) -> Matrix {
        Matrix::randn(r, c, &mut Rng::new(seed))
    }

    /// Quantization-aware tolerance for C = A * W at reduction depth `k`:
    /// weight error `w_eb` per element, activation error `a_eb`, operand
    /// magnitudes bounded by the oracle inputs.
    fn tolerance(a: &Matrix, w: &Matrix, a_eb: f32, w_eb: f32) -> f32 {
        let a_amax = a.data.iter().fold(0.0f32, |x, &v| x.max(v.abs()));
        let w_amax = w.data.iter().fold(0.0f32, |x, &v| x.max(v.abs()));
        let k = a.cols as f32;
        k * (w_eb * a_amax + a_eb * w_amax + a_eb * w_eb) + 1e-5
    }

    #[test]
    fn int8_dense_matches_fp32_within_quant_error() {
        for &(m, k, n) in &[(1usize, 7usize, 5usize), (9, 33, 21), (16, 64, 48)] {
            let a = mat(m, k, 300 + m as u64);
            let w = mat(k, n, 400 + n as u64);
            let q = QuantMatrix::quantize(&w);
            let mut c = Matrix::zeros(m, n);
            let mut scratch = GemmScratch::new();
            int8_matmul_tiled_into(&a, &q, None, &mut c, &TileConfig::dense_default(), &mut scratch);
            let want = matmul_naive(&a, &w);
            let a_eb = a.data.iter().fold(0.0f32, |x, &v| x.max(v.abs())) / 254.0;
            let tol = tolerance(&a, &w, a_eb, q.max_error_bound());
            assert!(c.max_abs_diff(&want) <= tol, "{m}x{k}x{n}: {} > {tol}", c.max_abs_diff(&want));
        }
    }

    #[test]
    fn int8_dense_panel_and_scalar_agree_exactly() {
        let cfg = TileConfig::dense_default();
        let r = micro::resolve(&cfg);
        if !r.is_simd() {
            return; // scalar host: single path, nothing to cross-check
        }
        let (m, k, n) = (6usize, 35usize, 29usize);
        let a = mat(m, k, 301);
        let q = QuantMatrix::quantize(&mat(k, n, 401));
        let panel = int8_dense_panel(&q, r.nr);
        let mut scratch = GemmScratch::new();
        let mut simd = Matrix::zeros(m, n);
        int8_matmul_tiled_into(&a, &q, Some(&panel), &mut simd, &cfg, &mut scratch);
        let mut scalar = Matrix::zeros(m, n);
        let scfg = cfg.with_micro(MicroCfg::Scalar);
        int8_matmul_tiled_into(&a, &q, None, &mut scalar, &scfg, &mut scratch);
        // both paths share the i32 accumulation and the same scales: the
        // dequantized outputs are bit-identical
        assert_eq!(simd.data, scalar.data);
    }

    #[test]
    fn int8_dense_parallel_matches_serial() {
        let pool = ThreadPool::new(4);
        let (m, k, n) = (64usize, 32usize, 24usize);
        let a = mat(m, k, 302);
        let q = QuantMatrix::quantize(&mat(k, n, 402));
        let cfg = TileConfig::dense_default();
        let mut scratch = GemmScratch::new();
        let mut serial = Matrix::zeros(m, n);
        int8_matmul_tiled_into(&a, &q, None, &mut serial, &cfg, &mut scratch);
        let mut par = Matrix::zeros(m, n);
        let eff =
            int8_matmul_parallel_into(&a, &q, None, &mut par, &cfg, 4, &pool, &mut scratch);
        assert_eq!(eff, 4);
        assert_eq!(par.data, serial.data);
    }

    #[test]
    fn int8_tw_matches_masked_oracle_within_quant_error() {
        let (k, n, g) = (64usize, 48usize, 16usize);
        let w = mat(k, n, 403);
        let tw = prune_tw(&w, 0.75, g, None);
        let plan = crate::sparse::TwPlan::encode(&w, &tw);
        let qplan = Int8TwPlan::from_plan(&plan);
        let wd = plan.decode(); // the masked f32 oracle weight
        let a = mat(9, k, 303);
        let mut c = Matrix::zeros(9, n);
        let mut scratch = GemmScratch::new();
        int8_tw_matmul_into(&a, &qplan, None, &mut c, &TileConfig::tw_default(), &mut scratch);
        let want = matmul_naive(&a, &wd);
        let a_eb = a.data.iter().fold(0.0f32, |x, &v| x.max(v.abs())) / 254.0;
        let w_eb = qplan.scales.iter().fold(0.0f32, |x, &s| x.max(s)) * 0.5;
        let tol = tolerance(&a, &wd, a_eb, w_eb);
        assert!(c.max_abs_diff(&want) <= tol, "{} > {tol}", c.max_abs_diff(&want));
        // panel path agrees exactly with the scalar i32 path
        let r = micro::resolve(&TileConfig::tw_default());
        if r.is_simd() {
            let panels = int8_tw_pack_panels(&qplan, r.nr);
            let mut cp = Matrix::zeros(9, n);
            int8_tw_matmul_into(
                &a,
                &qplan,
                Some(&panels),
                &mut cp,
                &TileConfig::tw_default(),
                &mut scratch,
            );
            assert_eq!(cp.data, c.data);
        }
    }

    #[test]
    fn int8_tvw_matches_masked_oracle_within_quant_error() {
        let (k, n, g) = (64usize, 32usize, 16usize);
        let w = mat(k, n, 404);
        let (tw, mask) = prune_tvw(&w, 0.5, g);
        let plan = crate::sparse::TvwPlan::encode(&w, &tw, &mask);
        let qplan = Int8TvwPlan::from_plan(&plan);
        let wd = plan.decode();
        let a = mat(7, k, 304);
        let mut c = Matrix::zeros(7, n);
        let mut scratch = GemmScratch::new();
        int8_tvw_matmul_into(&a, &qplan, &mut c, &TileConfig::tvw_default(), &mut scratch);
        let want = matmul_naive(&a, &wd);
        let a_eb = a.data.iter().fold(0.0f32, |x, &v| x.max(v.abs())) / 254.0;
        let w_eb = qplan.scales.iter().fold(0.0f32, |x, &s| x.max(s)) * 0.5;
        let tol = tolerance(&a, &wd, a_eb, w_eb);
        assert!(c.max_abs_diff(&want) <= tol, "{} > {tol}", c.max_abs_diff(&want));
    }

    #[test]
    fn int8_vw24_matches_masked_oracle_within_quant_error() {
        let (k, n) = (64usize, 40usize);
        let w = mat(k, n, 405);
        let mask = prune_vw(&w, 0.5, 4);
        let plan = crate::sparse::Vw24Plan::encode(&w, &mask).unwrap();
        let qplan = Int8Vw24Plan::from_plan(&plan);
        let wd = plan.decode();
        let a = mat(5, k, 305);
        let mut c = Matrix::zeros(5, n);
        let mut scratch = GemmScratch::new();
        int8_vw24_matmul_into(&a, &qplan, &mut c, &TileConfig::vw_default(), &mut scratch);
        let want = matmul_naive(&a, &wd);
        let a_eb = a.data.iter().fold(0.0f32, |x, &v| x.max(v.abs())) / 254.0;
        let w_eb = qplan.scales.iter().fold(0.0f32, |x, &s| x.max(s)) * 0.5;
        let tol = tolerance(&a, &wd, a_eb, w_eb);
        assert!(c.max_abs_diff(&want) <= tol, "{} > {tol}", c.max_abs_diff(&want));
    }

    #[test]
    fn int8_plans_decode_close_to_f32_plans() {
        let (k, n, g) = (32usize, 32usize, 8usize);
        let w = mat(k, n, 406);
        let tw = prune_tw(&w, 0.75, g, None);
        let plan = crate::sparse::TwPlan::encode(&w, &tw);
        let qplan = Int8TwPlan::from_plan(&plan);
        let (f, q) = (plan.decode(), qplan.decode());
        for c in 0..n {
            for r in 0..k {
                let d = (f.at(r, c) - q.at(r, c)).abs();
                assert!(d <= qplan.scales[c] * 0.5 + 1e-6, "({r},{c}) d={d}");
            }
        }
        // quantized storage is roughly a quarter of the f32 plan's values
        assert!(qplan.storage_bytes() < plan.storage_bytes());
    }

    #[test]
    fn int8_fused_epilogue_matches_separate_passes() {
        use crate::gemm::Act;
        let (m, k, n) = (9usize, 33usize, 21usize);
        let a = mat(m, k, 310);
        let q = QuantMatrix::quantize(&mat(k, n, 410));
        let bias: Vec<f32> = (0..n).map(|j| (j as f32 - 4.0) * 0.05).collect();
        let res = mat(m, n, 510);
        let cfg = TileConfig::dense_default();
        let mut scratch = GemmScratch::new();
        // unfused reference: int8 GEMM, then bias+relu, then residual
        let mut want = Matrix::zeros(m, n);
        int8_matmul_tiled_into(&a, &q, None, &mut want, &cfg, &mut scratch);
        for i in 0..m {
            for j in 0..n {
                let mut v = want.at(i, j) + bias[j];
                if v < 0.0 {
                    v = 0.0;
                }
                *want.at_mut(i, j) = v + res.at(i, j);
            }
        }
        let epi = Epilogue { bias: Some(&bias), act: Some(Act::Relu), residual: Some(&res) };
        let mut got = Matrix::zeros(m, n);
        int8_matmul_tiled_into_epi(&a, &q, None, &mut got, &cfg, &mut scratch, Some(&epi));
        // same i32 accumulation + same f32 epilogue order: bit-identical
        assert_eq!(got.data, want.data);
        // pooled lane
        let pool = ThreadPool::new(3);
        let mut gp = Matrix::zeros(m, n);
        int8_matmul_parallel_into_epi(
            &a,
            &q,
            None,
            &mut gp,
            &cfg,
            3,
            &pool,
            &mut scratch,
            Some(&epi),
        );
        assert_eq!(gp.data, want.data);
    }

    #[test]
    fn quantize_rows_pads_quads_with_zeros() {
        let a = mat(3, 7, 407); // stride rounds 7 -> 8
        let lda = quad_stride(7);
        assert_eq!(lda, 8);
        let mut dst = vec![99i8; 3 * lda];
        let scale = quantize_rows_into(&a, lda, &mut dst);
        assert!(scale > 0.0);
        for i in 0..3 {
            assert_eq!(dst[i * lda + 7], 0, "row {i} pad");
        }
        let zero = Matrix::zeros(2, 4);
        let mut dz = vec![5i8; 8];
        assert_eq!(quantize_rows_into(&zero, 4, &mut dz), 1.0);
        assert!(dz.iter().all(|&x| x == 0));
    }
}
