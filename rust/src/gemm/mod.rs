//! CPU GEMM kernels: the dense baseline, the TW fused-CTO kernel and its
//! ablation variants, the 2:4 / TVW kernels, and the CSR / block-sparse
//! baselines.  These are the §Perf-profiled hot paths; the GPU-side cost
//! analysis lives in `gpusim`.
//!
//! Every hot path takes a [`TileConfig`] describing its cache-blocking —
//! the `*_with` entry points — with the historical hard-coded tile sizes
//! preserved as defaults behind the original names.  The `autotune` layer
//! searches over these configs empirically.

pub mod dense;
pub mod int8;
pub mod micro;
pub mod spmm;
pub mod tw;
pub mod vw;

pub use dense::{
    effective_parallel_threads, matmul, matmul_naive, matmul_parallel, matmul_parallel_into,
    matmul_parallel_into_epi, matmul_tiled, matmul_tiled_into, matmul_tiled_into_panel,
    matmul_tiled_into_panel_epi,
};
pub use int8::{
    int8_dense_panel, int8_matmul_parallel_into, int8_matmul_parallel_into_epi,
    int8_matmul_tiled_into, int8_matmul_tiled_into_epi, int8_tvw_matmul_into,
    int8_tvw_matmul_into_epi, int8_tw_matmul_into, int8_tw_matmul_into_epi, int8_tw_pack_panels,
    int8_vw24_matmul_into, int8_vw24_matmul_into_epi, Int8TvwPlan, Int8TwPlan, Int8Vw24Plan,
};
pub use micro::{Int8Panel, MicroCfg, PackedPanel};
pub use spmm::{block_spmm, csr_spmm, BlockSparse};
pub use tw::{
    tw_effective_parallel_threads, tw_matmul, tw_matmul_into, tw_matmul_into_scratch,
    tw_matmul_into_scratch_panels, tw_matmul_into_scratch_panels_epi, tw_matmul_into_with,
    tw_matmul_masked, tw_matmul_parallel, tw_matmul_parallel_into, tw_matmul_parallel_into_epi,
    tw_matmul_per_tile, tw_matmul_with, tw_pack_panels,
};
pub use vw::{
    tvw_effective_parallel_threads, tvw_matmul, tvw_matmul_into_scratch,
    tvw_matmul_into_scratch_epi, tvw_matmul_into_with, tvw_matmul_parallel_into,
    tvw_matmul_parallel_into_epi, tvw_matmul_with, vw24_effective_parallel_threads, vw24_matmul,
    vw24_matmul_into_epi, vw24_matmul_into_with, vw24_matmul_parallel_into,
    vw24_matmul_parallel_into_epi, vw24_matmul_with,
};

use crate::tensor::Matrix;

/// Elementwise activation a fused epilogue (or the unfused
/// `Op::BiasAct` executor arm — same formulas, so dense-f32 fusion is
/// bit-identical) applies after the bias add.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    Relu,
    Tanh,
}

/// A fused GEMM epilogue applied at the kernel's store site:
///
/// ```text
/// c[i][j] = act(acc[i][j] + bias[j]) + residual[i][j]
/// ```
///
/// Each stage is optional.  Fusing here removes the separate
/// `Op::BiasAct` / `Op::Residual` full-matrix sweeps the graph executor
/// would otherwise pay — on the bandwidth-bound serving shapes those
/// sweeps cost as much memory traffic as the GEMM's own C write.
///
/// Contract per pattern (see `docs/DESIGN.md` §12): kernels that store
/// every output cell (dense, 2:4) apply it on their completed row
/// blocks before moving on; the condensed kernels (TW, TVW) apply it in
/// the CTO scatter and require the **caller** to seed pruned — never
/// stored — cells with [`Epilogue::prefill`] instead of zeroing C.  The
/// int8 kernels compose it after the per-channel dequant in the same
/// store.  All fields are shared references, so one epilogue is lent
/// simultaneously to every lane of a pooled dispatch.
#[derive(Clone, Copy)]
pub struct Epilogue<'a> {
    /// Per-output-column bias row (length N), added before `act`.
    pub bias: Option<&'a [f32]>,
    pub act: Option<Act>,
    /// Residual operand (same shape as C), added after `act`.
    pub residual: Option<&'a Matrix>,
}

impl Epilogue<'_> {
    /// The epilogue transform for one output cell.
    #[inline(always)]
    pub fn apply(&self, i: usize, j: usize, v: f32) -> f32 {
        let mut v = v;
        if let Some(b) = self.bias {
            v += b[j];
        }
        match self.act {
            Some(Act::Relu) => {
                if v < 0.0 {
                    v = 0.0;
                }
            }
            Some(Act::Tanh) => v = v.tanh(),
            None => {}
        }
        if let Some(r) = self.residual {
            v += r.data[i * r.cols + j];
        }
        v
    }

    /// Seed every cell of `c` with `apply(i, j, 0.0)` — what the
    /// condensed kernels' pruned columns must read after the dispatch
    /// (their accumulator is identically zero).  Replaces the
    /// `c.data.fill(0.0)` a caller performs on the unfused path; same
    /// single sweep of C.
    pub fn prefill(&self, c: &mut Matrix) {
        let cols = c.cols;
        for (i, row) in c.data.chunks_exact_mut(cols).enumerate() {
            for (j, v) in row.iter_mut().enumerate() {
                *v = self.apply(i, j, 0.0);
            }
        }
    }

    /// Apply in place over the completed rows `i0..i1` of `c` — the
    /// post-pass form for kernels that finish whole row blocks (dense
    /// scalar, 2:4) before the epilogue.
    pub fn apply_rows(&self, c: &mut Matrix, i0: usize, i1: usize) {
        let cols = c.cols;
        for i in i0..i1 {
            let row = &mut c.data[i * cols..(i + 1) * cols];
            for (j, v) in row.iter_mut().enumerate() {
                *v = self.apply(i, j, *v);
            }
        }
    }

    /// Apply in place over a raw row-major chunk whose first row is
    /// global row `row0` (the pooled kernels' per-lane output bands).
    pub fn apply_chunk(&self, chunk: &mut [f32], row0: usize, n: usize) {
        for (ri, row) in chunk.chunks_exact_mut(n).enumerate() {
            let i = row0 + ri;
            for (j, v) in row.iter_mut().enumerate() {
                *v = self.apply(i, j, *v);
            }
        }
    }

    /// Compact bit-flag code for telemetry: 1 = bias, 2 = relu,
    /// 4 = tanh, 8 = residual (0 = no epilogue recorded).
    pub fn kind_code(&self) -> usize {
        let mut code = 0;
        if self.bias.is_some() {
            code |= 1;
        }
        match self.act {
            Some(Act::Relu) => code |= 2,
            Some(Act::Tanh) => code |= 4,
            None => {}
        }
        if self.residual.is_some() {
            code |= 8;
        }
        code
    }
}

/// Human-readable label for an [`Epilogue::kind_code`] (telemetry /
/// `profile` output).
pub fn epilogue_label(code: usize) -> String {
    if code == 0 {
        return "-".to_string();
    }
    let mut parts = Vec::new();
    if code & 1 != 0 {
        parts.push("bias");
    }
    if code & 2 != 0 {
        parts.push("relu");
    }
    if code & 4 != 0 {
        parts.push("tanh");
    }
    if code & 8 != 0 {
        parts.push("res");
    }
    parts.join("+")
}

/// Reusable internal scratch for the condensed-kernel hot paths (the CTO
/// gather block and the compact output tile).  The serial TW/TVW `_into`
/// kernels need a small gather/accumulate staging area; the historical
/// entry points allocate it per call, which is fine for one-shot GEMMs but
/// shows up as per-request heap traffic in the serving loop.  The graph
/// executor owns one `GemmScratch` per model workspace, sized once at
/// graph-compile time, and lends it to every `*_into_scratch` call — the
/// steady-state request path then performs zero kernel-side allocations.
#[derive(Default)]
pub struct GemmScratch {
    pub(crate) a: Vec<f32>,
    pub(crate) c: Vec<f32>,
    /// Quantized activation rows (i8, quad-padded) for the int8 paths.
    pub(crate) qa: Vec<i8>,
    /// Int8 CTO gather staging (quantized A columns, per tile).
    pub(crate) qg: Vec<i8>,
    /// i32 accumulator tile for the int8 condensed kernels.
    pub(crate) qi: Vec<i32>,
}

impl GemmScratch {
    pub fn new() -> GemmScratch {
        GemmScratch::default()
    }

    /// Pre-sized scratch (graph compile computes the per-model maxima).
    pub fn with_capacity(a_len: usize, c_len: usize) -> GemmScratch {
        GemmScratch { a: vec![0.0; a_len], c: vec![0.0; c_len], ..GemmScratch::default() }
    }

    /// Grow (never shrink) to at least the requested staging sizes.
    pub(crate) fn ensure(&mut self, a_len: usize, c_len: usize) {
        if self.a.len() < a_len {
            self.a.resize(a_len, 0.0);
        }
        if self.c.len() < c_len {
            self.c.resize(c_len, 0.0);
        }
    }

    /// Grow the int8 staging areas: quantized activations (`qa`), the
    /// per-tile gather block (`qg`) and the i32 accumulator tile (`qi`).
    pub(crate) fn ensure_int8(&mut self, qa_len: usize, qg_len: usize, qi_len: usize) {
        if self.qa.len() < qa_len {
            self.qa.resize(qa_len, 0);
        }
        if self.qg.len() < qg_len {
            self.qg.resize(qg_len, 0);
        }
        if self.qi.len() < qi_len {
            self.qi.resize(qi_len, 0);
        }
    }
}

/// Cache-blocking parameters of a CPU kernel — the register/L1-level "tile
/// shape" the autotuner searches (the GPU-side analogue is the threadblock
/// tile in `gpusim::plans`).
///
/// Not every kernel consumes every field: the dense kernel blocks over
/// (`bm`, `bk`); the TW fused-CTO and TVW kernels block activation rows by
/// `bm` only (their reduction extent is fixed by the condensed plan).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileConfig {
    /// Row-block (M) extent.
    pub bm: usize,
    /// Reduction-block (K) extent.
    pub bk: usize,
    /// Microkernel request for the inner loops (the autotuner's third
    /// axis; `Auto` picks SIMD whenever the runtime ISA allows it).
    pub micro: MicroCfg,
}

impl TileConfig {
    pub const fn new(bm: usize, bk: usize) -> TileConfig {
        TileConfig { bm, bk, micro: MicroCfg::Auto }
    }

    /// Same blocking with an explicit microkernel request.
    pub const fn with_micro(mut self, micro: MicroCfg) -> TileConfig {
        self.micro = micro;
        self
    }

    /// The crate's historical hard-coded dense blocking (64 x 64, tuned
    /// for ~32 KiB L1).
    pub const fn dense_default() -> TileConfig {
        TileConfig::new(64, 64)
    }

    /// The historical hard-coded TW fused-CTO row block (32).
    pub const fn tw_default() -> TileConfig {
        TileConfig::new(32, 64)
    }

    /// The historical 2:4 (VW) behaviour: one activation row at a time.
    pub const fn vw_default() -> TileConfig {
        TileConfig::new(1, 64)
    }

    /// The historical TVW behaviour: tile-outer, one pass over all rows
    /// per tile (`bm` larger than any activation batch in the zoo).
    pub const fn tvw_default() -> TileConfig {
        TileConfig::new(1 << 20, 64)
    }

    /// Degenerate configs (zero extents) clamp to 1 rather than panic.
    pub fn bm(&self) -> usize {
        self.bm.max(1)
    }

    pub fn bk(&self) -> usize {
        self.bk.max(1)
    }

    /// Validate block extents against a pattern family label ("DENSE" /
    /// "TW" / "TVW" / "VW-4").  The kernels themselves clamp degenerate
    /// extents (the historical in-process behaviour, kept above), but
    /// *persisted* configs — plan-cache entries crossing a process
    /// boundary — are rejected instead: a stale entry with `bm = 0` or a
    /// misaligned `bk` should fail loudly at load time, not silently
    /// mis-tile every request it routes.
    pub fn validate(&self, pattern: &str) -> Result<(), String> {
        if self.bm == 0 || self.bk == 0 {
            return Err(format!(
                "invalid tile config bm={} bk={}: block extents must be nonzero",
                self.bm, self.bk
            ));
        }
        if matches!(pattern, "TVW" | "VW-4") && self.bk % 4 != 0 {
            return Err(format!(
                "invalid tile config for {pattern}: bk={} must be a multiple of 4 \
                 (2:4 K-groups are four reduction rows wide)",
                self.bk
            ));
        }
        Ok(())
    }
}

impl Default for TileConfig {
    fn default() -> TileConfig {
        TileConfig::dense_default()
    }
}
