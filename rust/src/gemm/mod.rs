//! CPU GEMM kernels: the dense baseline, the TW fused-CTO kernel and its
//! ablation variants, the 2:4 / TVW kernels, and the CSR / block-sparse
//! baselines.  These are the §Perf-profiled hot paths; the GPU-side cost
//! analysis lives in `gpusim`.
//!
//! Every hot path takes a [`TileConfig`] describing its cache-blocking —
//! the `*_with` entry points — with the historical hard-coded tile sizes
//! preserved as defaults behind the original names.  The `autotune` layer
//! searches over these configs empirically.

pub mod dense;
pub mod int8;
pub mod micro;
pub mod spmm;
pub mod tw;
pub mod vw;

pub use dense::{
    effective_parallel_threads, matmul, matmul_naive, matmul_parallel, matmul_parallel_into,
    matmul_tiled, matmul_tiled_into, matmul_tiled_into_panel,
};
pub use int8::{
    int8_dense_panel, int8_matmul_parallel_into, int8_matmul_tiled_into, int8_tvw_matmul_into,
    int8_tw_matmul_into, int8_tw_pack_panels, int8_vw24_matmul_into, Int8TvwPlan, Int8TwPlan,
    Int8Vw24Plan,
};
pub use micro::{Int8Panel, MicroCfg, PackedPanel};
pub use spmm::{block_spmm, csr_spmm, BlockSparse};
pub use tw::{
    tw_effective_parallel_threads, tw_matmul, tw_matmul_into, tw_matmul_into_scratch,
    tw_matmul_into_scratch_panels, tw_matmul_into_with, tw_matmul_masked, tw_matmul_parallel,
    tw_matmul_parallel_into, tw_matmul_per_tile, tw_matmul_with, tw_pack_panels,
};
pub use vw::{
    tvw_effective_parallel_threads, tvw_matmul, tvw_matmul_into_scratch, tvw_matmul_into_with,
    tvw_matmul_parallel_into, tvw_matmul_with, vw24_effective_parallel_threads, vw24_matmul,
    vw24_matmul_into_with, vw24_matmul_parallel_into, vw24_matmul_with,
};

/// Reusable internal scratch for the condensed-kernel hot paths (the CTO
/// gather block and the compact output tile).  The serial TW/TVW `_into`
/// kernels need a small gather/accumulate staging area; the historical
/// entry points allocate it per call, which is fine for one-shot GEMMs but
/// shows up as per-request heap traffic in the serving loop.  The graph
/// executor owns one `GemmScratch` per model workspace, sized once at
/// graph-compile time, and lends it to every `*_into_scratch` call — the
/// steady-state request path then performs zero kernel-side allocations.
#[derive(Default)]
pub struct GemmScratch {
    pub(crate) a: Vec<f32>,
    pub(crate) c: Vec<f32>,
    /// Quantized activation rows (i8, quad-padded) for the int8 paths.
    pub(crate) qa: Vec<i8>,
    /// Int8 CTO gather staging (quantized A columns, per tile).
    pub(crate) qg: Vec<i8>,
    /// i32 accumulator tile for the int8 condensed kernels.
    pub(crate) qi: Vec<i32>,
}

impl GemmScratch {
    pub fn new() -> GemmScratch {
        GemmScratch::default()
    }

    /// Pre-sized scratch (graph compile computes the per-model maxima).
    pub fn with_capacity(a_len: usize, c_len: usize) -> GemmScratch {
        GemmScratch { a: vec![0.0; a_len], c: vec![0.0; c_len], ..GemmScratch::default() }
    }

    /// Grow (never shrink) to at least the requested staging sizes.
    pub(crate) fn ensure(&mut self, a_len: usize, c_len: usize) {
        if self.a.len() < a_len {
            self.a.resize(a_len, 0.0);
        }
        if self.c.len() < c_len {
            self.c.resize(c_len, 0.0);
        }
    }

    /// Grow the int8 staging areas: quantized activations (`qa`), the
    /// per-tile gather block (`qg`) and the i32 accumulator tile (`qi`).
    pub(crate) fn ensure_int8(&mut self, qa_len: usize, qg_len: usize, qi_len: usize) {
        if self.qa.len() < qa_len {
            self.qa.resize(qa_len, 0);
        }
        if self.qg.len() < qg_len {
            self.qg.resize(qg_len, 0);
        }
        if self.qi.len() < qi_len {
            self.qi.resize(qi_len, 0);
        }
    }
}

/// Cache-blocking parameters of a CPU kernel — the register/L1-level "tile
/// shape" the autotuner searches (the GPU-side analogue is the threadblock
/// tile in `gpusim::plans`).
///
/// Not every kernel consumes every field: the dense kernel blocks over
/// (`bm`, `bk`); the TW fused-CTO and TVW kernels block activation rows by
/// `bm` only (their reduction extent is fixed by the condensed plan).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileConfig {
    /// Row-block (M) extent.
    pub bm: usize,
    /// Reduction-block (K) extent.
    pub bk: usize,
    /// Microkernel request for the inner loops (the autotuner's third
    /// axis; `Auto` picks SIMD whenever the runtime ISA allows it).
    pub micro: MicroCfg,
}

impl TileConfig {
    pub const fn new(bm: usize, bk: usize) -> TileConfig {
        TileConfig { bm, bk, micro: MicroCfg::Auto }
    }

    /// Same blocking with an explicit microkernel request.
    pub const fn with_micro(mut self, micro: MicroCfg) -> TileConfig {
        self.micro = micro;
        self
    }

    /// The crate's historical hard-coded dense blocking (64 x 64, tuned
    /// for ~32 KiB L1).
    pub const fn dense_default() -> TileConfig {
        TileConfig::new(64, 64)
    }

    /// The historical hard-coded TW fused-CTO row block (32).
    pub const fn tw_default() -> TileConfig {
        TileConfig::new(32, 64)
    }

    /// The historical 2:4 (VW) behaviour: one activation row at a time.
    pub const fn vw_default() -> TileConfig {
        TileConfig::new(1, 64)
    }

    /// The historical TVW behaviour: tile-outer, one pass over all rows
    /// per tile (`bm` larger than any activation batch in the zoo).
    pub const fn tvw_default() -> TileConfig {
        TileConfig::new(1 << 20, 64)
    }

    /// Degenerate configs (zero extents) clamp to 1 rather than panic.
    pub fn bm(&self) -> usize {
        self.bm.max(1)
    }

    pub fn bk(&self) -> usize {
        self.bk.max(1)
    }

    /// Validate block extents against a pattern family label ("DENSE" /
    /// "TW" / "TVW" / "VW-4").  The kernels themselves clamp degenerate
    /// extents (the historical in-process behaviour, kept above), but
    /// *persisted* configs — plan-cache entries crossing a process
    /// boundary — are rejected instead: a stale entry with `bm = 0` or a
    /// misaligned `bk` should fail loudly at load time, not silently
    /// mis-tile every request it routes.
    pub fn validate(&self, pattern: &str) -> Result<(), String> {
        if self.bm == 0 || self.bk == 0 {
            return Err(format!(
                "invalid tile config bm={} bk={}: block extents must be nonzero",
                self.bm, self.bk
            ));
        }
        if matches!(pattern, "TVW" | "VW-4") && self.bk % 4 != 0 {
            return Err(format!(
                "invalid tile config for {pattern}: bk={} must be a multiple of 4 \
                 (2:4 K-groups are four reduction rows wide)",
                self.bk
            ));
        }
        Ok(())
    }
}

impl Default for TileConfig {
    fn default() -> TileConfig {
        TileConfig::dense_default()
    }
}
