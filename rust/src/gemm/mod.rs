//! CPU GEMM kernels: the dense baseline, the TW fused-CTO kernel and its
//! ablation variants, the 2:4 / TVW kernels, and the CSR / block-sparse
//! baselines.  These are the §Perf-profiled hot paths; the GPU-side cost
//! analysis lives in `gpusim`.

pub mod dense;
pub mod spmm;
pub mod tw;
pub mod vw;

pub use dense::{matmul, matmul_naive, matmul_parallel};
pub use spmm::{block_spmm, csr_spmm, BlockSparse};
pub use tw::{tw_matmul, tw_matmul_into, tw_matmul_masked, tw_matmul_parallel, tw_matmul_per_tile};
pub use vw::{tvw_matmul, vw24_matmul};
