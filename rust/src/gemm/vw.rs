//! 2:4 vector-wise sparse GEMM (sparse-tensor-core emulation) and the TVW
//! fused kernel on the CPU.

use super::micro;
use super::{Epilogue, TileConfig};
use crate::pool::{split_range, SendPtr, ThreadPool};
use crate::sparse::{TvwPlan, Vw24Plan};
use crate::tensor::Matrix;

/// C = A * B with B stored 2:4-compressed along K, one activation row at a
/// time (the historical behaviour; see [`vw24_matmul_with`]).
pub fn vw24_matmul(a: &Matrix, plan: &Vw24Plan) -> Matrix {
    vw24_matmul_with(a, plan, &TileConfig::vw_default())
}

/// C = A * B with B stored 2:4-compressed along K.  Walks only the kept
/// half of the operands — the arithmetic saving the sparse tensor core
/// realises in hardware.
///
/// Perf (§Perf log): processes one 4-row *group* at a time, staging the
/// four A operands in a register-resident array indexed by the 2-bit
/// metadata, and fusing the group's two compressed rows into one pass —
/// halving metadata-loop overhead and removing the strided A re-reads of
/// the naive per-compressed-row loop (2.0x on the 256x512x512 bench).
///
/// `cfg.bm` blocks activation rows so one compressed B group is reused
/// across the whole row block before moving on (B-operand L1/L2 reuse);
/// `bm = 1` reproduces the historical row-at-a-time order exactly.
pub fn vw24_matmul_with(a: &Matrix, plan: &Vw24Plan, cfg: &TileConfig) -> Matrix {
    let mut c = Matrix::zeros(a.rows, plan.n);
    vw24_matmul_into_with(a, plan, &mut c, cfg);
    c
}

/// In-place 2:4 kernel: `c` is fully overwritten (zeroed, then accumulated
/// group by group).  The serving hot loop reuses the output allocation —
/// the same idiom as [`crate::gemm::tw_matmul_into_with`].
pub fn vw24_matmul_into_with(a: &Matrix, plan: &Vw24Plan, c: &mut Matrix, cfg: &TileConfig) {
    vw24_matmul_into_epi(a, plan, c, cfg, None);
}

/// [`vw24_matmul_into_with`] with a fused [`Epilogue`]: 2:4 stores every
/// output cell, so the epilogue applies in place on each completed row
/// block (still cache-hot) before the kernel advances.
pub fn vw24_matmul_into_epi(
    a: &Matrix,
    plan: &Vw24Plan,
    c: &mut Matrix,
    cfg: &TileConfig,
    epi: Option<&Epilogue>,
) {
    assert_eq!(a.cols, plan.k);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, plan.n);
    let (m, n) = (a.rows, plan.n);
    let groups = plan.k / 4;
    let bm = cfg.bm();
    let r = micro::resolve(cfg);
    c.data.fill(0.0);
    for i0 in (0..m).step_by(bm) {
        let i1 = (i0 + bm).min(m);
        for g in 0..groups {
            let v0 = &plan.b_vals[(g * 2) * n..(g * 2 + 1) * n];
            let s0 = &plan.b_sel[(g * 2) * n..(g * 2 + 1) * n];
            let v1 = &plan.b_vals[(g * 2 + 1) * n..(g * 2 + 2) * n];
            let s1 = &plan.b_sel[(g * 2 + 1) * n..(g * 2 + 2) * n];
            for i in i0..i1 {
                let arow = a.row(i);
                // the four candidate A operands of this group, in registers
                let a4 = [arow[g * 4], arow[g * 4 + 1], arow[g * 4 + 2], arow[g * 4 + 3]];
                if a4 == [0.0; 4] {
                    continue;
                }
                let crow = &mut c.data[i * n..(i + 1) * n];
                // register-level 2:4: expand the metadata with in-register
                // shuffles when the resolved microkernel has that path
                if micro::sel24_row(&r, &a4, v0, s0, v1, s1, crow) {
                    continue;
                }
                for j in 0..n {
                    crow[j] += a4[s0[j] as usize] * v0[j] + a4[s1[j] as usize] * v1[j];
                }
            }
        }
        if let Some(e) = epi {
            e.apply_rows(c, i0, i1);
        }
    }
}

/// TVW fused kernel at the historical tile-outer blocking (one pass over
/// all activation rows per tile).
pub fn tvw_matmul(a: &Matrix, plan: &TvwPlan) -> Matrix {
    tvw_matmul_with(a, plan, &TileConfig::tvw_default())
}

/// TVW fused kernel: CTO gather (global-memory level) + 2:4 metadata
/// expansion (register level) per condensed tile.
///
/// `cfg.bm` blocks activation rows *outside* the tile loop: each row block
/// streams the whole condensed plan before the next block, trading
/// condensed-B re-reads for A/C residency (tiles own disjoint output
/// columns, so block order cannot change any output element's value).
/// `bm >= m` reproduces the historical tile-outer single pass.
pub fn tvw_matmul_with(a: &Matrix, plan: &TvwPlan, cfg: &TileConfig) -> Matrix {
    let mut c = Matrix::zeros(a.rows, plan.n);
    tvw_matmul_into_with(a, plan, &mut c, cfg);
    c
}

/// In-place TVW fused kernel: `c` is fully overwritten (zeroed, then
/// tile-accumulated).  Allocates its small gather/accumulate staging per
/// call; the serving hot loop uses [`tvw_matmul_into_scratch`] instead.
pub fn tvw_matmul_into_with(a: &Matrix, plan: &TvwPlan, c: &mut Matrix, cfg: &TileConfig) {
    tvw_matmul_into_scratch(a, plan, c, cfg, &mut crate::gemm::GemmScratch::new());
}

/// In-place TVW fused kernel reusing a caller-owned
/// [`crate::gemm::GemmScratch`] for the CTO gather row (`kmax`) and the
/// compact output tile (`g`) — zero allocations once the scratch has
/// grown to the model's largest plan.
pub fn tvw_matmul_into_scratch(
    a: &Matrix,
    plan: &TvwPlan,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut crate::gemm::GemmScratch,
) {
    tvw_matmul_into_scratch_epi(a, plan, c, cfg, scratch, None);
}

/// [`tvw_matmul_into_scratch`] with a fused [`Epilogue`] applied at the
/// CTO scatter.  Tiles own disjoint output columns and each row block
/// visits a tile once, so every (row, column) is scattered exactly once
/// — the kernel seeds C itself ([`Epilogue::prefill`] when fused, zeros
/// otherwise; pruned columns then read `act(bias) + residual`) and the
/// scatter assigns `epi.apply(...)` over that seed.
pub fn tvw_matmul_into_scratch_epi(
    a: &Matrix,
    plan: &TvwPlan,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut crate::gemm::GemmScratch,
    epi: Option<&Epilogue>,
) {
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, plan.n);
    let m = a.rows;
    let khalf = plan.kmax / 2;
    let bm = cfg.bm();
    let micro_r = micro::resolve(cfg);
    match epi {
        Some(e) => e.prefill(c),
        None => c.data.fill(0.0),
    }
    scratch.ensure(plan.kmax, plan.g);
    // §Perf: accumulate into a compact c_tile and scatter once per row —
    // the inner loop then writes a contiguous stream the compiler can
    // vectorize, instead of CTO-scattered stores per element.
    let (a_gather, c_tile) = (&mut scratch.a, &mut scratch.c);
    for i0 in (0..m).step_by(bm) {
        let i1 = (i0 + bm).min(m);
        for t in 0..plan.tiles {
            let kt = plan.row_len[t] as usize;
            let width = (0..plan.g)
                .take_while(|&j| (plan.col_idx[t * plan.g + j] as usize) < plan.n)
                .count();
            if kt == 0 || width == 0 {
                continue;
            }
            let rows = &plan.row_idx[t * plan.kmax..t * plan.kmax + kt];
            // only groups whose base is inside the valid kt range can carry
            // nonzeros (encode zero-pads beyond kt)
            let groups_max = kt.div_ceil(4).min(plan.kmax / 4);
            for i in i0..i1 {
                let arow = a.row(i);
                for (d, &r) in a_gather[..kt].iter_mut().zip(rows) {
                    *d = arow[r as usize];
                }
                for x in a_gather[kt..plan.kmax].iter_mut() {
                    *x = 0.0;
                }
                c_tile[..width].fill(0.0);
                for g in 0..groups_max {
                    let a4 = [
                        a_gather[g * 4],
                        a_gather[g * 4 + 1],
                        a_gather[g * 4 + 2],
                        a_gather[g * 4 + 3],
                    ];
                    if a4 == [0.0; 4] {
                        continue;
                    }
                    let base0 = (t * khalf + g * 2) * plan.g;
                    let base1 = (t * khalf + g * 2 + 1) * plan.g;
                    let v0 = &plan.b_vals[base0..base0 + width];
                    let s0 = &plan.b_sel[base0..base0 + width];
                    let v1 = &plan.b_vals[base1..base1 + width];
                    let s1 = &plan.b_sel[base1..base1 + width];
                    let ct = &mut c_tile[..width];
                    if micro::sel24_row(&micro_r, &a4, v0, s0, v1, s1, ct) {
                        continue;
                    }
                    for j in 0..width {
                        c_tile[j] += a4[s0[j] as usize] * v0[j] + a4[s1[j] as usize] * v1[j];
                    }
                }
                let crow = c.row_mut(i);
                match epi {
                    Some(e) => {
                        for j in 0..width {
                            let cj = plan.col_idx[t * plan.g + j] as usize;
                            crow[cj] = e.apply(i, cj, c_tile[j]);
                        }
                    }
                    None => {
                        for j in 0..width {
                            crow[plan.col_idx[t * plan.g + j] as usize] += c_tile[j];
                        }
                    }
                }
            }
        }
    }
}

/// The thread count the column-parallel 2:4 kernel will actually use for
/// an output `n` columns wide: blocks narrower than 16 columns give up
/// vectorization for nothing, so narrow problems run serial.  The single
/// source of truth for the kernel and the autotuner's phantom-parallelism
/// guard.
pub fn vw24_effective_parallel_threads(n: usize, threads: usize) -> usize {
    if threads <= 1 || n < threads * 16 {
        1
    } else {
        threads
    }
}

/// The thread count the tile-parallel TVW kernel will actually use for a
/// plan with `tiles` condensed tiles (the unit of parallelism — twin of
/// [`crate::gemm::tw_effective_parallel_threads`]).
pub fn tvw_effective_parallel_threads(tiles: usize, threads: usize) -> usize {
    if threads <= 1 || tiles < 2 {
        1
    } else {
        threads.min(tiles)
    }
}

/// In-place multi-threaded 2:4 kernel: the output is partitioned into
/// disjoint *column blocks* (each claimed from `pool`), because at
/// serving-sized M (batch ≤ 32) the column dimension is the only axis
/// wide enough to feed many threads.  Every block walks all compressed
/// K-groups over its own column range, so blocks never overlap a write.
/// `c` is fully overwritten.  Returns the effective thread count; on the
/// serial fallback (1) the kernel honours the caller's tuned `cfg`.
pub fn vw24_matmul_parallel_into(
    a: &Matrix,
    plan: &Vw24Plan,
    c: &mut Matrix,
    cfg: &TileConfig,
    threads: usize,
    pool: &ThreadPool,
) -> usize {
    vw24_matmul_parallel_into_epi(a, plan, c, cfg, threads, pool, None)
}

/// [`vw24_matmul_parallel_into`] with a fused [`Epilogue`]: each lane
/// applies it over its own column block once all K-groups have been
/// accumulated, so the fused sweeps parallelize with the GEMM.
#[allow(clippy::too_many_arguments)]
pub fn vw24_matmul_parallel_into_epi(
    a: &Matrix,
    plan: &Vw24Plan,
    c: &mut Matrix,
    cfg: &TileConfig,
    threads: usize,
    pool: &ThreadPool,
    epi: Option<&Epilogue>,
) -> usize {
    assert_eq!(a.cols, plan.k);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, plan.n);
    let (m, n) = (a.rows, plan.n);
    let eff = vw24_effective_parallel_threads(n, threads);
    if eff == 1 {
        vw24_matmul_into_epi(a, plan, c, cfg, epi);
        return 1;
    }
    let groups = plan.k / 4;
    let micro_r = micro::resolve(cfg);
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    pool.parallel_for(eff, |chunk| {
        let (j0, j1) = split_range(n, eff, chunk);
        if j0 >= j1 {
            return;
        }
        let width = j1 - j0;
        for i in 0..m {
            // SAFETY: column ranges are disjoint across chunks
            let crow = unsafe { std::slice::from_raw_parts_mut(c_ptr.0.add(i * n + j0), width) };
            crow.fill(0.0);
        }
        for g in 0..groups {
            let v0 = &plan.b_vals[(g * 2) * n + j0..(g * 2) * n + j1];
            let s0 = &plan.b_sel[(g * 2) * n + j0..(g * 2) * n + j1];
            let v1 = &plan.b_vals[(g * 2 + 1) * n + j0..(g * 2 + 1) * n + j1];
            let s1 = &plan.b_sel[(g * 2 + 1) * n + j0..(g * 2 + 1) * n + j1];
            for i in 0..m {
                let arow = a.row(i);
                let a4 = [arow[g * 4], arow[g * 4 + 1], arow[g * 4 + 2], arow[g * 4 + 3]];
                if a4 == [0.0; 4] {
                    continue;
                }
                // SAFETY: as above — this chunk owns columns j0..j1
                let crow =
                    unsafe { std::slice::from_raw_parts_mut(c_ptr.0.add(i * n + j0), width) };
                if micro::sel24_row(&micro_r, &a4, v0, s0, v1, s1, crow) {
                    continue;
                }
                for j in 0..width {
                    crow[j] += a4[s0[j] as usize] * v0[j] + a4[s1[j] as usize] * v1[j];
                }
            }
        }
        if let Some(e) = epi {
            for i in 0..m {
                // SAFETY: as above — this chunk owns columns j0..j1
                let crow =
                    unsafe { std::slice::from_raw_parts_mut(c_ptr.0.add(i * n + j0), width) };
                for (jo, v) in crow.iter_mut().enumerate() {
                    *v = e.apply(i, j0 + jo, *v);
                }
            }
        }
    });
    eff
}

/// In-place tile-parallel TVW fused kernel: like the TW twin
/// ([`crate::gemm::tw_matmul_parallel_into`]), condensed tiles own
/// disjoint output columns, so contiguous tile ranges are claimed from
/// `pool` lock-free.  `c` is fully overwritten (pruned columns zeroed).
/// Returns the effective thread count; on the serial fallback (1) the
/// kernel honours the caller's tuned `cfg`.
pub fn tvw_matmul_parallel_into(
    a: &Matrix,
    plan: &TvwPlan,
    c: &mut Matrix,
    cfg: &TileConfig,
    threads: usize,
    pool: &ThreadPool,
) -> usize {
    tvw_matmul_parallel_into_epi(a, plan, c, cfg, threads, pool, None)
}

/// [`tvw_matmul_parallel_into`] with a fused [`Epilogue`] applied at the
/// disjoint-column scatter (same seed-then-assign contract as the serial
/// [`tvw_matmul_into_scratch_epi`]; the kernel seeds C itself).
#[allow(clippy::too_many_arguments)]
pub fn tvw_matmul_parallel_into_epi(
    a: &Matrix,
    plan: &TvwPlan,
    c: &mut Matrix,
    cfg: &TileConfig,
    threads: usize,
    pool: &ThreadPool,
    epi: Option<&Epilogue>,
) -> usize {
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, plan.n);
    let eff = tvw_effective_parallel_threads(plan.tiles, threads);
    if eff == 1 {
        tvw_matmul_into_scratch_epi(a, plan, c, cfg, &mut crate::gemm::GemmScratch::new(), epi);
        return 1;
    }
    let m = a.rows;
    let n = plan.n;
    let khalf = plan.kmax / 2;
    let micro_r = micro::resolve(cfg);
    match epi {
        Some(e) => e.prefill(c),
        None => c.data.fill(0.0),
    }
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    pool.parallel_for(eff, |chunk| {
        let (t0, t1) = split_range(plan.tiles, eff, chunk);
        let mut a_gather = vec![0.0f32; plan.kmax];
        let mut c_tile = vec![0.0f32; plan.g];
        for t in t0..t1 {
            let kt = plan.row_len[t] as usize;
            let width = (0..plan.g)
                .take_while(|&j| (plan.col_idx[t * plan.g + j] as usize) < n)
                .count();
            if kt == 0 || width == 0 {
                continue;
            }
            let rows = &plan.row_idx[t * plan.kmax..t * plan.kmax + kt];
            let groups_max = kt.div_ceil(4).min(plan.kmax / 4);
            for i in 0..m {
                let arow = a.row(i);
                for (d, &r) in a_gather[..kt].iter_mut().zip(rows) {
                    *d = arow[r as usize];
                }
                for x in a_gather[kt..plan.kmax].iter_mut() {
                    *x = 0.0;
                }
                c_tile[..width].fill(0.0);
                for g in 0..groups_max {
                    let a4 = [
                        a_gather[g * 4],
                        a_gather[g * 4 + 1],
                        a_gather[g * 4 + 2],
                        a_gather[g * 4 + 3],
                    ];
                    if a4 == [0.0; 4] {
                        continue;
                    }
                    let base0 = (t * khalf + g * 2) * plan.g;
                    let base1 = (t * khalf + g * 2 + 1) * plan.g;
                    let v0 = &plan.b_vals[base0..base0 + width];
                    let s0 = &plan.b_sel[base0..base0 + width];
                    let v1 = &plan.b_vals[base1..base1 + width];
                    let s1 = &plan.b_sel[base1..base1 + width];
                    let ct = &mut c_tile[..width];
                    if micro::sel24_row(&micro_r, &a4, v0, s0, v1, s1, ct) {
                        continue;
                    }
                    for j in 0..width {
                        c_tile[j] += a4[s0[j] as usize] * v0[j] + a4[s1[j] as usize] * v1[j];
                    }
                }
                for j in 0..width {
                    let cj = plan.col_idx[t * plan.g + j] as usize;
                    let v = match epi {
                        Some(e) => e.apply(i, cj, c_tile[j]),
                        None => c_tile[j],
                    };
                    // SAFETY: tiles own disjoint output columns, and tile
                    // ranges are disjoint across chunks; each (row, tile)
                    // pair is visited exactly once, so assignment over the
                    // pre-seeded output equals the serial accumulate
                    unsafe { *c_ptr.0.add(i * n + cj) = v };
                }
            }
        }
    });
    eff
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dense::matmul_naive;
    use crate::sparse::{prune_tvw, prune_vw, TvwPlan, Vw24Plan};
    use crate::util::Rng;

    #[test]
    fn vw24_matches_mask_oracle() {
        let mut rng = Rng::new(90);
        let a = Matrix::randn(24, 64, &mut rng);
        let w = Matrix::randn(64, 48, &mut rng);
        let mask = prune_vw(&w, 0.5, 4);
        let plan = Vw24Plan::encode(&w, &mask).unwrap();
        let want = matmul_naive(&a, &mask.apply(&w));
        assert!(vw24_matmul(&a, &plan).max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn tvw_matches_mask_oracle() {
        let mut rng = Rng::new(91);
        let a = Matrix::randn(24, 96, &mut rng);
        let w = Matrix::randn(96, 80, &mut rng);
        for &s in &[0.5, 0.7, 0.875] {
            let (tw, mask) = prune_tvw(&w, s, 16);
            let plan = TvwPlan::encode(&w, &tw, &mask);
            let want = matmul_naive(&a, &mask.apply(&w));
            let got = tvw_matmul(&a, &plan);
            assert!(got.max_abs_diff(&want) < 1e-3, "s={s}: {}", got.max_abs_diff(&want));
        }
    }

    #[test]
    fn tile_configs_agree_with_default() {
        let mut rng = Rng::new(93);
        let a = Matrix::randn(37, 64, &mut rng);
        let w = Matrix::randn(64, 48, &mut rng);
        let (tw, tvmask) = prune_tvw(&w, 0.75, 16);
        let tvplan = TvwPlan::encode(&w, &tw, &tvmask);
        let want_tvw = tvw_matmul(&a, &tvplan);
        let mask24 = prune_vw(&w, 0.5, 4);
        let vplan = Vw24Plan::encode(&w, &mask24).unwrap();
        let want_vw = vw24_matmul(&a, &vplan);
        for &bm in &[1usize, 7, 16, 64, 128, 0] {
            let cfg = TileConfig::new(bm, 64);
            assert!(tvw_matmul_with(&a, &tvplan, &cfg).max_abs_diff(&want_tvw) < 1e-4, "tvw bm={bm}");
            assert!(vw24_matmul_with(&a, &vplan, &cfg).max_abs_diff(&want_vw) < 1e-4, "vw bm={bm}");
        }
    }

    #[test]
    fn into_variants_fully_overwrite() {
        let mut rng = Rng::new(94);
        let a = Matrix::randn(13, 64, &mut rng);
        let w = Matrix::randn(64, 48, &mut rng);
        let (tw, tvmask) = prune_tvw(&w, 0.75, 16);
        let tvplan = TvwPlan::encode(&w, &tw, &tvmask);
        let mask24 = prune_vw(&w, 0.5, 4);
        let vplan = Vw24Plan::encode(&w, &mask24).unwrap();
        let cfg = TileConfig::new(8, 64);
        let want_tvw = tvw_matmul_with(&a, &tvplan, &cfg);
        let want_vw = vw24_matmul_with(&a, &vplan, &cfg);
        let mut c = Matrix::zeros(13, 48);
        for v in &mut c.data {
            *v = 1e9; // stale output must not leak through
        }
        tvw_matmul_into_with(&a, &tvplan, &mut c, &cfg);
        assert!(c.max_abs_diff(&want_tvw) < 1e-4);
        for v in &mut c.data {
            *v = -1e9;
        }
        vw24_matmul_into_with(&a, &vplan, &mut c, &cfg);
        assert!(c.max_abs_diff(&want_vw) < 1e-4);
    }

    #[test]
    fn scratch_variant_matches_and_is_reusable() {
        // one undersized scratch across differently-shaped plans: results
        // must match the allocating kernels exactly
        let mut rng = Rng::new(95);
        let mut scratch = crate::gemm::GemmScratch::new();
        for (k, n, g) in [(64usize, 48usize, 16usize), (96, 80, 8), (32, 32, 32)] {
            let a = Matrix::randn(11, k, &mut rng);
            let w = Matrix::randn(k, n, &mut rng);
            let (tw, mask) = prune_tvw(&w, 0.75, g);
            let plan = TvwPlan::encode(&w, &tw, &mask);
            let cfg = TileConfig::new(8, 64);
            let want = tvw_matmul_with(&a, &plan, &cfg);
            let mut c = Matrix::zeros(11, n);
            tvw_matmul_into_scratch(&a, &plan, &mut c, &cfg, &mut scratch);
            assert!(c.max_abs_diff(&want) < 1e-6, "{k}x{n} g={g}");
        }
    }

    #[test]
    fn simd_paths_match_scalar_oracle() {
        // forced-scalar vs forced-SIMD parity for both 2:4 kernels, serial
        // and pooled, at m = 1 and at a column count that is not a lane
        // multiple (84 = 10 full 8-wide chunks + a 4-wide scalar tail);
        // on non-SIMD hosts the SIMD request degrades to scalar and the
        // comparison is exact
        use crate::gemm::MicroCfg;
        let mut rng = Rng::new(96);
        let scalar_cfg = TileConfig::new(8, 64).with_micro(MicroCfg::Scalar);
        let simd_cfg = TileConfig::new(8, 64).with_micro(MicroCfg::Simd { mr: 4, nr: 16 });
        let pool = ThreadPool::new(4);
        for m in [1usize, 33] {
            let a = Matrix::randn(m, 96, &mut rng);
            let w = Matrix::randn(96, 84, &mut rng);
            let mask = prune_vw(&w, 0.5, 4);
            let vplan = Vw24Plan::encode(&w, &mask).unwrap();
            let want = vw24_matmul_with(&a, &vplan, &scalar_cfg);
            let got = vw24_matmul_with(&a, &vplan, &simd_cfg);
            assert!(got.max_abs_diff(&want) < 1e-4, "vw24 serial m={m}");
            let mut c = Matrix::zeros(m, 84);
            vw24_matmul_parallel_into(&a, &vplan, &mut c, &simd_cfg, 4, &pool);
            assert!(c.max_abs_diff(&want) < 1e-4, "vw24 pooled m={m}");

            let (tw, tvmask) = prune_tvw(&w, 0.7, 16);
            let tvplan = TvwPlan::encode(&w, &tw, &tvmask);
            let want = tvw_matmul_with(&a, &tvplan, &scalar_cfg);
            let got = tvw_matmul_with(&a, &tvplan, &simd_cfg);
            assert!(got.max_abs_diff(&want) < 1e-4, "tvw serial m={m}");
            let mut c = Matrix::zeros(m, 84);
            tvw_matmul_parallel_into(&a, &tvplan, &mut c, &simd_cfg, 4, &pool);
            assert!(c.max_abs_diff(&want) < 1e-4, "tvw pooled m={m}");
        }
    }

    #[test]
    fn tvw_agrees_with_decode_then_dense() {
        let mut rng = Rng::new(92);
        let a = Matrix::randn(16, 64, &mut rng);
        let w = Matrix::randn(64, 64, &mut rng);
        let (tw, mask) = prune_tvw(&w, 0.75, 16);
        let plan = TvwPlan::encode(&w, &tw, &mask);
        let want = matmul_naive(&a, &plan.decode());
        assert!(tvw_matmul(&a, &plan).max_abs_diff(&want) < 1e-3);
    }
}
