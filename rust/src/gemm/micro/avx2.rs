//! AVX2 f32 microkernels: 8-lane FMA with register-blocked MR x NR
//! accumulator tiles, plus the 2:4 metadata-shuffle selection kernel.
//!
//! Everything here requires AVX2+FMA at runtime.  Callers go through the
//! dispatch wrappers in [`super`], which consult
//! `is_x86_feature_detected!` (cached in [`super::active_isa`]) before
//! reaching this module — these functions are never called on hardware
//! that lacks the features they enable.

use core::arch::x86_64::*;

use super::panel::{Int8Panel, PackedPanel};

/// Snap an arbitrary (MR, NR-vectors) request onto a compiled kernel
/// instantiation: NRV in {1, 2}, MR in {1, 2, 4, 8}, capped at MR = 4
/// when NRV = 2 so the accumulator tile plus the two B vectors and the
/// A broadcast stay inside the 16-register ymm file.
pub(super) fn clamp_block(mr: usize, nrv: usize) -> (usize, usize) {
    let nrv = if nrv >= 2 { 2 } else { 1 };
    let cap = if nrv == 2 { 4 } else { 8 };
    let want = mr.clamp(1, cap);
    let mr = [8usize, 4, 2, 1].into_iter().find(|&c| c <= want).unwrap_or(1);
    (mr, nrv)
}

macro_rules! def_kernel {
    ($name:ident, $mr:expr, $nrv:expr) => {
        /// One register tile: C[MR x 8*NRV] += A[MR x kt] * B[kt x 8*NRV].
        /// A rows stride by `lda`, B reduction steps stride by `ldb`,
        /// C rows stride by `ldc`; all pointers at the tile origin.
        #[target_feature(enable = "avx2,fma")]
        unsafe fn $name(
            a: *const f32,
            lda: usize,
            b: *const f32,
            ldb: usize,
            c: *mut f32,
            ldc: usize,
            kt: usize,
        ) {
            const MR: usize = $mr;
            const NRV: usize = $nrv;
            let mut acc = [[_mm256_setzero_ps(); NRV]; MR];
            let mut ap = a;
            let mut bp = b;
            for _ in 0..kt {
                let mut bv = [_mm256_setzero_ps(); NRV];
                for (v, slot) in bv.iter_mut().enumerate() {
                    *slot = _mm256_loadu_ps(bp.add(8 * v));
                }
                for (i, row) in acc.iter_mut().enumerate() {
                    let av = _mm256_set1_ps(*ap.add(i * lda));
                    for (cell, bvec) in row.iter_mut().zip(bv.iter()) {
                        *cell = _mm256_fmadd_ps(av, *bvec, *cell);
                    }
                }
                ap = ap.add(1);
                bp = bp.add(ldb);
            }
            for (i, row) in acc.iter().enumerate() {
                for (v, cell) in row.iter().enumerate() {
                    let cp = c.add(i * ldc + 8 * v);
                    _mm256_storeu_ps(cp, _mm256_add_ps(_mm256_loadu_ps(cp), *cell));
                }
            }
        }
    };
}

def_kernel!(k1x1, 1, 1);
def_kernel!(k2x1, 2, 1);
def_kernel!(k4x1, 4, 1);
def_kernel!(k8x1, 8, 1);
def_kernel!(k1x2, 1, 2);
def_kernel!(k2x2, 2, 2);
def_kernel!(k4x2, 4, 2);

/// Route to the matching instantiation; `(mr, nrv)` must come from
/// [`clamp_block`] (the wildcard arm is the remaining (1, 2) case).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
unsafe fn kernel(
    mr: usize,
    nrv: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
    kt: usize,
) {
    match (mr, nrv) {
        (8, 1) => k8x1(a, lda, b, ldb, c, ldc, kt),
        (4, 1) => k4x1(a, lda, b, ldb, c, ldc, kt),
        (2, 1) => k2x1(a, lda, b, ldb, c, ldc, kt),
        (1, 1) => k1x1(a, lda, b, ldb, c, ldc, kt),
        (4, 2) => k4x2(a, lda, b, ldb, c, ldc, kt),
        (2, 2) => k2x2(a, lda, b, ldb, c, ldc, kt),
        _ => k1x2(a, lda, b, ldb, c, ldc, kt),
    }
}

/// All rows of one strip: MR-sized row blocks, row remainder at MR = 1.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
unsafe fn strip(
    m: usize,
    kt: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nrv: usize,
) {
    let mut i = 0;
    while i + mr <= m {
        kernel(mr, nrv, a.add(i * lda), lda, b, ldb, c.add(i * ldc), ldc, kt);
        i += mr;
    }
    while i < m {
        kernel(1, nrv, a.add(i * lda), lda, b, ldb, c.add(i * ldc), ldc, kt);
        i += 1;
    }
}

/// Columns past the last full 8-wide strip (< 8 of them): plain scalar —
/// B is strided here, so masked loads would not pay for themselves.
#[allow(clippy::too_many_arguments)]
unsafe fn scalar_cols(
    m: usize,
    kt: usize,
    w: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
) {
    for i in 0..m {
        for j in 0..w {
            let mut acc = 0.0f32;
            for kk in 0..kt {
                acc += *a.add(i * lda + kk) * *b.add(kk * ldb + j);
            }
            *c.add(i * ldc + j) += acc;
        }
    }
}

/// C (m x n, row stride `ldc`) += A (m x kt, row stride `lda`) *
/// B (kt x n, row stride `ldb`): the strided-B entry point.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn gemm_strided(
    m: usize,
    kt: usize,
    n: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nrv: usize,
) {
    let (mr, nrv) = clamp_block(mr, nrv);
    let mut j = 0;
    while j + 8 * nrv <= n {
        strip(m, kt, a, lda, b.add(j), ldb, c.add(j), ldc, mr, nrv);
        j += 8 * nrv;
    }
    if nrv == 2 && j + 8 <= n {
        strip(m, kt, a, lda, b.add(j), ldb, c.add(j), ldc, mr, 1);
        j += 8;
    }
    if j < n {
        scalar_cols(m, kt, n - j, a, lda, b.add(j), ldb, c.add(j), ldc);
    }
}

/// C (m x panel.n, row stride `ldc`) += A (m x kt, row stride `lda`,
/// reduction offset `k0` into the panel) * the packed strips of `panel`.
/// Full strips stream contiguously at stride NR; the zero-padded tail
/// strip is computed into a stack tile and only its valid lanes added.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn gemm_panel(
    m: usize,
    k0: usize,
    kt: usize,
    a: *const f32,
    lda: usize,
    panel: &PackedPanel,
    c: *mut f32,
    ldc: usize,
    mr: usize,
) {
    let nr = panel.nr;
    let (mr, nrv) = clamp_block(mr, nr / 8);
    let data = panel.data.as_ptr();
    for p in 0..panel.strips() {
        let j0 = p * nr;
        let bp = data.add(p * panel.kc * nr + k0 * nr);
        if j0 + nr <= panel.n {
            strip(m, kt, a, lda, bp, nr, c.add(j0), ldc, mr, nrv);
        } else {
            let w = panel.n - j0;
            for i in 0..m {
                let mut tile = [0.0f32; 16];
                kernel(1, nrv, a.add(i * lda), lda, bp, nr, tile.as_mut_ptr(), 16, kt);
                let crow = c.add(i * ldc + j0);
                for (jj, v) in tile.iter().take(w).enumerate() {
                    *crow.add(jj) += *v;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Int8 microkernels: i8 x i8 -> i32 with the `maddubs`/`madd` pair.
//
// AVX2 has no signed-by-signed byte multiply; `vpmaddubsw` multiplies
// *unsigned* bytes by signed bytes.  The standard identity rescues it:
// `a * b == |a| * (b * sign(a))`, so each A quad is broadcast, `vpabsb`'d
// into the unsigned operand, and `vpsignb` transfers A's sign onto B.
// Values are clamped to +/-127 at quantization time, so the i16 pair sums
// stay <= 2 * 127 * 127 = 32258 and `vpmaddubsw`'s saturation never
// engages; `vpmaddwd` against ones then widens the pairs into the i32
// accumulator lanes.  This is exactly the two-instruction emulation of
// AVX-512 VNNI's `vpdpbusd` (which `gemm/micro/avx512.rs` uses directly
// when the CPU has it).
// ---------------------------------------------------------------------

macro_rules! def_int8_kernel {
    ($name:ident, $mr:expr, $nrv:expr) => {
        /// One register tile: C_i32[MR x 8*NRV] += A_q[MR x 4*kq] * the
        /// quad-grouped panel bytes at `b`.  A rows stride by `lda` bytes
        /// and must be zero-padded to the panel's quad extent; `b` steps
        /// `nr * 4` bytes per quad.
        #[target_feature(enable = "avx2")]
        unsafe fn $name(
            a: *const i8,
            lda: usize,
            b: *const i8,
            c: *mut i32,
            ldc: usize,
            kq: usize,
            nr: usize,
        ) {
            const MR: usize = $mr;
            const NRV: usize = $nrv;
            let ones = _mm256_set1_epi16(1);
            let mut acc = [[_mm256_setzero_si256(); NRV]; MR];
            let mut bp = b;
            for q in 0..kq {
                let mut bv = [_mm256_setzero_si256(); NRV];
                for (v, slot) in bv.iter_mut().enumerate() {
                    *slot = _mm256_loadu_si256(bp.add(32 * v) as *const __m256i);
                }
                for (i, row) in acc.iter_mut().enumerate() {
                    let quad = (a.add(i * lda + q * 4) as *const i32).read_unaligned();
                    let ab = _mm256_set1_epi32(quad);
                    let ua = _mm256_abs_epi8(ab);
                    for (cell, bvec) in row.iter_mut().zip(bv.iter()) {
                        let sb = _mm256_sign_epi8(*bvec, ab);
                        let pairs = _mm256_maddubs_epi16(ua, sb);
                        *cell = _mm256_add_epi32(*cell, _mm256_madd_epi16(pairs, ones));
                    }
                }
                bp = bp.add(nr * 4);
            }
            for (i, row) in acc.iter().enumerate() {
                for (v, cell) in row.iter().enumerate() {
                    let cp = c.add(i * ldc + 8 * v) as *mut __m256i;
                    _mm256_storeu_si256(cp, _mm256_add_epi32(_mm256_loadu_si256(cp), *cell));
                }
            }
        }
    };
}

def_int8_kernel!(q1x1, 1, 1);
def_int8_kernel!(q2x1, 2, 1);
def_int8_kernel!(q4x1, 4, 1);
def_int8_kernel!(q8x1, 8, 1);
def_int8_kernel!(q1x2, 1, 2);
def_int8_kernel!(q2x2, 2, 2);
def_int8_kernel!(q4x2, 4, 2);

/// Route to the matching int8 instantiation; `(mr, nrv)` must come from
/// [`clamp_block`] (the wildcard arm is the remaining (1, 2) case).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
unsafe fn int8_kernel(
    mr: usize,
    nrv: usize,
    a: *const i8,
    lda: usize,
    b: *const i8,
    c: *mut i32,
    ldc: usize,
    kq: usize,
    nr: usize,
) {
    match (mr, nrv) {
        (8, 1) => q8x1(a, lda, b, c, ldc, kq, nr),
        (4, 1) => q4x1(a, lda, b, c, ldc, kq, nr),
        (2, 1) => q2x1(a, lda, b, c, ldc, kq, nr),
        (1, 1) => q1x1(a, lda, b, c, ldc, kq, nr),
        (4, 2) => q4x2(a, lda, b, c, ldc, kq, nr),
        (2, 2) => q2x2(a, lda, b, c, ldc, kq, nr),
        _ => q1x2(a, lda, b, c, ldc, kq, nr),
    }
}

/// C_i32 (m x panel.n, row stride `ldc`) += A_q (m x kc i8, row stride
/// `lda` with rows zero-padded to `panel.kq * 4` bytes) * the packed
/// strips of `panel`.  The full reduction runs in one pass — at serving
/// M the i8 operands of one strip stay L1-resident, and a single pass
/// keeps the i32 accumulators in registers for their whole lifetime.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn int8_gemm_panel(
    m: usize,
    a: *const i8,
    lda: usize,
    panel: &Int8Panel,
    c: *mut i32,
    ldc: usize,
    mr: usize,
) {
    let nr = panel.nr;
    let kq = panel.kq;
    let (mr, nrv) = clamp_block(mr, nr / 8);
    let data = panel.data.as_ptr();
    for s in 0..panel.strips() {
        let j0 = s * nr;
        let bp = data.add(s * kq * nr * 4);
        if j0 + nr <= panel.n {
            let mut i = 0;
            while i + mr <= m {
                int8_kernel(mr, nrv, a.add(i * lda), lda, bp, c.add(i * ldc + j0), ldc, kq, nr);
                i += mr;
            }
            while i < m {
                int8_kernel(1, nrv, a.add(i * lda), lda, bp, c.add(i * ldc + j0), ldc, kq, nr);
                i += 1;
            }
        } else {
            // zero-padded tail strip: compute the full width into a stack
            // tile, add only the valid lanes
            let w = panel.n - j0;
            for i in 0..m {
                let mut tile = [0i32; 16];
                int8_kernel(1, nrv, a.add(i * lda), lda, bp, tile.as_mut_ptr(), 16, kq, nr);
                let crow = c.add(i * ldc + j0);
                for (jj, v) in tile.iter().take(w).enumerate() {
                    *crow.add(jj) += *v;
                }
            }
        }
    }
}

/// Int8 twin of [`sel24_row`]: `c[j] += a4[s0[j]] * v0[j] + a4[s1[j]] *
/// v1[j]` with i32 accumulators.  The gathered A quad arrives widened to
/// i32; `vpermd` against the duplicated quad expands the 2-bit metadata,
/// and the compressed i8 value rows are sign-extended with `vpmovsxbd`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn int8_sel24_row(
    a4: *const i32,
    v0: *const i8,
    s0: *const i32,
    v1: *const i8,
    s1: *const i32,
    c: *mut i32,
    n: usize,
) {
    let a128 = _mm_loadu_si128(a4 as *const __m128i);
    let av = _mm256_set_m128i(a128, a128);
    let mut j = 0;
    while j + 8 <= n {
        let sel0 = _mm256_loadu_si256(s0.add(j) as *const __m256i);
        let sel1 = _mm256_loadu_si256(s1.add(j) as *const __m256i);
        let x0 = _mm256_permutevar8x32_epi32(av, sel0);
        let x1 = _mm256_permutevar8x32_epi32(av, sel1);
        let w0 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(v0.add(j) as *const __m128i));
        let w1 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(v1.add(j) as *const __m128i));
        let mut acc = _mm256_loadu_si256(c.add(j) as *const __m256i);
        acc = _mm256_add_epi32(acc, _mm256_mullo_epi32(x0, w0));
        acc = _mm256_add_epi32(acc, _mm256_mullo_epi32(x1, w1));
        _mm256_storeu_si256(c.add(j) as *mut __m256i, acc);
        j += 8;
    }
    while j < n {
        let q0 = (*s0.add(j) as usize) & 3;
        let q1 = (*s1.add(j) as usize) & 3;
        *c.add(j) += *a4.add(q0) * *v0.add(j) as i32 + *a4.add(q1) * *v1.add(j) as i32;
        j += 1;
    }
}

/// One activation row of the 2:4 selection kernel: for each output
/// column `j`, `c[j] += a4[s0[j]] * v0[j] + a4[s1[j]] * v1[j]`.
///
/// The 2-bit metadata (in-group positions 0..4, stored as i32) is
/// expanded in registers: `a4` is duplicated into both 128-bit halves of
/// a ymm, so `vpermps` with the raw selector values picks the right A
/// element in every lane, and both compressed value rows are folded in
/// with one FMA each per 8 columns.
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn sel24_row(
    a4: *const f32,
    v0: *const f32,
    s0: *const i32,
    v1: *const f32,
    s1: *const i32,
    c: *mut f32,
    n: usize,
) {
    let a128 = _mm_loadu_ps(a4);
    let av = _mm256_set_m128(a128, a128);
    let mut j = 0;
    while j + 8 <= n {
        let sel0 = _mm256_loadu_si256(s0.add(j) as *const __m256i);
        let sel1 = _mm256_loadu_si256(s1.add(j) as *const __m256i);
        let x0 = _mm256_permutevar8x32_ps(av, sel0);
        let x1 = _mm256_permutevar8x32_ps(av, sel1);
        let mut acc = _mm256_loadu_ps(c.add(j));
        acc = _mm256_fmadd_ps(x0, _mm256_loadu_ps(v0.add(j)), acc);
        acc = _mm256_fmadd_ps(x1, _mm256_loadu_ps(v1.add(j)), acc);
        _mm256_storeu_ps(c.add(j), acc);
        j += 8;
    }
    while j < n {
        let q0 = (*s0.add(j) as usize) & 3;
        let q1 = (*s1.add(j) as usize) & 3;
        *c.add(j) += *a4.add(q0) * *v0.add(j) + *a4.add(q1) * *v1.add(j);
        j += 1;
    }
}
