//! K-major packed-B panels.
//!
//! The strided microkernel loads each B row at stride `ldb`, which walks
//! the cache a full row apart per reduction step.  For the serving path —
//! where the weight is packed once and streamed on every request — we
//! re-lay B out as NR-wide column strips stored K-major:
//!
//! ```text
//! data[strip * kc * nr + kk * nr + lane]  ==  B[kk, strip * nr + lane]
//! ```
//!
//! so the microkernel's per-k step reads one contiguous `nr`-wide run and
//! an entire strip streams sequentially through the hardware prefetcher.
//! The last strip is zero-padded to `nr` lanes: kernels may compute the
//! full strip width into a staging tile, and the padding contributes
//! exact zeros.
//!
//! Only the dense and TW operands need this treatment.  The TVW / 2:4
//! plan arrays (`b_vals` / `b_sel`) are already laid out contiguously in
//! the output-column direction — the condensed plan is its own panel
//! layout — so those kernels stream the plan directly.

/// One B operand repacked into K-major, NR-wide column strips.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedPanel {
    /// Strip width (the microkernel NR).
    pub nr: usize,
    /// Reduction extent (B rows).
    pub kc: usize,
    /// Valid output columns (B cols; the last strip pads up to `nr`).
    pub n: usize,
    /// `strips() * kc * nr` values.
    pub data: Vec<f32>,
}

impl PackedPanel {
    /// Repack a row-major `kc x n` block (row stride `ldb >= n`) into
    /// K-major NR-wide strips.  Rows beyond the source block are the
    /// caller's concern; lanes past `n` in the last strip are zero.
    pub fn pack(b: &[f32], kc: usize, n: usize, ldb: usize, nr: usize) -> PackedPanel {
        assert!(nr > 0, "panel strip width must be nonzero");
        assert!(n <= ldb, "panel: n={n} exceeds row stride ldb={ldb}");
        assert!(kc == 0 || n == 0 || (kc - 1) * ldb + n <= b.len(), "panel source out of bounds");
        let strips = n.div_ceil(nr);
        let mut data = vec![0.0f32; strips * kc * nr];
        for s in 0..strips {
            let j0 = s * nr;
            let w = (n - j0).min(nr);
            for kk in 0..kc {
                let src = &b[kk * ldb + j0..kk * ldb + j0 + w];
                let base = s * kc * nr + kk * nr;
                data[base..base + w].copy_from_slice(src);
            }
        }
        PackedPanel { nr, kc, n, data }
    }

    /// Number of NR-wide strips (the last one may be partial).
    pub fn strips(&self) -> usize {
        self.n.div_ceil(self.nr)
    }

    /// Bytes held by the packed copy (memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_reorders_into_k_major_strips() {
        // 3 x 5 block inside a row stride of 6, nr = 2 -> 3 strips
        let ldb = 6;
        let b: Vec<f32> = (0..3 * ldb).map(|x| x as f32).collect();
        let p = PackedPanel::pack(&b, 3, 5, ldb, 2);
        assert_eq!(p.strips(), 3);
        assert_eq!(p.data.len(), 3 * 3 * 2);
        for kk in 0..3 {
            for j in 0..5 {
                let (s, lane) = (j / 2, j % 2);
                let got = p.data[s * 3 * 2 + kk * 2 + lane];
                assert_eq!(got, b[kk * ldb + j], "k={kk} j={j}");
            }
            // padded lane of the last strip stays zero
            assert_eq!(p.data[2 * 3 * 2 + kk * 2 + 1], 0.0);
        }
        assert_eq!(p.bytes(), p.data.len() * 4);
    }

    #[test]
    fn degenerate_shapes_pack_cleanly() {
        let p = PackedPanel::pack(&[], 0, 0, 0, 8);
        assert_eq!(p.strips(), 0);
        assert!(p.data.is_empty());
        let b = vec![1.0f32; 4];
        let p = PackedPanel::pack(&b, 4, 1, 1, 8);
        assert_eq!(p.strips(), 1);
        assert_eq!(p.data.len(), 4 * 8);
        assert_eq!(p.data[0], 1.0);
        assert_eq!(p.data[1], 0.0);
    }
}
