//! K-major packed-B panels.
//!
//! The strided microkernel loads each B row at stride `ldb`, which walks
//! the cache a full row apart per reduction step.  For the serving path —
//! where the weight is packed once and streamed on every request — we
//! re-lay B out as NR-wide column strips stored K-major:
//!
//! ```text
//! data[strip * kc * nr + kk * nr + lane]  ==  B[kk, strip * nr + lane]
//! ```
//!
//! so the microkernel's per-k step reads one contiguous `nr`-wide run and
//! an entire strip streams sequentially through the hardware prefetcher.
//! The last strip is zero-padded to `nr` lanes: kernels may compute the
//! full strip width into a staging tile, and the padding contributes
//! exact zeros.
//!
//! Only the dense and TW operands need this treatment.  The TVW / 2:4
//! plan arrays (`b_vals` / `b_sel`) are already laid out contiguously in
//! the output-column direction — the condensed plan is its own panel
//! layout — so those kernels stream the plan directly.

/// One B operand repacked into K-major, NR-wide column strips.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedPanel {
    /// Strip width (the microkernel NR).
    pub nr: usize,
    /// Reduction extent (B rows).
    pub kc: usize,
    /// Valid output columns (B cols; the last strip pads up to `nr`).
    pub n: usize,
    /// `strips() * kc * nr` values.
    pub data: Vec<f32>,
}

impl PackedPanel {
    /// Repack a row-major `kc x n` block (row stride `ldb >= n`) into
    /// K-major NR-wide strips.  Rows beyond the source block are the
    /// caller's concern; lanes past `n` in the last strip are zero.
    pub fn pack(b: &[f32], kc: usize, n: usize, ldb: usize, nr: usize) -> PackedPanel {
        assert!(nr > 0, "panel strip width must be nonzero");
        assert!(n <= ldb, "panel: n={n} exceeds row stride ldb={ldb}");
        assert!(kc == 0 || n == 0 || (kc - 1) * ldb + n <= b.len(), "panel source out of bounds");
        let strips = n.div_ceil(nr);
        let mut data = vec![0.0f32; strips * kc * nr];
        for s in 0..strips {
            let j0 = s * nr;
            let w = (n - j0).min(nr);
            for kk in 0..kc {
                let src = &b[kk * ldb + j0..kk * ldb + j0 + w];
                let base = s * kc * nr + kk * nr;
                data[base..base + w].copy_from_slice(src);
            }
        }
        PackedPanel { nr, kc, n, data }
    }

    /// Number of NR-wide strips (the last one may be partial).
    pub fn strips(&self) -> usize {
        self.n.div_ceil(self.nr)
    }

    /// Bytes held by the packed copy (memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// The i8 analogue of [`PackedPanel`] for the Int8 kernels, with one
/// extra twist: K is grouped into **quads** (4 reduction rows), because
/// the int8 dot-product instructions (`vpmaddubsw`+`vpmaddwd`,
/// `vpdpbusd`, `sdot`) all consume 4 bytes per lane per step.
///
/// ```text
/// data[((strip * kq + q) * nr + lane) * 4 + p]  ==  B[q * 4 + p, strip * nr + lane]
/// ```
///
/// so one quad step reads a contiguous `nr * 4`-byte run whose byte
/// groups line up with the i32 accumulator lanes.  Both the last quad
/// (K not a multiple of 4) and the last strip (N not a multiple of NR)
/// are zero-padded: padding contributes exact zero products.
#[derive(Clone, Debug, PartialEq)]
pub struct Int8Panel {
    /// Strip width in output columns (the microkernel's i32-lane NR).
    pub nr: usize,
    /// Reduction extent before quad padding (B rows).
    pub kc: usize,
    /// Quad count: `kc.div_ceil(4)`.
    pub kq: usize,
    /// Valid output columns (the last strip pads up to `nr`).
    pub n: usize,
    /// `strips() * kq * nr * 4` bytes.
    pub data: Vec<i8>,
}

impl Int8Panel {
    /// Repack a row-major `kc x n` i8 block (row stride `ldb >= n`) into
    /// quad-grouped K-major NR-wide strips.
    pub fn pack(b: &[i8], kc: usize, n: usize, ldb: usize, nr: usize) -> Int8Panel {
        assert!(nr > 0, "panel strip width must be nonzero");
        assert!(n <= ldb, "panel: n={n} exceeds row stride ldb={ldb}");
        assert!(kc == 0 || n == 0 || (kc - 1) * ldb + n <= b.len(), "panel source out of bounds");
        let strips = n.div_ceil(nr);
        let kq = kc.div_ceil(4);
        let mut data = vec![0i8; strips * kq * nr * 4];
        for s in 0..strips {
            let j0 = s * nr;
            let w = (n - j0).min(nr);
            for kk in 0..kc {
                let (q, p) = (kk / 4, kk % 4);
                for lane in 0..w {
                    data[((s * kq + q) * nr + lane) * 4 + p] = b[kk * ldb + j0 + lane];
                }
            }
        }
        Int8Panel { nr, kc, kq, n, data }
    }

    /// Number of NR-wide strips (the last one may be partial).
    pub fn strips(&self) -> usize {
        self.n.div_ceil(self.nr)
    }

    /// Bytes held by the packed copy (memory accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_reorders_into_k_major_strips() {
        // 3 x 5 block inside a row stride of 6, nr = 2 -> 3 strips
        let ldb = 6;
        let b: Vec<f32> = (0..3 * ldb).map(|x| x as f32).collect();
        let p = PackedPanel::pack(&b, 3, 5, ldb, 2);
        assert_eq!(p.strips(), 3);
        assert_eq!(p.data.len(), 3 * 3 * 2);
        for kk in 0..3 {
            for j in 0..5 {
                let (s, lane) = (j / 2, j % 2);
                let got = p.data[s * 3 * 2 + kk * 2 + lane];
                assert_eq!(got, b[kk * ldb + j], "k={kk} j={j}");
            }
            // padded lane of the last strip stays zero
            assert_eq!(p.data[2 * 3 * 2 + kk * 2 + 1], 0.0);
        }
        assert_eq!(p.bytes(), p.data.len() * 4);
    }

    #[test]
    fn degenerate_shapes_pack_cleanly() {
        let p = PackedPanel::pack(&[], 0, 0, 0, 8);
        assert_eq!(p.strips(), 0);
        assert!(p.data.is_empty());
        let b = vec![1.0f32; 4];
        let p = PackedPanel::pack(&b, 4, 1, 1, 8);
        assert_eq!(p.strips(), 1);
        assert_eq!(p.data.len(), 4 * 8);
        assert_eq!(p.data[0], 1.0);
        assert_eq!(p.data[1], 0.0);
    }

    #[test]
    fn int8_pack_groups_k_into_quads() {
        // 6 x 5 block inside a row stride of 6, nr = 2 -> 3 strips, 2 quads
        let ldb = 6;
        let b: Vec<i8> = (0..6 * ldb).map(|x| (x % 100) as i8).collect();
        let p = Int8Panel::pack(&b, 6, 5, ldb, 2);
        assert_eq!((p.strips(), p.kq), (3, 2));
        assert_eq!(p.data.len(), 3 * 2 * 2 * 4);
        for kk in 0..6 {
            let (q, pos) = (kk / 4, kk % 4);
            for j in 0..5 {
                let (s, lane) = (j / 2, j % 2);
                let got = p.data[((s * 2 + q) * 2 + lane) * 4 + pos];
                assert_eq!(got, b[kk * ldb + j], "k={kk} j={j}");
            }
        }
        // quad padding (k = 6, 7 within strip 0's quad 1) and lane padding
        // stay zero
        let (s, q) = (0, 1);
        for lane in 0..2 {
            for pos in 2..4 {
                assert_eq!(p.data[((s * 2 + q) * 2 + lane) * 4 + pos], 0, "quad pad");
            }
        }
        for q in 0..2 {
            for pos in 0..4 {
                assert_eq!(p.data[((2 * 2 + q) * 2 + 1) * 4 + pos], 0, "lane pad");
            }
        }
        assert_eq!(p.bytes(), p.data.len());
    }

    #[test]
    fn int8_degenerate_shapes_pack_cleanly() {
        let p = Int8Panel::pack(&[], 0, 0, 0, 8);
        assert_eq!(p.strips(), 0);
        assert!(p.data.is_empty());
        let b = vec![1i8; 4];
        let p = Int8Panel::pack(&b, 4, 1, 1, 8);
        assert_eq!((p.strips(), p.kq), (1, 1));
        assert_eq!(p.data.len(), 8 * 4);
        assert_eq!(&p.data[0..4], &[1, 1, 1, 1]);
        assert_eq!(&p.data[4..8], &[0, 0, 0, 0]);
    }
}
