//! NEON f32 microkernels (4-lane FMA) for aarch64.
//!
//! Mirrors the AVX2 module at half the lane width: register-blocked
//! MR x NR dense/strided kernels and the packed-panel driver.  The 2:4
//! selection kernel stays scalar on this architecture (NEON `tbl` works
//! on bytes, not f32 lanes; the scalar selection loop is already cheap
//! relative to the 4-lane FMA win), so [`super::sel24_row`] reports
//! "unsupported" here and the caller keeps its scalar loop.

use core::arch::aarch64::*;

use super::panel::PackedPanel;

/// Snap onto a compiled instantiation: NRV in {1, 2}, MR in {1, 2, 4, 8}
/// (capped at 4 when NRV = 2 — same tile shapes as the AVX2 set, so one
/// autotune axis serves both ISAs).
pub(super) fn clamp_block(mr: usize, nrv: usize) -> (usize, usize) {
    let nrv = if nrv >= 2 { 2 } else { 1 };
    let cap = if nrv == 2 { 4 } else { 8 };
    let want = mr.clamp(1, cap);
    let mr = [8usize, 4, 2, 1].into_iter().find(|&c| c <= want).unwrap_or(1);
    (mr, nrv)
}

macro_rules! def_kernel {
    ($name:ident, $mr:expr, $nrv:expr) => {
        /// One register tile: C[MR x 4*NRV] += A[MR x kt] * B[kt x 4*NRV].
        #[target_feature(enable = "neon")]
        unsafe fn $name(
            a: *const f32,
            lda: usize,
            b: *const f32,
            ldb: usize,
            c: *mut f32,
            ldc: usize,
            kt: usize,
        ) {
            const MR: usize = $mr;
            const NRV: usize = $nrv;
            let mut acc = [[vdupq_n_f32(0.0); NRV]; MR];
            let mut ap = a;
            let mut bp = b;
            for _ in 0..kt {
                let mut bv = [vdupq_n_f32(0.0); NRV];
                for (v, slot) in bv.iter_mut().enumerate() {
                    *slot = vld1q_f32(bp.add(4 * v));
                }
                for (i, row) in acc.iter_mut().enumerate() {
                    let av = vdupq_n_f32(*ap.add(i * lda));
                    for (cell, bvec) in row.iter_mut().zip(bv.iter()) {
                        *cell = vfmaq_f32(*cell, av, *bvec);
                    }
                }
                ap = ap.add(1);
                bp = bp.add(ldb);
            }
            for (i, row) in acc.iter().enumerate() {
                for (v, cell) in row.iter().enumerate() {
                    let cp = c.add(i * ldc + 4 * v);
                    vst1q_f32(cp, vaddq_f32(vld1q_f32(cp), *cell));
                }
            }
        }
    };
}

def_kernel!(k1x1, 1, 1);
def_kernel!(k2x1, 2, 1);
def_kernel!(k4x1, 4, 1);
def_kernel!(k8x1, 8, 1);
def_kernel!(k1x2, 1, 2);
def_kernel!(k2x2, 2, 2);
def_kernel!(k4x2, 4, 2);

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
unsafe fn kernel(
    mr: usize,
    nrv: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
    kt: usize,
) {
    match (mr, nrv) {
        (8, 1) => k8x1(a, lda, b, ldb, c, ldc, kt),
        (4, 1) => k4x1(a, lda, b, ldb, c, ldc, kt),
        (2, 1) => k2x1(a, lda, b, ldb, c, ldc, kt),
        (1, 1) => k1x1(a, lda, b, ldb, c, ldc, kt),
        (4, 2) => k4x2(a, lda, b, ldb, c, ldc, kt),
        (2, 2) => k2x2(a, lda, b, ldb, c, ldc, kt),
        _ => k1x2(a, lda, b, ldb, c, ldc, kt),
    }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
unsafe fn strip(
    m: usize,
    kt: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nrv: usize,
) {
    let mut i = 0;
    while i + mr <= m {
        kernel(mr, nrv, a.add(i * lda), lda, b, ldb, c.add(i * ldc), ldc, kt);
        i += mr;
    }
    while i < m {
        kernel(1, nrv, a.add(i * lda), lda, b, ldb, c.add(i * ldc), ldc, kt);
        i += 1;
    }
}

#[allow(clippy::too_many_arguments)]
unsafe fn scalar_cols(
    m: usize,
    kt: usize,
    w: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
) {
    for i in 0..m {
        for j in 0..w {
            let mut acc = 0.0f32;
            for kk in 0..kt {
                acc += *a.add(i * lda + kk) * *b.add(kk * ldb + j);
            }
            *c.add(i * ldc + j) += acc;
        }
    }
}

/// C (m x n) += A (m x kt) * B (kt x n), strided row-major operands.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub(super) unsafe fn gemm_strided(
    m: usize,
    kt: usize,
    n: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nrv: usize,
) {
    let (mr, nrv) = clamp_block(mr, nrv);
    let mut j = 0;
    while j + 4 * nrv <= n {
        strip(m, kt, a, lda, b.add(j), ldb, c.add(j), ldc, mr, nrv);
        j += 4 * nrv;
    }
    if nrv == 2 && j + 4 <= n {
        strip(m, kt, a, lda, b.add(j), ldb, c.add(j), ldc, mr, 1);
        j += 4;
    }
    if j < n {
        scalar_cols(m, kt, n - j, a, lda, b.add(j), ldb, c.add(j), ldc);
    }
}

/// Panel driver: full strips stream contiguously, the zero-padded tail
/// strip goes through a stack tile (see the AVX2 twin for the layout).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub(super) unsafe fn gemm_panel(
    m: usize,
    k0: usize,
    kt: usize,
    a: *const f32,
    lda: usize,
    panel: &PackedPanel,
    c: *mut f32,
    ldc: usize,
    mr: usize,
) {
    let nr = panel.nr;
    let (mr, nrv) = clamp_block(mr, nr / 4);
    let data = panel.data.as_ptr();
    for p in 0..panel.strips() {
        let j0 = p * nr;
        let bp = data.add(p * panel.kc * nr + k0 * nr);
        if j0 + nr <= panel.n {
            strip(m, kt, a, lda, bp, nr, c.add(j0), ldc, mr, nrv);
        } else {
            let w = panel.n - j0;
            for i in 0..m {
                let mut tile = [0.0f32; 8];
                kernel(1, nrv, a.add(i * lda), lda, bp, nr, tile.as_mut_ptr(), 8, kt);
                let crow = c.add(i * ldc + j0);
                for (jj, v) in tile.iter().take(w).enumerate() {
                    *crow.add(jj) += *v;
                }
            }
        }
    }
}
