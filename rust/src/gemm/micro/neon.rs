//! NEON f32 microkernels (4-lane FMA) for aarch64.
//!
//! Mirrors the AVX2 module at half the lane width: register-blocked
//! MR x NR dense/strided kernels and the packed-panel driver.  The 2:4
//! selection kernel stays scalar on this architecture (NEON `tbl` works
//! on bytes, not f32 lanes; the scalar selection loop is already cheap
//! relative to the 4-lane FMA win), so [`super::sel24_row`] reports
//! "unsupported" here and the caller keeps its scalar loop.

use core::arch::aarch64::*;

use super::panel::{Int8Panel, PackedPanel};

/// Snap onto a compiled instantiation: NRV in {1, 2}, MR in {1, 2, 4, 8}
/// (capped at 4 when NRV = 2 — same tile shapes as the AVX2 set, so one
/// autotune axis serves both ISAs).
pub(super) fn clamp_block(mr: usize, nrv: usize) -> (usize, usize) {
    let nrv = if nrv >= 2 { 2 } else { 1 };
    let cap = if nrv == 2 { 4 } else { 8 };
    let want = mr.clamp(1, cap);
    let mr = [8usize, 4, 2, 1].into_iter().find(|&c| c <= want).unwrap_or(1);
    (mr, nrv)
}

macro_rules! def_kernel {
    ($name:ident, $mr:expr, $nrv:expr) => {
        /// One register tile: C[MR x 4*NRV] += A[MR x kt] * B[kt x 4*NRV].
        #[target_feature(enable = "neon")]
        unsafe fn $name(
            a: *const f32,
            lda: usize,
            b: *const f32,
            ldb: usize,
            c: *mut f32,
            ldc: usize,
            kt: usize,
        ) {
            const MR: usize = $mr;
            const NRV: usize = $nrv;
            let mut acc = [[vdupq_n_f32(0.0); NRV]; MR];
            let mut ap = a;
            let mut bp = b;
            for _ in 0..kt {
                let mut bv = [vdupq_n_f32(0.0); NRV];
                for (v, slot) in bv.iter_mut().enumerate() {
                    *slot = vld1q_f32(bp.add(4 * v));
                }
                for (i, row) in acc.iter_mut().enumerate() {
                    let av = vdupq_n_f32(*ap.add(i * lda));
                    for (cell, bvec) in row.iter_mut().zip(bv.iter()) {
                        *cell = vfmaq_f32(*cell, av, *bvec);
                    }
                }
                ap = ap.add(1);
                bp = bp.add(ldb);
            }
            for (i, row) in acc.iter().enumerate() {
                for (v, cell) in row.iter().enumerate() {
                    let cp = c.add(i * ldc + 4 * v);
                    vst1q_f32(cp, vaddq_f32(vld1q_f32(cp), *cell));
                }
            }
        }
    };
}

def_kernel!(k1x1, 1, 1);
def_kernel!(k2x1, 2, 1);
def_kernel!(k4x1, 4, 1);
def_kernel!(k8x1, 8, 1);
def_kernel!(k1x2, 1, 2);
def_kernel!(k2x2, 2, 2);
def_kernel!(k4x2, 4, 2);

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
unsafe fn kernel(
    mr: usize,
    nrv: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
    kt: usize,
) {
    match (mr, nrv) {
        (8, 1) => k8x1(a, lda, b, ldb, c, ldc, kt),
        (4, 1) => k4x1(a, lda, b, ldb, c, ldc, kt),
        (2, 1) => k2x1(a, lda, b, ldb, c, ldc, kt),
        (1, 1) => k1x1(a, lda, b, ldb, c, ldc, kt),
        (4, 2) => k4x2(a, lda, b, ldb, c, ldc, kt),
        (2, 2) => k2x2(a, lda, b, ldb, c, ldc, kt),
        _ => k1x2(a, lda, b, ldb, c, ldc, kt),
    }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
unsafe fn strip(
    m: usize,
    kt: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nrv: usize,
) {
    let mut i = 0;
    while i + mr <= m {
        kernel(mr, nrv, a.add(i * lda), lda, b, ldb, c.add(i * ldc), ldc, kt);
        i += mr;
    }
    while i < m {
        kernel(1, nrv, a.add(i * lda), lda, b, ldb, c.add(i * ldc), ldc, kt);
        i += 1;
    }
}

#[allow(clippy::too_many_arguments)]
unsafe fn scalar_cols(
    m: usize,
    kt: usize,
    w: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
) {
    for i in 0..m {
        for j in 0..w {
            let mut acc = 0.0f32;
            for kk in 0..kt {
                acc += *a.add(i * lda + kk) * *b.add(kk * ldb + j);
            }
            *c.add(i * ldc + j) += acc;
        }
    }
}

/// C (m x n) += A (m x kt) * B (kt x n), strided row-major operands.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub(super) unsafe fn gemm_strided(
    m: usize,
    kt: usize,
    n: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nrv: usize,
) {
    let (mr, nrv) = clamp_block(mr, nrv);
    let mut j = 0;
    while j + 4 * nrv <= n {
        strip(m, kt, a, lda, b.add(j), ldb, c.add(j), ldc, mr, nrv);
        j += 4 * nrv;
    }
    if nrv == 2 && j + 4 <= n {
        strip(m, kt, a, lda, b.add(j), ldb, c.add(j), ldc, mr, 1);
        j += 4;
    }
    if j < n {
        scalar_cols(m, kt, n - j, a, lda, b.add(j), ldb, c.add(j), ldc);
    }
}

/// Panel driver: full strips stream contiguously, the zero-padded tail
/// strip goes through a stack tile (see the AVX2 twin for the layout).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub(super) unsafe fn gemm_panel(
    m: usize,
    k0: usize,
    kt: usize,
    a: *const f32,
    lda: usize,
    panel: &PackedPanel,
    c: *mut f32,
    ldc: usize,
    mr: usize,
) {
    let nr = panel.nr;
    let (mr, nrv) = clamp_block(mr, nr / 4);
    let data = panel.data.as_ptr();
    for p in 0..panel.strips() {
        let j0 = p * nr;
        let bp = data.add(p * panel.kc * nr + k0 * nr);
        if j0 + nr <= panel.n {
            strip(m, kt, a, lda, bp, nr, c.add(j0), ldc, mr, nrv);
        } else {
            let w = panel.n - j0;
            for i in 0..m {
                let mut tile = [0.0f32; 8];
                kernel(1, nrv, a.add(i * lda), lda, bp, nr, tile.as_mut_ptr(), 8, kt);
                let crow = c.add(i * ldc + j0);
                for (jj, v) in tile.iter().take(w).enumerate() {
                    *crow.add(jj) += *v;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Int8 path.
//
// Each 128-bit B vector holds 4 output columns x one 4-byte K-quad (the
// Int8Panel byte order), and the A quad is splatted as 4 x i32 then
// reinterpreted to bytes, so corresponding byte positions multiply.
// When the build enables `dotprod`, a single `sdot` reduces each column
// group straight into the i32 accumulator; the baseline NEON fallback
// widens through `smull` / `smull2` and pairwise-adds twice
// (`saddlp` + `addp`), which costs 4 ops per vector instead of 1 but
// needs nothing past the aarch64 baseline.  Signed x signed multiply is
// native here — no AVX2-style sign trick.
// ---------------------------------------------------------------------------

macro_rules! def_int8_kernel {
    ($name:ident, $mr:expr, $nrv:expr) => {
        /// One register tile: C[MR x 4*NRV] (i32) += A[MR x kq quads] * strip.
        #[target_feature(enable = "neon")]
        unsafe fn $name(
            a: *const i8,
            lda: usize,
            b: *const i8,
            c: *mut i32,
            ldc: usize,
            kq: usize,
            nr: usize,
        ) {
            const MR: usize = $mr;
            const NRV: usize = $nrv;
            let mut acc = [[vdupq_n_s32(0); NRV]; MR];
            let mut bp = b;
            for q in 0..kq {
                let mut bv = [vdupq_n_s8(0); NRV];
                for (v, slot) in bv.iter_mut().enumerate() {
                    *slot = vld1q_s8(bp.add(16 * v));
                }
                for (i, row) in acc.iter_mut().enumerate() {
                    let quad = (a.add(i * lda + q * 4) as *const i32).read_unaligned();
                    let ab = vreinterpretq_s8_s32(vdupq_n_s32(quad));
                    for (cell, bvec) in row.iter_mut().zip(bv.iter()) {
                        #[cfg(target_feature = "dotprod")]
                        {
                            *cell = vdotq_s32(*cell, *bvec, ab);
                        }
                        #[cfg(not(target_feature = "dotprod"))]
                        {
                            let lo = vpaddlq_s16(vmull_s8(vget_low_s8(*bvec), vget_low_s8(ab)));
                            let hi = vpaddlq_s16(vmull_s8(vget_high_s8(*bvec), vget_high_s8(ab)));
                            *cell = vaddq_s32(*cell, vpaddq_s32(lo, hi));
                        }
                    }
                }
                bp = bp.add(nr * 4);
            }
            for (i, row) in acc.iter().enumerate() {
                for (v, cell) in row.iter().enumerate() {
                    let cp = c.add(i * ldc + 4 * v);
                    vst1q_s32(cp, vaddq_s32(vld1q_s32(cp), *cell));
                }
            }
        }
    };
}

def_int8_kernel!(q1x1, 1, 1);
def_int8_kernel!(q2x1, 2, 1);
def_int8_kernel!(q4x1, 4, 1);
def_int8_kernel!(q8x1, 8, 1);
def_int8_kernel!(q1x2, 1, 2);
def_int8_kernel!(q2x2, 2, 2);
def_int8_kernel!(q4x2, 4, 2);

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
unsafe fn int8_kernel(
    mr: usize,
    nrv: usize,
    a: *const i8,
    lda: usize,
    b: *const i8,
    c: *mut i32,
    ldc: usize,
    kq: usize,
    nr: usize,
) {
    match (mr, nrv) {
        (8, 1) => q8x1(a, lda, b, c, ldc, kq, nr),
        (4, 1) => q4x1(a, lda, b, c, ldc, kq, nr),
        (2, 1) => q2x1(a, lda, b, c, ldc, kq, nr),
        (1, 1) => q1x1(a, lda, b, c, ldc, kq, nr),
        (4, 2) => q4x2(a, lda, b, c, ldc, kq, nr),
        (2, 2) => q2x2(a, lda, b, c, ldc, kq, nr),
        _ => q1x2(a, lda, b, c, ldc, kq, nr),
    }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
unsafe fn int8_strip(
    m: usize,
    a: *const i8,
    lda: usize,
    b: *const i8,
    c: *mut i32,
    ldc: usize,
    kq: usize,
    nr: usize,
    mr: usize,
    nrv: usize,
) {
    let mut i = 0;
    while i + mr <= m {
        int8_kernel(mr, nrv, a.add(i * lda), lda, b, c.add(i * ldc), ldc, kq, nr);
        i += mr;
    }
    while i < m {
        int8_kernel(1, nrv, a.add(i * lda), lda, b, c.add(i * ldc), ldc, kq, nr);
        i += 1;
    }
}

/// C (m x panel.n, i32) += A (m x kq quads) * panel; dequant elsewhere.
/// A rows must be zero-padded to `panel.kq * 4` bytes (whole-quad reads).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub(super) unsafe fn int8_gemm_panel(
    m: usize,
    a: *const i8,
    lda: usize,
    panel: &Int8Panel,
    c: *mut i32,
    ldc: usize,
    mr: usize,
) {
    let nr = panel.nr;
    let (mr, nrv) = clamp_block(mr, nr / 4);
    let data = panel.data.as_ptr();
    for p in 0..panel.strips() {
        let j0 = p * nr;
        let bp = data.add(p * panel.kq * nr * 4);
        if j0 + nr <= panel.n {
            int8_strip(m, a, lda, bp, c.add(j0), ldc, panel.kq, nr, mr, nrv);
        } else {
            let w = panel.n - j0;
            for i in 0..m {
                let mut tile = [0i32; 8];
                int8_kernel(1, nrv, a.add(i * lda), lda, bp, tile.as_mut_ptr(), 8, panel.kq, nr);
                let crow = c.add(i * ldc + j0);
                for (jj, v) in tile.iter().take(w).enumerate() {
                    *crow.add(jj) += *v;
                }
            }
        }
    }
}
