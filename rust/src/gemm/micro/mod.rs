//! Register-level SIMD microkernels and their runtime dispatch.
//!
//! The paper's speedups live at two levels: tile-wise sparsity keeps the
//! *memory*-level access pattern dense and regular, and the 2:4 pattern
//! executes its selection at the *register* level.  This module supplies
//! the register level for the CPU backend: explicit `std::arch`
//! microkernels with register-blocked MR x NR accumulator tiles, a
//! packed-B panel layout ([`PackedPanel`]) built once at weight-pack
//! time, and the metadata-shuffle kernel for the compressed 2:4 format.
//!
//! Dispatch contract (see `docs/DESIGN.md` §9):
//!
//! 1. [`MicroCfg`] on a `TileConfig` *requests* a kernel (the autotuner's
//!    microkernel axis; `Auto` everywhere else).
//! 2. [`resolve`] turns the request into a concrete [`Resolved`] against
//!    the runtime-detected ISA (`is_x86_feature_detected!`, cached) —
//!    honouring `PALLAS_FORCE_SCALAR=1` and snapping MR/NR onto a
//!    compiled instantiation.
//! 3. Every kernel keeps its scalar loops as the always-available
//!    fallback: a SIMD request on hardware without that ISA degrades to
//!    scalar, it never panics.  All wrappers here return `bool` — `false`
//!    means "not handled, run your scalar loop".

pub mod panel;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
mod avx512;
#[cfg(target_arch = "aarch64")]
mod neon;

pub use panel::{Int8Panel, PackedPanel};

use std::sync::OnceLock;

use super::{Epilogue, TileConfig};
use crate::tensor::Matrix;

/// Per-config microkernel request, carried on `TileConfig` and searched
/// by the autotuner alongside the cache-blocking axes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MicroCfg {
    /// Dispatcher's choice: SIMD at the detected ISA's default register
    /// block when available, scalar otherwise.
    Auto,
    /// Pin to the scalar reference loops.
    Scalar,
    /// Pin to SIMD with an explicit MR x NR register block.  Snapped to
    /// the nearest compiled instantiation; degrades to scalar when no
    /// SIMD ISA is available at runtime.
    Simd {
        /// Accumulator rows per register tile.
        mr: u8,
        /// Output columns per register tile (a multiple of the lane width).
        nr: u8,
    },
}

impl MicroCfg {
    /// Stable text form, used by the plan cache and candidate labels.
    pub fn label(&self) -> String {
        match self {
            MicroCfg::Auto => "auto".to_string(),
            MicroCfg::Scalar => "scalar".to_string(),
            MicroCfg::Simd { mr, nr } => format!("simd{mr}x{nr}"),
        }
    }

    /// Inverse of [`MicroCfg::label`].
    pub fn from_label(s: &str) -> Option<MicroCfg> {
        match s {
            "auto" => Some(MicroCfg::Auto),
            "scalar" => Some(MicroCfg::Scalar),
            _ => {
                let (mr, nr) = s.strip_prefix("simd")?.split_once('x')?;
                Some(MicroCfg::Simd { mr: mr.parse().ok()?, nr: nr.parse().ok()? })
            }
        }
    }
}

/// The SIMD instruction sets the dispatcher knows about.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Isa {
    Scalar,
    Avx2,
    Avx512,
    Neon,
}

impl Isa {
    pub fn label(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    /// f32 lanes per SIMD register.
    pub fn lanes(&self) -> usize {
        match self {
            Isa::Scalar => 1,
            Isa::Avx2 => 8,
            Isa::Avx512 => 16,
            Isa::Neon => 4,
        }
    }

    fn index(self) -> usize {
        match self {
            Isa::Scalar => 0,
            Isa::Avx2 => 1,
            Isa::Avx512 => 2,
            Isa::Neon => 3,
        }
    }

    fn from_index(i: usize) -> Isa {
        match i {
            1 => Isa::Avx2,
            2 => Isa::Avx512,
            3 => Isa::Neon,
            _ => Isa::Scalar,
        }
    }
}

/// `PALLAS_FORCE_SCALAR=1` pins every dispatch to the scalar loops — the
/// CI lane that keeps the fallback path exercised on any hardware.
pub fn force_scalar() -> bool {
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| {
        std::env::var("PALLAS_FORCE_SCALAR").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
    })
}

#[cfg(target_arch = "x86_64")]
fn detect() -> Isa {
    #[cfg(target_feature = "avx512f")]
    {
        if std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return Isa::Avx512;
        }
    }
    if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma") {
        Isa::Avx2
    } else {
        Isa::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect() -> Isa {
    // NEON is part of the aarch64 baseline.
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> Isa {
    Isa::Scalar
}

/// The runtime-detected SIMD ISA, resolved once per process and
/// overridden to `Scalar` by `PALLAS_FORCE_SCALAR`.
pub fn active_isa() -> Isa {
    static ISA: OnceLock<Isa> = OnceLock::new();
    if force_scalar() {
        return Isa::Scalar;
    }
    *ISA.get_or_init(detect)
}

/// Whether any SIMD path can dispatch in this process.
pub fn simd_available() -> bool {
    active_isa() != Isa::Scalar
}

/// Banner label for `serve` startup: which kernel family this process
/// dispatches to by default.
pub fn active_label() -> String {
    if force_scalar() {
        "scalar(forced)".to_string()
    } else {
        active_isa().label().to_string()
    }
}

/// Default register block (MR x NR) per ISA.
pub fn default_block(isa: Isa) -> (usize, usize) {
    match isa {
        Isa::Scalar => (0, 0),
        // 4x2 ymm accumulators + 2 B vectors + 1 A broadcast = 11/16 regs
        Isa::Avx2 => (4, 16),
        // one zmm per row out of the 32-register file
        Isa::Avx512 => (8, 16),
        Isa::Neon => (4, 8),
    }
}

/// Snap a requested (MR, NR) onto the instantiations the ISA compiles.
fn snap(isa: Isa, mr: usize, nr: usize) -> (usize, usize) {
    let lanes = isa.lanes();
    let wide = isa != Isa::Avx512 && nr >= 2 * lanes;
    let nr = if wide { 2 * lanes } else { lanes };
    let cap = if wide { 4 } else { 8 };
    let want = mr.clamp(1, cap);
    let mr = [8usize, 4, 2, 1].into_iter().find(|&c| c <= want).unwrap_or(1);
    (mr, nr)
}

/// A concrete microkernel choice: what [`resolve`] turned a [`MicroCfg`]
/// into for this process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Resolved {
    pub isa: Isa,
    pub mr: usize,
    pub nr: usize,
}

impl Resolved {
    pub const SCALAR: Resolved = Resolved { isa: Isa::Scalar, mr: 0, nr: 0 };

    pub fn is_simd(&self) -> bool {
        self.isa != Isa::Scalar
    }

    /// Telemetry label, e.g. `"avx2 4x16"` or `"scalar"`.
    pub fn label(&self) -> String {
        if self.is_simd() {
            format!("{} {}x{}", self.isa.label(), self.mr, self.nr)
        } else {
            "scalar".to_string()
        }
    }

    /// Pack into a usize for lock-free telemetry
    /// (`NodeProfile::last_micro` stores this in an atomic).
    pub fn code(&self) -> usize {
        (self.isa.index() << 16) | ((self.mr & 0xff) << 8) | (self.nr & 0xff)
    }

    pub fn from_code(code: usize) -> Resolved {
        let isa = Isa::from_index((code >> 16) & 0xf);
        Resolved { isa, mr: (code >> 8) & 0xff, nr: code & 0xff }
    }
}

/// Telemetry label for a packed [`Resolved::code`] value.
pub fn describe(code: usize) -> String {
    Resolved::from_code(code).label()
}

/// Resolve a config's microkernel request against the detected ISA.
pub fn resolve(cfg: &TileConfig) -> Resolved {
    resolve_with(cfg.micro, active_isa())
}

/// Pure form of [`resolve`] (unit-testable on any hardware).
pub fn resolve_with(micro: MicroCfg, isa: Isa) -> Resolved {
    if isa == Isa::Scalar {
        return Resolved::SCALAR;
    }
    match micro {
        MicroCfg::Scalar => Resolved::SCALAR,
        MicroCfg::Auto => {
            let (mr, nr) = default_block(isa);
            Resolved { isa, mr, nr }
        }
        MicroCfg::Simd { mr, nr } => {
            let (mr, nr) = snap(isa, mr as usize, nr as usize);
            Resolved { isa, mr, nr }
        }
    }
}

/// The autotuner's microkernel axis: always the scalar loops, plus the
/// register blocks worth trying on the detected ISA.
pub fn search_axis() -> Vec<MicroCfg> {
    let mut axis = vec![MicroCfg::Scalar];
    let isa = active_isa();
    if isa != Isa::Scalar {
        let (mr, nr) = default_block(isa);
        axis.push(MicroCfg::Simd { mr: mr as u8, nr: nr as u8 });
        // a narrow-NR alternative: deeper MR, one B vector per step
        let alt = MicroCfg::Simd { mr: 8, nr: isa.lanes() as u8 };
        if !axis.contains(&alt) {
            axis.push(alt);
        }
    }
    axis
}

/// Whether this binary actually compiled kernels for `r`'s ISA.
pub fn supported(r: &Resolved) -> bool {
    match r.isa {
        Isa::Scalar => false,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => true,
        #[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
        Isa::Avx512 => true,
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => true,
        #[allow(unreachable_patterns)]
        _ => false,
    }
}

/// C (m x n, row stride `ldc`) += A (m x kt, row stride `lda`) *
/// B (kt x n, row stride `ldb`).  Returns `false` when `r` resolves to
/// scalar (or its ISA is compiled out) — the caller then runs its
/// scalar loop; `c` is untouched in that case.
#[allow(clippy::too_many_arguments)]
pub fn gemm_strided(
    r: &Resolved,
    m: usize,
    kt: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
) -> bool {
    if !supported(r) {
        return false;
    }
    if m == 0 || n == 0 || kt == 0 {
        return true; // nothing to accumulate; counts as handled
    }
    debug_assert!((m - 1) * lda + kt <= a.len());
    debug_assert!((kt - 1) * ldb + n <= b.len());
    debug_assert!((m - 1) * ldc + n <= c.len());
    match r.isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            avx2::gemm_strided(
                m,
                kt,
                n,
                a.as_ptr(),
                lda,
                b.as_ptr(),
                ldb,
                c.as_mut_ptr(),
                ldc,
                r.mr,
                r.nr / 8,
            );
            true
        },
        #[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
        Isa::Avx512 => unsafe {
            let (bp, cp) = (b.as_ptr(), c.as_mut_ptr());
            avx512::gemm_strided(m, kt, n, a.as_ptr(), lda, bp, ldb, cp, ldc, r.mr);
            true
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            neon::gemm_strided(
                m,
                kt,
                n,
                a.as_ptr(),
                lda,
                b.as_ptr(),
                ldb,
                c.as_mut_ptr(),
                ldc,
                r.mr,
                r.nr / 4,
            );
            true
        },
        _ => false,
    }
}

/// C (m x panel.n, row stride `ldc`) += A (m x kt, row stride `lda`,
/// reduction offset `k0` into the panel's K extent) * the packed strips
/// of `panel`.  Returns `false` (and leaves `c` untouched) when `r` is
/// scalar, compiled out, or the panel's strip width does not match the
/// resolved NR — callers fall back to [`gemm_strided`] or scalar.
#[allow(clippy::too_many_arguments)]
pub fn gemm_panel(
    r: &Resolved,
    m: usize,
    k0: usize,
    kt: usize,
    a: &[f32],
    lda: usize,
    panel: &PackedPanel,
    c: &mut [f32],
    ldc: usize,
) -> bool {
    if !supported(r) || panel.nr != r.nr {
        return false;
    }
    if m == 0 || kt == 0 || panel.n == 0 {
        return true;
    }
    debug_assert!(k0 + kt <= panel.kc);
    debug_assert!((m - 1) * lda + kt <= a.len());
    debug_assert!((m - 1) * ldc + panel.n <= c.len());
    match r.isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            avx2::gemm_panel(m, k0, kt, a.as_ptr(), lda, panel, c.as_mut_ptr(), ldc, r.mr);
            true
        },
        #[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
        Isa::Avx512 => unsafe {
            avx512::gemm_panel(m, k0, kt, a.as_ptr(), lda, panel, c.as_mut_ptr(), ldc, r.mr);
            true
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            neon::gemm_panel(m, k0, kt, a.as_ptr(), lda, panel, c.as_mut_ptr(), ldc, r.mr);
            true
        },
        _ => false,
    }
}

/// One activation-row step of the 2:4 selection: for each output column
/// `j`, `c[j] += a4[s0[j]] * v0[j] + a4[s1[j]] * v1[j]`, with the 2-bit
/// metadata expanded via in-register shuffles.  Returns `false` when the
/// resolved kernel is scalar or the ISA has no shuffle path (NEON) —
/// the caller then runs the scalar selection loop.
pub fn sel24_row(
    r: &Resolved,
    a4: &[f32; 4],
    v0: &[f32],
    s0: &[i32],
    v1: &[f32],
    s1: &[i32],
    c: &mut [f32],
) -> bool {
    if !supported(r) {
        return false;
    }
    let n = c.len();
    debug_assert!(v0.len() >= n && s0.len() >= n && v1.len() >= n && s1.len() >= n);
    match r.isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 | Isa::Avx512 => unsafe {
            avx2::sel24_row(
                a4.as_ptr(),
                v0.as_ptr(),
                s0.as_ptr(),
                v1.as_ptr(),
                s1.as_ptr(),
                c.as_mut_ptr(),
                n,
            );
            true
        },
        _ => false,
    }
}

/// C (m x panel.n, i32, row stride `ldc`) += quantized A (m rows of
/// `panel.kq * 4` zero-padded i8 bytes, row stride `lda`) * the packed
/// quad-strips of `panel`.  The i32 accumulation is exact; the caller
/// dequantizes on store.  Returns `false` (with `c` untouched) when `r`
/// is scalar, compiled out, or the panel's strip width does not match
/// the resolved NR — callers then run the scalar i32 loop.
///
/// On an AVX-512 resolve the VNNI kernel is tried first; machines
/// without `avx512vnni` drop to the AVX2 `maddubs` pair kernel, which
/// handles the 16-lane strips as two ymm vectors.
pub fn int8_gemm_panel(
    r: &Resolved,
    m: usize,
    a: &[i8],
    lda: usize,
    panel: &Int8Panel,
    c: &mut [i32],
    ldc: usize,
) -> bool {
    if !supported(r) || panel.nr != r.nr {
        return false;
    }
    if m == 0 || panel.n == 0 || panel.kq == 0 {
        return true;
    }
    debug_assert!(lda >= panel.kq * 4, "A rows must be padded to whole quads");
    debug_assert!((m - 1) * lda + panel.kq * 4 <= a.len());
    debug_assert!((m - 1) * ldc + panel.n <= c.len());
    match r.isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            avx2::int8_gemm_panel(m, a.as_ptr(), lda, panel, c.as_mut_ptr(), ldc, r.mr);
            true
        },
        #[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
        Isa::Avx512 => unsafe {
            let (ap, cp) = (a.as_ptr(), c.as_mut_ptr());
            if !avx512::int8_gemm_panel(m, ap, lda, panel, cp, ldc, r.mr) {
                avx2::int8_gemm_panel(m, ap, lda, panel, cp, ldc, r.mr);
            }
            true
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            neon::int8_gemm_panel(m, a.as_ptr(), lda, panel, c.as_mut_ptr(), ldc, r.mr);
            true
        },
        _ => false,
    }
}

/// Int8 analogue of [`sel24_row`]: `c[j] += a4[s0[j]] * v0[j] +
/// a4[s1[j]] * v1[j]` with `a4` already quantized to i32 lanes and the
/// plan values as i8.  Same support surface as the f32 kernel (x86
/// shuffle path only); returns `false` for the scalar i32 loop.
pub fn int8_sel24_row(
    r: &Resolved,
    a4: &[i32; 4],
    v0: &[i8],
    s0: &[i32],
    v1: &[i8],
    s1: &[i32],
    c: &mut [i32],
) -> bool {
    if !supported(r) {
        return false;
    }
    let n = c.len();
    debug_assert!(v0.len() >= n && s0.len() >= n && v1.len() >= n && s1.len() >= n);
    match r.isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 | Isa::Avx512 => unsafe {
            avx2::int8_sel24_row(
                a4.as_ptr(),
                v0.as_ptr(),
                s0.as_ptr(),
                v1.as_ptr(),
                s1.as_ptr(),
                c.as_mut_ptr(),
                n,
            );
            true
        },
        _ => false,
    }
}

/// Cache-blocked SIMD driver for the dense pattern: bm x bk blocking
/// outside, register microkernels inside.  `panel` is consumed when its
/// geometry matches the resolved NR and the operand shape; otherwise B
/// streams strided.  A fused [`Epilogue`] applies to each row block as
/// soon as its full reduction is complete — the block is still hot in
/// cache, so the bias/activation/residual transform costs no extra
/// memory traffic.  Returns `false` on a scalar resolve — the caller
/// then runs its scalar blocked loops (applying `epi` itself).
pub fn dense_blocked(
    r: &Resolved,
    a: &Matrix,
    b: &Matrix,
    panel: Option<&PackedPanel>,
    c: &mut Matrix,
    cfg: &TileConfig,
    epi: Option<&Epilogue>,
) -> bool {
    if !supported(r) {
        return false;
    }
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let bm = cfg.bm();
    let bk = cfg.bk();
    let panel = panel.filter(|p| p.nr == r.nr && p.kc == k && p.n == n);
    for i0 in (0..m).step_by(bm) {
        let i1 = (i0 + bm).min(m);
        let mi = i1 - i0;
        for k0 in (0..k).step_by(bk) {
            let kt = (k0 + bk).min(k) - k0;
            let arow = &a.data[i0 * k + k0..];
            let cblk = &mut c.data[i0 * n..];
            let done = match panel {
                Some(p) => gemm_panel(r, mi, k0, kt, arow, k, p, cblk, n),
                None => false,
            };
            if !done {
                gemm_strided(r, mi, kt, n, arow, k, &b.data[k0 * n..], n, cblk, n);
            }
        }
        if let Some(e) = epi {
            e.apply_rows(c, i0, i1);
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn micro_cfg_labels_roundtrip() {
        for mc in [MicroCfg::Auto, MicroCfg::Scalar, MicroCfg::Simd { mr: 4, nr: 16 }] {
            assert_eq!(MicroCfg::from_label(&mc.label()), Some(mc));
        }
        assert_eq!(MicroCfg::from_label("simd8x8"), Some(MicroCfg::Simd { mr: 8, nr: 8 }));
        assert!(MicroCfg::from_label("simd8").is_none());
        assert!(MicroCfg::from_label("avx3").is_none());
    }

    #[test]
    fn resolved_code_roundtrips_for_telemetry() {
        for r in [
            Resolved::SCALAR,
            Resolved { isa: Isa::Avx2, mr: 4, nr: 16 },
            Resolved { isa: Isa::Avx512, mr: 8, nr: 16 },
            Resolved { isa: Isa::Neon, mr: 2, nr: 8 },
        ] {
            assert_eq!(Resolved::from_code(r.code()), r);
        }
        assert_eq!(describe(Resolved { isa: Isa::Avx2, mr: 4, nr: 16 }.code()), "avx2 4x16");
        assert_eq!(describe(0), "scalar");
    }

    #[test]
    fn resolve_snaps_onto_compiled_blocks() {
        assert_eq!(resolve_with(MicroCfg::Auto, Isa::Scalar), Resolved::SCALAR);
        assert_eq!(resolve_with(MicroCfg::Scalar, Isa::Avx2), Resolved::SCALAR);
        let r = resolve_with(MicroCfg::Auto, Isa::Avx2);
        assert_eq!((r.mr, r.nr), (4, 16));
        // 8x16 exceeds the ymm file at NRV=2: MR snaps down
        let r = resolve_with(MicroCfg::Simd { mr: 8, nr: 16 }, Isa::Avx2);
        assert_eq!((r.mr, r.nr), (4, 16));
        let r = resolve_with(MicroCfg::Simd { mr: 3, nr: 9 }, Isa::Avx2);
        assert_eq!((r.mr, r.nr), (2, 8));
        let r = resolve_with(MicroCfg::Simd { mr: 200, nr: 200 }, Isa::Avx2);
        assert_eq!((r.mr, r.nr), (4, 16));
        let r = resolve_with(MicroCfg::Simd { mr: 8, nr: 4 }, Isa::Neon);
        assert_eq!((r.mr, r.nr), (8, 4));
        let r = resolve_with(MicroCfg::Simd { mr: 5, nr: 64 }, Isa::Avx512);
        assert_eq!((r.mr, r.nr), (4, 16));
    }

    #[test]
    fn search_axis_always_offers_scalar() {
        let axis = search_axis();
        assert!(axis.contains(&MicroCfg::Scalar));
        if simd_available() {
            assert!(axis.iter().any(|m| matches!(m, MicroCfg::Simd { .. })));
        } else {
            assert_eq!(axis, vec![MicroCfg::Scalar]);
        }
    }

    fn reference(m: usize, kt: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut want = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..kt {
                    acc += a[i * kt + kk] * b[kk * n + j];
                }
                want[i * n + j] = acc;
            }
        }
        want
    }

    #[test]
    fn strided_kernel_matches_scalar_reference() {
        let r = resolve_with(MicroCfg::Auto, active_isa());
        if !supported(&r) {
            return; // scalar-only host: the fallback path is the oracle
        }
        let mut rng = Rng::new(901);
        for &(m, kt, n) in &[(1usize, 3usize, 1usize), (5, 7, 9), (13, 16, 24), (17, 33, 50)] {
            let a: Vec<f32> = (0..m * kt).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..kt * n).map(|_| rng.next_f32() - 0.5).collect();
            let mut c = vec![0.0f32; m * n];
            assert!(gemm_strided(&r, m, kt, n, &a, kt, &b, n, &mut c, n));
            let want = reference(m, kt, n, &a, &b);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-4, "{m}x{kt}x{n}");
            }
        }
    }

    #[test]
    fn panel_kernel_matches_strided() {
        let r = resolve_with(MicroCfg::Auto, active_isa());
        if !supported(&r) {
            return;
        }
        let mut rng = Rng::new(902);
        // N deliberately not a multiple of NR: exercises the padded tail
        for &(m, kt, n) in &[(6usize, 11usize, 19usize), (3, 8, 8), (1, 5, 33)] {
            let a: Vec<f32> = (0..m * kt).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..kt * n).map(|_| rng.next_f32() - 0.5).collect();
            let panel = PackedPanel::pack(&b, kt, n, n, r.nr);
            let mut c = vec![0.0f32; m * n];
            assert!(gemm_panel(&r, m, 0, kt, &a, kt, &panel, &mut c, n));
            let want = reference(m, kt, n, &a, &b);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-4, "{m}x{kt}x{n}");
            }
        }
        // strip-width mismatch refuses rather than mis-indexing
        let b = vec![0.0f32; 4 * 8];
        let panel = PackedPanel::pack(&b, 4, 8, 8, r.nr * 2);
        let mut c = vec![0.0f32; 8];
        assert!(!gemm_panel(&r, 1, 0, 4, &[0.0; 4], 4, &panel, &mut c, 8));
    }

    #[test]
    fn int8_panel_kernel_matches_scalar_i32_reference() {
        let r = resolve_with(MicroCfg::Auto, active_isa());
        if !supported(&r) {
            return;
        }
        let mut rng = Rng::new(904);
        // K and N deliberately off the quad/strip grid: padding in play
        for &(m, kt, n) in &[(1usize, 3usize, 1usize), (5, 7, 9), (6, 13, 19), (9, 32, 40)] {
            let kq = kt.div_ceil(4);
            let lda = kq * 4;
            let mut a = vec![0i8; m * lda];
            for i in 0..m {
                for kk in 0..kt {
                    a[i * lda + kk] = (rng.below(255) as i32 - 127) as i8;
                }
            }
            let b: Vec<i8> = (0..kt * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let panel = Int8Panel::pack(&b, kt, n, n, r.nr);
            let mut c = vec![0i32; m * n];
            assert!(int8_gemm_panel(&r, m, &a, lda, &panel, &mut c, n));
            for i in 0..m {
                for j in 0..n {
                    let mut want = 0i32;
                    for kk in 0..kt {
                        want += a[i * lda + kk] as i32 * b[kk * n + j] as i32;
                    }
                    assert_eq!(c[i * n + j], want, "{m}x{kt}x{n} at ({i},{j})");
                }
            }
        }
        // strip-width mismatch refuses rather than mis-indexing
        let b = vec![0i8; 4 * 8];
        let panel = Int8Panel::pack(&b, 4, 8, 8, r.nr * 2);
        let mut c = vec![0i32; 8];
        assert!(!int8_gemm_panel(&r, 1, &[0i8; 4], 4, &panel, &mut c, 8));
    }

    #[test]
    fn int8_kernel_accumulates_into_existing_c() {
        let r = resolve_with(MicroCfg::Auto, active_isa());
        if !supported(&r) {
            return;
        }
        let (m, kt, n) = (2usize, 8usize, 5usize);
        let a = vec![1i8; m * kt];
        let b = vec![2i8; kt * n];
        let panel = Int8Panel::pack(&b, kt, n, n, r.nr);
        let mut c = vec![100i32; m * n];
        assert!(int8_gemm_panel(&r, m, &a, kt, &panel, &mut c, n));
        assert!(c.iter().all(|&x| x == 100 + 16), "{c:?}");
    }

    #[test]
    fn int8_sel24_matches_scalar_selection() {
        let r = resolve_with(MicroCfg::Auto, active_isa());
        if !supported(&r) {
            return;
        }
        let mut rng = Rng::new(905);
        let n = 21; // not a multiple of 8: scalar tail in play
        let a4 = [127i32, -88, 3, -127];
        let v0: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let v1: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let s0: Vec<i32> = (0..n).map(|_| rng.below(4) as i32).collect();
        let s1: Vec<i32> = (0..n).map(|_| rng.below(4) as i32).collect();
        let init: Vec<i32> = (0..n).map(|_| rng.below(1000) as i32 - 500).collect();
        let mut c = init.clone();
        if !int8_sel24_row(&r, &a4, &v0, &s0, &v1, &s1, &mut c) {
            return; // no shuffle path on this ISA (NEON)
        }
        for j in 0..n {
            let want =
                init[j] + a4[s0[j] as usize] * v0[j] as i32 + a4[s1[j] as usize] * v1[j] as i32;
            assert_eq!(c[j], want, "j={j}");
        }
    }

    #[test]
    fn sel24_matches_scalar_selection() {
        let r = resolve_with(MicroCfg::Auto, active_isa());
        if !supported(&r) {
            return;
        }
        let mut rng = Rng::new(903);
        let n = 21; // not a multiple of 8: scalar tail in play
        let a4 = [0.5f32, -1.25, 2.0, 0.125];
        let v0: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let v1: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let s0: Vec<i32> = (0..n).map(|_| rng.below(4) as i32).collect();
        let s1: Vec<i32> = (0..n).map(|_| rng.below(4) as i32).collect();
        let init: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let mut c = init.clone();
        if !sel24_row(&r, &a4, &v0, &s0, &v1, &s1, &mut c) {
            return; // no shuffle path on this ISA (NEON)
        }
        for j in 0..n {
            let want = init[j] + a4[s0[j] as usize] * v0[j] + a4[s1[j] as usize] * v1[j];
            assert!((c[j] - want).abs() < 1e-4, "j={j}");
        }
    }
}
