//! AVX-512 f32 dense strip kernels (16-lane FMA).
//!
//! Compiled only when the build itself targets `avx512f`
//! (`RUSTFLAGS="-C target-feature=+avx512f"`); default builds never see
//! these intrinsics and the runtime dispatcher stops at AVX2.  One zmm
//! per accumulator row (NR = 16), MR in {1, 2, 4, 8} — the 32-register
//! file leaves headroom, but deeper tiles gain nothing at this width.
//! The 2:4 selection kernel reuses the AVX2 shuffle path (any `avx512f`
//! machine has AVX2+FMA).

use core::arch::x86_64::*;

use super::panel::{Int8Panel, PackedPanel};

/// Snap MR onto a compiled instantiation (NR is fixed at 16 lanes).
pub(super) fn clamp_mr(mr: usize) -> usize {
    let want = mr.clamp(1, 8);
    [8usize, 4, 2, 1].into_iter().find(|&c| c <= want).unwrap_or(1)
}

macro_rules! def_kernel {
    ($name:ident, $mr:expr) => {
        /// One register tile: C[MR x 16] += A[MR x kt] * B[kt x 16].
        #[target_feature(enable = "avx512f")]
        unsafe fn $name(
            a: *const f32,
            lda: usize,
            b: *const f32,
            ldb: usize,
            c: *mut f32,
            ldc: usize,
            kt: usize,
        ) {
            const MR: usize = $mr;
            let mut acc = [_mm512_setzero_ps(); MR];
            let mut ap = a;
            let mut bp = b;
            for _ in 0..kt {
                let bv = _mm512_loadu_ps(bp);
                for (i, cell) in acc.iter_mut().enumerate() {
                    let av = _mm512_set1_ps(*ap.add(i * lda));
                    *cell = _mm512_fmadd_ps(av, bv, *cell);
                }
                ap = ap.add(1);
                bp = bp.add(ldb);
            }
            for (i, cell) in acc.iter().enumerate() {
                let cp = c.add(i * ldc);
                _mm512_storeu_ps(cp, _mm512_add_ps(_mm512_loadu_ps(cp), *cell));
            }
        }
    };
}

def_kernel!(k1, 1);
def_kernel!(k2, 2);
def_kernel!(k4, 4);
def_kernel!(k8, 8);

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx512f")]
unsafe fn kernel(
    mr: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
    kt: usize,
) {
    match mr {
        8 => k8(a, lda, b, ldb, c, ldc, kt),
        4 => k4(a, lda, b, ldb, c, ldc, kt),
        2 => k2(a, lda, b, ldb, c, ldc, kt),
        _ => k1(a, lda, b, ldb, c, ldc, kt),
    }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx512f")]
unsafe fn strip(
    m: usize,
    kt: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
    mr: usize,
) {
    let mut i = 0;
    while i + mr <= m {
        kernel(mr, a.add(i * lda), lda, b, ldb, c.add(i * ldc), ldc, kt);
        i += mr;
    }
    while i < m {
        kernel(1, a.add(i * lda), lda, b, ldb, c.add(i * ldc), ldc, kt);
        i += 1;
    }
}

#[allow(clippy::too_many_arguments)]
unsafe fn scalar_cols(
    m: usize,
    kt: usize,
    w: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
) {
    for i in 0..m {
        for j in 0..w {
            let mut acc = 0.0f32;
            for kk in 0..kt {
                acc += *a.add(i * lda + kk) * *b.add(kk * ldb + j);
            }
            *c.add(i * ldc + j) += acc;
        }
    }
}

/// C (m x n) += A (m x kt) * B (kt x n), strided row-major operands.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn gemm_strided(
    m: usize,
    kt: usize,
    n: usize,
    a: *const f32,
    lda: usize,
    b: *const f32,
    ldb: usize,
    c: *mut f32,
    ldc: usize,
    mr: usize,
) {
    let mr = clamp_mr(mr);
    let mut j = 0;
    while j + 16 <= n {
        strip(m, kt, a, lda, b.add(j), ldb, c.add(j), ldc, mr);
        j += 16;
    }
    if j < n {
        scalar_cols(m, kt, n - j, a, lda, b.add(j), ldb, c.add(j), ldc);
    }
}

/// Panel driver (NR = 16 strips; zero-padded tail via a stack tile).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx512f")]
pub(super) unsafe fn gemm_panel(
    m: usize,
    k0: usize,
    kt: usize,
    a: *const f32,
    lda: usize,
    panel: &PackedPanel,
    c: *mut f32,
    ldc: usize,
    mr: usize,
) {
    let nr = panel.nr;
    let mr = clamp_mr(mr);
    let data = panel.data.as_ptr();
    for p in 0..panel.strips() {
        let j0 = p * nr;
        let bp = data.add(p * panel.kc * nr + k0 * nr);
        if j0 + nr <= panel.n {
            strip(m, kt, a, lda, bp, nr, c.add(j0), ldc, mr);
        } else {
            let w = panel.n - j0;
            for i in 0..m {
                let mut tile = [0.0f32; 16];
                kernel(1, a.add(i * lda), lda, bp, nr, tile.as_mut_ptr(), 16, kt);
                let crow = c.add(i * ldc + j0);
                for (jj, v) in tile.iter().take(w).enumerate() {
                    *crow.add(jj) += *v;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Int8 path (VNNI).
//
// `vpdpbusd` multiplies u8 x i8 and accumulates pair-of-pairs into i32
// lanes, so the signed A quad is split as a * b == |a| * (b * sign(a)):
// |a| rides the unsigned operand, and b is conditionally negated under
// the byte-sign mask of a (AVX-512 has no `vpsignb`; a masked subtract
// from zero does the same and zeros nothing — where a == 0, |a| = 0
// already kills the product).  Both the sign mask and the fallback-free
// negation need AVX512-BW, which every VNNI part ships; the driver
// returns `false` when the running CPU lacks either feature and the
// dispatcher drops to the AVX2 int8 kernel instead.
// ---------------------------------------------------------------------------

macro_rules! def_int8_kernel {
    ($name:ident, $mr:expr) => {
        /// One register tile: C[MR x 16] (i32) += A[MR x kq quads] * strip.
        #[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
        unsafe fn $name(
            a: *const i8,
            lda: usize,
            b: *const i8,
            c: *mut i32,
            ldc: usize,
            kq: usize,
            nr: usize,
        ) {
            const MR: usize = $mr;
            let zero = _mm512_setzero_si512();
            let mut acc = [zero; MR];
            let mut bp = b;
            for q in 0..kq {
                let bv = _mm512_loadu_si512(bp as *const _);
                for (i, cell) in acc.iter_mut().enumerate() {
                    let quad = (a.add(i * lda + q * 4) as *const i32).read_unaligned();
                    let ab = _mm512_set1_epi32(quad);
                    let ua = _mm512_abs_epi8(ab);
                    let neg = _mm512_movepi8_mask(ab);
                    let sb = _mm512_mask_sub_epi8(bv, neg, zero, bv);
                    *cell = _mm512_dpbusd_epi32(*cell, ua, sb);
                }
                bp = bp.add(nr * 4);
            }
            for (i, cell) in acc.iter().enumerate() {
                let cp = c.add(i * ldc);
                let sum = _mm512_add_epi32(_mm512_loadu_si512(cp as *const _), *cell);
                _mm512_storeu_si512(cp as *mut _, sum);
            }
        }
    };
}

def_int8_kernel!(q1, 1);
def_int8_kernel!(q2, 2);
def_int8_kernel!(q4, 4);
def_int8_kernel!(q8, 8);

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
unsafe fn int8_kernel(
    mr: usize,
    a: *const i8,
    lda: usize,
    b: *const i8,
    c: *mut i32,
    ldc: usize,
    kq: usize,
    nr: usize,
) {
    match mr {
        8 => q8(a, lda, b, c, ldc, kq, nr),
        4 => q4(a, lda, b, c, ldc, kq, nr),
        2 => q2(a, lda, b, c, ldc, kq, nr),
        _ => q1(a, lda, b, c, ldc, kq, nr),
    }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
unsafe fn int8_strip(
    m: usize,
    a: *const i8,
    lda: usize,
    b: *const i8,
    c: *mut i32,
    ldc: usize,
    kq: usize,
    nr: usize,
    mr: usize,
) {
    let mut i = 0;
    while i + mr <= m {
        int8_kernel(mr, a.add(i * lda), lda, b, c.add(i * ldc), ldc, kq, nr);
        i += mr;
    }
    while i < m {
        int8_kernel(1, a.add(i * lda), lda, b, c.add(i * ldc), ldc, kq, nr);
        i += 1;
    }
}

/// C (m x panel.n, i32) += A (m x kq quads) * panel, dequant elsewhere.
///
/// A rows must be zero-padded to `panel.kq * 4` bytes (the kernel reads
/// whole 4-byte quads).  Returns `false` without touching `c` when the
/// running CPU lacks VNNI (or BW); the caller then retries on the AVX2
/// int8 kernel, which any x86 machine reaching this module supports.
pub(super) unsafe fn int8_gemm_panel(
    m: usize,
    a: *const i8,
    lda: usize,
    panel: &Int8Panel,
    c: *mut i32,
    ldc: usize,
    mr: usize,
) -> bool {
    if !std::arch::is_x86_feature_detected!("avx512vnni")
        || !std::arch::is_x86_feature_detected!("avx512bw")
    {
        return false;
    }
    let nr = panel.nr;
    let mr = clamp_mr(mr);
    let data = panel.data.as_ptr();
    for p in 0..panel.strips() {
        let j0 = p * nr;
        let bp = data.add(p * panel.kq * nr * 4);
        if j0 + nr <= panel.n {
            int8_strip(m, a, lda, bp, c.add(j0), ldc, panel.kq, nr, mr);
        } else {
            let w = panel.n - j0;
            for i in 0..m {
                let mut tile = [0i32; 16];
                int8_kernel(1, a.add(i * lda), lda, bp, tile.as_mut_ptr(), 16, panel.kq, nr);
                let crow = c.add(i * ldc + j0);
                for (jj, v) in tile.iter().take(w).enumerate() {
                    *crow.add(jj) += *v;
                }
            }
        }
    }
    true
}
