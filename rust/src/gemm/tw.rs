//! TW condensed GEMM on the CPU — the Rust twin of the fused-CTO kernel
//! (paper §V), plus the naive variants used by the Fig. 4 ablation.
//!
//! Strategies, in the paper's optimization order:
//!   1. `tw_matmul_masked`  — skip pruned work via mask tests inside the
//!      dense loop (the "naive tiling" strawman; uncoalesced analogue).
//!   2. `tw_matmul_per_tile` — one GEMM per condensed tile (the
//!      stream/batched stage: condensed operands, separate launches).
//!   3. `tw_matmul`          — single fused pass over all tiles driven by
//!      the CTO offset tables (the paper's final CTO kernel).

use super::micro::{self, PackedPanel};
use super::{Epilogue, TileConfig};
use crate::pool::{self, split_range, SendPtr, ThreadPool};
use crate::sparse::{Mask, TwPlan};
use crate::tensor::Matrix;

/// Strawman: dense loop with per-element mask tests (no condensation).
pub fn tw_matmul_masked(a: &Matrix, w: &Matrix, mask: &Mask) -> Matrix {
    assert_eq!(a.cols, w.rows);
    let (m, k, n) = (a.rows, a.cols, w.cols);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for kk in 0..k {
            let aik = a.at(i, kk);
            for j in 0..n {
                if mask.at(kk, j) {
                    *c.at_mut(i, j) += aik * w.at(kk, j);
                }
            }
        }
    }
    c
}

/// One condensed GEMM per tile: gather A columns, multiply, scatter C.
pub fn tw_matmul_per_tile(a: &Matrix, plan: &TwPlan) -> Matrix {
    let m = a.rows;
    let mut c = Matrix::zeros(m, plan.n);
    let mut a_gather = vec![0.0f32; m * plan.kmax];
    for t in 0..plan.tiles {
        let kt = plan.row_len[t] as usize;
        let width = (0..plan.g).take_while(|&j| (plan.col_idx[t * plan.g + j] as usize) < plan.n).count();
        // gather: a_gather (m x kt)
        for i in 0..m {
            let arow = a.row(i);
            for ii in 0..kt {
                a_gather[i * plan.kmax + ii] = arow[plan.row_idx[t * plan.kmax + ii] as usize];
            }
        }
        // multiply + scatter
        for i in 0..m {
            for j in 0..width {
                let cj = plan.col_idx[t * plan.g + j] as usize;
                let mut acc = 0.0f32;
                for ii in 0..kt {
                    acc += a_gather[i * plan.kmax + ii] * plan.b_cond[(t * plan.kmax + ii) * plan.g + j];
                }
                *c.at_mut(i, cj) = acc;
            }
        }
    }
    c
}

/// The fused-CTO kernel: a single pass over all tiles with a blocked inner
/// GEMM over the gathered operands.  This is the §Perf-optimized hot path,
/// at the historical hard-coded row block (32).
pub fn tw_matmul(a: &Matrix, plan: &TwPlan) -> Matrix {
    tw_matmul_with(a, plan, &TileConfig::tw_default())
}

/// Fused-CTO kernel with an explicit tile config (`cfg.bm` = activation
/// row block; the reduction extent is fixed by the condensed plan).
pub fn tw_matmul_with(a: &Matrix, plan: &TwPlan, cfg: &TileConfig) -> Matrix {
    let m = a.rows;
    let mut c = Matrix::zeros(m, plan.n);
    tw_matmul_into_with(a, plan, &mut c, cfg);
    c
}

/// In-place variant (the serving loop reuses the output allocation).
pub fn tw_matmul_into(a: &Matrix, plan: &TwPlan, c: &mut Matrix) {
    tw_matmul_into_with(a, plan, c, &TileConfig::tw_default());
}

/// In-place fused-CTO kernel with an explicit tile config.  Allocates its
/// gather/accumulate staging per call; the serving hot loop uses
/// [`tw_matmul_into_scratch`] instead.
pub fn tw_matmul_into_with(a: &Matrix, plan: &TwPlan, c: &mut Matrix, cfg: &TileConfig) {
    tw_matmul_into_scratch(a, plan, c, cfg, &mut crate::gemm::GemmScratch::new());
}

/// In-place fused-CTO kernel reusing a caller-owned [`crate::gemm::GemmScratch`]
/// for the CTO gather block (`bm x kmax`) and the compact output tile
/// (`bm x g`) — zero allocations once the scratch has grown to the
/// model's largest plan.
pub fn tw_matmul_into_scratch(
    a: &Matrix,
    plan: &TwPlan,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut crate::gemm::GemmScratch,
) {
    tw_matmul_into_scratch_panels(a, plan, None, c, cfg, scratch);
}

/// Pack each condensed tile's `b_cond` block (`kmax x g`) into K-major
/// panels for the SIMD microkernel.  Built once at weight-pack time
/// (`graph::pack`) and fed to [`tw_matmul_into_scratch_panels`]; rows
/// past a tile's `row_len` are the plan's zero padding, so the panels
/// stay valid for every `kt`.
pub fn tw_pack_panels(plan: &TwPlan, nr: usize) -> Vec<PackedPanel> {
    (0..plan.tiles)
        .map(|t| {
            let base = t * plan.kmax * plan.g;
            let block = &plan.b_cond[base..base + plan.kmax * plan.g];
            PackedPanel::pack(block, plan.kmax, plan.g, plan.g, nr)
        })
        .collect()
}

/// Panel-aware form of [`tw_matmul_into_scratch`]: with matching panels
/// the SIMD kernel streams each tile's condensed B contiguously; without
/// them it strides `b_cond` directly (row stride `g`), and a scalar
/// resolve keeps the historical blocked loops.
pub fn tw_matmul_into_scratch_panels(
    a: &Matrix,
    plan: &TwPlan,
    panels: Option<&[PackedPanel]>,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut crate::gemm::GemmScratch,
) {
    tw_matmul_into_scratch_panels_epi(a, plan, panels, c, cfg, scratch, None);
}

/// [`tw_matmul_into_scratch_panels`] with a fused [`Epilogue`] applied
/// inside the CTO scatter itself — TW's output transform rides the
/// scatter's existing write, paying **zero** extra passes over C (the
/// paper's fused-epilogue argument for tile-wise sparsity).  When `epi`
/// is `Some`, the caller must seed C with [`Epilogue::prefill`] instead
/// of zeroing it, so pruned (never-scattered) columns also read
/// `act(bias) + residual`.
#[allow(clippy::too_many_arguments)]
pub fn tw_matmul_into_scratch_panels_epi(
    a: &Matrix,
    plan: &TwPlan,
    panels: Option<&[PackedPanel]>,
    c: &mut Matrix,
    cfg: &TileConfig,
    scratch: &mut crate::gemm::GemmScratch,
    epi: Option<&Epilogue>,
) {
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, plan.n);
    let m = a.rows;
    let bm = cfg.bm();
    let r = micro::resolve(cfg);
    scratch.ensure(bm * plan.kmax, bm * plan.g);
    let (a_gather, c_tile) = (&mut scratch.a, &mut scratch.c);
    for t in 0..plan.tiles {
        let kt = plan.row_len[t] as usize;
        let width = (0..plan.g)
            .take_while(|&j| (plan.col_idx[t * plan.g + j] as usize) < plan.n)
            .count();
        if kt == 0 || width == 0 {
            continue;
        }
        let rows = &plan.row_idx[t * plan.kmax..t * plan.kmax + kt];
        for i0 in (0..m).step_by(bm) {
            let bm = bm.min(m - i0);
            // CTO gather of A columns into a compact (bm x kt) block
            for i in 0..bm {
                let arow = a.row(i0 + i);
                let dst = &mut a_gather[i * plan.kmax..i * plan.kmax + kt];
                for (d, &r) in dst.iter_mut().zip(rows) {
                    *d = arow[r as usize];
                }
            }
            // (bm x kt) x (kt x width) GEMM into c_tile; `stride` is the
            // c_tile row stride the scatter below must use (the panel
            // path computes the full g-wide tile, the others pack tight)
            let mut stride = 0usize;
            if let Some(ps) = panels {
                let p = &ps[t];
                if p.nr == r.nr && p.kc == plan.kmax && p.n == plan.g {
                    let ct = &mut c_tile[..bm * plan.g];
                    ct.fill(0.0);
                    if micro::gemm_panel(&r, bm, 0, kt, a_gather, plan.kmax, p, ct, plan.g) {
                        stride = plan.g;
                    }
                }
            }
            if stride == 0 && r.is_simd() {
                let b = &plan.b_cond[t * plan.kmax * plan.g..];
                let ct = &mut c_tile[..bm * width];
                ct.fill(0.0);
                if micro::gemm_strided(&r, bm, kt, width, a_gather, plan.kmax, b, plan.g, ct, width)
                {
                    stride = width;
                }
            }
            if stride == 0 {
                // scalar fallback (§Perf: 2-way k unroll matching
                // gemm::dense — one pass over the C row per two condensed
                // B rows)
                stride = width;
                c_tile[..bm * width].fill(0.0);
                for i in 0..bm {
                    let ag = &a_gather[i * plan.kmax..i * plan.kmax + kt];
                    let crow = &mut c_tile[i * width..(i + 1) * width];
                    let mut ii = 0usize;
                    while ii + 1 < kt {
                        let a0 = ag[ii];
                        let a1 = ag[ii + 1];
                        let base0 = (t * plan.kmax + ii) * plan.g;
                        let base1 = (t * plan.kmax + ii + 1) * plan.g;
                        let b0 = &plan.b_cond[base0..base0 + width];
                        let b1 = &plan.b_cond[base1..base1 + width];
                        for ((cv, bv0), bv1) in crow.iter_mut().zip(b0).zip(b1) {
                            *cv += a0 * bv0 + a1 * bv1;
                        }
                        ii += 2;
                    }
                    if ii < kt {
                        let av = ag[ii];
                        let base = (t * plan.kmax + ii) * plan.g;
                        let brow = &plan.b_cond[base..base + width];
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += av * bv;
                        }
                    }
                }
            }
            // CTO scatter of output columns (the epilogue fuses into the
            // scatter write itself)
            match epi {
                Some(e) => {
                    for i in 0..bm {
                        let row = i0 + i;
                        let crow = c.row_mut(row);
                        for j in 0..width {
                            let cj = plan.col_idx[t * plan.g + j] as usize;
                            crow[cj] = e.apply(row, cj, c_tile[i * stride + j]);
                        }
                    }
                }
                None => {
                    for i in 0..bm {
                        let crow = c.row_mut(i0 + i);
                        for j in 0..width {
                            crow[plan.col_idx[t * plan.g + j] as usize] = c_tile[i * stride + j];
                        }
                    }
                }
            }
        }
    }
}

/// The thread count the tile-parallel kernel will actually use for a plan
/// with `tiles` condensed tiles (tiles are the unit of parallelism, so a
/// 1-tile plan runs serial regardless of budget).  Exposed so the
/// autotuner can skip candidates that silently degrade to serial.
pub fn tw_effective_parallel_threads(tiles: usize, threads: usize) -> usize {
    if threads <= 1 || tiles < 2 {
        1
    } else {
        threads.min(tiles)
    }
}

/// Multi-threaded fused kernel on the global persistent pool (historical
/// signature; see [`tw_matmul_parallel_into`]).
pub fn tw_matmul_parallel(a: &Matrix, plan: &TwPlan, threads: usize) -> Matrix {
    let mut c = Matrix::zeros(a.rows, plan.n);
    tw_matmul_parallel_into(a, plan, &mut c, &TileConfig::tw_default(), threads, pool::global());
    c
}

/// In-place tile-parallel fused kernel: condensed tiles write disjoint
/// output columns, so contiguous tile ranges are claimed from `pool`
/// lock-free with no per-call thread spawns.  Like
/// [`tw_matmul_into_with`], only *kept* output columns are written — the
/// caller zeroes `c` if pruned columns may hold stale data.  Returns the
/// effective thread count; on the serial fallback (1) the kernel honours
/// the caller's tuned `cfg`.
pub fn tw_matmul_parallel_into(
    a: &Matrix,
    plan: &TwPlan,
    c: &mut Matrix,
    cfg: &TileConfig,
    threads: usize,
    pool: &ThreadPool,
) -> usize {
    tw_matmul_parallel_into_epi(a, plan, c, cfg, threads, pool, None)
}

/// [`tw_matmul_parallel_into`] with a fused [`Epilogue`] applied at both
/// scatter sites (SIMD row step and scalar fallback).  Same prefill
/// contract as [`tw_matmul_into_scratch_panels_epi`]: with `epi: Some`
/// the caller seeds C via [`Epilogue::prefill`] rather than zeroing.
pub fn tw_matmul_parallel_into_epi(
    a: &Matrix,
    plan: &TwPlan,
    c: &mut Matrix,
    cfg: &TileConfig,
    threads: usize,
    pool: &ThreadPool,
    epi: Option<&Epilogue>,
) -> usize {
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, plan.n);
    let eff = tw_effective_parallel_threads(plan.tiles, threads);
    if eff == 1 {
        tw_matmul_into_scratch_panels_epi(
            a,
            plan,
            None,
            c,
            cfg,
            &mut crate::gemm::GemmScratch::new(),
            epi,
        );
        return 1;
    }
    let m = a.rows;
    let n = plan.n;
    let r = micro::resolve(cfg);
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    pool.parallel_for(eff, |chunk| {
        let (t0, t1) = split_range(plan.tiles, eff, chunk);
        let mut a_gather = vec![0.0f32; plan.kmax];
        let mut c_row = vec![0.0f32; plan.g];
        for t in t0..t1 {
            let kt = plan.row_len[t] as usize;
            let width = (0..plan.g)
                .take_while(|&j| (plan.col_idx[t * plan.g + j] as usize) < n)
                .count();
            if kt == 0 || width == 0 {
                continue;
            }
            let rows = &plan.row_idx[t * plan.kmax..t * plan.kmax + kt];
            for i in 0..m {
                let arow = a.row(i);
                for (d, &ri) in a_gather[..kt].iter_mut().zip(rows) {
                    *d = arow[ri as usize];
                }
                // SIMD row step: (1 x kt) x (kt x width) into c_row, then
                // the same disjoint-column scatter as the scalar path
                if r.is_simd() {
                    let b = &plan.b_cond[t * plan.kmax * plan.g..];
                    let ag = &a_gather[..kt];
                    let ct = &mut c_row[..width];
                    ct.fill(0.0);
                    if micro::gemm_strided(&r, 1, kt, width, ag, kt, b, plan.g, ct, width) {
                        for j in 0..width {
                            let cj = plan.col_idx[t * plan.g + j] as usize;
                            let v = match epi {
                                Some(e) => e.apply(i, cj, c_row[j]),
                                None => c_row[j],
                            };
                            // SAFETY: tiles own disjoint output columns, and
                            // tile ranges are disjoint across chunks
                            unsafe { *c_ptr.0.add(i * n + cj) = v };
                        }
                        continue;
                    }
                }
                for j in 0..width {
                    let mut acc = 0.0f32;
                    for ii in 0..kt {
                        acc += a_gather[ii] * plan.b_cond[(t * plan.kmax + ii) * plan.g + j];
                    }
                    let cj = plan.col_idx[t * plan.g + j] as usize;
                    let v = match epi {
                        Some(e) => e.apply(i, cj, acc),
                        None => acc,
                    };
                    // SAFETY: tiles own disjoint output columns, and tile
                    // ranges are disjoint across chunks
                    unsafe { *c_ptr.0.add(i * n + cj) = v };
                }
            }
        }
    });
    eff
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::dense::matmul_naive;
    use crate::sparse::prune_tw;
    use crate::util::Rng;

    fn setup(m: usize, k: usize, n: usize, s: f64, g: usize, seed: u64) -> (Matrix, Matrix, crate::sparse::TwStructure, TwPlan) {
        let mut rng = Rng::new(seed);
        let a = Matrix::randn(m, k, &mut rng);
        let w = Matrix::randn(k, n, &mut rng);
        let tw = prune_tw(&w, s, g, None);
        let plan = TwPlan::encode(&w, &tw);
        (a, w, tw, plan)
    }

    #[test]
    fn fused_matches_mask_oracle() {
        let (a, w, tw, plan) = setup(40, 96, 80, 0.6, 16, 80);
        let want = matmul_naive(&a, &tw.mask().apply(&w));
        let got = tw_matmul(&a, &plan);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn all_strategies_agree() {
        let (a, w, tw, plan) = setup(24, 64, 48, 0.5, 16, 81);
        let oracle = matmul_naive(&a, &tw.mask().apply(&w));
        let masked = tw_matmul_masked(&a, &w, &tw.mask());
        let per_tile = tw_matmul_per_tile(&a, &plan);
        let fused = tw_matmul(&a, &plan);
        let par = tw_matmul_parallel(&a, &plan, 4);
        for (name, got) in [
            ("masked", &masked),
            ("per_tile", &per_tile),
            ("fused", &fused),
            ("parallel", &par),
        ] {
            assert!(got.max_abs_diff(&oracle) < 1e-3, "{name}");
        }
    }

    #[test]
    fn tile_configs_agree_with_default() {
        let (a, _, _, plan) = setup(40, 96, 80, 0.6, 16, 85);
        let want = tw_matmul(&a, &plan);
        for &bm in &[1usize, 7, 16, 33, 64, 128, 0] {
            let got = tw_matmul_with(&a, &plan, &TileConfig::new(bm, 64));
            assert!(got.max_abs_diff(&want) < 1e-4, "bm={bm}");
        }
    }

    #[test]
    fn pruned_columns_are_zero() {
        let (a, _, tw, plan) = setup(16, 32, 32, 0.7, 8, 82);
        let got = tw_matmul(&a, &plan);
        let kept: std::collections::HashSet<usize> = tw.kept_cols.iter().copied().collect();
        for j in 0..32 {
            if !kept.contains(&j) {
                for i in 0..16 {
                    assert_eq!(got.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn high_sparsity_extreme() {
        let (a, w, tw, plan) = setup(8, 64, 64, 0.95, 16, 83);
        let want = matmul_naive(&a, &tw.mask().apply(&w));
        assert!(tw_matmul(&a, &plan).max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn scratch_variant_matches_and_is_reusable() {
        // one undersized scratch across differently-shaped plans: results
        // must match the allocating kernel exactly
        let mut scratch = crate::gemm::GemmScratch::new();
        for (seed, (m, k, n, g)) in [(86u64, (24usize, 64usize, 48usize, 16usize)), (87, (40, 96, 80, 8))] {
            let (a, _, _, plan) = setup(m, k, n, 0.6, g, seed);
            let cfg = TileConfig::new(16, 64);
            let want = tw_matmul_with(&a, &plan, &cfg);
            let mut c = Matrix::zeros(m, n);
            tw_matmul_into_scratch(&a, &plan, &mut c, &cfg, &mut scratch);
            assert!(c.max_abs_diff(&want) < 1e-6, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn simd_paths_match_scalar_oracle() {
        use crate::gemm::MicroCfg;
        // odd m, K, N: row remainders, strip tails, and partial tiles
        let (a, _, _, plan) = setup(33, 96, 80, 0.6, 16, 88);
        let scalar_cfg = TileConfig::new(16, 64).with_micro(MicroCfg::Scalar);
        let want = tw_matmul_with(&a, &plan, &scalar_cfg);
        let simd_cfg = TileConfig::new(16, 64).with_micro(MicroCfg::Simd { mr: 4, nr: 16 });
        let got = tw_matmul_with(&a, &plan, &simd_cfg);
        assert!(got.max_abs_diff(&want) < 1e-4, "strided simd vs scalar");
        // panel-fed serial form
        let r = micro::resolve(&simd_cfg);
        if r.is_simd() {
            let panels = tw_pack_panels(&plan, r.nr);
            let mut c = Matrix::zeros(a.rows, plan.n);
            let mut scratch = crate::gemm::GemmScratch::new();
            let ps = Some(panels.as_slice());
            tw_matmul_into_scratch_panels(&a, &plan, ps, &mut c, &simd_cfg, &mut scratch);
            assert!(c.max_abs_diff(&want) < 1e-4, "panel simd vs scalar");
        }
        // pooled form (disjoint-column scatter with the SIMD row step)
        let pool = crate::pool::ThreadPool::new(4);
        let mut c = Matrix::zeros(a.rows, plan.n);
        tw_matmul_parallel_into(&a, &plan, &mut c, &simd_cfg, 4, &pool);
        assert!(c.max_abs_diff(&want) < 1e-4, "pooled simd vs scalar");
    }

    #[test]
    fn fused_epilogue_matches_separate_passes_including_pruned_columns() {
        use crate::gemm::Act;
        let (a, w, tw, plan) = setup(19, 64, 48, 0.6, 16, 89);
        let (m, n) = (a.rows, plan.n);
        let mut rng = Rng::new(90);
        let bias: Vec<f32> = (0..n).map(|j| (j as f32 - 20.0) * 0.07).collect();
        let res = Matrix::randn(m, n, &mut rng);
        // unfused reference: masked-dense GEMM, then bias+relu, then residual
        let mut want = matmul_naive(&a, &tw.mask().apply(&w));
        for i in 0..m {
            for j in 0..n {
                let mut v = want.at(i, j) + bias[j];
                if v < 0.0 {
                    v = 0.0;
                }
                *want.at_mut(i, j) = v + res.at(i, j);
            }
        }
        let epi = Epilogue { bias: Some(&bias), act: Some(Act::Relu), residual: Some(&res) };
        let cfg = TileConfig::new(16, 64);
        let mut scratch = crate::gemm::GemmScratch::new();
        let mut c = Matrix::zeros(m, n);
        epi.prefill(&mut c); // pruned columns read act(bias) + residual
        tw_matmul_into_scratch_panels_epi(&a, &plan, None, &mut c, &cfg, &mut scratch, Some(&epi));
        assert!(c.max_abs_diff(&want) < 1e-3, "serial fused");
        let pool = crate::pool::ThreadPool::new(4);
        let mut cp = Matrix::zeros(m, n);
        epi.prefill(&mut cp);
        tw_matmul_parallel_into_epi(&a, &plan, &mut cp, &cfg, 4, &pool, Some(&epi));
        assert!(cp.max_abs_diff(&want) < 1e-3, "pooled fused");
    }

    #[test]
    fn into_variant_overwrites() {
        let (a, w, tw, plan) = setup(8, 32, 32, 0.5, 8, 84);
        let mut c = Matrix::zeros(8, 32);
        // poison kept columns; scatter must overwrite them
        for v in &mut c.data {
            *v = 123.0;
        }
        tw_matmul_into(&a, &plan, &mut c);
        let want = matmul_naive(&a, &tw.mask().apply(&w));
        let kept: std::collections::HashSet<usize> = tw.kept_cols.iter().copied().collect();
        for i in 0..8 {
            for j in 0..32 {
                if kept.contains(&j) {
                    assert!((c.at(i, j) - want.at(i, j)).abs() < 1e-3);
                }
            }
        }
    }
}
