//! The shared serving-variant vocabulary.
//!
//! Every layer of the serving stack used to pass `"model_tw"`-style
//! strings around (router policies, autotune keys, metrics labels,
//! telemetry), which made exhaustiveness unverifiable: a typo'd variant
//! string routed requests into `run()` errors at the worker, not at the
//! call site.  [`Variant`] is the typed replacement — the coordinator
//! speaks `Variant` end to end and converts to the executable's string
//! name (`Variant::name`) only at the `PreparedModel::run` seam, where
//! oracle variants (`"model_tw_oracle"`) and other compiled program
//! names legitimately extend past this enum.
//!
//! `Display`/`FromStr` round-trip the historical names so CLI flags and
//! JSON plan caches are unchanged: `"model_tw".parse::<Variant>()` and
//! the short CLI form `"tw"` both resolve to [`Variant::Tw`].

use crate::bail;
use crate::error::Error;
use std::fmt;
use std::str::FromStr;

/// A sparsity-pattern serving variant (one compiled program per model).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Unpruned baseline.
    Dense,
    /// Tile-wise (fused CTO) sparsity.
    Tw,
    /// Tile-vector-wise sparsity.
    Tvw,
    /// 2:4 structured sparsity.
    Vw24,
    /// Per-layer pattern selection from the autotune plan cache.
    Auto,
}

impl Variant {
    pub const ALL: [Variant; 5] =
        [Variant::Dense, Variant::Tw, Variant::Tvw, Variant::Vw24, Variant::Auto];

    /// The executable program name (`GraphProgram::variant` /
    /// `PreparedModel::run` key).
    pub fn name(self) -> &'static str {
        match self {
            Variant::Dense => "model_dense",
            Variant::Tw => "model_tw",
            Variant::Tvw => "model_tvw",
            Variant::Vw24 => "model_vw24",
            Variant::Auto => "model_auto",
        }
    }

    /// The short CLI label (`--policy tw`, zoo spec variant lists).
    pub fn short(self) -> &'static str {
        match self {
            Variant::Dense => "dense",
            Variant::Tw => "tw",
            Variant::Tvw => "tvw",
            Variant::Vw24 => "vw24",
            Variant::Auto => "auto",
        }
    }
}

impl fmt::Display for Variant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Variant {
    type Err = Error;

    /// Accepts both the program name (`"model_tw"`) and the short CLI
    /// form (`"tw"`).
    fn from_str(s: &str) -> Result<Variant, Error> {
        let stripped = s.strip_prefix("model_").unwrap_or(s);
        for v in Variant::ALL {
            if stripped == v.short() {
                return Ok(v);
            }
        }
        bail!("unknown variant {s:?} (expected one of dense/tw/tvw/vw24/auto)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_fromstr_round_trips_both_forms() {
        for v in Variant::ALL {
            assert_eq!(v.to_string().parse::<Variant>().unwrap(), v);
            assert_eq!(v.short().parse::<Variant>().unwrap(), v);
            assert_eq!(v.name(), format!("model_{}", v.short()));
        }
    }

    #[test]
    fn unknown_variant_is_an_error() {
        assert!("model_bogus".parse::<Variant>().is_err());
        assert!("".parse::<Variant>().is_err());
    }
}
