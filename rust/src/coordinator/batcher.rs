//! Dynamic batcher: size- and deadline-bounded request coalescing.
//!
//! The executable batch dimension B is an upper bound; the batcher's job
//! is to fill as much of B as possible without letting the head request
//! wait longer than `max_wait` — the classic serving trade-off
//! (throughput from batching vs p99 from waiting).  With a dynamic-batch
//! backend the real coalesced count flows through to execution (compute
//! proportional to real rows); `eager` additionally skips the
//! co-batching wait entirely when the queue is already drained — the
//! low-latency mode for partial-load serving.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::request::Request;

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Maximum executable batch size.
    pub max_batch: usize,
    /// Longest the head-of-line request may wait for co-batching.
    pub max_wait: Duration,
    /// Low-latency mode: dispatch immediately at partial fill when the
    /// queue is empty instead of waiting out `max_wait`.  Whatever is
    /// already queued still coalesces (the non-blocking drain below), so
    /// under saturation batches stay full; only the *speculative* wait
    /// for requests that have not arrived yet is skipped.  Pairs with
    /// `ServerConfig::dynamic_batch`: a partial batch then also costs
    /// partial compute.
    pub eager: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2), eager: false }
    }
}

impl BatcherConfig {
    /// The low-latency preset: same size bound, no speculative waiting.
    pub fn low_latency(max_batch: usize) -> BatcherConfig {
        BatcherConfig { max_batch, max_wait: Duration::ZERO, eager: true }
    }
}

/// One collected batch with its assembly timestamps, the raw material of
/// the queue/assembly stage spans: `first_recv` is taken right after the
/// head request arrives (closing its queue-wait span) and `assembled`
/// when the batch is handed to the worker (closing the assembly span).
pub struct CollectedBatch {
    pub requests: Vec<Request>,
    pub first_recv: Instant,
    pub assembled: Instant,
}

/// Collect the next batch from `rx`.  Blocks for the first request (or
/// returns `None` if the channel closed), drains whatever is already
/// queued without blocking, then — unless `cfg.eager` — keeps waiting
/// until the batch is full or the head request's deadline expires.
pub fn collect_batch(rx: &Receiver<Request>, cfg: &BatcherConfig) -> Option<Vec<Request>> {
    collect_batch_traced(rx, cfg).map(|b| b.requests)
}

/// [`collect_batch`] with the stage-tracing timestamps attached.
pub fn collect_batch_traced(rx: &Receiver<Request>, cfg: &BatcherConfig) -> Option<CollectedBatch> {
    let first = rx.recv().ok()?;
    let first_recv = Instant::now();
    let deadline = first_recv + cfg.max_wait;
    let mut batch = vec![first];
    // non-blocking drain of the backlog: everything already queued joins
    // this batch regardless of mode
    while batch.len() < cfg.max_batch {
        match rx.try_recv() {
            Ok(req) => batch.push(req),
            Err(_) => break,
        }
    }
    if cfg.eager {
        return Some(CollectedBatch { requests: batch, first_recv, assembled: Instant::now() });
    }
    while batch.len() < cfg.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(req) => batch.push(req),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(CollectedBatch { requests: batch, first_recv, assembled: Instant::now() })
}

/// Multi-worker variant: the worker pool shares one request channel, so
/// the receiver lives behind a mutex.  The lock is held for the *whole*
/// collection — batches stay contiguous (no interleaved stealing mid-
/// batch), and exactly one worker blocks in `recv` while the others
/// execute; on release the next idle worker takes over collection.  That
/// is the pipeline: collect(worker A) overlaps execute(workers B..).
/// Returns `None` on a closed channel or a poisoned lock (a worker
/// panicked mid-collect) so the caller can exit its loop.
pub fn collect_batch_shared(
    rx: &Mutex<Receiver<Request>>,
    cfg: &BatcherConfig,
) -> Option<Vec<Request>> {
    collect_batch_shared_traced(rx, cfg).map(|b| b.requests)
}

/// [`collect_batch_shared`] with the stage-tracing timestamps attached.
pub fn collect_batch_shared_traced(
    rx: &Mutex<Receiver<Request>>,
    cfg: &BatcherConfig,
) -> Option<CollectedBatch> {
    let guard = rx.lock().ok()?;
    collect_batch_traced(&guard, cfg)
}

/// Pack per-request activations into one batch tensor of `max_batch`
/// slots; missing slots are zero.  The padded path passes the model's
/// full B here; the dynamic path passes the real coalesced count, so the
/// tensor holds exactly the live rows and no padding is materialised.
pub fn pack_batch(batch: &[Request], max_batch: usize, per_request_len: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; max_batch * per_request_len];
    for (i, req) in batch.iter().enumerate().take(max_batch) {
        out[i * per_request_len..i * per_request_len + req.activation.len()]
            .copy_from_slice(&req.activation);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64, len: usize) -> (Request, super::super::request::ResponseStream) {
        let (tx, stream) = super::super::request::ResponseStream::channel();
        (
            Request {
                id,
                activation: vec![id as f32; len],
                variant: None,
                decode_steps: 0,
                submitted: Instant::now(),
                events: tx,
            },
            stream,
        )
    }

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, resp_rx) = req(i, 4);
            keep.push(resp_rx);
            tx.send(r).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(50), eager: false };
        let batch = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(batch.len(), 3);
        let batch2 = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(batch2.len(), 2);
    }

    #[test]
    fn deadline_bounds_waiting() {
        let (tx, rx) = mpsc::channel::<Request>();
        let (r, _resp) = req(1, 4);
        tx.send(r).unwrap();
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(10), eager: false };
        let start = Instant::now();
        let batch = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(start.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        assert!(collect_batch(&rx, &BatcherConfig::default()).is_none());
    }

    #[test]
    fn shared_receiver_collects_and_closes() {
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Mutex::new(rx);
        let mut keep = Vec::new();
        for i in 0..3 {
            let (r, resp_rx) = req(i, 4);
            keep.push(resp_rx);
            tx.send(r).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5), eager: false };
        let batch = collect_batch_shared(&rx, &cfg).unwrap();
        assert_eq!(batch.len(), 3);
        drop(tx);
        assert!(collect_batch_shared(&rx, &cfg).is_none());
    }

    #[test]
    fn eager_dispatches_partial_without_waiting() {
        // empty queue after the head request: eager mode returns at once
        // instead of sleeping out a long max_wait
        let (tx, rx) = mpsc::channel::<Request>();
        let (r, _resp) = req(1, 4);
        tx.send(r).unwrap();
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(250), eager: true };
        let start = Instant::now();
        let batch = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            start.elapsed() < Duration::from_millis(100),
            "eager collect must not wait out max_wait"
        );
    }

    #[test]
    fn eager_still_coalesces_queued_backlog() {
        // everything already in the queue joins the batch even in eager
        // mode — low latency never costs already-available coalescing
        let (tx, rx) = mpsc::channel::<Request>();
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, resp_rx) = req(i, 4);
            keep.push(resp_rx);
            tx.send(r).unwrap();
        }
        let cfg = BatcherConfig::low_latency(4);
        assert!(cfg.eager);
        let batch = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(batch.len(), 4, "size bound still applies");
        let batch2 = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(batch2.len(), 1);
    }

    #[test]
    fn traced_collection_timestamps_are_ordered() {
        let (tx, rx) = mpsc::channel::<Request>();
        let before = Instant::now();
        let (r, _resp) = req(1, 4);
        tx.send(r).unwrap();
        let cfg = BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(5), eager: false };
        let b = collect_batch_traced(&rx, &cfg).unwrap();
        assert_eq!(b.requests.len(), 1);
        // submitted <= first_recv <= assembled: the stage spans derived
        // from these never go negative
        assert!(b.first_recv >= b.requests[0].submitted);
        assert!(b.first_recv >= before);
        assert!(b.assembled >= b.first_recv);
    }

    #[test]
    fn pack_pads_with_zeros() {
        let (r1, _k1) = req(1, 3);
        let (r2, _k2) = req(2, 3);
        let packed = pack_batch(&[r1, r2], 4, 3);
        assert_eq!(packed.len(), 12);
        assert_eq!(&packed[0..3], &[1.0, 1.0, 1.0]);
        assert_eq!(&packed[3..6], &[2.0, 2.0, 2.0]);
        assert_eq!(&packed[6..], &[0.0; 6]);
    }
}
