//! Dynamic batcher: size- and deadline-bounded request coalescing.
//!
//! The executable has a fixed batch dimension B (AOT shapes are static),
//! so the batcher's job is to fill as much of B as possible without
//! letting the head request wait longer than `max_wait` — the classic
//! serving trade-off (throughput from batching vs p99 from waiting).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::request::Request;

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Fixed executable batch size (pad with zeros beyond real requests).
    pub max_batch: usize,
    /// Longest the head-of-line request may wait for co-batching.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Collect the next batch from `rx`.  Blocks for the first request (or
/// returns `None` if the channel closed), then drains until the batch is
/// full or the head request's deadline expires.
pub fn collect_batch(rx: &Receiver<Request>, cfg: &BatcherConfig) -> Option<Vec<Request>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + cfg.max_wait;
    let mut batch = vec![first];
    while batch.len() < cfg.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(req) => batch.push(req),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Multi-worker variant: the worker pool shares one request channel, so
/// the receiver lives behind a mutex.  The lock is held for the *whole*
/// collection — batches stay contiguous (no interleaved stealing mid-
/// batch), and exactly one worker blocks in `recv` while the others
/// execute; on release the next idle worker takes over collection.  That
/// is the pipeline: collect(worker A) overlaps execute(workers B..).
/// Returns `None` on a closed channel or a poisoned lock (a worker
/// panicked mid-collect) so the caller can exit its loop.
pub fn collect_batch_shared(
    rx: &Mutex<Receiver<Request>>,
    cfg: &BatcherConfig,
) -> Option<Vec<Request>> {
    let guard = rx.lock().ok()?;
    collect_batch(&guard, cfg)
}

/// Pack per-request activations into one padded batch tensor.
/// Returns the flat `(B, per_request_len)` tensor; missing slots are zero.
pub fn pack_batch(batch: &[Request], max_batch: usize, per_request_len: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; max_batch * per_request_len];
    for (i, req) in batch.iter().enumerate().take(max_batch) {
        out[i * per_request_len..i * per_request_len + req.activation.len()]
            .copy_from_slice(&req.activation);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64, len: usize) -> (Request, mpsc::Receiver<super::super::request::Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id,
                activation: vec![id as f32; len],
                variant: None,
                submitted: Instant::now(),
                respond_to: tx,
            },
            rx,
        )
    }

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, resp_rx) = req(i, 4);
            keep.push(resp_rx);
            tx.send(r).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(50) };
        let batch = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(batch.len(), 3);
        let batch2 = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(batch2.len(), 2);
    }

    #[test]
    fn deadline_bounds_waiting() {
        let (tx, rx) = mpsc::channel::<Request>();
        let (r, _resp) = req(1, 4);
        tx.send(r).unwrap();
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(10) };
        let start = Instant::now();
        let batch = collect_batch(&rx, &cfg).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(start.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        assert!(collect_batch(&rx, &BatcherConfig::default()).is_none());
    }

    #[test]
    fn shared_receiver_collects_and_closes() {
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Mutex::new(rx);
        let mut keep = Vec::new();
        for i in 0..3 {
            let (r, resp_rx) = req(i, 4);
            keep.push(resp_rx);
            tx.send(r).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) };
        let batch = collect_batch_shared(&rx, &cfg).unwrap();
        assert_eq!(batch.len(), 3);
        drop(tx);
        assert!(collect_batch_shared(&rx, &cfg).is_none());
    }

    #[test]
    fn pack_pads_with_zeros() {
        let (r1, _k1) = req(1, 3);
        let (r2, _k2) = req(2, 3);
        let packed = pack_batch(&[r1, r2], 4, 3);
        assert_eq!(packed.len(), 12);
        assert_eq!(&packed[0..3], &[1.0, 1.0, 1.0]);
        assert_eq!(&packed[3..6], &[2.0, 2.0, 2.0]);
        assert_eq!(&packed[6..], &[0.0; 6]);
    }
}
