//! Request/response types flowing through the serving stack.

use std::sync::mpsc;
use std::time::Instant;

/// One inference request: a single sequence's activations `(seq, d_model)`
/// flattened row-major.  The dynamic batcher packs up to `batch` of these
/// into one executable invocation.
pub struct Request {
    pub id: u64,
    pub activation: Vec<f32>,
    /// Preferred model variant ("model_dense" / "model_tw" / "model_tvw");
    /// `None` lets the router decide.
    pub variant: Option<String>,
    pub submitted: Instant,
    pub respond_to: mpsc::Sender<Response>,
}

/// The answer: per-sequence logits plus serving telemetry.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Per-request logits; empty when `error` is set.
    pub logits: Vec<f32>,
    /// Which executable served this request.
    pub variant: String,
    /// Time spent waiting in the queue + batcher, seconds.
    pub queue_secs: f64,
    /// Executable invocation time (shared by the whole batch), seconds.
    pub execute_secs: f64,
    /// How many real requests shared the batch (the coalesced size, not
    /// this request's position in it).
    pub batch_size: usize,
    /// Set when the execute failed: the whole batch gets an explicit
    /// error response instead of a silently dropped channel.
    pub error: Option<String>,
}

impl Response {
    pub fn total_secs(&self) -> f64 {
        self.queue_secs + self.execute_secs
    }

    /// True when the request was served (no execute error).
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}
