//! Request/response types flowing through the serving stack: the
//! session-oriented streaming surface.
//!
//! Every submission — one-shot forward or autoregressive decode — is a
//! *stream*: the worker pushes zero or more [`StreamEvent::Token`]s
//! (one per decode step) and terminates with exactly one
//! [`StreamEvent::Done`] (carrying the final [`Response`]) or
//! [`StreamEvent::Error`].  A one-shot forward is simply a single-`Done`
//! stream, so the historical `submit → recv` call sites migrate to
//! `submit → wait` mechanically.

use std::sync::mpsc;
use std::time::Instant;

use crate::bail;
use crate::variant::Variant;

/// One inference request: a single sequence's activations `(seq, d_model)`
/// flattened row-major.  For decode submissions (`decode_steps > 0`) the
/// activation is the prompt, consumed one `(d_model)` row per step.
pub struct Request {
    pub id: u64,
    pub activation: Vec<f32>,
    /// Preferred model variant; `None` lets the router decide.
    pub variant: Option<Variant>,
    /// Number of tokens to generate *after* the prompt is consumed.
    /// `0` requests a one-shot forward over the full activation.
    pub decode_steps: usize,
    pub submitted: Instant,
    /// Event sink for this request's stream.  Send failures mean the
    /// client dropped its [`ResponseStream`]; workers ignore them.
    pub events: mpsc::Sender<StreamEvent>,
}

impl Request {
    /// True when this request wants streaming decode rather than a
    /// one-shot forward.
    pub fn is_decode(&self) -> bool {
        self.decode_steps > 0
    }
}

/// One streamed decode step: the logits produced at this step and the
/// greedy token derived from them.  Steps that consume prompt rows are
/// streamed too — the event at the last prompt step carries the logits a
/// one-shot forward of the same prompt would return.
#[derive(Clone, Debug)]
pub struct TokenEvent {
    pub id: u64,
    /// Workspace slot this request occupied when the step ran.
    pub slot: usize,
    /// 0-based step index within this request's lifetime.
    pub step: usize,
    /// argmax of `logits`.
    pub token: usize,
    pub logits: Vec<f32>,
}

/// One element of a [`ResponseStream`].
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// A decode step completed for this request.
    Token(TokenEvent),
    /// Terminal: the request finished; carries the final [`Response`].
    Done(Response),
    /// Terminal: the request failed (shed, rejected, or execute error).
    Error(String),
}

/// The final answer: per-sequence logits plus serving telemetry.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Per-request logits (for decode: the last step's logits).
    pub logits: Vec<f32>,
    /// Which executable served this request.
    pub variant: String,
    /// Time spent waiting for the batcher's first receive, seconds.
    pub queue_secs: f64,
    /// Batch assembly window (drain + wait), seconds.
    pub assembly_secs: f64,
    /// Routing + activation packing, seconds.
    pub pack_secs: f64,
    /// Executable invocation time (for decode: summed step time), seconds.
    pub execute_secs: f64,
    /// How many real requests shared the batch (for decode: the mean
    /// in-flight slot count over this request's steps, rounded).
    pub batch_size: usize,
    /// Decode steps streamed before `Done` (0 for one-shot forwards).
    pub tokens: usize,
}

impl Response {
    /// End-to-end seconds as the coordinator observed them: every stage
    /// of the request pipeline, matching `RequestTrace::total()` up to
    /// the respond span (which ends after this response is sent, so it
    /// cannot be part of it).  Historically this omitted assembly+pack,
    /// under-reporting latency versus the stage histograms.
    pub fn total_secs(&self) -> f64 {
        self.queue_secs + self.assembly_secs + self.pack_secs + self.execute_secs
    }
}

/// Iterator over one request's [`StreamEvent`]s.  Ends after the
/// terminal `Done`/`Error` event (or when the server drops the sender).
pub struct ResponseStream {
    rx: mpsc::Receiver<StreamEvent>,
    terminated: bool,
}

impl ResponseStream {
    /// A stream plus its sending half; the coordinator keeps the sender
    /// on the [`Request`] and hands the stream to the caller.
    pub fn channel() -> (mpsc::Sender<StreamEvent>, ResponseStream) {
        let (tx, rx) = mpsc::channel();
        (tx, ResponseStream { rx, terminated: false })
    }

    /// Block until the terminal event, discarding intermediate tokens:
    /// the one-shot ergonomic (`submit(..).wait()?`).
    pub fn wait(self) -> crate::error::Result<Response> {
        for ev in self {
            match ev {
                StreamEvent::Token(_) => {}
                StreamEvent::Done(resp) => return Ok(resp),
                StreamEvent::Error(msg) => bail!("{msg}"),
            }
        }
        bail!("response stream closed before completion")
    }
}

impl Iterator for ResponseStream {
    type Item = StreamEvent;

    fn next(&mut self) -> Option<StreamEvent> {
        if self.terminated {
            return None;
        }
        match self.rx.recv() {
            Ok(ev) => {
                if matches!(ev, StreamEvent::Done(_) | StreamEvent::Error(_)) {
                    self.terminated = true;
                }
                Some(ev)
            }
            Err(_) => {
                self.terminated = true;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::RequestTrace;

    fn resp(q: f64, a: f64, p: f64, e: f64) -> Response {
        Response {
            id: 1,
            logits: vec![0.0],
            variant: "model_tw".into(),
            queue_secs: q,
            assembly_secs: a,
            pack_secs: p,
            execute_secs: e,
            batch_size: 1,
            tokens: 0,
        }
    }

    #[test]
    fn total_secs_includes_every_stage() {
        // regression: total_secs used to be queue + execute only, so a
        // response disagreed with its own RequestTrace by assembly+pack
        let r = resp(0.5, 0.25, 0.125, 2.0);
        let trace = RequestTrace {
            queue: 0.5,
            assembly: 0.25,
            pack: 0.125,
            execute: 2.0,
            respond: 0.0,
        };
        assert!((r.total_secs() - trace.total()).abs() < 1e-12);
        assert!((r.total_secs() - 2.875).abs() < 1e-12);
    }

    #[test]
    fn stream_yields_tokens_then_terminates_on_done() {
        let (tx, stream) = ResponseStream::channel();
        tx.send(StreamEvent::Token(TokenEvent {
            id: 1,
            slot: 0,
            step: 0,
            token: 3,
            logits: vec![0.0, 0.0, 0.0, 1.0],
        }))
        .unwrap();
        tx.send(StreamEvent::Done(resp(0.0, 0.0, 0.0, 0.0))).unwrap();
        // events after the terminal must never be yielded
        tx.send(StreamEvent::Error("late".into())).unwrap();
        let events: Vec<StreamEvent> = stream.collect();
        assert_eq!(events.len(), 2);
        assert!(matches!(events[0], StreamEvent::Token(ref t) if t.token == 3));
        assert!(matches!(events[1], StreamEvent::Done(_)));
    }

    #[test]
    fn wait_surfaces_errors_and_dropped_channels() {
        let (tx, stream) = ResponseStream::channel();
        tx.send(StreamEvent::Error("execute failed: model_bogus".into())).unwrap();
        let err = stream.wait().unwrap_err().to_string();
        assert!(err.contains("model_bogus"), "{err}");

        let (tx, stream) = ResponseStream::channel();
        drop(tx);
        assert!(stream.wait().is_err());
    }
}
