//! The serving loop: an executor thread owning the PJRT engine, fed by a
//! request channel through the dynamic batcher and the router.
//!
//! Python never appears here — artifacts were compiled once by `make
//! artifacts`; this loop is allocation-light and lock-free on the hot path
//! (one channel recv, one buffer staging, one execute).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use super::batcher::{collect_batch, pack_batch, BatcherConfig};
use super::metrics::Metrics;
use super::request::{Request, Response};
use super::router::{Policy, Router};
use crate::autotune::PlanCache;
use crate::error::Result;
use crate::runtime::Engine;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub policy: Policy,
    /// Which executables to load ("model_*" entries in meta.json).
    pub variants: Vec<String>,
    /// Backpressure: submissions beyond this queue depth are shed
    /// immediately instead of growing the tail (0 = unbounded).
    pub max_queue: usize,
    /// Autotuner plan cache (`tilewise autotune --out ...`) loaded at
    /// startup; `Policy::Tuned` resolves its serving variant from it.
    /// An unreadable or stale cache degrades to no cache with a warning.
    pub plan_cache: Option<PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            policy: Policy::Fixed("model_tw".into()),
            variants: vec!["model_dense".into(), "model_tw".into(), "model_tvw".into()],
            max_queue: 0,
            plan_cache: None,
        }
    }
}

/// Client handle: submit requests, read metrics, shut down.
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
    pub metrics: Arc<Metrics>,
    /// The tuned plan cache the server loaded at startup, if any.
    pub plan_cache: Option<Arc<PlanCache>>,
    next_id: AtomicU64,
    queue_depth: Arc<AtomicUsize>,
    join: Option<std::thread::JoinHandle<()>>,
    max_queue: usize,
    pub seq: usize,
    pub d_model: usize,
    pub batch: usize,
    pub n_classes: usize,
}

impl ServerHandle {
    /// Number of requests shed by backpressure so far (also visible in
    /// `Metrics::full_snapshot`).
    pub fn shed_count(&self) -> u64 {
        self.metrics.sheds()
    }

    /// Submit with backpressure: sheds (returns None) when the queue is
    /// beyond `max_queue`.
    pub fn try_submit(
        &self,
        activation: Vec<f32>,
        variant: Option<String>,
    ) -> Option<mpsc::Receiver<Response>> {
        if self.max_queue > 0 && self.queue_depth.load(Ordering::Relaxed) >= self.max_queue {
            self.metrics.record_shed();
            return None;
        }
        Some(self.submit(activation, variant))
    }

    /// Submit one sequence's activations; returns the response receiver.
    pub fn submit(&self, activation: Vec<f32>, variant: Option<String>) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            activation,
            variant,
            submitted: Instant::now(),
            respond_to: tx,
        };
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
        // a closed channel means the server already shut down; the caller
        // sees it as a dropped response channel
        let _ = self.tx.send(req);
        rx
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, activation: Vec<f32>, variant: Option<String>) -> Result<Response> {
        let rx = self.submit(activation, variant);
        Ok(rx.recv()?)
    }

    /// Graceful shutdown: close the request channel and join the executor.
    /// (Equivalent to dropping the handle; provided for explicitness.)
    pub fn shutdown(self) {}
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        // Closing tx ends collect_batch -> executor exits.
        let (dead_tx, _) = mpsc::channel();
        self.tx = dead_tx;
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Start the serving stack over an artifact directory.
///
/// The PJRT engine is not `Send` (it wraps `Rc` handles), so it is created
/// *inside* the executor thread; startup results are handed back over a
/// one-shot channel.
pub fn start(artifact_dir: &Path, cfg: ServerConfig) -> Result<ServerHandle> {
    let (tx, rx) = mpsc::channel::<Request>();
    let metrics = Arc::new(Metrics::default());
    let queue_depth = Arc::new(AtomicUsize::new(0));
    let (init_tx, init_rx) = mpsc::channel::<Result<(usize, usize, usize, usize)>>();

    // tuned plan cache: loaded once at startup; Policy::Tuned resolves
    // against it before the executor thread spins up
    let plan_cache: Option<Arc<PlanCache>> = cfg.plan_cache.as_ref().and_then(|path| {
        match PlanCache::load(path) {
            Ok(c) => Some(Arc::new(c)),
            Err(e) => {
                eprintln!("[server] plan cache {}: {e} (serving untuned)", path.display());
                None
            }
        }
    });
    let policy = cfg.policy.clone().resolve(plan_cache.as_deref());

    let metrics2 = metrics.clone();
    let queue_depth2 = queue_depth.clone();
    let batcher_cfg = cfg.batcher.clone();
    let variants = cfg.variants.clone();
    let dir = artifact_dir.to_path_buf();
    let join = std::thread::Builder::new()
        .name("tilewise-executor".into())
        .spawn(move || {
            let variant_refs: Vec<&str> = variants.iter().map(String::as_str).collect();
            let engine = match Engine::load_only(&dir, &variant_refs) {
                Ok(e) => e,
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                    return;
                }
            };
            let (batch, n_classes) = match engine.model(&variants[0]) {
                Ok(m) => (m.output_shape[0], m.output_shape[1]),
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                    return;
                }
            };
            let (seq, d_model) = (engine.meta.seq, engine.meta.d_model);
            let per_request_len = seq * d_model;
            let _ = init_tx.send(Ok((batch, n_classes, seq, d_model)));
            // never collect more requests than the executable batch holds —
            // overflow requests would silently get no response
            let mut batcher_cfg = batcher_cfg;
            batcher_cfg.max_batch = batcher_cfg.max_batch.min(batch).max(1);
            let mut router = Router::new(policy);
            while let Some(batch_reqs) = collect_batch(&rx, &batcher_cfg) {
                let depth = queue_depth2.load(Ordering::Relaxed).saturating_sub(batch_reqs.len());
                let variant = router.route(&batch_reqs, depth);
                let packed = pack_batch(&batch_reqs, batch, per_request_len);
                let t0 = Instant::now();
                let result = engine.run_named(&variant, &packed);
                let exec_secs = t0.elapsed().as_secs_f64();
                queue_depth2.fetch_sub(batch_reqs.len().min(batch), Ordering::Relaxed);
                match result {
                    Ok(logits) => {
                        for (i, req) in batch_reqs.into_iter().enumerate().take(batch) {
                            let queue_secs =
                                (t0 - req.submitted).as_secs_f64().max(0.0);
                            metrics2.record(&variant, queue_secs + exec_secs, i + 1);
                            let _ = req.respond_to.send(Response {
                                id: req.id,
                                logits: logits[i * n_classes..(i + 1) * n_classes].to_vec(),
                                variant: variant.clone(),
                                queue_secs,
                                execute_secs: exec_secs,
                                batch_size: i + 1,
                            });
                        }
                    }
                    Err(e) => {
                        eprintln!("[server] execute failed: {e:#}");
                        // responses dropped: clients see a closed channel
                    }
                }
            }
        })?;

    let (batch, n_classes, seq, d_model) = init_rx.recv()??;
    Ok(ServerHandle {
        tx,
        metrics,
        plan_cache,
        next_id: AtomicU64::new(0),
        queue_depth,
        join: Some(join),
        max_queue: cfg.max_queue,
        seq,
        d_model,
        batch,
        n_classes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("meta.json").exists().then_some(dir)
    }

    #[test]
    fn serve_roundtrip_all_variants() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let handle = start(&dir, ServerConfig::default()).unwrap();
        let len = handle.seq * handle.d_model;
        let mut rng = crate::util::Rng::new(8);
        for variant in ["model_dense", "model_tw", "model_tvw"] {
            let x: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let resp = handle.infer(x, Some(variant.into())).unwrap();
            assert_eq!(resp.variant, variant);
            assert_eq!(resp.logits.len(), handle.n_classes);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
        }
        assert_eq!(handle.metrics.completed(), 3);
    }

    #[test]
    fn backpressure_sheds_over_limit() {
        let Some(dir) = artifacts_dir() else { return };
        let cfg = ServerConfig { max_queue: 2, ..Default::default() };
        let handle = start(&dir, cfg).unwrap();
        let len = handle.seq * handle.d_model;
        let mut kept = Vec::new();
        let mut shed = 0;
        for _ in 0..32 {
            match handle.try_submit(vec![0.1; len], None) {
                Some(rx) => kept.push(rx),
                None => shed += 1,
            }
        }
        assert!(shed > 0, "expected some sheds with max_queue=2");
        assert_eq!(handle.shed_count(), shed);
        for rx in kept {
            let _ = rx.recv();
        }
    }

    #[test]
    fn batching_coalesces_concurrent_requests() {
        let Some(dir) = artifacts_dir() else { return };
        let cfg = ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: std::time::Duration::from_millis(50) },
            ..Default::default()
        };
        let handle = start(&dir, cfg).unwrap();
        let len = handle.seq * handle.d_model;
        let rxs: Vec<_> = (0..4).map(|_| handle.submit(vec![0.1; len], None)).collect();
        let resps: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        // all four should have shared one executable invocation
        let max_batch_seen = resps.iter().map(|r| r.batch_size).max().unwrap();
        assert!(max_batch_seen >= 4, "batch {max_batch_seen}");
    }
}
