//! The serving loop: a pool of worker threads sharing one request channel
//! through the dynamic batcher and the router, each worker owning its own
//! backend-loaded model — plus the two specialised lanes of the streaming
//! API:
//!
//! - the **fast lane** (`ServerConfig::fast_lane`): one dedicated worker
//!   on its own channel with an M=1 eager batcher, bypassing the
//!   co-batching wait entirely for latency-critical one-shot requests
//!   ([`ServerHandle::submit_fast`]);
//! - the **decode lane**: one dedicated worker running the continuous-
//!   batching step scheduler — autoregressive sessions join and leave the
//!   in-flight slot set at *step boundaries* (Orca-style), each streaming
//!   [`StreamEvent::Token`]s as it goes ([`ServerHandle::submit_decode`]).
//!
//! The one-shot hot path stays allocation-light and contention-light: one
//! shared-channel batch collection (exactly one worker blocks in `recv`
//! while the others execute — that lock *is* the pipeline), one buffer
//! staging, one execute.  Which kernels run is the backend's business
//! ([`crate::exec::Backend`]): the PJRT artifact engine, or the native
//! in-process backend that packs weights once and runs the paper's
//! TW/TVW/2:4 CPU kernels with no artifacts at all.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::batcher::{collect_batch_shared_traced, pack_batch, BatcherConfig, CollectedBatch};
use super::metrics::Metrics;
use super::request::{Request, Response, ResponseStream, StreamEvent, TokenEvent};
use super::router::{Policy, Router};
use crate::autotune::PlanCache;
use crate::error::Result;
use crate::exec::{Backend, DecodeCaps, ModelDims, PjrtBackend, PreparedModel};
use crate::pool::{LaneStats, ThreadPool};
use crate::telemetry::RequestTrace;
use crate::variant::Variant;
use crate::{anyhow, ensure};

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub policy: Policy,
    /// Which executables to load ("model_*" entries in meta.json).
    pub variants: Vec<Variant>,
    /// Backpressure: submissions beyond this queue depth are shed
    /// immediately instead of growing the tail (0 = unbounded).
    pub max_queue: usize,
    /// Autotuner plan cache (`tilewise autotune --out ...`) loaded at
    /// startup; `Policy::Tuned` resolves its serving variant from it.
    /// An unreadable or stale cache degrades to no cache with a warning.
    pub plan_cache: Option<PathBuf>,
    /// Worker threads sharing the request channel.  Each owns one model
    /// instance loaded from the backend (clamped to >= 1).
    pub workers: usize,
    /// Intra-op kernel parallelism: lanes of ONE pool shared by every
    /// worker's GEMM kernels (`crate::pool`), composing with the
    /// inter-request `workers` pool.  Each submitting worker is itself a
    /// lane of its own job, so concurrent kernel threads are bounded by
    /// `workers + intra_threads - 1`; size that sum near the core count
    /// (DESIGN.md §5).  `<= 1` keeps the kernels serial (the historical
    /// behaviour).
    pub intra_threads: usize,
    /// Dynamic effective-batch execution (DESIGN.md §7): pack and run
    /// only the real coalesced requests (`PreparedModel::run_batch`)
    /// instead of zero-padding to the model's full batch.  Numerically
    /// identical on every backend — models that don't advertise
    /// `supports_dynamic_batch` (the static-shape PJRT artifacts) keep
    /// the historical full-B pack + `run` — and strictly cheaper on
    /// dynamic ones (graph/native), where a half-full batch costs half
    /// the compute.  `false` restores the historical padded path
    /// everywhere (the A/B baseline `benches/serving_throughput.rs`
    /// measures against).
    pub dynamic_batch: bool,
    /// Spawn the M=1 low-latency fast lane: a dedicated worker on its own
    /// channel with an eager single-request batcher, reached via
    /// [`ServerHandle::submit_fast`].  Without it `submit_fast` degrades
    /// to the normal batched path.
    pub fast_lane: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            policy: Policy::Fixed(Variant::Tw),
            variants: vec![Variant::Dense, Variant::Tw, Variant::Tvw],
            max_queue: 0,
            plan_cache: None,
            workers: 1,
            intra_threads: 1,
            dynamic_batch: true,
            fast_lane: false,
        }
    }
}

impl ServerConfig {
    /// Start from the defaults and override field by field.
    pub fn builder() -> ServerConfigBuilder {
        ServerConfigBuilder { cfg: ServerConfig::default() }
    }

    /// Throughput preset: a deeper batch window and a second worker so
    /// collection overlaps execution — the saturation-serving shape.
    pub fn throughput() -> ServerConfigBuilder {
        ServerConfig::builder()
            .workers(2)
            .max_batch(16)
            .max_wait(Duration::from_millis(4))
            .dynamic_batch(true)
    }

    /// Low-latency preset: eager dispatch (no speculative co-batching
    /// wait) plus the dedicated M=1 fast lane.
    pub fn low_latency() -> ServerConfigBuilder {
        ServerConfig::builder()
            .batcher(BatcherConfig::low_latency(8))
            .fast_lane(true)
            .dynamic_batch(true)
    }
}

/// Builder for [`ServerConfig`] with validation at
/// [`ServerConfigBuilder::build`] — the misconfigurations that used to
/// surface as runtime panics or silent starvation (a zero-worker pool, an
/// empty round-robin rotation, a zero-size batch) are rejected up front.
#[derive(Clone, Debug)]
pub struct ServerConfigBuilder {
    cfg: ServerConfig,
}

impl ServerConfigBuilder {
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }

    pub fn intra_threads(mut self, n: usize) -> Self {
        self.cfg.intra_threads = n;
        self
    }

    pub fn max_queue(mut self, n: usize) -> Self {
        self.cfg.max_queue = n;
        self
    }

    pub fn policy(mut self, policy: Policy) -> Self {
        self.cfg.policy = policy;
        self
    }

    pub fn variants(mut self, variants: Vec<Variant>) -> Self {
        self.cfg.variants = variants;
        self
    }

    pub fn plan_cache(mut self, path: PathBuf) -> Self {
        self.cfg.plan_cache = Some(path);
        self
    }

    pub fn batcher(mut self, batcher: BatcherConfig) -> Self {
        self.cfg.batcher = batcher;
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.batcher.max_batch = n;
        self
    }

    pub fn max_wait(mut self, wait: Duration) -> Self {
        self.cfg.batcher.max_wait = wait;
        self
    }

    pub fn eager(mut self, eager: bool) -> Self {
        self.cfg.batcher.eager = eager;
        self
    }

    pub fn dynamic_batch(mut self, on: bool) -> Self {
        self.cfg.dynamic_batch = on;
        self
    }

    pub fn fast_lane(mut self, on: bool) -> Self {
        self.cfg.fast_lane = on;
        self
    }

    /// Validate and produce the config.
    pub fn build(self) -> Result<ServerConfig> {
        let cfg = self.cfg;
        ensure!(cfg.workers >= 1, "server config: the worker pool needs at least one worker");
        ensure!(cfg.intra_threads >= 1, "server config: intra_threads must be >= 1");
        ensure!(cfg.batcher.max_batch >= 1, "server config: max_batch must be >= 1");
        ensure!(!cfg.variants.is_empty(), "server config: at least one variant must be loaded");
        if let Policy::RoundRobin(vs) = &cfg.policy {
            ensure!(!vs.is_empty(), "server config: a round-robin rotation cannot be empty");
        }
        if let Policy::Adaptive { dense, sparse, .. } = &cfg.policy {
            ensure!(
                dense != sparse,
                "server config: adaptive policy needs two distinct variants (got {dense} twice)"
            );
        }
        Ok(cfg)
    }
}

/// Client handle: submit requests (batched, fast-lane, or streaming
/// decode), read metrics, shut down.
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
    /// Dedicated M=1 channel (`Some` iff `cfg.fast_lane`).
    fast_tx: Option<mpsc::Sender<Request>>,
    /// The decode lane's channel (always spawned; the lane answers with
    /// an error stream when the model is one-shot only).
    decode_tx: mpsc::Sender<Request>,
    pub metrics: Arc<Metrics>,
    /// The tuned plan cache the server loaded at startup, if any.
    pub plan_cache: Option<Arc<PlanCache>>,
    next_id: AtomicU64,
    queue_depth: Arc<AtomicUsize>,
    joins: Vec<std::thread::JoinHandle<()>>,
    max_queue: usize,
    /// The shared intra-op kernel pool, kept for lane telemetry
    /// (`None` when `intra_threads <= 1`).
    intra: Option<Arc<ThreadPool>>,
    /// How many pool workers serve the shared channel (the fast and
    /// decode lanes not included).
    pub workers: usize,
    pub seq: usize,
    pub d_model: usize,
    pub batch: usize,
    pub n_classes: usize,
    /// Streaming-decode capability of the loaded model (`None` = the
    /// backend is one-shot only and `submit_decode` returns error
    /// streams).
    pub decode_caps: Option<DecodeCaps>,
}

impl ServerHandle {
    /// Number of requests shed by backpressure so far (also visible in
    /// `Metrics::full_snapshot`).
    pub fn shed_count(&self) -> u64 {
        self.metrics.sheds()
    }

    /// Per-lane busy/idle split of the shared intra-op kernel pool, when
    /// one exists (`intra_threads > 1`): lane 0 folds the submitting
    /// serving workers together, lanes 1.. are the pinned pool workers.
    pub fn intra_lane_stats(&self) -> Option<Vec<LaneStats>> {
        self.intra.as_ref().map(|p| p.lane_stats())
    }

    /// Submit with backpressure: sheds (returns `None`) when the queue is
    /// beyond `max_queue`.
    pub fn try_submit(
        &self,
        activation: Vec<f32>,
        variant: Option<Variant>,
    ) -> Option<ResponseStream> {
        if self.max_queue > 0 && self.queue_depth.load(Ordering::Relaxed) >= self.max_queue {
            self.metrics.record_shed();
            return None;
        }
        Some(self.submit(activation, variant))
    }

    /// Submit one sequence's activations; returns the event stream (a
    /// one-shot forward is a single-`Done` stream, so
    /// `submit(..).wait()` is the blocking ergonomic).
    ///
    /// An activation longer than the model's per-request capacity
    /// (`seq * d_model`) is rejected here with a terminal
    /// [`StreamEvent::Error`] (counted in `Metrics::errors`) — it could
    /// never be served, and letting it reach `pack_batch` used to panic
    /// the worker thread mid-batch.  Shorter activations remain accepted
    /// and zero-padded, as ever.
    pub fn submit(&self, activation: Vec<f32>, variant: Option<Variant>) -> ResponseStream {
        self.submit_to(&self.tx, activation, variant)
    }

    /// Submit on the M=1 low-latency fast lane, bypassing the batcher's
    /// co-batching wait entirely.  Degrades to the normal batched path
    /// when the server was started without `fast_lane`.
    pub fn submit_fast(&self, activation: Vec<f32>, variant: Option<Variant>) -> ResponseStream {
        let lane = self.fast_tx.as_ref().unwrap_or(&self.tx);
        self.submit_to(lane, activation, variant)
    }

    fn submit_to(
        &self,
        lane: &mpsc::Sender<Request>,
        activation: Vec<f32>,
        variant: Option<Variant>,
    ) -> ResponseStream {
        let (tx, stream) = ResponseStream::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let per_request_len = self.seq * self.d_model;
        if activation.len() > per_request_len {
            self.metrics.record_error();
            let _ = tx.send(StreamEvent::Error(format!(
                "activation has {} floats, exceeding the model's per-request \
                 capacity {per_request_len} (seq {} x d_model {})",
                activation.len(),
                self.seq,
                self.d_model
            )));
            return stream;
        }
        let req = Request {
            id,
            activation,
            variant,
            decode_steps: 0,
            submitted: Instant::now(),
            events: tx,
        };
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
        // a closed channel means the server already shut down; the caller
        // sees it as a closed stream
        let _ = lane.send(req);
        stream
    }

    /// Open a streaming decode session: the prompt (`prompt.len()` a
    /// positive multiple of `DecodeCaps::d_in`) is consumed one row per
    /// step, then `max_new_tokens` tokens are generated by greedy
    /// feedback — every step streams a [`StreamEvent::Token`], and the
    /// terminal `Done` carries the last step's logits.  The session joins
    /// the in-flight batch at the next step boundary with a free slot
    /// (continuous batching) and leaves the moment its last token is out.
    pub fn submit_decode(
        &self,
        prompt: Vec<f32>,
        variant: Option<Variant>,
        max_new_tokens: usize,
    ) -> ResponseStream {
        let (tx, stream) = ResponseStream::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let Some(caps) = self.decode_caps else {
            self.metrics.record_error();
            let _ = tx.send(StreamEvent::Error(
                "streaming decode unavailable: the loaded model is one-shot only".into(),
            ));
            return stream;
        };
        if max_new_tokens == 0 {
            self.metrics.record_error();
            let _ = tx.send(StreamEvent::Error(
                "streaming decode needs max_new_tokens >= 1 (use submit for one-shot)".into(),
            ));
            return stream;
        }
        if prompt.is_empty()
            || prompt.len() % caps.d_in != 0
            || prompt.len() / caps.d_in + max_new_tokens > caps.max_steps
        {
            self.metrics.record_error();
            let _ = tx.send(StreamEvent::Error(format!(
                "decode prompt of {} floats + {max_new_tokens} new tokens does not fit \
                 the slot shape (d_in {}, max_steps {})",
                prompt.len(),
                caps.d_in,
                caps.max_steps
            )));
            return stream;
        }
        let req = Request {
            id,
            activation: prompt,
            variant,
            decode_steps: max_new_tokens,
            submitted: Instant::now(),
            events: tx,
        };
        let _ = self.decode_tx.send(req);
        stream
    }

    /// Blocking convenience: submit and wait for the terminal response.
    pub fn infer(&self, activation: Vec<f32>, variant: Option<Variant>) -> Result<Response> {
        self.submit(activation, variant).wait()
    }

    /// Graceful shutdown: close the request channels and join the workers.
    /// (Equivalent to dropping the handle; provided for explicitness.)
    pub fn shutdown(self) {}
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        // Closing every lane ends collect_batch / the decode intake on
        // every worker -> the pool drains; resident decode sessions still
        // run to completion before their lane exits.
        let (dead_tx, _) = mpsc::channel();
        self.tx = dead_tx;
        if let Some(fast) = self.fast_tx.as_mut() {
            let (dead_tx, _) = mpsc::channel();
            *fast = dead_tx;
        }
        let (dead_tx, _) = mpsc::channel();
        self.decode_tx = dead_tx;
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// Start the serving stack over an artifact directory (the PJRT backend —
/// kept as the historical entry point; degrades at startup when the
/// `pjrt` feature or the artifacts are missing).
pub fn start(artifact_dir: &Path, cfg: ServerConfig) -> Result<ServerHandle> {
    let names: Vec<String> = cfg.variants.iter().map(|v| v.name().to_string()).collect();
    let backend = Arc::new(PjrtBackend::new(artifact_dir, &names));
    start_with_backend(backend, cfg)
}

/// Shared per-lane context for [`worker_loop`].
struct WorkerCtx {
    metrics: Arc<Metrics>,
    queue_depth: Arc<AtomicUsize>,
    dynamic_batch: bool,
    wid: usize,
}

/// One lane of the one-shot serving pool: collect a batch, route it,
/// pack it, execute, stream every request its terminal event.  Both the
/// shared pool workers and the M=1 fast lane run this loop — they differ
/// only in channel and batcher config.
fn worker_loop(
    rx: &Mutex<mpsc::Receiver<Request>>,
    cfg: &BatcherConfig,
    model: &mut dyn PreparedModel,
    router: &mut Router,
    ctx: &WorkerCtx,
) {
    let dims = model.dims();
    // static-shape models (PJRT) would only re-pad a partial pack
    // internally — give them the single full-B pack instead (same
    // numerics, one allocation)
    let dynamic_batch = ctx.dynamic_batch && model.supports_dynamic_batch();
    let per_request_len = dims.per_request_len();
    let n_classes = dims.n_classes;
    while let Some(CollectedBatch { requests: batch_reqs, first_recv, assembled }) =
        collect_batch_shared_traced(rx, cfg)
    {
        // the true coalesced size every response reports
        let real = batch_reqs.len().min(dims.batch);
        let depth = ctx.queue_depth.load(Ordering::Relaxed).saturating_sub(batch_reqs.len());
        let variant = router.route(&batch_reqs, depth);
        let vname = variant.name();
        // dynamic effective batch: pack and execute only the real
        // coalesced rows — the padded path packs (and computes) the full
        // B as it always did
        let t0;
        let result = if dynamic_batch {
            let packed = pack_batch(&batch_reqs, real, per_request_len);
            t0 = Instant::now();
            model.run_batch(vname, &packed, real)
        } else {
            let packed = pack_batch(&batch_reqs, dims.batch, per_request_len);
            t0 = Instant::now();
            model.run(vname, &packed)
        };
        let exec_secs = t0.elapsed().as_secs_f64();
        ctx.queue_depth.fetch_sub(batch_reqs.len(), Ordering::Relaxed);
        match result {
            Ok(logits) => {
                ctx.metrics.record_batch(vname, real, dims.batch, dynamic_batch);
                for (i, req) in batch_reqs.into_iter().enumerate().take(dims.batch) {
                    // stage decomposition: queue-wait ends at the head
                    // recv, assembly at batch handoff, pack at execute
                    // start; saturating math keeps requests that joined
                    // mid-assembly non-negative
                    let queue = first_recv.saturating_duration_since(req.submitted).as_secs_f64();
                    let arrived = first_recv.max(req.submitted);
                    let assembly = assembled.saturating_duration_since(arrived).as_secs_f64();
                    let pack = t0.saturating_duration_since(assembled).as_secs_f64();
                    ctx.metrics.record_for_worker(
                        vname,
                        (t0 - req.submitted).as_secs_f64().max(0.0) + exec_secs,
                        real,
                        ctx.wid,
                    );
                    let t_resp = Instant::now();
                    let _ = req.events.send(StreamEvent::Done(Response {
                        id: req.id,
                        logits: logits[i * n_classes..(i + 1) * n_classes].to_vec(),
                        variant: vname.to_string(),
                        queue_secs: queue,
                        assembly_secs: assembly,
                        pack_secs: pack,
                        execute_secs: exec_secs,
                        batch_size: real,
                        tokens: 0,
                    }));
                    let trace = RequestTrace {
                        queue,
                        assembly,
                        pack,
                        execute: exec_secs,
                        respond: t_resp.elapsed().as_secs_f64(),
                    };
                    ctx.metrics.record_trace(vname, trace);
                }
            }
            Err(e) => {
                // failures are counted and reported, never silently
                // dropped
                ctx.metrics.record_error();
                let msg = format!("execute {vname}: {e}");
                eprintln!("[server] worker {}: {msg}", ctx.wid);
                for req in batch_reqs {
                    let _ = req.events.send(StreamEvent::Error(msg.clone()));
                }
            }
        }
    }
}

/// One in-flight decode session's coordinator-side bookkeeping (the
/// model-side state — KV rows, recurrent rows, prompt cursor — lives in
/// the engine's slot table behind [`PreparedModel::decode_begin`]).
struct DecodeSession {
    id: u64,
    events: mpsc::Sender<StreamEvent>,
    queue_secs: f64,
    assembly_secs: f64,
    pack_secs: f64,
    /// Tokens to generate before retirement.
    want_tokens: usize,
    tokens: usize,
    steps: usize,
    /// Sum of the in-flight slot count over this session's steps (its
    /// mean is the decode analogue of `Response::batch_size`).
    slot_sum: usize,
    exec_secs: f64,
    last_logits: Vec<f32>,
}

struct PendingDecode {
    req: Request,
    /// When the decode lane first saw the request (closes its queue span).
    seen: Instant,
}

/// The continuous-batching step scheduler (DESIGN.md §10).
///
/// One thread owns the decode-capable model and loops over step
/// boundaries: drain the intake channel, admit pending sessions into
/// free slots (lowest-free-first, keeping the high-water execution
/// prefix tight), run ONE step for every resident slot, stream each
/// slot its token, retire finished sessions.  Admission enforces the
/// engine's single-variant in-flight set: a session demanding a
/// different variant waits until the engine drains, while variant-
/// agnostic sessions join whatever is resident.
fn decode_loop(
    rx: mpsc::Receiver<Request>,
    mut model: Box<dyn PreparedModel>,
    metrics: Arc<Metrics>,
    policy: Policy,
    wid: usize,
) {
    let Some(caps) = model.decode_caps() else {
        // one-shot-only backend: answer every session with an error
        // stream instead of leaving clients blocked
        while let Ok(req) = rx.recv() {
            metrics.record_error();
            let _ = req.events.send(StreamEvent::Error(
                "streaming decode unavailable: the loaded model is one-shot only".into(),
            ));
        }
        return;
    };
    let mut router = Router::new(policy);
    let mut pending: VecDeque<PendingDecode> = VecDeque::new();
    let mut sessions: Vec<Option<DecodeSession>> = (0..caps.slots).map(|_| None).collect();
    // the variant every resident slot decodes under (a step is one
    // row-wise pass through one variant's packed weights)
    let mut current: Option<Variant> = None;
    let mut open = true;
    loop {
        // intake: block only when fully idle; otherwise a non-blocking
        // drain so new sessions join at this step boundary
        if open && pending.is_empty() && sessions.iter().all(Option::is_none) {
            match rx.recv() {
                Ok(r) => pending.push_back(PendingDecode { req: r, seen: Instant::now() }),
                Err(_) => open = false,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(r) => pending.push_back(PendingDecode { req: r, seen: Instant::now() }),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        // admission at the step boundary
        let mut i = 0;
        while i < pending.len() {
            let Some(slot) = model.decode_free_slot() else { break };
            let engine_empty = sessions.iter().all(Option::is_none);
            let want = pending[i].req.variant;
            if !engine_empty && want.is_some() && want != current {
                // single-variant in-flight set: joins once the engine
                // drains to this request's variant
                i += 1;
                continue;
            }
            let p = pending.remove(i).expect("index in bounds");
            let prompt_rows = p.req.activation.len() / caps.d_in.max(1);
            if p.req.activation.is_empty()
                || p.req.activation.len() % caps.d_in != 0
                || prompt_rows + p.req.decode_steps > caps.max_steps
            {
                metrics.record_error();
                let _ = p.req.events.send(StreamEvent::Error(format!(
                    "decode prompt of {} floats + {} new tokens does not fit the slot \
                     shape (d_in {}, max_steps {})",
                    p.req.activation.len(),
                    p.req.decode_steps,
                    caps.d_in,
                    caps.max_steps
                )));
                continue;
            }
            let admitted = Instant::now();
            if let Err(e) = model.decode_begin(slot, &p.req.activation) {
                metrics.record_error();
                let _ = p.req.events.send(StreamEvent::Error(format!("decode admission: {e}")));
                continue;
            }
            if engine_empty {
                current = Some(want.unwrap_or_else(|| router.route_policy(pending.len())));
            }
            let arrived = p.seen.max(p.req.submitted);
            sessions[slot] = Some(DecodeSession {
                id: p.req.id,
                events: p.req.events,
                queue_secs: p.seen.saturating_duration_since(p.req.submitted).as_secs_f64(),
                assembly_secs: admitted.saturating_duration_since(arrived).as_secs_f64(),
                pack_secs: admitted.elapsed().as_secs_f64(),
                want_tokens: p.req.decode_steps,
                tokens: 0,
                steps: 0,
                slot_sum: 0,
                exec_secs: 0.0,
                last_logits: Vec::new(),
            });
        }
        let n_active = sessions.iter().filter(|s| s.is_some()).count();
        if n_active == 0 {
            if !open && pending.is_empty() {
                break;
            }
            continue;
        }
        let variant = current.expect("resident sessions imply an in-flight variant");
        let vname = variant.name();
        let t0 = Instant::now();
        match model.decode_step(vname) {
            Ok(outs) => {
                let secs = t0.elapsed().as_secs_f64();
                let emitted = outs.iter().filter(|o| o.prompt_done).count();
                metrics.record_decode_step(secs, outs.len(), emitted);
                let mut retired = Vec::new();
                for out in outs {
                    let sess = sessions[out.slot].as_mut().expect("step output of resident slot");
                    sess.steps += 1;
                    sess.slot_sum += n_active;
                    sess.exec_secs += secs;
                    let _ = sess.events.send(StreamEvent::Token(TokenEvent {
                        id: sess.id,
                        slot: out.slot,
                        step: out.step,
                        token: out.token,
                        logits: out.logits.clone(),
                    }));
                    sess.last_logits = out.logits;
                    if out.prompt_done {
                        // the step consuming the last prompt row already
                        // emits the first generated token (its logits are
                        // the one-shot-parity logits)
                        sess.tokens += 1;
                        if sess.tokens >= sess.want_tokens {
                            retired.push(out.slot);
                        }
                    }
                }
                for slot in retired {
                    let sess = sessions[slot].take().expect("retiring a resident slot");
                    let _ = model.decode_end(slot);
                    let mean_slots =
                        (sess.slot_sum as f64 / sess.steps.max(1) as f64).round().max(1.0) as usize;
                    metrics.record_for_worker(
                        vname,
                        sess.queue_secs + sess.assembly_secs + sess.pack_secs + sess.exec_secs,
                        mean_slots,
                        wid,
                    );
                    metrics.record_trace(
                        vname,
                        RequestTrace {
                            queue: sess.queue_secs,
                            assembly: sess.assembly_secs,
                            pack: sess.pack_secs,
                            execute: sess.exec_secs,
                            respond: 0.0,
                        },
                    );
                    let _ = sess.events.send(StreamEvent::Done(Response {
                        id: sess.id,
                        logits: sess.last_logits,
                        variant: vname.to_string(),
                        queue_secs: sess.queue_secs,
                        assembly_secs: sess.assembly_secs,
                        pack_secs: sess.pack_secs,
                        execute_secs: sess.exec_secs,
                        batch_size: mean_slots,
                        tokens: sess.tokens,
                    }));
                }
                if sessions.iter().all(Option::is_none) {
                    current = None;
                }
            }
            Err(e) => {
                // a failed step poisons every resident session (shared
                // workspace state can no longer be trusted); fail them
                // all explicitly and reset the in-flight set
                metrics.record_error();
                let msg = format!("decode step {vname}: {e}");
                eprintln!("[server] decode lane: {msg}");
                for (slot, s) in sessions.iter_mut().enumerate() {
                    if let Some(sess) = s.take() {
                        let _ = sess.events.send(StreamEvent::Error(msg.clone()));
                        let _ = model.decode_end(slot);
                    }
                }
                current = None;
            }
        }
    }
}

/// Start the serving stack over any execution backend.
///
/// Spawns `cfg.workers` pool threads plus the decode lane (and the fast
/// lane when configured); each calls `backend.load()` from inside its
/// own thread (models need not be `Send` — the PJRT engine wraps `Rc`
/// handles) and reports startup over a one-shot channel.  Any worker
/// failing to load tears the pool down and surfaces the first error.
pub fn start_with_backend(backend: Arc<dyn Backend>, cfg: ServerConfig) -> Result<ServerHandle> {
    let (tx, rx) = mpsc::channel::<Request>();
    let rx = Arc::new(Mutex::new(rx));
    let (decode_tx, decode_rx) = mpsc::channel::<Request>();
    let metrics = Arc::new(Metrics::default());
    let queue_depth = Arc::new(AtomicUsize::new(0));
    let workers = cfg.workers.max(1);
    metrics.reserve_workers(workers + usize::from(cfg.fast_lane));
    let (init_tx, init_rx) = mpsc::channel::<Result<(ModelDims, Option<DecodeCaps>)>>();

    // tuned plan cache: loaded once at startup; Policy::Tuned resolves
    // against it before the pool spins up
    let plan_cache: Option<Arc<PlanCache>> = cfg.plan_cache.as_ref().and_then(|path| {
        match PlanCache::load(path) {
            Ok(c) => Some(Arc::new(c)),
            Err(e) => {
                eprintln!("[server] plan cache {}: {e} (serving untuned)", path.display());
                None
            }
        }
    });
    let policy = cfg.policy.clone().resolve(plan_cache.as_deref());

    // one intra-op kernel pool shared across the whole worker pool:
    // concurrent kernel threads stay bounded by workers + intra_threads-1
    // (each submitter is a lane of its own job; the pool adds
    // intra_threads-1 shared helpers) no matter how deep the queue gets
    // (two-level model, DESIGN.md §5)
    let intra: Option<Arc<ThreadPool>> =
        (cfg.intra_threads > 1).then(|| Arc::new(ThreadPool::new(cfg.intra_threads)));

    let mut joins = Vec::with_capacity(workers + 2);
    let mut spawned = 0usize;
    let dynamic_batch = cfg.dynamic_batch;

    // every one-shot lane: the pool workers on the shared channel, plus
    // the M=1 fast lane on its own channel with eager singleton batches
    let fast_pair = cfg.fast_lane.then(|| {
        let (ftx, frx) = mpsc::channel::<Request>();
        (ftx, Arc::new(Mutex::new(frx)))
    });
    let mut lanes: Vec<(usize, Arc<Mutex<mpsc::Receiver<Request>>>, BatcherConfig)> =
        (0..workers).map(|wid| (wid, rx.clone(), cfg.batcher.clone())).collect();
    if let Some((_, frx)) = &fast_pair {
        lanes.push((workers, frx.clone(), BatcherConfig::low_latency(1)));
    }

    for (wid, lane_rx, lane_cfg) in lanes {
        let metrics2 = metrics.clone();
        let queue_depth2 = queue_depth.clone();
        let backend = backend.clone();
        let policy = policy.clone();
        let init_tx = init_tx.clone();
        let intra = intra.clone();
        joins.push(
            std::thread::Builder::new()
                .name(format!("tilewise-worker-{wid}"))
                .spawn(move || {
                    let mut model = match backend.load_with_intra(intra) {
                        Ok(m) => m,
                        Err(e) => {
                            let _ = init_tx.send(Err(e));
                            return;
                        }
                    };
                    let dims = model.dims();
                    let _ = init_tx.send(Ok((dims, model.decode_caps())));
                    // never collect more requests than the model batch
                    // holds — overflow requests would get no response
                    let mut lane_cfg = lane_cfg;
                    lane_cfg.max_batch = lane_cfg.max_batch.min(dims.batch).max(1);
                    // per-worker router: RoundRobin/Adaptive state is
                    // local to each worker (resolved policies are
                    // deterministic)
                    let mut router = Router::new(policy);
                    let ctx = WorkerCtx {
                        metrics: metrics2,
                        queue_depth: queue_depth2,
                        dynamic_batch,
                        wid,
                    };
                    worker_loop(&lane_rx, &lane_cfg, model.as_mut(), &mut router, &ctx);
                })?,
        );
        spawned += 1;
    }

    // the decode lane: always spawned so submit_decode always has a
    // responder; degrades to an error-answering drain when the model
    // advertises no decode capability
    {
        let metrics2 = metrics.clone();
        let backend = backend.clone();
        let policy = policy.clone();
        let init_tx = init_tx.clone();
        let intra = intra.clone();
        let wid = workers + usize::from(cfg.fast_lane);
        joins.push(
            std::thread::Builder::new()
                .name("tilewise-decode".into())
                .spawn(move || {
                    let model = match backend.load_with_intra(intra) {
                        Ok(m) => m,
                        Err(e) => {
                            let _ = init_tx.send(Err(e));
                            return;
                        }
                    };
                    let _ = init_tx.send(Ok((model.dims(), model.decode_caps())));
                    decode_loop(decode_rx, model, metrics2, policy, wid);
                })?,
        );
        spawned += 1;
    }
    drop(init_tx);

    // wait for every lane's load result; fail fast on the first error
    let mut dims: Option<ModelDims> = None;
    let mut decode_caps: Option<DecodeCaps> = None;
    let mut first_err: Option<crate::error::Error> = None;
    for _ in 0..spawned {
        match init_rx.recv() {
            Ok(Ok((d, caps))) => {
                dims = Some(d);
                decode_caps = decode_caps.or(caps);
            }
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => {
                first_err =
                    first_err.or_else(|| Some(anyhow!("worker exited before reporting startup")))
            }
        }
    }
    if let Some(e) = first_err {
        // disconnect every channel so loaded workers exit
        drop(tx);
        drop(fast_pair);
        drop(decode_tx);
        for j in joins {
            let _ = j.join();
        }
        return Err(e);
    }
    let dims = dims.ok_or_else(|| anyhow!("no worker reported model dims"))?;

    Ok(ServerHandle {
        tx,
        fast_tx: fast_pair.map(|(ftx, _)| ftx),
        decode_tx,
        metrics,
        plan_cache,
        next_id: AtomicU64::new(0),
        queue_depth,
        joins,
        max_queue: cfg.max_queue,
        intra,
        workers,
        seq: dims.seq,
        d_model: dims.d_model,
        batch: dims.batch,
        n_classes: dims.n_classes,
        decode_caps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{NativeBackend, NativeModelSpec, ZooBackend, ZooSpec};

    fn native_backend() -> Arc<NativeBackend> {
        Arc::new(NativeBackend::new(NativeModelSpec::default(), None).expect("pack native model"))
    }

    fn start_native(cfg: ServerConfig) -> ServerHandle {
        start_with_backend(native_backend(), cfg).expect("native server start")
    }

    fn tiny_zoo(model: &str) -> ZooSpec {
        let mut spec = ZooSpec::for_model(model).unwrap();
        spec.batch = 2;
        spec.seq = 4;
        spec.width = 16;
        spec.n_layers = 1;
        spec.n_classes = 4;
        spec.g = 8;
        spec.max_steps = 8;
        spec
    }

    const VARIANTS: [Variant; 3] = [Variant::Dense, Variant::Tw, Variant::Tvw];

    // ---- native-backend serving tests: run unconditionally in CI (no
    // ---- artifacts, no `pjrt` feature needed)

    #[test]
    fn native_serve_roundtrip_all_variants() {
        let handle = start_native(ServerConfig::default());
        let len = handle.seq * handle.d_model;
        let mut rng = crate::util::Rng::new(8);
        for variant in VARIANTS {
            let x: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let resp = handle.infer(x, Some(variant)).unwrap();
            assert_eq!(resp.variant, variant.name());
            assert_eq!(resp.logits.len(), handle.n_classes);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
            // the bugfix: total_secs now covers every stage, so it can
            // never undercut the execute span alone
            assert!(resp.total_secs() >= resp.execute_secs);
        }
        assert_eq!(handle.metrics.completed(), 3);
        assert_eq!(handle.metrics.errors(), 0);
    }

    #[test]
    fn config_builder_validates_and_presets_build() {
        let tp = ServerConfig::throughput().build().unwrap();
        assert_eq!(tp.workers, 2);
        assert_eq!(tp.batcher.max_batch, 16);
        let ll = ServerConfig::low_latency().build().unwrap();
        assert!(ll.fast_lane);
        assert!(ll.batcher.eager);
        let custom = ServerConfig::builder()
            .workers(3)
            .max_queue(64)
            .policy(Policy::Fixed(Variant::Tvw))
            .max_batch(4)
            .build()
            .unwrap();
        assert_eq!((custom.workers, custom.max_queue, custom.batcher.max_batch), (3, 64, 4));
        assert!(matches!(custom.policy, Policy::Fixed(Variant::Tvw)));
        // the misconfigurations that used to surface downstream
        assert!(ServerConfig::builder().workers(0).build().is_err());
        assert!(ServerConfig::builder().max_batch(0).build().is_err());
        assert!(ServerConfig::builder().intra_threads(0).build().is_err());
        assert!(ServerConfig::builder().variants(vec![]).build().is_err());
        assert!(ServerConfig::builder().policy(Policy::RoundRobin(vec![])).build().is_err());
        assert!(ServerConfig::builder()
            .policy(Policy::Adaptive {
                dense: Variant::Tw,
                sparse: Variant::Tw,
                queue_threshold: 4
            })
            .build()
            .is_err());
    }

    #[test]
    fn native_backpressure_sheds_over_limit() {
        let cfg = ServerConfig { max_queue: 2, ..Default::default() };
        let handle = start_native(cfg);
        let len = handle.seq * handle.d_model;
        let mut kept = Vec::new();
        let mut shed = 0;
        for _ in 0..64 {
            match handle.try_submit(vec![0.1; len], None) {
                Some(stream) => kept.push(stream),
                None => shed += 1,
            }
        }
        assert!(shed > 0, "expected some sheds with max_queue=2");
        assert_eq!(handle.shed_count(), shed);
        for stream in kept {
            assert!(stream.wait().is_ok());
        }
    }

    #[test]
    fn native_batching_coalesces_concurrent_requests() {
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(250),
                ..BatcherConfig::default()
            },
            ..Default::default()
        };
        let handle = start_native(cfg);
        let len = handle.seq * handle.d_model;
        let streams: Vec<_> = (0..4).map(|_| handle.submit(vec![0.1; len], None)).collect();
        let resps: Vec<_> = streams.into_iter().map(|s| s.wait().unwrap()).collect();
        // all four shared one invocation, and each response reports the
        // true coalesced size (not its position index)
        let max_batch_seen = resps.iter().map(|r| r.batch_size).max().unwrap();
        assert_eq!(max_batch_seen, 4, "expected one coalesced batch of 4");
        assert!(resps.iter().all(|r| r.batch_size == 4));
    }

    #[test]
    fn native_worker_pool_serves_and_folds_worker_stats() {
        let cfg = ServerConfig { workers: 4, ..Default::default() };
        let handle = start_native(cfg);
        assert_eq!(handle.workers, 4);
        let len = handle.seq * handle.d_model;
        let streams: Vec<_> = (0..32).map(|_| handle.submit(vec![0.2; len], None)).collect();
        for stream in streams {
            let resp = stream.wait().unwrap();
            assert_eq!(resp.logits.len(), handle.n_classes);
        }
        let snap = handle.metrics.full_snapshot();
        assert_eq!(snap.completed, 32);
        assert_eq!(snap.per_worker.iter().sum::<u64>(), 32);
        // idle workers appear as explicit zeros, one slot per pool member
        assert_eq!(snap.per_worker.len(), 4);
    }

    #[test]
    fn native_two_level_pool_serves_and_matches_serial() {
        // workers x intra_threads: every worker's kernels claim chunks
        // from one shared intra-op pool; logits must match a fully serial
        // server on the same deterministic model
        let cfg = ServerConfig { workers: 2, intra_threads: 2, ..Default::default() };
        let pooled = start_native(cfg);
        let serial = start_native(ServerConfig::default());
        let len = pooled.seq * pooled.d_model;
        let x: Vec<f32> = (0..len).map(|i| ((i % 19) as f32 - 9.0) * 0.02).collect();
        for variant in VARIANTS {
            let rp = pooled.infer(x.clone(), Some(variant)).unwrap();
            let rs = serial.infer(x.clone(), Some(variant)).unwrap();
            assert_eq!(rp.logits.len(), rs.logits.len());
            for (a, b) in rp.logits.iter().zip(&rs.logits) {
                assert!((a - b).abs() < 1e-3, "{variant}: {a} vs {b}");
            }
        }
        // sustained load over the shared intra pool
        let streams: Vec<_> = (0..24).map(|_| pooled.submit(x.clone(), None)).collect();
        for stream in streams {
            assert!(stream.wait().is_ok());
        }
        assert_eq!(pooled.metrics.errors(), 0);
    }

    #[test]
    fn fast_lane_matches_batched_logits() {
        // the M=1 fast path must be a latency optimisation only: same
        // model, same kernels, same logits as the batched path
        let handle = start_native(ServerConfig::low_latency().build().unwrap());
        let len = handle.seq * handle.d_model;
        let x: Vec<f32> = (0..len).map(|i| ((i % 11) as f32 - 5.0) * 0.06).collect();
        for variant in VARIANTS {
            let fast = handle.submit_fast(x.clone(), Some(variant)).wait().unwrap();
            let batched = handle.submit(x.clone(), Some(variant)).wait().unwrap();
            assert_eq!(fast.batch_size, 1, "{variant}: fast lane runs M=1");
            assert_eq!(fast.logits.len(), batched.logits.len());
            for (a, b) in fast.logits.iter().zip(&batched.logits) {
                assert!((a - b).abs() < 1e-5, "{variant}: {a} vs {b}");
            }
        }
        // without the lane, submit_fast degrades to the batched path
        let plain = start_native(ServerConfig::default());
        let resp = plain.submit_fast(x.clone(), Some(Variant::Tw)).wait().unwrap();
        assert_eq!(resp.logits.len(), plain.n_classes);
        assert_eq!(plain.metrics.errors(), 0);
    }

    #[test]
    fn serving_records_stage_traces() {
        let handle = start_native(ServerConfig::default());
        let len = handle.seq * handle.d_model;
        for _ in 0..4 {
            let resp = handle.infer(vec![0.1; len], Some(Variant::Tw)).unwrap();
            // the response's own stage fields agree with what the trace
            // histograms were fed
            assert!(resp.total_secs() >= resp.execute_secs);
        }
        let snap = handle.metrics.full_snapshot();
        let tw = snap.stages.iter().find(|s| s.variant == "model_tw").expect("traced variant");
        // every stage histogram saw all four requests, and the dominant
        // stages carry real time
        for stage in &tw.stages {
            assert_eq!(stage.count, 4, "{}", stage.stage);
            assert!(stage.mean_ms >= 0.0 && stage.p95_ms >= stage.p50_ms * 0.5, "{stage:?}");
        }
        let execute = tw.stages.iter().find(|s| s.stage == "execute").unwrap();
        assert!(execute.mean_ms > 0.0, "execute span must be non-trivial: {execute:?}");
        // no intra pool configured -> no lane telemetry
        assert!(handle.intra_lane_stats().is_none());
    }

    #[test]
    fn intra_pool_lane_stats_surface_through_the_handle() {
        let cfg = ServerConfig { intra_threads: 2, ..Default::default() };
        let handle = start_native(cfg);
        let len = handle.seq * handle.d_model;
        for _ in 0..4 {
            assert!(handle.infer(vec![0.2; len], Some(Variant::Tw)).is_ok());
        }
        let lanes = handle.intra_lane_stats().expect("intra pool exists");
        assert_eq!(lanes.len(), 2);
        assert!(lanes.iter().all(|l| l.busy_secs >= 0.0 && l.idle_secs >= 0.0), "{lanes:?}");
    }

    #[test]
    fn graph_zoo_backend_serves_through_the_pool() {
        // the whole zoo goes through the same coordinator seam: a tiny
        // graph-compiled BERT encoder served by a 2-worker pool with a
        // shared intra-op kernel pool
        let backend = Arc::new(ZooBackend::new(tiny_zoo("bert"), None).unwrap());
        let cfg = ServerConfig { workers: 2, intra_threads: 2, ..Default::default() };
        let handle = start_with_backend(backend, cfg).expect("zoo server start");
        assert_eq!(handle.n_classes, 4);
        // a one-shot encoder advertises no decode slots ...
        assert!(handle.decode_caps.is_none());
        let len = handle.seq * handle.d_model;
        let x: Vec<f32> = (0..len).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        for variant in VARIANTS {
            let resp = handle.infer(x.clone(), Some(variant)).unwrap();
            assert_eq!(resp.logits.len(), handle.n_classes);
            assert!(resp.logits.iter().all(|v| v.is_finite()), "{variant}");
        }
        // ... and submit_decode fails fast instead of hanging
        let err = handle.submit_decode(x.clone(), None, 2).wait().unwrap_err().to_string();
        assert!(err.contains("one-shot only"), "{err}");
        assert_eq!(handle.metrics.errors(), 1);
    }

    #[test]
    fn streaming_decode_sessions_join_stream_and_finish() {
        // the tentpole end to end: two NMT sessions share the in-flight
        // slot set, stream one token per step, and retire independently
        let backend = Arc::new(ZooBackend::new(tiny_zoo("nmt"), None).unwrap());
        let handle = start_with_backend(backend, ServerConfig::default()).unwrap();
        let caps = handle.decode_caps.expect("nmt decodes");
        assert_eq!((caps.slots, caps.d_in, caps.max_steps), (2, 16, 8));

        let prompt: Vec<f32> = (0..2 * caps.d_in).map(|i| ((i % 7) as f32 - 3.0) * 0.2).collect();
        let s1 = handle.submit_decode(prompt.clone(), Some(Variant::Tw), 3);
        let s2 = handle.submit_decode(prompt.clone(), Some(Variant::Tw), 2);

        // a 2-row prompt + N new tokens runs 2 + N - 1 steps (the last
        // prompt row's step already emits the first generated token)
        for (stream, want_tokens, want_steps) in [(s1, 3, 4), (s2, 2, 3)] {
            let events: Vec<StreamEvent> = stream.collect();
            assert_eq!(events.len(), want_steps + 1, "steps + terminal Done");
            for (step, ev) in events[..want_steps].iter().enumerate() {
                let StreamEvent::Token(t) = ev else { panic!("expected Token, got {ev:?}") };
                assert_eq!(t.step, step, "steps stream in order");
                assert_eq!(t.logits.len(), handle.n_classes);
            }
            let StreamEvent::Done(resp) = &events[want_steps] else {
                panic!("expected terminal Done, got {:?}", events[want_steps])
            };
            assert_eq!(resp.tokens, want_tokens);
            assert_eq!(resp.variant, "model_tw");
            assert_eq!(resp.logits.len(), handle.n_classes);
            assert!(resp.execute_secs > 0.0);
            assert!(resp.total_secs() >= resp.execute_secs);
        }

        let stats = handle.metrics.decode_stats();
        assert_eq!(stats.tokens, 5, "3 + 2 generated tokens");
        assert!(stats.steps >= 4, "at least the longer session's steps ran");
        assert!(stats.mean_active_slots >= 1.0);

        // the same handle still serves one-shot forwards
        let x = vec![0.1; handle.seq * handle.d_model];
        assert!(handle.infer(x, Some(Variant::Tw)).is_ok());
        assert_eq!(handle.metrics.errors(), 0);
    }

    #[test]
    fn decode_rejects_oversized_sessions_up_front() {
        let backend = Arc::new(ZooBackend::new(tiny_zoo("nmt"), None).unwrap());
        let handle = start_with_backend(backend, ServerConfig::default()).unwrap();
        let caps = handle.decode_caps.unwrap();
        // prompt rows + new tokens beyond max_steps could never retire
        let long_prompt = vec![0.1; caps.d_in * caps.max_steps];
        let err = handle.submit_decode(long_prompt, None, 1).wait().unwrap_err().to_string();
        assert!(err.contains("does not fit"), "{err}");
        // ragged prompt width
        let ragged = vec![0.1; caps.d_in + 1];
        assert!(handle.submit_decode(ragged, None, 1).wait().is_err());
        // zero new tokens is a one-shot, not a decode
        assert!(handle.submit_decode(vec![0.1; caps.d_in], None, 0).wait().is_err());
        assert_eq!(handle.metrics.errors(), 3);
        // valid sessions still run afterwards
        let ok = handle.submit_decode(vec![0.1; caps.d_in], None, 2).wait().unwrap();
        assert_eq!(ok.tokens, 2);
    }

    #[test]
    fn oversized_activation_rejected_at_submit_not_worker_panic() {
        // regression: an activation longer than seq*d_model used to blow
        // up pack_batch's copy_from_slice inside a worker thread; now the
        // submit path rejects it with a terminal Error event
        let handle = start_native(ServerConfig::default());
        let len = handle.seq * handle.d_model;
        let err = handle.infer(vec![0.1; len + 1], None).unwrap_err().to_string();
        assert!(err.contains("per-request capacity"), "{err}");
        assert_eq!(handle.metrics.errors(), 1);
        // try_submit validates through the same path
        let stream =
            handle.try_submit(vec![0.1; 2 * len], None).expect("length rejection is not a shed");
        assert!(stream.wait().is_err());
        assert_eq!(handle.metrics.errors(), 2);
        assert_eq!(handle.metrics.completed(), 0);
        // the worker pool survived: a valid request still round-trips
        let ok = handle.infer(vec![0.1; len], Some(Variant::Tw)).unwrap();
        assert_eq!(ok.logits.len(), handle.n_classes);
        assert_eq!(handle.metrics.completed(), 1);
    }

    #[test]
    fn dynamic_partial_batch_matches_padded_logits() {
        // a single request (effective batch 1 inside a batch-8 model)
        // must produce identical logits on the dynamic and padded paths
        let dynamic = start_native(ServerConfig::default());
        let padded = start_native(ServerConfig { dynamic_batch: false, ..Default::default() });
        let len = dynamic.seq * dynamic.d_model;
        let x: Vec<f32> = (0..len).map(|i| ((i % 23) as f32 - 11.0) * 0.04).collect();
        for variant in VARIANTS {
            let rd = dynamic.infer(x.clone(), Some(variant)).unwrap();
            let rp = padded.infer(x.clone(), Some(variant)).unwrap();
            assert_eq!(rd.logits.len(), rp.logits.len(), "{variant}");
            for (a, b) in rd.logits.iter().zip(&rp.logits) {
                assert!((a - b).abs() < 1e-4, "{variant}: {a} vs {b}");
            }
        }
        // occupancy telemetry: 3 singleton batches on a batch-8 model
        let snap = dynamic.metrics.full_snapshot();
        assert_eq!(snap.batches, 3);
        assert_eq!(snap.padded_rows_avoided, 3 * (dynamic.batch as u64 - 1));
        for v in &snap.variants {
            assert!((v.mean_occupancy - 1.0 / dynamic.batch as f64).abs() < 1e-9, "{v:?}");
        }
        // the padded server records occupancy but avoids nothing
        let psnap = padded.metrics.full_snapshot();
        assert_eq!(psnap.padded_rows_avoided, 0);
        assert_eq!(psnap.batches, 3);
    }

    #[test]
    fn execute_failure_sends_error_stream_and_counts() {
        // a zoo backend restricted to one variant: requesting another is
        // a real execute failure surfaced through the stream
        let backend =
            Arc::new(ZooBackend::new(tiny_zoo("bert").with_variants(&["model_tw"]), None).unwrap());
        let handle = start_with_backend(backend, ServerConfig::default()).unwrap();
        let len = handle.seq * handle.d_model;
        let err = handle.infer(vec![0.0; len], Some(Variant::Dense)).unwrap_err().to_string();
        assert!(err.contains("model_dense"), "{err}");
        assert_eq!(handle.metrics.errors(), 1);
        assert_eq!(handle.metrics.completed(), 0);
        // the server keeps serving after a failed batch
        let ok = handle.infer(vec![0.0; len], Some(Variant::Tw)).unwrap();
        assert_eq!(ok.logits.len(), handle.n_classes);
        assert_eq!(handle.metrics.full_snapshot().errors, 1);
    }

    /// Parity across backends: the native backend serves finite logits of
    /// the advertised shape for every variant; the pjrt backend on the
    /// same config degrades cleanly at startup when its artifacts (or the
    /// `pjrt` feature) are missing, rather than panicking or hanging.
    #[test]
    fn native_and_pjrt_backends_parity_and_degradation() {
        let handle = start_native(ServerConfig::default());
        let len = handle.seq * handle.d_model;
        let mut shapes = Vec::new();
        for variant in VARIANTS {
            let resp = handle.infer(vec![0.3; len], Some(variant)).unwrap();
            assert!(resp.logits.iter().all(|v| v.is_finite()), "{variant}");
            shapes.push(resp.logits.len());
        }
        assert!(shapes.iter().all(|&s| s == handle.n_classes), "variants agree on shape");
        let missing = Path::new("/no/such/artifact/dir");
        assert!(start(missing, ServerConfig::default()).is_err());
    }

    // ---- artifact-gated tests: exercise the PJRT path when `make
    // ---- artifacts` ran (and the `pjrt` feature supplies the engine)

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("meta.json").exists().then_some(dir)
    }

    #[test]
    fn serve_roundtrip_all_variants() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let handle = start(&dir, ServerConfig::default()).unwrap();
        let len = handle.seq * handle.d_model;
        let mut rng = crate::util::Rng::new(8);
        for variant in VARIANTS {
            let x: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let resp = handle.infer(x, Some(variant)).unwrap();
            assert_eq!(resp.variant, variant.name());
            assert_eq!(resp.logits.len(), handle.n_classes);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
        }
        assert_eq!(handle.metrics.completed(), 3);
    }

    #[test]
    fn backpressure_sheds_over_limit() {
        let Some(dir) = artifacts_dir() else { return };
        let cfg = ServerConfig { max_queue: 2, ..Default::default() };
        let handle = start(&dir, cfg).unwrap();
        let len = handle.seq * handle.d_model;
        let mut kept = Vec::new();
        let mut shed = 0;
        for _ in 0..32 {
            match handle.try_submit(vec![0.1; len], None) {
                Some(stream) => kept.push(stream),
                None => shed += 1,
            }
        }
        assert!(shed > 0, "expected some sheds with max_queue=2");
        assert_eq!(handle.shed_count(), shed);
        for stream in kept {
            let _ = stream.wait();
        }
    }

    #[test]
    fn batching_coalesces_concurrent_requests() {
        let Some(dir) = artifacts_dir() else { return };
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(50),
                ..BatcherConfig::default()
            },
            ..Default::default()
        };
        let handle = start(&dir, cfg).unwrap();
        let len = handle.seq * handle.d_model;
        let streams: Vec<_> = (0..4).map(|_| handle.submit(vec![0.1; len], None)).collect();
        let resps: Vec<_> = streams.into_iter().map(|s| s.wait().unwrap()).collect();
        // all four should have shared one executable invocation, and each
        // response reports the true coalesced size
        let max_batch_seen = resps.iter().map(|r| r.batch_size).max().unwrap();
        assert!(max_batch_seen >= 2, "batch {max_batch_seen}");
        assert!(
            resps.iter().filter(|r| r.batch_size == max_batch_seen).count() >= max_batch_seen,
            "batch_size must be the coalesced size shared by the whole batch"
        );
    }
}
