//! The serving loop: a pool of worker threads sharing one request channel
//! through the dynamic batcher and the router, each worker owning its own
//! backend-loaded model.
//!
//! The hot path stays allocation-light and contention-light: one shared-
//! channel batch collection (exactly one worker blocks in `recv` while the
//! others execute — that lock *is* the pipeline), one buffer staging, one
//! execute.  Which kernels run is the backend's business
//! ([`crate::exec::Backend`]): the PJRT artifact engine, or the native
//! in-process backend that packs weights once and runs the paper's
//! TW/TVW/2:4 CPU kernels with no artifacts at all.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use super::batcher::{collect_batch_shared_traced, pack_batch, BatcherConfig, CollectedBatch};
use super::metrics::Metrics;
use super::request::{Request, Response};
use super::router::{Policy, Router};
use crate::anyhow;
use crate::autotune::PlanCache;
use crate::error::Result;
use crate::exec::{Backend, ModelDims, PjrtBackend};
use crate::pool::{LaneStats, ThreadPool};
use crate::telemetry::RequestTrace;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub policy: Policy,
    /// Which executables to load ("model_*" entries in meta.json).
    pub variants: Vec<String>,
    /// Backpressure: submissions beyond this queue depth are shed
    /// immediately instead of growing the tail (0 = unbounded).
    pub max_queue: usize,
    /// Autotuner plan cache (`tilewise autotune --out ...`) loaded at
    /// startup; `Policy::Tuned` resolves its serving variant from it.
    /// An unreadable or stale cache degrades to no cache with a warning.
    pub plan_cache: Option<PathBuf>,
    /// Worker threads sharing the request channel.  Each owns one model
    /// instance loaded from the backend (clamped to >= 1).
    pub workers: usize,
    /// Intra-op kernel parallelism: lanes of ONE pool shared by every
    /// worker's GEMM kernels (`crate::pool`), composing with the
    /// inter-request `workers` pool.  Each submitting worker is itself a
    /// lane of its own job, so concurrent kernel threads are bounded by
    /// `workers + intra_threads - 1`; size that sum near the core count
    /// (DESIGN.md §5).  `<= 1` keeps the kernels serial (the historical
    /// behaviour).
    pub intra_threads: usize,
    /// Dynamic effective-batch execution (DESIGN.md §7): pack and run
    /// only the real coalesced requests (`PreparedModel::run_batch`)
    /// instead of zero-padding to the model's full batch.  Numerically
    /// identical on every backend — models that don't advertise
    /// `supports_dynamic_batch` (the static-shape PJRT artifacts) keep
    /// the historical full-B pack + `run` — and strictly cheaper on
    /// dynamic ones (graph/native), where a half-full batch costs half
    /// the compute.  `false` restores the historical padded path
    /// everywhere (the A/B baseline `benches/serving_throughput.rs`
    /// measures against).
    pub dynamic_batch: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            policy: Policy::Fixed("model_tw".into()),
            variants: vec!["model_dense".into(), "model_tw".into(), "model_tvw".into()],
            max_queue: 0,
            plan_cache: None,
            workers: 1,
            intra_threads: 1,
            dynamic_batch: true,
        }
    }
}

/// Client handle: submit requests, read metrics, shut down.
pub struct ServerHandle {
    tx: mpsc::Sender<Request>,
    pub metrics: Arc<Metrics>,
    /// The tuned plan cache the server loaded at startup, if any.
    pub plan_cache: Option<Arc<PlanCache>>,
    next_id: AtomicU64,
    queue_depth: Arc<AtomicUsize>,
    joins: Vec<std::thread::JoinHandle<()>>,
    max_queue: usize,
    /// The shared intra-op kernel pool, kept for lane telemetry
    /// (`None` when `intra_threads <= 1`).
    intra: Option<Arc<ThreadPool>>,
    /// How many workers the pool runs.
    pub workers: usize,
    pub seq: usize,
    pub d_model: usize,
    pub batch: usize,
    pub n_classes: usize,
}

impl ServerHandle {
    /// Number of requests shed by backpressure so far (also visible in
    /// `Metrics::full_snapshot`).
    pub fn shed_count(&self) -> u64 {
        self.metrics.sheds()
    }

    /// Per-lane busy/idle split of the shared intra-op kernel pool, when
    /// one exists (`intra_threads > 1`): lane 0 folds the submitting
    /// serving workers together, lanes 1.. are the pinned pool workers.
    pub fn intra_lane_stats(&self) -> Option<Vec<LaneStats>> {
        self.intra.as_ref().map(|p| p.lane_stats())
    }

    /// Submit with backpressure: sheds (returns None) when the queue is
    /// beyond `max_queue`.
    pub fn try_submit(
        &self,
        activation: Vec<f32>,
        variant: Option<String>,
    ) -> Option<mpsc::Receiver<Response>> {
        if self.max_queue > 0 && self.queue_depth.load(Ordering::Relaxed) >= self.max_queue {
            self.metrics.record_shed();
            return None;
        }
        Some(self.submit(activation, variant))
    }

    /// Submit one sequence's activations; returns the response receiver.
    ///
    /// An activation longer than the model's per-request capacity
    /// (`seq * d_model`) is rejected here with an explicit error
    /// [`Response`] (counted in `Metrics::errors`) — it could never be
    /// served, and letting it reach `pack_batch` used to panic the
    /// worker thread mid-batch.  Shorter activations remain accepted and
    /// zero-padded, as ever.
    pub fn submit(
        &self,
        activation: Vec<f32>,
        variant: Option<String>,
    ) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let per_request_len = self.seq * self.d_model;
        if activation.len() > per_request_len {
            self.metrics.record_error();
            let _ = tx.send(Response {
                id,
                logits: Vec::new(),
                variant: variant.unwrap_or_default(),
                queue_secs: 0.0,
                execute_secs: 0.0,
                batch_size: 0,
                error: Some(format!(
                    "activation has {} floats, exceeding the model's per-request \
                     capacity {per_request_len} (seq {} x d_model {})",
                    activation.len(),
                    self.seq,
                    self.d_model
                )),
            });
            return rx;
        }
        let req = Request { id, activation, variant, submitted: Instant::now(), respond_to: tx };
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
        // a closed channel means the server already shut down; the caller
        // sees it as a dropped response channel
        let _ = self.tx.send(req);
        rx
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, activation: Vec<f32>, variant: Option<String>) -> Result<Response> {
        let rx = self.submit(activation, variant);
        Ok(rx.recv()?)
    }

    /// Graceful shutdown: close the request channel and join the workers.
    /// (Equivalent to dropping the handle; provided for explicitness.)
    pub fn shutdown(self) {}
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        // Closing tx ends collect_batch on every worker -> pool drains.
        let (dead_tx, _) = mpsc::channel();
        self.tx = dead_tx;
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// Start the serving stack over an artifact directory (the PJRT backend —
/// kept as the historical entry point; degrades at startup when the
/// `pjrt` feature or the artifacts are missing).
pub fn start(artifact_dir: &Path, cfg: ServerConfig) -> Result<ServerHandle> {
    let backend = Arc::new(PjrtBackend::new(artifact_dir, &cfg.variants));
    start_with_backend(backend, cfg)
}

/// Start the serving stack over any execution backend.
///
/// Spawns `cfg.workers` threads; each calls `backend.load()` from inside
/// its own thread (models need not be `Send` — the PJRT engine wraps `Rc`
/// handles) and reports startup over a one-shot channel.  Any worker
/// failing to load tears the pool down and surfaces the first error.
pub fn start_with_backend(backend: Arc<dyn Backend>, cfg: ServerConfig) -> Result<ServerHandle> {
    let (tx, rx) = mpsc::channel::<Request>();
    let rx = Arc::new(Mutex::new(rx));
    let metrics = Arc::new(Metrics::default());
    let queue_depth = Arc::new(AtomicUsize::new(0));
    let workers = cfg.workers.max(1);
    metrics.reserve_workers(workers);
    let (init_tx, init_rx) = mpsc::channel::<Result<ModelDims>>();

    // tuned plan cache: loaded once at startup; Policy::Tuned resolves
    // against it before the pool spins up
    let plan_cache: Option<Arc<PlanCache>> = cfg.plan_cache.as_ref().and_then(|path| {
        match PlanCache::load(path) {
            Ok(c) => Some(Arc::new(c)),
            Err(e) => {
                eprintln!("[server] plan cache {}: {e} (serving untuned)", path.display());
                None
            }
        }
    });
    let policy = cfg.policy.clone().resolve(plan_cache.as_deref());

    // one intra-op kernel pool shared across the whole worker pool:
    // concurrent kernel threads stay bounded by workers + intra_threads-1
    // (each submitter is a lane of its own job; the pool adds
    // intra_threads-1 shared helpers) no matter how deep the queue gets
    // (two-level model, DESIGN.md §5)
    let intra: Option<Arc<ThreadPool>> =
        (cfg.intra_threads > 1).then(|| Arc::new(ThreadPool::new(cfg.intra_threads)));

    let mut joins = Vec::with_capacity(workers);
    let dynamic_batch = cfg.dynamic_batch;
    for wid in 0..workers {
        let rx = rx.clone();
        let metrics2 = metrics.clone();
        let queue_depth2 = queue_depth.clone();
        let batcher_cfg = cfg.batcher.clone();
        let backend = backend.clone();
        let policy = policy.clone();
        let init_tx = init_tx.clone();
        let intra = intra.clone();
        joins.push(
            std::thread::Builder::new()
                .name(format!("tilewise-worker-{wid}"))
                .spawn(move || {
                    let mut model = match backend.load_with_intra(intra) {
                        Ok(m) => m,
                        Err(e) => {
                            let _ = init_tx.send(Err(e));
                            return;
                        }
                    };
                    let dims = model.dims();
                    let _ = init_tx.send(Ok(dims));
                    // static-shape models (PJRT) would only re-pad a
                    // partial pack internally — give them the single
                    // full-B pack instead (same numerics, one allocation)
                    let dynamic_batch = dynamic_batch && model.supports_dynamic_batch();
                    let per_request_len = dims.per_request_len();
                    let n_classes = dims.n_classes;
                    // never collect more requests than the model batch
                    // holds — overflow requests would get no response
                    let mut batcher_cfg = batcher_cfg;
                    batcher_cfg.max_batch = batcher_cfg.max_batch.min(dims.batch).max(1);
                    // per-worker router: RoundRobin/Adaptive state is local
                    // to each worker (resolved policies are deterministic)
                    let mut router = Router::new(policy);
                    while let Some(CollectedBatch { requests: batch_reqs, first_recv, assembled }) =
                        collect_batch_shared_traced(&rx, &batcher_cfg)
                    {
                        // the true coalesced size every response reports
                        let real = batch_reqs.len().min(dims.batch);
                        let depth = queue_depth2
                            .load(Ordering::Relaxed)
                            .saturating_sub(batch_reqs.len());
                        let variant = router.route(&batch_reqs, depth);
                        // dynamic effective batch: pack and execute only
                        // the real coalesced rows — the padded path packs
                        // (and computes) the full B as it always did
                        let t0;
                        let result = if dynamic_batch {
                            let packed = pack_batch(&batch_reqs, real, per_request_len);
                            t0 = Instant::now();
                            model.run_batch(&variant, &packed, real)
                        } else {
                            let packed = pack_batch(&batch_reqs, dims.batch, per_request_len);
                            t0 = Instant::now();
                            model.run(&variant, &packed)
                        };
                        let exec_secs = t0.elapsed().as_secs_f64();
                        queue_depth2.fetch_sub(batch_reqs.len(), Ordering::Relaxed);
                        match result {
                            Ok(logits) => {
                                metrics2.record_batch(&variant, real, dims.batch, dynamic_batch);
                                for (i, req) in
                                    batch_reqs.into_iter().enumerate().take(dims.batch)
                                {
                                    let queue_secs =
                                        (t0 - req.submitted).as_secs_f64().max(0.0);
                                    metrics2.record_for_worker(
                                        &variant,
                                        queue_secs + exec_secs,
                                        real,
                                        wid,
                                    );
                                    let t_resp = Instant::now();
                                    let _ = req.respond_to.send(Response {
                                        id: req.id,
                                        logits: logits[i * n_classes..(i + 1) * n_classes]
                                            .to_vec(),
                                        variant: variant.clone(),
                                        queue_secs,
                                        execute_secs: exec_secs,
                                        batch_size: real,
                                        error: None,
                                    });
                                    // stage decomposition: queue-wait ends
                                    // at the head recv, assembly at batch
                                    // handoff, pack at execute start;
                                    // saturating math keeps requests that
                                    // joined mid-assembly non-negative
                                    let arrived = first_recv.max(req.submitted);
                                    let trace = RequestTrace {
                                        queue: first_recv
                                            .saturating_duration_since(req.submitted)
                                            .as_secs_f64(),
                                        assembly: assembled
                                            .saturating_duration_since(arrived)
                                            .as_secs_f64(),
                                        pack: t0.saturating_duration_since(assembled).as_secs_f64(),
                                        execute: exec_secs,
                                        respond: t_resp.elapsed().as_secs_f64(),
                                    };
                                    metrics2.record_trace(&variant, trace);
                                }
                            }
                            Err(e) => {
                                // failures are counted and reported, never
                                // silently dropped
                                metrics2.record_error();
                                let msg = format!("execute {variant}: {e}");
                                eprintln!("[server] worker {wid}: {msg}");
                                for req in batch_reqs.into_iter().take(dims.batch) {
                                    let queue_secs =
                                        (t0 - req.submitted).as_secs_f64().max(0.0);
                                    let _ = req.respond_to.send(Response {
                                        id: req.id,
                                        logits: Vec::new(),
                                        variant: variant.clone(),
                                        queue_secs,
                                        execute_secs: exec_secs,
                                        batch_size: real,
                                        error: Some(msg.clone()),
                                    });
                                }
                            }
                        }
                    }
                })?,
        );
    }
    drop(init_tx);

    // wait for every worker's load result; fail fast on the first error
    let mut dims: Option<ModelDims> = None;
    let mut first_err: Option<crate::error::Error> = None;
    for _ in 0..workers {
        match init_rx.recv() {
            Ok(Ok(d)) => dims = Some(d),
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => {
                first_err =
                    first_err.or_else(|| Some(anyhow!("worker exited before reporting startup")))
            }
        }
    }
    if let Some(e) = first_err {
        drop(tx); // disconnect the channel so loaded workers exit
        for j in joins {
            let _ = j.join();
        }
        return Err(e);
    }
    let dims = dims.ok_or_else(|| anyhow!("no worker reported model dims"))?;

    Ok(ServerHandle {
        tx,
        metrics,
        plan_cache,
        next_id: AtomicU64::new(0),
        queue_depth,
        joins,
        max_queue: cfg.max_queue,
        intra,
        workers,
        seq: dims.seq,
        d_model: dims.d_model,
        batch: dims.batch,
        n_classes: dims.n_classes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{NativeBackend, NativeModelSpec};

    fn native_backend() -> Arc<NativeBackend> {
        Arc::new(NativeBackend::new(NativeModelSpec::default(), None).expect("pack native model"))
    }

    fn start_native(cfg: ServerConfig) -> ServerHandle {
        start_with_backend(native_backend(), cfg).expect("native server start")
    }

    // ---- native-backend serving tests: run unconditionally in CI (no
    // ---- artifacts, no `pjrt` feature needed)

    #[test]
    fn native_serve_roundtrip_all_variants() {
        let handle = start_native(ServerConfig::default());
        let len = handle.seq * handle.d_model;
        let mut rng = crate::util::Rng::new(8);
        for variant in ["model_dense", "model_tw", "model_tvw"] {
            let x: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let resp = handle.infer(x, Some(variant.into())).unwrap();
            assert!(resp.is_ok(), "{variant}: {:?}", resp.error);
            assert_eq!(resp.variant, variant);
            assert_eq!(resp.logits.len(), handle.n_classes);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
        }
        assert_eq!(handle.metrics.completed(), 3);
        assert_eq!(handle.metrics.errors(), 0);
    }

    #[test]
    fn native_backpressure_sheds_over_limit() {
        let cfg = ServerConfig { max_queue: 2, ..Default::default() };
        let handle = start_native(cfg);
        let len = handle.seq * handle.d_model;
        let mut kept = Vec::new();
        let mut shed = 0;
        for _ in 0..64 {
            match handle.try_submit(vec![0.1; len], None) {
                Some(rx) => kept.push(rx),
                None => shed += 1,
            }
        }
        assert!(shed > 0, "expected some sheds with max_queue=2");
        assert_eq!(handle.shed_count(), shed);
        for rx in kept {
            let _ = rx.recv();
        }
    }

    #[test]
    fn native_batching_coalesces_concurrent_requests() {
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(250),
                ..BatcherConfig::default()
            },
            ..Default::default()
        };
        let handle = start_native(cfg);
        let len = handle.seq * handle.d_model;
        let rxs: Vec<_> = (0..4).map(|_| handle.submit(vec![0.1; len], None)).collect();
        let resps: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        // all four shared one invocation, and each response reports the
        // true coalesced size (not its position index)
        let max_batch_seen = resps.iter().map(|r| r.batch_size).max().unwrap();
        assert_eq!(max_batch_seen, 4, "expected one coalesced batch of 4");
        assert!(resps.iter().all(|r| r.batch_size == 4));
    }

    #[test]
    fn native_worker_pool_serves_and_folds_worker_stats() {
        let cfg = ServerConfig { workers: 4, ..Default::default() };
        let handle = start_native(cfg);
        assert_eq!(handle.workers, 4);
        let len = handle.seq * handle.d_model;
        let rxs: Vec<_> = (0..32).map(|_| handle.submit(vec![0.2; len], None)).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok());
            assert_eq!(resp.logits.len(), handle.n_classes);
        }
        let snap = handle.metrics.full_snapshot();
        assert_eq!(snap.completed, 32);
        assert_eq!(snap.per_worker.iter().sum::<u64>(), 32);
        // idle workers appear as explicit zeros, one slot per pool member
        assert_eq!(snap.per_worker.len(), 4);
    }

    #[test]
    fn native_two_level_pool_serves_and_matches_serial() {
        // workers x intra_threads: every worker's kernels claim chunks
        // from one shared intra-op pool; logits must match a fully serial
        // server on the same deterministic model
        let cfg = ServerConfig { workers: 2, intra_threads: 2, ..Default::default() };
        let pooled = start_native(cfg);
        let serial = start_native(ServerConfig::default());
        let len = pooled.seq * pooled.d_model;
        let x: Vec<f32> = (0..len).map(|i| ((i % 19) as f32 - 9.0) * 0.02).collect();
        for variant in ["model_dense", "model_tw", "model_tvw"] {
            let rp = pooled.infer(x.clone(), Some(variant.into())).unwrap();
            let rs = serial.infer(x.clone(), Some(variant.into())).unwrap();
            assert!(rp.is_ok(), "{variant}: {:?}", rp.error);
            assert_eq!(rp.logits.len(), rs.logits.len());
            for (a, b) in rp.logits.iter().zip(&rs.logits) {
                assert!((a - b).abs() < 1e-3, "{variant}: {a} vs {b}");
            }
        }
        // sustained load over the shared intra pool
        let rxs: Vec<_> = (0..24).map(|_| pooled.submit(x.clone(), None)).collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        assert_eq!(pooled.metrics.errors(), 0);
    }

    #[test]
    fn serving_records_stage_traces() {
        let handle = start_native(ServerConfig::default());
        let len = handle.seq * handle.d_model;
        for _ in 0..4 {
            let resp = handle.infer(vec![0.1; len], Some("model_tw".into())).unwrap();
            assert!(resp.is_ok());
        }
        let snap = handle.metrics.full_snapshot();
        let tw = snap.stages.iter().find(|s| s.variant == "model_tw").expect("traced variant");
        // every stage histogram saw all four requests, and the dominant
        // stages carry real time
        for stage in &tw.stages {
            assert_eq!(stage.count, 4, "{}", stage.stage);
            assert!(stage.mean_ms >= 0.0 && stage.p95_ms >= stage.p50_ms * 0.5, "{stage:?}");
        }
        let execute = tw.stages.iter().find(|s| s.stage == "execute").unwrap();
        assert!(execute.mean_ms > 0.0, "execute span must be non-trivial: {execute:?}");
        // no intra pool configured -> no lane telemetry
        assert!(handle.intra_lane_stats().is_none());
    }

    #[test]
    fn intra_pool_lane_stats_surface_through_the_handle() {
        let cfg = ServerConfig { intra_threads: 2, ..Default::default() };
        let handle = start_native(cfg);
        let len = handle.seq * handle.d_model;
        for _ in 0..4 {
            assert!(handle.infer(vec![0.2; len], Some("model_tw".into())).unwrap().is_ok());
        }
        let lanes = handle.intra_lane_stats().expect("intra pool exists");
        assert_eq!(lanes.len(), 2);
        assert!(lanes.iter().all(|l| l.busy_secs >= 0.0 && l.idle_secs >= 0.0), "{lanes:?}");
    }

    #[test]
    fn graph_zoo_backend_serves_through_the_pool() {
        // the whole zoo goes through the same coordinator seam: a tiny
        // graph-compiled BERT encoder served by a 2-worker pool with a
        // shared intra-op kernel pool
        use crate::exec::{ZooBackend, ZooSpec};
        let mut spec = ZooSpec::for_model("bert").unwrap();
        spec.batch = 2;
        spec.seq = 4;
        spec.width = 16;
        spec.n_layers = 1;
        spec.n_classes = 4;
        spec.g = 8;
        let backend = Arc::new(ZooBackend::new(spec, None).unwrap());
        let cfg = ServerConfig { workers: 2, intra_threads: 2, ..Default::default() };
        let handle = start_with_backend(backend, cfg).expect("zoo server start");
        assert_eq!(handle.n_classes, 4);
        let len = handle.seq * handle.d_model;
        let x: Vec<f32> = (0..len).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        for variant in ["model_dense", "model_tw", "model_tvw"] {
            let resp = handle.infer(x.clone(), Some(variant.into())).unwrap();
            assert!(resp.is_ok(), "{variant}: {:?}", resp.error);
            assert_eq!(resp.logits.len(), handle.n_classes);
            assert!(resp.logits.iter().all(|v| v.is_finite()), "{variant}");
        }
        assert_eq!(handle.metrics.errors(), 0);
    }

    #[test]
    fn oversized_activation_rejected_at_submit_not_worker_panic() {
        // regression: an activation longer than seq*d_model used to blow
        // up pack_batch's copy_from_slice inside a worker thread; now the
        // submit path rejects it with an explicit error Response
        let handle = start_native(ServerConfig::default());
        let len = handle.seq * handle.d_model;
        let resp = handle.infer(vec![0.1; len + 1], None).unwrap();
        assert!(!resp.is_ok());
        assert!(
            resp.error.as_deref().unwrap().contains("per-request capacity"),
            "{:?}",
            resp.error
        );
        assert!(resp.logits.is_empty());
        assert_eq!(handle.metrics.errors(), 1);
        // try_submit validates through the same path
        let resp2 = handle
            .try_submit(vec![0.1; 2 * len], None)
            .expect("length rejection is not a shed")
            .recv()
            .unwrap();
        assert!(!resp2.is_ok());
        assert_eq!(handle.metrics.errors(), 2);
        assert_eq!(handle.metrics.completed(), 0);
        // the worker pool survived: a valid request still round-trips
        let ok = handle.infer(vec![0.1; len], Some("model_tw".into())).unwrap();
        assert!(ok.is_ok());
        assert_eq!(handle.metrics.completed(), 1);
    }

    #[test]
    fn dynamic_partial_batch_matches_padded_logits() {
        // a single request (effective batch 1 inside a batch-8 model)
        // must produce identical logits on the dynamic and padded paths
        let dynamic = start_native(ServerConfig::default());
        let padded = start_native(ServerConfig { dynamic_batch: false, ..Default::default() });
        let len = dynamic.seq * dynamic.d_model;
        let x: Vec<f32> = (0..len).map(|i| ((i % 23) as f32 - 11.0) * 0.04).collect();
        for variant in ["model_dense", "model_tw", "model_tvw"] {
            let rd = dynamic.infer(x.clone(), Some(variant.into())).unwrap();
            let rp = padded.infer(x.clone(), Some(variant.into())).unwrap();
            assert!(rd.is_ok() && rp.is_ok(), "{variant}");
            assert_eq!(rd.logits.len(), rp.logits.len(), "{variant}");
            for (a, b) in rd.logits.iter().zip(&rp.logits) {
                assert!((a - b).abs() < 1e-4, "{variant}: {a} vs {b}");
            }
        }
        // occupancy telemetry: 3 singleton batches on a batch-8 model
        let snap = dynamic.metrics.full_snapshot();
        assert_eq!(snap.batches, 3);
        assert_eq!(snap.padded_rows_avoided, 3 * (dynamic.batch as u64 - 1));
        for v in &snap.variants {
            assert!((v.mean_occupancy - 1.0 / dynamic.batch as f64).abs() < 1e-9, "{v:?}");
        }
        // the padded server records occupancy but avoids nothing
        let psnap = padded.metrics.full_snapshot();
        assert_eq!(psnap.padded_rows_avoided, 0);
        assert_eq!(psnap.batches, 3);
    }

    #[test]
    fn execute_failure_sends_error_response_and_counts() {
        let handle = start_native(ServerConfig::default());
        let len = handle.seq * handle.d_model;
        let resp = handle.infer(vec![0.0; len], Some("model_bogus".into())).unwrap();
        assert!(!resp.is_ok());
        assert!(resp.error.as_deref().unwrap().contains("model_bogus"));
        assert!(resp.logits.is_empty());
        assert_eq!(handle.metrics.errors(), 1);
        assert_eq!(handle.metrics.completed(), 0);
        // the server keeps serving after a failed batch
        let ok = handle.infer(vec![0.0; len], Some("model_tw".into())).unwrap();
        assert!(ok.is_ok());
        assert_eq!(handle.metrics.full_snapshot().errors, 1);
    }

    /// Parity across backends: the native backend serves finite logits of
    /// the advertised shape for every variant; the pjrt backend on the
    /// same config degrades cleanly at startup when its artifacts (or the
    /// `pjrt` feature) are missing, rather than panicking or hanging.
    #[test]
    fn native_and_pjrt_backends_parity_and_degradation() {
        let handle = start_native(ServerConfig::default());
        let len = handle.seq * handle.d_model;
        let mut shapes = Vec::new();
        for variant in ["model_dense", "model_tw", "model_tvw"] {
            let resp = handle.infer(vec![0.3; len], Some(variant.into())).unwrap();
            assert!(resp.logits.iter().all(|v| v.is_finite()), "{variant}");
            shapes.push(resp.logits.len());
        }
        assert!(shapes.iter().all(|&s| s == handle.n_classes), "variants agree on shape");
        let missing = Path::new("/no/such/artifact/dir");
        assert!(start(missing, ServerConfig::default()).is_err());
    }

    // ---- artifact-gated tests: exercise the PJRT path when `make
    // ---- artifacts` ran (and the `pjrt` feature supplies the engine)

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("meta.json").exists().then_some(dir)
    }

    #[test]
    fn serve_roundtrip_all_variants() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let handle = start(&dir, ServerConfig::default()).unwrap();
        let len = handle.seq * handle.d_model;
        let mut rng = crate::util::Rng::new(8);
        for variant in ["model_dense", "model_tw", "model_tvw"] {
            let x: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let resp = handle.infer(x, Some(variant.into())).unwrap();
            assert_eq!(resp.variant, variant);
            assert_eq!(resp.logits.len(), handle.n_classes);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
        }
        assert_eq!(handle.metrics.completed(), 3);
    }

    #[test]
    fn backpressure_sheds_over_limit() {
        let Some(dir) = artifacts_dir() else { return };
        let cfg = ServerConfig { max_queue: 2, ..Default::default() };
        let handle = start(&dir, cfg).unwrap();
        let len = handle.seq * handle.d_model;
        let mut kept = Vec::new();
        let mut shed = 0;
        for _ in 0..32 {
            match handle.try_submit(vec![0.1; len], None) {
                Some(rx) => kept.push(rx),
                None => shed += 1,
            }
        }
        assert!(shed > 0, "expected some sheds with max_queue=2");
        assert_eq!(handle.shed_count(), shed);
        for rx in kept {
            let _ = rx.recv();
        }
    }

    #[test]
    fn batching_coalesces_concurrent_requests() {
        let Some(dir) = artifacts_dir() else { return };
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(50),
                ..BatcherConfig::default()
            },
            ..Default::default()
        };
        let handle = start(&dir, cfg).unwrap();
        let len = handle.seq * handle.d_model;
        let rxs: Vec<_> = (0..4).map(|_| handle.submit(vec![0.1; len], None)).collect();
        let resps: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        // all four should have shared one executable invocation, and each
        // response reports the true coalesced size
        let max_batch_seen = resps.iter().map(|r| r.batch_size).max().unwrap();
        assert!(max_batch_seen >= 2, "batch {max_batch_seen}");
        assert!(
            resps.iter().filter(|r| r.batch_size == max_batch_seen).count() >= max_batch_seen,
            "batch_size must be the coalesced size shared by the whole batch"
        );
    }
}
