//! Serving metrics: per-variant latency histograms + counters, with
//! percentile snapshots for the e2e report.  Backpressure sheds are
//! counted here too, so one snapshot shows latency percentiles *and*
//! how much load the server refused to take.
//!
//! Storage is bounded and the record path is lock-free: every latency,
//! batch-size, occupancy, and stage-span sample lands in atomic
//! counters ([`telemetry::Histogram`] buckets or scaled-integer sums),
//! never in a growable sample vector.  The only locks left are a
//! read-mostly variant map (write-locked once per *new* variant name)
//! and a small clock/per-worker mutex — nothing is sorted under a lock
//! at snapshot time anymore.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::json::{arr, num, obj, s, Json};
use crate::telemetry::{Histogram, RequestTrace, Stage, StageStats, TraceExemplar, TraceRing};

/// Bounded per-variant meters: one latency histogram, one histogram per
/// pipeline stage, and exact scaled-integer sums for the means the
/// reports quote exactly (batch size, occupancy).
struct VariantMeters {
    latency: Histogram,
    /// Sum of per-request batch sizes (mean = rows / latency count).
    batch_rows: AtomicU64,
    /// Occupancy samples: count + sum scaled by 1e9 (exact to 1e-9).
    occ_count: AtomicU64,
    occ_scaled: AtomicU64,
    /// One histogram per [`Stage`], indexed by `Stage::index()`.
    stages: [Histogram; 5],
}

impl VariantMeters {
    fn new() -> VariantMeters {
        VariantMeters {
            latency: Histogram::new(),
            batch_rows: AtomicU64::new(0),
            occ_count: AtomicU64::new(0),
            occ_scaled: AtomicU64::new(0),
            stages: std::array::from_fn(|_| Histogram::new()),
        }
    }

    fn mean_occupancy(&self) -> f64 {
        let n = self.occ_count.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.occ_scaled.load(Ordering::Relaxed) as f64 / (n as f64 * 1e9)
        }
    }
}

/// First/last completion instants plus the per-worker completion split —
/// the only mutex-guarded metrics state, touched once per completion.
#[derive(Default)]
struct Clock {
    first: Option<Instant>,
    last: Option<Instant>,
    /// Completions per worker (index = worker id), grown on demand.
    worker_completed: Vec<u64>,
}

/// Thread-safe metrics sink shared between the worker pool and clients.
#[derive(Default)]
pub struct Metrics {
    /// Per-variant meters behind a read-mostly lock: the hot path takes
    /// the read lock, clones an `Arc`, and records lock-free; the write
    /// lock is taken once per previously-unseen variant name.
    variants: RwLock<HashMap<String, Arc<VariantMeters>>>,
    clock: Mutex<Clock>,
    completed: AtomicU64,
    /// Executed batch invocations (the denominator of the occupancy
    /// counters).
    batches: AtomicU64,
    /// Padding rows whose compute dynamic-M execution skipped (`B - real`
    /// summed over dynamic batches; 0 under padded execution).
    padded_rows_avoided: AtomicU64,
    /// Requests shed by backpressure (the shed path is the hot rejection
    /// path and must not contend with the executors).
    sheds: AtomicU64,
    /// Execute invocations that failed (one per failed batch; every
    /// request in that batch got an error `Response`).
    errors: AtomicU64,
    /// Slow-request exemplar ring (last N traces over the threshold).
    slow: TraceRing,
    /// Streaming-decode meters (step spans, token counters, occupancy).
    decode: DecodeMeters,
}

/// Bounded streaming-decode meters: one histogram of per-step wall time
/// plus exact counters for steps, emitted tokens, and the active-slot
/// occupancy mean — all lock-free except the tokens/s clock (touched once
/// per step, like the completion clock).
#[derive(Default)]
struct DecodeMeters {
    step_hist: Histogram,
    steps: AtomicU64,
    tokens: AtomicU64,
    /// Active-slot samples: count + sum scaled by 1e9 (exact to 1e-9).
    slot_count: AtomicU64,
    slot_scaled: AtomicU64,
    /// First/last step instants — the tokens/s window, so an idle tail
    /// after decode stops does not dilute the figure.
    clock: Mutex<(Option<Instant>, Option<Instant>)>,
}

/// Snapshot of the streaming-decode meters.
#[derive(Clone, Debug, Default)]
pub struct DecodeStats {
    /// Decode steps executed (one step advances every resident slot).
    pub steps: u64,
    /// Tokens emitted across all sessions (one per active slot per step).
    pub tokens: u64,
    /// Tokens per second over the first→last step window.
    pub tokens_per_sec: f64,
    /// Mean resident slots per step — continuous batching keeps this high
    /// under churn where static batching drains to a long tail.
    pub mean_active_slots: f64,
    pub step_mean_ms: f64,
    pub step_p50_ms: f64,
    pub step_p95_ms: f64,
}

/// Snapshot of one variant's serving statistics.
#[derive(Clone, Debug)]
pub struct VariantStats {
    pub variant: String,
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_batch: f64,
    /// Mean batch occupancy (`real / B`) over this variant's executed
    /// batches — 1.0 means every invocation ran full; lower means
    /// dynamic-M serving skipped padding compute (or, padded, wasted it).
    pub mean_occupancy: f64,
}

/// One variant's per-stage span aggregates.
#[derive(Clone, Debug)]
pub struct VariantStageStats {
    pub variant: String,
    /// In [`Stage::ALL`] order: queue, assembly, pack, execute, respond.
    pub stages: Vec<StageStats>,
}

/// Whole-server snapshot: per-variant percentiles plus the global
/// counters (completions, backpressure sheds, errors, throughput), the
/// per-worker completion split, per-stage span aggregates, and the
/// retained slow-request exemplars.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub variants: Vec<VariantStats>,
    pub completed: u64,
    /// Requests refused by backpressure (`ServerHandle::try_submit`).
    pub sheds: u64,
    /// Failed execute invocations (clients got an error `Response`).
    pub errors: u64,
    /// Completions per worker (index = worker id).
    pub per_worker: Vec<u64>,
    pub throughput_rps: f64,
    /// Executed batch invocations across all variants.
    pub batches: u64,
    /// Padding rows dynamic-M execution never computed (`B - real` summed
    /// over dynamic batches) — the observable win of effective-batch
    /// serving; stays 0 under padded execution.
    pub padded_rows_avoided: u64,
    /// Per-variant stage breakdown (queue → assembly → pack → execute →
    /// respond), present for variants served through the traced path.
    pub stages: Vec<VariantStageStats>,
    /// Slow-request exemplars retained by the trace ring, oldest first.
    pub exemplars: Vec<TraceExemplar>,
    /// Streaming-decode aggregates (zeroed when no decode ran).
    pub decode: DecodeStats,
}

impl Metrics {
    /// Resolve (or create) one variant's meters; hot path is a read
    /// lock + `Arc` clone.
    fn meters(&self, variant: &str) -> Arc<VariantMeters> {
        if let Some(m) = self.variants.read().unwrap().get(variant) {
            return Arc::clone(m);
        }
        let mut map = self.variants.write().unwrap();
        Arc::clone(map.entry(variant.to_string()).or_insert_with(|| Arc::new(VariantMeters::new())))
    }

    /// Pre-size the per-worker counters to the pool size, so idle workers
    /// show up as explicit zeros in snapshots (an idle/stuck worker must
    /// be distinguishable from a nonexistent one).
    pub fn reserve_workers(&self, workers: usize) {
        let mut clock = self.clock.lock().unwrap();
        if clock.worker_completed.len() < workers {
            clock.worker_completed.resize(workers, 0);
        }
    }

    /// Record one completed request served by `worker`.
    pub fn record_for_worker(
        &self,
        variant: &str,
        latency_secs: f64,
        batch_size: usize,
        worker: usize,
    ) {
        let m = self.meters(variant);
        m.latency.record(latency_secs);
        m.batch_rows.fetch_add(batch_size as u64, Ordering::Relaxed);
        self.completed.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        let mut clock = self.clock.lock().unwrap();
        if clock.first.is_none() {
            clock.first = Some(now);
        }
        clock.last = Some(now);
        if clock.worker_completed.len() <= worker {
            clock.worker_completed.resize(worker + 1, 0);
        }
        clock.worker_completed[worker] += 1;
    }

    /// Single-executor convenience (worker 0).
    pub fn record(&self, variant: &str, latency_secs: f64, batch_size: usize) {
        self.record_for_worker(variant, latency_secs, batch_size, 0);
    }

    /// Record one request's stage decomposition: each span lands in the
    /// variant's per-stage histogram and the whole trace is offered to
    /// the slow-request exemplar ring.
    pub fn record_trace(&self, variant: &str, trace: RequestTrace) {
        let m = self.meters(variant);
        for stage in Stage::ALL {
            m.stages[stage.index()].record(trace.stage(stage));
        }
        self.slow.offer(variant, trace);
    }

    /// Record one executed batch invocation: occupancy sample
    /// (`real / capacity`) for `variant`, plus the padded-rows-avoided
    /// counter when the batch ran on the dynamic effective-batch path.
    pub fn record_batch(&self, variant: &str, real: usize, capacity: usize, dynamic: bool) {
        let m = self.meters(variant);
        let occ = real as f64 / capacity.max(1) as f64;
        m.occ_count.fetch_add(1, Ordering::Relaxed);
        m.occ_scaled.fetch_add((occ * 1e9).round() as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        if dynamic {
            let avoided = capacity.saturating_sub(real) as u64;
            self.padded_rows_avoided.fetch_add(avoided, Ordering::Relaxed);
        }
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn padded_rows_avoided(&self) -> u64 {
        self.padded_rows_avoided.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Completions per worker (index = worker id).
    pub fn per_worker(&self) -> Vec<u64> {
        self.clock.lock().unwrap().worker_completed.clone()
    }

    /// Count one backpressure shed (lock-free).
    pub fn record_shed(&self) {
        self.sheds.fetch_add(1, Ordering::Relaxed);
    }

    pub fn sheds(&self) -> u64 {
        self.sheds.load(Ordering::Relaxed)
    }

    /// Record one decode step: its wall time, how many slots were
    /// resident, and how many tokens it emitted (== active slots, but
    /// kept separate so a future speculative path can differ).
    pub fn record_decode_step(&self, secs: f64, active_slots: usize, tokens: usize) {
        let d = &self.decode;
        d.step_hist.record(secs);
        d.steps.fetch_add(1, Ordering::Relaxed);
        d.tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        d.slot_count.fetch_add(1, Ordering::Relaxed);
        d.slot_scaled.fetch_add(((active_slots as f64) * 1e9).round() as u64, Ordering::Relaxed);
        let now = Instant::now();
        let mut clock = d.clock.lock().unwrap();
        if clock.0.is_none() {
            clock.0 = Some(now);
        }
        clock.1 = Some(now);
    }

    pub fn decode_tokens(&self) -> u64 {
        self.decode.tokens.load(Ordering::Relaxed)
    }

    /// Tokens per second over the first→last decode-step window (0.0
    /// before two spread-out steps exist).
    pub fn decode_tokens_per_sec(&self) -> f64 {
        let tokens = self.decode_tokens();
        let clock = self.decode.clock.lock().unwrap();
        match *clock {
            (Some(first), Some(last)) if last > first => {
                tokens as f64 / (last - first).as_secs_f64().max(1e-9)
            }
            (Some(first), _) => tokens as f64 / first.elapsed().as_secs_f64().max(1e-9),
            _ => 0.0,
        }
    }

    /// Streaming-decode aggregates in one view.
    pub fn decode_stats(&self) -> DecodeStats {
        let d = &self.decode;
        let steps = d.steps.load(Ordering::Relaxed);
        let slot_n = d.slot_count.load(Ordering::Relaxed);
        DecodeStats {
            steps,
            tokens: self.decode_tokens(),
            tokens_per_sec: self.decode_tokens_per_sec(),
            mean_active_slots: if slot_n == 0 {
                0.0
            } else {
                d.slot_scaled.load(Ordering::Relaxed) as f64 / (slot_n as f64 * 1e9)
            },
            step_mean_ms: d.step_hist.mean_secs() * 1e3,
            step_p50_ms: if steps > 0 { d.step_hist.percentile(0.50) * 1e3 } else { 0.0 },
            step_p95_ms: if steps > 0 { d.step_hist.percentile(0.95) * 1e3 } else { 0.0 },
        }
    }

    /// Count one failed execute invocation (lock-free).
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// Retune the slow-request exemplar threshold (seconds).
    pub fn set_slow_threshold(&self, secs: f64) {
        self.slow.set_threshold_secs(secs);
    }

    /// Slow-request exemplars retained so far, oldest first.
    pub fn exemplars(&self) -> Vec<TraceExemplar> {
        self.slow.exemplars()
    }

    /// Requests per second over the first→last completion window, so an
    /// idle tail after load stops no longer dilutes the figure.  With
    /// fewer than two spread-out completions there is no window yet and
    /// the old elapsed-to-now behaviour applies.
    pub fn throughput(&self) -> f64 {
        let completed = self.completed();
        let clock = self.clock.lock().unwrap();
        match (clock.first, clock.last) {
            (Some(first), Some(last)) if last > first => {
                completed as f64 / (last - first).as_secs_f64().max(1e-9)
            }
            (Some(first), _) => completed as f64 / first.elapsed().as_secs_f64().max(1e-9),
            _ => 0.0,
        }
    }

    pub fn snapshot(&self) -> Vec<VariantStats> {
        let map = self.variants.read().unwrap();
        let mut out = Vec::new();
        for (variant, m) in map.iter() {
            let count = m.latency.count();
            if count == 0 {
                continue;
            }
            out.push(VariantStats {
                variant: variant.clone(),
                count: count as usize,
                mean_ms: m.latency.mean_secs() * 1e3,
                p50_ms: m.latency.percentile(0.50) * 1e3,
                p95_ms: m.latency.percentile(0.95) * 1e3,
                p99_ms: m.latency.percentile(0.99) * 1e3,
                mean_batch: m.batch_rows.load(Ordering::Relaxed) as f64 / count as f64,
                mean_occupancy: m.mean_occupancy(),
            });
        }
        out.sort_by(|a, b| a.variant.cmp(&b.variant));
        out
    }

    /// Per-variant stage-span aggregates for variants served through the
    /// traced path.
    pub fn stage_stats(&self) -> Vec<VariantStageStats> {
        let map = self.variants.read().unwrap();
        let mut out = Vec::new();
        for (variant, m) in map.iter() {
            if m.stages.iter().all(|h| h.count() == 0) {
                continue;
            }
            let stages = Stage::ALL
                .iter()
                .map(|&stage| {
                    let h = &m.stages[stage.index()];
                    StageStats {
                        stage: stage.label(),
                        count: h.count(),
                        mean_ms: h.mean_secs() * 1e3,
                        p50_ms: h.percentile(0.50) * 1e3,
                        p95_ms: h.percentile(0.95) * 1e3,
                    }
                })
                .collect();
            out.push(VariantStageStats { variant: variant.clone(), stages });
        }
        out.sort_by(|a, b| a.variant.cmp(&b.variant));
        out
    }

    /// Per-variant percentiles plus global counters in one view — the
    /// shape the serve CLI and e2e reports print.
    pub fn full_snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            variants: self.snapshot(),
            completed: self.completed(),
            sheds: self.sheds(),
            errors: self.errors(),
            per_worker: self.per_worker(),
            throughput_rps: self.throughput(),
            batches: self.batches(),
            padded_rows_avoided: self.padded_rows_avoided(),
            stages: self.stage_stats(),
            exemplars: self.exemplars(),
            decode: self.decode_stats(),
        }
    }
}

impl MetricsSnapshot {
    /// Serialize for `serve --telemetry-json` via the in-tree `json`
    /// module (schema in `docs/DESIGN.md` §8).
    pub fn to_json(&self) -> Json {
        let variants: Vec<Json> = self
            .variants
            .iter()
            .map(|v| {
                obj(vec![
                    ("variant", s(&v.variant)),
                    ("count", num(v.count as f64)),
                    ("mean_ms", num(v.mean_ms)),
                    ("p50_ms", num(v.p50_ms)),
                    ("p95_ms", num(v.p95_ms)),
                    ("p99_ms", num(v.p99_ms)),
                    ("mean_batch", num(v.mean_batch)),
                    ("mean_occupancy", num(v.mean_occupancy)),
                ])
            })
            .collect();
        let stages: Vec<Json> = self
            .stages
            .iter()
            .map(|vs| {
                let rows: Vec<Json> = vs
                    .stages
                    .iter()
                    .map(|st| {
                        obj(vec![
                            ("stage", s(st.stage)),
                            ("count", num(st.count as f64)),
                            ("mean_ms", num(st.mean_ms)),
                            ("p50_ms", num(st.p50_ms)),
                            ("p95_ms", num(st.p95_ms)),
                        ])
                    })
                    .collect();
                obj(vec![("variant", s(&vs.variant)), ("stages", arr(rows))])
            })
            .collect();
        let exemplars: Vec<Json> = self
            .exemplars
            .iter()
            .map(|e| {
                obj(vec![
                    ("variant", s(&e.variant)),
                    ("total_ms", num(e.trace.total() * 1e3)),
                    ("queue_ms", num(e.trace.queue * 1e3)),
                    ("assembly_ms", num(e.trace.assembly * 1e3)),
                    ("pack_ms", num(e.trace.pack * 1e3)),
                    ("execute_ms", num(e.trace.execute * 1e3)),
                    ("respond_ms", num(e.trace.respond * 1e3)),
                ])
            })
            .collect();
        let decode = obj(vec![
            ("steps", num(self.decode.steps as f64)),
            ("tokens", num(self.decode.tokens as f64)),
            ("tokens_per_sec", num(self.decode.tokens_per_sec)),
            ("mean_active_slots", num(self.decode.mean_active_slots)),
            ("step_mean_ms", num(self.decode.step_mean_ms)),
            ("step_p50_ms", num(self.decode.step_p50_ms)),
            ("step_p95_ms", num(self.decode.step_p95_ms)),
        ]);
        obj(vec![
            ("completed", num(self.completed as f64)),
            ("sheds", num(self.sheds as f64)),
            ("errors", num(self.errors as f64)),
            ("throughput_rps", num(self.throughput_rps)),
            ("batches", num(self.batches as f64)),
            ("padded_rows_avoided", num(self.padded_rows_avoided as f64)),
            ("per_worker", arr(self.per_worker.iter().map(|&w| num(w as f64)).collect())),
            ("variants", arr(variants)),
            ("stages", arr(stages)),
            ("slow_exemplars", arr(exemplars)),
            ("decode", decode),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn snapshot_percentiles() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record("model_tw", i as f64 / 1000.0, 4);
        }
        let snap = m.snapshot();
        assert_eq!(snap.len(), 1);
        let s = &snap[0];
        assert_eq!(s.count, 100);
        assert!((s.p50_ms - 50.5).abs() < 1.0);
        assert!(s.p99_ms > 98.0);
        assert_eq!(s.mean_batch, 4.0);
    }

    #[test]
    fn multiple_variants_separate() {
        let m = Metrics::default();
        m.record("a", 0.001, 1);
        m.record("b", 0.002, 2);
        let snap = m.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(m.completed(), 2);
    }

    #[test]
    fn sheds_surface_in_full_snapshot() {
        let m = Metrics::default();
        for i in 1..=10 {
            m.record("model_tw", i as f64 / 1000.0, 2);
        }
        for _ in 0..3 {
            m.record_shed();
        }
        let snap = m.full_snapshot();
        assert_eq!(snap.sheds, 3);
        assert_eq!(snap.completed, 10);
        assert_eq!(snap.variants.len(), 1);
        // sheds sit alongside the latency percentiles in one view
        assert!(snap.variants[0].p95_ms > snap.variants[0].p50_ms);
        assert_eq!(m.sheds(), 3);
        assert_eq!(snap.errors, 0);
    }

    #[test]
    fn per_worker_counts_fold_into_snapshot() {
        let m = Metrics::default();
        m.record_for_worker("model_tw", 0.001, 2, 0);
        m.record_for_worker("model_tw", 0.001, 2, 2);
        m.record_for_worker("model_dense", 0.002, 1, 2);
        let snap = m.full_snapshot();
        assert_eq!(snap.per_worker, vec![1, 0, 2]);
        assert_eq!(snap.per_worker.iter().sum::<u64>(), snap.completed);
    }

    #[test]
    fn reserved_workers_show_as_zeros() {
        let m = Metrics::default();
        m.reserve_workers(4);
        assert_eq!(m.per_worker(), vec![0, 0, 0, 0]);
        m.record_for_worker("model_tw", 0.001, 1, 1);
        assert_eq!(m.per_worker(), vec![0, 1, 0, 0]);
        m.reserve_workers(2); // never shrinks
        assert_eq!(m.per_worker().len(), 4);
    }

    #[test]
    fn occupancy_and_padded_rows_avoided_surface() {
        let m = Metrics::default();
        // two dynamic batches at half and full occupancy of B=8
        m.record_batch("model_tw", 4, 8, true);
        m.record_batch("model_tw", 8, 8, true);
        // one padded batch: occupancy recorded, no rows-avoided credit
        m.record_batch("model_dense", 2, 8, false);
        m.record("model_tw", 0.001, 4);
        m.record("model_dense", 0.002, 2);
        let snap = m.full_snapshot();
        assert_eq!(snap.batches, 3);
        assert_eq!(snap.padded_rows_avoided, 4);
        let tw = snap.variants.iter().find(|v| v.variant == "model_tw").unwrap();
        assert!((tw.mean_occupancy - 0.75).abs() < 1e-9, "{}", tw.mean_occupancy);
        let dense = snap.variants.iter().find(|v| v.variant == "model_dense").unwrap();
        assert!((dense.mean_occupancy - 0.25).abs() < 1e-9);
        // occupancy is per batch, not per request: a variant with no
        // record_batch samples reports 0 rather than a skewed mean
        m.record("model_tvw", 0.001, 8);
        let snap2 = m.full_snapshot();
        let tvw = snap2.variants.iter().find(|v| v.variant == "model_tvw").unwrap();
        assert_eq!(tvw.mean_occupancy, 0.0);
    }

    #[test]
    fn errors_surface_in_full_snapshot() {
        let m = Metrics::default();
        m.record("model_tw", 0.001, 1);
        m.record_error();
        m.record_error();
        let snap = m.full_snapshot();
        assert_eq!(snap.errors, 2);
        assert_eq!(snap.completed, 1);
        assert_eq!(m.errors(), 2);
    }

    #[test]
    fn throughput_uses_completion_window_not_idle_tail() {
        let m = Metrics::default();
        m.record("model_tw", 0.001, 1);
        std::thread::sleep(Duration::from_millis(20));
        m.record("model_tw", 0.001, 1);
        let busy = m.throughput();
        // 2 completions ~20ms apart: ~100 rps over the completion window
        assert!(busy > 20.0, "window throughput {busy}");
        std::thread::sleep(Duration::from_millis(120));
        let idle = m.throughput();
        // the idle tail must not dilute the figure (the old elapsed-to-now
        // computation would report ~2/0.14s ≈ 14 rps here)
        assert!((idle - busy).abs() < 1e-9, "idle tail changed throughput: {busy} -> {idle}");
    }

    #[test]
    fn stage_spans_sum_to_end_to_end_latency() {
        let m = Metrics::default();
        let trace = RequestTrace {
            queue: 0.004,
            assembly: 0.001,
            pack: 0.0005,
            execute: 0.010,
            respond: 0.0005,
        };
        m.record("model_tw", trace.total(), 4);
        m.record_trace("model_tw", trace);
        let snap = m.full_snapshot();
        let vs = snap.stages.iter().find(|v| v.variant == "model_tw").expect("stage stats");
        assert_eq!(vs.stages.len(), 5);
        assert_eq!(vs.stages[0].stage, "queue");
        // stage means are exact (nanosecond sums), so their sum reproduces
        // the recorded end-to-end latency
        let sum_ms: f64 = vs.stages.iter().map(|st| st.mean_ms).sum();
        let total_ms = trace.total() * 1e3;
        let drift = (sum_ms - total_ms).abs() / total_ms;
        assert!(drift < 0.01, "stage sum {sum_ms} vs e2e {total_ms}");
        // the variant latency percentile agrees with the trace total
        // within bucket resolution
        let v = snap.variants.iter().find(|v| v.variant == "model_tw").unwrap();
        assert!((v.p50_ms - total_ms).abs() / total_ms < 0.05);
    }

    #[test]
    fn slow_traces_surface_as_exemplars() {
        let m = Metrics::default();
        m.set_slow_threshold(0.005);
        m.record_trace("model_tw", RequestTrace { execute: 0.001, ..Default::default() });
        m.record_trace("model_tw", RequestTrace { execute: 0.050, ..Default::default() });
        let snap = m.full_snapshot();
        assert_eq!(snap.exemplars.len(), 1, "only the slow trace is retained");
        assert_eq!(snap.exemplars[0].variant, "model_tw");
        let json = snap.to_json().to_string();
        assert!(json.contains("slow_exemplars"), "{json}");
        assert!(json.contains("\"stages\""), "{json}");
    }

    #[test]
    fn decode_steps_aggregate_tokens_and_occupancy() {
        let m = Metrics::default();
        let snap0 = m.full_snapshot();
        assert_eq!(snap0.decode.steps, 0);
        assert_eq!(snap0.decode.tokens_per_sec, 0.0);

        // three steps: 4, 4, then 2 resident slots
        m.record_decode_step(0.002, 4, 4);
        std::thread::sleep(Duration::from_millis(15));
        m.record_decode_step(0.002, 4, 4);
        m.record_decode_step(0.001, 2, 2);
        let d = m.decode_stats();
        assert_eq!(d.steps, 3);
        assert_eq!(d.tokens, 10);
        assert!((d.mean_active_slots - 10.0 / 3.0).abs() < 1e-9, "{}", d.mean_active_slots);
        assert!(d.tokens_per_sec > 0.0, "window tokens/s: {}", d.tokens_per_sec);
        assert!(d.step_p95_ms >= d.step_p50_ms);
        let json = m.full_snapshot().to_json().to_string();
        assert!(json.contains("tokens_per_sec"), "{json}");
        assert!(json.contains("mean_active_slots"), "{json}");
    }

    #[test]
    fn snapshot_of_variant_with_counters_but_no_latency_does_not_panic() {
        // regression: util::percentile used to assert on empty input; a
        // variant that only recorded batches (no completions yet) must
        // snapshot cleanly and stay invisible in the variant list
        let m = Metrics::default();
        m.record_batch("model_tw", 4, 8, true);
        let snap = m.full_snapshot();
        assert!(snap.variants.is_empty());
        assert_eq!(snap.batches, 1);
    }
}
