//! Serving metrics: per-variant latency samples + counters, with
//! percentile snapshots for the e2e report.  Backpressure sheds are
//! counted here too, so one snapshot shows latency percentiles *and*
//! how much load the server refused to take.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::{mean, percentile};

#[derive(Default)]
struct Inner {
    /// Per-variant end-to-end latency samples (seconds).
    latency: HashMap<String, Vec<f64>>,
    /// Per-variant batch-size samples.
    batch_sizes: HashMap<String, Vec<f64>>,
    /// Per-variant batch-occupancy samples (`real / B`, one per executed
    /// batch — not per request, so mean occupancy is not skewed toward
    /// full batches).
    occupancy: HashMap<String, Vec<f64>>,
    /// Completions per worker (index = worker id), grown on demand.
    worker_completed: Vec<u64>,
    completed: u64,
    /// Executed batch invocations (the denominator of the occupancy
    /// counters).
    batches: u64,
    /// Padding rows whose compute dynamic-M execution skipped (`B - real`
    /// summed over dynamic batches; 0 under padded execution).
    padded_rows_avoided: u64,
    started_at: Option<Instant>,
}

/// Thread-safe metrics sink shared between the worker pool and clients.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    /// Requests shed by backpressure (outside the mutex: the shed path is
    /// the hot rejection path and must not contend with the executors).
    sheds: AtomicU64,
    /// Execute invocations that failed (one per failed batch; every
    /// request in that batch got an error `Response`).
    errors: AtomicU64,
}

/// Snapshot of one variant's serving statistics.
#[derive(Clone, Debug)]
pub struct VariantStats {
    pub variant: String,
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_batch: f64,
    /// Mean batch occupancy (`real / B`) over this variant's executed
    /// batches — 1.0 means every invocation ran full; lower means
    /// dynamic-M serving skipped padding compute (or, padded, wasted it).
    pub mean_occupancy: f64,
}

/// Whole-server snapshot: per-variant percentiles plus the global
/// counters (completions, backpressure sheds, errors, throughput) and the
/// per-worker completion split.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub variants: Vec<VariantStats>,
    pub completed: u64,
    /// Requests refused by backpressure (`ServerHandle::try_submit`).
    pub sheds: u64,
    /// Failed execute invocations (clients got an error `Response`).
    pub errors: u64,
    /// Completions per worker (index = worker id).
    pub per_worker: Vec<u64>,
    pub throughput_rps: f64,
    /// Executed batch invocations across all variants.
    pub batches: u64,
    /// Padding rows dynamic-M execution never computed (`B - real` summed
    /// over dynamic batches) — the observable win of effective-batch
    /// serving; stays 0 under padded execution.
    pub padded_rows_avoided: u64,
}

impl Metrics {
    /// Pre-size the per-worker counters to the pool size, so idle workers
    /// show up as explicit zeros in snapshots (an idle/stuck worker must
    /// be distinguishable from a nonexistent one).
    pub fn reserve_workers(&self, workers: usize) {
        let mut inner = self.inner.lock().unwrap();
        if inner.worker_completed.len() < workers {
            inner.worker_completed.resize(workers, 0);
        }
    }

    /// Record one completed request served by `worker`.
    pub fn record_for_worker(
        &self,
        variant: &str,
        latency_secs: f64,
        batch_size: usize,
        worker: usize,
    ) {
        let mut inner = self.inner.lock().unwrap();
        if inner.started_at.is_none() {
            inner.started_at = Some(Instant::now());
        }
        inner.latency.entry(variant.to_string()).or_default().push(latency_secs);
        inner.batch_sizes.entry(variant.to_string()).or_default().push(batch_size as f64);
        if inner.worker_completed.len() <= worker {
            inner.worker_completed.resize(worker + 1, 0);
        }
        inner.worker_completed[worker] += 1;
        inner.completed += 1;
    }

    /// Single-executor convenience (worker 0).
    pub fn record(&self, variant: &str, latency_secs: f64, batch_size: usize) {
        self.record_for_worker(variant, latency_secs, batch_size, 0);
    }

    /// Record one executed batch invocation: occupancy sample
    /// (`real / capacity`) for `variant`, plus the padded-rows-avoided
    /// counter when the batch ran on the dynamic effective-batch path.
    pub fn record_batch(&self, variant: &str, real: usize, capacity: usize, dynamic: bool) {
        let mut inner = self.inner.lock().unwrap();
        let occ = real as f64 / capacity.max(1) as f64;
        inner.occupancy.entry(variant.to_string()).or_default().push(occ);
        inner.batches += 1;
        if dynamic {
            inner.padded_rows_avoided += capacity.saturating_sub(real) as u64;
        }
    }

    pub fn batches(&self) -> u64 {
        self.inner.lock().unwrap().batches
    }

    pub fn padded_rows_avoided(&self) -> u64 {
        self.inner.lock().unwrap().padded_rows_avoided
    }

    pub fn completed(&self) -> u64 {
        self.inner.lock().unwrap().completed
    }

    /// Completions per worker (index = worker id).
    pub fn per_worker(&self) -> Vec<u64> {
        self.inner.lock().unwrap().worker_completed.clone()
    }

    /// Count one backpressure shed (lock-free).
    pub fn record_shed(&self) {
        self.sheds.fetch_add(1, Ordering::Relaxed);
    }

    pub fn sheds(&self) -> u64 {
        self.sheds.load(Ordering::Relaxed)
    }

    /// Count one failed execute invocation (lock-free).
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// Requests per second since the first recorded completion.
    pub fn throughput(&self) -> f64 {
        let inner = self.inner.lock().unwrap();
        match inner.started_at {
            Some(t0) => inner.completed as f64 / t0.elapsed().as_secs_f64().max(1e-9),
            None => 0.0,
        }
    }

    pub fn snapshot(&self) -> Vec<VariantStats> {
        let inner = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for (variant, lats) in &inner.latency {
            let mut ms: Vec<f64> = lats.iter().map(|s| s * 1e3).collect();
            let batches = inner.batch_sizes.get(variant).cloned().unwrap_or_default();
            let occ = inner.occupancy.get(variant).cloned().unwrap_or_default();
            out.push(VariantStats {
                variant: variant.clone(),
                count: ms.len(),
                mean_ms: mean(&ms),
                p50_ms: percentile(&mut ms, 0.50),
                p95_ms: percentile(&mut ms, 0.95),
                p99_ms: percentile(&mut ms, 0.99),
                mean_batch: mean(&batches),
                mean_occupancy: mean(&occ),
            });
        }
        out.sort_by(|a, b| a.variant.cmp(&b.variant));
        out
    }

    /// Per-variant percentiles plus global counters in one view — the
    /// shape the serve CLI and e2e reports print.
    pub fn full_snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            variants: self.snapshot(),
            completed: self.completed(),
            sheds: self.sheds(),
            errors: self.errors(),
            per_worker: self.per_worker(),
            throughput_rps: self.throughput(),
            batches: self.batches(),
            padded_rows_avoided: self.padded_rows_avoided(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_percentiles() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record("model_tw", i as f64 / 1000.0, 4);
        }
        let snap = m.snapshot();
        assert_eq!(snap.len(), 1);
        let s = &snap[0];
        assert_eq!(s.count, 100);
        assert!((s.p50_ms - 50.5).abs() < 1.0);
        assert!(s.p99_ms > 98.0);
        assert_eq!(s.mean_batch, 4.0);
    }

    #[test]
    fn multiple_variants_separate() {
        let m = Metrics::default();
        m.record("a", 0.001, 1);
        m.record("b", 0.002, 2);
        let snap = m.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(m.completed(), 2);
    }

    #[test]
    fn sheds_surface_in_full_snapshot() {
        let m = Metrics::default();
        for i in 1..=10 {
            m.record("model_tw", i as f64 / 1000.0, 2);
        }
        for _ in 0..3 {
            m.record_shed();
        }
        let snap = m.full_snapshot();
        assert_eq!(snap.sheds, 3);
        assert_eq!(snap.completed, 10);
        assert_eq!(snap.variants.len(), 1);
        // sheds sit alongside the latency percentiles in one view
        assert!(snap.variants[0].p95_ms > snap.variants[0].p50_ms);
        assert_eq!(m.sheds(), 3);
        assert_eq!(snap.errors, 0);
    }

    #[test]
    fn per_worker_counts_fold_into_snapshot() {
        let m = Metrics::default();
        m.record_for_worker("model_tw", 0.001, 2, 0);
        m.record_for_worker("model_tw", 0.001, 2, 2);
        m.record_for_worker("model_dense", 0.002, 1, 2);
        let snap = m.full_snapshot();
        assert_eq!(snap.per_worker, vec![1, 0, 2]);
        assert_eq!(snap.per_worker.iter().sum::<u64>(), snap.completed);
    }

    #[test]
    fn reserved_workers_show_as_zeros() {
        let m = Metrics::default();
        m.reserve_workers(4);
        assert_eq!(m.per_worker(), vec![0, 0, 0, 0]);
        m.record_for_worker("model_tw", 0.001, 1, 1);
        assert_eq!(m.per_worker(), vec![0, 1, 0, 0]);
        m.reserve_workers(2); // never shrinks
        assert_eq!(m.per_worker().len(), 4);
    }

    #[test]
    fn occupancy_and_padded_rows_avoided_surface() {
        let m = Metrics::default();
        // two dynamic batches at half and full occupancy of B=8
        m.record_batch("model_tw", 4, 8, true);
        m.record_batch("model_tw", 8, 8, true);
        // one padded batch: occupancy recorded, no rows-avoided credit
        m.record_batch("model_dense", 2, 8, false);
        m.record("model_tw", 0.001, 4);
        m.record("model_dense", 0.002, 2);
        let snap = m.full_snapshot();
        assert_eq!(snap.batches, 3);
        assert_eq!(snap.padded_rows_avoided, 4);
        let tw = snap.variants.iter().find(|v| v.variant == "model_tw").unwrap();
        assert!((tw.mean_occupancy - 0.75).abs() < 1e-9, "{}", tw.mean_occupancy);
        let dense = snap.variants.iter().find(|v| v.variant == "model_dense").unwrap();
        assert!((dense.mean_occupancy - 0.25).abs() < 1e-9);
        // occupancy is per batch, not per request: a variant with no
        // record_batch samples reports 0 rather than a skewed mean
        m.record("model_tvw", 0.001, 8);
        let snap2 = m.full_snapshot();
        let tvw = snap2.variants.iter().find(|v| v.variant == "model_tvw").unwrap();
        assert_eq!(tvw.mean_occupancy, 0.0);
    }

    #[test]
    fn errors_surface_in_full_snapshot() {
        let m = Metrics::default();
        m.record("model_tw", 0.001, 1);
        m.record_error();
        m.record_error();
        let snap = m.full_snapshot();
        assert_eq!(snap.errors, 2);
        assert_eq!(snap.completed, 1);
        assert_eq!(m.errors(), 2);
    }
}
