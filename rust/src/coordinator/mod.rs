//! Layer-3 serving coordinator: request router, dynamic batcher,
//! executable registry, metrics — the deployment wrapper that turns the
//! AOT artifacts into a service (vLLM-router-shaped, scaled to this
//! paper's inference-acceleration setting).

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{collect_batch, pack_batch, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot, VariantStats};
pub use request::{Request, Response};
pub use router::{Policy, Router};
pub use server::{start, ServerConfig, ServerHandle};
