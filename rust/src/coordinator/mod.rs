//! Layer-3 serving coordinator: request router, dynamic batcher, worker
//! pool, metrics — the deployment wrapper that turns an execution backend
//! ([`crate::exec`]: PJRT artifacts or the native CPU kernels) into a
//! service (vLLM-router-shaped, scaled to this paper's
//! inference-acceleration setting).
//!
//! The session-oriented API is stream-first: every submission returns a
//! [`ResponseStream`] of [`StreamEvent`]s.  One-shot forwards are a
//! single-`Done` stream ([`ResponseStream::wait`] for the blocking
//! ergonomic); autoregressive decode sessions
//! ([`server::ServerHandle::submit_decode`]) stream one
//! [`StreamEvent::Token`] per step under the continuous-batching step
//! scheduler, then the terminal `Done` (DESIGN.md §10).

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{
    collect_batch, collect_batch_shared, collect_batch_shared_traced, collect_batch_traced,
    pack_batch, BatcherConfig, CollectedBatch,
};
pub use metrics::{DecodeStats, Metrics, MetricsSnapshot, VariantStageStats, VariantStats};
pub use request::{Request, Response, ResponseStream, StreamEvent, TokenEvent};
pub use router::{Policy, Router};
pub use server::{
    start, start_with_backend, ServerConfig, ServerConfigBuilder, ServerHandle,
};
