//! Variant router: which executable serves a batch.
//!
//! The TW/TVW artifacts trade accuracy for latency; the router lets the
//! deployment pick a policy: a fixed variant, round-robin (for A/B
//! latency comparisons, as the e2e example does), or load-adaptive —
//! serve dense while the queue is short, shed to the sparse variant under
//! pressure (the paper's motivation: sparse models buy latency headroom).

use super::request::Request;
use crate::autotune::PlanCache;

#[derive(Clone, Debug)]
pub enum Policy {
    /// Always this variant.
    Fixed(String),
    /// Rotate over variants per batch.
    RoundRobin(Vec<String>),
    /// Dense until queue depth exceeds the threshold, then sparse.
    Adaptive { dense: String, sparse: String, queue_threshold: usize },
    /// Serve whatever the autotuner's plan cache recommends for `model`
    /// (`cache.model_variant(model)`), or `fallback` when the cache has no
    /// recommendation.  Resolved once at server startup via [`Policy::resolve`].
    Tuned { model: String, fallback: String },
}

impl Policy {
    /// Collapse a `Tuned` policy to the concrete `Fixed` variant the plan
    /// cache recommends; every other policy passes through unchanged.
    pub fn resolve(self, cache: Option<&PlanCache>) -> Policy {
        match self {
            Policy::Tuned { model, fallback } => match cache.and_then(|c| c.model_variant(&model)) {
                Some(variant) => Policy::Fixed(variant.to_string()),
                None => {
                    eprintln!(
                        "[router] no tuned recommendation for {model:?} \
                         (cache {}); serving fallback {fallback:?}",
                        if cache.is_some() { "loaded" } else { "absent" }
                    );
                    Policy::Fixed(fallback)
                }
            },
            other => other,
        }
    }
}

pub struct Router {
    policy: Policy,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: Policy) -> Router {
        Router { policy, rr_next: 0 }
    }

    /// Pick the executable for a batch.  A request's explicit variant
    /// preference (first in the batch that has one) wins over the policy.
    pub fn route(&mut self, batch: &[Request], queue_depth: usize) -> String {
        if let Some(v) = batch.iter().find_map(|r| r.variant.clone()) {
            return v;
        }
        match &self.policy {
            Policy::Fixed(v) => v.clone(),
            Policy::RoundRobin(vs) => {
                let v = vs[self.rr_next % vs.len()].clone();
                self.rr_next += 1;
                v
            }
            Policy::Adaptive { dense, sparse, queue_threshold } => {
                if queue_depth > *queue_threshold {
                    sparse.clone()
                } else {
                    dense.clone()
                }
            }
            // an unresolved Tuned policy behaves like its fallback
            Policy::Tuned { fallback, .. } => fallback.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(variant: Option<&str>) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id: 0,
            activation: vec![],
            variant: variant.map(String::from),
            submitted: Instant::now(),
            respond_to: tx,
        }
    }

    #[test]
    fn fixed_policy() {
        let mut r = Router::new(Policy::Fixed("model_tw".into()));
        assert_eq!(r.route(&[req(None)], 0), "model_tw");
    }

    #[test]
    fn round_robin_rotates() {
        let mut r = Router::new(Policy::RoundRobin(vec!["a".into(), "b".into()]));
        assert_eq!(r.route(&[req(None)], 0), "a");
        assert_eq!(r.route(&[req(None)], 0), "b");
        assert_eq!(r.route(&[req(None)], 0), "a");
    }

    #[test]
    fn adaptive_sheds_under_load() {
        let mut r = Router::new(Policy::Adaptive {
            dense: "model_dense".into(),
            sparse: "model_tvw".into(),
            queue_threshold: 4,
        });
        assert_eq!(r.route(&[req(None)], 0), "model_dense");
        assert_eq!(r.route(&[req(None)], 10), "model_tvw");
    }

    #[test]
    fn explicit_preference_wins() {
        let mut r = Router::new(Policy::Fixed("model_dense".into()));
        assert_eq!(r.route(&[req(None), req(Some("model_tvw"))], 0), "model_tvw");
    }

    #[test]
    fn tuned_policy_resolves_against_cache() {
        let mut cache = PlanCache::new();
        cache.set_model_variant("bert", "model_tw");
        let tuned = Policy::Tuned { model: "bert".into(), fallback: "model_dense".into() };
        match tuned.clone().resolve(Some(&cache)) {
            Policy::Fixed(v) => assert_eq!(v, "model_tw"),
            other => panic!("expected Fixed, got {other:?}"),
        }
        // no cache -> fallback; unknown model -> fallback
        match tuned.clone().resolve(None) {
            Policy::Fixed(v) => assert_eq!(v, "model_dense"),
            other => panic!("expected Fixed, got {other:?}"),
        }
        let other_model =
            Policy::Tuned { model: "vgg16".into(), fallback: "model_dense".into() };
        match other_model.resolve(Some(&cache)) {
            Policy::Fixed(v) => assert_eq!(v, "model_dense"),
            other => panic!("expected Fixed, got {other:?}"),
        }
        // unresolved Tuned routes to its fallback
        let mut r = Router::new(tuned);
        assert_eq!(r.route(&[req(None)], 0), "model_dense");
    }
}
