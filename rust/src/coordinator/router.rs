//! Variant router: which executable serves a batch.
//!
//! The TW/TVW artifacts trade accuracy for latency; the router lets the
//! deployment pick a policy: a fixed variant, round-robin (for A/B
//! latency comparisons, as the e2e example does), or load-adaptive —
//! serve dense while the queue is short, shed to the sparse variant under
//! pressure (the paper's motivation: sparse models buy latency headroom).
//!
//! Policies are over the typed [`Variant`] enum, not strings: a policy
//! that routes to a nonexistent variant is unrepresentable, and every
//! match over patterns is checked for exhaustiveness at compile time.
//! The string form only appears at the [`crate::exec::PreparedModel`]
//! seam (`Variant::name`).

use super::request::Request;
use crate::autotune::PlanCache;
use crate::variant::Variant;

#[derive(Clone, Debug)]
pub enum Policy {
    /// Always this variant.
    Fixed(Variant),
    /// Rotate over variants per batch.
    RoundRobin(Vec<Variant>),
    /// Dense until queue depth exceeds the threshold, then sparse.
    Adaptive { dense: Variant, sparse: Variant, queue_threshold: usize },
    /// Serve whatever the autotuner's plan cache recommends for `model`
    /// (`cache.model_variant(model)`), or `fallback` when the cache has no
    /// recommendation.  Resolved once at server startup via [`Policy::resolve`].
    Tuned { model: String, fallback: Variant },
}

impl Policy {
    /// Collapse a `Tuned` policy to the concrete `Fixed` variant the plan
    /// cache recommends; every other policy passes through unchanged.
    /// A recommendation that fails to parse as a [`Variant`] falls back
    /// like a missing one (the cache file is external input).
    pub fn resolve(self, cache: Option<&PlanCache>) -> Policy {
        match self {
            Policy::Tuned { model, fallback } => {
                match cache.and_then(|c| c.model_variant(&model)).and_then(|v| v.parse().ok()) {
                    Some(variant) => Policy::Fixed(variant),
                    None => {
                        eprintln!(
                            "[router] no tuned recommendation for {model:?} \
                             (cache {}); serving fallback {fallback}",
                            if cache.is_some() { "loaded" } else { "absent" }
                        );
                        Policy::Fixed(fallback)
                    }
                }
            }
            other => other,
        }
    }
}

pub struct Router {
    policy: Policy,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: Policy) -> Router {
        Router { policy, rr_next: 0 }
    }

    /// Pick the executable for a batch.  A request's explicit variant
    /// preference (first in the batch that has one) wins over the policy.
    pub fn route(&mut self, batch: &[Request], queue_depth: usize) -> Variant {
        if let Some(v) = batch.iter().find_map(|r| r.variant) {
            return v;
        }
        self.route_policy(queue_depth)
    }

    /// Policy-only routing (no per-request preferences) — the decode
    /// step-scheduler uses this to pick the variant a joining session is
    /// admitted under when the request states no preference.
    pub fn route_policy(&mut self, queue_depth: usize) -> Variant {
        match &self.policy {
            Policy::Fixed(v) => *v,
            Policy::RoundRobin(vs) => {
                let v = vs[self.rr_next % vs.len()];
                self.rr_next += 1;
                v
            }
            Policy::Adaptive { dense, sparse, queue_threshold } => {
                if queue_depth > *queue_threshold {
                    *sparse
                } else {
                    *dense
                }
            }
            // an unresolved Tuned policy behaves like its fallback
            Policy::Tuned { fallback, .. } => *fallback,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ResponseStream;
    use std::time::Instant;

    fn req(variant: Option<Variant>) -> Request {
        let (tx, _rx) = ResponseStream::channel();
        Request {
            id: 0,
            activation: vec![],
            variant,
            decode_steps: 0,
            submitted: Instant::now(),
            events: tx,
        }
    }

    #[test]
    fn fixed_policy() {
        let mut r = Router::new(Policy::Fixed(Variant::Tw));
        assert_eq!(r.route(&[req(None)], 0), Variant::Tw);
    }

    #[test]
    fn round_robin_rotates() {
        let mut r = Router::new(Policy::RoundRobin(vec![Variant::Dense, Variant::Tvw]));
        assert_eq!(r.route(&[req(None)], 0), Variant::Dense);
        assert_eq!(r.route(&[req(None)], 0), Variant::Tvw);
        assert_eq!(r.route(&[req(None)], 0), Variant::Dense);
    }

    #[test]
    fn adaptive_sheds_under_load() {
        let mut r = Router::new(Policy::Adaptive {
            dense: Variant::Dense,
            sparse: Variant::Tvw,
            queue_threshold: 4,
        });
        assert_eq!(r.route(&[req(None)], 0), Variant::Dense);
        assert_eq!(r.route(&[req(None)], 10), Variant::Tvw);
    }

    #[test]
    fn explicit_preference_wins() {
        let mut r = Router::new(Policy::Fixed(Variant::Dense));
        assert_eq!(r.route(&[req(None), req(Some(Variant::Tvw))], 0), Variant::Tvw);
    }

    #[test]
    fn tuned_policy_resolves_against_cache() {
        let mut cache = PlanCache::new();
        cache.set_model_variant("bert", "model_tw");
        let tuned = Policy::Tuned { model: "bert".into(), fallback: Variant::Dense };
        match tuned.clone().resolve(Some(&cache)) {
            Policy::Fixed(v) => assert_eq!(v, Variant::Tw),
            other => panic!("expected Fixed, got {other:?}"),
        }
        // no cache -> fallback; unknown model -> fallback
        match tuned.clone().resolve(None) {
            Policy::Fixed(v) => assert_eq!(v, Variant::Dense),
            other => panic!("expected Fixed, got {other:?}"),
        }
        let other_model = Policy::Tuned { model: "vgg16".into(), fallback: Variant::Dense };
        match other_model.resolve(Some(&cache)) {
            Policy::Fixed(v) => assert_eq!(v, Variant::Dense),
            other => panic!("expected Fixed, got {other:?}"),
        }
        // unresolved Tuned routes to its fallback
        let mut r = Router::new(tuned);
        assert_eq!(r.route(&[req(None)], 0), Variant::Dense);
    }

    #[test]
    fn unparseable_recommendation_falls_back() {
        let mut cache = PlanCache::new();
        cache.set_model_variant("bert", "model_bogus");
        let tuned = Policy::Tuned { model: "bert".into(), fallback: Variant::Tw };
        match tuned.resolve(Some(&cache)) {
            Policy::Fixed(v) => assert_eq!(v, Variant::Tw),
            other => panic!("expected Fixed, got {other:?}"),
        }
    }
}
