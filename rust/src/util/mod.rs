//! Small self-contained utilities: deterministic PRNG, timing, stats.
//!
//! The offline crate registry carries only the `xla` closure, so the usual
//! suspects (`rand`, `criterion`'s stats, ...) are implemented here.

/// SplitMix64 PRNG — tiny, fast, deterministic, good enough for weight
/// initialisation and workload generation (not cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-300 {
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(1e-300).ln() / lambda
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Wall-clock stopwatch returning seconds.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn micros(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e6
    }
}

/// Percentile of a sample (linear interpolation); `q` in [0, 1].
/// Total: an empty sample yields 0.0 instead of panicking, so callers
/// snapshotting counters-but-no-samples state never abort.
pub fn percentile(samples: &mut [f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (samples.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        samples[lo]
    } else {
        samples[lo] + (samples[hi] - samples[lo]) * (pos - lo as f64)
    }
}

/// Arithmetic mean.
pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Geometric mean (for speedup aggregation, as the paper's averages).
pub fn geomean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    (samples.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / samples.len() as f64).exp()
}

/// argsort descending by key, stable.
pub fn argsort_desc_by<F: Fn(usize) -> f64>(n: usize, key: F) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| key(b).partial_cmp(&key(a)).unwrap_or(std::cmp::Ordering::Equal));
    idx
}

/// Round `x` up to a multiple of `m`.
pub const fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Ceiling division.
pub const fn ceil_div(x: usize, m: usize) -> usize {
    x.div_ceil(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let m = mean(&xs);
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn percentile_endpoints() {
        let mut xs = vec![3.0, 1.0, 2.0];
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut xs, 1.0), 3.0);
        assert_eq!(percentile(&mut xs, 0.5), 2.0);
    }

    #[test]
    fn percentile_of_empty_is_zero() {
        let mut xs: Vec<f64> = vec![];
        assert_eq!(percentile(&mut xs, 0.0), 0.0);
        assert_eq!(percentile(&mut xs, 0.5), 0.0);
        assert_eq!(percentile(&mut xs, 1.0), 0.0);
    }

    #[test]
    fn percentile_of_singleton() {
        let mut xs = vec![7.5];
        assert_eq!(percentile(&mut xs, 0.0), 7.5);
        assert_eq!(percentile(&mut xs, 0.99), 7.5);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn round_helpers() {
        assert_eq!(round_up(5, 4), 8);
        assert_eq!(round_up(8, 4), 8);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(1);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
