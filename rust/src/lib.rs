//! # tilewise
//!
//! A full-system reproduction of *"Accelerating Sparse DNNs Based on Tiled
//! GEMM"* (Guo et al., 2024): the tile-wise (TW), tile-element-wise (TEW)
//! and tile-vector-wise (TVW) sparsity patterns, the multi-stage global
//! pruning algorithm, the condensed/CTO GEMM execution machinery, and the
//! serving runtime that runs AOT-compiled JAX/Pallas artifacts through
//! PJRT — Python never on the request path.
//!
//! Layer map (see DESIGN.md):
//! - [`sparse`] — the six sparsity patterns, CTO plans, CSR/CSC, stats
//! - [`pruner`] — Algorithm 1 multi-stage schedule + global budget
//! - [`gemm`] — CPU GEMM hot paths (dense, TW fused-CTO, 2:4, TVW, SpMM),
//!   parameterised by [`gemm::TileConfig`] cache-blocking
//! - [`pool`] — persistent work-chunking thread pool: every parallel
//!   kernel path runs on it (no per-call thread spawns); serving workers
//!   share an intra-op instance, benches/autotune use the global one
//! - [`gpusim`] — A100-class analytical latency simulator
//! - [`autotune`] — empirical kernel autotuner: candidate space, gpusim
//!   pre-filter, wall-clock measurement, persistent plan cache
//! - [`models`] — model zoo: per-layer GEMM workloads (BERT, VGG, ResNet,
//!   NMT), each layer carrying its operator provenance (`LayerKind`)
//! - [`nn`] — executable operators (attention, img2col conv, LSTM cell)
//!   with workspace-buffered `_into` cores + closure-based shims
//! - [`graph`] — layer-graph execution IR (DESIGN.md §6): compile a zoo
//!   workload into an op list over packed per-layer weights
//!   (dense/TW/TVW/2:4) and run it allocation-free over a workspace arena
//! - [`accuracy`] — trainable proxy + calibrated surrogate accuracy models
//! - [`runtime`] — PJRT engine: load HLO-text artifacts, execute
//!   (stubbed unless the `pjrt` feature supplies the `xla` crate)
//! - [`exec`] — backend-agnostic execution layer: the `Backend` /
//!   `PreparedModel` seam, with the PJRT adapter and the graph-compiled
//!   native/zoo backends that run the CPU kernels in-process
//! - [`coordinator`] — serving layer: router, dynamic batcher, worker
//!   pool, metrics, tuned-plan routing
//! - [`telemetry`] — lock-free log-scale latency histograms,
//!   request-stage tracing (queue → assembly → pack → execute →
//!   respond) with slow-request exemplars, and per-GEMM-node graph
//!   profiling for Fig. 10-style time attribution
//! - [`figures`] — regeneration harnesses for every paper figure
//! - [`error`] — in-tree `anyhow`-subset error type (offline registry)

pub mod accuracy;
pub mod autotune;
pub mod coordinator;
pub mod error;
pub mod exec;
pub mod figures;
pub mod gemm;
pub mod graph;
pub mod gpusim;
pub mod json;
pub mod models;
pub mod nn;
pub mod pool;
pub mod pruner;
pub mod quant;
pub mod runtime;
pub mod sparse;
pub mod telemetry;
pub mod tensor;
pub mod util;
pub mod variant;
